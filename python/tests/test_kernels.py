"""L1 kernel correctness: Pallas (interpret) vs pure-jnp oracles.

Hypothesis sweeps shapes and value ranges; every kernel must match its
``ref.py`` oracle to float32 tolerance. This is the CORE correctness
signal for the compute layer — the AOT artifacts embed exactly these
computations.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import dorefa, qmatmul, ref, roundclamp

jax.config.update("jax_platform_name", "cpu")

SETTINGS = dict(max_examples=25, deadline=None)


def _uniform(key, shape, lo=0.0, hi=1.0):
    return jax.random.uniform(jax.random.PRNGKey(key), shape, minval=lo, maxval=hi)


# ---------------------------------------------------------------------------
# roundclamp fused kernel
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    rows=st.integers(1, 300),
    cols=st.integers(1, 300),
    n=st.integers(2, 8),
    k=st.integers(1, 2),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_qlsb_matches_ref(rows, cols, n, k, seed):
    w = _uniform(seed, (rows, cols))
    q, b = roundclamp.fused_qlsb(w, float(n), float(k))
    qr, br = ref.fused_qlsb_ref(w, float(n), float(k))
    np.testing.assert_allclose(q, qr, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(b, br, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("n", [2, 3, 4, 6, 8])
def test_roundclamp_range(n):
    w = _uniform(0, (64, 64))
    q, _ = roundclamp.fused_qlsb(w, float(n), 1.0)
    assert float(jnp.min(q)) >= 0.0
    assert float(jnp.max(q)) <= 1.0
    # values land on the 1/(2^n - 1) lattice
    codes = np.asarray(q) * (2**n - 1)
    np.testing.assert_allclose(codes, np.round(codes), atol=1e-4)


def test_lsb_zero_on_lsbzero_bin_centres():
    """B_k vanishes exactly at the centres of the LSB-zero n-bit bins,
    w = j / 2^{n-k} (whose n-bit RoundClamp code is exactly 2^k * j)."""
    n, k = 4, 1
    j = jnp.arange(2 ** (n - k), dtype=jnp.float32)
    w = jnp.tile(j / (2.0 ** (n - k)), (8, 1))
    _, b = roundclamp.fused_qlsb(w, float(n), float(k))
    np.testing.assert_allclose(b, 0.0, atol=1e-6)
    # and those centres indeed have zero LSBs under the n-bit code
    nz = ref.lsb_nonzero_ref(w, float(n), float(k))
    np.testing.assert_allclose(nz, 0.0)


def test_lsb_sign_points_to_nearest_lsbzero_bin():
    """sign(B_k) is the descent direction onto the LSB-zero bins.

    n=3, k=1: targets are {0, 1/4, 1/2, 3/4}; basin boundaries sit at the
    midpoints of the odd n-bit bins (paper Fig. 3b): (j+0.5)/4 = 3/8, ...
    """
    n, k = 3, 1
    w = jnp.array([[0.22, 0.28, 0.45, 0.55]], dtype=jnp.float32)
    _, b = roundclamp.fused_qlsb(w, float(n), float(k))
    b = np.asarray(b)[0]
    # 0.22 < 1/4 < 0.28 (both inside basin j=1: [0.125, 0.375))
    assert b[0] < 0 and b[1] > 0
    # 0.45 < 1/2 < 0.55 (both inside basin j=2: [0.375, 0.625))
    assert b[2] < 0 and b[3] > 0


def test_lsb_basin_boundaries_at_odd_bin_midpoints():
    """Fig. 3b property: the MSB-code switch happens at the midpoint of the
    n-bit bins with nonzero LSBs, so odd codes can round up OR down."""
    n, k = 3, 1
    eps = 1e-3
    # n-bit code 3's bin is [2.5/8, 3.5/8); its midpoint is 3/8.
    lo = jnp.array([[3.0 / 8.0 - eps]], dtype=jnp.float32)
    hi = jnp.array([[3.0 / 8.0 + eps]], dtype=jnp.float32)
    _, b_lo = roundclamp.fused_qlsb(lo, float(n), float(k))
    _, b_hi = roundclamp.fused_qlsb(hi, float(n), float(k))
    # below the midpoint: target 1/4 (B>0, descend); above: target 1/2 (B<0)
    assert float(b_lo[0, 0]) > 0.0
    assert float(b_hi[0, 0]) < 0.0


# ---------------------------------------------------------------------------
# dorefa kernel
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    rows=st.integers(1, 300),
    cols=st.integers(1, 300),
    n=st.integers(2, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_dorefa_matches_ref(rows, cols, n, seed):
    w = _uniform(seed, (rows, cols))
    q = dorefa.dorefa_quant(w, float(n))
    np.testing.assert_allclose(q, ref.dorefa_ref(w, float(n)), rtol=1e-6, atol=1e-6)


def test_dorefa_bin_misalignment_vs_roundclamp():
    """Fig. 3a vs 3b: under RoundClamp, every weight whose n-bit code has
    zero LSBs also has B_k == 0 (codes align across precisions); under
    DoReFa some LSB-zero codes still carry nonzero B_k (misaligned bins).
    """
    import sys

    sys.path.insert(0, __file__.rsplit("/tests", 1)[0])
    from compile import quant

    n, k = 3.0, 1.0
    w = jnp.linspace(0.0, 1.0, 2001).reshape(1, -1)
    ln = 2.0**n
    # --- RoundClamp: targets are LSB-zero bin centres, so inside every
    # LSB-zero bin |B_k| <= half a bin width.
    code_rc = np.minimum(np.round(ln * np.asarray(w)), ln - 1.0)
    zero_rc = (code_rc % 2.0**k) == 0
    _, b_rc = roundclamp.fused_qlsb(w, n, k)
    assert (np.abs(np.asarray(b_rc))[zero_rc] <= 0.5 / ln + 1e-6).all()
    # --- DoReFa: on a macroscopic fraction of its *LSB-zero* codes the
    # regularizer target lies outside the bin (|B| > half width) — the
    # paper's "even has a gradient for 110, which should not exist".
    code_df = np.round((ln - 1.0) * np.asarray(w))
    zero_df = (code_df % 2.0**k) == 0
    b_df = np.abs(np.asarray(quant.lsb_proxy(w, n, k, "dorefa")))
    frac_bad = (b_df[zero_df] > 0.5 / (ln - 1.0) + 1e-6).mean()
    assert frac_bad > 0.10
    # --- and RoundClamp's descent is balanced on the interior nonzero-LSB
    # bins (codes 1,3,5 — excluding the clamped top bin), while DoReFa's is
    # biased negative ("induce the value of W to be constantly smaller").
    interior_rc = (code_rc % 2.0**k != 0) & (code_rc < ln - 1.0)
    s_rc = np.sign(np.asarray(b_rc))[interior_rc]
    assert abs(s_rc.mean()) < 0.1
    interior_df = (code_df % 2.0**k != 0) & (code_df < ln - 1.0)
    s_df = np.sign(np.asarray(quant.lsb_proxy(w, n, k, "dorefa")))[interior_df]
    assert s_df.mean() > 0.3


# ---------------------------------------------------------------------------
# qmatmul kernel
# ---------------------------------------------------------------------------


@settings(max_examples=12, deadline=None)
@given(
    m=st.integers(1, 200),
    k=st.integers(1, 300),
    n_out=st.integers(1, 200),
    bits=st.integers(2, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_qmatmul_matches_ref(m, k, n_out, bits, seed):
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (m, k))
    w = jax.random.normal(kw, (k, n_out)) * 0.4
    o = qmatmul.qmatmul(x, w, 1.0, float(bits))
    orf = ref.qmatmul_ref(x, w, 1.0, float(bits))
    np.testing.assert_allclose(o, orf, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("shape", [(128, 128, 128), (256, 384, 128), (130, 257, 190)])
def test_qmatmul_tile_boundaries(shape):
    m, k, n_out = shape
    kx, kw = jax.random.split(jax.random.PRNGKey(7))
    x = jax.random.normal(kx, (m, k))
    w = jax.random.normal(kw, (k, n_out)) * 0.3
    np.testing.assert_allclose(
        qmatmul.qmatmul(x, w, 0.9, 4.0),
        ref.qmatmul_ref(x, w, 0.9, 4.0),
        rtol=2e-4,
        atol=2e-4,
    )


def test_qmatmul_high_bits_approaches_fp():
    """At 8 bits the fake-quant error is small; the product should be close
    to the unquantized matmul (sanity on scale handling)."""
    kx, kw = jax.random.split(jax.random.PRNGKey(9))
    x = jax.random.normal(kx, (64, 128))
    w = jax.random.normal(kw, (128, 64)) * 0.25
    o = qmatmul.qmatmul(x, w, 1.0, 8.0)
    fp = x @ w
    err = float(jnp.max(jnp.abs(o - fp)) / (jnp.max(jnp.abs(fp)) + 1e-9))
    assert err < 0.05


def test_vmem_budgets():
    """TPU VMEM budget assertions from DESIGN.md §Hardware-Adaptation."""
    assert qmatmul.vmem_bytes() <= 512 * 1024
    assert roundclamp.vmem_bytes() <= 2 * 1024 * 1024
