"""Model zoo (L2) tests: shapes, parameter registration stability,
q-layer counts, and method-variant parameter accounting.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import models as M
from compile import nn
from compile import train as T

jax.config.update("jax_platform_name", "cpu")


def _forward(model_name, method="msq", batch=2):
    m = M.get_model(model_name)
    rec = T.record(model_name, method)
    trainable = [jnp.asarray(v) for v in rec.init_values]
    consts = [jnp.asarray(v) for v in rec.init_consts]
    lq = len(rec.qlayers)
    ctx = nn.Ctx(
        mode="eval",
        method=method,
        params=trainable,
        consts=consts,
        bits=jnp.full((lq,), 8.0),
        ks=jnp.ones((lq,)),
        n_act=jnp.asarray(0.0),
        temp=jnp.asarray(1.0),
    )
    x = jnp.zeros((batch,) + tuple(m["image"]), jnp.float32)
    return m["fn"](ctx, x), m, rec


SMALL_MODELS = ["mlp", "resnet20", "vit_t"]
ALL_MODELS = ["mlp", "resnet20", "resnet18s", "resnet50s", "mbv3s", "vit_t", "vit_s", "swinlite"]


@pytest.mark.parametrize("name", ALL_MODELS)
def test_logit_shapes(name):
    logits, m, _ = _forward(name)
    assert logits.shape == (2, m["classes"])
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("name", ALL_MODELS)
def test_registration_deterministic(name):
    a = T.record(name, "msq")
    b = T.record(name, "msq")
    assert [s.name for s in a.specs] == [s.name for s in b.specs]
    assert [s.shape for s in a.specs] == [s.shape for s in b.specs]
    for va, vb in zip(a.init_values, b.init_values):
        np.testing.assert_array_equal(va, vb)


def test_resnet20_has_paper_layer_count():
    rec = T.record("resnet20", "msq")
    # 19 convs + fc = 20 quantized layers, 0.27M trainable params
    assert len(rec.qlayers) == 20
    total = sum(s.numel() for s in rec.specs if s.trainable)
    assert 0.25e6 < total < 0.30e6, total


def test_bitsplit_param_multiplication():
    msq = T.record("resnet20", "msq")
    bsq = T.record("resnet20", "bsq")
    csq = T.record("resnet20", "csq")
    p_msq = sum(s.numel() for s in msq.specs if s.trainable)
    p_bsq = sum(s.numel() for s in bsq.specs if s.trainable)
    p_csq = sum(s.numel() for s in csq.specs if s.trainable)
    assert 7.5 < p_bsq / p_msq < 8.5
    assert p_csq >= p_bsq


def test_bsq_weight_reconstruction_matches_float_init():
    """At full precision (all 8 planes active) the bit-split reconstruction
    approximates the float init within one LSB of the plane decomposition."""
    rec = T.record("mlp", "bsq")
    trainable = [jnp.asarray(v) for v in rec.init_values]
    consts = [jnp.asarray(v) for v in rec.init_consts]
    lq = len(rec.qlayers)
    ctx = nn.Ctx(
        mode="eval", method="bsq", params=trainable, consts=consts,
        bits=jnp.full((lq,), 8.0), ks=jnp.ones((lq,)),
        n_act=None, temp=jnp.asarray(1.0),
    )
    w_eff = ctx.qweight("probe", rec.qlayers[0].shape, fan_in=10)
    # the recorded float init for the same layer comes from a fresh record
    rec_f = T.record("mlp", "msq")
    w0 = rec_f.init_values[0]
    err = np.abs(np.asarray(w_eff) - w0).max()
    lsb = np.abs(w0).max() * 2.0 ** -8 * 2
    assert err < max(lsb * 4, 2e-2), (err, lsb)


@pytest.mark.parametrize("name", SMALL_MODELS)
def test_stats_builder_outputs(name):
    fn, specs, meta = T.build_stats(name, "msq")
    out = fn(*[jnp.zeros(s.shape, s.dtype) for s in specs])
    beta, qerr, reg = out
    lq = meta["num_q_layers"]
    assert beta.shape == (lq,) and qerr.shape == (lq,) and reg.shape == (lq,)


def test_hessian_vhv_positive_for_convex_head():
    """On a model reduced to (almost) a linear softmax classifier, vᵀHv of
    the CE loss must be non-negative for any probe."""
    fn, specs, meta = T.build_hessian("mlp", batch=8)
    rec = T.record("mlp", "msq")
    params = [jnp.asarray(v) for v in rec.init_values]
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(*specs[-3].shape).astype(np.float32))
    y = jnp.asarray(rng.randint(0, 10, specs[-2].shape).astype(np.int32))
    vhv = fn(*params, x, y, jnp.asarray(3, jnp.int32))[0]
    assert np.isfinite(np.asarray(vhv)).all()


def test_activation_quant_changes_logits():
    l_fp, _, _ = _forward("resnet20")
    m = M.get_model("resnet20")
    rec = T.record("resnet20", "msq")
    trainable = [jnp.asarray(v) for v in rec.init_values]
    lq = len(rec.qlayers)
    x = jax.random.normal(jax.random.PRNGKey(0), (2,) + tuple(m["image"]))
    ctx_fp = nn.Ctx(mode="eval", method="msq", params=trainable, consts=[],
                    bits=jnp.full((lq,), 8.0), ks=jnp.ones((lq,)),
                    n_act=jnp.asarray(0.0), temp=None)
    l_fp = m["fn"](ctx_fp, x)
    ctx = nn.Ctx(mode="eval", method="msq", params=trainable, consts=[],
                 bits=jnp.full((lq,), 8.0), ks=jnp.ones((lq,)),
                 n_act=jnp.asarray(2.0), temp=None)
    l_a2 = m["fn"](ctx, x)
    assert not np.allclose(np.asarray(l_fp), np.asarray(l_a2))
