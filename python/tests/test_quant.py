"""Quantizer library (L2 `quant.py`) tests: STE gradients, bipartite
slicing, regularizer gradient identity (paper Eq. 7), activation quant.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import quant

jax.config.update("jax_platform_name", "cpu")


def test_ste_round_gradient_is_identity():
    g = jax.grad(lambda x: jnp.sum(quant.ste_round(x) * 3.0))(jnp.array([0.2, 1.7]))
    np.testing.assert_allclose(g, [3.0, 3.0])


@settings(max_examples=30, deadline=None)
@given(n=st.integers(2, 8), seed=st.integers(0, 10_000))
def test_quantize01_in_range_and_on_lattice(n, seed):
    w = jax.random.uniform(jax.random.PRNGKey(seed), (64,))
    for qname in ("roundclamp", "dorefa"):
        q = quant.quantize01(w, float(n), qname)
        assert float(jnp.min(q)) >= 0.0 and float(jnp.max(q)) <= 1.0
        codes = np.asarray(q) * (2**n - 1)
        np.testing.assert_allclose(codes, np.round(codes), atol=1e-4)


def test_lsb_l1_gradient_is_sign(paper_eq7_tol=1e-6):
    """d(Σ|B_k|)/dW must be exactly sign(B_k)/(2s) (Eq. 7, chain through
    the [0,1] mapping)."""
    w = jnp.array([0.1, -0.2, 0.31, 0.07])
    scale = 1.0

    def reg(w):
        return quant.lsb_l1(w, scale, 8.0, 1.0)

    g = jax.grad(reg)(w)
    w01 = quant.to_unit(w, scale)
    b = quant.lsb_proxy(w01, 8.0, 1.0)
    expect = jnp.sign(b) / (2.0 * scale)
    np.testing.assert_allclose(g, expect, atol=paper_eq7_tol)


def test_fake_quant_ste_gradient_passes_through():
    w = jnp.linspace(-0.4, 0.4, 9)

    def f(w):
        return jnp.sum(quant.fake_quant(w, 0.5, 4.0) * 2.0)

    g = jax.grad(f)(w)
    # inside the clip range the STE passes the gradient through, up to
    # RoundClamp's inherent 2^n/(2^n - 1) scale (the quantizer multiplies
    # by 2^n but normalizes by 2^n - 1; -> 1 as n grows)
    np.testing.assert_allclose(g, 2.0 * 16.0 / 15.0, atol=1e-5)


def test_fake_quant_clipped_region_masks_gradient():
    w = jnp.array([-5.0, 5.0])  # far outside 2*scale
    g = jax.grad(lambda w: jnp.sum(quant.fake_quant(w, 0.5, 4.0)))(w)
    np.testing.assert_allclose(g, 0.0, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(2, 8), k=st.integers(1, 2), seed=st.integers(0, 9999))
def test_lsb_nonzero_rate_falls_when_snapped(n, k, seed):
    """Snapping weights onto the LSB-zero bin centres must zero β."""
    if n - k < 1:
        return
    m = n - k
    w01 = jax.random.uniform(jax.random.PRNGKey(seed), (256,))
    snapped = jnp.minimum(jnp.round(w01 * 2**m), 2**m - 1) / (2**m)
    nz = quant.lsb_nonzero(snapped, float(n), float(k))
    assert float(jnp.mean(nz)) == 0.0


def test_act_quant_off_is_identity():
    x = jnp.array([-0.5, 0.2, 0.9, 1.4])
    np.testing.assert_allclose(quant.act_quant(x, 0.0), x)


def test_act_quant_quantizes_clipped_range():
    x = jnp.linspace(0.0, 1.0, 33)
    q = quant.act_quant(x, 2.0)
    lattice = np.asarray(q) * 3.0
    np.testing.assert_allclose(lattice, np.round(lattice), atol=1e-5)


def test_act_quant_gradient_finite_at_zero_bits():
    g = jax.grad(lambda x: jnp.sum(quant.act_quant(x, 0.0)))(jnp.array([0.3, 0.7]))
    assert np.isfinite(np.asarray(g)).all()


def test_dorefa_bias_vs_roundclamp_balance():
    """Fig. 4a mechanism: dorefa's reg-descent sign is biased positive
    (pushes W down), roundclamp's is balanced (interior bins)."""
    w01 = jnp.linspace(0.001, 0.999, 4001)
    n, k = 3.0, 1.0
    code_rc = np.minimum(np.round(8.0 * np.asarray(w01)), 7.0)
    inner_rc = (code_rc % 2 == 1) & (code_rc < 7)
    s_rc = np.sign(np.asarray(quant.lsb_proxy(w01, n, k, "roundclamp")))[inner_rc]
    code_df = np.round(7.0 * np.asarray(w01))
    inner_df = (code_df % 2 == 1) & (code_df < 7)
    s_df = np.sign(np.asarray(quant.lsb_proxy(w01, n, k, "dorefa")))[inner_df]
    assert abs(s_rc.mean()) < 0.1
    assert abs(s_df.mean()) > 0.3
