"""DoReFa quantizer (paper Eq. 1) as a Pallas kernel — the baseline.

Same single-pass VMEM structure as :mod:`roundclamp`; kept separate so the
Fig. 3 / Fig. 4 quantizer-comparison experiments exercise both kernels
through identical machinery. Note the scaling factor ``2^n - 1`` (vs
RoundClamp's ``2^n``): this is precisely the bin misalignment the paper's
Fig. 3a illustrates, so the kernel is deliberately bit-faithful to it.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_TILE_R = 256
_TILE_C = 256


def _kernel(n_ref, w_ref, q_ref):
    scale = jnp.exp2(n_ref[0]) - 1.0
    q_ref[...] = jnp.round(scale * w_ref[...]) / scale


@functools.partial(jax.jit, static_argnames=("interpret",))
def dorefa_quant(w01, n, interpret: bool = True):
    """DoReFa-quantize a 2-D [0,1] f32 tensor at runtime bit-width ``n``."""
    r, c = w01.shape
    tr, tc = min(_TILE_R, r), min(_TILE_C, c)
    grid = (pl.cdiv(r, tr), pl.cdiv(c, tc))
    n = jnp.asarray(n, jnp.float32).reshape((1,))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec((tr, tc), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((tr, tc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r, c), jnp.float32),
        interpret=interpret,
    )(n, w01)
