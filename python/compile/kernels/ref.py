"""Pure-jnp oracles for the Pallas kernels (L1 correctness ground truth).

Every kernel in this package has an exact reference here, written with
plain ``jax.numpy`` ops only (no pallas, no custom_vjp). pytest sweeps
shapes/dtypes and asserts ``assert_allclose(kernel(x), ref(x))``.

These are *forward-only* oracles: the kernels are used inside
``quant.py``'s STE wrappers, so gradients never flow through the kernel
bodies themselves.
"""

from __future__ import annotations

import jax.numpy as jnp


def roundclamp_ref(w01, n):
    """Paper Eq. 4: q_r(w; n) = min(round(2^n w), 2^n - 1) / (2^n - 1)."""
    n = jnp.asarray(n, w01.dtype)
    levels = jnp.exp2(n)
    return jnp.minimum(jnp.round(levels * w01), levels - 1.0) / (levels - 1.0)


def dorefa_ref(w01, n):
    """Paper Eq. 1: q_d(w; n) = round((2^n - 1) w) / (2^n - 1)."""
    n = jnp.asarray(n, w01.dtype)
    scale = jnp.exp2(n) - 1.0
    return jnp.round(scale * w01) / scale


def fused_qlsb_ref(w01, n, k):
    """Fused RoundClamp quantize + bipartite LSB slice (paper Eq. 4+5).

    Returns ``(q_n, b_k)``: ``q_n = roundclamp(w01; n)`` and the sawtooth
    ``b_k = w01 - code_{n-k}(w01) / 2^{n-k}`` — zero exactly at the centres
    of the n-bit bins whose k LSBs are zero.
    """
    n = jnp.asarray(n, w01.dtype)
    k = jnp.asarray(k, w01.dtype)
    lm = jnp.exp2(n - k)
    target = jnp.minimum(jnp.round(lm * w01), lm - 1.0) / lm
    return roundclamp_ref(w01, n), w01 - target


def qmatmul_ref(x, w, scale, n):
    """Fake-quantized matmul: x @ fake_quant(w).

    ``w`` is signed; it is mapped to [0,1] with per-tensor ``scale``,
    RoundClamp-quantized at ``n`` bits, mapped back, then contracted.
    """
    w01 = jnp.clip(w / (2.0 * scale) + 0.5, 0.0, 1.0)
    wq = (roundclamp_ref(w01, n) - 0.5) * (2.0 * scale)
    return jnp.dot(x, wq, preferred_element_type=jnp.float32)


def lsb_nonzero_ref(w01, n, k):
    """Exact integer-code LSB-nonzero indicator under RoundClamp:
    ``code_n mod 2^k != 0``."""
    n = jnp.asarray(n, w01.dtype)
    k = jnp.asarray(k, w01.dtype)
    ln = jnp.exp2(n)
    code_n = jnp.minimum(jnp.round(ln * w01), ln - 1.0)
    rem = code_n - jnp.exp2(k) * jnp.floor(code_n / jnp.exp2(k))
    return (rem > 0.5).astype(w01.dtype)
