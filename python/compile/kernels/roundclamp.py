"""Fused RoundClamp fake-quant + bipartite LSB slice as a Pallas kernel.

The naive L2 graph for MSQ's per-layer weight transform makes three
separate passes over the weight tensor in HBM:

    q_n   = roundclamp(w01; n)          # forward fake-quant
    q_nk  = roundclamp(w01; n - k)      # MSB branch of the bipartite slice
    b_k   = w01 - q_nk                  # LSB proxy for the L1 regularizer

This kernel fuses all three into a single VMEM pass: one HBM read of the
weight tile, two rounds + one FMA on the VPU, two HBM writes. On TPU this
is the difference between 3× and 1× of the layer's weight-bandwidth per
step (weights are read thrice per step by the naive schedule: fwd quant,
reg value, reg grad sign).

TPU mapping (DESIGN.md §Hardware-Adaptation): elementwise → VPU (8,128)
lanes; tiles of (256, 256) f32 = 256 KiB ≪ 16 MiB VMEM, so the grid is
bandwidth-bound and double-buffering hides the HBM latency entirely.

Bit-widths arrive as an SMEM scalar (runtime-prunable precision — the Rust
coordinator changes them without recompiling).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# VPU-aligned tile for the elementwise pass: (8·32, 128·2) f32 = 256 KiB.
_TILE_R = 256
_TILE_C = 256


def _kernel(nk_ref, w_ref, q_ref, b_ref):
    """One VMEM tile: q_n = rc(w; n), b_k = w - rc(w; n-k)."""
    n = nk_ref[0]
    k = nk_ref[1]
    w = w_ref[...]
    ln = jnp.exp2(n)
    lm = jnp.exp2(n - k)
    # RoundClamp at n bits (forward fake-quant value).
    q_ref[...] = jnp.minimum(jnp.round(ln * w), ln - 1.0) / (ln - 1.0)
    # Bipartite LSB slice: distance to the centre of the nearest LSB-zero
    # n-bit bin (= the (n-k)-bit RoundClamp bin centre, paper Fig. 3b).
    b_ref[...] = w - jnp.minimum(jnp.round(lm * w), lm - 1.0) / lm


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_qlsb(w01, n, k, interpret: bool = True):
    """Fused (roundclamp(w01; n), w01 - roundclamp(w01; n-k)).

    ``w01``: 2-D f32 in [0,1] (callers reshape); ``n``, ``k``: f32 scalars
    (runtime bit-widths). Returns ``(q_n, b_k)`` with ``w01``'s shape.
    """
    r, c = w01.shape
    tr, tc = min(_TILE_R, r), min(_TILE_C, c)
    grid = (pl.cdiv(r, tr), pl.cdiv(c, tc))
    nk = jnp.stack([jnp.asarray(n, jnp.float32), jnp.asarray(k, jnp.float32)])
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),  # scalars, replicated
            pl.BlockSpec((tr, tc), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((tr, tc), lambda i, j: (i, j)),
            pl.BlockSpec((tr, tc), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, c), jnp.float32),
            jax.ShapeDtypeStruct((r, c), jnp.float32),
        ],
        interpret=interpret,
    )(nk, w01)


def vmem_bytes(tr: int = _TILE_R, tc: int = _TILE_C) -> int:
    """VMEM footprint of one grid step (double-buffered in + 2 out)."""
    return 2 * (tr * tc * 4) + 2 * 2 * (tr * tc * 4)


@jax.custom_vjp
def fused_qlsb_ste(w01, n, k):
    """:func:`fused_qlsb` with the MSQ training gradients attached:

    * ``q`` carries the straight-through estimator (dq/dw = 1, paper Eq. 2)
    * ``b`` is the LSB sawtooth (db/dw = 1 a.e., so d|b|/dw = sign(b),
      paper Eq. 7)

    ``pallas_call`` has no autodiff rule, so the kernel sits behind this
    custom_vjp — the backward pass never enters the kernel body.
    """
    return fused_qlsb(w01, n, k)


def _fused_fwd(w01, n, k):
    return fused_qlsb(w01, n, k), None


def _fused_bwd(_, cts):
    gq, gb = cts
    return (gq + gb, jnp.zeros(()), jnp.zeros(()))


fused_qlsb_ste.defvjp(_fused_fwd, _fused_bwd)
