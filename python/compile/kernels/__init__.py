"""L1 Pallas kernels for MSQ.

Modules:
  roundclamp — fused RoundClamp fake-quant + bipartite LSB slice
  dorefa     — DoReFa baseline quantizer kernel
  qmatmul    — tiled matmul with fused weight fake-quantization
  ref        — pure-jnp oracles (correctness ground truth)

All kernels are lowered with ``interpret=True``: the CPU PJRT plugin used
by the Rust runtime cannot execute Mosaic custom-calls, so interpret mode
is the executable path; the BlockSpec structure is still the TPU schedule
(VMEM/MXU analysis in DESIGN.md §Hardware-Adaptation).
"""

from . import dorefa, qmatmul, ref, roundclamp  # noqa: F401
