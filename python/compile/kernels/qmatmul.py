"""Quantized matmul: ``x @ fake_quant(w)`` with the fake-quant fused into
the tile loop — MSQ's compute hot-spot as a Pallas kernel.

GPU→TPU rethink (DESIGN.md §Hardware-Adaptation): a CUDA implementation
would fuse the weight fake-quant into the tensor-core mainloop prologue
(dequant in registers after the shared-memory stage). On TPU the analogue
is: fake-quantize the weight tile *in VMEM* right after the HBM→VMEM copy
that the BlockSpec schedule issues, then feed the MXU. The quantized
weight matrix never exists in HBM.

Tiling: (bm, bk) × (bk, bn) with bm=bn=bk=128 — one MXU-shaped tile per
operand. VMEM per grid step at double buffering:
  2·(bm·bk + bk·bn + bm·bn)·4 B = 2·3·64 KiB = 384 KiB  (≪ 16 MiB)
Arithmetic intensity per tile-pair: 2·128³ FLOP / 192 KiB ≈ 21 FLOP/B —
MXU-bound for K ≥ 512 after amortizing the 8-VPU-op quant prologue.

The K-reduction runs as the innermost grid dimension with a VMEM
accumulator (standard Pallas revisiting pattern).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BM, BK, BN = 128, 128, 128


def _kernel(sn_ref, x_ref, w_ref, o_ref):
    """Grid (i, j, kk): o[i,j] += x[i,kk] @ rc_fakequant(w[kk,j])."""
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    scale = sn_ref[0]
    n = sn_ref[1]
    levels = jnp.exp2(n)
    w = w_ref[...]
    # fake-quant prologue, fused in VMEM (8 VPU ops per MXU tile-pair)
    w01 = jnp.clip(w / (2.0 * scale) + 0.5, 0.0, 1.0)
    q = jnp.minimum(jnp.round(levels * w01), levels - 1.0) / (levels - 1.0)
    wq = (q - 0.5) * (2.0 * scale)
    o_ref[...] += jnp.dot(x_ref[...], wq, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def qmatmul(x, w, scale, n, interpret: bool = True):
    """``x:(M,K) @ fake_quant(w:(K,N); scale, n) -> (M,N)`` f32.

    ``scale`` (per-tensor weight scale) and ``n`` (bit-width) are runtime
    f32 scalars, carried to the kernel in SMEM.
    """
    m, k = x.shape
    k2, nn = w.shape
    assert k == k2, (x.shape, w.shape)
    bm, bk, bn = min(BM, m), min(BK, k), min(BN, nn)
    # Pad every dim to a tile multiple: partial tiles would otherwise read
    # unmasked garbage along the K reduction (real-TPU OOB semantics).
    # Zero-padding is exact here — padded x columns/rows contribute 0 to
    # the contraction, and padded w columns are sliced off the output.
    mp, kp, np_ = -(-m // bm) * bm, -(-k // bk) * bk, -(-nn // bn) * bn
    if (mp, kp) != (m, k):
        x = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    if (kp, np_) != (k, nn):
        w = jnp.pad(w, ((0, kp - k), (0, np_ - nn)))
    grid = (mp // bm, np_ // bn, kp // bk)
    sn = jnp.stack([jnp.asarray(scale, jnp.float32), jnp.asarray(n, jnp.float32)])
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(sn, x, w)
    return out[:m, :nn]


def vmem_bytes(bm: int = BM, bk: int = BK, bn: int = BN) -> int:
    """Double-buffered VMEM footprint of one grid step, bytes."""
    return 2 * 4 * (bm * bk + bk * bn + bm * bn)


def mxu_flops_per_tile(bm: int = BM, bk: int = BK, bn: int = BN) -> int:
    return 2 * bm * bk * bn
