"""AOT pipeline (S9): lower every (model × method × fn) step graph to HLO
text and emit the artifact manifest + initial parameters.

HLO **text** (not serialized HloModuleProto) is the interchange format:
jax ≥ 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version behind the Rust ``xla`` crate) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs under ``--out-dir`` (default ``artifacts/``):

* ``{model}_{method}_{fn}_b{batch}.hlo.txt`` — one XLA program each
* ``{model}_{method}.init.npz``              — initial trainable params
  (entries ``t000.<name>``) and frozen consts (``c000.<name>``), in
  registration order (the order the artifact's flat inputs expect)
* ``manifest.json``                          — every artifact's I/O
  descriptors, q-layer tables, trainable-param counts

Python runs ONCE: ``make artifacts`` skips everything that is already
up-to-date (mtime vs this package's sources) unless ``--force``.
"""

from __future__ import annotations

import argparse
import json
import multiprocessing as mp
import os
import sys
import time

BITSPLIT_MODELS = ["mlp", "resnet20", "resnet18s", "resnet50s"]
ALL_MODELS = [
    "mlp", "resnet20", "resnet18s", "resnet50s", "mbv3s",
    "vit_t", "vit_s", "swinlite", "vit_m",
]
FIG6_BATCHES = [64, 128, 512, 1024]


def default_jobs(models, large=False, fig6=True):
    """The full artifact matrix (DESIGN.md per-experiment index)."""
    jobs = []
    for model in models:
        jobs.append(dict(model=model, method="msq", fn="train"))
        jobs.append(dict(model=model, method="msq", fn="eval"))
        jobs.append(dict(model=model, method="msq", fn="stats"))
        jobs.append(dict(model=model, method="msq", fn="hessian"))
        jobs.append(dict(model=model, method="dorefa", fn="train"))
        jobs.append(dict(model=model, method="dorefa", fn="eval"))
        jobs.append(dict(model=model, method="dorefa", fn="stats"))
        if model in BITSPLIT_MODELS:
            for method in ("bsq", "csq"):
                jobs.append(dict(model=model, method=method, fn="train"))
                jobs.append(dict(model=model, method=method, fn="eval"))
                jobs.append(dict(model=model, method=method, fn="stats"))
    # Fig. 6 batch sweep: resnet20 train at several batch sizes per method
    if fig6 and "resnet20" in models:
        for b in FIG6_BATCHES:
            for method in ("msq", "bsq", "csq"):
                jobs.append(dict(model="resnet20", method=method, fn="train", batch=b))
    # L1 Pallas-path artifact: proves the kernel composes into AOT e2e
    if "mlp" in models:
        jobs.append(dict(model="mlp", method="msq", fn="train", use_pallas=True))
    if large:
        for fn in ("train", "eval", "stats", "hessian"):
            jobs.append(dict(model="vit_base", method="msq", fn=fn))
    return jobs


def job_name(j):
    from . import models as models_lib

    b = j.get("batch") or models_lib.get_model(j["model"])["batch"]
    suffix = "_pallas" if j.get("use_pallas") else ""
    return f"{j['model']}_{j['method']}_{j['fn']}_b{b}{suffix}"


def to_hlo_text(lowered) -> str:
    from jax._src.lib import xla_client as xc

    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build_one(j, out_dir):
    """Worker: build + lower one artifact; returns its manifest entry."""
    import jax

    jax.config.update("jax_platform_name", "cpu")
    from . import train as train_lib

    t0 = time.time()
    fn_kind = j["fn"]
    if fn_kind == "train":
        fn, specs, meta = train_lib.build_train(
            j["model"], j["method"], batch=j.get("batch"),
            use_pallas=j.get("use_pallas", False),
        )
    elif fn_kind == "eval":
        fn, specs, meta = train_lib.build_eval(j["model"], j["method"], batch=j.get("batch"))
    elif fn_kind == "stats":
        fn, specs, meta = train_lib.build_stats(j["model"], j["method"])
    elif fn_kind == "hessian":
        fn, specs, meta = train_lib.build_hessian(j["model"], batch=j.get("batch"))
    else:
        raise ValueError(fn_kind)
    # keep_unused: the manifest's input list must match the compiled
    # program 1:1 even when a method ignores an input (e.g. msq ignores
    # `temp`); jit would silently prune it otherwise.
    lowered = jax.jit(fn, keep_unused=True).lower(*specs)
    text = to_hlo_text(lowered)
    name = job_name(j)
    path = os.path.join(out_dir, name + ".hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    meta["name"] = name
    meta["file"] = os.path.basename(path)
    meta["use_pallas"] = bool(j.get("use_pallas", False))
    meta["lower_seconds"] = round(time.time() - t0, 2)
    meta["hlo_bytes"] = len(text)
    return meta


def export_init(model, method, out_dir, seed=0):
    """Initial params npz for one (model, method): t### trainable, c### consts."""
    import numpy as np

    from . import train as train_lib

    rec = train_lib.record(model, method, seed=seed)
    arrs = {}
    ti = ci = 0
    for s, v in zip([s for s in rec.specs if s.trainable], rec.init_values):
        arrs[f"t{ti:03d}.{s.name}"] = np.asarray(v, np.float32)
        ti += 1
    for s, v in zip([s for s in rec.specs if not s.trainable], rec.init_consts):
        arrs[f"c{ci:03d}.{s.name}"] = np.asarray(v, np.float32)
        ci += 1
    path = os.path.join(out_dir, f"{model}_{method}.init.npz")
    np.savez(path, **arrs)
    return os.path.basename(path)


def _worker(args):
    j, out_dir = args
    try:
        return build_one(j, out_dir)
    except Exception as e:  # surface which job failed
        import traceback

        return dict(error=f"{job_name(j)}: {e}\n{traceback.format_exc()}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=None, help="(compat) single-output path; ignored")
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", nargs="*", default=ALL_MODELS)
    ap.add_argument("--large", action="store_true", help="include vit_base artifacts")
    ap.add_argument("--no-fig6", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--jobs", type=int, default=max(1, (os.cpu_count() or 4) // 2))
    args = ap.parse_args()

    out_dir = os.path.abspath(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)
    manifest_path = os.path.join(out_dir, "manifest.json")
    old = {}
    if os.path.exists(manifest_path) and not args.force:
        with open(manifest_path) as f:
            old = {a["name"]: a for a in json.load(f).get("artifacts", [])}

    jobs = default_jobs(args.models, large=args.large, fig6=not args.no_fig6)
    todo, kept = [], []
    for j in jobs:
        name = job_name(j)
        path = os.path.join(out_dir, name + ".hlo.txt")
        if not args.force and name in old and os.path.exists(path):
            kept.append(old[name])
        else:
            todo.append(j)
    print(f"[aot] {len(jobs)} artifacts: {len(kept)} up-to-date, {len(todo)} to build "
          f"({args.jobs} workers)", flush=True)

    t0 = time.time()
    results = []
    if todo:
        if args.jobs > 1:
            ctx = mp.get_context("spawn")
            with ctx.Pool(args.jobs) as pool:
                for r in pool.imap_unordered(_worker, [(j, out_dir) for j in todo]):
                    results.append(r)
                    if "error" in r:
                        print("[aot] FAILED:", r["error"], file=sys.stderr, flush=True)
                    else:
                        print(f"[aot] built {r['name']} ({r['lower_seconds']}s, "
                              f"{r['hlo_bytes']//1024} KiB)", flush=True)
        else:
            for j in todo:
                r = _worker((j, out_dir))
                results.append(r)
                if "error" in r:
                    print("[aot] FAILED:", r["error"], file=sys.stderr, flush=True)
                else:
                    print(f"[aot] built {r['name']} ({r['lower_seconds']}s)", flush=True)
    errors = [r for r in results if "error" in r]
    if errors:
        sys.exit(1)

    # init params per distinct (model, method)
    inits = {}
    pairs = sorted({(j["model"], j["method"]) for j in jobs})
    for model, method in pairs:
        key = f"{model}_{method}"
        path = os.path.join(out_dir, f"{key}.init.npz")
        if args.force or not os.path.exists(path):
            inits[key] = export_init(model, method, out_dir)
            print(f"[aot] init {key}", flush=True)
        else:
            inits[key] = os.path.basename(path)

    artifacts = kept + [r for r in results if "error" not in r]
    artifacts.sort(key=lambda a: a["name"])
    with open(manifest_path, "w") as f:
        json.dump(dict(version=1, artifacts=artifacts, inits=inits), f, indent=1)
    print(f"[aot] wrote manifest with {len(artifacts)} artifacts in "
          f"{time.time()-t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
