"""Step-function builders (L2): the four AOT-compiled graphs per
(model × method) — train / eval / stats / hessian.

Each builder returns ``(fn, arg_specs, meta)``:

* ``fn`` — a pure function over *flat positional tensors* (params first, in
  registration order), so the Rust coordinator can drive it through the
  PJRT bridge without any pytree knowledge;
* ``arg_specs`` — ``jax.ShapeDtypeStruct`` per argument (lowering inputs);
* ``meta`` — the manifest fragment: input/output descriptors with roles,
  quantized-layer table, trainable-parameter count.

The MSQ training objective (paper Eq. 8)::

    L = CE(W_n) + λ Σ_l |B_k^{(l)}|

is optimized with SGD + momentum 0.9 (paper Sec. 4.1 uses SGD; the cosine
learning-rate schedule lives in the Rust coordinator — ``lr`` is a runtime
input). For BSQ/CSQ the same objective form applies with their bit-level
regularizers (``nn.Ctx`` produces the method's ``reg_terms``).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import models as models_lib
from . import nn

MOMENTUM = 0.9
GRAD_CLIP = 5.0  # global-norm clip


# ---------------------------------------------------------------------------
# Recording pass: specs + initial values
# ---------------------------------------------------------------------------


def record(model_name: str, method: str = "msq", seed: int = 0):
    """Run the model once in recording mode; returns the populated Ctx."""
    m = models_lib.get_model(model_name)
    ctx = nn.Ctx(mode="train", method=method, recording=True, seed=seed)
    x = jnp.zeros((2,) + tuple(m["image"]), jnp.float32)
    with jax.disable_jit():
        m["fn"](ctx, x)
    return ctx


def _specs_meta(ctx: nn.Ctx):
    trainable = [s for s in ctx.specs if s.trainable]
    consts = [s for s in ctx.specs if not s.trainable]
    return trainable, consts


def _input_descs(trainable, consts, extra):
    descs = []
    for s in trainable:
        descs.append(dict(name=s.name, shape=list(s.shape), dtype="f32", role="param",
                          kind=s.kind, q_index=s.q_index))
    for s in consts:
        descs.append(dict(name=s.name, shape=list(s.shape), dtype="f32", role="const",
                          kind=s.kind, q_index=s.q_index))
    descs.extend(extra)
    return descs


def _sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


# ---------------------------------------------------------------------------
# Builders
# ---------------------------------------------------------------------------


def build_train(model_name: str, method: str, quantizer: str = "roundclamp",
                batch: Optional[int] = None, use_pallas: bool = False):
    """fn(params..., consts..., momenta..., bits, ks, lam, lr, temp, n_act, x, y)
       -> (new_params..., new_momenta..., loss, ce, correct)"""
    m = models_lib.get_model(model_name)
    rec = record(model_name, method)
    trainable, consts = _specs_meta(rec)
    nt, nc, lq = len(trainable), len(consts), len(rec.qlayers)
    b = batch or m["batch"]
    img, ncls = tuple(m["image"]), m["classes"]

    def fn(*args):
        params = list(args[:nt])
        cvals = list(args[nt : nt + nc])
        momenta = list(args[nt + nc : 2 * nt + nc])
        bits, ks, lam, lr, temp, n_act, x, y = args[2 * nt + nc :]

        def loss_fn(params):
            ctx = nn.Ctx(mode="train", method=method, quantizer=quantizer,
                         params=params, consts=cvals, bits=bits, ks=ks,
                         n_act=n_act, temp=temp, use_pallas=use_pallas)
            logits = m["fn"](ctx, x)
            logp = jax.nn.log_softmax(logits, axis=-1)
            ce = -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))
            reg = jnp.sum(jnp.stack([jnp.sum(r) for r in ctx.reg_terms])) if ctx.reg_terms else 0.0
            correct = jnp.sum((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))
            return ce + lam * reg, (ce, correct)

        (loss, (ce, correct)), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        # global grad-norm clipping (stabilizes the aggressive-λ and
        # high-lr regimes; standard QAT practice)
        gsq = sum(jnp.sum(g * g) for g in grads)
        gscale = jnp.minimum(1.0, GRAD_CLIP / (jnp.sqrt(gsq) + 1e-12))
        new_m = [MOMENTUM * mo + gscale * g for mo, g in zip(momenta, grads)]
        new_p = [p - lr * mo for p, mo in zip(params, new_m)]
        return tuple(new_p) + tuple(new_m) + (loss, ce, correct)

    arg_specs = (
        [_sds(s.shape) for s in trainable]
        + [_sds(s.shape) for s in consts]
        + [_sds(s.shape) for s in trainable]
        + [_sds((lq,)), _sds((lq,)), _sds(()), _sds(()), _sds(()), _sds(()),
           _sds((b,) + img), _sds((b,), jnp.int32)]
    )
    extra = (
        [dict(name=s.name + ".m", shape=list(s.shape), dtype="f32", role="momentum",
              kind=s.kind, q_index=s.q_index) for s in trainable]
        + [dict(name="bits", shape=[lq], dtype="f32", role="bits"),
           dict(name="ks", shape=[lq], dtype="f32", role="ks"),
           dict(name="lam", shape=[], dtype="f32", role="hyper"),
           dict(name="lr", shape=[], dtype="f32", role="hyper"),
           dict(name="temp", shape=[], dtype="f32", role="hyper"),
           dict(name="n_act", shape=[], dtype="f32", role="hyper"),
           dict(name="x", shape=[b] + list(img), dtype="f32", role="data"),
           dict(name="y", shape=[b], dtype="i32", role="data")]
    )
    inputs = _input_descs(trainable, consts, extra)
    outputs = (
        [dict(name=s.name, shape=list(s.shape), dtype="f32", role="param") for s in trainable]
        + [dict(name=s.name + ".m", shape=list(s.shape), dtype="f32", role="momentum")
           for s in trainable]
        + [dict(name="loss", shape=[], dtype="f32", role="metric"),
           dict(name="ce", shape=[], dtype="f32", role="metric"),
           dict(name="correct", shape=[], dtype="f32", role="metric")]
    )
    meta = _meta(model_name, method, "train", b, rec, trainable, consts, inputs, outputs)
    return fn, arg_specs, meta


def build_eval(model_name: str, method: str, quantizer: str = "roundclamp",
               batch: Optional[int] = None):
    """fn(params..., consts..., bits, temp, n_act, x, y) -> (ce_sum, correct)"""
    m = models_lib.get_model(model_name)
    rec = record(model_name, method)
    trainable, consts = _specs_meta(rec)
    nt, nc, lq = len(trainable), len(consts), len(rec.qlayers)
    b = batch or m["batch"]
    img = tuple(m["image"])

    def fn(*args):
        params = list(args[:nt])
        cvals = list(args[nt : nt + nc])
        bits, temp, n_act, x, y = args[nt + nc :]
        ctx = nn.Ctx(mode="eval", method=method, quantizer=quantizer,
                     params=params, consts=cvals, bits=bits,
                     ks=jnp.ones((lq,), jnp.float32), n_act=n_act, temp=temp)
        logits = m["fn"](ctx, x)
        logp = jax.nn.log_softmax(logits, axis=-1)
        ce_sum = -jnp.sum(jnp.take_along_axis(logp, y[:, None], axis=1))
        correct = jnp.sum((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))
        return ce_sum, correct

    arg_specs = (
        [_sds(s.shape) for s in trainable]
        + [_sds(s.shape) for s in consts]
        + [_sds((lq,)), _sds(()), _sds(()), _sds((b,) + img), _sds((b,), jnp.int32)]
    )
    extra = [dict(name="bits", shape=[lq], dtype="f32", role="bits"),
             dict(name="temp", shape=[], dtype="f32", role="hyper"),
             dict(name="n_act", shape=[], dtype="f32", role="hyper"),
             dict(name="x", shape=[b] + list(img), dtype="f32", role="data"),
             dict(name="y", shape=[b], dtype="i32", role="data")]
    inputs = _input_descs(trainable, consts, extra)
    outputs = [dict(name="ce_sum", shape=[], dtype="f32", role="metric"),
               dict(name="correct", shape=[], dtype="f32", role="metric")]
    meta = _meta(model_name, method, "eval", b, rec, trainable, consts, inputs, outputs)
    return fn, arg_specs, meta


def build_stats(model_name: str, method: str, quantizer: str = "roundclamp"):
    """Per-layer LSB statistics for the pruning decision (Algorithm 1).

    msq/dorefa: fn(params..., consts..., bits, ks) -> (beta[Lq], qerr[Lq], reg[Lq])
    bsq/csq:    fn(params..., consts..., bits, temp) -> (plane_nz[Lq,N0],)
    """
    m = models_lib.get_model(model_name)
    rec = record(model_name, method)
    trainable, consts = _specs_meta(rec)
    nt, nc, lq = len(trainable), len(consts), len(rec.qlayers)
    img = tuple(m["image"])
    bitsplit = method in ("bsq", "csq")

    def fn(*args):
        params = list(args[:nt])
        cvals = list(args[nt : nt + nc])
        if bitsplit:
            bits, temp = args[nt + nc :]
            ks = jnp.ones((lq,), jnp.float32)
        else:
            bits, ks = args[nt + nc :]
            temp = jnp.asarray(1.0, jnp.float32)
        ctx = nn.Ctx(mode="stats", method=method, quantizer=quantizer,
                     params=params, consts=cvals, bits=bits, ks=ks,
                     n_act=None, temp=temp)
        x = jnp.zeros((1,) + img, jnp.float32)
        m["fn"](ctx, x)
        if bitsplit:
            return (jnp.stack(ctx.beta),)  # (Lq, N0)
        beta = jnp.stack(ctx.beta)
        qerr = jnp.stack(ctx.qerr)
        reg = jnp.stack([jnp.sum(r) for r in ctx.reg_terms])
        return beta, qerr, reg

    tail = [_sds((lq,)), _sds(())] if bitsplit else [_sds((lq,)), _sds((lq,))]
    arg_specs = [_sds(s.shape) for s in trainable] + [_sds(s.shape) for s in consts] + tail
    extra = ([dict(name="bits", shape=[lq], dtype="f32", role="bits"),
              dict(name="temp", shape=[], dtype="f32", role="hyper")] if bitsplit else
             [dict(name="bits", shape=[lq], dtype="f32", role="bits"),
              dict(name="ks", shape=[lq], dtype="f32", role="ks")])
    inputs = _input_descs(trainable, consts, extra)
    if bitsplit:
        outputs = [dict(name="plane_nz", shape=[lq, nn.N0], dtype="f32", role="metric")]
    else:
        outputs = [dict(name="beta", shape=[lq], dtype="f32", role="metric"),
                   dict(name="qerr", shape=[lq], dtype="f32", role="metric"),
                   dict(name="reg", shape=[lq], dtype="f32", role="metric")]
    meta = _meta(model_name, method, "stats", 1, rec, trainable, consts, inputs, outputs)
    return fn, arg_specs, meta


def build_hessian(model_name: str, batch: Optional[int] = None):
    """Hutchinson probe (HAWQ-V2, paper Eq. 9 input): one Rademacher hvp.

    fn(params..., x, y, seed) -> vhv[Lq]: per-layer vᵀ H v of the CE loss
    of the *full-precision* forward w.r.t. that layer's weights. The Rust
    coordinator averages probes and forms Ω_l = Tr(H_l)·‖W_n−W‖².
    Built for the msq param structure (one float tensor per q-layer).
    """
    m = models_lib.get_model(model_name)
    rec = record(model_name, "msq")
    trainable, _ = _specs_meta(rec)
    nt, lq = len(trainable), len(rec.qlayers)
    b = batch or max(m["batch"] // 4, 8)
    img = tuple(m["image"])
    qw_idx = [i for i, s in enumerate(trainable) if s.kind == "qw"]
    q_of = {i: s.q_index for i, s in enumerate(trainable) if s.kind == "qw"}

    def fn(*args):
        params = list(args[:nt])
        x, y, seed = args[nt], args[nt + 1], args[nt + 2]

        def ce_of_qw(qws):
            full = list(params)
            for j, i in enumerate(qw_idx):
                full[i] = qws[j]
            ctx = nn.Ctx(mode="fp", method="msq", params=full,
                         consts=[], bits=None, ks=None, n_act=None)
            logits = m["fn"](ctx, x)
            logp = jax.nn.log_softmax(logits, axis=-1)
            return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))

        qws = [params[i] for i in qw_idx]
        key = jax.random.PRNGKey(seed)
        vs = []
        for j, w in enumerate(qws):
            kj = jax.random.fold_in(key, j)
            vs.append(jax.random.rademacher(kj, w.shape, dtype=jnp.float32))
        g_fn = jax.grad(ce_of_qw)
        _, hv = jax.jvp(g_fn, (qws,), (vs,))
        vhv = jnp.zeros((lq,), jnp.float32)
        for j, i in enumerate(qw_idx):
            vhv = vhv.at[q_of[i]].add(jnp.sum(vs[j] * hv[j]))
        return (vhv,)

    arg_specs = ([_sds(s.shape) for s in trainable]
                 + [_sds((b,) + img), _sds((b,), jnp.int32), _sds((), jnp.int32)])
    extra = [dict(name="x", shape=[b] + list(img), dtype="f32", role="data"),
             dict(name="y", shape=[b], dtype="i32", role="data"),
             dict(name="seed", shape=[], dtype="i32", role="seed")]
    inputs = _input_descs(trainable, [], extra)
    outputs = [dict(name="vhv", shape=[lq], dtype="f32", role="metric")]
    meta = _meta(model_name, "msq", "hessian", b, rec, trainable, [], inputs, outputs)
    return fn, arg_specs, meta


# ---------------------------------------------------------------------------
# Manifest fragments
# ---------------------------------------------------------------------------


def _meta(model_name, method, fn_name, batch, rec, trainable, consts, inputs, outputs):
    m = models_lib.get_model(model_name)
    return dict(
        model=model_name,
        method=method,
        fn=fn_name,
        batch=batch,
        image=list(m["image"]),
        classes=m["classes"],
        num_q_layers=len(rec.qlayers),
        q_layers=[dict(name=q.name, shape=list(q.shape), numel=q.numel) for q in rec.qlayers],
        trainable_params=int(sum(s.numel() for s in trainable)),
        num_trainable=len(trainable),
        num_consts=len(consts),
        inputs=inputs,
        outputs=outputs,
    )
