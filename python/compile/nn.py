"""Functional NN library with quantization-aware parameter registry (L2).

Models are pure functions ``model_fn(ctx, x) -> logits`` over a :class:`Ctx`
that owns parameter registration and the method-specific *weight producer*.
The same model function serves four graph modes:

* ``train`` — fake-quantized forward + LSB L1 regularization terms
* ``eval``  — fake-quantized forward only
* ``fp``    — full-precision forward (Hessian probes, FP reference rows)
* ``stats`` — fake-quantized forward + per-layer β / ‖W_n−W‖² / Σ|B_k|

and four *methods* (weight producers):

* ``msq``    — MSQ: float weight per layer, RoundClamp fake-quant, LSB reg
* ``dorefa`` — same structure with the DoReFa quantizer (paper baseline)
* ``bsq``    — explicit bit-split planes per layer (BSQ baseline): the
  trainable parameter count multiplies by the initial bit-width, which is
  exactly the memory/time overhead Table 1 measures
* ``csq``    — bit-split planes + continuous-sparsification gates with a
  runtime temperature (CSQ baseline)

Everything that changes during training (per-layer bit-widths ``bits``,
prune-widths ``ks``, λ, lr, activation bits, CSQ temperature) is a runtime
tensor, so one AOT artifact serves the whole schedule.

Two-phase execution: a *recording* pass (``Ctx.recording=True``) runs the
model on a dummy batch to register parameter specs and draw initial values
(numpy RNG, seeded); *replay* passes consume concrete parameters in
registration order inside the jitted graph.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import quant

N0 = 8  # initial bit-width for every quantized layer (paper setting)


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------


@dataclass
class ParamSpec:
    name: str
    shape: tuple
    kind: str  # 'qw' | 'plane' | 'wscale' | 'gate' | 'f'  (trainable) | 'sign' (const)
    q_index: int = -1  # quantized-layer index for 'qw'/'plane'/'sign'/'wscale'/'gate'
    init: str = "zeros"

    @property
    def trainable(self) -> bool:
        return self.kind != "sign"

    def numel(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1


@dataclass
class QLayerInfo:
    name: str
    shape: tuple
    numel: int


class Ctx:
    """Parameter registry + model-mode state for one graph construction."""

    def __init__(
        self,
        mode: str = "train",
        method: str = "msq",
        quantizer: str = "roundclamp",
        recording: bool = False,
        params: Optional[list] = None,
        consts: Optional[list] = None,
        bits=None,
        ks=None,
        n_act=None,
        temp=None,
        seed: int = 0,
        use_pallas: bool = False,
    ):
        assert mode in ("train", "eval", "fp", "stats")
        assert method in ("msq", "dorefa", "bsq", "csq")
        self.mode = mode
        self.method = method
        self.quantizer = "dorefa" if method == "dorefa" else quantizer
        self.recording = recording
        self.params = params
        self.consts = consts
        self.bits = bits
        self.ks = ks
        self.n_act = n_act
        self.temp = temp
        self.use_pallas = use_pallas
        self.specs: list[ParamSpec] = []
        self.qlayers: list[QLayerInfo] = []
        self.reg_terms: list = []
        self.beta: list = []
        self.qerr: list = []
        self.init_values: list = []
        self.init_consts: list = []
        self._pi = 0  # replay cursor: trainable params
        self._ci = 0  # replay cursor: consts
        self._rng = np.random.RandomState(seed)
        self._names: set = set()

    # -- parameter plumbing -------------------------------------------------

    def _take(self, spec: ParamSpec, init_value):
        assert spec.name not in self._names, f"duplicate param {spec.name}"
        self._names.add(spec.name)
        self.specs.append(spec)
        if self.recording:
            if spec.kind == "sign":
                self.init_consts.append(init_value)
            else:
                self.init_values.append(init_value)
            return jnp.asarray(init_value)
        if spec.kind == "sign":
            v = self.consts[self._ci]
            self._ci += 1
        else:
            v = self.params[self._pi]
            self._pi += 1
        assert v.shape == spec.shape, f"{spec.name}: {v.shape} != {spec.shape}"
        return v

    def _init(self, shape, init: str, fan_in: int = 0):
        if init == "zeros":
            return np.zeros(shape, np.float32)
        if init == "ones":
            return np.ones(shape, np.float32)
        if init == "he":
            std = math.sqrt(2.0 / max(fan_in, 1))
            return self._rng.randn(*shape).astype(np.float32) * std
        if init == "xavier":
            std = math.sqrt(1.0 / max(fan_in, 1))
            return self._rng.randn(*shape).astype(np.float32) * std
        if init == "trunc02":
            return np.clip(self._rng.randn(*shape) * 0.02, -0.04, 0.04).astype(np.float32)
        raise ValueError(init)

    def fparam(self, name: str, shape, init: str = "zeros", fan_in: int = 0):
        """A non-quantized trainable parameter (norm scales, biases, ...)."""
        shape = tuple(shape)
        return self._take(
            ParamSpec(name, shape, "f", init=init), self._init(shape, init, fan_in)
        )

    # -- quantized weights (method dispatch) ---------------------------------

    def qweight(self, name: str, shape, fan_in: int, init: str = "he"):
        """A quantized layer weight, produced per the ctx's method/mode.

        Registers the layer in q-layer order; in quantized modes its
        bit-width is read from ``self.bits[q_index]`` at runtime.
        """
        shape = tuple(shape)
        qi = len(self.qlayers)
        self.qlayers.append(QLayerInfo(name, shape, int(np.prod(shape))))
        if self.method in ("msq", "dorefa"):
            return self._qweight_fake(name, shape, fan_in, init, qi)
        return self._qweight_bitsplit(name, shape, fan_in, init, qi)

    def _qweight_fake(self, name, shape, fan_in, init, qi):
        w = self._take(
            ParamSpec(name, shape, "qw", q_index=qi, init=init),
            self._init(shape, init, fan_in),
        )
        if self.mode == "fp" or self.recording:
            return w
        n = self.bits[qi]
        scale = jax.lax.stop_gradient(jnp.max(jnp.abs(w))) + 1e-8
        w01 = quant.to_unit(w, scale)
        if self.use_pallas and self.quantizer == "roundclamp" and self.mode in ("train", "stats"):
            # L1 Pallas path: fused quantize + LSB slice, one VMEM pass.
            # STE / sign-grad re-attached around the kernel call.
            from .kernels import roundclamp as rc_kernel

            w2d = w01.reshape(-1, shape[-1]) if len(shape) > 1 else w01.reshape(1, -1)
            qk, bk = rc_kernel.fused_qlsb_ste(w2d, n, self.ks[qi])
            q, b = qk.reshape(shape), bk.reshape(shape)
            wq = quant.from_unit(q, scale)
            self.reg_terms.append(jnp.sum(jnp.abs(b)))
            if self.mode == "stats":
                nz = quant.lsb_nonzero(jax.lax.stop_gradient(w01), n, self.ks[qi], self.quantizer)
                self.beta.append(jnp.mean(nz))
                self.qerr.append(jnp.sum((wq - w) ** 2))
            return wq
        wq = quant.from_unit(quant.quantize01(w01, n, self.quantizer), scale)
        if self.mode in ("train", "stats"):
            k = self.ks[qi]
            b = quant.lsb_proxy(w01, n, k, self.quantizer)
            self.reg_terms.append(jnp.sum(jnp.abs(b)))
        if self.mode == "stats":
            nz = quant.lsb_nonzero(jax.lax.stop_gradient(w01), n, self.ks[qi], self.quantizer)
            self.beta.append(jnp.mean(nz))
            self.qerr.append(jnp.sum((wq - w) ** 2))
        return wq

    def _qweight_bitsplit(self, name, shape, fan_in, init, qi):
        """BSQ/CSQ: weight = scale * sign * Σ_b m_b(bits) [g_b] 2^{-b-1} round(a_b).

        ``a_b ∈ [0,1]`` are N0 trainable bit-planes (MSB first), ``sign`` a
        frozen const, ``scale`` a trainable per-layer scalar. Runtime
        ``bits[qi]`` masks the low planes off (pruning); CSQ multiplies
        each plane by a gate σ(T·g_b) with runtime temperature T.
        """
        w0 = self._init(shape, init, fan_in)
        sgn = np.where(w0 >= 0, 1.0, -1.0).astype(np.float32)
        mag01 = np.abs(w0) / (np.abs(w0).max() + 1e-8)
        # decompose |w|/max into N0 binary planes (MSB first)
        planes0 = np.zeros((N0,) + tuple(shape), np.float32)
        resid = mag01.copy()
        for b in range(N0):
            planes0[b] = (resid >= 2.0 ** (-(b + 1))).astype(np.float32)
            resid = resid - planes0[b] * 2.0 ** (-(b + 1))
        planes = self._take(
            ParamSpec(f"{name}.planes", (N0,) + shape, "plane", q_index=qi, init="bitsplit"),
            planes0,
        )
        sign = self._take(
            ParamSpec(f"{name}.sign", shape, "sign", q_index=qi, init="sign"), sgn
        )
        wscale = self._take(
            ParamSpec(f"{name}.scale", (), "wscale", q_index=qi, init="wscale"),
            np.float32(np.abs(w0).max() + 1e-8),
        )
        gates = None
        if self.method == "csq":
            gates = self._take(
                ParamSpec(f"{name}.gates", (N0,), "gate", q_index=qi, init="gate1"),
                np.full((N0,), 2.0, np.float32),
            )
        if self.recording:
            return jnp.asarray(w0)
        # runtime plane mask: plane b active iff b < bits[qi]
        barange = jnp.arange(N0, dtype=jnp.float32)
        mask = (barange < self.bits[qi]).astype(jnp.float32)
        a = jnp.clip(planes, 0.0, 1.0)
        ar = quant.ste_round(a)
        weights_b = jnp.exp2(-(barange + 1.0))  # plane b contributes 2^-(b+1)
        bshape = (N0,) + (1,) * len(shape)
        if self.method == "csq" and self.mode != "fp":
            g = jax.nn.sigmoid(self.temp * gates)
            eff = ar * (mask * g * weights_b).reshape(bshape)
        else:
            eff = ar * (mask * weights_b).reshape(bshape)
        mag = jnp.sum(eff, axis=0)
        w = sign * wscale * mag
        if self.mode in ("train", "stats"):
            # bit-level L1: Σ_b |round(a_b)| over active planes (BSQ reg);
            # CSQ regularizes the gated magnitude instead.
            if self.method == "csq":
                g = jax.nn.sigmoid(self.temp * gates)
                self.reg_terms.append(jnp.sum(jnp.abs(ar) * (mask * g).reshape(bshape)))
            else:
                self.reg_terms.append(jnp.sum(jnp.abs(ar) * mask.reshape(bshape)))
        if self.mode == "stats":
            # per-plane nonzero rate (LSB plane prunability signal)
            nz = jnp.mean(jnp.abs(jax.lax.stop_gradient(ar)), axis=tuple(range(1, 1 + len(shape))))
            self.beta.append(nz)  # (N0,) per layer
            self.qerr.append(jnp.asarray(0.0))
        return w

    # -- activations ----------------------------------------------------------

    def act(self, x):
        """Activation quantization hook (uniform, runtime ``n_act``)."""
        if self.mode == "fp" or self.recording or self.n_act is None:
            return x
        return quant.act_quant(x, self.n_act)


# ---------------------------------------------------------------------------
# Layers
# ---------------------------------------------------------------------------


def dense(ctx: Ctx, x, dout: int, name: str, bias: bool = True, quantized: bool = True):
    din = x.shape[-1]
    if quantized:
        w = ctx.qweight(f"{name}.w", (din, dout), fan_in=din)
    else:
        w = ctx.fparam(f"{name}.w", (din, dout), init="he", fan_in=din)
    y = x @ w
    if bias:
        y = y + ctx.fparam(f"{name}.b", (dout,))
    return y


def conv2d(
    ctx: Ctx,
    x,
    cout: int,
    ksize: int,
    name: str,
    stride: int = 1,
    groups: int = 1,
    bias: bool = False,
    quantized: bool = True,
):
    """NHWC conv with HWIO weights; ``groups=C`` gives depthwise."""
    cin = x.shape[-1]
    wshape = (ksize, ksize, cin // groups, cout)
    fan_in = ksize * ksize * (cin // groups)
    if quantized:
        w = ctx.qweight(f"{name}.w", wshape, fan_in=fan_in)
    else:
        w = ctx.fparam(f"{name}.w", wshape, init="he", fan_in=fan_in)
    y = jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups,
    )
    if bias:
        y = y + ctx.fparam(f"{name}.b", (cout,))
    return y


def groupnorm(ctx: Ctx, x, name: str, groups: int = 8, eps: float = 1e-5):
    """GroupNorm over NHWC (running-stat-free; quantization-friendly eval)."""
    n, h, w, c = x.shape
    g = min(groups, c)
    while c % g:
        g -= 1
    xg = x.reshape(n, h, w, g, c // g)
    mean = jnp.mean(xg, axis=(1, 2, 4), keepdims=True)
    var = jnp.var(xg, axis=(1, 2, 4), keepdims=True)
    xn = ((xg - mean) / jnp.sqrt(var + eps)).reshape(n, h, w, c)
    gamma = ctx.fparam(f"{name}.g", (c,), init="ones")
    beta = ctx.fparam(f"{name}.b", (c,))
    return xn * gamma + beta


def layernorm(ctx: Ctx, x, name: str, eps: float = 1e-5):
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    xn = (x - mean) / jnp.sqrt(var + eps)
    gamma = ctx.fparam(f"{name}.g", (x.shape[-1],), init="ones")
    beta = ctx.fparam(f"{name}.b", (x.shape[-1],))
    return xn * gamma + beta


def mhsa(ctx: Ctx, x, heads: int, name: str):
    """Multi-head self-attention with quantized qkv/proj weights."""
    b, t, d = x.shape
    dh = d // heads
    qkv = dense(ctx, x, 3 * d, f"{name}.qkv", bias=True)
    qkv = qkv.reshape(b, t, 3, heads, dh).transpose(2, 0, 3, 1, 4)
    q, k, v = qkv[0], qkv[1], qkv[2]
    att = (q @ k.transpose(0, 1, 3, 2)) / math.sqrt(dh)
    att = jax.nn.softmax(att, axis=-1)
    y = (att @ v).transpose(0, 2, 1, 3).reshape(b, t, d)
    return dense(ctx, y, d, f"{name}.proj", bias=True)


def vit_block(ctx: Ctx, x, heads: int, mlp_ratio: int, name: str):
    x = x + mhsa(ctx, layernorm(ctx, x, f"{name}.ln1"), heads, f"{name}.attn")
    h = layernorm(ctx, x, f"{name}.ln2")
    h = dense(ctx, h, x.shape[-1] * mlp_ratio, f"{name}.fc1")
    h = ctx.act(jax.nn.gelu(h))
    h = dense(ctx, h, x.shape[-1], f"{name}.fc2")
    return x + h


def se_block(ctx: Ctx, x, name: str, reduction: int = 4):
    """Squeeze-and-excitation (MobileNetV3-style, quantized FCs)."""
    c = x.shape[-1]
    s = jnp.mean(x, axis=(1, 2))
    s = jax.nn.relu(dense(ctx, s, max(c // reduction, 4), f"{name}.fc1"))
    s = jax.nn.sigmoid(dense(ctx, s, c, f"{name}.fc2"))
    return x * s[:, None, None, :]


def hardswish(x):
    return x * jax.nn.relu6(x + 3.0) / 6.0


def global_avgpool(x):
    return jnp.mean(x, axis=(1, 2))


def avgpool2(x):
    n, h, w, c = x.shape
    return x.reshape(n, h // 2, 2, w // 2, 2, c).mean(axis=(2, 4))
