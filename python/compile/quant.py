"""Quantizers for MSQ (L2, build-time JAX).

Implements the paper's two linear quantizers over weights scaled to [0,1]:

* **RoundClamp** (paper Eq. 4) — the MSQ contribution. Scaling factor of
  the rounding function is ``2^n`` (not ``2^n - 1``), with a clamp to keep
  the code in range::

      q_r(w; n) = min(round(2^n * w), 2^n - 1) / (2^n - 1)

  This places the (n-k)-bit bin boundaries at the *midpoints* of the n-bit
  bins, so a weight with nonzero LSBs can round either up or down to the
  nearest LSB-zero value (paper Fig. 3b).

* **DoReFa** (paper Eq. 1) — the conventional baseline::

      q_d(w; n) = round((2^n - 1) * w) / (2^n - 1)

Bit-widths are **runtime inputs** (f32 scalars), not Python constants:
``2^n`` is computed as ``exp2(n)`` inside the graph. This is what lets the
Rust coordinator prune precision during training against a single AOT
artifact, with zero recompiles — the reproduction's analogue of "no
bit-level splitting".

All quantizers use the straight-through estimator (STE, paper Eq. 2) via
``jax.custom_vjp``: forward emits the quantized value, backward passes the
incoming gradient through unchanged.

Weight scaling convention (DESIGN.md §Quantizer math): a layer weight ``W``
(float, any range) with a fixed per-layer scale ``s`` maps to
``w01 = clamp(W/(2s) + 1/2, 0, 1)``, is quantized at n bits, and maps back
with ``W_n = (q - 1/2) * 2s``.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Rounding with STE
# ---------------------------------------------------------------------------


@jax.custom_vjp
def ste_round(x):
    """round-to-nearest (ties to even, XLA semantics) with identity vjp."""
    return jnp.round(x)


def _ste_round_fwd(x):
    return jnp.round(x), None


def _ste_round_bwd(_, g):
    return (g,)


ste_round.defvjp(_ste_round_fwd, _ste_round_bwd)


# ---------------------------------------------------------------------------
# Core quantizers on [0, 1] (runtime bit-width)
# ---------------------------------------------------------------------------


def roundclamp01(w01, n):
    """RoundClamp quantizer on [0,1] weights, paper Eq. 4, STE backward.

    ``n`` may be a traced f32 scalar (runtime bit-width).
    """
    n = jnp.asarray(n, jnp.float32)
    levels = jnp.exp2(n)  # 2^n
    code = jnp.minimum(ste_round(levels * w01), levels - 1.0)
    return code / (levels - 1.0)


def dorefa01(w01, n):
    """DoReFa quantizer on [0,1] weights, paper Eq. 1, STE backward."""
    n = jnp.asarray(n, jnp.float32)
    scale = jnp.exp2(n) - 1.0  # 2^n - 1
    return ste_round(scale * w01) / scale


def quantize01(w01, n, quantizer: str):
    if quantizer == "roundclamp":
        return roundclamp01(w01, n)
    if quantizer == "dorefa":
        return dorefa01(w01, n)
    raise ValueError(f"unknown quantizer {quantizer!r}")


# ---------------------------------------------------------------------------
# Bipartite bit slicing (paper Sec. 3.1)
# ---------------------------------------------------------------------------


def lsb_proxy(w01, n, k, quantizer: str = "roundclamp"):
    """Continuous LSB value ``B_k`` in [0,1]-scale, paper Eq. 5.

    A weight's k LSBs are zero iff its n-bit code is ``2^k · j``, i.e. iff
    ``w01`` lies in the bin centred at ``t_j = j / 2^{n-k}`` (RoundClamp
    bins of width ``1/2^n`` around ``2^k·j/2^n = t_j``). Eq. 5's continuous
    proxy is the sawtooth ``B_k = w01 - t_{j(w01)}``, where the MSB code
    ``j(w01)`` is assigned by the chosen quantizer's (n-k)-bit bin
    placement:

    * RoundClamp: ``j = min(round(2^{n-k} w), 2^{n-k}-1)``, target
      ``j / 2^{n-k}``. Basin boundaries fall exactly at the midpoints of
      the n-bit bins with nonzero LSBs (paper Fig. 3b), so ``sign(B_k)``
      always points at the *nearest* LSB-zero bin, and the target is that
      bin's centre.
    * DoReFa: ``j = round((2^{n-k}-1) w)``, target ``j / (2^{n-k}-1)`` —
      the misaligned placement of paper Fig. 3a, with the documented
      pathology (descent direction biased negative, targets that are not
      LSB-zero under the n-bit code). Implemented faithfully for the
      Fig. 3/4 comparison experiments.

    The target branch is wrapped in ``stop_gradient`` so that
    ``d|B_k|/dW == sign(B_k)`` exactly (paper Eq. 7).
    """
    n = jnp.asarray(n, jnp.float32)
    k = jnp.asarray(k, jnp.float32)
    m = n - k
    if quantizer == "roundclamp":
        lm = jnp.exp2(m)
        target = jnp.minimum(jnp.round(lm * w01), lm - 1.0) / lm
    elif quantizer == "dorefa":
        sc = jnp.exp2(m) - 1.0
        target = jnp.round(sc * w01) / sc
    else:
        raise ValueError(f"unknown quantizer {quantizer!r}")
    return w01 - jax.lax.stop_gradient(target)


def lsb_nonzero(w01, n, k, quantizer: str = "roundclamp"):
    """Indicator (f32 0/1) that the k LSBs of the n-bit code are nonzero —
    i.e. that pruning k bits would change this weight.

    ``code_n mod 2^k != 0``; β_l (Algorithm 1 line 16) is the mean of this
    over a layer. Non-differentiable diagnostic — callers wrap in
    stop_gradient.
    """
    n = jnp.asarray(n, jnp.float32)
    k = jnp.asarray(k, jnp.float32)
    ln = jnp.exp2(n)
    if quantizer == "dorefa":
        code_n = jnp.round((ln - 1.0) * w01)
    else:
        code_n = jnp.minimum(jnp.round(ln * w01), ln - 1.0)
    rem = code_n - jnp.exp2(k) * jnp.floor(code_n / jnp.exp2(k))
    return (rem > 0.5).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Signed-weight fake quantization (layer-facing API)
# ---------------------------------------------------------------------------


def to_unit(w, scale):
    """Map a signed weight tensor to [0,1] with per-layer scale ``s``."""
    return jnp.clip(w / (2.0 * scale) + 0.5, 0.0, 1.0)


def from_unit(w01, scale):
    """Inverse of :func:`to_unit` (on the quantized lattice)."""
    return (w01 - 0.5) * (2.0 * scale)


def fake_quant(w, scale, n, quantizer: str = "roundclamp"):
    """Fake-quantize a signed weight tensor at runtime bit-width ``n``.

    Forward: W -> (q(w01; n) - 1/2) * 2s.  Backward: STE (identity through
    the round; the clip in ``to_unit`` masks gradients outside range, the
    standard DoReFa-style clipped STE).
    """
    return from_unit(quantize01(to_unit(w, scale), n, quantizer), scale)


def act_quant(x, n_act):
    """Uniform activation quantization on [0, 1] after a clip (PACT-like).

    ``n_act <= 0`` (runtime scalar) disables quantization. Activations are
    clipped to [0, alpha] with alpha = 1 (post-normalization activations in
    our models are O(1)); quantized with DoReFa-style uniform bins.
    """
    n_act = jnp.asarray(n_act, jnp.float32)
    x01 = jnp.clip(x, 0.0, 1.0)
    # guard the divisor: at n_act <= 0 the quantized branch is unused, but
    # an unguarded 0-divisor still poisons the backward pass with NaNs.
    scale = jnp.maximum(jnp.exp2(n_act) - 1.0, 1.0)
    xq = ste_round(scale * x01) / scale
    return jnp.where(n_act > 0.5, xq + (x - x01), x)


# ---------------------------------------------------------------------------
# Regularizer (paper Eq. 6/8)
# ---------------------------------------------------------------------------


def lsb_l1(w, scale, n, k, quantizer: str = "roundclamp"):
    """Σ|B_k| for one layer, in [0,1] weight scale (paper Eq. 6)."""
    return jnp.sum(jnp.abs(lsb_proxy(to_unit(w, scale), n, k, quantizer)))


__all__ = [
    "ste_round",
    "roundclamp01",
    "dorefa01",
    "quantize01",
    "lsb_proxy",
    "lsb_nonzero",
    "to_unit",
    "from_unit",
    "fake_quant",
    "act_quant",
    "lsb_l1",
]
