"""Model zoo (L2): the architectures the paper evaluates, width/depth-scaled
where the paper's testbed is ImageNet-scale (DESIGN.md §Substitutions).

All models are functions ``fn(ctx, x) -> logits`` over :class:`nn.Ctx`,
NHWC inputs. Quantized layers (every conv + fc weight) register in a fixed
order; that order *is* the layer index used by the Rust coordinator's
bit-state, the Ω plots, and the final bit-scheme figures.

| name      | paper model        | input      | classes | ~params |
|-----------|--------------------|------------|---------|---------|
| mlp       | (quickstart)       | 32×32×3    | 10      | 0.8M    |
| resnet20  | ResNet-20          | 32×32×3    | 10      | 0.27M   |
| resnet18s | ResNet-18 (scaled) | 64×64×3    | 100     | 2.8M    |
| resnet50s | ResNet-50 (scaled) | 64×64×3    | 100     | 1.7M    |
| mbv3s     | MobileNetV3-L (s)  | 64×64×3    | 100     | 0.9M    |
| vit_t     | DeiT-T (scaled)    | 64×64×3    | 100     | 0.9M    |
| vit_s     | DeiT-S (scaled)    | 64×64×3    | 100     | 2.8M    |
| swinlite  | Swin-T (scaled)    | 64×64×3    | 100     | 1.9M    |
| vit_m     | e2e driver         | 64×64×3    | 100     | ~11M    |
| vit_base  | ViT-Base (supp T1) | 64×64×3    | 100     | ~86M    |
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import nn


# ---------------------------------------------------------------------------
# MLP (quickstart / tests)
# ---------------------------------------------------------------------------


def mlp(ctx: nn.Ctx, x):
    x = x.reshape(x.shape[0], -1)
    x = ctx.act(jax.nn.relu(nn.dense(ctx, x, 256, "fc1")))
    x = ctx.act(jax.nn.relu(nn.dense(ctx, x, 128, "fc2")))
    return nn.dense(ctx, x, 10, "head")


# ---------------------------------------------------------------------------
# ResNets
# ---------------------------------------------------------------------------


def _basic_block(ctx, x, cout, stride, name):
    h = nn.conv2d(ctx, x, cout, 3, f"{name}.c1", stride=stride)
    h = ctx.act(jax.nn.relu(nn.groupnorm(ctx, h, f"{name}.n1")))
    h = nn.conv2d(ctx, h, cout, 3, f"{name}.c2")
    h = nn.groupnorm(ctx, h, f"{name}.n2")
    if stride != 1 or x.shape[-1] != cout:
        # option-A shortcut: stride-subsample + zero-pad channels (no params)
        s = x[:, ::stride, ::stride, :]
        pad = cout - s.shape[-1]
        s = jnp.pad(s, ((0, 0), (0, 0), (0, 0), (0, pad)))
    else:
        s = x
    return ctx.act(jax.nn.relu(h + s))


def _basic_block_proj(ctx, x, cout, stride, name):
    h = nn.conv2d(ctx, x, cout, 3, f"{name}.c1", stride=stride)
    h = ctx.act(jax.nn.relu(nn.groupnorm(ctx, h, f"{name}.n1")))
    h = nn.conv2d(ctx, h, cout, 3, f"{name}.c2")
    h = nn.groupnorm(ctx, h, f"{name}.n2")
    if stride != 1 or x.shape[-1] != cout:
        s = nn.conv2d(ctx, x, cout, 1, f"{name}.sc", stride=stride)
        s = nn.groupnorm(ctx, s, f"{name}.sn")
    else:
        s = x
    return ctx.act(jax.nn.relu(h + s))


def _bottleneck(ctx, x, cmid, cout, stride, name):
    h = nn.conv2d(ctx, x, cmid, 1, f"{name}.c1")
    h = ctx.act(jax.nn.relu(nn.groupnorm(ctx, h, f"{name}.n1")))
    h = nn.conv2d(ctx, h, cmid, 3, f"{name}.c2", stride=stride)
    h = ctx.act(jax.nn.relu(nn.groupnorm(ctx, h, f"{name}.n2")))
    h = nn.conv2d(ctx, h, cout, 1, f"{name}.c3")
    h = nn.groupnorm(ctx, h, f"{name}.n3")
    if stride != 1 or x.shape[-1] != cout:
        s = nn.conv2d(ctx, x, cout, 1, f"{name}.sc", stride=stride)
        s = nn.groupnorm(ctx, s, f"{name}.sn")
    else:
        s = x
    return ctx.act(jax.nn.relu(h + s))


def resnet20(ctx: nn.Ctx, x):
    """ResNet-20 (CIFAR scale, paper Table 2): 19 convs + fc = 20 q-layers."""
    x = nn.conv2d(ctx, x, 16, 3, "stem")
    x = ctx.act(jax.nn.relu(nn.groupnorm(ctx, x, "stem.n")))
    for stage, (c, s) in enumerate([(16, 1), (32, 2), (64, 2)]):
        for b in range(3):
            x = _basic_block(ctx, x, c, s if b == 0 else 1, f"s{stage}.b{b}")
    x = nn.global_avgpool(x)
    return nn.dense(ctx, x, 10, "head")


def resnet18s(ctx: nn.Ctx, x):
    """ResNet-18 scaled to base width 32 (paper Table 1/3 proxy)."""
    x = nn.conv2d(ctx, x, 32, 3, "stem")
    x = ctx.act(jax.nn.relu(nn.groupnorm(ctx, x, "stem.n")))
    for stage, (c, s) in enumerate([(32, 1), (64, 2), (128, 2), (256, 2)]):
        for b in range(2):
            x = _basic_block_proj(ctx, x, c, s if b == 0 else 1, f"s{stage}.b{b}")
    x = nn.global_avgpool(x)
    return nn.dense(ctx, x, 100, "head")


def resnet50s(ctx: nn.Ctx, x):
    """ResNet-50 scaled to base width 16 (bottleneck blocks)."""
    x = nn.conv2d(ctx, x, 16, 3, "stem")
    x = ctx.act(jax.nn.relu(nn.groupnorm(ctx, x, "stem.n")))
    depths = [3, 4, 6, 3]
    for stage, (cm, s) in enumerate([(16, 1), (32, 2), (64, 2), (128, 2)]):
        for b in range(depths[stage]):
            x = _bottleneck(ctx, x, cm, cm * 4, s if b == 0 else 1, f"s{stage}.b{b}")
    x = nn.global_avgpool(x)
    return nn.dense(ctx, x, 100, "head")


# ---------------------------------------------------------------------------
# MobileNetV3-style (depthwise separable + SE, hardswish)
# ---------------------------------------------------------------------------

# (expansion, cout, kernel, stride, use_se, activation)
_MBV3_BLOCKS = [
    (1, 16, 3, 1, True, "relu"),
    (4, 24, 3, 2, False, "relu"),
    (3, 24, 3, 1, False, "relu"),
    (3, 40, 5, 2, True, "hswish"),
    (3, 40, 5, 1, True, "hswish"),
    (6, 80, 3, 2, False, "hswish"),
    (2, 80, 3, 1, False, "hswish"),
    (6, 112, 3, 1, True, "hswish"),
    (6, 160, 5, 2, True, "hswish"),
]


def _mb_act(ctx, x, act):
    return ctx.act(nn.hardswish(x) if act == "hswish" else jax.nn.relu(x))


def mbv3s(ctx: nn.Ctx, x):
    """MobileNetV3-Large, reduced block table (paper Table 5 proxy)."""
    x = nn.conv2d(ctx, x, 16, 3, "stem", stride=2)
    x = _mb_act(ctx, nn.groupnorm(ctx, x, "stem.n"), "hswish")
    for i, (exp, cout, k, s, se, act) in enumerate(_MBV3_BLOCKS):
        cin = x.shape[-1]
        cexp = cin * exp
        name = f"mb{i}"
        h = x
        if exp != 1:
            h = nn.conv2d(ctx, h, cexp, 1, f"{name}.expand")
            h = _mb_act(ctx, nn.groupnorm(ctx, h, f"{name}.en"), act)
        h = nn.conv2d(ctx, h, cexp, k, f"{name}.dw", stride=s, groups=cexp)
        h = _mb_act(ctx, nn.groupnorm(ctx, h, f"{name}.dn"), act)
        if se:
            h = nn.se_block(ctx, h, f"{name}.se")
        h = nn.conv2d(ctx, h, cout, 1, f"{name}.project")
        h = nn.groupnorm(ctx, h, f"{name}.pn")
        if s == 1 and cin == cout:
            h = h + x
        x = h
    x = nn.conv2d(ctx, x, 480, 1, "headconv")
    x = _mb_act(ctx, nn.groupnorm(ctx, x, "headconv.n"), "hswish")
    x = nn.global_avgpool(x)
    x = _mb_act(ctx, nn.dense(ctx, x, 640, "pre_head"), "hswish")
    return nn.dense(ctx, x, 100, "head")


# ---------------------------------------------------------------------------
# Vision transformers
# ---------------------------------------------------------------------------


def _vit(ctx: nn.Ctx, x, dim, depth, heads, patch, classes, mlp_ratio=4):
    b, h, w, c = x.shape
    # patch embedding as a strided conv (quantized)
    x = nn.conv2d(ctx, x, dim, patch, "patch", stride=patch)
    t = (h // patch) * (w // patch)
    x = x.reshape(b, t, dim)
    cls = ctx.fparam("cls", (1, 1, dim), init="trunc02")
    pos = ctx.fparam("pos", (1, t + 1, dim), init="trunc02")
    x = jnp.concatenate([jnp.tile(cls, (b, 1, 1)), x], axis=1) + pos
    for i in range(depth):
        x = nn.vit_block(ctx, x, heads, mlp_ratio, f"blk{i}")
    x = nn.layernorm(ctx, x, "norm")
    return nn.dense(ctx, x[:, 0], classes, "head")


def vit_t(ctx, x):
    """DeiT-T proxy (Table 4)."""
    return _vit(ctx, x, dim=128, depth=4, heads=4, patch=8, classes=100)


def vit_s(ctx, x):
    """DeiT-S proxy (Table 4)."""
    return _vit(ctx, x, dim=192, depth=6, heads=6, patch=8, classes=100)


def vit_m(ctx, x):
    """~11M-param transformer for the end-to-end driver (EXPERIMENTS.md)."""
    return _vit(ctx, x, dim=384, depth=6, heads=6, patch=8, classes=100)


def vit_base(ctx, x):
    """ViT-Base-shaped (dim 768, depth 12) for supp Table 1 / large e2e."""
    return _vit(ctx, x, dim=768, depth=12, heads=12, patch=8, classes=100)


# ---------------------------------------------------------------------------
# Swin-lite: windowed attention + patch merging (no shifted windows —
# documented substitution; hierarchy and window locality preserved)
# ---------------------------------------------------------------------------


def _window_attn(ctx, x, heads, win, name):
    b, h, w, d = x.shape
    nh, nw = h // win, w // win
    xw = x.reshape(b, nh, win, nw, win, d).transpose(0, 1, 3, 2, 4, 5)
    xw = xw.reshape(b * nh * nw, win * win, d)
    y = nn.mhsa(ctx, xw, heads, name)
    y = y.reshape(b, nh, nw, win, win, d).transpose(0, 1, 3, 2, 4, 5)
    return y.reshape(b, h, w, d)


def _swin_block(ctx, x, heads, win, name, mlp_ratio=4):
    b, h, w, d = x.shape
    sc = x
    xx = nn.layernorm(ctx, x.reshape(b, h * w, d), f"{name}.ln1").reshape(b, h, w, d)
    x = sc + _window_attn(ctx, xx, heads, win, f"{name}.attn")
    sc = x
    xx = nn.layernorm(ctx, x.reshape(b, h * w, d), f"{name}.ln2")
    xx = nn.dense(ctx, xx, d * mlp_ratio, f"{name}.fc1")
    xx = ctx.act(jax.nn.gelu(xx))
    xx = nn.dense(ctx, xx, d, f"{name}.fc2")
    return sc + xx.reshape(b, h, w, d)


def _patch_merge(ctx, x, name):
    b, h, w, d = x.shape
    x = x.reshape(b, h // 2, 2, w // 2, 2, d).transpose(0, 1, 3, 2, 4, 5)
    x = x.reshape(b, h // 2, w // 2, 4 * d)
    x = nn.layernorm(ctx, x.reshape(b, -1, 4 * d), f"{name}.ln").reshape(b, h // 2, w // 2, 4 * d)
    return nn.dense(ctx, x, 2 * d, f"{name}.reduce", bias=False)


def swinlite(ctx: nn.Ctx, x):
    """Swin-T proxy (Table 4): 3 stages, window attention, patch merging."""
    b = x.shape[0]
    x = nn.conv2d(ctx, x, 64, 4, "patch", stride=4)  # 16x16 tokens
    dims_heads = [(64, 2), (128, 4), (256, 8)]
    for stage, (d, heads) in enumerate(dims_heads):
        for blk in range(2):
            x = _swin_block(ctx, x, heads, 4, f"s{stage}.b{blk}")
        if stage < 2:
            x = _patch_merge(ctx, x, f"merge{stage}")
    bsz, h, w, d = x.shape
    x = nn.layernorm(ctx, x.reshape(bsz, h * w, d), "norm")
    x = jnp.mean(x, axis=1)
    return nn.dense(ctx, x, 100, "head")


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

MODELS = {
    "mlp": dict(fn=mlp, image=(32, 32, 3), classes=10, batch=256),
    "resnet20": dict(fn=resnet20, image=(32, 32, 3), classes=10, batch=256),
    "resnet18s": dict(fn=resnet18s, image=(64, 64, 3), classes=100, batch=64),
    "resnet50s": dict(fn=resnet50s, image=(64, 64, 3), classes=100, batch=64),
    "mbv3s": dict(fn=mbv3s, image=(64, 64, 3), classes=100, batch=64),
    "vit_t": dict(fn=vit_t, image=(64, 64, 3), classes=100, batch=64),
    "vit_s": dict(fn=vit_s, image=(64, 64, 3), classes=100, batch=64),
    "swinlite": dict(fn=swinlite, image=(64, 64, 3), classes=100, batch=64),
    "vit_m": dict(fn=vit_m, image=(64, 64, 3), classes=100, batch=32),
    "vit_base": dict(fn=vit_base, image=(64, 64, 3), classes=100, batch=8),
}


def get_model(name: str):
    return MODELS[name]
