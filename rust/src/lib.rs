// `std::simd` is still unstable: the `simd` cargo feature opts the
// kernel core's inner loops into portable SIMD on a nightly toolchain.
// The default (stable) build uses the scalar twin, which computes
// bit-identical results (see `kernels::simd`).
#![cfg_attr(feature = "simd", feature(portable_simd))]

//! # MSQ — Memory-Efficient Bit Sparsification Quantization
//!
//! A Rust + JAX + Pallas reproduction of *MSQ: Memory-Efficient Bit
//! Sparsification Quantization* (CS.LG 2025), structured as a three-layer
//! stack (DESIGN.md):
//!
//! * **L3 (this crate)** — the training coordinator: Algorithm 1's
//!   schedule (LSB L1 regularization → β-thresholded pruning →
//!   Hessian-aware prune-bit assignment → final-round sorted pruning →
//!   post-Γ QAT), plus baselines (DoReFa, BSQ, CSQ), datasets, metrics,
//!   and the experiment harness regenerating every paper table/figure.
//! * **L2** — JAX model graphs, AOT-lowered once to HLO text
//!   (`python/compile/`); bit-widths are *runtime tensors*, so a single
//!   compiled executable serves the entire mixed-precision schedule.
//! * **L1** — Pallas kernels for the quantization hot-spot
//!   (`python/compile/kernels/`).
//!
//! Python never runs at training time: the coordinator drives an
//! execution [`runtime::Backend`] from Rust — either the pure-Rust
//! `native` backend (default build, zero XLA: tensor/autodiff/SGD in
//! `src/native/`) or the PJRT engine loading the HLO artifacts
//! (`--features pjrt`).
//!
//! Both execution paths share one hot-loop foundation: the [`kernels`]
//! module — lane-structured SIMD/scalar primitives (`std::simd` behind
//! the `simd` feature, bit-identical scalar fallback otherwise), the
//! `.msqpack` n-bit decode + RoundClamp dequant affine, and
//! cache-blocked matmul/conv microkernels — sits under both the
//! quantized serving kernels and the native training ops (see
//! `docs/ARCHITECTURE.md` for the full dataflow).
//!
//! Deployment side, the `serve` module executes packed `.msqpack` models
//! (produced by `quant::pack`) with pure-Rust quantized kernels and a
//! dynamic request batcher, and the `net` module puts them on the
//! network: `msq gateway` is a pure-`std` HTTP/1.1 front-end with
//! multi-model routing, Prometheus `/metrics`, and zero-downtime
//! `/admin/reload` — zero XLA/PJRT linkage, so the default feature set
//! builds and serves fully offline. The XLA-backed training path is
//! gated behind the `pjrt` cargo feature.

pub mod bench;
pub mod coordinator;
pub mod data;
pub mod exp;
pub mod kernels;
pub mod metrics;
pub mod native;
pub mod net;
pub mod obs;
pub mod quant;
pub mod runtime;
pub mod serve;
pub mod util;

pub use coordinator::{MsqConfig, Trainer};
pub use native::NativeBackend;
pub use net::{Gateway, GatewayConfig};
pub use runtime::Backend;
#[cfg(feature = "pjrt")]
pub use runtime::{Engine, ModelState};
pub use serve::{ModelRegistry, ServableModel, Server, ServerConfig};
