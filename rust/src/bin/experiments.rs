//! `experiments` — regenerate every paper table and figure.
//!
//! ```text
//! experiments all                 # everything at the quick preset
//! experiments table1 [--preset smoke|quick|full]
//! experiments table2 | table3 | table4 | table5
//! experiments fig3 | fig4 | fig5 | fig6 | fig7 | fig8 | fig9
//! experiments supp_lambda | supp_vitbase | perf
//! ```
//!
//! Results land under `results/` as CSV/JSON; paper-style tables print to
//! stdout. EXPERIMENTS.md records paper-vs-measured per experiment.

use anyhow::Result;

use msq::exp::{tables, Preset};
use msq::runtime::Engine;
use msq::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env(&["preset"]);
    let preset = Preset::parse(args.opt("preset").unwrap_or("quick"));
    let which = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let eng = Engine::new()?;
    println!("[experiments] {} @ preset {}", which, preset.name());
    match which {
        "table1" => tables::table1(&eng, preset)?,
        "table2" => tables::table2(&eng, preset)?,
        "table3" => tables::table3(&eng, preset)?,
        "table4" => tables::table4(&eng, preset)?,
        "table5" => tables::table5(&eng, preset)?,
        "fig3" => tables::fig3(&eng)?,
        "fig4" => tables::fig4(&eng, preset)?,
        "fig5" => tables::fig5(&eng, preset)?,
        "fig6" => tables::fig6(&eng, preset)?,
        "fig7" | "fig8" | "fig78" => tables::fig78(&eng, preset)?,
        "fig9" => tables::fig9(&eng, preset)?,
        "supp_lambda" => tables::supp_lambda(&eng, preset)?,
        "supp_vitbase" => tables::supp_vitbase(&eng, preset)?,
        "perf" => tables::perf_probe(&eng)?,
        "all" => {
            tables::fig3(&eng)?;
            tables::table1(&eng, preset)?;
            tables::fig6(&eng, preset)?;
            tables::table2(&eng, preset)?;
            tables::fig4(&eng, preset)?;
            tables::fig5(&eng, preset)?;
            tables::fig78(&eng, preset)?;
            tables::fig9(&eng, preset)?;
            tables::supp_lambda(&eng, preset)?;
            tables::table3(&eng, preset)?;
            tables::table4(&eng, preset)?;
            tables::table5(&eng, preset)?;
        }
        _ => {
            eprintln!("usage: experiments <all|table1..5|fig3..9|supp_lambda|supp_vitbase|perf> \
                       [--preset smoke|quick|full]");
        }
    }
    Ok(())
}
