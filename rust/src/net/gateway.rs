//! The gateway runtime: replicated TCP accept loops + per-connection
//! workers on the resident [`ThreadPool`], over the pure [`router`]
//! logic.
//!
//! ```text
//! accept ×N ─► budget check ──► pool worker: read_request ─► router::handle ─► write
//!    │            │ (503, close)      │ keep-alive loop, idle tick = read timeout
//!    ▼            ▼                   ▼
//! shared      shed               per-model Server (admission gate + batcher)
//! listener
//! ```
//!
//! **Accept replicas** — `replicas` accept loops (default: one per
//! core) share one listener via dup'd handles, so a connection burst is
//! drained by whichever replica the kernel wakes instead of serializing
//! behind a single accept thread. Each replica labels its admitted
//! connections (`msq_replica_connections_total{replica}`) and its
//! serialize-stage latency, so per-replica skew is visible on
//! `/metrics`.
//!
//! **Connection budget** — at most `max_conns` connections are open at
//! once across all replicas; excess accepts are answered `503` and
//! closed immediately (cheap shed at the edge, before any parsing). The
//! worker pool has exactly `max_conns` threads, so an admitted
//! connection always has a worker.
//!
//! **Graceful shutdown** ([`Gateway::shutdown`], the SIGTERM-equivalent)
//! — sets the drain flag, closes every model's batcher to new
//! admissions, wakes the accept loops with self-connections until every
//! replica has exited, joins the connection workers (each notices the
//! flag at its next idle tick or after its in-flight response), then
//! drops the model servers, whose batchers flush every in-flight batch
//! before joining. No admitted request is dropped.

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::serve::ServerConfig;
use crate::util::threadpool::ThreadPool;

use super::http::{HttpReader, Limits, ReadError, Response};
use super::router::{self, AppState};

#[derive(Clone, Debug)]
pub struct GatewayConfig {
    /// Bind host (default loopback; 0.0.0.0 to expose).
    pub host: String,
    /// Bind port; 0 picks an ephemeral port (tests, benches).
    pub port: u16,
    /// Connection budget = worker-pool size; accepts beyond it are shed
    /// with an immediate 503.
    pub max_conns: usize,
    /// Accept-loop replicas sharing the listener. 0 (the default) means
    /// one per available core; 1 restores the single-loop layout.
    pub replicas: usize,
    /// Decoded-weight cache budget in MiB (`--weight-cache-mb`). 0 (the
    /// default) leaves the process-wide cache untouched — important for
    /// tests, where flipping the global budget would race other
    /// gateways; a nonzero value sets it at startup.
    pub weight_cache_mb: usize,
    /// Keep-alive idle tick: how often a blocked reader wakes to check
    /// the drain flag (also the mid-request stall timeout).
    pub read_timeout: Duration,
    /// HTTP parser limits (line/header/body caps).
    pub limits: Limits,
    /// Emit one access-log line per request on stderr (trace ID, peer,
    /// request line, status, body bytes, latency). Off by default so
    /// tests and benches stay quiet; `msq gateway` turns it on.
    pub access_log: bool,
    /// When set, `POST /admin/reload` requires `Authorization: Bearer
    /// <token>`; requests without it are answered 401. `None` (the
    /// default) leaves the endpoint open — fine on loopback, set a token
    /// before exposing the gateway.
    pub admin_token: Option<String>,
    /// Enable kernel-level profiling ([`crate::obs::Profiler`]) at
    /// startup: per-layer decode-vs-matmul time, bytes decoded, codes/s,
    /// surfaced on `/metrics` and `/debug/stats`. Off by default — the
    /// disabled path is one relaxed atomic load per kernel call.
    pub profile: bool,
    /// Enable activation observers ([`crate::obs::qstats`]) at startup
    /// with this sample rate (`Some(1.0)` = every kernel call, `Some(r)`
    /// = a deterministic 1-in-⌈1/r⌉ stride). Feeds the per-layer
    /// `msq_layer_act_*` series, saturation counters, and the
    /// `/debug/model/{name}` activations table. `None` (default) keeps
    /// the observers off — one relaxed atomic load per kernel call.
    pub qstats: Option<f32>,
    /// Serve int-capable layers through the integer kernels (`--int8`):
    /// activations quantize to u8 against observer-calibrated scales
    /// (EMA absmax when `qstats` has samples, static analysis bound
    /// otherwise) and inner loops accumulate in i32. Applies to every
    /// model the gateway loads, including `/admin/reload`. Off by
    /// default — the float path is untouched.
    pub int8: bool,
    /// Batcher/kernel config for every model server the gateway starts.
    pub server: ServerConfig,
}

impl Default for GatewayConfig {
    fn default() -> Self {
        GatewayConfig {
            host: "127.0.0.1".into(),
            port: 8080,
            max_conns: 64,
            replicas: 0,
            weight_cache_mb: 0,
            read_timeout: Duration::from_millis(250),
            limits: Limits::default(),
            access_log: false,
            admin_token: None,
            profile: false,
            qstats: None,
            int8: false,
            server: ServerConfig::default(),
        }
    }
}

/// A model to serve at startup: `(route name, .msqpack path, --input-dim
/// override)`.
pub type ModelSpec = (String, PathBuf, Option<usize>);

/// A running gateway. Dropping it without calling [`Gateway::shutdown`]
/// also shuts down (less gracefully ordered but never hanging).
pub struct Gateway {
    addr: SocketAddr,
    state: Arc<AppState>,
    accept: Vec<thread::JoinHandle<()>>,
    /// Accept replicas still inside their loop; drain wakes the
    /// listener until this hits zero before joining.
    live_accepts: Arc<AtomicUsize>,
    pool: Option<Arc<ThreadPool>>,
}

impl Gateway {
    /// Bind, load every model, and start accepting.
    pub fn start(cfg: GatewayConfig, models: &[ModelSpec]) -> Result<Gateway> {
        let pool = Arc::new(ThreadPool::new(cfg.max_conns.max(1)));
        let mut state = AppState::new(cfg.server.clone(), pool.clone());
        state.admin_token = cfg.admin_token.clone();
        state.int8 = cfg.int8;
        let state = Arc::new(state);
        if cfg.profile {
            crate::obs::profiler().enable(true);
        }
        if let Some(rate) = cfg.qstats {
            let qs = crate::obs::qstats::qstats();
            qs.set_rate(rate);
            qs.enable(true);
        }
        if cfg.weight_cache_mb > 0 {
            crate::serve::weightcache::cache().set_budget_mb(cfg.weight_cache_mb);
        }
        for (name, path, dim) in models {
            state.load_model(name, path, *dim)?;
        }
        let listener = TcpListener::bind((cfg.host.as_str(), cfg.port))
            .with_context(|| format!("binding {}:{}", cfg.host, cfg.port))?;
        let addr = listener.local_addr()?;
        let replicas = match cfg.replicas {
            0 => thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            n => n,
        };
        let live_accepts = Arc::new(AtomicUsize::new(0));
        let mut accept = Vec::with_capacity(replicas);
        for i in 0..replicas {
            let l = listener.try_clone().context("cloning gateway listener")?;
            let state = state.clone();
            let pool = pool.clone();
            let cfg = cfg.clone();
            let live = live_accepts.clone();
            live_accepts.fetch_add(1, Ordering::AcqRel);
            accept.push(
                thread::Builder::new()
                    .name(format!("msq-gateway-accept-{i}"))
                    .spawn(move || {
                        accept_loop(l, state, pool, cfg, i);
                        live.fetch_sub(1, Ordering::AcqRel);
                    })
                    .context("spawning accept loop")?,
            );
        }
        Ok(Gateway { addr, state, accept, live_accepts, pool: Some(pool) })
    }

    /// The bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn state(&self) -> &Arc<AppState> {
        &self.state
    }

    /// Graceful drain; blocks until every in-flight request finished and
    /// all threads are joined.
    pub fn shutdown(mut self) {
        self.drain();
    }

    fn drain(&mut self) {
        // 1. flip the flag: routes answer 503, batchers stop admitting
        self.state.start_drain();
        // 2. wake the accept loops (each re-checks the flag per
        // connection). An unspecified bind address (0.0.0.0 / [::]) is
        // not dialable on every platform — connect to the same-family
        // loopback instead, and bound each dial so a refused wake cannot
        // stall the join. One dial wakes at most one replica, so keep
        // dialing until every replica has left its loop.
        let mut wake = self.addr;
        if wake.ip().is_unspecified() {
            wake.set_ip(if wake.is_ipv4() {
                std::net::IpAddr::V4(std::net::Ipv4Addr::LOCALHOST)
            } else {
                std::net::IpAddr::V6(std::net::Ipv6Addr::LOCALHOST)
            });
        }
        while self.live_accepts.load(Ordering::Acquire) > 0 {
            let _ = TcpStream::connect_timeout(&wake, Duration::from_secs(1));
            thread::sleep(Duration::from_millis(1));
        }
        for h in self.accept.drain(..) {
            let _ = h.join();
        }
        // 3. join connection workers: each exits at its next idle tick
        //    (read_timeout) or right after its current response
        if let Some(pool) = self.pool.take() {
            drop(pool); // state still holds an Arc — only our handle drops
        }
        // the pool Arc inside AppState keeps workers alive until every
        // queued connection job ran; wait for that explicitly
        self.state.conn_pool.wait();
        // 4. retire the model servers — Drop flushes each batcher
        self.state.clear_models();
    }
}

impl Drop for Gateway {
    fn drop(&mut self) {
        if !self.accept.is_empty() {
            self.drain();
        }
    }
}

fn accept_loop(
    listener: TcpListener,
    state: Arc<AppState>,
    pool: Arc<ThreadPool>,
    cfg: GatewayConfig,
    replica: usize,
) {
    let label = replica.to_string();
    let admitted =
        state.obs.counter("msq_replica_connections_total", &[("replica", &label)]);
    let serialize = state
        .obs
        .hist(crate::obs::STAGE_FAMILY, &[("replica", &label), ("stage", "serialize")]);
    for stream in listener.incoming() {
        if state.draining.load(Ordering::Acquire) {
            break;
        }
        let mut stream = match stream {
            Ok(s) => s,
            Err(_) => continue, // transient accept error
        };
        state.http.connections_total.fetch_add(1, Ordering::Relaxed);
        // connection budget: every admitted connection gets a dedicated
        // worker, so beyond pool capacity we shed instead of queueing
        let active = state.http.connections_active.load(Ordering::Acquire);
        if active >= cfg.max_conns as u64 {
            state.http.connections_rejected.fetch_add(1, Ordering::Relaxed);
            state.http.record_response(503);
            let id = router::mint_request_id();
            if cfg.access_log {
                let peer = peer_label(&stream);
                eprintln!("[gateway] {id} {peer} - 503 0B shed(connection budget)");
            }
            let _ = router::tag(
                Response::error(503, "connection budget exhausted — retry")
                    .header("Retry-After", "1"),
                &id,
            )
            .write_to(&mut stream, false);
            continue; // stream drops → close
        }
        admitted.inc();
        state.http.connections_active.fetch_add(1, Ordering::AcqRel);
        let st = state.clone();
        let conn_cfg = ConnConfig {
            read_timeout: cfg.read_timeout,
            limits: cfg.limits.clone(),
            access_log: cfg.access_log,
            replica_serialize: serialize.clone(),
        };
        pool.submit(move || {
            handle_conn(stream, &st, &conn_cfg);
            st.http.connections_active.fetch_sub(1, Ordering::AcqRel);
        });
    }
}

struct ConnConfig {
    read_timeout: Duration,
    limits: Limits,
    access_log: bool,
    /// This replica's labelled serialize-stage histogram, recorded next
    /// to the aggregate `stage="serialize"` series so per-replica skew
    /// shows up without breaking existing dashboards.
    replica_serialize: Arc<crate::obs::Hist>,
}

fn peer_label(stream: &TcpStream) -> String {
    stream.peer_addr().map(|a| a.to_string()).unwrap_or_else(|_| "-".into())
}

/// One connection's keep-alive loop: parse → route → respond, until the
/// peer closes, a protocol error forces a close, or drain is signalled.
fn handle_conn(stream: TcpStream, state: &AppState, cfg: &ConnConfig) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(cfg.read_timeout));
    let peer = peer_label(&stream);
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = HttpReader::new(stream);
    loop {
        match reader.read_request(&cfg.limits) {
            Ok(req) => {
                let t0 = Instant::now();
                let resp = router::handle(state, &req);
                state.http.record_response(resp.status);
                if cfg.access_log {
                    let id = resp
                        .extra
                        .iter()
                        .find(|(k, _)| k == "x-request-id")
                        .map(|(_, v)| v.as_str())
                        .unwrap_or("-");
                    eprintln!(
                        "[gateway] {id} {peer} \"{} {}\" {} {}B {:.2}ms",
                        req.method,
                        req.target,
                        resp.status,
                        resp.body.len(),
                        t0.elapsed().as_secs_f64() * 1e3,
                    );
                }
                // drain closes the connection after the in-flight response
                let keep = req.keep_alive() && !state.draining.load(Ordering::Acquire);
                // serialize stage: header + body hit the socket here, after
                // the router already stamped parse/queue/batch/kernel
                let t_ser = Instant::now();
                let wrote = resp.write_to(&mut writer, keep);
                let spent = t_ser.elapsed();
                state.obs.stage("serialize").record_duration(spent);
                cfg.replica_serialize.record_duration(spent);
                if wrote.is_err() || !keep {
                    return;
                }
            }
            Err(ReadError::Idle) => {
                if state.draining.load(Ordering::Acquire) {
                    return; // idle keep-alive connection during drain
                }
            }
            Err(ReadError::Closed) => return,
            Err(ReadError::Bad { status, msg }) => {
                state.http.record_response(status);
                let id = router::mint_request_id();
                if cfg.access_log {
                    eprintln!("[gateway] {id} {peer} - {status} 0B parse({msg})");
                }
                let _ = router::tag(Response::error(status, &msg), &id)
                    .write_to(&mut writer, false);
                return; // stream state unknown after a parse error
            }
            Err(ReadError::Io(_)) => return,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::pack::PackedModel;
    use crate::util::json;
    use std::io::Write as _;

    fn toy_gateway(max_conns: usize) -> Gateway {
        let pm = PackedModel::synth_mlp(&[6, 8, 3], &[4, 3], 3).unwrap();
        let path = std::env::temp_dir().join("msq_gateway_unit.msqpack");
        pm.save(&path).unwrap();
        let cfg = GatewayConfig {
            port: 0,
            max_conns,
            read_timeout: Duration::from_millis(50),
            server: ServerConfig {
                max_batch: 8,
                max_delay: Duration::from_millis(1),
                queue_cap: 64,
                threads: 1,
                ..ServerConfig::default()
            },
            ..Default::default()
        };
        Gateway::start(cfg, &[("toy".to_string(), path, None)]).unwrap()
    }

    fn roundtrip(addr: SocketAddr, method: &str, target: &str, body: &[u8]) -> (u16, Vec<u8>) {
        let mut s = TcpStream::connect(addr).unwrap();
        super::super::http::write_request(&mut s, method, target, Some("application/json"), body)
            .unwrap();
        let mut r = HttpReader::new(s);
        r.read_response(&Limits::default()).unwrap()
    }

    #[test]
    fn serves_and_shuts_down_cleanly() {
        let gw = toy_gateway(8);
        let addr = gw.addr();
        let (code, body) = roundtrip(addr, "GET", "/healthz", b"");
        assert_eq!(code, 200, "{}", String::from_utf8_lossy(&body));
        let (code, body) = roundtrip(addr, "POST", "/v1/models/toy/infer", b"[[0,0,0,0,0,0]]");
        assert_eq!(code, 200, "{}", String::from_utf8_lossy(&body));
        let v = json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert_eq!(v.path(&["outputs", "0"]).unwrap().as_arr().unwrap().len(), 3);
        let (code, body) = roundtrip(addr, "GET", "/debug/stats", b"");
        assert_eq!(code, 200, "{}", String::from_utf8_lossy(&body));
        let v = json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert!(v.path(&["stages", "queue"]).is_some(), "stages missing from /debug/stats");
        let state = gw.state().clone();
        gw.shutdown(); // must drain and join without hanging
        // every worker joined → all three responses stamped the serialize stage
        assert!(state.obs.stage("serialize").count() >= 3);
    }

    #[test]
    fn keep_alive_serves_sequential_requests_on_one_socket() {
        let gw = toy_gateway(8);
        let mut s = TcpStream::connect(gw.addr()).unwrap();
        let mut wire = Vec::new();
        for _ in 0..3 {
            super::super::http::write_request(
                &mut wire,
                "POST",
                "/v1/models/toy/infer",
                Some("application/json"),
                b"[[1,2,3,4,5,6]]",
            )
            .unwrap();
        }
        s.write_all(&wire).unwrap(); // pipelined
        let mut r = HttpReader::new(s);
        for _ in 0..3 {
            let (code, _) = r.read_response(&Limits::default()).unwrap();
            assert_eq!(code, 200);
        }
        gw.shutdown();
    }

    #[test]
    fn malformed_request_gets_400_and_close() {
        let gw = toy_gateway(8);
        let mut s = TcpStream::connect(gw.addr()).unwrap();
        s.write_all(b"NOTAREQUEST\r\n\r\n").unwrap(); // no target/version → 400
        // read raw so headers are visible: parse errors still get a trace ID
        let mut raw = String::new();
        std::io::Read::read_to_string(&mut s, &mut raw).unwrap();
        assert!(raw.starts_with("HTTP/1.1 400"), "{raw}");
        assert!(raw.contains("x-request-id: msq-"), "{raw}");
        gw.shutdown();
    }

    #[test]
    fn pipelined_requests_answer_in_order_and_close_honors_the_last() {
        // three pipelined requests with distinct trace IDs, the last one
        // Connection: close — responses must come back in request order
        // and the server must EOF after the third
        let gw = toy_gateway(8);
        let mut s = TcpStream::connect(gw.addr()).unwrap();
        let mut wire = Vec::new();
        for (id, last) in [("pl-one", false), ("pl-two", false), ("pl-three", true)] {
            let conn = if last { "Connection: close\r\n" } else { "" };
            wire.extend_from_slice(
                format!(
                    "POST /v1/models/toy/infer HTTP/1.1\r\nHost: t\r\nx-request-id: {id}\r\n\
                     Content-Type: application/json\r\nContent-Length: 15\r\n{conn}\r\n\
                     [[1,2,3,4,5,6]]"
                )
                .as_bytes(),
            );
        }
        s.write_all(&wire).unwrap();
        let mut raw = String::new();
        std::io::Read::read_to_string(&mut s, &mut raw).unwrap(); // EOF ends it
        assert_eq!(raw.matches("HTTP/1.1 200").count(), 3, "{raw}");
        let pos = |id: &str| raw.find(id).unwrap_or_else(|| panic!("{id} missing: {raw}"));
        assert!(pos("pl-one") < pos("pl-two") && pos("pl-two") < pos("pl-three"), "{raw}");
        gw.shutdown();
    }

    #[test]
    fn connection_close_is_honored_with_eof() {
        let gw = toy_gateway(8);
        let mut s = TcpStream::connect(gw.addr()).unwrap();
        s.write_all(b"GET /healthz HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n").unwrap();
        let mut raw = String::new();
        // read_to_string returning means the server closed its end
        std::io::Read::read_to_string(&mut s, &mut raw).unwrap();
        assert_eq!(raw.matches("HTTP/1.1 200").count(), 1, "{raw}");
        assert!(raw.to_ascii_lowercase().contains("connection: close"), "{raw}");
        gw.shutdown();
    }

    #[test]
    fn idle_keep_alive_connection_closes_cleanly_during_drain() {
        let gw = toy_gateway(8);
        let mut s = TcpStream::connect(gw.addr()).unwrap();
        s.write_all(b"GET /healthz HTTP/1.1\r\nHost: t\r\n\r\n").unwrap();
        let mut r = HttpReader::new(s.try_clone().unwrap());
        let (code, _) = r.read_response(&Limits::default()).unwrap();
        assert_eq!(code, 200);
        // leave the connection idle and drain: the worker must notice at
        // its next idle tick and close without writing anything else
        gw.shutdown();
        let mut rest = Vec::new();
        std::io::Read::read_to_end(&mut s, &mut rest).unwrap();
        assert!(rest.is_empty(), "drain must not emit bytes on an idle connection");
    }

    #[test]
    fn replicas_share_the_listener_and_label_their_connections() {
        let pm = PackedModel::synth_mlp(&[6, 8, 3], &[4, 3], 3).unwrap();
        let path = std::env::temp_dir().join("msq_gateway_replicas.msqpack");
        pm.save(&path).unwrap();
        let cfg = GatewayConfig {
            port: 0,
            max_conns: 8,
            replicas: 2,
            read_timeout: Duration::from_millis(50),
            ..Default::default()
        };
        let gw = Gateway::start(cfg, &[("toy".to_string(), path, None)]).unwrap();
        for _ in 0..4 {
            let (code, _) = roundtrip(gw.addr(), "GET", "/healthz", b"");
            assert_eq!(code, 200);
        }
        let (code, body) = roundtrip(gw.addr(), "GET", "/metrics", b"");
        assert_eq!(code, 200);
        let text = String::from_utf8_lossy(&body);
        assert!(text.contains("msq_replica_connections_total{replica="), "{text}");
        gw.shutdown();
    }

    #[test]
    fn client_request_id_is_echoed_over_the_wire() {
        let gw = toy_gateway(8);
        let mut s = TcpStream::connect(gw.addr()).unwrap();
        s.write_all(
            b"GET /healthz HTTP/1.1\r\nHost: t\r\nx-request-id: trace-me-42\r\n\
              Connection: close\r\n\r\n",
        )
        .unwrap();
        let mut raw = String::new();
        std::io::Read::read_to_string(&mut s, &mut raw).unwrap();
        assert!(raw.starts_with("HTTP/1.1 200"), "{raw}");
        assert!(raw.contains("x-request-id: trace-me-42"), "{raw}");
        gw.shutdown();
    }
}
