//! Closed-loop HTTP load generator (`msq loadgen`): N keep-alive
//! connections, each issuing `POST /v1/models/{name}/infer` requests
//! back-to-back and timing write→response wall clock. Discovers the
//! model's input width from `/healthz`, so pointing it at a gateway is
//! one flag. The report records p50/p95/p99 latency and req/s — the
//! numbers `benches/http_gateway.rs` persists to `BENCH_http.json`.

use std::collections::BTreeMap;
use std::net::TcpStream;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::util::json::{self, Json};
use crate::util::prng::Rng;
use crate::util::stats::percentile;

use super::http::{write_request, HttpReader, Limits};

/// Traffic shape (`--scenario`). The deterministic part of every shape
/// — which model each request hits and what its body is — lives in
/// [`connection_plan`]; the scenario only adds pacing (bursty) or model
/// mixing (zipfian) on top of the steady closed loop.
#[derive(Clone, Debug)]
pub enum Scenario {
    /// Back-to-back requests: the legacy closed loop.
    Steady,
    /// `burst` back-to-back requests, then sleep `gap`, repeat — the
    /// admission wait room's natural prey.
    Bursty { burst: usize, gap: Duration },
    /// Each request picks one of `models` with Zipf weights (1/k on the
    /// k-th listed name), exercising multi-model cache contention.
    Zipfian { models: Vec<String> },
}

impl Scenario {
    /// The `--scenario` spelling of this shape.
    pub fn name(&self) -> &'static str {
        match self {
            Scenario::Steady => "steady",
            Scenario::Bursty { .. } => "bursty",
            Scenario::Zipfian { .. } => "zipfian",
        }
    }
}

#[derive(Clone, Debug)]
pub struct LoadgenConfig {
    /// Gateway address, `host:port`.
    pub addr: String,
    /// Model route name (must be served — see `GET /v1/models`).
    pub model: String,
    /// Total requests across all connections.
    pub requests: usize,
    /// Concurrent keep-alive connections (closed loop: each waits for
    /// its response before sending the next request).
    pub concurrency: usize,
    /// Rows per request body (the gateway fans rows into the batcher).
    pub batch: usize,
    pub seed: u64,
    /// Per-read socket timeout (a stuck gateway fails fast, not forever).
    pub timeout: Duration,
    /// Traffic shape: steady, bursty, or multi-model zipfian.
    pub scenario: Scenario,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            addr: "127.0.0.1:8080".into(),
            model: "mlp".into(),
            requests: 1000,
            concurrency: 8,
            batch: 1,
            seed: 42,
            timeout: Duration::from_secs(30),
            scenario: Scenario::Steady,
        }
    }
}

/// Aggregated closed-loop results.
#[derive(Clone, Debug)]
pub struct LoadReport {
    /// The [`Scenario::name`] this run used.
    pub scenario: String,
    pub sent: usize,
    pub ok: usize,
    /// Non-2xx responses by status code (429 shed shows up here).
    pub by_status: BTreeMap<u16, usize>,
    /// Transport failures (connect/read errors).
    pub errors: usize,
    /// (non-2xx + transport errors) / sent, in [0, 1].
    pub error_rate: f64,
    pub wall_s: f64,
    pub rps: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub mean_ms: f64,
    pub max_ms: f64,
    /// Server-side per-stage breakdown: the delta of the gateway's
    /// `/debug/stats` stage histograms between run start and end. Empty
    /// when the gateway predates the endpoint (best-effort scrape).
    pub stages: Vec<StageSlo>,
    /// Activation-observer deltas over the run (gateway started with
    /// `--qstats`); `None` when the observers are off.
    pub qstats: Option<QstatsDelta>,
}

/// What the gateway's activation observers accumulated during the run,
/// summed over layers: the quant-health counterpart of [`StageSlo`].
#[derive(Clone, Debug)]
pub struct QstatsDelta {
    /// Activation values observed during the run.
    pub observations: u64,
    /// Endpoint-saturated weight codes counted during the run.
    pub saturated: u64,
    /// Layers with at least one observation by run end.
    pub layers: usize,
}

/// One request-lifecycle stage's share of the run, as seen by the server.
#[derive(Clone, Debug)]
pub struct StageSlo {
    pub stage: String,
    /// Stage observations recorded during the run.
    pub count: u64,
    /// Mean stage duration over those observations, milliseconds.
    pub mean_ms: f64,
    /// Total stage time during the run, seconds.
    pub sum_s: f64,
}

impl LoadReport {
    pub fn to_json(&self) -> Json {
        let by_status: Vec<Json> = self
            .by_status
            .iter()
            .map(|(c, n)| {
                Json::obj(vec![
                    ("code", Json::Num(*c as f64)),
                    ("count", Json::Num(*n as f64)),
                ])
            })
            .collect();
        let stages: Vec<Json> = self
            .stages
            .iter()
            .map(|s| {
                Json::obj(vec![
                    ("stage", Json::Str(s.stage.clone())),
                    ("count", Json::Num(s.count as f64)),
                    ("mean_ms", Json::Num(s.mean_ms)),
                    ("sum_s", Json::Num(s.sum_s)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("scenario", Json::Str(self.scenario.clone())),
            ("sent", Json::Num(self.sent as f64)),
            ("ok", Json::Num(self.ok as f64)),
            ("errors", Json::Num(self.errors as f64)),
            ("error_rate", Json::Num(self.error_rate)),
            ("by_status", Json::Arr(by_status)),
            ("wall_s", Json::Num(self.wall_s)),
            ("rps", Json::Num(self.rps)),
            ("p50_ms", Json::Num(self.p50_ms)),
            ("p95_ms", Json::Num(self.p95_ms)),
            ("p99_ms", Json::Num(self.p99_ms)),
            ("mean_ms", Json::Num(self.mean_ms)),
            ("max_ms", Json::Num(self.max_ms)),
            ("stages", Json::Arr(stages)),
            (
                "qstats",
                match &self.qstats {
                    Some(q) => Json::obj(vec![
                        ("observations", Json::Num(q.observations as f64)),
                        ("saturated", Json::Num(q.saturated as f64)),
                        ("layers", Json::Num(q.layers as f64)),
                    ]),
                    None => Json::Null,
                },
            ),
        ])
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "{} ok / {} non-2xx / {} errors ({:.2}% err) | {:.0} req/s | p50 {:.2} ms \
             p95 {:.2} ms p99 {:.2} ms",
            self.ok,
            self.by_status.values().sum::<usize>(),
            self.errors,
            self.error_rate * 100.0,
            self.rps,
            self.p50_ms,
            self.p95_ms,
            self.p99_ms,
        )
    }

    /// Multi-line per-stage SLO breakdown (empty string when the gateway
    /// exposed no `/debug/stats` stage data).
    pub fn stage_summary(&self) -> String {
        let mut out = String::new();
        for s in &self.stages {
            if s.count == 0 {
                continue;
            }
            out.push_str(&format!(
                "  stage {:<9} {:>8} obs  mean {:>8.3} ms  total {:>8.3} s\n",
                s.stage, s.count, s.mean_ms, s.sum_s,
            ));
        }
        out
    }
}

/// The model names this run will route to, in Zipf-rank order: the
/// zipfian list when one is set (and non-empty), else the single
/// `--model` target.
fn target_models(cfg: &LoadgenConfig) -> Vec<String> {
    match &cfg.scenario {
        Scenario::Zipfian { models } if !models.is_empty() => models.clone(),
        _ => vec![cfg.model.clone()],
    }
}

/// Ask `/healthz` for the input width of every model this run targets.
fn discover_input_dims(cfg: &LoadgenConfig) -> Result<BTreeMap<String, usize>> {
    let mut s = TcpStream::connect(&cfg.addr)
        .with_context(|| format!("connecting {}", cfg.addr))?;
    s.set_read_timeout(Some(cfg.timeout))?;
    write_request(&mut s, "GET", "/healthz", None, b"")?;
    let mut r = HttpReader::new(s);
    let (status, body) = r
        .read_response(&Limits::default())
        .map_err(|e| anyhow::anyhow!("reading /healthz: {e}"))?;
    // 200 when serving, 503 while draining — both carry the inventory
    if status != 200 && status != 503 {
        bail!("/healthz answered {status}");
    }
    let v = json::parse(std::str::from_utf8(&body).context("healthz body not UTF-8")?)
        .map_err(|e| anyhow::anyhow!("healthz JSON: {e}"))?;
    let models = v.get("models").and_then(Json::as_arr).context("healthz lacks models[]")?;
    let mut dims = BTreeMap::new();
    for m in models {
        if let (Some(name), Some(dim)) = (
            m.get("name").and_then(Json::as_str),
            m.get("input_dim").and_then(Json::as_usize),
        ) {
            dims.insert(name.to_string(), dim);
        }
    }
    for want in target_models(cfg) {
        if !dims.contains_key(&want) {
            bail!("gateway does not serve model {want:?} (see GET /v1/models)");
        }
    }
    Ok(dims)
}

/// The deterministic half of one connection's request stream: for each
/// of its `n` requests, the model it routes to and the JSON body it
/// sends. Pure in `(cfg.seed, cfg.scenario, c, n, dims)` — no sockets,
/// no clock — so two runs with the same seed produce byte-identical
/// traffic (the `--seed` determinism contract). Bursty pacing does not
/// touch the RNG, so it changes *when* requests go out, never *what*.
pub fn connection_plan(
    cfg: &LoadgenConfig,
    c: usize,
    n: usize,
    dims: &BTreeMap<String, usize>,
) -> Vec<(String, String)> {
    let mut rng = Rng::new(cfg.seed ^ (c as u64).wrapping_mul(0x9E37_79B9));
    let names = target_models(cfg);
    // Zipf over list order: the k-th listed model gets weight 1/k
    let weights: Vec<f32> = (1..=names.len()).map(|k| 1.0 / k as f32).collect();
    let total: f32 = weights.iter().sum();
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let pick = if names.len() == 1 {
            0
        } else {
            let mut u = rng.uniform() * total;
            let mut pick = names.len() - 1;
            for (i, w) in weights.iter().enumerate() {
                if u < *w {
                    pick = i;
                    break;
                }
                u -= w;
            }
            pick
        };
        let model = &names[pick];
        let dim = dims.get(model).copied().unwrap_or(0);
        out.push((model.clone(), random_batch_body(&mut rng, cfg.batch, dim)));
    }
    out
}

/// Scrape `GET /debug/stats` for per-stage `(count, sum_s)` pairs.
/// Best-effort: any failure (old gateway, transport error) yields an
/// empty map, so SLO deltas degrade to "no stage data" not a hard error.
fn scrape_stages(cfg: &LoadgenConfig) -> BTreeMap<String, (f64, f64)> {
    let mut out = BTreeMap::new();
    let Ok(mut s) = TcpStream::connect(&cfg.addr) else { return out };
    if s.set_read_timeout(Some(cfg.timeout)).is_err()
        || write_request(&mut s, "GET", "/debug/stats", None, b"").is_err()
    {
        return out;
    }
    let mut r = HttpReader::new(s);
    let Ok((200, body)) = r.read_response(&Limits::default()) else { return out };
    let Ok(text) = std::str::from_utf8(&body) else { return out };
    let Ok(v) = json::parse(text) else { return out };
    if let Some(Json::Obj(map)) = v.get("stages") {
        for (stage, st) in map {
            let count = st.get("count").and_then(Json::as_f64).unwrap_or(0.0);
            let sum_s = st.get("sum_s").and_then(Json::as_f64).unwrap_or(0.0);
            out.insert(stage.clone(), (count, sum_s));
        }
    }
    out
}

/// Scrape the `"qstats"` section of `/debug/stats`: `(observations,
/// saturated, live layers)` summed over per-layer observers. `None`
/// when the observers are disabled or the scrape fails (best-effort,
/// like [`scrape_stages`]).
fn scrape_qstats(cfg: &LoadgenConfig) -> Option<(u64, u64, usize)> {
    let mut s = TcpStream::connect(&cfg.addr).ok()?;
    s.set_read_timeout(Some(cfg.timeout)).ok()?;
    write_request(&mut s, "GET", "/debug/stats", None, b"").ok()?;
    let mut r = HttpReader::new(s);
    let Ok((200, body)) = r.read_response(&Limits::default()) else { return None };
    let v = json::parse(std::str::from_utf8(&body).ok()?).ok()?;
    let q = v.get("qstats")?;
    if q.get("enabled").and_then(Json::as_bool) != Some(true) {
        return None;
    }
    let layers = q.get("layers").and_then(Json::as_obj)?;
    let (mut obs, mut sat, mut live) = (0u64, 0u64, 0usize);
    for l in layers.values() {
        let count = l.get("count").and_then(Json::as_f64).unwrap_or(0.0) as u64;
        obs += count;
        sat += l.get("sat_low").and_then(Json::as_f64).unwrap_or(0.0) as u64
            + l.get("sat_high").and_then(Json::as_f64).unwrap_or(0.0) as u64;
        live += usize::from(count > 0);
    }
    Some((obs, sat, live))
}

/// Per-stage deltas between two scrapes, in taxonomy order.
fn stage_deltas(
    before: &BTreeMap<String, (f64, f64)>,
    after: &BTreeMap<String, (f64, f64)>,
) -> Vec<StageSlo> {
    let mut out = Vec::new();
    for stage in crate::obs::STAGES {
        let Some(&(c1, s1)) = after.get(stage) else { continue };
        let (c0, s0) = before.get(stage).copied().unwrap_or((0.0, 0.0));
        let count = (c1 - c0).max(0.0) as u64;
        let sum_s = (s1 - s0).max(0.0);
        let mean_ms = if count > 0 { sum_s / count as f64 * 1e3 } else { 0.0 };
        out.push(StageSlo { stage: stage.to_string(), count, mean_ms, sum_s });
    }
    out
}

/// Run the closed loop; blocks until all requests are answered.
pub fn run(cfg: &LoadgenConfig) -> Result<LoadReport> {
    ensure_valid(cfg)?;
    let dims = discover_input_dims(cfg)?;
    let stages_before = scrape_stages(cfg);
    let qstats_before = scrape_qstats(cfg);
    let latencies: Mutex<Vec<f64>> = Mutex::new(Vec::with_capacity(cfg.requests));
    let by_status: Mutex<BTreeMap<u16, usize>> = Mutex::new(BTreeMap::new());
    let errors = std::sync::atomic::AtomicUsize::new(0);
    let ok = std::sync::atomic::AtomicUsize::new(0);
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for c in 0..cfg.concurrency {
            // distribute the remainder so the total is exactly `requests`
            let n = cfg.requests / cfg.concurrency
                + usize::from(c < cfg.requests % cfg.concurrency);
            let latencies = &latencies;
            let by_status = &by_status;
            let errors = &errors;
            let ok = &ok;
            let dims = &dims;
            let cfg = &cfg;
            s.spawn(move || {
                let plan = connection_plan(cfg, c, n, dims);
                let mut conn: Option<HttpReader<TcpStream>> = None;
                let mut local_lat = Vec::with_capacity(n);
                for (i, (model, body)) in plan.iter().enumerate() {
                    // bursty pacing: `burst` back-to-back, then a gap —
                    // pacing only, the plan above is already fixed
                    if let Scenario::Bursty { burst, gap } = &cfg.scenario {
                        if i > 0 && i % burst == 0 {
                            std::thread::sleep(*gap);
                        }
                    }
                    let target = format!("/v1/models/{model}/infer");
                    let t = Instant::now();
                    match one_request(&mut conn, cfg, &target, body.as_bytes()) {
                        Ok(status) => {
                            if (200..300).contains(&status) {
                                ok.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                                local_lat.push(t.elapsed().as_secs_f64());
                            } else {
                                *by_status.lock().unwrap().entry(status).or_insert(0) += 1;
                            }
                        }
                        Err(_) => {
                            errors.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                            conn = None; // reconnect on the next request
                        }
                    }
                }
                latencies.lock().unwrap().extend(local_lat);
            });
        }
    });
    let wall_s = t0.elapsed().as_secs_f64();
    let stages = stage_deltas(&stages_before, &scrape_stages(cfg));
    let qstats = scrape_qstats(cfg).map(|(obs1, sat1, layers)| {
        let (obs0, sat0, _) = qstats_before.unwrap_or((0, 0, 0));
        QstatsDelta {
            observations: obs1.saturating_sub(obs0),
            saturated: sat1.saturating_sub(sat0),
            layers,
        }
    });
    let lats = latencies.into_inner().unwrap();
    let ok = ok.into_inner();
    let by_status = by_status.into_inner().unwrap();
    let errors = errors.into_inner();
    let failed = by_status.values().sum::<usize>() + errors;
    Ok(LoadReport {
        scenario: cfg.scenario.name().to_string(),
        sent: cfg.requests,
        ok,
        by_status,
        errors,
        error_rate: failed as f64 / cfg.requests.max(1) as f64,
        wall_s,
        rps: ok as f64 / wall_s.max(1e-9),
        p50_ms: percentile(&lats, 50.0) * 1e3,
        p95_ms: percentile(&lats, 95.0) * 1e3,
        p99_ms: percentile(&lats, 99.0) * 1e3,
        mean_ms: if lats.is_empty() {
            0.0
        } else {
            lats.iter().sum::<f64>() / lats.len() as f64 * 1e3
        },
        max_ms: lats.iter().copied().fold(0.0f64, f64::max) * 1e3,
        stages,
        qstats,
    })
}

fn ensure_valid(cfg: &LoadgenConfig) -> Result<()> {
    if cfg.requests == 0 || cfg.concurrency == 0 || cfg.batch == 0 {
        bail!("loadgen needs nonzero --requests, --concurrency, and --batch");
    }
    match &cfg.scenario {
        Scenario::Bursty { burst: 0, .. } => bail!("--scenario bursty needs a nonzero --burst"),
        Scenario::Zipfian { models } if models.is_empty() => {
            bail!("--scenario zipfian needs at least one --model")
        }
        _ => Ok(()),
    }
}

/// `[[f32,…],…]` body of `batch` random normal rows.
fn random_batch_body(rng: &mut Rng, batch: usize, input_dim: usize) -> String {
    let mut s = String::with_capacity(batch * input_dim * 8);
    s.push('[');
    for b in 0..batch {
        if b > 0 {
            s.push(',');
        }
        s.push('[');
        for i in 0..input_dim {
            if i > 0 {
                s.push(',');
            }
            // short decimal keeps bodies compact; exact value is irrelevant
            s.push_str(&format!("{:.4}", rng.normal()));
        }
        s.push(']');
    }
    s.push(']');
    s
}

/// Issue one request over the cached keep-alive connection, dialing a
/// fresh one when absent or broken.
fn one_request(
    conn: &mut Option<HttpReader<TcpStream>>,
    cfg: &LoadgenConfig,
    target: &str,
    body: &[u8],
) -> Result<u16> {
    if conn.is_none() {
        let s = TcpStream::connect(&cfg.addr)?;
        s.set_read_timeout(Some(cfg.timeout))?;
        s.set_nodelay(true)?;
        *conn = Some(HttpReader::new(s));
    }
    let r = conn.as_mut().unwrap();
    // HttpReader owns the stream; clone a write handle for the request
    let mut w = r.stream().try_clone()?;
    if let Err(e) = write_request(&mut w, "POST", target, Some("application/json"), body) {
        *conn = None;
        return Err(e.into());
    }
    match r.read_response(&Limits::default()) {
        Ok((status, _body)) => Ok(status),
        Err(e) => {
            *conn = None;
            bail!("reading response: {e}")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::gateway::{Gateway, GatewayConfig};
    use crate::quant::pack::PackedModel;
    use crate::serve::ServerConfig;

    #[test]
    fn closed_loop_against_live_gateway() {
        // hold the qstats test lock so another test's enabled observers
        // can't leak into this gateway's (observers-off) report
        let _guard = crate::obs::qstats::test_mutex();
        let pm = PackedModel::synth_mlp(&[6, 8, 3], &[4, 3], 3).unwrap();
        let path = std::env::temp_dir().join("msq_loadgen_unit.msqpack");
        pm.save(&path).unwrap();
        let gw = Gateway::start(
            GatewayConfig {
                port: 0,
                max_conns: 8,
                server: ServerConfig {
                    max_batch: 8,
                    max_delay: Duration::from_millis(1),
                    queue_cap: 256,
                    threads: 1,
                    ..Default::default()
                },
                ..Default::default()
            },
            &[("toy".to_string(), path, None)],
        )
        .unwrap();
        let report = run(&LoadgenConfig {
            addr: gw.addr().to_string(),
            model: "toy".into(),
            requests: 60,
            concurrency: 3,
            batch: 2,
            seed: 9,
            timeout: Duration::from_secs(30),
            scenario: Scenario::Steady,
        })
        .unwrap();
        assert_eq!(report.sent, 60);
        assert_eq!(report.ok + report.by_status.values().sum::<usize>() + report.errors, 60);
        assert_eq!(report.errors, 0, "{report:?}");
        assert_eq!(report.ok, 60, "{report:?}");
        assert!(report.p99_ms >= report.p50_ms);
        assert!(report.rps > 0.0);
        assert_eq!(report.error_rate, 0.0, "{report:?}");
        // server-side stage SLO: 60 requests × 2 rows each → 120 queue
        // observations, all recorded before their responses were written
        let q = report.stages.iter().find(|s| s.stage == "queue").expect("queue stage");
        assert_eq!(q.count, 120, "{report:?}");
        assert!(report.stages.iter().any(|s| s.stage == "serialize"), "{report:?}");
        let j = report.to_json().to_string();
        assert!(j.contains("\"p99_ms\""), "{j}");
        assert!(j.contains("\"stages\""), "{j}");
        assert!(j.contains("\"error_rate\""), "{j}");
        assert!(j.contains("\"scenario\":\"steady\""), "{j}");
        // observers were never enabled → the report says so explicitly
        assert!(report.qstats.is_none(), "{report:?}");
        assert!(j.contains("\"qstats\":null"), "{j}");
        // unknown model errors cleanly
        assert!(run(&LoadgenConfig {
            addr: gw.addr().to_string(),
            model: "ghost".into(),
            requests: 1,
            concurrency: 1,
            batch: 1,
            seed: 1,
            timeout: Duration::from_secs(5),
            scenario: Scenario::Steady,
        })
        .is_err());
        gw.shutdown();
    }

    #[test]
    fn qstats_deltas_ride_the_report() {
        let _guard = crate::obs::qstats::test_mutex();
        let pm = PackedModel::synth_mlp(&[6, 8, 3], &[4, 3], 3).unwrap();
        let path = std::env::temp_dir().join("msq_loadgen_qstats.msqpack");
        pm.save(&path).unwrap();
        let gw = Gateway::start(
            GatewayConfig {
                port: 0,
                max_conns: 4,
                qstats: Some(1.0),
                server: ServerConfig {
                    max_batch: 8,
                    max_delay: Duration::from_millis(1),
                    queue_cap: 256,
                    threads: 1,
                    ..Default::default()
                },
                ..Default::default()
            },
            &[("lgq".to_string(), path, None)],
        )
        .unwrap();
        let report = run(&LoadgenConfig {
            addr: gw.addr().to_string(),
            model: "lgq".into(),
            requests: 20,
            concurrency: 2,
            batch: 1,
            seed: 5,
            timeout: Duration::from_secs(30),
            scenario: Scenario::Steady,
        })
        .unwrap();
        assert_eq!(report.ok, 20, "{report:?}");
        let q = report.qstats.as_ref().expect("observers were on");
        assert!(q.observations > 0, "{report:?}");
        assert_eq!(q.layers, 2, "one observer per planned layer: {report:?}");
        let j = report.to_json().to_string();
        assert!(j.contains("\"observations\""), "{j}");
        let qs = crate::obs::qstats::qstats();
        qs.enable(false);
        qs.reset_prefix("lgq/");
        gw.shutdown();
    }

    #[test]
    fn connection_plans_are_seed_deterministic() {
        // pure-plan determinism: no gateway, no clock — same seed, same
        // bytes; bursty pacing must not perturb the stream
        let dims: BTreeMap<String, usize> =
            [("a".to_string(), 4), ("b".to_string(), 6)].into_iter().collect();
        let mk = |seed, scenario| LoadgenConfig {
            model: "a".into(),
            batch: 2,
            seed,
            scenario,
            ..Default::default()
        };
        let steady = mk(7, Scenario::Steady);
        for c in 0..3 {
            assert_eq!(
                connection_plan(&steady, c, 40, &dims),
                connection_plan(&steady, c, 40, &dims)
            );
        }
        // different connections and different seeds diverge
        assert_ne!(connection_plan(&steady, 0, 40, &dims), connection_plan(&steady, 1, 40, &dims));
        assert_ne!(
            connection_plan(&steady, 0, 40, &dims),
            connection_plan(&mk(8, Scenario::Steady), 0, 40, &dims)
        );
        // bursty is pacing only: the planned traffic is identical
        let bursty = mk(7, Scenario::Bursty { burst: 8, gap: Duration::from_millis(5) });
        assert_eq!(connection_plan(&steady, 2, 40, &dims), connection_plan(&bursty, 2, 40, &dims));
        // steady plans route every request to --model
        assert!(connection_plan(&steady, 0, 40, &dims).iter().all(|(m, _)| m == "a"));
    }

    #[test]
    fn zipfian_plans_skew_toward_the_head_model() {
        let dims: BTreeMap<String, usize> =
            [("hot".to_string(), 4), ("cold".to_string(), 4)].into_iter().collect();
        let cfg = LoadgenConfig {
            scenario: Scenario::Zipfian { models: vec!["hot".into(), "cold".into()] },
            seed: 11,
            ..Default::default()
        };
        let plan = connection_plan(&cfg, 0, 300, &dims);
        let hot = plan.iter().filter(|(m, _)| m == "hot").count();
        let cold = plan.len() - hot;
        assert!(hot > cold, "1/k weights must favor the first listed model: {hot} vs {cold}");
        assert!(cold > 0, "the tail model still sees traffic: {hot} vs {cold}");
        // determinism holds for the mixed stream too
        assert_eq!(plan, connection_plan(&cfg, 0, 300, &dims));
    }
}
