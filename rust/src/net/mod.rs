//! `msq gateway` (S17): the HTTP serving front-end over `serve`.
//!
//! PR 1 made packed models answer requests in-process; this subsystem
//! puts them on the network with **zero new dependencies** — `std::net`
//! sockets, the resident `util::threadpool` for per-connection workers,
//! and `util::json` for the wire format. Four pieces:
//!
//! * [`http`] — minimal HTTP/1.1: request parser with hard limits
//!   (never panics on wire data), response writer, keep-alive, and the
//!   client half the load generator reuses;
//! * [`router`] — the URL space (`/v1/models/{name}/infer`, `/healthz`,
//!   `/metrics` in Prometheus text, `/admin/reload` hot-swap) over a
//!   multi-model [`router::AppState`]; pure request → response, so it
//!   unit-tests without sockets;
//! * [`gateway`] — accept loop with a connection budget, graceful
//!   drain (flag + listener wake + batcher flush) on the
//!   SIGTERM-equivalent [`gateway::Gateway::shutdown`];
//! * [`loadgen`] — closed-loop multi-connection load generator behind
//!   `msq loadgen` and `benches/http_gateway.rs` → `BENCH_http.json`.
//!
//! Backpressure contract, end to end: batcher `QueueFull` → **429**
//! (`Retry-After: 1`), drain/shutdown → **503**, malformed input →
//! **400**, connection budget exhausted → **503** at accept time.
//!
//! Threading model: one resident `util::threadpool` worker per
//! connection (keep-alive loops run on the worker), the accept loop on
//! the gateway thread; inference inside a handler re-enters the same
//! pool via the batcher, which is safe because `par_for` callers
//! participate and help drain (nested dispatch cannot deadlock).
//! Handlers hold a per-generation `serve::Server` handle, so a hot
//! reload never changes responses mid-request — and the response bytes
//! themselves are bit-identical to in-process inference (pinned by
//! `tests/gateway_e2e.rs`), because the serving kernels guarantee
//! configuration-independent logits (see [`crate::kernels`]).

pub mod gateway;
pub mod http;
pub mod loadgen;
pub mod router;

pub use gateway::{Gateway, GatewayConfig, ModelSpec};
pub use http::{Limits, Request, Response};
pub use loadgen::{LoadReport, LoadgenConfig};
pub use router::AppState;
