//! Gateway routing: URL space, multi-model state, and the Prometheus
//! scrape. Pure request → response logic (no sockets), so the whole
//! surface unit-tests without binding a port.
//!
//! ```text
//! GET  /healthz                    liveness + model inventory (503 when draining)
//! GET  /metrics                    Prometheus text format
//! GET  /debug/stats                JSON dump: stage histograms, per-model metrics, profiler
//! GET  /debug/model/{name}         per-layer quantization health: load-time static
//!                                  analysis + runtime activation observers
//! GET  /v1/models                  model inventory
//! POST /v1/models/{name}/infer     JSON batch [[f32,…],…] → logits
//! POST /admin/reload               zero-downtime .msqpack hot-swap
//! ```
//!
//! When an admin token is configured, `POST /admin/reload` and both
//! `/debug/*` endpoints require `Authorization: Bearer <token>` (the
//! debug pages leak layer names and activation ranges, so they sit
//! behind the same gate as the mutating route).
//!
//! Backpressure maps [`SubmitError`] onto status codes: `QueueFull` →
//! **429** (with `Retry-After`), `ShuttingDown`/drain → **503**,
//! `BadInput` → **400**. In-flight requests always finish: a reload
//! swaps the [`Server`] handle under new traffic while handlers that
//! hold the old `Arc` drain through the old batcher.

use std::collections::{BTreeMap, BTreeSet};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock, RwLock};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::metrics::Prom;
use crate::serve::batcher::SubmitError;
use crate::serve::{ServableModel, Server, ServerConfig};
use crate::util::json::{self, Json};
use crate::util::threadpool::ThreadPool;

use super::http::{Request, Response};

/// One served model: the running [`Server`] plus enough provenance to
/// hot-reload it (`source` path, dim override) and report freshness
/// (`generation` bumps on every swap).
pub struct ModelEntry {
    pub server: Arc<Server>,
    pub source: PathBuf,
    pub input_dim_override: Option<usize>,
    pub generation: u64,
    /// Per-layer activation absmax observed by the *previous* generation
    /// (qstats keys under `"{model}/"`), snapshotted at swap time so
    /// [`DRIFT_THRESHOLD`] can compare the new pack's input ranges
    /// against what the outgoing weights were seeing.
    pub prev_absmax: BTreeMap<String, f32>,
    /// Layers that already bumped `msq_act_range_drift_total` this
    /// generation: the counter fires once per layer per swap, so
    /// repeated scrapes stay idempotent.
    pub drift_fired: Mutex<BTreeSet<String>>,
}

/// Route name a `.msqpack` path implies: its file stem. Shared by
/// `/admin/reload` and the `msq gateway --packed path` CLI so the two
/// naming rules cannot drift.
pub fn model_name_from_path(path: &Path) -> Result<String> {
    path.file_stem()
        .and_then(|s| s.to_str())
        .map(|s| s.to_string())
        .with_context(|| format!("cannot derive a model name from {path:?}"))
}

/// Gateway-level counters (the per-model serving counters live in each
/// model's `ServeMetrics`).
#[derive(Default)]
pub struct HttpMetrics {
    pub connections_total: AtomicU64,
    pub connections_rejected: AtomicU64,
    pub connections_active: AtomicU64,
    pub reloads_total: AtomicU64,
    responses: Mutex<BTreeMap<u16, u64>>,
}

impl HttpMetrics {
    pub fn record_response(&self, code: u16) {
        *self.responses.lock().unwrap().entry(code).or_insert(0) += 1;
    }

    pub fn responses(&self) -> BTreeMap<u16, u64> {
        self.responses.lock().unwrap().clone()
    }
}

/// Shared gateway state: the model map, batcher config for (re)loads,
/// the drain flag, and the connection pool (for backlog observability).
pub struct AppState {
    models: RwLock<BTreeMap<String, ModelEntry>>,
    pub server_cfg: ServerConfig,
    pub draining: AtomicBool,
    pub http: HttpMetrics,
    pub started: Instant,
    pub conn_pool: Arc<ThreadPool>,
    /// Per-gateway observability registry: request-lifecycle stage
    /// histograms plus reload counters, rendered into `/metrics` and
    /// dumped by `GET /debug/stats`.
    pub obs: crate::obs::Registry,
    /// When set, `POST /admin/reload` requires `Authorization: Bearer
    /// <token>` and answers 401 otherwise.
    pub admin_token: Option<String>,
    /// Serve int-capable layers through the integer kernels: every model
    /// loaded (or hot-reloaded) by this gateway gets
    /// [`ServableModel::int8`] set. Mirrors `GatewayConfig::int8`.
    pub int8: bool,
}

impl AppState {
    pub fn new(server_cfg: ServerConfig, conn_pool: Arc<ThreadPool>) -> AppState {
        let obs = crate::obs::Registry::new();
        obs.init_stages();
        obs.describe("msq_reload_outcomes_total", "Reload attempts by outcome");
        obs.describe("msq_reload_duration_seconds", "Wall time of /admin/reload handling");
        obs.describe("msq_reload_generation", "Generation after the last successful reload");
        obs.describe(
            "msq_act_range_drift_total",
            "Layers whose activation absmax shifted beyond the drift threshold across a reload",
        );
        obs.describe(
            "msq_replica_connections_total",
            "Connections admitted per gateway accept-loop replica",
        );
        AppState {
            models: RwLock::new(BTreeMap::new()),
            server_cfg,
            draining: AtomicBool::new(false),
            http: HttpMetrics::default(),
            started: Instant::now(),
            conn_pool,
            obs,
            admin_token: None,
            int8: false,
        }
    }

    /// Load (or hot-swap) `name` from a `.msqpack`. The new [`Server`]
    /// replaces the old handle atomically under the map lock; handlers
    /// still holding the old `Arc` drain through the old batcher, so no
    /// in-flight request is dropped.
    pub fn load_model(
        &self,
        name: &str,
        path: &Path,
        override_dim: Option<usize>,
    ) -> Result<Json> {
        if name.is_empty() || name.contains('/') {
            bail!("model name {name:?} must be a non-empty path segment");
        }
        let mut model = ServableModel::load(name, path, override_dim)
            .with_context(|| format!("loading {path:?}"))?;
        model.int8 = self.int8;
        let model = Arc::new(model);
        let server = Arc::new(Server::start(model, self.server_cfg.clone()));
        // snapshot the outgoing generation's activation ranges (empty
        // unless --qstats saw traffic) and clear the observers, so the
        // new generation accumulates from scratch and the drift check
        // compares new-vs-old rather than a running mixture of both
        let qs = crate::obs::qstats::qstats();
        let prefix = format!("{name}/");
        let prev_absmax = qs.absmax_by_prefix(&prefix);
        qs.reset_prefix(&prefix);
        let mut map = self.models.write().unwrap();
        let generation = map.get(name).map(|e| e.generation + 1).unwrap_or(1);
        let entry = ModelEntry {
            server,
            source: path.to_path_buf(),
            input_dim_override: override_dim,
            generation,
            prev_absmax,
            drift_fired: Mutex::new(BTreeSet::new()),
        };
        let info = Self::entry_info(name, &entry);
        let old = map.insert(name.to_string(), entry);
        drop(map);
        // retire the old server outside the lock; if this was the last
        // handle its batcher drains here (admin path, not the hot path)
        drop(old);
        Ok(info)
    }

    /// The running server for `name` (lock dropped before any inference).
    pub fn server(&self, name: &str) -> Option<Arc<Server>> {
        self.models.read().unwrap().get(name).map(|e| e.server.clone())
    }

    pub fn model_names(&self) -> Vec<String> {
        self.models.read().unwrap().keys().cloned().collect()
    }

    /// Signal drain: infer/reload answer 503 from now on, and every
    /// model's batcher stops admitting while it flushes.
    pub fn start_drain(&self) {
        self.draining.store(true, Ordering::Release);
        for e in self.models.read().unwrap().values() {
            e.server.close();
        }
    }

    /// Drop every model entry (joining each batcher via `Drop`) — the
    /// last step of a graceful shutdown, after connections are joined.
    pub fn clear_models(&self) {
        let mut map = self.models.write().unwrap();
        let entries: Vec<ModelEntry> = std::mem::take(&mut *map).into_values().collect();
        drop(map);
        drop(entries);
    }

    fn entry_info(name: &str, e: &ModelEntry) -> Json {
        let m = &e.server.model;
        Json::obj(vec![
            ("name", Json::Str(name.to_string())),
            ("input_dim", Json::Num(m.input_dim as f64)),
            ("output_dim", Json::Num(m.output_dim() as f64)),
            ("layers", Json::Num(m.layers.len() as f64)),
            (
                "bits",
                Json::Arr(m.layers.iter().map(|l| Json::Num(l.bits as f64)).collect()),
            ),
            (
                "ops",
                Json::Arr(
                    m.layers
                        .iter()
                        .map(|l| Json::Str(l.kind_name().to_string()))
                        .collect(),
                ),
            ),
            ("payload_bytes", Json::Num(m.payload_bytes() as f64)),
            ("compression", Json::Num(m.compression())),
            ("source", Json::Str(e.source.display().to_string())),
            ("generation", Json::Num(e.generation as f64)),
            ("queue_depth", Json::Num(e.server.queue_depth() as f64)),
            ("completed", Json::Num(e.server.metrics.completed() as f64)),
        ])
    }

    pub fn model_infos(&self) -> Json {
        let map = self.models.read().unwrap();
        Json::Arr(map.iter().map(|(n, e)| Self::entry_info(n, e)).collect())
    }
}

/// Mint a process-unique trace ID: `msq-<boot>-<seq>`, where `boot`
/// mixes the start timestamp with the pid (two gateways started the
/// same nanosecond still differ) and `seq` is a monotonic counter.
pub fn mint_request_id() -> String {
    static SEQ: AtomicU64 = AtomicU64::new(1);
    static BOOT: OnceLock<u64> = OnceLock::new();
    let boot = BOOT.get_or_init(|| {
        let ns = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0);
        ns ^ u64::from(std::process::id()).rotate_left(48)
    });
    format!("msq-{:08x}-{}", (boot >> 12) & 0xffff_ffff, SEQ.fetch_add(1, Ordering::Relaxed))
}

/// The trace ID for one request: a sane client-supplied `x-request-id`
/// is honoured (so callers can stitch gateway log lines into their own
/// traces); anything absent, oversized, or non-printable is replaced
/// with a minted one.
pub fn request_id(req: &Request) -> String {
    if let Some(v) = req.header("x-request-id") {
        let v = v.trim();
        if !v.is_empty() && v.len() <= 128 && v.bytes().all(|b| b.is_ascii_graphic()) {
            return v.to_string();
        }
    }
    mint_request_id()
}

/// Attach the trace ID to a response: always as an `x-request-id`
/// header, and for JSON errors also inside the body, so clients that
/// only keep the payload can still quote the ID in a report.
pub(crate) fn tag(mut resp: Response, id: &str) -> Response {
    if resp.status >= 400 && resp.content_type == "application/json" {
        if let Some(Json::Obj(mut m)) =
            std::str::from_utf8(&resp.body).ok().and_then(|t| json::parse(t).ok())
        {
            m.insert("request_id".to_string(), Json::Str(id.to_string()));
            resp.body = Json::Obj(m).to_string().into_bytes();
        }
    }
    resp.header("x-request-id", id)
}

/// Route one parsed request. Infallible: every outcome is a `Response`,
/// and every response carries the request's trace ID.
pub fn handle(state: &AppState, req: &Request) -> Response {
    let id = request_id(req);
    tag(route(state, req), &id)
}

/// Bearer-token check shared by every admin-gated route (`/admin/reload`
/// and the `/debug/*` pages). With no token configured the gate is open
/// (dev default); with one, the request must carry `Authorization:
/// Bearer <token>` exactly.
fn authorized(state: &AppState, req: &Request) -> bool {
    match &state.admin_token {
        None => true,
        Some(token) => req
            .header("authorization")
            .map(str::trim)
            .and_then(|v| v.strip_prefix("Bearer "))
            .map(|t| t.trim() == token)
            .unwrap_or(false),
    }
}

fn unauthorized() -> Response {
    Response::error(401, "this endpoint requires 'Authorization: Bearer <admin-token>'")
}

fn route(state: &AppState, req: &Request) -> Response {
    let path = req.path();
    match (req.method.as_str(), path) {
        ("GET", "/healthz") => healthz(state),
        ("GET", "/metrics") => Response::prometheus(render_metrics(state)),
        ("GET", "/debug/stats") => {
            if !authorized(state, req) {
                return unauthorized();
            }
            debug_stats(state)
        }
        ("GET", "/v1/models") => {
            Response::json(200, &Json::obj(vec![("models", state.model_infos())]))
        }
        ("POST", "/admin/reload") => reload(state, req),
        (method, _) => {
            if let Some(name) =
                path.strip_prefix("/v1/models/").and_then(|r| r.strip_suffix("/infer"))
            {
                if name.is_empty() || name.contains('/') {
                    return Response::error(404, "no such route");
                }
                if method != "POST" {
                    return Response::error(405, "infer requires POST");
                }
                return infer(state, name, req);
            }
            if let Some(name) = path.strip_prefix("/debug/model/") {
                if name.is_empty() || name.contains('/') {
                    return Response::error(404, "no such route");
                }
                if method != "GET" {
                    return Response::error(405, "debug/model requires GET");
                }
                if !authorized(state, req) {
                    return unauthorized();
                }
                return debug_model(state, name);
            }
            match path {
                "/healthz" | "/metrics" | "/debug/stats" | "/v1/models" | "/admin/reload" => {
                    Response::error(405, "method not allowed")
                }
                _ => Response::error(404, "no such route"),
            }
        }
    }
}

fn healthz(state: &AppState) -> Response {
    let draining = state.draining.load(Ordering::Acquire);
    let body = Json::obj(vec![
        ("status", Json::Str(if draining { "draining" } else { "ok" }.into())),
        ("uptime_s", Json::Num(state.started.elapsed().as_secs_f64())),
        ("models", state.model_infos()),
    ]);
    // 503 while draining so load balancers stop routing here
    Response::json(if draining { 503 } else { 200 }, &body)
}

/// `POST /v1/models/{name}/infer` — body is `[[f32,…],…]` (or a flat
/// row, or `{"inputs": …}`); rows are submitted individually so the
/// dynamic batcher can coalesce them with concurrent connections.
fn infer(state: &AppState, name: &str, req: &Request) -> Response {
    if state.draining.load(Ordering::Acquire) {
        return Response::error(503, "gateway is draining");
    }
    let server = match state.server(name) {
        Some(s) => s,
        None => return Response::error(404, &format!("no model {name:?} (see /v1/models)")),
    };
    let t_parse = Instant::now();
    let text = match std::str::from_utf8(&req.body) {
        Ok(t) => t,
        Err(_) => return Response::error(400, "body must be UTF-8 JSON"),
    };
    let parsed = match json::parse(text) {
        Ok(v) => v,
        Err(e) => return Response::error(400, &format!("bad JSON body: {e}")),
    };
    let rows = match parsed.get("inputs").unwrap_or(&parsed).as_batch_f32() {
        Some(r) => r,
        None => {
            return Response::error(
                400,
                "body must be [[f32,…],…], a flat [f32,…] row, or {\"inputs\": …}",
            )
        }
    };
    let parse_d = t_parse.elapsed();
    state.obs.stage("parse").record_duration(parse_d);
    let batch = rows.len();
    let t0 = Instant::now();
    // decode-stage attribution via the kernel profiler aggregate delta
    // (only meaningful — and only paid for — when profiling is on)
    let k0 = if crate::obs::profiler().on() {
        Some(crate::obs::profiler().kernel_snapshot())
    } else {
        None
    };
    let mut rxs = Vec::with_capacity(batch);
    for row in rows {
        match server.submit_admit(row) {
            Ok(rx) => rxs.push(rx),
            Err(e) => {
                // fail fast: drop the receivers of already-admitted rows
                // (the batcher tolerates dead channels) so a 429 returns
                // now, not after the deadline flush. Clients retry the
                // whole batch.
                drop(rxs);
                return submit_error(&e);
            }
        }
    }
    let mut outputs = Vec::with_capacity(batch);
    let mut argmax = Vec::with_capacity(batch);
    // per-request stage durations: rows may ride different flushed
    // batches, so the request-level figure is the max over its rows
    let (mut queue_d, mut kernel_d, mut form_d) =
        (Duration::ZERO, Duration::ZERO, Duration::ZERO);
    for rx in rxs {
        match rx.recv() {
            Ok(r) => {
                let form = r.latency.saturating_sub(r.queue_wait + r.compute);
                state.obs.stage("queue").record_duration(r.queue_wait);
                state.obs.stage("batch").record_duration(form);
                state.obs.stage("kernel").record_duration(r.compute);
                queue_d = queue_d.max(r.queue_wait);
                kernel_d = kernel_d.max(r.compute);
                form_d = form_d.max(form);
                outputs.push(Json::arr_f32(&r.logits));
                argmax.push(Json::Num(r.argmax as f64));
            }
            Err(_) => return Response::error(503, "model shut down mid-request"),
        }
    }
    let decode_s = k0.map(|(d0, _, _, _)| {
        let s = crate::obs::profiler().kernel_snapshot().0.saturating_sub(d0) as f64 / 1e9;
        state.obs.stage("decode").record(s);
        s
    });
    let total = t0.elapsed();
    let ms = |d: Duration| d.as_secs_f64() * 1e3;
    // Server-Timing: the stages this handler can know before the body
    // is written (serialize lands in the histograms only), keyed to the
    // request's x-request-id by riding the same tagged response.
    let mut timing = format!(
        "parse;dur={:.3}, queue;dur={:.3}, batch;dur={:.3}, kernel;dur={:.3}",
        ms(parse_d),
        ms(queue_d),
        ms(form_d),
        ms(kernel_d)
    );
    if let Some(s) = decode_s {
        timing.push_str(&format!(", decode;dur={:.3}", s * 1e3));
    }
    timing.push_str(&format!(", total;dur={:.3}", ms(parse_d + total)));
    Response::json(
        200,
        &Json::obj(vec![
            ("model", Json::Str(name.to_string())),
            ("outputs", Json::Arr(outputs)),
            ("argmax", Json::Arr(argmax)),
            ("batch", Json::Num(batch as f64)),
            ("latency_ms", Json::Num(total.as_secs_f64() * 1e3)),
        ]),
    )
    .header("Server-Timing", &timing)
}

/// `GET /debug/stats` — one JSON page with everything the gateway
/// knows: per-stage lifecycle histograms, per-model `ServeMetrics`
/// snapshots, connection counters, the obs registry dump, and the
/// kernel profiler table (aggregates + per-layer, when enabled).
fn debug_stats(state: &AppState) -> Response {
    eval_drift(state);
    let map = state.models.read().unwrap();
    let mut models = BTreeMap::new();
    for (n, e) in map.iter() {
        let mut snap = e.server.metrics.snapshot(e.server.queue_depth());
        if let Json::Obj(m) = &mut snap {
            m.insert("admission".to_string(), e.server.admission.metrics.to_json());
        }
        models.insert(n.clone(), snap);
    }
    drop(map);
    let mut stages = BTreeMap::new();
    for s in crate::obs::STAGES {
        let h = state.obs.stage(s).snapshot();
        stages.insert(
            s.to_string(),
            Json::obj(vec![
                ("count", Json::Num(h.count() as f64)),
                ("sum_s", Json::Num(h.sum())),
                ("mean_ms", Json::Num(h.mean() * 1e3)),
                ("p50_ms", Json::Num(h.percentile(50.0) * 1e3)),
                ("p95_ms", Json::Num(h.percentile(95.0) * 1e3)),
                ("p99_ms", Json::Num(h.percentile(99.0) * 1e3)),
                ("max_ms", Json::Num(h.max() * 1e3)),
            ]),
        );
    }
    let h = &state.http;
    let body = Json::obj(vec![
        ("uptime_s", Json::Num(state.started.elapsed().as_secs_f64())),
        ("draining", Json::Bool(state.draining.load(Ordering::Acquire))),
        (
            "connections",
            Json::obj(vec![
                ("total", Json::Num(h.connections_total.load(Ordering::Relaxed) as f64)),
                ("rejected", Json::Num(h.connections_rejected.load(Ordering::Relaxed) as f64)),
                ("active", Json::Num(h.connections_active.load(Ordering::Relaxed) as f64)),
            ]),
        ),
        (
            "responses",
            Json::Obj(
                h.responses()
                    .into_iter()
                    .map(|(c, n)| (c.to_string(), Json::Num(n as f64)))
                    .collect(),
            ),
        ),
        ("stages", Json::Obj(stages)),
        ("models", Json::Obj(models)),
        ("weight_cache", crate::serve::weightcache::cache().to_json()),
        ("registry", state.obs.to_json()),
        ("profiler", crate::obs::profiler().to_json()),
        ("qstats", crate::obs::qstats::qstats().to_json()),
    ]);
    Response::json(200, &body)
}

/// `GET /debug/model/{name}` — the quantization-health page for one
/// model: the load-time static analysis (per-layer bits / entropy /
/// quant-error / size, identical by construction to `msq inspect` over
/// the same pack) plus whatever the runtime activation observers have
/// accumulated under this model's prefix.
fn debug_model(state: &AppState, name: &str) -> Response {
    eval_drift(state);
    let map = state.models.read().unwrap();
    let Some(e) = map.get(name) else {
        return Response::error(404, &format!("no model {name:?} (see /v1/models)"));
    };
    let qs = crate::obs::qstats::qstats();
    let m = &e.server.model;
    // the activation-quant calibration the integer path would use right
    // now, one row per int-capable *planned* layer (indices match the
    // qstats attribution keys, not pack record order)
    let calibration: Vec<Json> = m
        .layers
        .iter()
        .enumerate()
        .filter(|(_, l)| l.supports_int())
        .map(|(i, l)| {
            let (act, from_ema) = m.act_quant(i);
            Json::obj(vec![
                ("layer", Json::Str(format!("{i:02}:{}", l.name))),
                ("scale", Json::Num(act.scale as f64)),
                ("zero_point", Json::Num(128.0)),
                ("act_bound", Json::Num(l.act_bound as f64)),
                ("source", Json::Str(if from_ema { "ema" } else { "static" }.into())),
            ])
        })
        .collect();
    let body = Json::obj(vec![
        ("model", Json::Str(name.to_string())),
        ("generation", Json::Num(e.generation as f64)),
        ("source", Json::Str(e.source.display().to_string())),
        ("input_dim", Json::Num(m.input_dim as f64)),
        ("output_dim", Json::Num(m.output_dim() as f64)),
        ("int8", Json::Bool(m.int8)),
        ("calibration", Json::Arr(calibration)),
        ("analysis", m.analysis.to_json()),
        ("activations", qs.layers_json(&format!("{name}/"))),
        ("qstats_enabled", Json::Bool(qs.on())),
    ]);
    Response::json(200, &body)
}

/// Relative activation-absmax shift across a reload that counts as
/// drift: `|now − prev| / max(|prev|, 1e-6) > 0.5`.
pub const DRIFT_THRESHOLD: f32 = 0.5;

/// Activation-range drift check: compare each layer's current absmax
/// (live qstats observers) against the snapshot taken from the previous
/// generation at swap time. A relative shift beyond [`DRIFT_THRESHOLD`]
/// increments `msq_act_range_drift_total{model}` — once per layer per
/// generation. Runs on every scrape / debug dump; a no-op while qstats
/// is disabled or before the first reload.
fn eval_drift(state: &AppState) {
    let qs = crate::obs::qstats::qstats();
    if !qs.on() {
        return;
    }
    let map = state.models.read().unwrap();
    for (name, e) in map.iter() {
        if e.prev_absmax.is_empty() {
            continue;
        }
        let now = qs.absmax_by_prefix(&format!("{name}/"));
        let mut fired = e.drift_fired.lock().unwrap();
        for (layer, cur) in now {
            let Some(prev) = e.prev_absmax.get(&layer) else { continue };
            let rel = (cur - prev).abs() / prev.abs().max(1e-6);
            if rel > DRIFT_THRESHOLD && fired.insert(layer) {
                state
                    .obs
                    .counter("msq_act_range_drift_total", &[("model", name.as_str())])
                    .inc();
            }
        }
    }
}

/// 4xx/5xx mapping for [`SubmitError`] (the documented backpressure
/// contract: 429 shed, 503 drain, 400 caller bug).
fn submit_error(e: &SubmitError) -> Response {
    match e {
        SubmitError::QueueFull { depth, cap } => {
            Response::error(429, &format!("queue full ({depth}/{cap}) — retry with backoff"))
                .header("Retry-After", "1")
        }
        SubmitError::BadInput { got, want } => {
            Response::error(400, &format!("input row has {got} values, model expects {want}"))
        }
        SubmitError::ShuttingDown => Response::error(503, "model is draining"),
    }
}

/// `POST /admin/reload` — body `{"model": name?, "path": file?,
/// "input_dim": n?}`. With a path: (re)load that file under `model`
/// (file stem when omitted). Without: re-read the recorded source of
/// `model`, or of every model when no name is given.
fn reload(state: &AppState, req: &Request) -> Response {
    if state.draining.load(Ordering::Acquire) {
        return Response::error(503, "gateway is draining");
    }
    // bearer-token gate: when the gateway was started with an admin
    // token, an absent/mismatched Authorization header is a hard 401
    if !authorized(state, req) {
        state
            .obs
            .counter("msq_reload_outcomes_total", &[("outcome", "unauthorized")])
            .inc();
        return unauthorized();
    }
    let t_reload = Instant::now();
    let fail = |state: &AppState, resp: Response| {
        state.obs.counter("msq_reload_outcomes_total", &[("outcome", "error")]).inc();
        resp
    };
    let spec = if req.body.is_empty() {
        Json::Null
    } else {
        match std::str::from_utf8(&req.body).ok().map(json::parse) {
            Some(Ok(v)) => v,
            _ => return fail(state, Response::error(400, "reload body must be JSON")),
        }
    };
    let name = spec.get("model").and_then(Json::as_str).map(str::to_string);
    let path = spec.get("path").and_then(Json::as_str).map(PathBuf::from);
    let dim = spec.get("input_dim").and_then(Json::as_usize);

    // resolve the (name, path, override) set to load
    let mut targets: Vec<(String, PathBuf, Option<usize>)> = Vec::new();
    match (&name, &path) {
        (_, Some(p)) => {
            let n = match &name {
                Some(n) => n.clone(),
                None => match model_name_from_path(p) {
                    Ok(stem) => stem,
                    Err(e) => return fail(state, Response::error(400, &e.to_string())),
                },
            };
            targets.push((n, p.clone(), dim));
        }
        (Some(n), None) => {
            let map = state.models.read().unwrap();
            match map.get(n) {
                Some(e) => targets.push((
                    n.clone(),
                    e.source.clone(),
                    dim.or(e.input_dim_override),
                )),
                None => {
                    return fail(state, Response::error(404, &format!("no model {n:?} to reload")))
                }
            }
        }
        (None, None) => {
            let map = state.models.read().unwrap();
            for (n, e) in map.iter() {
                targets.push((n.clone(), e.source.clone(), e.input_dim_override));
            }
        }
    }
    if targets.is_empty() {
        return fail(
            state,
            Response::error(400, "no models loaded — pass {\"model\":…, \"path\":…}"),
        );
    }
    let mut reloaded = Vec::new();
    for (n, p, d) in targets {
        match state.load_model(&n, &p, d) {
            Ok(info) => reloaded.push(info),
            Err(e) => {
                // partial reloads keep their new servers; report both halves
                state.obs.hist("msq_reload_duration_seconds", &[]).record_duration(
                    t_reload.elapsed(),
                );
                return fail(
                    state,
                    Response::json(
                        400,
                        &Json::obj(vec![
                            ("error", Json::Str(format!("reloading {n:?}: {e}"))),
                            ("reloaded", Json::Arr(reloaded)),
                        ]),
                    ),
                );
            }
        }
    }
    state.http.reloads_total.fetch_add(1, Ordering::Relaxed);
    // tag the event into the registry: outcome, duration, and the new
    // generation of every swapped model
    state.obs.counter("msq_reload_outcomes_total", &[("outcome", "ok")]).inc();
    state.obs.hist("msq_reload_duration_seconds", &[]).record_duration(t_reload.elapsed());
    for info in &reloaded {
        if let (Some(n), Some(g)) = (
            info.get("name").and_then(Json::as_str),
            info.get("generation").and_then(Json::as_f64),
        ) {
            state.obs.gauge("msq_reload_generation", &[("model", n)]).set(g);
        }
    }
    Response::json(200, &Json::obj(vec![("reloaded", Json::Arr(reloaded))]))
}

/// Assemble the Prometheus scrape: gateway counters plus one labelled
/// series set per model, fed from `ServeMetrics`/`LatencyHist`.
pub fn render_metrics(state: &AppState) -> String {
    let mut p = Prom::new();
    p.family("msq_gateway_uptime_seconds", "gauge", "Seconds since gateway start");
    p.sample("msq_gateway_uptime_seconds", &[], state.started.elapsed().as_secs_f64());
    p.family("msq_gateway_draining", "gauge", "1 while shutting down");
    p.sample(
        "msq_gateway_draining",
        &[],
        if state.draining.load(Ordering::Acquire) { 1.0 } else { 0.0 },
    );

    let h = &state.http;
    p.family("msq_gateway_connections_total", "counter", "Accepted TCP connections");
    p.sample(
        "msq_gateway_connections_total",
        &[],
        h.connections_total.load(Ordering::Relaxed) as f64,
    );
    p.family(
        "msq_gateway_connections_rejected_total",
        "counter",
        "Connections shed at the budget",
    );
    p.sample(
        "msq_gateway_connections_rejected_total",
        &[],
        h.connections_rejected.load(Ordering::Relaxed) as f64,
    );
    p.family("msq_gateway_connections_active", "gauge", "Connections currently open");
    p.sample(
        "msq_gateway_connections_active",
        &[],
        h.connections_active.load(Ordering::Relaxed) as f64,
    );
    p.family("msq_gateway_pool_outstanding", "gauge", "Connection-pool jobs queued or running");
    p.sample("msq_gateway_pool_outstanding", &[], state.conn_pool.outstanding() as f64);
    p.family("msq_gateway_reloads_total", "counter", "Successful /admin/reload calls");
    p.sample("msq_gateway_reloads_total", &[], h.reloads_total.load(Ordering::Relaxed) as f64);

    p.family("msq_gateway_http_responses_total", "counter", "HTTP responses by status code");
    for (code, n) in h.responses() {
        let c = code.to_string();
        p.sample("msq_gateway_http_responses_total", &[("code", &c)], n as f64);
    }

    p.family("msq_requests_submitted_total", "counter", "Requests presented per model");
    p.family("msq_requests_rejected_total", "counter", "Requests shed per model");
    p.family("msq_requests_completed_total", "counter", "Requests completed per model");
    p.family("msq_queue_depth", "gauge", "Requests waiting in the batcher");
    p.family("msq_batch_occupancy_mean", "gauge", "Mean batch size a request rode in");
    p.family("msq_window_rps", "gauge", "Completions per second over the sliding window");
    p.family("msq_model_payload_bytes", "gauge", "Resident packed weight bytes");
    p.family("msq_model_generation", "gauge", "Reload generation of the loaded pack");
    p.family(
        "msq_request_latency_seconds",
        "summary",
        "Submit-to-response latency (queue + compute)",
    );
    p.family(
        "msq_admission_admitted_total",
        "counter",
        "Requests admitted to the batcher queue (immediately or after waiting)",
    );
    p.family(
        "msq_admission_waited_total",
        "counter",
        "Requests admitted only after at least one queue-full retry",
    );
    p.family(
        "msq_admission_expired_total",
        "counter",
        "Requests that waited the full admission deadline and were rejected",
    );
    p.family(
        "msq_admission_shed_total",
        "counter",
        "Requests shed without waiting (wait room full or disabled)",
    );
    p.family("msq_admission_waiting", "gauge", "Requests currently in the admission wait room");
    p.family(
        "msq_admission_wait_seconds",
        "summary",
        "Time spent in the admission wait room (admitted or not)",
    );
    let map = state.models.read().unwrap();
    for (name, e) in map.iter() {
        let lbl = [("model", name.as_str())];
        let m = &e.server.metrics;
        p.sample("msq_requests_submitted_total", &lbl, m.submitted() as f64);
        p.sample("msq_requests_rejected_total", &lbl, m.rejected() as f64);
        p.sample("msq_requests_completed_total", &lbl, m.completed() as f64);
        p.sample("msq_queue_depth", &lbl, e.server.queue_depth() as f64);
        p.sample("msq_batch_occupancy_mean", &lbl, m.mean_batch());
        p.sample("msq_window_rps", &lbl, m.window_rps());
        p.sample("msq_model_payload_bytes", &lbl, e.server.model.payload_bytes() as f64);
        p.sample("msq_model_generation", &lbl, e.generation as f64);
        p.summary("msq_request_latency_seconds", &lbl, &m.latency_hist(), &[0.5, 0.9, 0.95, 0.99]);
        let a = &e.server.admission.metrics;
        p.sample("msq_admission_admitted_total", &lbl, a.admitted() as f64);
        p.sample("msq_admission_waited_total", &lbl, a.waited() as f64);
        p.sample("msq_admission_expired_total", &lbl, a.expired() as f64);
        p.sample("msq_admission_shed_total", &lbl, a.shed() as f64);
        p.sample("msq_admission_waiting", &lbl, a.waiting() as f64);
        p.summary("msq_admission_wait_seconds", &lbl, &a.wait_hist(), &[0.5, 0.95, 0.99]);
    }
    // load-time static quantization analysis: constant between reloads,
    // so a dashboard can join runtime activation ranges onto bits /
    // entropy / error. Structural records (numel 0) carry no codes and
    // are skipped.
    let layer_family = |p: &mut Prom,
                        fam: &str,
                        help: &str,
                        value: &dyn Fn(&crate::serve::LayerAnalysis) -> f64| {
        p.family(fam, "gauge", help);
        for (model, e) in map.iter() {
            for (i, l) in e.server.model.analysis.layers.iter().enumerate() {
                if l.numel == 0 {
                    continue;
                }
                let layer = format!("{i:02}:{}", l.name);
                p.sample(fam, &[("model", model.as_str()), ("layer", layer.as_str())], value(l));
            }
        }
    };
    layer_family(&mut p, "msq_layer_bits", "Packed bit-width per layer", &|l| l.bits as f64);
    layer_family(
        &mut p,
        "msq_layer_entropy_bits",
        "Shannon entropy of the layer's code histogram (bits per code)",
        &|l| l.entropy_bits,
    );
    layer_family(
        &mut p,
        "msq_layer_quant_error",
        "Histogram-estimated relative error of dropping one bit",
        &|l| l.qerr_drop_rel,
    );
    layer_family(
        &mut p,
        "msq_layer_payload_bytes",
        "Packed payload bytes per layer",
        &|l| l.payload_bytes as f64,
    );
    // activation-quant calibration per int-capable planned layer: the
    // scale the integer path would use right now (EMA-driven when the
    // observers have samples, static analysis bound otherwise). Layer
    // indices here are planned-layer positions — the same keys qstats
    // attributes under — not pack record order.
    p.family(
        "msq_layer_act_scale",
        "gauge",
        "Activation quantization scale of the integer serving path",
    );
    for (model, e) in map.iter() {
        let m = &e.server.model;
        for (i, l) in m.layers.iter().enumerate() {
            if !l.supports_int() {
                continue;
            }
            let (act, _) = m.act_quant(i);
            let layer = format!("{i:02}:{}", l.name);
            p.sample(
                "msq_layer_act_scale",
                &[("model", model.as_str()), ("layer", layer.as_str())],
                act.scale as f64,
            );
        }
    }
    drop(map);
    // activation-range drift vs the previous generation: evaluated here
    // so the scrape that reports the counter is the one that detected it
    eval_drift(state);
    // the obs registry: per-stage lifecycle histograms + reload events
    state.obs.render(&mut p, &crate::obs::QUANTILES);
    // process-wide decoded-weight cache (zeros while disabled)
    crate::serve::weightcache::cache().render(&mut p);
    // global kernel profiler aggregates (zeros unless profiling is on)
    crate::obs::profiler().render(&mut p);
    // runtime activation observers (empty unless --qstats is on)
    crate::obs::qstats::qstats().render(&mut p);
    p.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::pack::PackedModel;
    use std::io::Cursor;
    use std::time::Duration;

    fn toy_state() -> AppState {
        let pool = Arc::new(ThreadPool::new(2));
        let cfg = ServerConfig {
            max_batch: 8,
            max_delay: Duration::from_millis(1),
            queue_cap: 64,
            threads: 1,
            ..Default::default()
        };
        let state = AppState::new(cfg, pool);
        let pm = PackedModel::synth_mlp(&[6, 8, 3], &[4, 3], 3).unwrap();
        let path = std::env::temp_dir().join("msq_router_toy.msqpack");
        pm.save(&path).unwrap();
        state.load_model("toy", &path, None).unwrap();
        state
    }

    fn req(method: &str, target: &str, body: &[u8]) -> Request {
        let mut wire = Vec::new();
        super::super::http::write_request(
            &mut wire,
            method,
            target,
            Some("application/json"),
            body,
        )
        .unwrap();
        super::super::http::HttpReader::new(Cursor::new(wire))
            .read_request(&super::super::http::Limits::default())
            .unwrap()
    }

    fn body_json(r: &Response) -> Json {
        json::parse(std::str::from_utf8(&r.body).unwrap()).unwrap()
    }

    #[test]
    fn infer_roundtrips_against_direct_forward() {
        let state = toy_state();
        let r = handle(&state, &req("POST", "/v1/models/toy/infer", b"[[0.5,1,0,-1,0.25,2]]"));
        assert_eq!(r.status, 200, "{}", String::from_utf8_lossy(&r.body));
        let v = body_json(&r);
        let out = v.path(&["outputs", "0"]).unwrap().as_f32s().unwrap();
        // bit-identical to the direct forward pass through the same model
        let model = state.server("toy").unwrap().model.clone();
        let expect = model.infer_batch(&[0.5, 1.0, 0.0, -1.0, 0.25, 2.0], 1, None).unwrap();
        assert_eq!(out, expect);
        assert_eq!(v.get("batch").unwrap().as_usize(), Some(1));
    }

    #[test]
    fn infer_accepts_all_three_body_shapes() {
        let state = toy_state();
        for body in [
            &b"[[0,0,0,0,0,0],[1,1,1,1,1,1]]"[..],
            &b"[0,0,0,0,0,0]"[..],
            &br#"{"inputs": [[0,0,0,0,0,0]]}"#[..],
        ] {
            let r = handle(&state, &req("POST", "/v1/models/toy/infer", body));
            assert_eq!(r.status, 200, "{}", String::from_utf8_lossy(body));
        }
    }

    #[test]
    fn conv_models_route_and_report_ops() {
        let pool = Arc::new(ThreadPool::new(2));
        let cfg = ServerConfig {
            max_batch: 8,
            max_delay: Duration::from_millis(1),
            queue_cap: 64,
            threads: 1,
            ..Default::default()
        };
        let state = AppState::new(cfg, pool);
        let pm = PackedModel::synth_conv(8, 8, &[3, 4, 5], &[4, 3], 6).unwrap();
        let path = std::env::temp_dir().join("msq_router_conv.msqpack");
        pm.save(&path).unwrap();
        state.load_model("conv", &path, None).unwrap();

        let r = handle(&state, &req("GET", "/v1/models", b""));
        let v = body_json(&r);
        assert_eq!(v.path(&["models", "0", "ops", "0"]).unwrap().as_str(), Some("conv2d"));
        assert_eq!(v.path(&["models", "0", "ops", "1"]).unwrap().as_str(), Some("linear"));
        assert_eq!(v.path(&["models", "0", "input_dim"]).unwrap().as_usize(), Some(192));

        // a conv infer routes exactly like an MLP one (flat NHWC row)
        let x: Vec<f32> = (0..192).map(|i| (i as f32 / 96.0) - 1.0).collect();
        let body = Json::Arr(vec![Json::arr_f32(&x)]).to_string();
        let r = handle(&state, &req("POST", "/v1/models/conv/infer", body.as_bytes()));
        assert_eq!(r.status, 200, "{}", String::from_utf8_lossy(&r.body));
        let out = body_json(&r).path(&["outputs", "0"]).unwrap().as_f32s().unwrap();
        let model = state.server("conv").unwrap().model.clone();
        let expect = model.infer_batch(&x, 1, None).unwrap();
        assert_eq!(out, expect, "gateway conv logits diverge from the direct forward");
    }

    #[test]
    fn routing_errors() {
        let state = toy_state();
        assert_eq!(handle(&state, &req("GET", "/nope", b"")).status, 404);
        assert_eq!(handle(&state, &req("GET", "/v1/models/toy/infer", b"")).status, 405);
        assert_eq!(handle(&state, &req("PUT", "/healthz", b"")).status, 405);
        assert_eq!(
            handle(&state, &req("POST", "/v1/models/ghost/infer", b"[[1]]")).status,
            404
        );
        assert_eq!(
            handle(&state, &req("POST", "/v1/models/a/b/infer", b"[[1]]")).status,
            404
        );
        // malformed bodies
        for body in [&b"not json"[..], &b"[]"[..], &b"[[1,\"x\"]]"[..], &b"{}"[..]] {
            let r = handle(&state, &req("POST", "/v1/models/toy/infer", body));
            assert_eq!(r.status, 400, "{}", String::from_utf8_lossy(body));
        }
        // wrong row width maps BadInput → 400
        let r = handle(&state, &req("POST", "/v1/models/toy/infer", b"[[1,2,3]]"));
        assert_eq!(r.status, 400);
        assert!(String::from_utf8_lossy(&r.body).contains("expects 6"), "{:?}", r.body);
    }

    fn resp_id(r: &Response) -> Option<String> {
        r.extra.iter().find(|(k, _)| k == "x-request-id").map(|(_, v)| v.clone())
    }

    fn req_with_id(method: &str, target: &str, id: &str, body: &[u8]) -> Request {
        let mut wire = format!(
            "{method} {target} HTTP/1.1\r\nHost: t\r\nx-request-id: {id}\r\n\
             Content-Length: {}\r\n\r\n",
            body.len()
        )
        .into_bytes();
        wire.extend_from_slice(body);
        super::super::http::HttpReader::new(Cursor::new(wire))
            .read_request(&super::super::http::Limits::default())
            .unwrap()
    }

    #[test]
    fn request_ids_are_minted_unique_and_attached_everywhere() {
        let state = toy_state();
        let a = handle(&state, &req("GET", "/healthz", b""));
        let b = handle(&state, &req("POST", "/v1/models/toy/infer", b"[[0,0,0,0,0,0]]"));
        let (ia, ib) = (resp_id(&a).unwrap(), resp_id(&b).unwrap());
        assert!(ia.starts_with("msq-"), "{ia}");
        assert_ne!(ia, ib, "two requests shared a minted trace ID");

        // error responses carry the ID in the header AND the JSON body
        let r = handle(&state, &req("POST", "/v1/models/ghost/infer", b"[[1]]"));
        assert_eq!(r.status, 404);
        let id = resp_id(&r).unwrap();
        let v = body_json(&r);
        assert_eq!(v.get("request_id").unwrap().as_str(), Some(id.as_str()));
        assert!(v.get("error").is_some());
    }

    #[test]
    fn client_supplied_request_ids_are_echoed_or_replaced() {
        let state = toy_state();
        let r = handle(&state, &req_with_id("GET", "/healthz", "trace-abc.42", b""));
        assert_eq!(resp_id(&r).as_deref(), Some("trace-abc.42"));
        // non-printable / oversized client IDs are replaced, not echoed
        let long = "x".repeat(200);
        for bad in ["bad id with spaces", long.as_str()] {
            let r = handle(&state, &req_with_id("GET", "/healthz", bad, b""));
            let got = resp_id(&r).unwrap();
            assert!(got.starts_with("msq-"), "echoed a hostile ID: {got:?}");
        }
    }

    #[test]
    fn healthz_and_models_inventory() {
        let state = toy_state();
        let r = handle(&state, &req("GET", "/healthz", b""));
        assert_eq!(r.status, 200);
        let v = body_json(&r);
        assert_eq!(v.get("status").unwrap().as_str(), Some("ok"));
        assert_eq!(v.path(&["models", "0", "name"]).unwrap().as_str(), Some("toy"));
        assert_eq!(v.path(&["models", "0", "input_dim"]).unwrap().as_usize(), Some(6));

        let r = handle(&state, &req("GET", "/v1/models", b""));
        assert_eq!(body_json(&r).path(&["models", "0", "output_dim"]).unwrap().as_usize(), Some(3));
    }

    #[test]
    fn metrics_scrape_has_counters_and_quantiles() {
        let state = toy_state();
        // complete one request so the latency summary is non-trivial
        let r = handle(&state, &req("POST", "/v1/models/toy/infer", b"[[0,0,0,0,0,0]]"));
        assert_eq!(r.status, 200);
        let text = render_metrics(&state);
        assert!(text.contains("# TYPE msq_requests_completed_total counter"), "{text}");
        assert!(text.contains("msq_requests_completed_total{model=\"toy\"} 1"), "{text}");
        assert!(text.contains("msq_requests_submitted_total{model=\"toy\"} 1"), "{text}");
        assert!(text.contains("msq_requests_rejected_total{model=\"toy\"} 0"), "{text}");
        assert!(
            text.contains("msq_request_latency_seconds{model=\"toy\",quantile=\"0.5\"}"),
            "{text}"
        );
        assert!(text.contains("msq_request_latency_seconds_count{model=\"toy\"} 1"), "{text}");
        assert!(text.contains("msq_queue_depth{model=\"toy\"}"), "{text}");
        // admission gate: the infer above was admitted without waiting
        assert!(text.contains("# TYPE msq_admission_admitted_total counter"), "{text}");
        assert!(text.contains("msq_admission_admitted_total{model=\"toy\"} 1"), "{text}");
        assert!(text.contains("msq_admission_waited_total{model=\"toy\"} 0"), "{text}");
        assert!(text.contains("msq_admission_waiting{model=\"toy\"} 0"), "{text}");
        assert!(text.contains("msq_admission_wait_seconds_count{model=\"toy\"} 0"), "{text}");
        // decoded-weight cache families render even while disabled
        assert!(text.contains("# TYPE msq_weight_cache_enabled gauge"), "{text}");
        assert!(text.contains("msq_weight_cache_hits_total"), "{text}");
    }

    #[test]
    fn infer_carries_server_timing_and_debug_stats_agree() {
        let state = toy_state();
        let r = handle(&state, &req("POST", "/v1/models/toy/infer", b"[[0,0,0,0,0,0]]"));
        assert_eq!(r.status, 200);
        let timing = r
            .extra
            .iter()
            .find(|(k, _)| k == "Server-Timing")
            .map(|(_, v)| v.clone())
            .expect("infer response carries Server-Timing");
        for stage in ["parse;dur=", "queue;dur=", "batch;dur=", "kernel;dur=", "total;dur="] {
            assert!(timing.contains(stage), "missing {stage} in {timing:?}");
        }
        // the same response is keyed by its x-request-id
        assert!(resp_id(&r).is_some());

        let d = handle(&state, &req("GET", "/debug/stats", b""));
        assert_eq!(d.status, 200);
        let v = body_json(&d);
        // stage sums partition the recorded end-to-end latency: the
        // batch stage is defined as latency − queue − kernel, so the
        // three sums reconstruct the ServeMetrics latency sum exactly
        // (modulo float rounding)
        let stage_sum = |s: &str| v.path(&["stages", s, "sum_s"]).unwrap().as_f64().unwrap();
        let stage_count = |s: &str| v.path(&["stages", s, "count"]).unwrap().as_f64().unwrap();
        assert_eq!(stage_count("queue"), 1.0);
        assert_eq!(stage_count("kernel"), 1.0);
        assert_eq!(stage_count("parse"), 1.0);
        let e2e_sum = v.path(&["models", "toy", "mean_ms"]).unwrap().as_f64().unwrap() / 1e3
            * v.path(&["models", "toy", "completed"]).unwrap().as_f64().unwrap();
        let stages = stage_sum("queue") + stage_sum("batch") + stage_sum("kernel");
        assert!(
            (stages - e2e_sum).abs() < 1e-6,
            "stage sums {stages} diverge from e2e latency sum {e2e_sum}"
        );
        // the registry dump and profiler section are present
        assert!(v.path(&["registry"]).is_some());
        assert_eq!(v.path(&["profiler", "enabled"]).unwrap().as_bool(), Some(false));
        // per-model admission snapshot + top-level weight-cache section
        let adm = v.path(&["models", "toy", "admission", "admitted"]).unwrap();
        assert_eq!(adm.as_usize(), Some(1));
        assert!(v.path(&["weight_cache", "enabled"]).is_some());
        // /metrics renders the stage family alongside the legacy series
        let text = render_metrics(&state);
        assert!(text.contains("# TYPE msq_stage_duration_seconds summary"), "{text}");
        for s in crate::obs::STAGES {
            assert!(
                text.contains(&format!("msq_stage_duration_seconds_count{{stage=\"{s}\"}}")),
                "missing stage {s}:\n{text}"
            );
        }
    }

    fn req_with_auth(method: &str, target: &str, auth: Option<&str>, body: &[u8]) -> Request {
        let mut wire = format!("{method} {target} HTTP/1.1\r\nHost: t\r\n").into_bytes();
        if let Some(a) = auth {
            wire.extend_from_slice(format!("Authorization: {a}\r\n").as_bytes());
        }
        wire.extend_from_slice(format!("Content-Length: {}\r\n\r\n", body.len()).as_bytes());
        wire.extend_from_slice(body);
        super::super::http::HttpReader::new(Cursor::new(wire))
            .read_request(&super::super::http::Limits::default())
            .unwrap()
    }

    #[test]
    fn reload_requires_bearer_token_when_configured() {
        let mut state = toy_state();
        state.admin_token = Some("s3cret".to_string());
        // no header, wrong scheme, wrong token: 401 and no reload
        for auth in [None, Some("Basic s3cret"), Some("Bearer nope")] {
            let r = handle(&state, &req_with_auth("POST", "/admin/reload", auth, b""));
            assert_eq!(r.status, 401, "auth {auth:?}");
        }
        assert_eq!(state.http.reloads_total.load(Ordering::Relaxed), 0);
        // correct token reloads and tags the registry
        let r = handle(
            &state,
            &req_with_auth("POST", "/admin/reload", Some("Bearer s3cret"), b""),
        );
        assert_eq!(r.status, 200, "{}", String::from_utf8_lossy(&r.body));
        let text = render_metrics(&state);
        assert!(text.contains("msq_reload_outcomes_total{outcome=\"ok\"} 1"), "{text}");
        assert!(
            text.contains("msq_reload_outcomes_total{outcome=\"unauthorized\"} 3"),
            "{text}"
        );
        assert!(text.contains("msq_reload_duration_seconds_count 1"), "{text}");
        assert!(text.contains("msq_reload_generation{model=\"toy\"} 2"), "{text}");
        // without a configured token the route stays open (dev default)
        let open = toy_state();
        let r = handle(&open, &req_with_auth("POST", "/admin/reload", None, b""));
        assert_eq!(r.status, 200);
    }

    #[test]
    fn reload_swaps_generation_and_weights() {
        let state = toy_state();
        let before = handle(&state, &req("POST", "/v1/models/toy/infer", b"[[1,1,1,1,1,1]]"));
        let out_before =
            body_json(&before).path(&["outputs", "0"]).unwrap().as_f32s().unwrap();

        // write a *different* pack (new seed) to a new path, reload onto it
        let pm = PackedModel::synth_mlp(&[6, 8, 3], &[4, 3], 99).unwrap();
        let path2 = std::env::temp_dir().join("msq_router_toy2.msqpack");
        pm.save(&path2).unwrap();
        let body = format!(r#"{{"model": "toy", "path": {:?}}}"#, path2.display().to_string());
        let r = handle(&state, &req("POST", "/admin/reload", body.as_bytes()));
        assert_eq!(r.status, 200, "{}", String::from_utf8_lossy(&r.body));
        let v = body_json(&r);
        assert_eq!(v.path(&["reloaded", "0", "generation"]).unwrap().as_usize(), Some(2));

        let after = handle(&state, &req("POST", "/v1/models/toy/infer", b"[[1,1,1,1,1,1]]"));
        let out_after = body_json(&after).path(&["outputs", "0"]).unwrap().as_f32s().unwrap();
        assert_ne!(out_before, out_after, "reload did not swap the weights");

        // bare reload (no body): re-reads every recorded source
        let r = handle(&state, &req("POST", "/admin/reload", b""));
        assert_eq!(r.status, 200);
        assert_eq!(
            body_json(&r).path(&["reloaded", "0", "generation"]).unwrap().as_usize(),
            Some(3)
        );
        // unknown model / bad path error cleanly
        assert_eq!(
            handle(&state, &req("POST", "/admin/reload", br#"{"model": "ghost"}"#)).status,
            404
        );
        assert_eq!(
            handle(
                &state,
                &req("POST", "/admin/reload", br#"{"model": "toy", "path": "/no/such.msqpack"}"#)
            )
            .status,
            400
        );
    }

    #[test]
    fn debug_endpoints_require_bearer_token_when_configured() {
        let mut state = toy_state();
        state.admin_token = Some("s3cret".to_string());
        for target in ["/debug/stats", "/debug/model/toy"] {
            for auth in [None, Some("Basic s3cret"), Some("Bearer nope")] {
                let r = handle(&state, &req_with_auth("GET", target, auth, b""));
                assert_eq!(r.status, 401, "{target} with {auth:?}");
            }
            let r = handle(&state, &req_with_auth("GET", target, Some("Bearer s3cret"), b""));
            assert_eq!(r.status, 200, "{target}: {}", String::from_utf8_lossy(&r.body));
        }
        // without a configured token both pages stay open (dev default)
        let open = toy_state();
        assert_eq!(handle(&open, &req("GET", "/debug/stats", b"")).status, 200);
        assert_eq!(handle(&open, &req("GET", "/debug/model/toy", b"")).status, 200);
    }

    #[test]
    fn debug_model_reports_the_load_time_analysis() {
        let state = toy_state();
        assert_eq!(handle(&state, &req("GET", "/debug/model/ghost", b"")).status, 404);
        assert_eq!(handle(&state, &req("GET", "/debug/model/", b"")).status, 404);
        assert_eq!(handle(&state, &req("POST", "/debug/model/toy", b"")).status, 405);
        let r = handle(&state, &req("GET", "/debug/model/toy", b""));
        assert_eq!(r.status, 200);
        let v = body_json(&r);
        assert_eq!(v.get("model").unwrap().as_str(), Some("toy"));
        assert_eq!(v.get("generation").unwrap().as_usize(), Some(1));
        assert_eq!(v.get("input_dim").unwrap().as_usize(), Some(6));
        // the embedded analysis is byte-for-byte what the served model
        // computed at load time (the msq-inspect agreement contract)
        let model = state.server("toy").unwrap().model.clone();
        assert_eq!(
            v.get("analysis").unwrap().to_string(),
            model.analysis.to_json().to_string()
        );
        let layers = v.path(&["analysis", "layers"]).unwrap().as_arr().unwrap();
        assert_eq!(layers.len(), 2);
        assert_eq!(layers[0].get("bits").unwrap().as_usize(), Some(4));
        assert_eq!(layers[1].get("bits").unwrap().as_usize(), Some(3));
        // /metrics renders the matching static per-layer families
        let text = render_metrics(&state);
        assert!(text.contains("msq_layer_bits{model=\"toy\",layer=\"00:"), "{text}");
        assert!(text.contains("msq_layer_entropy_bits{model=\"toy\""), "{text}");
        assert!(text.contains("msq_layer_quant_error{model=\"toy\""), "{text}");
        assert!(text.contains("msq_layer_payload_bytes{model=\"toy\""), "{text}");
    }

    #[test]
    fn int8_surfaces_calibration_on_debug_and_metrics() {
        // serialize against tests that flip the global qstats switch —
        // with observers on, the infer below would seed an EMA and the
        // calibration source would read "ema" instead of "static"
        let _guard = crate::obs::qstats::test_mutex();
        let pool = Arc::new(ThreadPool::new(2));
        let cfg = ServerConfig {
            max_batch: 8,
            max_delay: Duration::from_millis(1),
            queue_cap: 64,
            threads: 1,
            ..Default::default()
        };
        let mut state = AppState::new(cfg, pool);
        state.int8 = true;
        let pm = PackedModel::synth_mlp(&[6, 8, 3], &[4, 3], 3).unwrap();
        let path = std::env::temp_dir().join("msq_router_int8.msqpack");
        pm.save(&path).unwrap();
        state.load_model("qi", &path, None).unwrap();
        // the loaded model carries the flag (reloads would too)
        let model = state.server("qi").unwrap().model.clone();
        assert!(model.int8, "load_model must propagate AppState::int8");
        let r = handle(&state, &req("POST", "/v1/models/qi/infer", b"[[0.5,1,0,-1,0.25,1]]"));
        assert_eq!(r.status, 200, "{}", String::from_utf8_lossy(&r.body));
        // debug page: flag + one calibration row per int-capable layer
        let d = handle(&state, &req("GET", "/debug/model/qi", b""));
        assert_eq!(d.status, 200);
        let v = body_json(&d);
        assert_eq!(v.get("int8").unwrap().as_bool(), Some(true));
        let cal = v.get("calibration").unwrap().as_arr().unwrap();
        assert_eq!(cal.len(), 2, "both linear layers are int-capable");
        for row in cal {
            assert_eq!(row.get("zero_point").unwrap().as_usize(), Some(128));
            assert!(row.get("scale").unwrap().as_f64().unwrap() > 0.0);
            // qstats is off in this test: the static bound is in effect
            assert_eq!(row.get("source").unwrap().as_str(), Some("static"));
            assert!(row.get("act_bound").unwrap().as_f64().unwrap() > 0.0);
        }
        // /metrics carries the matching gauge family
        let text = render_metrics(&state);
        assert!(text.contains("# TYPE msq_layer_act_scale gauge"), "{text}");
        assert!(text.contains("msq_layer_act_scale{model=\"qi\",layer=\"00:"), "{text}");
        state.clear_models();
    }

    #[test]
    fn reload_fires_drift_counter_when_activation_ranges_shift() {
        let _guard = crate::obs::qstats::test_mutex();
        let pool = Arc::new(ThreadPool::new(2));
        let cfg = ServerConfig {
            max_batch: 8,
            max_delay: Duration::from_millis(1),
            queue_cap: 64,
            threads: 1,
            ..Default::default()
        };
        let state = AppState::new(cfg, pool);
        let pm = PackedModel::synth_mlp(&[6, 8, 3], &[4, 3], 3).unwrap();
        let path = std::env::temp_dir().join("msq_router_drift.msqpack");
        pm.save(&path).unwrap();
        state.load_model("driftm", &path, None).unwrap();

        let qs = crate::obs::qstats::qstats();
        qs.set_rate(1.0);
        qs.enable(true);
        // generation 1 sees large activations…
        let r = handle(&state, &req("POST", "/v1/models/driftm/infer", b"[[64,64,64,64,64,64]]"));
        assert_eq!(r.status, 200, "{}", String::from_utf8_lossy(&r.body));
        assert!(!qs.absmax_by_prefix("driftm/").is_empty(), "observers saw no traffic");
        // …the reload snapshots and clears the observers…
        let r = handle(&state, &req("POST", "/admin/reload", b""));
        assert_eq!(r.status, 200);
        assert!(qs.absmax_by_prefix("driftm/").is_empty(), "reload must reset observers");
        // …and generation 2 sees tiny ones: relative shift ≫ threshold
        let r =
            handle(&state, &req("POST", "/v1/models/driftm/infer", b"[[0.01,0,0,0,0,0.01]]"));
        assert_eq!(r.status, 200);
        let text = render_metrics(&state);
        let line = |t: &str| {
            t.lines()
                .find(|l| l.starts_with("msq_act_range_drift_total{model=\"driftm\"}"))
                .map(str::to_string)
        };
        assert!(line(&text).is_some(), "drift counter missing:\n{text}");
        // once per layer per generation: a second scrape does not double-count
        let text2 = render_metrics(&state);
        assert_eq!(line(&text), line(&text2));
        qs.enable(false);
        qs.reset_prefix("driftm/");
        state.clear_models();
    }

    #[test]
    fn drain_maps_to_503() {
        let state = toy_state();
        state.start_drain();
        assert_eq!(handle(&state, &req("GET", "/healthz", b"")).status, 503);
        assert_eq!(
            handle(&state, &req("POST", "/v1/models/toy/infer", b"[[0,0,0,0,0,0]]")).status,
            503
        );
        assert_eq!(handle(&state, &req("POST", "/admin/reload", b"")).status, 503);
        // metrics stay scrapeable during drain
        let r = handle(&state, &req("GET", "/metrics", b""));
        assert_eq!(r.status, 200);
        assert!(String::from_utf8_lossy(&r.body).contains("msq_gateway_draining 1"));
        state.clear_models();
    }
}
