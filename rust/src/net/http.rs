//! Minimal HTTP/1.1 on `std::net` (no hyper offline): request parser,
//! response writer, and the client half the load generator reuses.
//!
//! Scope is exactly what the gateway needs — origin-form targets,
//! `Content-Length` and `chunked` bodies (any other transfer coding is
//! answered with 501, and a request carrying *both* framings is a 400
//! request-smuggling refusal per RFC 9112 §6.1),
//! keep-alive with the HTTP/1.0/1.1 defaults, and hard limits on line
//! length, header count, and body size so a hostile peer cannot balloon
//! memory. Every malformed input maps to a 4xx/5xx [`ReadError::Bad`];
//! nothing in this module panics on wire data (pinned by property tests
//! over adversarial byte streams).
//!
//! The reader distinguishes *where* a connection went quiet:
//! [`ReadError::Closed`] (clean EOF between requests — drop the
//! connection), [`ReadError::Idle`] (read timeout with no request bytes
//! consumed — poll the shutdown flag and keep waiting), and mid-request
//! timeouts/EOFs, which are protocol errors (408 / connection drop).

use std::io::{Read, Write};

use crate::util::json::Json;

/// Parser limits; defaults match common proxy behaviour.
#[derive(Clone, Debug)]
pub struct Limits {
    /// Max request-line / header-line length in bytes (431 beyond).
    pub max_line: usize,
    /// Max header count (431 beyond).
    pub max_headers: usize,
    /// Max `Content-Length` body in bytes (413 beyond).
    pub max_body: usize,
}

impl Default for Limits {
    fn default() -> Self {
        Limits { max_line: 8192, max_headers: 64, max_body: 8 << 20 }
    }
}

/// One parsed request. Header names are lowercased at parse time.
#[derive(Clone, Debug)]
pub struct Request {
    pub method: String,
    /// Origin-form target as sent (`/v1/models/mlp/infer?x=1`).
    pub target: String,
    http11: bool,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    /// Target with the query string stripped.
    pub fn path(&self) -> &str {
        self.target.split('?').next().unwrap_or("")
    }

    /// First header with this (case-insensitive) name.
    pub fn header(&self, name: &str) -> Option<&str> {
        let n = name.to_ascii_lowercase();
        self.headers.iter().find(|(k, _)| *k == n).map(|(_, v)| v.as_str())
    }

    /// Connection persistence: explicit `Connection:` header wins,
    /// otherwise the HTTP-version default (1.1 keeps, 1.0 closes).
    pub fn keep_alive(&self) -> bool {
        match self.header("connection").map(|v| v.to_ascii_lowercase()) {
            Some(v) if v.contains("close") => false,
            Some(v) if v.contains("keep-alive") => true,
            _ => self.http11,
        }
    }
}

/// Why `read_request` returned without a request.
#[derive(Debug)]
pub enum ReadError {
    /// Clean EOF before any byte of a request — peer is done.
    Closed,
    /// Read timeout with no request bytes pending — connection is idle;
    /// the caller checks its shutdown flag and retries.
    Idle,
    /// Malformed/oversized input; respond with `status` and close.
    Bad { status: u16, msg: String },
    /// Transport failure (reset, EOF mid-request); just close.
    Io(String),
}

impl std::fmt::Display for ReadError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ReadError::Closed => write!(f, "connection closed"),
            ReadError::Idle => write!(f, "connection idle"),
            ReadError::Bad { status, msg } => write!(f, "{status}: {msg}"),
            ReadError::Io(e) => write!(f, "io: {e}"),
        }
    }
}

fn bad(status: u16, msg: impl Into<String>) -> ReadError {
    ReadError::Bad { status, msg: msg.into() }
}

enum Fill {
    Data,
    Eof,
    Timeout,
}

/// Message-body framing declared by the headers.
enum BodyKind {
    /// `Content-Length: n` (0 when absent).
    Len(usize),
    /// `Transfer-Encoding: chunked`.
    Chunked,
}

/// Buffered reader over a byte stream; owns the partial-read state so
/// pipelined requests parse back-to-back without losing bytes.
pub struct HttpReader<R: Read> {
    r: R,
    buf: Vec<u8>,
    pos: usize,
}

impl<R: Read> HttpReader<R> {
    pub fn new(r: R) -> HttpReader<R> {
        HttpReader { r, buf: Vec::with_capacity(4096), pos: 0 }
    }

    /// The underlying stream (e.g. to `try_clone` a write handle when
    /// `R = TcpStream`).
    pub fn stream(&self) -> &R {
        &self.r
    }

    fn pending(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Drop the consumed prefix (called between requests).
    fn compact(&mut self) {
        if self.pos > 0 {
            self.buf.drain(..self.pos);
            self.pos = 0;
        }
    }

    fn fill(&mut self) -> Result<Fill, ReadError> {
        let mut tmp = [0u8; 4096];
        match self.r.read(&mut tmp) {
            Ok(0) => Ok(Fill::Eof),
            Ok(n) => {
                self.buf.extend_from_slice(&tmp[..n]);
                Ok(Fill::Data)
            }
            Err(e) => match e.kind() {
                std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut => {
                    Ok(Fill::Timeout)
                }
                std::io::ErrorKind::Interrupted => Ok(Fill::Data),
                _ => Err(ReadError::Io(e.to_string())),
            },
        }
    }

    /// One CRLF/LF-terminated line, terminator stripped. `at_start`
    /// marks the first line of a message, where quiet means Idle/Closed
    /// rather than a protocol error.
    fn read_line(&mut self, max: usize, at_start: bool) -> Result<String, ReadError> {
        loop {
            if let Some(idx) = self.buf[self.pos..].iter().position(|&b| b == b'\n') {
                let end = self.pos + idx;
                let mut line = &self.buf[self.pos..end];
                if line.ends_with(b"\r") {
                    line = &line[..line.len() - 1];
                }
                if line.len() > max {
                    return Err(bad(431, "line too long"));
                }
                let s = String::from_utf8_lossy(line).into_owned();
                self.pos = end + 1;
                return Ok(s);
            }
            if self.pending() > max {
                return Err(bad(431, "line too long"));
            }
            match self.fill()? {
                Fill::Data => {}
                Fill::Eof => {
                    return Err(if at_start && self.pending() == 0 {
                        ReadError::Closed
                    } else {
                        ReadError::Io("connection closed mid-message".into())
                    });
                }
                Fill::Timeout => {
                    return Err(if at_start && self.pending() == 0 {
                        ReadError::Idle
                    } else {
                        bad(408, "timed out mid-message")
                    });
                }
            }
        }
    }

    /// Exactly `n` body bytes.
    fn read_body(&mut self, n: usize) -> Result<Vec<u8>, ReadError> {
        while self.pending() < n {
            match self.fill()? {
                Fill::Data => {}
                Fill::Eof => return Err(ReadError::Io("connection closed mid-body".into())),
                Fill::Timeout => return Err(bad(408, "timed out reading body")),
            }
        }
        let body = self.buf[self.pos..self.pos + n].to_vec();
        self.pos += n;
        Ok(body)
    }

    /// Headers shared by request and response parsing: lines until the
    /// blank separator, names lowercased.
    fn read_headers(&mut self, limits: &Limits) -> Result<Vec<(String, String)>, ReadError> {
        let mut headers = Vec::new();
        loop {
            let l = self.read_line(limits.max_line, false)?;
            if l.is_empty() {
                return Ok(headers);
            }
            if headers.len() >= limits.max_headers {
                return Err(bad(431, "too many headers"));
            }
            let colon = l.find(':').ok_or_else(|| bad(400, "malformed header"))?;
            let name = l[..colon].trim();
            if name.is_empty() || !name.bytes().all(is_token_byte) {
                return Err(bad(400, "malformed header name"));
            }
            headers.push((name.to_ascii_lowercase(), l[colon + 1..].trim().to_string()));
        }
    }

    /// How the message body is framed: a validated `Content-Length`, or
    /// chunked transfer coding. `chunked` must be the *only* coding
    /// (anything else is 501), and combining it with `Content-Length`
    /// is refused outright (400) — ambiguous framing is the classic
    /// request-smuggling vector.
    fn body_kind(headers: &[(String, String)], limits: &Limits) -> Result<BodyKind, ReadError> {
        let codings: Vec<String> = headers
            .iter()
            .filter(|(k, _)| k == "transfer-encoding")
            .flat_map(|(_, v)| v.split(','))
            .map(|c| c.trim().to_ascii_lowercase())
            .filter(|c| !c.is_empty())
            .collect();
        if !codings.is_empty() {
            if codings != ["chunked"] {
                return Err(bad(501, format!("unsupported transfer coding {codings:?}")));
            }
            if headers.iter().any(|(k, _)| k == "content-length") {
                return Err(bad(400, "both Content-Length and chunked framing"));
            }
            return Ok(BodyKind::Chunked);
        }
        let mut len: Option<usize> = None;
        for (k, v) in headers {
            if k == "content-length" {
                let n: usize =
                    v.trim().parse().map_err(|_| bad(400, "bad Content-Length"))?;
                if let Some(prev) = len {
                    if prev != n {
                        return Err(bad(400, "conflicting Content-Length headers"));
                    }
                }
                len = Some(n);
            }
        }
        let n = len.unwrap_or(0);
        if n > limits.max_body {
            return Err(bad(413, format!("body {n} bytes exceeds limit {}", limits.max_body)));
        }
        Ok(BodyKind::Len(n))
    }

    /// `chunked` body: `size-hex[;ext]\r\n data \r\n` repeated, a `0`
    /// chunk, then an (ignored but validated) trailer section. The
    /// cumulative size honours `limits.max_body` exactly like a declared
    /// length; every malformed framing byte is a 4xx, never a panic.
    fn read_chunked(&mut self, limits: &Limits) -> Result<Vec<u8>, ReadError> {
        let mut body = Vec::new();
        loop {
            let line = self.read_line(limits.max_line, false)?;
            let size = line.split(';').next().unwrap_or("").trim();
            if size.is_empty() || !size.bytes().all(|b| b.is_ascii_hexdigit()) {
                return Err(bad(400, format!("bad chunk size {size:?}")));
            }
            let n = usize::from_str_radix(size, 16)
                .map_err(|_| bad(413, "chunk size exceeds limit"))?;
            if n == 0 {
                break;
            }
            if body.len() + n > limits.max_body {
                return Err(bad(
                    413,
                    format!("chunked body exceeds limit {}", limits.max_body),
                ));
            }
            body.extend_from_slice(&self.read_body(n)?);
            if !self.read_line(limits.max_line, false)?.is_empty() {
                return Err(bad(400, "missing chunk terminator"));
            }
        }
        // trailer section: header-shaped lines until the blank line that
        // ends the message (we validate and drop them)
        let mut count = 0usize;
        loop {
            let l = self.read_line(limits.max_line, false)?;
            if l.is_empty() {
                return Ok(body);
            }
            count += 1;
            if count > limits.max_headers {
                return Err(bad(431, "too many trailers"));
            }
            let colon = l.find(':').ok_or_else(|| bad(400, "malformed trailer"))?;
            let name = l[..colon].trim();
            if name.is_empty() || !name.bytes().all(is_token_byte) {
                return Err(bad(400, "malformed trailer name"));
            }
        }
    }

    /// Parse one request (blocking until a full message or a failure).
    pub fn read_request(&mut self, limits: &Limits) -> Result<Request, ReadError> {
        self.compact();
        // tolerate stray blank lines between pipelined requests (RFC 9112 §2.2)
        let mut line = self.read_line(limits.max_line, true)?;
        while line.is_empty() {
            line = self.read_line(limits.max_line, true)?;
        }
        let mut parts = line.split(' ').filter(|s| !s.is_empty());
        let method = parts.next().unwrap_or("").to_string();
        let target = parts.next().unwrap_or("").to_string();
        let version = parts.next().unwrap_or("");
        if parts.next().is_some() || target.is_empty() || version.is_empty() {
            return Err(bad(400, "malformed request line"));
        }
        if method.is_empty() || !method.bytes().all(is_token_byte) {
            return Err(bad(400, "malformed method"));
        }
        let http11 = match version {
            "HTTP/1.1" => true,
            "HTTP/1.0" => false,
            _ => return Err(bad(505, "unsupported HTTP version")),
        };
        if !target.starts_with('/') {
            return Err(bad(400, "target must be origin-form (/path)"));
        }
        let headers = self.read_headers(limits)?;
        let body = match Self::body_kind(&headers, limits)? {
            BodyKind::Len(0) => Vec::new(),
            BodyKind::Len(n) => self.read_body(n)?,
            BodyKind::Chunked => self.read_chunked(limits)?,
        };
        Ok(Request { method, target, http11, headers, body })
    }

    /// Client half: parse one response, returning (status, body).
    pub fn read_response(&mut self, limits: &Limits) -> Result<(u16, Vec<u8>), ReadError> {
        self.compact();
        let line = self.read_line(limits.max_line, true)?;
        // "HTTP/1.1 200 OK"
        let mut it = line.splitn(3, ' ');
        let ver = it.next().unwrap_or("");
        if !ver.starts_with("HTTP/1.") {
            return Err(ReadError::Io(format!("malformed status line {line:?}")));
        }
        let status: u16 = it
            .next()
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| ReadError::Io(format!("malformed status line {line:?}")))?;
        let headers = self.read_headers(limits)?;
        let body = match Self::body_kind(&headers, limits)? {
            BodyKind::Len(0) => Vec::new(),
            BodyKind::Len(n) => self.read_body(n)?,
            BodyKind::Chunked => self.read_chunked(limits)?,
        };
        Ok((status, body))
    }
}

/// RFC 9110 token bytes (the subset we accept in methods/header names).
fn is_token_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b"!#$%&'*+-.^_`|~".contains(&b)
}

// ---------------------------------------------------------------------------
// Responses
// ---------------------------------------------------------------------------

/// One response; `write_to` adds `Content-Length` and `Connection`.
#[derive(Clone, Debug)]
pub struct Response {
    pub status: u16,
    pub content_type: String,
    pub body: Vec<u8>,
    /// Extra headers (e.g. `Retry-After` on 429).
    pub extra: Vec<(String, String)>,
}

impl Response {
    pub fn json(status: u16, v: &Json) -> Response {
        Response {
            status,
            content_type: "application/json".into(),
            body: v.to_string().into_bytes(),
            extra: Vec::new(),
        }
    }

    /// JSON error envelope: `{"error": msg}`.
    pub fn error(status: u16, msg: &str) -> Response {
        Self::json(status, &Json::obj(vec![("error", Json::Str(msg.to_string()))]))
    }

    pub fn text(status: u16, content_type: &str, body: String) -> Response {
        Response {
            status,
            content_type: content_type.to_string(),
            body: body.into_bytes(),
            extra: Vec::new(),
        }
    }

    /// Prometheus text exposition body.
    pub fn prometheus(body: String) -> Response {
        Self::text(200, "text/plain; version=0.0.4; charset=utf-8", body)
    }

    pub fn header(mut self, name: &str, value: &str) -> Response {
        self.extra.push((name.to_string(), value.to_string()));
        self
    }

    pub fn write_to<W: Write>(&self, w: &mut W, keep_alive: bool) -> std::io::Result<()> {
        write!(
            w,
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: {}\r\n",
            self.status,
            status_text(self.status),
            self.content_type,
            self.body.len(),
            if keep_alive { "keep-alive" } else { "close" },
        )?;
        for (k, v) in &self.extra {
            write!(w, "{k}: {v}\r\n")?;
        }
        w.write_all(b"\r\n")?;
        w.write_all(&self.body)?;
        w.flush()
    }
}

pub fn status_text(code: u16) -> &'static str {
    match code {
        200 => "OK",
        204 => "No Content",
        400 => "Bad Request",
        401 => "Unauthorized",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        503 => "Service Unavailable",
        505 => "HTTP Version Not Supported",
        _ => "Unknown",
    }
}

/// Client half: serialize one request (loadgen, e2e tests).
pub fn write_request<W: Write>(
    w: &mut W,
    method: &str,
    target: &str,
    content_type: Option<&str>,
    body: &[u8],
) -> std::io::Result<()> {
    write!(w, "{method} {target} HTTP/1.1\r\nHost: msq-gateway\r\n")?;
    if let Some(ct) = content_type {
        write!(w, "Content-Type: {ct}\r\n")?;
    }
    write!(w, "Content-Length: {}\r\n\r\n", body.len())?;
    w.write_all(body)?;
    w.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use std::io::Cursor;

    fn parse_bytes(bytes: &[u8]) -> Result<Request, ReadError> {
        HttpReader::new(Cursor::new(bytes.to_vec())).read_request(&Limits::default())
    }

    #[test]
    fn parses_post_with_body() {
        let req = parse_bytes(
            b"POST /v1/models/mlp/infer HTTP/1.1\r\nHost: x\r\nContent-Type: application/json\r\n\
              Content-Length: 9\r\n\r\n[[1,2,3]]",
        )
        .unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path(), "/v1/models/mlp/infer");
        assert_eq!(req.body, b"[[1,2,3]]");
        assert_eq!(req.header("HOST"), Some("x"));
        assert!(req.keep_alive());
    }

    #[test]
    fn query_strings_and_connection_close() {
        let req = parse_bytes(
            b"GET /healthz?verbose=1 HTTP/1.1\r\nConnection: close\r\n\r\n",
        )
        .unwrap();
        assert_eq!(req.path(), "/healthz");
        assert_eq!(req.target, "/healthz?verbose=1");
        assert!(!req.keep_alive());
        // HTTP/1.0 default is close
        let old = parse_bytes(b"GET / HTTP/1.0\r\n\r\n").unwrap();
        assert!(!old.keep_alive());
    }

    #[test]
    fn pipelined_requests_parse_back_to_back() {
        let mut r = HttpReader::new(Cursor::new(
            b"GET /a HTTP/1.1\r\n\r\nPOST /b HTTP/1.1\r\nContent-Length: 2\r\n\r\nhi".to_vec(),
        ));
        let lim = Limits::default();
        let a = r.read_request(&lim).unwrap();
        assert_eq!(a.path(), "/a");
        let b = r.read_request(&lim).unwrap();
        assert_eq!(b.path(), "/b");
        assert_eq!(b.body, b"hi");
        // then clean EOF
        assert!(matches!(r.read_request(&lim), Err(ReadError::Closed)));
    }

    #[test]
    fn malformed_inputs_are_4xx_not_panics() {
        let cases: &[(&[u8], u16)] = &[
            (b"GARBAGE\r\n\r\n", 400),                                // no target/version
            (b"GET /x HTTP/2.0\r\n\r\n", 505),                        // unsupported version
            (b"GET x HTTP/1.1\r\n\r\n", 400),                         // non-origin target
            (b"G@T /x HTTP/1.1\r\n\r\n", 400),                        // bad method byte
            (b"GET /x HTTP/1.1 extra\r\n\r\n", 400),                  // 4-part request line
            (b"GET /x HTTP/1.1\r\nno-colon-here\r\n\r\n", 400),       // header w/o colon
            (b"GET /x HTTP/1.1\r\n: empty\r\n\r\n", 400),             // empty header name
            (b"POST /x HTTP/1.1\r\nContent-Length: nope\r\n\r\n", 400), // garbage length
            (
                b"POST /x HTTP/1.1\r\nContent-Length: 2\r\nContent-Length: 3\r\n\r\nab",
                400,
            ), // conflicting lengths
            (b"POST /x HTTP/1.1\r\nTransfer-Encoding: gzip\r\n\r\n", 501),
            (b"POST /x HTTP/1.1\r\nContent-Length: 99999999999\r\n\r\n", 413),
        ];
        for (bytes, want) in cases {
            match parse_bytes(bytes) {
                Err(ReadError::Bad { status, .. }) => {
                    assert_eq!(status, *want, "input {:?}", String::from_utf8_lossy(bytes));
                }
                other => panic!(
                    "input {:?}: expected Bad({want}), got {other:?}",
                    String::from_utf8_lossy(bytes)
                ),
            }
        }
    }

    #[test]
    fn chunked_bodies_parse_and_preserve_order() {
        let req = parse_bytes(
            b"POST /x HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n\
              4\r\nWiki\r\n5\r\npedia\r\n0\r\n\r\n",
        )
        .unwrap();
        assert_eq!(req.body, b"Wikipedia");
        // chunk extensions are ignored; trailers are validated then dropped
        let req = parse_bytes(
            b"POST /x HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n\
              3;ext=1\r\nabc\r\n0\r\nx-sum: 3\r\n\r\n",
        )
        .unwrap();
        assert_eq!(req.body, b"abc");
        // coding value is case-insensitive; a zero-chunk body is empty
        let req = parse_bytes(
            b"POST /x HTTP/1.1\r\ntransfer-encoding: Chunked\r\n\r\n0\r\n\r\n",
        )
        .unwrap();
        assert!(req.body.is_empty());
        // the reader consumes exactly the message: pipelining still works
        let lim = Limits::default();
        let mut r = HttpReader::new(Cursor::new(
            b"POST /a HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n\
              2\r\nhi\r\n0\r\n\r\nGET /b HTTP/1.1\r\n\r\n"
                .to_vec(),
        ));
        let a = r.read_request(&lim).unwrap();
        assert_eq!(a.body, b"hi");
        assert_eq!(r.read_request(&lim).unwrap().path(), "/b");
    }

    #[test]
    fn malformed_chunked_bodies_are_4xx_not_panics() {
        let cases: &[(&[u8], u16)] = &[
            // non-hex / empty / signed chunk sizes
            (b"POST /x HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\nzz\r\nab\r\n0\r\n\r\n", 400),
            (b"POST /x HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n\r\nab\r\n0\r\n\r\n", 400),
            (b"POST /x HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n-5\r\nab\r\n0\r\n\r\n", 400),
            // chunk data not followed by its CRLF terminator
            (b"POST /x HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n2\r\nabX\r\n0\r\n\r\n", 400),
            // a size that overflows usize is over any body limit
            (b"POST /x HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\nffffffffffffffffff\r\n", 413),
            // trailer junk: no colon, empty name
            (b"POST /x HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n0\r\njunk trailer\r\n\r\n", 400),
            (b"POST /x HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n0\r\n: v\r\n\r\n", 400),
            // ambiguous framing (smuggling) and unsupported codings
            (
                b"POST /x HTTP/1.1\r\nTransfer-Encoding: chunked\r\nContent-Length: 2\r\n\r\n0\r\n\r\n",
                400,
            ),
            (b"POST /x HTTP/1.1\r\nTransfer-Encoding: chunked, gzip\r\n\r\n", 501),
        ];
        for (bytes, want) in cases {
            match parse_bytes(bytes) {
                Err(ReadError::Bad { status, .. }) => {
                    assert_eq!(status, *want, "input {:?}", String::from_utf8_lossy(bytes));
                }
                other => panic!(
                    "input {:?}: expected Bad({want}), got {other:?}",
                    String::from_utf8_lossy(bytes)
                ),
            }
        }
        // truncation mid-chunk is a transport error, not a panic
        let r = parse_bytes(b"POST /x HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n5\r\nab");
        assert!(matches!(r, Err(ReadError::Io(_))), "{r:?}");
        // the cumulative size honours max_body even when each chunk fits
        let lim = Limits { max_body: 3, ..Limits::default() };
        let r = HttpReader::new(Cursor::new(
            b"POST /x HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n\
              2\r\nab\r\n2\r\ncd\r\n0\r\n\r\n"
                .to_vec(),
        ))
        .read_request(&lim);
        assert!(matches!(r, Err(ReadError::Bad { status: 413, .. })), "{r:?}");
    }

    #[test]
    fn prop_chunked_truncations_never_panic_or_misparse() {
        let wire = b"POST /x HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n\
                     4;x=y\r\nWiki\r\n5\r\npedia\r\n0\r\nx-t: 1\r\n\r\n";
        prop::check(200, |g| {
            let cut = g.usize_in(0, wire.len());
            match parse_bytes(&wire[..cut]) {
                Ok(req) => prop::ensure(
                    cut == wire.len() && req.body == b"Wikipedia",
                    format!("parsed a truncated chunked request (cut {cut})"),
                ),
                Err(_) => Ok(()), // must fail, must not panic
            }
        });
    }

    #[test]
    fn truncated_body_is_io_error() {
        let r = parse_bytes(b"POST /x HTTP/1.1\r\nContent-Length: 10\r\n\r\nshort");
        assert!(matches!(r, Err(ReadError::Io(_))), "{r:?}");
        // EOF mid-header is an Io error too, not Closed
        let r = parse_bytes(b"GET /x HTTP/1.1\r\nHost: tru");
        assert!(matches!(r, Err(ReadError::Io(_))), "{r:?}");
    }

    #[test]
    fn oversized_lines_and_header_floods_are_431() {
        let mut big = b"GET /".to_vec();
        big.extend(std::iter::repeat(b'a').take(10_000));
        big.extend_from_slice(b" HTTP/1.1\r\n\r\n");
        assert!(matches!(parse_bytes(&big), Err(ReadError::Bad { status: 431, .. })));

        let mut flood = b"GET /x HTTP/1.1\r\n".to_vec();
        for i in 0..100 {
            flood.extend_from_slice(format!("h{i}: v\r\n").as_bytes());
        }
        flood.extend_from_slice(b"\r\n");
        assert!(matches!(parse_bytes(&flood), Err(ReadError::Bad { status: 431, .. })));
    }

    #[test]
    fn request_roundtrip_through_writer() {
        let mut wire = Vec::new();
        write_request(&mut wire, "POST", "/v1/models/m/infer", Some("application/json"), b"[[1]]")
            .unwrap();
        let req = parse_bytes(&wire).unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path(), "/v1/models/m/infer");
        assert_eq!(req.body, b"[[1]]");
    }

    #[test]
    fn response_roundtrip_through_reader() {
        let resp = Response::json(200, &Json::obj(vec![("ok", Json::Bool(true))]))
            .header("X-Test", "1");
        let mut wire = Vec::new();
        resp.write_to(&mut wire, true).unwrap();
        let (status, body) =
            HttpReader::new(Cursor::new(wire)).read_response(&Limits::default()).unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, br#"{"ok":true}"#);
        // error envelope carries the right status text
        let mut wire = Vec::new();
        Response::error(429, "queue full").write_to(&mut wire, false).unwrap();
        let text = String::from_utf8(wire).unwrap();
        assert!(text.starts_with("HTTP/1.1 429 Too Many Requests\r\n"), "{text}");
        assert!(text.contains("Connection: close\r\n"), "{text}");
    }

    #[test]
    fn prop_arbitrary_bytes_never_panic() {
        // adversarial wire data: random bytes, with a bias toward
        // HTTP-ish prefixes so the parser gets deep before failing
        prop::check(300, |g| {
            let n = g.usize_in(0, 200);
            let mut bytes: Vec<u8> = (0..n).map(|_| (g.rng().next_u64() & 0xFF) as u8).collect();
            if g.bool() {
                let mut v = b"POST /m HTTP/1.1\r\nContent-Length: ".to_vec();
                v.extend_from_slice(&bytes);
                bytes = v;
            }
            let _ = parse_bytes(&bytes); // any Result is fine; panics are not
            Ok(())
        });
    }

    #[test]
    fn prop_truncations_of_valid_request_never_panic_or_misparse() {
        let mut wire = Vec::new();
        let body = br#"{"inputs": [[0.25, -1.5]]}"#;
        write_request(&mut wire, "POST", "/v1/models/mlp/infer", Some("application/json"), body)
            .unwrap();
        prop::check(200, |g| {
            let cut = g.usize_in(0, wire.len());
            match parse_bytes(&wire[..cut]) {
                Ok(req) => prop::ensure(
                    cut == wire.len() && req.body.len() == 26,
                    format!("parsed a truncated request (cut {cut})"),
                ),
                Err(_) => Ok(()), // must fail, must not panic
            }
        });
    }
}
