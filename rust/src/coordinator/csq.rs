//! CSQ baseline (S8): continuous-sparsification bit-split training
//! (Xiao et al., DAC 2023).
//!
//! Same bit-split parameterization as BSQ plus per-(layer, plane) gates
//! `σ(T·g)` whose temperature `T` ramps 1 → 100 over training (the
//! continuous-sparsification smoothing of both bit training and precision
//! adjustment). Precision reduction happens when a gate saturates low;
//! the trainer mirrors that by pruning a layer's lowest active plane when
//! its *gated* nonzero rate crosses α. Reuses `BsqTrainer`'s loop with
//! `method = "csq"` (the artifact differs: gates are extra trainable
//! params and the regularizer is gate-weighted).

use anyhow::Result;

use super::bsq::BsqTrainer;
use super::trainer::MsqConfig;
use crate::runtime::Engine;

pub struct CsqTrainer;

impl CsqTrainer {
    pub fn new(eng: &Engine, cfg: MsqConfig) -> Result<BsqTrainer<'_>> {
        BsqTrainer::with_method(eng, cfg, "csq")
    }
}
