//! The MSQ trainer: Algorithm 1, generic over the execution [`Backend`].
//!
//! The same loop drives the pure-Rust native backend (default build) and
//! the XLA/PJRT engine (`--features pjrt`) — the backend owns parameters
//! and step execution; the trainer owns the schedule, the bit-state, and
//! the pruning policy. Also runs the `dorefa` method (same loop with the
//! DoReFa quantizer) and *uniform fixed-bit QAT* (λ = 0, no pruning) for
//! the tables' uniform baselines.

use anyhow::{bail, Result};

use super::bitstate::BitState;
use super::hessian::{omega, HessianEstimator};
use super::report::{PruneEvent, RunReport};
use super::schedule::cosine_lr;
use crate::data::{Batcher, Dataset};
use crate::metrics::Jsonl;
use crate::runtime::backend::Backend;
use crate::util::json::Json;
use crate::util::timer::{peak_rss_bytes, Timer};

/// `[f32,…]` telemetry array.
fn arr_f32(v: &[f32]) -> Json {
    Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
}

/// `[bits,…]` telemetry array.
fn arr_u8(v: &[u8]) -> Json {
    Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
}

/// Full configuration of one training run (paper Sec. 4.1 + supp Table 2).
#[derive(Clone, Debug)]
pub struct MsqConfig {
    pub model: String,
    /// "msq" | "dorefa" (quantizer baseline) — bsq/csq have their own trainers
    pub method: String,
    /// λ, the LSB L1 strength (0 disables regularization)
    pub lam: f32,
    /// α, the β threshold for pruning a layer
    pub alpha: f32,
    /// I, the pruning interval in epochs
    pub interval: usize,
    /// Γ, the target compression ratio (0 disables pruning → uniform QAT)
    pub gamma: f64,
    pub epochs: usize,
    pub batch: usize,
    pub lr0: f32,
    /// activation bits (0 = full precision activations)
    pub n_act: f32,
    /// initial per-layer precision
    pub n0: u8,
    pub use_hessian: bool,
    pub hessian_probes: usize,
    pub seed: u64,
    /// evaluate every k epochs (0 = only at the end)
    pub eval_every: usize,
    /// starting bits override (e.g. fixed 4-bit uniform baseline)
    pub fixed_bits: Option<u8>,
    /// scale λ by 2^(n0 − avg_bits): the LSB sawtooth's basin width
    /// doubles per pruned bit while its gradient stays ±λ, so constant λ
    /// sparsifies exponentially slower at low precision. The paper
    /// absorbs this with 400-epoch schedules; compressed schedules keep
    /// the *rate* constant instead (DESIGN.md §Deviations).
    pub adaptive_lam: bool,
    pub verbose: bool,
}

impl Default for MsqConfig {
    fn default() -> Self {
        MsqConfig {
            model: "resnet20".into(),
            method: "msq".into(),
            lam: 5e-5,
            alpha: 0.3,
            interval: 20,
            gamma: 16.0,
            epochs: 60,
            batch: 256,
            lr0: 0.1,
            n_act: 0.0,
            n0: 8,
            use_hessian: true,
            hessian_probes: 4,
            seed: 42,
            eval_every: 5,
            fixed_bits: None,
            adaptive_lam: true,
            verbose: true,
        }
    }
}

pub struct Trainer<B: Backend> {
    pub backend: B,
    pub cfg: MsqConfig,
    pub bitstate: BitState,
    /// When set, the run streams one JSON object per line: `run_start`,
    /// one `epoch` event per epoch (loss, bit-width histogram, LSB
    /// sparsity), one `prune` event per pruning round (β, Ω, bit moves),
    /// and a closing `run_end` — the structured replacement for the
    /// `verbose` prints, rendered back into a table by `msq report`.
    pub telemetry: Option<Jsonl>,
}

#[cfg(feature = "pjrt")]
impl<'e> Trainer<crate::runtime::PjrtBackend<'e>> {
    /// XLA path: resolve the artifact family for `(cfg.model, cfg.method)`
    /// and wrap the engine behind the backend trait.
    pub fn new(eng: &'e crate::runtime::Engine, cfg: MsqConfig) -> Result<Self> {
        let backend =
            crate::runtime::PjrtBackend::new(eng, &cfg.model, &cfg.method, cfg.batch)?;
        Trainer::from_backend(backend, cfg)
    }
}

impl<B: Backend> Trainer<B> {
    /// Wrap any backend; the bit-state starts uniform at `cfg.n0` (or
    /// `cfg.fixed_bits` for the uniform baselines).
    pub fn from_backend(backend: B, cfg: MsqConfig) -> Result<Trainer<B>> {
        if cfg.method != "msq" && cfg.method != "dorefa" {
            bail!(
                "Trainer handles msq/dorefa; use BsqTrainer/CsqTrainer for {}",
                cfg.method
            );
        }
        let mut bitstate = BitState::new(cfg.n0, &backend.q_sizes());
        if let Some(fb) = cfg.fixed_bits {
            bitstate.scheme.bits.iter_mut().for_each(|b| *b = fb);
        }
        Ok(Trainer { backend, cfg, bitstate, telemetry: None })
    }

    /// Stream telemetry events to a JSONL file (see `docs/OBSERVABILITY.md`
    /// for the schema; `msq report` renders it back into a table).
    pub fn telemetry_to(&mut self, path: &std::path::Path) -> Result<()> {
        self.telemetry = Some(Jsonl::create(path)?);
        Ok(())
    }

    /// Write one telemetry event if a sink is attached.
    fn emit(&mut self, ev: Json) -> Result<()> {
        if let Some(t) = self.telemetry.as_mut() {
            t.write(&ev)?;
        }
        Ok(())
    }

    /// `{bits → layer count}` histogram of the current bit assignment.
    fn bit_histogram(&self) -> Json {
        let mut h: std::collections::BTreeMap<String, Json> = Default::default();
        for &b in &self.bitstate.scheme.bits {
            match h.entry(b.to_string()).or_insert(Json::Num(0.0)) {
                Json::Num(n) => *n += 1.0,
                _ => unreachable!(),
            }
        }
        Json::Obj(h)
    }

    /// Run the full schedule on `ds`; returns the report.
    pub fn run(&mut self, ds: &Dataset) -> Result<RunReport> {
        let cfg = self.cfg.clone();
        let timer = Timer::start();
        let mut report = RunReport {
            label: format!("{}_{}", cfg.model, cfg.method),
            model: cfg.model.clone(),
            method: cfg.method.clone(),
            epochs: cfg.epochs,
            trainable_params: self.backend.trainable_params(),
            ..Default::default()
        };
        self.emit(Json::obj(vec![
            ("event", Json::Str("run_start".into())),
            ("label", Json::Str(report.label.clone())),
            ("model", Json::Str(cfg.model.clone())),
            ("method", Json::Str(cfg.method.clone())),
            ("epochs", Json::Num(cfg.epochs as f64)),
            ("lam", Json::Num(cfg.lam as f64)),
            ("alpha", Json::Num(cfg.alpha as f64)),
            ("interval", Json::Num(cfg.interval as f64)),
            ("gamma", Json::Num(cfg.gamma)),
            ("n0", Json::Num(cfg.n0 as f64)),
            ("seed", Json::Num(cfg.seed as f64)),
            ("trainable_params", Json::Num(report.trainable_params as f64)),
            ("layers", Json::Num(self.bitstate.scheme.bits.len() as f64)),
        ]))?;

        let batch = self.backend.batch();
        let elems = self.backend.input_elems();
        let mut batcher = Batcher::new(ds, batch, cfg.seed, true);
        // a separate stream for hessian probe batches
        let mut hess_batcher =
            Batcher::new(ds, batch.max(self.backend.hess_batch()), cfg.seed ^ 0x4E55, true);
        let steps_per_epoch = batcher.batches_per_epoch();
        let total_steps = steps_per_epoch * cfg.epochs;
        let mut hess = HessianEstimator::new(cfg.hessian_probes, cfg.seed);

        let mut gamma_reached = self.bitstate.compression() >= cfg.gamma && cfg.gamma > 0.0;
        let mut lam = if gamma_reached { 0.0 } else { cfg.lam };
        let mut step = 0usize;
        let mut step_time_acc = 0f64;

        for epoch in 0..cfg.epochs {
            // records one epoch wall-clock observation into the global
            // registry on drop (panic-safe)
            let _epoch_span = crate::obs::global().span("msq_train_epoch_seconds", &[]);
            let mut ep_loss = 0f64;
            let mut ep_correct = 0f64;
            let bits = self.bitstate.bits_f32();
            let ks = self.bitstate.ks_f32();
            let eff_lam = if cfg.adaptive_lam && lam > 0.0 {
                lam * 2f32.powf(cfg.n0 as f32 - self.bitstate.scheme.avg_bits() as f32)
            } else {
                lam
            };
            for _ in 0..steps_per_epoch {
                let b = batcher.next();
                let lr = cosine_lr(cfg.lr0, step, total_steps, 0.05, 0.0);
                let st = Timer::start();
                let stats = self.backend.train_step(
                    &bits,
                    &ks,
                    eff_lam,
                    lr,
                    cfg.n_act,
                    &b.x[..batch * elems],
                    &b.y[..batch],
                )?;
                step_time_acc += st.seconds();
                ep_loss += stats.loss as f64;
                ep_correct += stats.correct as f64;
                step += 1;
            }
            report.train_loss.push((ep_loss / steps_per_epoch as f64) as f32);
            report.train_acc.push((ep_correct / (steps_per_epoch * batch) as f64) as f32);

            // ---- pruning interval (Algorithm 1 lines 10..35) -------------
            let due = cfg.interval > 0 && (epoch + 1) % cfg.interval == 0;
            if due && !gamma_reached && cfg.gamma > 0.0 {
                self.prune_round(epoch, &mut hess, &mut hess_batcher, &mut report)?;
                if self.bitstate.compression() >= cfg.gamma {
                    gamma_reached = true;
                    lam = 0.0; // stop regularization; pure QAT from here
                    report.gamma_reached_epoch = Some(epoch);
                    if cfg.verbose {
                        println!(
                            "[{}] Γ reached at epoch {epoch}: comp {:.2}x — QAT phase",
                            report.label,
                            self.bitstate.compression()
                        );
                    }
                }
            }

            // ---- eval -----------------------------------------------------
            let do_eval = (cfg.eval_every > 0 && (epoch + 1) % cfg.eval_every == 0)
                || epoch + 1 == cfg.epochs;
            if do_eval {
                let (eacc, eloss) = self.evaluate(ds)?;
                report.eval_epochs.push(epoch);
                report.eval_acc.push(eacc);
                report.eval_loss.push(eloss);
                report.best_acc = report.best_acc.max(eacc);
                if cfg.verbose {
                    println!(
                        "[{}] epoch {epoch:3} loss {:.4} train-acc {:.3} eval-acc {:.3} \
                         comp {:.2}x",
                        report.label,
                        report.train_loss.last().unwrap(),
                        report.train_acc.last().unwrap(),
                        eacc,
                        self.bitstate.compression()
                    );
                }
            }

            // ---- telemetry ------------------------------------------------
            if self.telemetry.is_some() {
                // LSB sparsity = mean β from the most recent prune-round
                // stats pass; null until the first round (computing it
                // every epoch would add a stats pass and perturb timing)
                let lsb = report.prune_events.last().map(|e| {
                    e.beta.iter().map(|&b| b as f64).sum::<f64>()
                        / e.beta.len().max(1) as f64
                });
                let mut ev = vec![
                    ("event", Json::Str("epoch".into())),
                    ("epoch", Json::Num(epoch as f64)),
                    ("loss", Json::Num(*report.train_loss.last().unwrap() as f64)),
                    ("train_acc", Json::Num(*report.train_acc.last().unwrap() as f64)),
                    ("avg_bits", Json::Num(self.bitstate.scheme.avg_bits())),
                    ("compression", Json::Num(self.bitstate.compression())),
                    ("lsb_sparsity", lsb.map(Json::Num).unwrap_or(Json::Null)),
                    ("bits", arr_u8(&self.bitstate.scheme.bits)),
                    ("bit_hist", self.bit_histogram()),
                ];
                if do_eval {
                    ev.push(("eval_acc", Json::Num(*report.eval_acc.last().unwrap() as f64)));
                    ev.push(("eval_loss", Json::Num(*report.eval_loss.last().unwrap() as f64)));
                }
                self.emit(Json::obj(ev))?;
            }
        }

        report.steps = step;
        report.final_bits = self.bitstate.scheme.bits.clone();
        report.final_compression = self.bitstate.compression();
        report.final_acc = report.eval_acc.last().copied().unwrap_or(0.0);
        report.total_seconds = timer.seconds();
        report.step_seconds_mean = step_time_acc / step.max(1) as f64;
        report.peak_rss_bytes = peak_rss_bytes().unwrap_or(0);
        self.emit(Json::obj(vec![
            ("event", Json::Str("run_end".into())),
            ("steps", Json::Num(report.steps as f64)),
            ("final_compression", Json::Num(report.final_compression)),
            ("final_acc", Json::Num(report.final_acc as f64)),
            ("best_acc", Json::Num(report.best_acc as f64)),
            ("total_seconds", Json::Num(report.total_seconds)),
            ("step_seconds_mean", Json::Num(report.step_seconds_mean)),
            ("peak_rss_bytes", Json::Num(report.peak_rss_bytes as f64)),
        ]))?;
        if let Some(t) = self.telemetry.as_mut() {
            t.flush()?;
        }
        Ok(report)
    }

    /// One pruning round: stats → Ω → ascending-β prune → p reassignment.
    fn prune_round(
        &mut self,
        epoch: usize,
        hess: &mut HessianEstimator,
        hess_batcher: &mut Batcher,
        report: &mut RunReport,
    ) -> Result<()> {
        let cfg = self.cfg.clone();
        if !self.backend.supports_stats() {
            return Ok(());
        }
        let bits = self.bitstate.bits_f32();
        let ks = self.bitstate.ks_f32();
        let stats = self.backend.stats_step(&bits, &ks)?;
        let (beta, qerr) = (stats.beta, stats.qerr);

        // Hessian trace → Ω (or uniform Ω when the ablation disables it)
        let om = if cfg.use_hessian && self.backend.supports_hessian() {
            let tr = hess.trace(&mut self.backend, hess_batcher)?;
            omega(&tr, &qerr)
        } else {
            vec![1.0; beta.len()]
        };

        let bits_before = self.bitstate.scheme.bits.clone();
        // ascending-β order; prune while β < α and γ < Γ (lines 19..27)
        let mut order: Vec<usize> = (0..beta.len()).collect();
        order.sort_by(|&a, &b| beta[a].partial_cmp(&beta[b]).unwrap());
        for &l in &order {
            if self.bitstate.compression() >= cfg.gamma {
                break;
            }
            if beta[l] < cfg.alpha && self.bitstate.prunable(l) {
                self.bitstate.prune_layer(l);
            }
        }
        // Hessian-aware prune-width reassignment for the *next* round
        if cfg.use_hessian {
            self.bitstate.assign_prune_bits(&om);
        } else {
            self.bitstate.reset_prune_bits();
        }

        let event = PruneEvent {
            epoch,
            beta,
            omega: om,
            bits_before,
            bits_after: self.bitstate.scheme.bits.clone(),
            prune_bits: self.bitstate.prune_bits.clone(),
            compression: self.bitstate.compression(),
        };
        self.emit(Json::obj(vec![
            ("event", Json::Str("prune".into())),
            ("epoch", Json::Num(epoch as f64)),
            ("beta", arr_f32(&event.beta)),
            ("omega", arr_f32(&event.omega)),
            ("bits_before", arr_u8(&event.bits_before)),
            ("bits_after", arr_u8(&event.bits_after)),
            ("prune_bits", arr_u8(&event.prune_bits)),
            ("compression", Json::Num(event.compression)),
        ]))?;
        // per-layer quantization error measured at this round's bit
        // widths — the trainer-side half of the quant-health telemetry
        // (`msq report` renders these as a qerr trajectory table)
        self.emit(Json::obj(vec![
            ("event", Json::Str("quant_error".into())),
            ("epoch", Json::Num(epoch as f64)),
            ("qerr", arr_f32(&qerr)),
            ("bits", arr_u8(&event.bits_before)),
        ]))?;
        if cfg.verbose {
            println!("[{}_{}] {}", cfg.model, cfg.method, event.summary());
        }
        report.prune_events.push(event);
        Ok(())
    }

    /// Export the trained model as a physically bit-packed `.msqpack`
    /// (v3, or v4 when the backend's export layout interleaves
    /// transformer records — see [`Backend::export_records`])
    /// (realizes the reported compression as actual bytes; the packed file
    /// re-imports through [`crate::quant::pack::PackedModel::load`] +
    /// [`Backend::set_q_weights`] and serves through `serve::registry`).
    /// Each layer record is stamped with the backend's op descriptor and
    /// fused-ReLU flag, and the header carries the spatial input shape
    /// when the backend has one — so conv models deploy with zero flags.
    pub fn export_packed(&self, path: &std::path::Path) -> Result<crate::quant::pack::PackedModel> {
        let mut model = crate::quant::pack::PackedModel {
            // flattened input width — lets serving infer the topology
            // from the header alone (no --input-dim at deploy time)
            input_dim: self.backend.input_elems(),
            input_hwc: self.backend.input_shape(),
            ..Default::default()
        };
        use crate::runtime::backend::ExportRecord;
        let records = self.backend.export_records().unwrap_or_else(|| {
            (0..self.backend.num_q_layers())
                .map(|q| ExportRecord::Quantized { q, gelu: false })
                .collect()
        });
        for rec in records {
            match rec {
                ExportRecord::Quantized { q, gelu } => {
                    let w = self.backend.q_weights(q)?;
                    let bits = self.bitstate.scheme.bits[q];
                    let mut layer = crate::quant::pack::pack_layer(
                        &self.backend.q_layer_name(q),
                        &w,
                        bits,
                    );
                    layer.op = self.backend.q_layer_op(q);
                    layer.relu = self.backend.q_layer_relu(q);
                    layer.gelu = gelu;
                    model.layers.push(layer);
                }
                ExportRecord::Structural(layer) => model.layers.push(layer),
            }
        }
        model.save(path)?;
        Ok(model)
    }

    /// Full test-split evaluation: (top-1 acc, mean ce).
    pub fn evaluate(&mut self, ds: &Dataset) -> Result<(f32, f32)> {
        let batch = self.backend.eval_batch();
        let bits = self.bitstate.bits_f32();
        let n = ds.test_y.len();
        if n % batch != 0 {
            bail!("test split ({n}) must be divisible by eval batch ({batch})");
        }
        let helper = Batcher::new(ds, batch, 0, false);
        let mut correct = 0f64;
        let mut loss = 0f64;
        for tb in helper.test_batches(batch) {
            let (ce_sum, corr) =
                self.backend.eval_step(&bits, self.cfg.n_act, &tb.x, &tb.y)?;
            correct += corr as f64;
            loss += ce_sum as f64;
        }
        Ok(((correct / n as f64) as f32, (loss / n as f64) as f32))
    }
}
