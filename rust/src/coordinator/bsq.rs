//! BSQ baseline (S7): explicit bit-split training (Yang et al. 2021).
//!
//! Every quantized layer's weight is N0 trainable bit-planes (+ frozen
//! sign + per-layer scale) — 8× the trainable parameters of MSQ, which is
//! exactly the overhead Table 1 / Fig. 6 measure. Training induces
//! bit-level sparsity with an L1 regularizer on the *rounded* plane
//! values; every interval the LSB-most active plane of a layer is hard-
//! pruned (deactivated via the runtime `bits` mask) when its nonzero rate
//! falls below α. Re-quantization after pruning is implicit (remaining
//! planes keep training).

use anyhow::Result;

use super::bitstate::BitState;
use super::report::{PruneEvent, RunReport};
use super::schedule::cosine_lr;
use super::trainer::MsqConfig;
use crate::data::{Batcher, Dataset};
use crate::runtime::{engine, ArtifactMeta, Engine, ModelState};
use crate::util::timer::{peak_rss_bytes, Timer};

pub const N0: usize = 8;

pub struct BsqTrainer<'e> {
    pub eng: &'e Engine,
    pub cfg: MsqConfig,
    pub train_meta: ArtifactMeta,
    pub eval_meta: ArtifactMeta,
    pub stats_meta: ArtifactMeta,
    pub state: ModelState,
    pub bitstate: BitState,
    method: &'static str,
}

impl<'e> BsqTrainer<'e> {
    pub fn new(eng: &'e Engine, cfg: MsqConfig) -> Result<BsqTrainer<'e>> {
        Self::with_method(eng, cfg, "bsq")
    }

    pub(crate) fn with_method(
        eng: &'e Engine,
        cfg: MsqConfig,
        method: &'static str,
    ) -> Result<BsqTrainer<'e>> {
        let train_meta = eng
            .manifest
            .find_batch(&cfg.model, method, "train", cfg.batch)
            .or_else(|_| eng.manifest.find(&cfg.model, method, "train"))?
            .clone();
        let eval_meta = eng.manifest.find(&cfg.model, method, "eval")?.clone();
        let stats_meta = eng.manifest.find(&cfg.model, method, "stats")?.clone();
        let state = ModelState::init(&eng.manifest, &train_meta)?;
        let bitstate = BitState::new(cfg.n0, &train_meta.q_sizes());
        Ok(BsqTrainer { eng, cfg, train_meta, eval_meta, stats_meta, state, bitstate, method })
    }

    /// CSQ temperature for this step (1.0 for plain BSQ).
    fn temperature(&self, step: usize, total: usize) -> f32 {
        if self.method == "csq" {
            super::schedule::csq_temperature(step, total, 100.0)
        } else {
            1.0
        }
    }

    pub fn run(&mut self, ds: &Dataset) -> Result<RunReport> {
        let cfg = self.cfg.clone();
        let timer = Timer::start();
        let mut report = RunReport {
            label: format!("{}_{}", cfg.model, self.method),
            model: cfg.model.clone(),
            method: self.method.into(),
            epochs: cfg.epochs,
            trainable_params: self.state.trainable_params(),
            ..Default::default()
        };
        let batch = self.train_meta.batch;
        let mut batcher = Batcher::new(ds, batch, cfg.seed, true);
        let steps_per_epoch = batcher.batches_per_epoch();
        let total_steps = steps_per_epoch * cfg.epochs;
        let img = self.train_meta.image.clone();
        let mut gamma_reached = false;
        let mut lam = cfg.lam;
        let mut step = 0usize;
        let mut step_time = 0f64;

        for epoch in 0..cfg.epochs {
            let bits_l = self.bitstate.bits_literal()?;
            let ks_l = self.bitstate.ks_literal()?; // unused by graph semantics, same shape
            let mut ep_loss = 0f64;
            let mut ep_corr = 0f64;
            for _ in 0..steps_per_epoch {
                let b = batcher.next();
                let x = engine::lit_f32(&b.x, &[batch, img[0], img[1], img[2]])?;
                let y = engine::lit_i32(&b.y, &[batch])?;
                let lr = cosine_lr(cfg.lr0, step, total_steps, 0.05, 0.0);
                let temp = self.temperature(step, total_steps);
                let st = Timer::start();
                let (loss, _ce, corr) = self.state.train_step(
                    self.eng,
                    &self.train_meta.clone(),
                    &bits_l,
                    &ks_l,
                    lam,
                    lr,
                    temp,
                    cfg.n_act,
                    &x,
                    &y,
                )?;
                step_time += st.seconds();
                ep_loss += loss as f64;
                ep_corr += corr as f64;
                step += 1;
            }
            report.train_loss.push((ep_loss / steps_per_epoch as f64) as f32);
            report.train_acc.push((ep_corr / (steps_per_epoch * batch) as f64) as f32);

            let due = cfg.interval > 0 && (epoch + 1) % cfg.interval == 0;
            if due && !gamma_reached && cfg.gamma > 0.0 {
                self.prune_round(epoch, step, total_steps, &mut report)?;
                if self.bitstate.compression() >= cfg.gamma {
                    gamma_reached = true;
                    lam = 0.0;
                    report.gamma_reached_epoch = Some(epoch);
                }
            }

            let do_eval = (cfg.eval_every > 0 && (epoch + 1) % cfg.eval_every == 0)
                || epoch + 1 == cfg.epochs;
            if do_eval {
                let (eacc, eloss) = self.evaluate(ds)?;
                report.eval_epochs.push(epoch);
                report.eval_acc.push(eacc);
                report.eval_loss.push(eloss);
                report.best_acc = report.best_acc.max(eacc);
                if cfg.verbose {
                    println!(
                        "[{}] epoch {epoch:3} loss {:.4} eval-acc {:.3} comp {:.2}x",
                        report.label,
                        report.train_loss.last().unwrap(),
                        eacc,
                        self.bitstate.compression()
                    );
                }
            }
        }
        report.steps = step;
        report.final_bits = self.bitstate.scheme.bits.clone();
        report.final_compression = self.bitstate.compression();
        report.final_acc = report.eval_acc.last().copied().unwrap_or(0.0);
        report.total_seconds = timer.seconds();
        report.step_seconds_mean = step_time / step.max(1) as f64;
        report.peak_rss_bytes = peak_rss_bytes().unwrap_or(0);
        Ok(report)
    }

    /// Bit-plane pruning: deactivate a layer's lowest active plane when
    /// its nonzero rate < α (ascending-rate order, stop at Γ).
    fn prune_round(
        &mut self,
        epoch: usize,
        step: usize,
        total_steps: usize,
        report: &mut RunReport,
    ) -> Result<()> {
        let cfg = &self.cfg;
        let bits_l = self.bitstate.bits_literal()?;
        let temp = self.temperature(step, total_steps);
        let plane_nz =
            self.state.plane_stats_step(self.eng, &self.stats_meta, &bits_l, temp)?; // (Lq*N0)
        let lq = self.bitstate.num_layers();
        // per-layer rate of the lowest ACTIVE plane
        let mut lsb_rate = vec![1f32; lq];
        for l in 0..lq {
            let active = self.bitstate.scheme.bits[l] as usize;
            if active > 0 {
                lsb_rate[l] = plane_nz[l * N0 + (active - 1)];
            }
        }
        let bits_before = self.bitstate.scheme.bits.clone();
        let mut order: Vec<usize> = (0..lq).collect();
        order.sort_by(|&a, &b| lsb_rate[a].partial_cmp(&lsb_rate[b]).unwrap());
        for &l in &order {
            if self.bitstate.compression() >= cfg.gamma {
                break;
            }
            if lsb_rate[l] < cfg.alpha && self.bitstate.prunable(l) {
                self.bitstate.scheme.prune(l, 1);
            }
        }
        report.prune_events.push(PruneEvent {
            epoch,
            beta: lsb_rate,
            omega: vec![0.0; lq],
            bits_before,
            bits_after: self.bitstate.scheme.bits.clone(),
            prune_bits: vec![1; lq],
            compression: self.bitstate.compression(),
        });
        Ok(())
    }

    pub fn evaluate(&self, ds: &Dataset) -> Result<(f32, f32)> {
        let meta = self.eval_meta.clone();
        let batch = meta.batch;
        let bits_l = self.bitstate.bits_literal()?;
        let n = ds.test_y.len();
        anyhow::ensure!(n % batch == 0, "test split not divisible by eval batch");
        let img = &meta.image;
        let helper = Batcher::new(ds, batch, 0, false);
        let mut correct = 0f64;
        let mut loss = 0f64;
        for tb in helper.test_batches(batch) {
            let x = engine::lit_f32(&tb.x, &[batch, img[0], img[1], img[2]])?;
            let y = engine::lit_i32(&tb.y, &[batch])?;
            let (ce_sum, corr) =
                self.state.eval_step(self.eng, &meta, &bits_l, 1.0, self.cfg.n_act, &x, &y)?;
            correct += corr as f64;
            loss += ce_sum as f64;
        }
        Ok(((correct / n as f64) as f32, (loss / n as f64) as f32))
    }
}
