//! Hessian scheduler (S6): Hutchinson trace probes → per-layer Ω (Eq. 9).
//!
//! `Tr(H_l)` is estimated with Rademacher probes through
//! [`Backend::hessian_step`] (one hvp per probe, per-layer vᵀHv read
//! back — an AOT artifact on the XLA path, a finite-difference hvp on
//! the native path); the coordinator multiplies by the layer's
//! quantization error ‖W_n − W‖² (from `stats_step`) to form Ω_l.
//! Probes are drawn on fresh training batches, matching HAWQ-V2
//! practice.

use anyhow::Result;

use crate::data::Batcher;
use crate::runtime::backend::Backend;
use crate::util::prng::Rng;

pub struct HessianEstimator {
    pub probes: usize,
    rng: Rng,
}

impl HessianEstimator {
    pub fn new(probes: usize, seed: u64) -> Self {
        HessianEstimator { probes, rng: Rng::new(seed ^ 0x4E55_1A4) }
    }

    /// Per-layer Hessian-trace estimates (mean of vᵀHv over probes).
    pub fn trace<B: Backend>(
        &mut self,
        backend: &mut B,
        batcher: &mut Batcher,
    ) -> Result<Vec<f32>> {
        let lq = backend.num_q_layers();
        let mut acc = vec![0f64; lq];
        let b = backend.hess_batch();
        let elems = backend.input_elems();
        for _ in 0..self.probes {
            // a fresh batch per probe; the backend's hessian batch may be
            // smaller than the train batch — truncate deterministically.
            let batch = batcher.next();
            let seed = self.rng.next_u64();
            let vhv = backend.hessian_step(&batch.x[..b * elems], &batch.y[..b], seed)?;
            for (a, v) in acc.iter_mut().zip(&vhv) {
                *a += *v as f64;
            }
        }
        Ok(acc.into_iter().map(|a| (a / self.probes.max(1) as f64) as f32).collect())
    }
}

/// Ω_l = Tr(H_l) · ‖W_n − W‖² (paper Eq. 9). `qerr` comes from
/// `stats_step` under the *current* precision, so Ω tracks the scheme as
/// it evolves (paper Fig. 5a→5b).
pub fn omega(trace: &[f32], qerr: &[f32]) -> Vec<f32> {
    trace.iter().zip(qerr).map(|(&t, &e)| (t.max(0.0)) * e).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn omega_formula() {
        let o = omega(&[2.0, -1.0, 4.0], &[0.5, 3.0, 0.25]);
        assert_eq!(o, vec![1.0, 0.0, 1.0]); // negative traces clamped
    }
}
