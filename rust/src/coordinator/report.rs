//! Run report: everything the paper's tables/figures need from one
//! training run, serializable to JSON under `results/`.

use std::path::Path;

use anyhow::Result;

use crate::util::json::Json;

/// One pruning event (feeds Fig. 5/7/9 and supp Fig. 1).
#[derive(Clone, Debug)]
pub struct PruneEvent {
    pub epoch: usize,
    pub beta: Vec<f32>,
    pub omega: Vec<f32>,
    pub bits_before: Vec<u8>,
    pub bits_after: Vec<u8>,
    pub prune_bits: Vec<u8>,
    pub compression: f64,
}

impl PruneEvent {
    /// One-line human-readable form for the training log.
    pub fn summary(&self) -> String {
        let beta: Vec<String> = self.beta.iter().map(|b| format!("{b:.2}")).collect();
        format!(
            "prune @ epoch {}: β [{}] bits {:?} -> {:?} (p {:?}) comp {:.2}x",
            self.epoch,
            beta.join(" "),
            self.bits_before,
            self.bits_after,
            self.prune_bits,
            self.compression
        )
    }
}

/// Full history of one run.
#[derive(Clone, Debug, Default)]
pub struct RunReport {
    pub label: String,
    pub model: String,
    pub method: String,
    pub epochs: usize,
    pub steps: usize,
    pub train_loss: Vec<f32>,
    pub train_acc: Vec<f32>,
    pub eval_epochs: Vec<usize>,
    pub eval_acc: Vec<f32>,
    pub eval_loss: Vec<f32>,
    pub prune_events: Vec<PruneEvent>,
    pub final_bits: Vec<u8>,
    pub final_compression: f64,
    pub final_acc: f32,
    pub best_acc: f32,
    pub trainable_params: usize,
    pub total_seconds: f64,
    pub step_seconds_mean: f64,
    pub peak_rss_bytes: u64,
    pub gamma_reached_epoch: Option<usize>,
}

impl RunReport {
    pub fn to_json(&self) -> Json {
        let events: Vec<Json> = self
            .prune_events
            .iter()
            .map(|e| {
                Json::obj(vec![
                    ("epoch", Json::Num(e.epoch as f64)),
                    ("beta", Json::arr_f32(&e.beta)),
                    ("omega", Json::arr_f32(&e.omega)),
                    (
                        "bits_before",
                        Json::Arr(e.bits_before.iter().map(|&b| Json::Num(b as f64)).collect()),
                    ),
                    (
                        "bits_after",
                        Json::Arr(e.bits_after.iter().map(|&b| Json::Num(b as f64)).collect()),
                    ),
                    (
                        "prune_bits",
                        Json::Arr(e.prune_bits.iter().map(|&b| Json::Num(b as f64)).collect()),
                    ),
                    ("compression", Json::Num(e.compression)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("label", Json::Str(self.label.clone())),
            ("model", Json::Str(self.model.clone())),
            ("method", Json::Str(self.method.clone())),
            ("epochs", Json::Num(self.epochs as f64)),
            ("steps", Json::Num(self.steps as f64)),
            ("train_loss", Json::arr_f32(&self.train_loss)),
            ("train_acc", Json::arr_f32(&self.train_acc)),
            (
                "eval_epochs",
                Json::Arr(self.eval_epochs.iter().map(|&e| Json::Num(e as f64)).collect()),
            ),
            ("eval_acc", Json::arr_f32(&self.eval_acc)),
            ("eval_loss", Json::arr_f32(&self.eval_loss)),
            ("prune_events", Json::Arr(events)),
            (
                "final_bits",
                Json::Arr(self.final_bits.iter().map(|&b| Json::Num(b as f64)).collect()),
            ),
            ("final_compression", Json::Num(self.final_compression)),
            ("final_acc", Json::Num(self.final_acc as f64)),
            ("best_acc", Json::Num(self.best_acc as f64)),
            ("trainable_params", Json::Num(self.trainable_params as f64)),
            ("total_seconds", Json::Num(self.total_seconds)),
            ("step_seconds_mean", Json::Num(self.step_seconds_mean)),
            ("peak_rss_bytes", Json::Num(self.peak_rss_bytes as f64)),
            (
                "gamma_reached_epoch",
                self.gamma_reached_epoch.map(|e| Json::Num(e as f64)).unwrap_or(Json::Null),
            ),
        ])
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }
}
