//! Learning-rate and temperature schedules.
//!
//! The paper uses SGD with a warm-start cosine annealing schedule
//! (Sec. 4.1); CSQ additionally anneals a continuous-sparsification
//! temperature. Both live here, host-side — lr/temp are runtime scalars
//! fed to the artifacts each step.

/// Warm-start cosine: linear warmup over `warmup` fraction of training,
/// then cosine decay from `lr0` to `lr0 * floor_frac`.
pub fn cosine_lr(lr0: f32, step: usize, total_steps: usize, warmup_frac: f32, floor_frac: f32) -> f32 {
    let total = total_steps.max(1) as f32;
    let warm = (warmup_frac * total).max(1.0);
    let s = step as f32;
    if s < warm {
        return lr0 * (s + 1.0) / warm;
    }
    let t = ((s - warm) / (total - warm).max(1.0)).clamp(0.0, 1.0);
    let cos = 0.5 * (1.0 + (std::f32::consts::PI * t).cos());
    lr0 * (floor_frac + (1.0 - floor_frac) * cos)
}

/// CSQ temperature: exponential ramp 1 → t_max over training (continuous
/// sparsification; gates harden as T grows).
pub fn csq_temperature(step: usize, total_steps: usize, t_max: f32) -> f32 {
    let t = (step as f32 / total_steps.max(1) as f32).clamp(0.0, 1.0);
    t_max.powf(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_then_decay() {
        let lr0 = 0.1;
        let total = 1000;
        // warming up
        assert!(cosine_lr(lr0, 0, total, 0.05, 0.0) < lr0 * 0.1);
        // peak right after warmup
        let peak = cosine_lr(lr0, 50, total, 0.05, 0.0);
        assert!(peak > 0.95 * lr0, "{peak}");
        // decayed at the end
        let tail = cosine_lr(lr0, 999, total, 0.05, 0.0);
        assert!(tail < 0.01 * lr0, "{tail}");
        // monotone decreasing after warmup
        let a = cosine_lr(lr0, 200, total, 0.05, 0.0);
        let b = cosine_lr(lr0, 600, total, 0.05, 0.0);
        assert!(a > b);
    }

    #[test]
    fn floor_respected() {
        let tail = cosine_lr(0.1, 1000, 1000, 0.0, 0.1);
        assert!(tail >= 0.01 - 1e-6);
    }

    #[test]
    fn temperature_ramps() {
        assert!((csq_temperature(0, 100, 100.0) - 1.0).abs() < 1e-5);
        assert!((csq_temperature(100, 100, 100.0) - 100.0).abs() < 1e-3);
        assert!(csq_temperature(50, 100, 100.0) > 5.0);
    }
}
