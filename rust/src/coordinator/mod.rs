//! L3 coordinator (S11): the paper's training system.
//!
//! `Trainer` drives Algorithm 1 end-to-end against any execution
//! [`Backend`](crate::runtime::backend::Backend) — the pure-Rust native
//! backend by default, the AOT/PJRT artifacts with `--features pjrt`:
//!
//! 1. every step: `train_step` with `L = CE + λ·Σ|B_k|` (λ, lr, per-layer
//!    bits/ks all runtime inputs);
//! 2. every pruning interval `I` (while compression γ < target Γ):
//!    * `stats_step` → per-layer LSB-nonzero rate β_l;
//!    * Hutchinson probes → Tr(H_l); Ω_l = Tr(H_l)·‖W_n−W‖² (Eq. 9);
//!    * prune layers with β_l < α by p_l bits, ascending-β order, stopping
//!      as soon as γ ≥ Γ (final-round sorted pruning);
//!    * reassign p_l ∈ {1,2} by Ω_l vs mean(Ω) (Hessian-aware aggressive
//!      pruning — skipped when `use_hessian = false` for the Fig. 7/8
//!      ablation);
//! 3. once γ ≥ Γ: λ := 0, pruning stops, training continues as plain QAT.
//!
//! The BSQ and CSQ baselines (`bsq.rs`, `csq.rs`) run the same loop shape
//! over their bit-split artifacts with their own pruning policies.

pub mod bitstate;
#[cfg(feature = "pjrt")]
pub mod bsq;
#[cfg(feature = "pjrt")]
pub mod csq;
pub mod hessian;
pub mod report;
pub mod schedule;
pub mod trainer;

pub use bitstate::BitState;
pub use report::{PruneEvent, RunReport};
pub use schedule::{cosine_lr, csq_temperature};
pub use trainer::{MsqConfig, Trainer};
