//! Per-layer bit-state manager: the mutable mixed-precision scheme.
//!
//! Owns `q_l` (current bit-width) and `p_l` (prune width, the Hessian-
//! assigned `k` of the bipartite slice) per quantized layer, and renders
//! them as the `bits` / `ks` runtime literals the artifacts consume.

#[cfg(feature = "pjrt")]
use anyhow::Result;

use crate::quant::compression::BitScheme;
#[cfg(feature = "pjrt")]
use crate::runtime::engine;

#[derive(Clone, Debug)]
pub struct BitState {
    pub scheme: BitScheme,
    /// prune width p_l per layer (1 or 2; the `k` fed to the LSB slice)
    pub prune_bits: Vec<u8>,
    /// floor: layers never drop below this width
    pub min_bits: u8,
}

impl BitState {
    pub fn new(n0: u8, sizes: &[usize]) -> BitState {
        BitState {
            scheme: BitScheme::uniform(n0, sizes),
            prune_bits: vec![1; sizes.len()],
            min_bits: 1,
        }
    }

    pub fn num_layers(&self) -> usize {
        self.scheme.num_layers()
    }

    pub fn bits_f32(&self) -> Vec<f32> {
        self.scheme.bits.iter().map(|&b| b as f32).collect()
    }

    /// ks for the LSB slice, clamped so n - k >= min_bits.
    pub fn ks_f32(&self) -> Vec<f32> {
        self.scheme
            .bits
            .iter()
            .zip(&self.prune_bits)
            .map(|(&b, &p)| p.min(b.saturating_sub(self.min_bits)).max(1) as f32)
            .collect()
    }

    #[cfg(feature = "pjrt")]
    pub fn bits_literal(&self) -> Result<xla::Literal> {
        let v = self.bits_f32();
        engine::lit_f32(&v, &[v.len()])
    }

    #[cfg(feature = "pjrt")]
    pub fn ks_literal(&self) -> Result<xla::Literal> {
        let v = self.ks_f32();
        engine::lit_f32(&v, &[v.len()])
    }

    pub fn compression(&self) -> f64 {
        self.scheme.compression()
    }

    /// Can layer `l` still be pruned by its prune width?
    pub fn prunable(&self, l: usize) -> bool {
        self.scheme.bits[l] > self.min_bits
    }

    /// Prune layer `l` by its assigned width; returns bits removed.
    pub fn prune_layer(&mut self, l: usize) -> u8 {
        let before = self.scheme.bits[l];
        let k = self.prune_bits[l].min(before.saturating_sub(self.min_bits));
        if k == 0 {
            return 0;
        }
        self.scheme.prune(l, k);
        before - self.scheme.bits[l]
    }

    /// Hessian-aware prune-width assignment (paper Sec. 3.2): layers with
    /// Ω below the mean get p = 2, the rest p = 1.
    pub fn assign_prune_bits(&mut self, omega: &[f32]) {
        let mean = omega.iter().copied().sum::<f32>() / omega.len().max(1) as f32;
        for (p, &o) in self.prune_bits.iter_mut().zip(omega) {
            *p = if o < mean { 2 } else { 1 };
        }
    }

    pub fn reset_prune_bits(&mut self) {
        self.prune_bits.iter_mut().for_each(|p| *p = 1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn initial_state() {
        let s = BitState::new(8, &[100, 200, 300]);
        assert_eq!(s.bits_f32(), vec![8.0, 8.0, 8.0]);
        assert_eq!(s.ks_f32(), vec![1.0, 1.0, 1.0]);
        assert!((s.compression() - 4.0).abs() < 1e-9);
    }

    #[test]
    fn prune_respects_floor() {
        let mut s = BitState::new(2, &[10]);
        s.prune_bits[0] = 2;
        let removed = s.prune_layer(0);
        assert_eq!(removed, 1); // floor at 1 bit
        assert_eq!(s.scheme.bits[0], 1);
        assert_eq!(s.prune_layer(0), 0);
    }

    #[test]
    fn hessian_assignment() {
        let mut s = BitState::new(8, &[10, 10, 10]);
        s.assign_prune_bits(&[1.0, 5.0, 0.5]); // mean = 2.1667
        assert_eq!(s.prune_bits, vec![2, 1, 2]);
    }

    #[test]
    fn ks_never_exceed_headroom() {
        let mut s = BitState::new(3, &[10]);
        s.prune_bits[0] = 2;
        assert_eq!(s.ks_f32(), vec![2.0]);
        s.scheme.bits[0] = 2;
        assert_eq!(s.ks_f32(), vec![1.0]); // only 1 bit of headroom above floor
    }
}
