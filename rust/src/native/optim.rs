//! SGD with momentum for the native backend.
//!
//! The learning-rate *schedule* (warm-start cosine, paper Sec. 4.1) is
//! the trainer's job — `coordinator::schedule::cosine_lr` computes the
//! per-step lr and passes it down through `Backend::train_step`, exactly
//! as the XLA path feeds lr as a runtime scalar. This module owns the
//! parameter update itself: classic heavy-ball momentum
//! `v ← μ·v + g; θ ← θ − lr·v`, matching the artifacts' SGD.

#[derive(Clone, Copy, Debug)]
pub struct SgdMomentum {
    pub momentum: f32,
    pub weight_decay: f32,
}

impl Default for SgdMomentum {
    fn default() -> Self {
        SgdMomentum { momentum: 0.9, weight_decay: 0.0 }
    }
}

impl SgdMomentum {
    /// One parameter update; `v` is the persistent momentum buffer.
    pub fn step(&self, w: &mut [f32], g: &[f32], v: &mut [f32], lr: f32) {
        debug_assert_eq!(w.len(), g.len());
        debug_assert_eq!(w.len(), v.len());
        let mu = self.momentum;
        let wd = self.weight_decay;
        for ((wi, &gi), vi) in w.iter_mut().zip(g).zip(v.iter_mut()) {
            let grad = gi + wd * *wi;
            *vi = mu * *vi + grad;
            *wi -= lr * *vi;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plain_sgd_when_momentum_zero() {
        let opt = SgdMomentum { momentum: 0.0, weight_decay: 0.0 };
        let mut w = vec![1.0f32, -1.0];
        let mut v = vec![0f32; 2];
        opt.step(&mut w, &[0.5, -0.5], &mut v, 0.1);
        assert!((w[0] - 0.95).abs() < 1e-6);
        assert!((w[1] + 0.95).abs() < 1e-6);
    }

    #[test]
    fn momentum_accumulates() {
        let opt = SgdMomentum { momentum: 0.9, weight_decay: 0.0 };
        let mut w = vec![0f32];
        let mut v = vec![0f32];
        opt.step(&mut w, &[1.0], &mut v, 1.0); // v=1, w=-1
        opt.step(&mut w, &[1.0], &mut v, 1.0); // v=1.9, w=-2.9
        assert!((w[0] + 2.9).abs() < 1e-6);
        assert!((v[0] - 1.9).abs() < 1e-6);
    }

    #[test]
    fn descends_a_quadratic() {
        // f(w) = 0.5·w², g = w — momentum SGD must converge to 0
        let opt = SgdMomentum { momentum: 0.9, weight_decay: 0.0 };
        let mut w = vec![5.0f32];
        let mut v = vec![0f32];
        for _ in 0..200 {
            let g = [w[0]];
            opt.step(&mut w, &g, &mut v, 0.05);
        }
        assert!(w[0].abs() < 1e-2, "w = {}", w[0]);
    }
}
