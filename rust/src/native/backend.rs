//! [`NativeBackend`]: the pure-Rust implementation of
//! [`runtime::backend::Backend`] — Algorithm 1 with zero XLA linkage.
//!
//! Models are quantized MLPs, small conv nets, or pre-norm ViTs over
//! the synthetic images (the shape families `msq serve` executes —
//! see [`Topology`]): every layer's
//! weights pass through the RoundClamp (or DoReFa) fake-quant STE at
//! that layer's *runtime* bit-width before the matmul/conv, exactly like
//! the AOT graphs treat `bits` as an input tensor. Conv layers run NHWC
//! activations against OHWI filters — the `.msqpack` v3 layout — so the
//! export is byte-faithful to what the serving kernels execute. Biases
//! stay float and frozen at zero (see `ParamLayer`). When `n_act > 0`,
//! hidden activations are fake-quantized the same way after ReLU.
//!
//! Hutchinson probes (`hessian_step`) use the finite-difference
//! Hessian-vector product `Hv ≈ (∇L(θ+εv) − ∇L(θ−εv)) / 2ε` on the
//! *float* network — the same contract as the AOT hessian artifact,
//! which also takes only params + batch (no bits).

use anyhow::{bail, ensure, Result};

use super::autograd::{NodeId, Tape};
use super::ops::{self, Quantizer};
use super::optim::SgdMomentum;
use super::tensor::Tensor;
use crate::quant::pack::{AttnDesc, Conv2dDesc, LayerOp, PackedLayer};
use crate::quant::{lsb_proxy_dorefa, lsb_proxy_roundclamp, to_unit};
use crate::runtime::backend::{Backend, ExportRecord, LayerStats, StepStats};
use crate::util::prng::Rng;
use crate::util::threadpool::ThreadPool;

/// How a parameter layer executes (the native twin of [`LayerOp`]).
#[derive(Clone, Copy, Debug)]
enum ParamOp {
    /// Dense matmul: `out × in` weights (the pack/serve layout).
    Dense,
    /// NHWC conv over an `in_h × in_w` map: `out_ch × kh·kw·in_ch`
    /// weights (OHWI, the pack v3 conv layout).
    Conv { d: Conv2dDesc, in_h: usize, in_w: usize },
}

/// How the parameter layers compose into a forward graph.
#[derive(Clone, Copy, Debug)]
enum Topology {
    /// The classic sequential stack: layer → ReLU → layer → … → head.
    Chain,
    /// Pre-norm ViT over `seq` tokens of `token_dim` features: linear
    /// embed to `dim`, `depth` blocks of
    /// LN → MHA(`heads`) → +res → LN → GELU-MLP(2·dim) → +res, final
    /// LN, mean-pool, linear head. Parameter layers sit flat in
    /// quantized-export order: embed, per block wq/wk/wv/wproj/fc1/fc2,
    /// head.
    Vit { seq: usize, token_dim: usize, dim: usize, heads: usize, depth: usize },
}

/// One parameter layer: weights, a zero bias, the weight momentum
/// buffer, and its op.
///
/// Biases are **fixed at zero** by design: the `.msqpack` format and
/// the serve executor run bias-free layers, so training biases would
/// silently diverge the exported artifact (where they'd be dropped)
/// from the accuracy the trainer reports. The tape still threads a
/// bias node through every op so the backward stays covered.
struct ParamLayer {
    name: String,
    w: Tensor,
    b: Tensor,
    vw: Vec<f32>,
    op: ParamOp,
}

/// Per-layer `(dw, db)` gradient buffers.
type LayerGrads = Vec<(Vec<f32>, Vec<f32>)>;

pub struct NativeBackend {
    pub model: String,
    pub method: String,
    batch: usize,
    input_dim: usize,
    /// Spatial input shape for conv nets; (0, 0, 0) for flat MLPs.
    input_hwc: (usize, usize, usize),
    classes: usize,
    layers: Vec<ParamLayer>,
    topology: Topology,
    opt: SgdMomentum,
    pool: Option<ThreadPool>,
    quantizer: Quantizer,
}

fn quantizer_for(method: &str) -> Result<Quantizer> {
    match method {
        "msq" => Ok(Quantizer::RoundClamp),
        "dorefa" => Ok(Quantizer::DoReFa),
        _ => bail!("native backend trains msq/dorefa, got {method:?}"),
    }
}

impl NativeBackend {
    /// Quantized MLP `input_dim → hidden… → classes`, He-initialized
    /// from `seed`. `threads == 0` sizes the pool to the machine;
    /// `threads == 1` runs single-threaded (no pool).
    #[allow(clippy::too_many_arguments)]
    pub fn mlp(
        model: &str,
        method: &str,
        input_dim: usize,
        hidden: &[usize],
        classes: usize,
        batch: usize,
        seed: u64,
        threads: usize,
    ) -> Result<NativeBackend> {
        let quantizer = quantizer_for(method)?;
        ensure!(input_dim > 0 && classes > 1 && batch > 0, "bad mlp config");
        ensure!(hidden.iter().all(|&h| h > 0), "zero hidden width");
        let mut rng = Rng::new(seed);
        let mut dims = vec![input_dim];
        dims.extend_from_slice(hidden);
        dims.push(classes);
        let layers = (0..dims.len() - 1)
            .map(|l| {
                let (cin, cout) = (dims[l], dims[l + 1]);
                ParamLayer {
                    name: format!("fc{l}"),
                    w: Tensor::he_normal(cout, cin, &mut rng),
                    b: Tensor::zeros(1, cout),
                    vw: vec![0f32; cout * cin],
                    op: ParamOp::Dense,
                }
            })
            .collect();
        let threads = if threads == 0 { ThreadPool::default_size() } else { threads };
        let pool = (threads > 1).then(|| ThreadPool::new(threads));
        Ok(NativeBackend {
            model: model.to_string(),
            method: method.to_string(),
            batch,
            input_dim,
            input_hwc: (0, 0, 0),
            classes,
            layers,
            topology: Topology::Chain,
            opt: SgdMomentum::default(),
            pool,
            quantizer,
        })
    }

    /// Quantized conv net over `in_h × in_w × in_ch` NHWC images: each
    /// `channels[i-1] → channels[i]` stage is a 3×3 stride-2 pad-1 conv
    /// with ReLU (halving the map), then one linear head over the
    /// flattened final map — the `pack-synth --arch conv` shape family,
    /// so train → pack → serve works for conv end-to-end.
    #[allow(clippy::too_many_arguments)]
    pub fn conv_net(
        model: &str,
        method: &str,
        in_h: usize,
        in_w: usize,
        in_ch: usize,
        channels: &[usize],
        classes: usize,
        batch: usize,
        seed: u64,
        threads: usize,
    ) -> Result<NativeBackend> {
        let quantizer = quantizer_for(method)?;
        ensure!(
            in_h > 0 && in_w > 0 && in_ch > 0 && classes > 1 && batch > 0,
            "bad conv config"
        );
        ensure!(!channels.is_empty(), "conv net needs at least one conv stage");
        ensure!(channels.iter().all(|&c| c > 0), "zero channel width");
        let mut rng = Rng::new(seed);
        let mut layers = Vec::with_capacity(channels.len() + 1);
        let (mut h, mut w) = (in_h, in_w);
        let mut cin = in_ch;
        for (l, &cout) in channels.iter().enumerate() {
            let d = Conv2dDesc { in_ch: cin, out_ch: cout, kh: 3, kw: 3, stride: 2, pad: 1 };
            let (oh, ow) = d.out_hw(h, w)?;
            layers.push(ParamLayer {
                name: format!("conv{l}"),
                w: Tensor::he_normal(cout, d.filter_len(), &mut rng),
                b: Tensor::zeros(1, cout),
                vw: vec![0f32; cout * d.filter_len()],
                op: ParamOp::Conv { d, in_h: h, in_w: w },
            });
            (h, w) = (oh, ow);
            cin = cout;
        }
        let flat = h * w * cin;
        layers.push(ParamLayer {
            name: "fc".into(),
            w: Tensor::he_normal(classes, flat, &mut rng),
            b: Tensor::zeros(1, classes),
            vw: vec![0f32; classes * flat],
            op: ParamOp::Dense,
        });
        let threads = if threads == 0 { ThreadPool::default_size() } else { threads };
        let pool = (threads > 1).then(|| ThreadPool::new(threads));
        Ok(NativeBackend {
            model: model.to_string(),
            method: method.to_string(),
            batch,
            input_dim: in_h * in_w * in_ch,
            input_hwc: (in_h, in_w, in_ch),
            classes,
            layers,
            topology: Topology::Chain,
            opt: SgdMomentum::default(),
            pool,
            quantizer,
        })
    }

    /// Quantized pre-norm ViT over `seq` tokens of `token_dim` features
    /// (the flat input reshapes row-major — e.g. one token per image
    /// row): linear embed to `dim`, `depth` blocks of
    /// LN → MHA(`heads`) → +residual → LN → GELU-MLP(2·dim) → +residual,
    /// a final LN, mean-pool over tokens, and a linear head. Quantized
    /// layers in export order (embed, per block wq/wk/wv/wproj/fc1/fc2,
    /// head — `2 + 6·depth` total) with the exact record layout of
    /// `pack-synth --arch transformer` (see [`Backend::export_records`]),
    /// so train → pack → serve works for transformers end-to-end.
    #[allow(clippy::too_many_arguments)]
    pub fn vit(
        model: &str,
        method: &str,
        seq: usize,
        token_dim: usize,
        dim: usize,
        heads: usize,
        depth: usize,
        classes: usize,
        batch: usize,
        seed: u64,
        threads: usize,
    ) -> Result<NativeBackend> {
        let quantizer = quantizer_for(method)?;
        ensure!(
            seq > 0 && token_dim > 0 && dim > 0 && heads > 0 && depth > 0 && classes > 1
                && batch > 0,
            "bad vit config"
        );
        ensure!(dim % heads == 0, "vit: dim {dim} not divisible by {heads} heads");
        let hidden = 2 * dim;
        let mut rng = Rng::new(seed);
        let mut dense = |name: String, rows: usize, cols: usize| ParamLayer {
            name,
            w: Tensor::he_normal(rows, cols, &mut rng),
            b: Tensor::zeros(1, rows),
            vw: vec![0f32; rows * cols],
            op: ParamOp::Dense,
        };
        let mut layers = vec![dense("embed".into(), dim, token_dim)];
        for b in 0..depth {
            for w in ["wq", "wk", "wv", "wproj"] {
                layers.push(dense(format!("blk{b}.{w}"), dim, dim));
            }
            layers.push(dense(format!("blk{b}.fc1"), hidden, dim));
            layers.push(dense(format!("blk{b}.fc2"), dim, hidden));
        }
        layers.push(dense("head".into(), classes, dim));
        drop(dense);
        let threads = if threads == 0 { ThreadPool::default_size() } else { threads };
        let pool = (threads > 1).then(|| ThreadPool::new(threads));
        Ok(NativeBackend {
            model: model.to_string(),
            method: method.to_string(),
            batch,
            input_dim: seq * token_dim,
            input_hwc: (0, 0, 0),
            classes,
            layers,
            topology: Topology::Vit { seq, token_dim, dim, heads, depth },
            opt: SgdMomentum::default(),
            pool,
            quantizer,
        })
    }

    fn check_batch(&self, x: &[f32], y: &[i32]) -> Result<usize> {
        ensure!(
            !x.is_empty() && x.len() % self.input_dim == 0,
            "input length {} does not factor over input dim {}",
            x.len(),
            self.input_dim
        );
        let m = x.len() / self.input_dim;
        ensure!(y.len() == m, "{} labels for batch {m}", y.len());
        Ok(m)
    }

    /// Record the full forward graph on `tape` — the ONE statement of
    /// each topology, shared by training ([`Self::grads`]) and
    /// inference ([`Self::forward_logits`]) so the eval forward can
    /// never diverge from what the gradients were taken through.
    /// Returns the per-layer `(w, b)` leaves and the logits node.
    /// `bits` of `None` runs the float network (the Hessian-probe
    /// contract).
    fn build_graph(
        &self,
        tape: &mut Tape,
        bits: Option<&[f32]>,
        n_act: f32,
        x: &[f32],
        m: usize,
    ) -> (Vec<(NodeId, NodeId)>, NodeId) {
        let wids: Vec<(NodeId, NodeId)> = self
            .layers
            .iter()
            .map(|layer| (tape.leaf(layer.w.clone()), tape.leaf(layer.b.clone())))
            .collect();
        let weff: Vec<NodeId> = wids
            .iter()
            .enumerate()
            .map(|(l, &(w, _))| match bits {
                Some(bits) => tape.quant_ste(w, bits[l], self.quantizer),
                None => w,
            })
            .collect();
        let x0 = tape.leaf(Tensor::from_vec(m, self.input_dim, x.to_vec()));
        let last = self.layers.len() - 1;
        let logits = match self.topology {
            Topology::Chain => {
                let mut h = x0;
                for (l, layer) in self.layers.iter().enumerate() {
                    h = match layer.op {
                        ParamOp::Dense => tape.linear(h, weff[l], wids[l].1),
                        ParamOp::Conv { d, in_h, in_w } => {
                            tape.conv2d(h, weff[l], wids[l].1, d, in_h, in_w)
                        }
                    };
                    if l < last {
                        h = tape.relu(h);
                        if bits.is_some() && n_act > 0.0 {
                            h = tape.quant_ste(h, n_act, self.quantizer);
                        }
                    }
                }
                h
            }
            Topology::Vit { seq, token_dim, dim, heads, depth } => {
                let tokens = tape.reshape(x0, m * seq, token_dim);
                let mut h = tape.linear(tokens, weff[0], wids[0].1);
                for b in 0..depth {
                    let base = 1 + 6 * b; // this block's wq
                    let n1 = tape.layer_norm(h);
                    let qn = tape.linear(n1, weff[base], wids[base].1);
                    let kn = tape.linear(n1, weff[base + 1], wids[base + 1].1);
                    let vn = tape.linear(n1, weff[base + 2], wids[base + 2].1);
                    let ctx = tape.attention(qn, kn, vn, seq, heads, dim / heads);
                    let at = tape.linear(ctx, weff[base + 3], wids[base + 3].1);
                    let r1 = tape.add(at, h);
                    let n2 = tape.layer_norm(r1);
                    let h1 = tape.linear(n2, weff[base + 4], wids[base + 4].1);
                    let mut hg = tape.gelu(h1);
                    if bits.is_some() && n_act > 0.0 {
                        hg = tape.quant_ste(hg, n_act, self.quantizer);
                    }
                    let h2 = tape.linear(hg, weff[base + 5], wids[base + 5].1);
                    h = tape.add(h2, r1);
                }
                let nf = tape.layer_norm(h);
                let pooled = tape.mean_pool(nf, seq);
                tape.linear(pooled, weff[last], wids[last].1)
            }
        };
        (wids, logits)
    }

    /// Forward + backward on one batch; returns per-layer `(dw, db)`
    /// plus `(mean_ce, correct)`. `bits` of `None` runs the float
    /// network (the Hessian-probe contract).
    fn grads(
        &self,
        bits: Option<&[f32]>,
        n_act: f32,
        x: &[f32],
        y: &[i32],
    ) -> Result<(LayerGrads, f32, f32)> {
        let m = self.check_batch(x, y)?;
        let mut tape = Tape::new(self.pool.as_ref());
        let (wids, logits) = self.build_graph(&mut tape, bits, n_act, x, m);
        let out = tape.softmax_ce(logits, y);
        tape.backward(out.id);
        let grads = wids
            .into_iter()
            .map(|(w, b)| (tape.grad(w).to_vec(), tape.grad(b).to_vec()))
            .collect();
        Ok((grads, out.ce_mean, out.correct))
    }

    /// Inference-only forward pass; returns `m × classes` logits.
    /// Records the same graph as [`Self::grads`] (without the backward
    /// sweep), so eval and train forwards are identical by construction.
    fn forward_logits(&self, bits: Option<&[f32]>, n_act: f32, x: &[f32]) -> Vec<f32> {
        let m = x.len() / self.input_dim;
        let mut tape = Tape::new(self.pool.as_ref());
        let (_, logits) = self.build_graph(&mut tape, bits, n_act, x, m);
        tape.data(logits).data.clone()
    }

    fn lsb_proxy(&self, w01: f32, n: f32, k: f32) -> f32 {
        match self.quantizer {
            Quantizer::RoundClamp => lsb_proxy_roundclamp(w01, n, k),
            Quantizer::DoReFa => lsb_proxy_dorefa(w01, n, k),
        }
    }
}

impl Backend for NativeBackend {
    fn kind(&self) -> &'static str {
        "native"
    }

    fn batch(&self) -> usize {
        self.batch
    }

    fn input_elems(&self) -> usize {
        self.input_dim
    }

    fn num_q_layers(&self) -> usize {
        self.layers.len()
    }

    fn q_layer_name(&self, q: usize) -> String {
        self.layers[q].name.clone()
    }

    fn q_layer_op(&self, q: usize) -> LayerOp {
        match self.layers[q].op {
            ParamOp::Dense => LayerOp::Linear,
            ParamOp::Conv { d, .. } => LayerOp::Conv2d(d),
        }
    }

    fn input_shape(&self) -> (usize, usize, usize) {
        self.input_hwc
    }

    fn q_layer_relu(&self, q: usize) -> bool {
        match self.topology {
            // the classic chain fuses a ReLU after every layer but the head
            Topology::Chain => q + 1 < self.num_q_layers(),
            // the ViT graph has no ReLU anywhere (GELU rides on fc1)
            Topology::Vit { .. } => false,
        }
    }

    fn export_records(&self) -> Option<Vec<ExportRecord>> {
        let Topology::Vit { seq, token_dim, dim, heads, depth } = self.topology else {
            return None;
        };
        // Mirrors PackedModel::synth_transformer record-for-record, so a
        // trained export is indistinguishable in shape from a synthetic
        // pack and serves through the same registry plan.
        let structural = |name: String, op: LayerOp| {
            ExportRecord::Structural(PackedLayer { name, op, ..Default::default() })
        };
        let quant = |q: usize| ExportRecord::Quantized { q, gelu: false };
        let mut recs =
            vec![structural("patchify".into(), LayerOp::SeqView { seq, dim: token_dim })];
        recs.push(quant(0)); // embed
        for b in 0..depth {
            let base = recs.len(); // ln1 of this block
            recs.push(structural(format!("blk{b}.ln1"), LayerOp::LayerNorm));
            recs.push(structural(
                format!("blk{b}.attn"),
                LayerOp::Attention(AttnDesc {
                    num_heads: heads,
                    head_dim: dim / heads,
                    seq_len: seq,
                    q_ref: base + 2,
                    k_ref: base + 3,
                    v_ref: base + 4,
                    proj_ref: base + 5,
                }),
            ));
            for i in 0..4 {
                recs.push(quant(1 + 6 * b + i)); // wq / wk / wv / wproj
            }
            recs.push(structural(format!("blk{b}.res1"), LayerOp::Residual { src: base - 1 }));
            recs.push(structural(format!("blk{b}.ln2"), LayerOp::LayerNorm));
            recs.push(ExportRecord::Quantized { q: 5 + 6 * b, gelu: true }); // fc1
            recs.push(quant(6 + 6 * b)); // fc2
            recs.push(structural(format!("blk{b}.res2"), LayerOp::Residual { src: base + 6 }));
        }
        recs.push(structural("ln_f".into(), LayerOp::LayerNorm));
        recs.push(structural("pool".into(), LayerOp::MeanPool));
        recs.push(quant(self.layers.len() - 1)); // head
        Some(recs)
    }

    fn q_sizes(&self) -> Vec<usize> {
        self.layers.iter().map(|l| l.w.numel()).collect()
    }

    fn trainable_params(&self) -> usize {
        // biases are frozen at zero (see DenseLayer) — weights only
        self.layers.iter().map(|l| l.w.numel()).sum()
    }

    fn q_weights(&self, q: usize) -> Result<Vec<f32>> {
        ensure!(q < self.layers.len(), "layer {q} out of range");
        Ok(self.layers[q].w.data.clone())
    }

    fn set_q_weights(&mut self, q: usize, w: &[f32]) -> Result<()> {
        ensure!(q < self.layers.len(), "layer {q} out of range");
        let dst = &mut self.layers[q].w;
        ensure!(w.len() == dst.numel(), "layer {q}: {} != {}", w.len(), dst.numel());
        dst.data.copy_from_slice(w);
        Ok(())
    }

    #[allow(clippy::too_many_arguments)]
    fn train_step(
        &mut self,
        bits: &[f32],
        ks: &[f32],
        lam: f32,
        lr: f32,
        n_act: f32,
        x: &[f32],
        y: &[i32],
    ) -> Result<StepStats> {
        // one wall-clock observation per optimizer step into the global
        // registry (rendered by `/metrics` when a gateway shares the
        // process, and by telemetry consumers otherwise); recorded on
        // drop so error paths are counted too
        let _step_span = crate::obs::global().span("msq_native_step_seconds", &[]);
        ensure!(bits.len() == self.layers.len(), "bits len {}", bits.len());
        ensure!(ks.len() == self.layers.len(), "ks len {}", ks.len());
        let (mut grads, ce, correct) = self.grads(Some(bits), n_act, x, y)?;

        // LSB L1 regularizer: loss += λ·Σ_l mean|B_k|; through the STE,
        // d|B_k|/dw = sign(B_k)/(2s) (w ↦ [0,1] is affine with slope
        // 1/(2s); the rounded target contributes no gradient).
        let mut reg_total = 0f64;
        if lam != 0.0 {
            for (l, layer) in self.layers.iter().enumerate() {
                if ks[l] < 1.0 {
                    continue;
                }
                let scale = layer.w.max_abs() + 1e-8;
                let numel = layer.w.numel() as f32;
                let gslope = lam / (2.0 * scale * numel);
                let mut reg_l = 0f64;
                for (gw, &wv) in grads[l].0.iter_mut().zip(&layer.w.data) {
                    let b = self.lsb_proxy(to_unit(wv, scale), bits[l], ks[l]);
                    reg_l += b.abs() as f64;
                    *gw += gslope * b.signum();
                }
                reg_total += reg_l / numel as f64;
            }
        }

        let opt = self.opt;
        for (layer, (gw, _gb)) in self.layers.iter_mut().zip(&grads) {
            // bias grads are computed by the tape but not applied: the
            // packed format has nowhere to put trained biases
            opt.step(&mut layer.w.data, gw, &mut layer.vw, lr);
        }
        let loss = ce + lam * reg_total as f32;
        Ok(StepStats { loss, ce, correct })
    }

    fn eval_step(&mut self, bits: &[f32], n_act: f32, x: &[f32], y: &[i32]) -> Result<(f32, f32)> {
        let m = self.check_batch(x, y)?;
        ensure!(bits.len() == self.layers.len(), "bits len {}", bits.len());
        let logits = self.forward_logits(Some(bits), n_act, x);
        let mut probs = vec![0f32; m * self.classes];
        let (ce_mean, correct) =
            ops::softmax_ce_forward(&logits, y, m, self.classes, &mut probs);
        Ok((ce_mean * m as f32, correct))
    }

    fn supports_stats(&self) -> bool {
        true
    }

    fn stats_step(&mut self, bits: &[f32], ks: &[f32]) -> Result<LayerStats> {
        ensure!(bits.len() == self.layers.len(), "bits len {}", bits.len());
        let mut stats = LayerStats::default();
        let mut scratch = Vec::new();
        for (l, layer) in self.layers.iter().enumerate() {
            stats.beta.push(crate::quant::beta_slice(&layer.w.data, bits[l], ks[l]));
            scratch.resize(layer.w.numel(), 0.0);
            ops::fake_quant_forward(&layer.w.data, bits[l], self.quantizer, &mut scratch);
            let qerr: f64 = layer
                .w
                .data
                .iter()
                .zip(&scratch)
                .map(|(&a, &b)| ((a - b) as f64) * ((a - b) as f64))
                .sum();
            stats.qerr.push(qerr as f32);
            let scale = layer.w.max_abs() + 1e-8;
            let reg: f64 = layer
                .w
                .data
                .iter()
                .map(|&wv| self.lsb_proxy(to_unit(wv, scale), bits[l], ks[l]).abs() as f64)
                .sum();
            stats.reg.push((reg / layer.w.numel().max(1) as f64) as f32);
        }
        Ok(stats)
    }

    fn supports_hessian(&self) -> bool {
        true
    }

    fn hessian_step(&mut self, x: &[f32], y: &[i32], seed: u64) -> Result<Vec<f32>> {
        self.check_batch(x, y)?;
        let mut rng = Rng::new(seed ^ 0x4856_5052);
        let vs: Vec<Vec<f32>> = self
            .layers
            .iter()
            .map(|l| (0..l.w.numel()).map(|_| rng.rademacher()).collect())
            .collect();
        // ε relative to the parameter scale: FD noise ∝ 1/ε, curvature
        // error ∝ ε — 1e-2·rms sits comfortably between both for f32
        let sq: f64 = self.layers.iter().map(|l| l.w.sq_norm()).sum();
        let n: usize = self.layers.iter().map(|l| l.w.numel()).sum();
        let eps = (1e-2 * (sq / n.max(1) as f64).sqrt()).max(1e-5) as f32;

        let perturb = |layers: &mut Vec<ParamLayer>, sign: f32| {
            for (layer, v) in layers.iter_mut().zip(&vs) {
                for (w, &vi) in layer.w.data.iter_mut().zip(v) {
                    *w += sign * eps * vi;
                }
            }
        };
        perturb(&mut self.layers, 1.0);
        let (gp, _, _) = self.grads(None, 0.0, x, y)?;
        perturb(&mut self.layers, -2.0);
        let (gm, _, _) = self.grads(None, 0.0, x, y)?;
        perturb(&mut self.layers, 1.0); // restore

        let mut vhv = Vec::with_capacity(self.layers.len());
        for ((p, m), v) in gp.iter().zip(&gm).zip(&vs) {
            let dot: f64 = p
                .0
                .iter()
                .zip(&m.0)
                .zip(v)
                .map(|((&a, &b), &vi)| ((a - b) as f64) * vi as f64)
                .sum();
            vhv.push((dot / (2.0 * eps as f64)) as f32);
        }
        Ok(vhv)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> NativeBackend {
        NativeBackend::mlp("mlp", "msq", 8, &[6], 3, 4, 7, 1).unwrap()
    }

    fn toy_batch(be: &NativeBackend, seed: u64) -> (Vec<f32>, Vec<i32>) {
        let mut rng = Rng::new(seed);
        let x: Vec<f32> = (0..be.batch() * be.input_elems()).map(|_| rng.normal()).collect();
        let y: Vec<i32> = (0..be.batch()).map(|_| rng.below(3) as i32).collect();
        (x, y)
    }

    #[test]
    fn shapes_and_accessors() {
        let be = toy();
        assert_eq!(be.num_q_layers(), 2);
        assert_eq!(be.q_sizes(), vec![48, 18]);
        assert_eq!(be.trainable_params(), 48 + 18); // biases frozen at zero
        assert_eq!(be.q_layer_name(0), "fc0");
        assert_eq!(be.q_weights(0).unwrap().len(), 48);
    }

    #[test]
    fn train_step_reduces_loss_on_fixed_batch() {
        let mut be = toy();
        let (x, y) = toy_batch(&be, 1);
        let bits = vec![8.0f32; 2];
        let ks = vec![1.0f32; 2];
        let first = be.train_step(&bits, &ks, 0.0, 0.1, 0.0, &x, &y).unwrap();
        let mut last = first;
        for _ in 0..60 {
            last = be.train_step(&bits, &ks, 0.0, 0.1, 0.0, &x, &y).unwrap();
        }
        assert!(
            last.ce < 0.5 * first.ce,
            "loss did not drop: {} -> {}",
            first.ce,
            last.ce
        );
    }

    #[test]
    fn regularizer_increases_loss_and_moves_weights() {
        let mut a = toy();
        let mut b = NativeBackend::mlp("mlp", "msq", 8, &[6], 3, 4, 7, 1).unwrap();
        let (x, y) = toy_batch(&a, 2);
        let bits = vec![4.0f32; 2];
        let ks = vec![1.0f32; 2];
        let sa = a.train_step(&bits, &ks, 0.0, 0.05, 0.0, &x, &y).unwrap();
        let sb = b.train_step(&bits, &ks, 0.1, 0.05, 0.0, &x, &y).unwrap();
        assert!((sa.ce - sb.ce).abs() < 1e-5, "same init, same batch, same ce");
        assert!(sb.loss > sb.ce, "λ > 0 must add a positive reg term");
        assert_ne!(a.q_weights(0).unwrap(), b.q_weights(0).unwrap());
    }

    #[test]
    fn regularizer_drives_beta_down() {
        let mut be = toy();
        let (x, y) = toy_batch(&be, 3);
        let bits = vec![4.0f32; 2];
        let ks = vec![1.0f32; 2];
        let beta0 = be.stats_step(&bits, &ks).unwrap().beta;
        for _ in 0..150 {
            be.train_step(&bits, &ks, 0.5, 0.01, 0.0, &x, &y).unwrap();
        }
        let beta1 = be.stats_step(&bits, &ks).unwrap().beta;
        assert!(
            beta1.iter().sum::<f32>() < beta0.iter().sum::<f32>(),
            "β did not fall: {beta0:?} -> {beta1:?}"
        );
    }

    #[test]
    fn eval_matches_train_statistics_at_init() {
        let mut be = toy();
        let (x, y) = toy_batch(&be, 4);
        let bits = vec![8.0f32; 2];
        let (ce_sum, correct) = be.eval_step(&bits, 0.0, &x, &y).unwrap();
        assert!(ce_sum.is_finite() && ce_sum > 0.0);
        assert!((0.0..=4.0).contains(&correct));
    }

    #[test]
    fn hessian_probe_is_finite_and_restores_weights() {
        let mut be = toy();
        let (x, y) = toy_batch(&be, 5);
        let before = be.q_weights(0).unwrap();
        let vhv = be.hessian_step(&x, &y, 42).unwrap();
        assert_eq!(vhv.len(), 2);
        assert!(vhv.iter().all(|v| v.is_finite()));
        let after = be.q_weights(0).unwrap();
        for (a, b) in before.iter().zip(&after) {
            assert!((a - b).abs() < 1e-5, "weights not restored: {a} vs {b}");
        }
    }

    #[test]
    fn conv_net_shapes_and_descriptors() {
        // 8x8x3 -> conv(3->4)/2 -> 4x4x4 -> conv(4->6)/2 -> 2x2x6 -> fc 24->5
        let be =
            NativeBackend::conv_net("conv", "msq", 8, 8, 3, &[4, 6], 5, 4, 7, 1).unwrap();
        assert_eq!(be.num_q_layers(), 3);
        assert_eq!(be.input_elems(), 192);
        assert_eq!(be.input_shape(), (8, 8, 3));
        assert_eq!(be.q_sizes(), vec![4 * 27, 6 * 36, 5 * 24]);
        assert_eq!(be.q_layer_name(0), "conv0");
        assert_eq!(be.q_layer_name(2), "fc");
        match be.q_layer_op(0) {
            LayerOp::Conv2d(d) => {
                assert_eq!((d.in_ch, d.out_ch, d.kh, d.stride, d.pad), (3, 4, 3, 2, 1));
            }
            LayerOp::Linear => panic!("layer 0 must be conv"),
        }
        assert_eq!(be.q_layer_op(2), LayerOp::Linear);
    }

    #[test]
    fn conv_net_train_step_reduces_loss() {
        let mut be =
            NativeBackend::conv_net("conv", "msq", 6, 6, 2, &[4], 3, 4, 11, 1).unwrap();
        let mut rng = Rng::new(5);
        let x: Vec<f32> = (0..4 * be.input_elems()).map(|_| rng.normal()).collect();
        let y: Vec<i32> = (0..4).map(|_| rng.below(3) as i32).collect();
        let bits = vec![8.0f32; 2];
        let ks = vec![1.0f32; 2];
        let first = be.train_step(&bits, &ks, 0.0, 0.1, 0.0, &x, &y).unwrap();
        let mut last = first;
        for _ in 0..80 {
            last = be.train_step(&bits, &ks, 0.0, 0.1, 0.0, &x, &y).unwrap();
        }
        assert!(
            last.ce < 0.5 * first.ce,
            "conv loss did not drop: {} -> {}",
            first.ce,
            last.ce
        );
        // eval path agrees in shape and is finite
        let (ce_sum, correct) = be.eval_step(&bits, 0.0, &x, &y).unwrap();
        assert!(ce_sum.is_finite() && (0.0..=4.0).contains(&correct));
        // hessian probes restore conv weights too
        let before = be.q_weights(0).unwrap();
        let vhv = be.hessian_step(&x, &y, 3).unwrap();
        assert_eq!(vhv.len(), 2);
        assert!(vhv.iter().all(|v| v.is_finite()));
        for (a, b) in before.iter().zip(&be.q_weights(0).unwrap()) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    fn toy_vit(depth: usize, batch: usize) -> NativeBackend {
        // seq 4 tokens of 6 features, dim 8, 2 heads, 3 classes
        NativeBackend::vit("vit", "msq", 4, 6, 8, 2, depth, 3, batch, 7, 1).unwrap()
    }

    #[test]
    fn vit_shapes_names_and_relu_policy() {
        let be = toy_vit(2, 4);
        assert_eq!(be.num_q_layers(), 14); // embed + 2·6 + head
        assert_eq!(be.input_elems(), 24);
        assert_eq!(be.input_shape(), (0, 0, 0));
        assert_eq!(be.q_layer_name(0), "embed");
        assert_eq!(be.q_layer_name(1), "blk0.wq");
        assert_eq!(be.q_layer_name(11), "blk1.fc1");
        assert_eq!(be.q_layer_name(13), "head");
        assert_eq!(be.q_sizes()[0], 8 * 6);
        assert_eq!(be.q_sizes()[11], 16 * 8); // fc1 = 2·dim × dim
        // no fused ReLU anywhere in the transformer graph
        assert!((0..14).all(|q| !be.q_layer_relu(q)));
        assert!((0..14).all(|q| be.q_layer_op(q) == LayerOp::Linear));
    }

    #[test]
    fn vit_export_layout_matches_synth_transformer() {
        // the trained export must be record-for-record the layout
        // pack-synth --arch transformer emits
        let be = toy_vit(2, 4);
        let synth = crate::quant::pack::PackedModel::synth_transformer(
            4, 6, 8, 2, 2, 3, &[8; 14], 1,
        )
        .unwrap();
        let recs = be.export_records().unwrap();
        assert_eq!(recs.len(), synth.layers.len());
        for (rec, sl) in recs.iter().zip(&synth.layers) {
            match rec {
                ExportRecord::Quantized { q, gelu } => {
                    assert_eq!(be.q_layer_name(*q), sl.name);
                    assert_eq!(*gelu, sl.gelu, "{}", sl.name);
                    assert!(!sl.op.is_structural());
                }
                ExportRecord::Structural(l) => {
                    assert_eq!(l.name, sl.name);
                    assert_eq!(l.op, sl.op, "{}", sl.name);
                    assert_eq!(l.numel, 0, "{}", sl.name);
                }
            }
        }
    }

    #[test]
    fn vit_train_step_reduces_loss() {
        let mut be = toy_vit(1, 4);
        let (x, y) = toy_batch(&be, 9);
        let bits = vec![8.0f32; 8];
        let ks = vec![1.0f32; 8];
        let first = be.train_step(&bits, &ks, 0.0, 0.05, 0.0, &x, &y).unwrap();
        let mut last = first;
        for _ in 0..120 {
            last = be.train_step(&bits, &ks, 0.0, 0.05, 0.0, &x, &y).unwrap();
        }
        assert!(
            last.ce.is_finite() && last.ce < 0.7 * first.ce,
            "vit loss did not drop: {} -> {}",
            first.ce,
            last.ce
        );
        // eval path agrees in shape and is finite
        let (ce_sum, correct) = be.eval_step(&bits, 0.0, &x, &y).unwrap();
        assert!(ce_sum.is_finite() && (0.0..=4.0).contains(&correct));
    }

    #[test]
    fn vit_hessian_probe_is_finite_and_restores_weights() {
        let mut be = toy_vit(1, 4);
        let (x, y) = toy_batch(&be, 13);
        let before = be.q_weights(1).unwrap();
        let vhv = be.hessian_step(&x, &y, 21).unwrap();
        assert_eq!(vhv.len(), 8);
        assert!(vhv.iter().all(|v| v.is_finite()));
        for (a, b) in before.iter().zip(&be.q_weights(1).unwrap()) {
            assert!((a - b).abs() < 1e-5, "weights not restored: {a} vs {b}");
        }
    }

    #[test]
    fn vit_export_serves_like_the_native_forward() {
        // pack the float weights at 8 bits the way Trainer::export_packed
        // does, serve through the registry, and compare against the
        // backend's own quantized forward — the round-trip contract.
        let be = toy_vit(2, 2);
        let mut pm = crate::quant::pack::PackedModel {
            input_dim: be.input_elems(),
            input_hwc: be.input_shape(),
            ..Default::default()
        };
        for rec in be.export_records().unwrap() {
            match rec {
                ExportRecord::Quantized { q, gelu } => {
                    let mut l = crate::quant::pack::pack_layer(
                        &be.q_layer_name(q),
                        &be.q_weights(q).unwrap(),
                        8,
                    );
                    l.op = be.q_layer_op(q);
                    l.relu = be.q_layer_relu(q);
                    l.gelu = gelu;
                    pm.layers.push(l);
                }
                ExportRecord::Structural(l) => pm.layers.push(l),
            }
        }
        pm.validate_graph().unwrap();
        let sm = crate::serve::registry::ServableModel::from_packed(
            "vit", &pm, be.input_elems(),
        )
        .unwrap();
        let mut rng = Rng::new(17);
        let x: Vec<f32> = (0..2 * be.input_elems()).map(|_| rng.normal()).collect();
        let served = sm.infer_batch(&x, 2, None).unwrap();
        let native = be.forward_logits(Some(&vec![8.0; 14]), 0.0, &x);
        assert_eq!(served.len(), native.len());
        for (s, n) in served.iter().zip(&native) {
            assert!((s - n).abs() < 1e-4, "serve {s} vs native {n}");
        }
    }

    #[test]
    fn set_q_weights_roundtrip_and_validation() {
        let mut be = toy();
        let w = vec![0.25f32; 48];
        be.set_q_weights(0, &w).unwrap();
        assert_eq!(be.q_weights(0).unwrap(), w);
        assert!(be.set_q_weights(0, &[0.0; 3]).is_err());
        assert!(be.set_q_weights(9, &w).is_err());
    }
}
