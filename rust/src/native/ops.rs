//! Forward + backward kernels for the native training backend.
//!
//! Plain slice-level math with explicit dimensions; `autograd::Tape`
//! composes these into a differentiable MLP / conv net. The heavy
//! lifting lives in the shared kernel core ([`crate::kernels`]): the
//! matmul-shaped ops are thin wrappers over its cache-blocked
//! transposed-B microkernels, the conv ops run on its window
//! geometry/microkernels (the SAME clipping the serving kernels use —
//! training and serving geometry must never diverge, because the
//! `.msqpack` export is byte-faithful to what `serve::kernels`
//! executes), and the RoundClamp fake-quant applies the same
//! `rc_affine` dequantization the quantized serving kernels fold into
//! their inner loops.
//!
//! Threading model: every kernel takes `Option<&ThreadPool>` and
//! parallelizes over disjoint output rows (samples, or filter rows for
//! weight gradients) via the core's `par_blocks`; pooled and serial
//! execution are bit-identical because parallelism only partitions
//! outputs, never a reduction (see the contract in [`crate::kernels`]).
//!
//! Conventions (see `tensor.rs`): activations `m × k` batch-major,
//! weights `n × k` row-major (`n` outputs, `k` inputs — the serve/pack
//! layout), conv weights OHWI against NHWC activations, bias `1 × n`,
//! labels `i32` class ids.

use crate::kernels::{self, axpy, krange as tap_range, SendPtr};
use crate::quant::pack::Conv2dDesc;
use crate::quant::{dorefa01, from_unit, roundclamp_code, to_unit};
use crate::util::threadpool::ThreadPool;

/// Which [0,1] quantizer the fake-quant op applies (paper Eq. 1 vs 4).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Quantizer {
    RoundClamp,
    DoReFa,
}

/// `out[i,j] = Σ_t x[i,t]·w[j,t] + b[j]` — x is `m×k`, w is `n×k`
/// (transposed-B matmul: both dots run over contiguous memory). A thin
/// wrapper over the tiled [`kernels::matmul_bt`] microkernel.
#[allow(clippy::too_many_arguments)]
pub fn linear_forward(
    x: &[f32],
    w: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
    pool: Option<&ThreadPool>,
) {
    kernels::matmul_bt(x, w, Some(b), m, k, n, out, pool);
}

/// `dx[i,t] += Σ_j dy[i,j]·w[j,t]` (rows of `dx` are disjoint).
pub fn linear_backward_input(
    dy: &[f32],
    w: &[f32],
    m: usize,
    k: usize,
    n: usize,
    dx: &mut [f32],
    pool: Option<&ThreadPool>,
) {
    kernels::matmul_acc(dy, w, m, k, n, dx, pool);
}

/// `dw[j,t] += Σ_i dy[i,j]·x[i,t]` (rows of `dw` are disjoint).
pub fn linear_backward_weight(
    dy: &[f32],
    x: &[f32],
    m: usize,
    k: usize,
    n: usize,
    dw: &mut [f32],
    pool: Option<&ThreadPool>,
) {
    kernels::matmul_t_acc(dy, x, m, k, n, dw, pool);
}

/// `db[j] += Σ_i dy[i,j]`.
pub fn linear_backward_bias(dy: &[f32], m: usize, n: usize, db: &mut [f32]) {
    debug_assert_eq!(dy.len(), m * n);
    debug_assert_eq!(db.len(), n);
    for i in 0..m {
        for (j, d) in db.iter_mut().enumerate() {
            *d += dy[i * n + j];
        }
    }
}

/// NHWC conv2d forward: `x` is `m × (in_h·in_w·in_ch)`, `w` is OHWI
/// `out_ch × (kh·kw·in_ch)` (the `.msqpack` conv layout), `b` is
/// `1 × out_ch`; `out` is `m × (out_h·out_w·out_ch)`. Samples are
/// disjoint output rows, so they parallelize over the pool; each sample
/// runs the shared [`kernels::conv2d_forward_sample`] microkernel.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_forward(
    x: &[f32],
    w: &[f32],
    b: &[f32],
    m: usize,
    d: &Conv2dDesc,
    in_h: usize,
    in_w: usize,
    out: &mut [f32],
    pool: Option<&ThreadPool>,
) {
    let (out_h, out_w) = d.out_hw(in_h, in_w).expect("conv2d_forward: geometry");
    let in_elems = in_h * in_w * d.in_ch;
    let out_elems = out_h * out_w * d.out_ch;
    let flen = d.filter_len();
    debug_assert_eq!(x.len(), m * in_elems);
    debug_assert_eq!(w.len(), d.out_ch * flen);
    debug_assert_eq!(b.len(), d.out_ch);
    debug_assert_eq!(out.len(), m * out_elems);
    let optr = SendPtr(out.as_mut_ptr());
    let optr = &optr;
    kernels::par_blocks(pool, m, m * out_elems * flen, |i| {
        let xi = &x[i * in_elems..(i + 1) * in_elems];
        // SAFETY: sample `i` writes only its own out_elems row — disjoint
        // per task; `out` outlives the scoped par_for and is not read
        // until it returns.
        let orow =
            unsafe { std::slice::from_raw_parts_mut(optr.get().add(i * out_elems), out_elems) };
        kernels::conv2d_forward_sample(xi, w, b, d, in_h, in_w, out_h, out_w, orow);
    });
}

/// `dx[i, iy, ix, ic] += Σ dy[i, oy, ox, oc] · w[oc, ky, kx, ic]` over
/// every window that covers `(iy, ix)` — scattered from the output side
/// (rows of `dx` are per-sample, hence disjoint).
#[allow(clippy::too_many_arguments)]
pub fn conv2d_backward_input(
    dy: &[f32],
    w: &[f32],
    m: usize,
    d: &Conv2dDesc,
    in_h: usize,
    in_w: usize,
    dx: &mut [f32],
    pool: Option<&ThreadPool>,
) {
    let (out_h, out_w) = d.out_hw(in_h, in_w).expect("conv2d_backward_input: geometry");
    let in_elems = in_h * in_w * d.in_ch;
    let out_elems = out_h * out_w * d.out_ch;
    let flen = d.filter_len();
    debug_assert_eq!(dy.len(), m * out_elems);
    debug_assert_eq!(w.len(), d.out_ch * flen);
    debug_assert_eq!(dx.len(), m * in_elems);
    let dxp = SendPtr(dx.as_mut_ptr());
    let dxp = &dxp;
    kernels::par_blocks(pool, m, m * out_elems * flen, |i| {
        let dyi = &dy[i * out_elems..(i + 1) * out_elems];
        // SAFETY: sample `i` scatters only into its own in_elems row of
        // `dx` — disjoint per task (see conv2d_forward)
        let dxi = unsafe { std::slice::from_raw_parts_mut(dxp.get().add(i * in_elems), in_elems) };
        for oy in 0..out_h {
            let (ky0, ky1, iy0) = tap_range(oy, d.stride, d.pad, d.kh, in_h);
            for ox in 0..out_w {
                let (kx0, kx1, ix0) = tap_range(ox, d.stride, d.pad, d.kw, in_w);
                let seg = (kx1 - kx0) * d.in_ch;
                if seg == 0 {
                    continue; // window fully off the input: nothing to scatter
                }
                for oc in 0..d.out_ch {
                    let g = dyi[(oy * out_w + ox) * d.out_ch + oc];
                    if g == 0.0 {
                        continue;
                    }
                    let wf = &w[oc * flen..(oc + 1) * flen];
                    for ky in ky0..ky1 {
                        let iy = iy0 + (ky - ky0);
                        let wrow = &wf[(ky * d.kw + kx0) * d.in_ch..][..seg];
                        let dxrow = &mut dxi[(iy * in_w + ix0) * d.in_ch..][..seg];
                        axpy(g, wrow, dxrow);
                    }
                }
            }
        }
    });
}

/// `dw[oc, ky, kx, ic] += Σ dy[i, oy, ox, oc] · x[i, iy, ix, ic]`
/// (filters are disjoint rows of `dw`, so the parallel axis is `oc`).
#[allow(clippy::too_many_arguments)]
pub fn conv2d_backward_weight(
    dy: &[f32],
    x: &[f32],
    m: usize,
    d: &Conv2dDesc,
    in_h: usize,
    in_w: usize,
    dw: &mut [f32],
    pool: Option<&ThreadPool>,
) {
    let (out_h, out_w) = d.out_hw(in_h, in_w).expect("conv2d_backward_weight: geometry");
    let in_elems = in_h * in_w * d.in_ch;
    let out_elems = out_h * out_w * d.out_ch;
    let flen = d.filter_len();
    debug_assert_eq!(dy.len(), m * out_elems);
    debug_assert_eq!(x.len(), m * in_elems);
    debug_assert_eq!(dw.len(), d.out_ch * flen);
    let dwp = SendPtr(dw.as_mut_ptr());
    let dwp = &dwp;
    kernels::par_blocks(pool, d.out_ch, m * out_elems * flen, |oc| {
        // SAFETY: filter `oc` accumulates only into its own flen row of
        // `dw` — disjoint per task (see conv2d_forward)
        let dwf = unsafe { std::slice::from_raw_parts_mut(dwp.get().add(oc * flen), flen) };
        for i in 0..m {
            let xi = &x[i * in_elems..(i + 1) * in_elems];
            let dyi = &dy[i * out_elems..(i + 1) * out_elems];
            for oy in 0..out_h {
                let (ky0, ky1, iy0) = tap_range(oy, d.stride, d.pad, d.kh, in_h);
                for ox in 0..out_w {
                    let g = dyi[(oy * out_w + ox) * d.out_ch + oc];
                    if g == 0.0 {
                        continue;
                    }
                    let (kx0, kx1, ix0) = tap_range(ox, d.stride, d.pad, d.kw, in_w);
                    let seg = (kx1 - kx0) * d.in_ch;
                    if seg == 0 {
                        continue; // window fully off the input
                    }
                    for ky in ky0..ky1 {
                        let iy = iy0 + (ky - ky0);
                        let dwrow = &mut dwf[(ky * d.kw + kx0) * d.in_ch..][..seg];
                        let xrow = &xi[(iy * in_w + ix0) * d.in_ch..][..seg];
                        axpy(g, xrow, dwrow);
                    }
                }
            }
        }
    });
}

/// `db[oc] += Σ_{i, oy, ox} dy[i, oy, ox, oc]`.
pub fn conv2d_backward_bias(dy: &[f32], positions: usize, out_ch: usize, db: &mut [f32]) {
    debug_assert_eq!(dy.len(), positions * out_ch);
    debug_assert_eq!(db.len(), out_ch);
    for p in 0..positions {
        for (oc, d) in db.iter_mut().enumerate() {
            *d += dy[p * out_ch + oc];
        }
    }
}

/// Affine-free LayerNorm over each of `rows` rows of `cols`: forwards
/// through the shared serving kernel ([`kernels::layernorm_row`]) and
/// caches the per-row `1/√(var+eps)` for the backward.
pub fn layernorm_forward(x: &[f32], rows: usize, cols: usize, out: &mut [f32], inv: &mut [f32]) {
    debug_assert_eq!(x.len(), rows * cols);
    debug_assert_eq!(out.len(), rows * cols);
    debug_assert_eq!(inv.len(), rows);
    for r in 0..rows {
        inv[r] = kernels::layernorm_row(
            &x[r * cols..(r + 1) * cols],
            kernels::LN_EPS,
            &mut out[r * cols..(r + 1) * cols],
        );
    }
}

/// LayerNorm backward from the cached normalized output (`xhat`) and
/// per-row `inv`: `dx = inv·(dy − mean(dy) − xhat·mean(dy∘xhat))`.
pub fn layernorm_backward(
    xhat: &[f32],
    inv: &[f32],
    dy: &[f32],
    rows: usize,
    cols: usize,
    dx: &mut [f32],
) {
    debug_assert_eq!(xhat.len(), rows * cols);
    debug_assert_eq!(dy.len(), rows * cols);
    debug_assert_eq!(dx.len(), rows * cols);
    debug_assert_eq!(inv.len(), rows);
    for r in 0..rows {
        let xr = &xhat[r * cols..(r + 1) * cols];
        let dyr = &dy[r * cols..(r + 1) * cols];
        let mdy = kernels::sum(dyr) / cols as f32;
        let mdyx = kernels::dot(dyr, xr) / cols as f32;
        for ((d, &g), &xh) in dx[r * cols..(r + 1) * cols].iter_mut().zip(dyr).zip(xr) {
            *d += inv[r] * (g - mdy - xh * mdyx);
        }
    }
}

/// GELU (tanh approximation) through the shared kernel.
pub fn gelu_forward(x: &[f32], out: &mut [f32]) {
    for (o, &v) in out.iter_mut().zip(x) {
        *o = kernels::gelu(v);
    }
}

/// `dx[i] += dy[i] · gelu'(x[i])`.
pub fn gelu_backward(x: &[f32], dy: &[f32], dx: &mut [f32]) {
    for ((d, &g), &v) in dx.iter_mut().zip(dy).zip(x) {
        *d += g * kernels::gelu_grad(v);
    }
}

/// Mean over the token axis: `x` is `m·s` rows of `d`, `out` is `m × d`.
pub fn mean_pool_forward(x: &[f32], m: usize, s: usize, d: usize, out: &mut [f32]) {
    debug_assert_eq!(x.len(), m * s * d);
    debug_assert_eq!(out.len(), m * d);
    let inv = 1.0 / s as f32;
    for b in 0..m {
        let ob = &mut out[b * d..(b + 1) * d];
        ob.fill(0.0);
        for t in 0..s {
            axpy(1.0, &x[(b * s + t) * d..(b * s + t + 1) * d], ob);
        }
        for o in ob.iter_mut() {
            *o *= inv;
        }
    }
}

/// `dx[b, t, j] += dy[b, j] / s` for every token `t`.
pub fn mean_pool_backward(dy: &[f32], m: usize, s: usize, d: usize, dx: &mut [f32]) {
    debug_assert_eq!(dy.len(), m * d);
    debug_assert_eq!(dx.len(), m * s * d);
    let inv = 1.0 / s as f32;
    for b in 0..m {
        let g = &dy[b * d..(b + 1) * d];
        for t in 0..s {
            axpy(inv, g, &mut dx[(b * s + t) * d..(b * s + t + 1) * d]);
        }
    }
}

/// Batched multi-head attention forward over projected Q/K/V (`m·s`
/// rows of `d = heads·head_dim` each): per sample, the shared
/// [`kernels::mha_forward_sample`] core. `probs` caches the
/// `m · heads · s · s` softmax matrices for the backward. Samples are
/// disjoint output rows, so they parallelize over the pool.
#[allow(clippy::too_many_arguments)]
pub fn attention_forward(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    m: usize,
    s: usize,
    heads: usize,
    head_dim: usize,
    ctx: &mut [f32],
    probs: &mut [f32],
    pool: Option<&ThreadPool>,
) {
    let d = heads * head_dim;
    let se = s * d;
    let pe = heads * s * s;
    debug_assert_eq!(q.len(), m * se);
    debug_assert_eq!(ctx.len(), m * se);
    debug_assert_eq!(probs.len(), m * pe);
    let cptr = SendPtr(ctx.as_mut_ptr());
    let pptr = SendPtr(probs.as_mut_ptr());
    let (cptr, pptr) = (&cptr, &pptr);
    kernels::par_blocks(pool, m, m * (2 * s * s * d), |i| {
        // SAFETY: sample `i` writes only its own ctx/probs rows —
        // disjoint per task; both buffers outlive the scoped par_for.
        let ci = unsafe { std::slice::from_raw_parts_mut(cptr.get().add(i * se), se) };
        let pi = unsafe { std::slice::from_raw_parts_mut(pptr.get().add(i * pe), pe) };
        kernels::mha_forward_sample(
            &q[i * se..(i + 1) * se],
            &k[i * se..(i + 1) * se],
            &v[i * se..(i + 1) * se],
            s,
            heads,
            head_dim,
            ci,
            Some(pi),
        );
    });
}

/// Attention backward from the cached softmax `probs`. Per sample and
/// head (`P` is `s × s`, `scale = 1/√head_dim`):
/// `dV += Pᵀ·dctx`, `dP = dctx·Vᵀ`,
/// `dS = P ∘ (dP − rowsum(dP ∘ P))`, `dQ += scale·dS·K`,
/// `dK += scale·dSᵀ·Q`. Samples own disjoint gradient rows, so the
/// parallel axis is the sample.
#[allow(clippy::too_many_arguments)]
pub fn attention_backward(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    probs: &[f32],
    dctx: &[f32],
    m: usize,
    s: usize,
    heads: usize,
    head_dim: usize,
    dq: &mut [f32],
    dk: &mut [f32],
    dv: &mut [f32],
    pool: Option<&ThreadPool>,
) {
    let d = heads * head_dim;
    let se = s * d;
    let pe = heads * s * s;
    debug_assert_eq!(q.len(), m * se);
    debug_assert_eq!(probs.len(), m * pe);
    debug_assert_eq!(dctx.len(), m * se);
    debug_assert_eq!(dq.len(), m * se);
    let scale = 1.0 / (head_dim as f32).sqrt();
    let (qp, kp, vp) = (SendPtr(dq.as_mut_ptr()), SendPtr(dk.as_mut_ptr()), SendPtr(dv.as_mut_ptr()));
    let (qp, kp, vp) = (&qp, &kp, &vp);
    kernels::par_blocks(pool, m, m * (4 * s * s * d), |i| {
        // SAFETY: sample `i` accumulates only into its own se rows of
        // dq/dk/dv — disjoint per task (see attention_forward).
        let dqi = unsafe { std::slice::from_raw_parts_mut(qp.get().add(i * se), se) };
        let dki = unsafe { std::slice::from_raw_parts_mut(kp.get().add(i * se), se) };
        let dvi = unsafe { std::slice::from_raw_parts_mut(vp.get().add(i * se), se) };
        let (qi, ki, vi) = (&q[i * se..(i + 1) * se], &k[i * se..(i + 1) * se], &v[i * se..(i + 1) * se]);
        let (dci, pri) = (&dctx[i * se..(i + 1) * se], &probs[i * pe..(i + 1) * pe]);
        let mut dp = vec![0f32; s * s];
        for h in 0..heads {
            let o = h * head_dim;
            let ph = &pri[h * s * s..(h + 1) * s * s];
            for r in 0..s {
                let dcr = &dci[r * d + o..r * d + o + head_dim];
                for j in 0..s {
                    // dV[j] += P[r,j]·dctx[r]; dP[r,j] = dctx[r]·V[j]
                    axpy(ph[r * s + j], dcr, &mut dvi[j * d + o..j * d + o + head_dim]);
                    dp[r * s + j] = kernels::dot(dcr, &vi[j * d + o..j * d + o + head_dim]);
                }
            }
            for r in 0..s {
                let pr = &ph[r * s..(r + 1) * s];
                let dpr = &mut dp[r * s..(r + 1) * s];
                let rowsum = kernels::dot(dpr, pr);
                for (ds, &p) in dpr.iter_mut().zip(pr) {
                    *ds = p * (*ds - rowsum) * scale;
                }
                // dQ[r] += dS[r,j]·K[j]; dK[j] += dS[r,j]·Q[r]
                let qr = qi[r * d + o..r * d + o + head_dim].to_vec();
                for j in 0..s {
                    axpy(dpr[j], &ki[j * d + o..j * d + o + head_dim], &mut dqi[r * d + o..r * d + o + head_dim]);
                    axpy(dpr[j], &qr, &mut dki[j * d + o..j * d + o + head_dim]);
                }
            }
        }
    });
}

pub fn relu_forward(x: &[f32], out: &mut [f32]) {
    for (o, &v) in out.iter_mut().zip(x) {
        *o = v.max(0.0);
    }
}

/// `dx[i] += dy[i] · 1[x[i] > 0]`.
pub fn relu_backward(x: &[f32], dy: &[f32], dx: &mut [f32]) {
    for ((d, &g), &v) in dx.iter_mut().zip(dy).zip(x) {
        if v > 0.0 {
            *d += g;
        }
    }
}

/// Softmax cross-entropy over `m × c` logits with integer labels.
/// Writes the softmax probabilities into `probs` (cached for backward)
/// and returns `(mean_ce, correct_count)`. The log-sum-exp runs in f64
/// so gradient checks aren't drowned by accumulation noise.
pub fn softmax_ce_forward(
    logits: &[f32],
    labels: &[i32],
    m: usize,
    c: usize,
    probs: &mut [f32],
) -> (f32, f32) {
    debug_assert_eq!(logits.len(), m * c);
    debug_assert_eq!(labels.len(), m);
    debug_assert_eq!(probs.len(), m * c);
    let mut ce = 0f64;
    let mut correct = 0f32;
    for i in 0..m {
        let row = &logits[i * c..(i + 1) * c];
        let mx = row.iter().fold(f32::NEG_INFINITY, |a, &v| a.max(v));
        let mut z = 0f64;
        for &v in row {
            z += ((v - mx) as f64).exp();
        }
        let y = labels[i] as usize;
        debug_assert!(y < c, "label {y} out of range {c}");
        ce += z.ln() - (row[y] - mx) as f64;
        let mut argmax = 0usize;
        for (j, &v) in row.iter().enumerate() {
            if v > row[argmax] {
                argmax = j;
            }
            probs[i * c + j] = (((v - mx) as f64).exp() / z) as f32;
        }
        if argmax == y {
            correct += 1.0;
        }
    }
    ((ce / m as f64) as f32, correct)
}

/// `dlogits[i,j] += upstream · (p[i,j] − 1[j == y_i]) / m`.
pub fn softmax_ce_backward(
    probs: &[f32],
    labels: &[i32],
    m: usize,
    c: usize,
    upstream: f32,
    dlogits: &mut [f32],
) {
    let inv_m = upstream / m as f32;
    for i in 0..m {
        let y = labels[i] as usize;
        for j in 0..c {
            let ind = if j == y { 1.0 } else { 0.0 };
            dlogits[i * c + j] += inv_m * (probs[i * c + j] - ind);
        }
    }
}

/// Fake-quantize `w` at `bits` with the per-tensor max-abs scale
/// (`quant::to_unit` / `from_unit` lattice). Returns the scale; the
/// backward is the straight-through estimator (gradient copies through
/// unchanged), so there is no paired backward kernel.
///
/// The RoundClamp path goes through the integer code and the shared
/// serving-side dequant affine ([`kernels::rc_affine`] /
/// [`kernels::dequant_affine`]): `out = α·code + β` — exactly the map
/// `qgemm`/`qconv2d` fold into their inner loops, so training sees the
/// same lattice serving executes (up to one ulp of association against
/// the `roundclamp01` closed form; the golden-vector tests pin both).
pub fn fake_quant_forward(w: &[f32], bits: f32, q: Quantizer, out: &mut [f32]) -> f32 {
    let scale = w.iter().fold(0f32, |a, &x| a.max(x.abs())) + 1e-8;
    match q {
        Quantizer::RoundClamp => {
            let (alpha, beta) = kernels::rc_affine(bits, scale);
            for (o, &x) in out.iter_mut().zip(w) {
                *o = roundclamp_code(to_unit(x, scale), bits) as f32;
            }
            kernels::dequant_affine(out, alpha, beta);
        }
        Quantizer::DoReFa => {
            for (o, &x) in out.iter_mut().zip(w) {
                *o = from_unit(dorefa01(to_unit(x, scale), bits), scale);
            }
        }
    }
    scale
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn rand(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| r.normal()).collect()
    }

    #[test]
    fn linear_matches_naive() {
        let (m, k, n) = (3, 5, 4);
        let x = rand(m * k, 1);
        let w = rand(n * k, 2);
        let b = rand(n, 3);
        let mut out = vec![0f32; m * n];
        linear_forward(&x, &w, &b, m, k, n, &mut out, None);
        for i in 0..m {
            for j in 0..n {
                let want: f32 = (0..k).map(|t| x[i * k + t] * w[j * k + t]).sum::<f32>() + b[j];
                assert!((out[i * n + j] - want).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn linear_pooled_matches_serial() {
        let (m, k, n) = (64, 96, 32);
        let x = rand(m * k, 4);
        let w = rand(n * k, 5);
        let b = rand(n, 6);
        let mut serial = vec![0f32; m * n];
        let mut pooled = vec![0f32; m * n];
        linear_forward(&x, &w, &b, m, k, n, &mut serial, None);
        let pool = ThreadPool::new(4);
        linear_forward(&x, &w, &b, m, k, n, &mut pooled, Some(&pool));
        assert_eq!(serial, pooled);

        let dy = rand(m * n, 7);
        let mut dxs = vec![0f32; m * k];
        let mut dxp = vec![0f32; m * k];
        linear_backward_input(&dy, &w, m, k, n, &mut dxs, None);
        linear_backward_input(&dy, &w, m, k, n, &mut dxp, Some(&pool));
        assert_eq!(dxs, dxp);
        let mut dws = vec![0f32; n * k];
        let mut dwp = vec![0f32; n * k];
        linear_backward_weight(&dy, &x, m, k, n, &mut dws, None);
        linear_backward_weight(&dy, &x, m, k, n, &mut dwp, Some(&pool));
        assert_eq!(dws, dwp);
    }

    #[test]
    fn softmax_probs_normalize_and_count_correct() {
        let logits = vec![2.0, 0.5, -1.0, 0.0, 3.0, 0.0];
        let labels = vec![0, 1];
        let mut probs = vec![0f32; 6];
        let (ce, correct) = softmax_ce_forward(&logits, &labels, 2, 3, &mut probs);
        assert!(ce > 0.0);
        assert_eq!(correct, 2.0);
        for i in 0..2 {
            let s: f32 = probs[i * 3..(i + 1) * 3].iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn relu_roundtrip() {
        let x = vec![-1.0, 0.0, 2.0];
        let mut y = vec![0f32; 3];
        relu_forward(&x, &mut y);
        assert_eq!(y, vec![0.0, 0.0, 2.0]);
        let mut dx = vec![0f32; 3];
        relu_backward(&x, &[1.0, 1.0, 1.0], &mut dx);
        assert_eq!(dx, vec![0.0, 0.0, 1.0]);
    }

    #[test]
    fn conv1x1_equals_per_position_linear() {
        // a 1x1 stride-1 conv is a per-pixel matmul: run the same weights
        // through linear_forward with every position as its own row
        let d = Conv2dDesc { in_ch: 3, out_ch: 2, kh: 1, kw: 1, stride: 1, pad: 0 };
        let (m, h, w) = (2, 4, 5);
        let x = rand(m * h * w * 3, 10);
        let wv = rand(2 * 3, 11);
        let b = rand(2, 12);
        let mut conv = vec![0f32; m * h * w * 2];
        conv2d_forward(&x, &wv, &b, m, &d, h, w, &mut conv, None);
        let mut lin = vec![0f32; m * h * w * 2];
        linear_forward(&x, &wv, &b, m * h * w, 3, 2, &mut lin, None);
        for (i, (a, e)) in conv.iter().zip(&lin).enumerate() {
            assert!((a - e).abs() < 1e-6, "idx {i}: {a} vs {e}");
        }
    }

    #[test]
    fn conv_identity_kernel_passes_input_through() {
        // 3x3 single-channel kernel with only the centre tap set, pad 1,
        // stride 1: output map == input map
        let d = Conv2dDesc { in_ch: 1, out_ch: 1, kh: 3, kw: 3, stride: 1, pad: 1 };
        let (h, w) = (5, 4);
        let x = rand(h * w, 13);
        let mut kern = vec![0f32; 9];
        kern[4] = 1.0; // centre tap (ky=1, kx=1)
        let mut out = vec![0f32; h * w];
        conv2d_forward(&x, &kern, &[0.0], 1, &d, h, w, &mut out, None);
        for (a, e) in out.iter().zip(&x) {
            assert!((a - e).abs() < 1e-6, "{a} vs {e}");
        }
    }

    #[test]
    fn conv_strided_geometry_and_values() {
        // 1 channel, 2x2 kernel, stride 2, no pad over 4x4: four disjoint
        // windows whose sums are easy to hand-check with an all-ones kernel
        let d = Conv2dDesc { in_ch: 1, out_ch: 1, kh: 2, kw: 2, stride: 2, pad: 0 };
        let x: Vec<f32> = (0..16).map(|i| i as f32).collect();
        let mut out = vec![0f32; 4];
        conv2d_forward(&x, &[1.0; 4], &[0.0], 1, &d, 4, 4, &mut out, None);
        // windows: {0,1,4,5}, {2,3,6,7}, {8,9,12,13}, {10,11,14,15}
        assert_eq!(out, vec![10.0, 18.0, 42.0, 50.0]);
    }

    #[test]
    fn conv_pooled_matches_serial_everywhere() {
        let d = Conv2dDesc { in_ch: 3, out_ch: 6, kh: 3, kw: 3, stride: 2, pad: 1 };
        let (m, h, w) = (8, 9, 7);
        let (oh, ow) = d.out_hw(h, w).unwrap();
        let x = rand(m * h * w * 3, 20);
        let wv = rand(6 * 27, 21);
        let b = rand(6, 22);
        let pool = ThreadPool::new(4);

        let mut fs = vec![0f32; m * oh * ow * 6];
        let mut fp = fs.clone();
        conv2d_forward(&x, &wv, &b, m, &d, h, w, &mut fs, None);
        conv2d_forward(&x, &wv, &b, m, &d, h, w, &mut fp, Some(&pool));
        assert_eq!(fs, fp);

        let dy = rand(m * oh * ow * 6, 23);
        let mut dxs = vec![0f32; m * h * w * 3];
        let mut dxp = dxs.clone();
        conv2d_backward_input(&dy, &wv, m, &d, h, w, &mut dxs, None);
        conv2d_backward_input(&dy, &wv, m, &d, h, w, &mut dxp, Some(&pool));
        assert_eq!(dxs, dxp);

        let mut dws = vec![0f32; 6 * 27];
        let mut dwp = dws.clone();
        conv2d_backward_weight(&dy, &x, m, &d, h, w, &mut dws, None);
        conv2d_backward_weight(&dy, &x, m, &d, h, w, &mut dwp, Some(&pool));
        assert_eq!(dws, dwp);

        let mut db = vec![0f32; 6];
        conv2d_backward_bias(&dy, m * oh * ow, 6, &mut db);
        let expect: f32 = dy.iter().sum();
        assert!((db.iter().sum::<f32>() - expect).abs() < 1e-3);
    }

    #[test]
    fn fake_quant_lattice() {
        let w = vec![-1.0f32, -0.5, 0.0, 0.25, 1.0];
        let mut q = vec![0f32; w.len()];
        let scale = fake_quant_forward(&w, 8.0, Quantizer::RoundClamp, &mut q);
        assert!((scale - 1.0).abs() < 1e-6);
        for (a, b) in w.iter().zip(&q) {
            assert!((a - b).abs() < 2.0 * scale * 2.0 / 255.0, "{a} vs {b}");
        }
    }
}
