//! Dense f32 tensors for the native backend.
//!
//! Everything the native MLP trainer touches is rank ≤ 2, so `Tensor` is
//! a row-major `rows × cols` buffer: activations are `batch × dim`,
//! weights are `out × in` (matching the `.msqpack` / serve layout), a
//! bias is `1 × dim`, and a scalar is `1 × 1`. Images enter flattened.

use crate::util::prng::Rng;

#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(rows: usize, cols: usize) -> Tensor {
        Tensor { rows, cols, data: vec![0f32; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Tensor {
        assert_eq!(data.len(), rows * cols, "tensor {rows}x{cols} from {} values", data.len());
        Tensor { rows, cols, data }
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor { rows: 1, cols: 1, data: vec![v] }
    }

    /// He-normal init for a `out × in` weight matrix (std = √(2/in)).
    pub fn he_normal(rows: usize, cols: usize, rng: &mut Rng) -> Tensor {
        let std = (2.0 / cols.max(1) as f32).sqrt();
        let data = (0..rows * cols).map(|_| rng.normal() * std).collect();
        Tensor { rows, cols, data }
    }

    pub fn numel(&self) -> usize {
        self.rows * self.cols
    }

    /// Row `r` as a slice (activations: one sample; weights: one output).
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Max-abs value (the per-tensor quantization scale's numerator).
    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0f32, |a, &x| a.max(x.abs()))
    }

    /// Σ x², accumulated in f64 (quantization-error accounting).
    pub fn sq_norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_views() {
        let t = Tensor::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(t.numel(), 6);
        assert_eq!(t.row(1), &[4.0, 5.0, 6.0]);
        assert_eq!(Tensor::zeros(2, 2).data, vec![0.0; 4]);
        assert_eq!(Tensor::scalar(3.5).data, vec![3.5]);
    }

    #[test]
    fn he_init_scale() {
        let mut rng = Rng::new(1);
        let t = Tensor::he_normal(64, 128, &mut rng);
        let var = t.sq_norm() / t.numel() as f64;
        let want = 2.0 / 128.0;
        assert!((var - want).abs() < 0.3 * want, "var {var} vs {want}");
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::from_vec(2, 2, vec![0.0; 5]);
    }
}
