//! Native pure-Rust training backend (design notes).
//!
//! The XLA/PJRT path trains by driving AOT-compiled HLO artifacts, which
//! requires a real xla-rs vendoring the default build doesn't have. This
//! module closes the train → pack → serve loop **with zero XLA linkage**:
//! a small tensor + autodiff subsystem sized exactly to what Algorithm 1
//! needs, behind the same [`crate::runtime::backend::Backend`] trait the
//! PJRT engine implements. `msq train --backend native` therefore runs
//! the paper's full schedule — RoundClamp STE quantization in the
//! forward pass, LSB L1 bit-sparsity regularization, Hutchinson
//! Hessian-trace probes driving multi-LSB pruning — on stock hardware,
//! and its `.msqpack` exports load straight into the `serve` registry.
//!
//! Layout (≈ one concept per file):
//!
//! * [`tensor`] — row-major rank-≤2 f32 tensors (`batch × dim`
//!   activations, `out × in` weights, matching the pack/serve layout);
//! * [`ops`] — forward/backward kernels: transposed-B matmul, NHWC
//!   conv2d against OHWI filters (the `.msqpack` v3 layout), bias,
//!   ReLU, softmax-CE (f64 log-sum-exp), RoundClamp/DoReFa fake-quant
//!   with the straight-through estimator, plus the transformer set —
//!   multi-head attention, LayerNorm, GELU, sequence mean-pool — each
//!   with an analytic backward. The matmul/conv/attention-shaped ops
//!   are thin wrappers over the shared kernel core ([`crate::kernels`]:
//!   tiled microkernels, SIMD/scalar lane primitives, the serving-side
//!   conv geometry and RoundClamp affine) and parallelize over
//!   `util::threadpool`'s resident workers, pooled ≡ serial bitwise;
//! * [`autograd`] — a reverse-mode tape over those ops (enum-coded
//!   graph, no boxed closures; one tape per step);
//! * [`optim`] — SGD with heavy-ball momentum (the cosine lr schedule
//!   stays in `coordinator::schedule`, fed per step like the XLA path);
//! * [`backend`] — [`NativeBackend`]: a quantized MLP (`--model mlp`),
//!   small conv net (`--model conv`, 3×3 stride-2 stages + linear
//!   head), or pre-norm ViT (`--model vit-tiny`, one token per image
//!   row, MHA + GELU-MLP blocks, mean-pool head, exported as pack v4)
//!   over the synthetic images implementing `Backend`, including
//!   per-layer β/‖W_n−W‖² stats and finite-difference Hutchinson
//!   probes (`Hv ≈ (∇L(θ+εv) − ∇L(θ−εv))/2ε`).
//!
//! Deviations from the XLA path, by design: models are the topologies
//! the `.msqpack` op table can express and `msq serve` executes
//! (linear + conv2d, NHWC/OHWI), with biases frozen at zero (the
//! packed format has no bias section, so training them would diverge
//! the exported artifact from the reported accuracy); activation
//! quantization maps through the same signed `to_unit` affine as
//! weights; Hessian probes differentiate twice by finite differences
//! instead of a second reverse sweep. Gradient
//! correctness is pinned by finite-difference checks in
//! `tests/native_grad.rs` (rel. err < 1e-3) and the STE/oracle golden
//! vectors shared with `python/compile/quant.py`.

pub mod autograd;
pub mod backend;
pub mod ops;
pub mod optim;
pub mod tensor;

pub use autograd::{CeOut, NodeId, Tape};
pub use backend::NativeBackend;
pub use ops::Quantizer;
pub use tensor::Tensor;
