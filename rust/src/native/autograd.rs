//! Minimal reverse-mode autodiff tape over [`Tensor`].
//!
//! One [`Tape`] lives for one forward/backward pass: the backend pushes
//! leaves (batch, weights, biases), composes the ops in `ops.rs`
//! (linear, relu, fake-quant STE, softmax-CE), calls
//! [`Tape::backward`] on the scalar loss, and reads gradients back off
//! the leaves. Ops are recorded as an enum (no boxed closures), so the
//! whole graph is inspectable and the backward sweep is a plain reverse
//! iteration — nodes are created in topological order by construction.
//!
//! The RoundClamp/DoReFa fake-quant node uses the straight-through
//! estimator (paper Sec. 3.1): forward snaps to the n-bit lattice,
//! backward passes the incoming gradient through unchanged.

use super::ops::{self, Quantizer};
use super::tensor::Tensor;
use crate::quant::pack::Conv2dDesc;
use crate::util::threadpool::ThreadPool;

/// Handle to a tape node (index into the tape, valid for its lifetime).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct NodeId(usize);

enum Op {
    Leaf,
    /// y = x·Wᵀ + b  (x: m×k, w: n×k, b: 1×n)
    Linear { x: NodeId, w: NodeId, b: NodeId },
    /// NHWC conv2d (x: m × h·w·c flattened, w: OHWI out_ch × kh·kw·in_ch)
    Conv2d { x: NodeId, w: NodeId, b: NodeId, d: Conv2dDesc, in_h: usize, in_w: usize },
    Relu { x: NodeId },
    /// fake-quant with straight-through backward
    QuantSte { x: NodeId },
    /// free re-dimension of the same row-major buffer (patchify etc.)
    Reshape { x: NodeId },
    /// affine-free per-row LayerNorm; caches 1/√(var+eps) per row
    LayerNorm { x: NodeId, inv: Vec<f32> },
    Gelu { x: NodeId },
    /// elementwise residual sum (same shape)
    Add { a: NodeId, b: NodeId },
    /// mean over the token axis: (m·s)×d → m×d
    MeanPool { x: NodeId, s: usize },
    /// multi-head self-attention over projected Q/K/V ((m·s)×d each);
    /// caches the m·heads·s·s softmax matrices for the backward
    Attention { q: NodeId, k: NodeId, v: NodeId, s: usize, heads: usize, head_dim: usize, probs: Vec<f32> },
    /// scalar mean cross-entropy; caches probs for the backward
    SoftmaxCe { logits: NodeId, labels: Vec<i32>, probs: Vec<f32> },
}

struct Node {
    t: Tensor,
    grad: Vec<f32>,
    op: Op,
}

/// Result of [`Tape::softmax_ce`]: the scalar loss node plus the batch
/// statistics every trainer loop wants.
pub struct CeOut {
    pub id: NodeId,
    pub ce_mean: f32,
    pub correct: f32,
}

pub struct Tape<'p> {
    pool: Option<&'p ThreadPool>,
    nodes: Vec<Node>,
}

impl<'p> Tape<'p> {
    pub fn new(pool: Option<&'p ThreadPool>) -> Tape<'p> {
        Tape { pool, nodes: Vec::new() }
    }

    fn push(&mut self, t: Tensor, op: Op) -> NodeId {
        let grad = vec![0f32; t.numel()];
        self.nodes.push(Node { t, grad, op });
        NodeId(self.nodes.len() - 1)
    }

    pub fn leaf(&mut self, t: Tensor) -> NodeId {
        self.push(t, Op::Leaf)
    }

    pub fn data(&self, id: NodeId) -> &Tensor {
        &self.nodes[id.0].t
    }

    /// Gradient of the last `backward` loss w.r.t. node `id`.
    pub fn grad(&self, id: NodeId) -> &[f32] {
        &self.nodes[id.0].grad
    }

    /// `x·Wᵀ + b` — x: `m×k`, w: `n×k` (row-major out×in), b: `1×n`.
    pub fn linear(&mut self, x: NodeId, w: NodeId, b: NodeId) -> NodeId {
        let (m, k) = (self.nodes[x.0].t.rows, self.nodes[x.0].t.cols);
        let n = self.nodes[w.0].t.rows;
        assert_eq!(self.nodes[w.0].t.cols, k, "linear: x cols {k} vs w cols");
        assert_eq!(self.nodes[b.0].t.numel(), n, "linear: bias size");
        let mut out = Tensor::zeros(m, n);
        ops::linear_forward(
            &self.nodes[x.0].t.data,
            &self.nodes[w.0].t.data,
            &self.nodes[b.0].t.data,
            m,
            k,
            n,
            &mut out.data,
            self.pool,
        );
        self.push(out, Op::Linear { x, w, b })
    }

    /// NHWC conv2d over flattened maps — x: `m × (in_h·in_w·in_ch)`,
    /// w: `out_ch × (kh·kw·in_ch)` (OHWI), b: `1 × out_ch`.
    pub fn conv2d(
        &mut self,
        x: NodeId,
        w: NodeId,
        b: NodeId,
        d: Conv2dDesc,
        in_h: usize,
        in_w: usize,
    ) -> NodeId {
        let m = self.nodes[x.0].t.rows;
        let (out_h, out_w) = d.out_hw(in_h, in_w).expect("conv2d: geometry");
        assert_eq!(
            self.nodes[x.0].t.cols,
            in_h * in_w * d.in_ch,
            "conv2d: x cols vs {in_h}x{in_w}x{}",
            d.in_ch
        );
        assert_eq!(self.nodes[w.0].t.rows, d.out_ch, "conv2d: w rows");
        assert_eq!(self.nodes[w.0].t.cols, d.filter_len(), "conv2d: w cols");
        assert_eq!(self.nodes[b.0].t.numel(), d.out_ch, "conv2d: bias size");
        let mut out = Tensor::zeros(m, out_h * out_w * d.out_ch);
        ops::conv2d_forward(
            &self.nodes[x.0].t.data,
            &self.nodes[w.0].t.data,
            &self.nodes[b.0].t.data,
            m,
            &d,
            in_h,
            in_w,
            &mut out.data,
            self.pool,
        );
        self.push(out, Op::Conv2d { x, w, b, d, in_h, in_w })
    }

    pub fn relu(&mut self, x: NodeId) -> NodeId {
        let src = &self.nodes[x.0].t;
        let mut out = Tensor::zeros(src.rows, src.cols);
        ops::relu_forward(&src.data, &mut out.data);
        self.push(out, Op::Relu { x })
    }

    /// Fake-quantize at `bits` with per-tensor max-abs scale; backward is
    /// the straight-through estimator.
    pub fn quant_ste(&mut self, x: NodeId, bits: f32, q: Quantizer) -> NodeId {
        let src = &self.nodes[x.0].t;
        let mut out = Tensor::zeros(src.rows, src.cols);
        ops::fake_quant_forward(&src.data, bits, q, &mut out.data);
        self.push(out, Op::QuantSte { x })
    }

    /// Reinterpret `x`'s row-major buffer as `rows × cols` (numel must
    /// match). Forward copies; backward passes the gradient through.
    pub fn reshape(&mut self, x: NodeId, rows: usize, cols: usize) -> NodeId {
        let src = &self.nodes[x.0].t;
        assert_eq!(src.numel(), rows * cols, "reshape: numel mismatch");
        let out = Tensor::from_vec(rows, cols, src.data.clone());
        self.push(out, Op::Reshape { x })
    }

    /// Affine-free LayerNorm over each row (tokens are rows).
    pub fn layer_norm(&mut self, x: NodeId) -> NodeId {
        let src = &self.nodes[x.0].t;
        let (rows, cols) = (src.rows, src.cols);
        let mut out = Tensor::zeros(rows, cols);
        let mut inv = vec![0f32; rows];
        ops::layernorm_forward(&src.data, rows, cols, &mut out.data, &mut inv);
        self.push(out, Op::LayerNorm { x, inv })
    }

    pub fn gelu(&mut self, x: NodeId) -> NodeId {
        let src = &self.nodes[x.0].t;
        let mut out = Tensor::zeros(src.rows, src.cols);
        ops::gelu_forward(&src.data, &mut out.data);
        self.push(out, Op::Gelu { x })
    }

    /// Elementwise `a + b` (residual connection; shapes must match).
    pub fn add(&mut self, a: NodeId, b: NodeId) -> NodeId {
        let (ta, tb) = (&self.nodes[a.0].t, &self.nodes[b.0].t);
        assert_eq!((ta.rows, ta.cols), (tb.rows, tb.cols), "add: shape mismatch");
        let mut out = Tensor::zeros(ta.rows, ta.cols);
        for ((o, &u), &w) in out.data.iter_mut().zip(&ta.data).zip(&tb.data) {
            *o = u + w;
        }
        self.push(out, Op::Add { a, b })
    }

    /// Mean over the token axis: `(m·s) × d` → `m × d`.
    pub fn mean_pool(&mut self, x: NodeId, s: usize) -> NodeId {
        let src = &self.nodes[x.0].t;
        assert!(s > 0 && src.rows % s == 0, "mean_pool: rows {} vs seq {s}", src.rows);
        let (m, d) = (src.rows / s, src.cols);
        let mut out = Tensor::zeros(m, d);
        ops::mean_pool_forward(&src.data, m, s, d, &mut out.data);
        self.push(out, Op::MeanPool { x, s })
    }

    /// Multi-head self-attention over already-projected Q/K/V token
    /// streams (each `(m·s) × heads·head_dim`); returns the context
    /// stream of the same shape.
    pub fn attention(
        &mut self,
        q: NodeId,
        k: NodeId,
        v: NodeId,
        s: usize,
        heads: usize,
        head_dim: usize,
    ) -> NodeId {
        let (rows, cols) = (self.nodes[q.0].t.rows, self.nodes[q.0].t.cols);
        for &n in &[k, v] {
            assert_eq!(
                (self.nodes[n.0].t.rows, self.nodes[n.0].t.cols),
                (rows, cols),
                "attention: q/k/v shape mismatch"
            );
        }
        assert_eq!(cols, heads * head_dim, "attention: cols vs heads·head_dim");
        assert!(s > 0 && rows % s == 0, "attention: rows {rows} vs seq {s}");
        let m = rows / s;
        let mut out = Tensor::zeros(rows, cols);
        let mut probs = vec![0f32; m * heads * s * s];
        ops::attention_forward(
            &self.nodes[q.0].t.data,
            &self.nodes[k.0].t.data,
            &self.nodes[v.0].t.data,
            m,
            s,
            heads,
            head_dim,
            &mut out.data,
            &mut probs,
            self.pool,
        );
        self.push(out, Op::Attention { q, k, v, s, heads, head_dim, probs })
    }

    /// Mean softmax cross-entropy of `m×c` logits against class labels.
    pub fn softmax_ce(&mut self, logits: NodeId, labels: &[i32]) -> CeOut {
        let (m, c) = (self.nodes[logits.0].t.rows, self.nodes[logits.0].t.cols);
        assert_eq!(labels.len(), m, "softmax_ce: {m} rows vs {} labels", labels.len());
        let mut probs = vec![0f32; m * c];
        let (ce, correct) =
            ops::softmax_ce_forward(&self.nodes[logits.0].t.data, labels, m, c, &mut probs);
        let id = self.push(
            Tensor::scalar(ce),
            Op::SoftmaxCe { logits, labels: labels.to_vec(), probs },
        );
        CeOut { id, ce_mean: ce, correct }
    }

    fn acc_grad(&mut self, id: NodeId, buf: &[f32]) {
        for (g, &d) in self.nodes[id.0].grad.iter_mut().zip(buf) {
            *g += d;
        }
    }

    /// Reverse sweep from scalar node `loss` (seeds d loss/d loss = 1).
    /// Consumes the recorded ops; leaf gradients stay readable.
    pub fn backward(&mut self, loss: NodeId) {
        assert_eq!(self.nodes[loss.0].t.numel(), 1, "backward needs a scalar loss");
        self.nodes[loss.0].grad[0] = 1.0;
        for i in (0..=loss.0).rev() {
            let op = std::mem::replace(&mut self.nodes[i].op, Op::Leaf);
            // nodes above `i` are already processed, so grad[i] is final
            let g = std::mem::take(&mut self.nodes[i].grad);
            match op {
                Op::Leaf => {
                    // keep leaf grads readable after the sweep
                    self.nodes[i].grad = g;
                }
                Op::Linear { x, w, b } => {
                    let (m, k) = (self.nodes[x.0].t.rows, self.nodes[x.0].t.cols);
                    let n = self.nodes[w.0].t.rows;
                    let mut dx = vec![0f32; m * k];
                    ops::linear_backward_input(
                        &g, &self.nodes[w.0].t.data, m, k, n, &mut dx, self.pool,
                    );
                    let mut dw = vec![0f32; n * k];
                    ops::linear_backward_weight(
                        &g, &self.nodes[x.0].t.data, m, k, n, &mut dw, self.pool,
                    );
                    let mut db = vec![0f32; n];
                    ops::linear_backward_bias(&g, m, n, &mut db);
                    self.acc_grad(x, &dx);
                    self.acc_grad(w, &dw);
                    self.acc_grad(b, &db);
                }
                Op::Conv2d { x, w, b, d, in_h, in_w } => {
                    let m = self.nodes[x.0].t.rows;
                    let mut dx = vec![0f32; self.nodes[x.0].t.numel()];
                    ops::conv2d_backward_input(
                        &g, &self.nodes[w.0].t.data, m, &d, in_h, in_w, &mut dx, self.pool,
                    );
                    let mut dw = vec![0f32; self.nodes[w.0].t.numel()];
                    ops::conv2d_backward_weight(
                        &g, &self.nodes[x.0].t.data, m, &d, in_h, in_w, &mut dw, self.pool,
                    );
                    let mut db = vec![0f32; d.out_ch];
                    ops::conv2d_backward_bias(&g, g.len() / d.out_ch, d.out_ch, &mut db);
                    self.acc_grad(x, &dx);
                    self.acc_grad(w, &dw);
                    self.acc_grad(b, &db);
                }
                Op::Relu { x } => {
                    let mut dx = vec![0f32; g.len()];
                    ops::relu_backward(&self.nodes[x.0].t.data, &g, &mut dx);
                    self.acc_grad(x, &dx);
                }
                Op::QuantSte { x } | Op::Reshape { x } => {
                    // straight-through / same buffer: pass the gradient unchanged
                    self.acc_grad(x, &g);
                }
                Op::LayerNorm { x, inv } => {
                    // xhat is this node's own output
                    let (rows, cols) = (self.nodes[i].t.rows, self.nodes[i].t.cols);
                    let mut dx = vec![0f32; rows * cols];
                    let xhat = std::mem::take(&mut self.nodes[i].t.data);
                    ops::layernorm_backward(&xhat, &inv, &g, rows, cols, &mut dx);
                    self.nodes[i].t.data = xhat;
                    self.acc_grad(x, &dx);
                }
                Op::Gelu { x } => {
                    let mut dx = vec![0f32; g.len()];
                    ops::gelu_backward(&self.nodes[x.0].t.data, &g, &mut dx);
                    self.acc_grad(x, &dx);
                }
                Op::Add { a, b } => {
                    self.acc_grad(a, &g);
                    self.acc_grad(b, &g);
                }
                Op::MeanPool { x, s } => {
                    let (m, d) = (self.nodes[i].t.rows, self.nodes[i].t.cols);
                    let mut dx = vec![0f32; m * s * d];
                    ops::mean_pool_backward(&g, m, s, d, &mut dx);
                    self.acc_grad(x, &dx);
                }
                Op::Attention { q, k, v, s, heads, head_dim, probs } => {
                    let m = self.nodes[q.0].t.rows / s;
                    let n = self.nodes[q.0].t.numel();
                    let (mut dq, mut dk, mut dv) = (vec![0f32; n], vec![0f32; n], vec![0f32; n]);
                    ops::attention_backward(
                        &self.nodes[q.0].t.data,
                        &self.nodes[k.0].t.data,
                        &self.nodes[v.0].t.data,
                        &probs,
                        &g,
                        m,
                        s,
                        heads,
                        head_dim,
                        &mut dq,
                        &mut dk,
                        &mut dv,
                        self.pool,
                    );
                    self.acc_grad(q, &dq);
                    self.acc_grad(k, &dk);
                    self.acc_grad(v, &dv);
                }
                Op::SoftmaxCe { logits, labels, probs } => {
                    let (m, c) = (self.nodes[logits.0].t.rows, self.nodes[logits.0].t.cols);
                    let mut dl = vec![0f32; m * c];
                    ops::softmax_ce_backward(&probs, &labels, m, c, g[0], &mut dl);
                    self.acc_grad(logits, &dl);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_chain_gradients_match_hand_math() {
        // y = x·Wᵀ + b with one sample, CE over 2 classes; compare the
        // logit gradient (p − onehot)/m pushed through the linear op.
        let mut tape = Tape::new(None);
        let x = tape.leaf(Tensor::from_vec(1, 3, vec![1.0, -2.0, 0.5]));
        let w = tape.leaf(Tensor::from_vec(2, 3, vec![0.1, 0.2, 0.3, -0.1, 0.0, 0.4]));
        let b = tape.leaf(Tensor::from_vec(1, 2, vec![0.05, -0.05]));
        let y = tape.linear(x, w, b);
        let out = tape.softmax_ce(y, &[1]);
        tape.backward(out.id);

        let logits = tape.data(y).data.clone();
        let z: f32 = logits.iter().map(|&v| v.exp()).sum();
        let p: Vec<f32> = logits.iter().map(|&v| v.exp() / z).collect();
        let dlogit = [p[0], p[1] - 1.0];
        let gw = tape.grad(w);
        for j in 0..2 {
            for t in 0..3 {
                let want = dlogit[j] * tape.data(x).data[t];
                assert!((gw[j * 3 + t] - want).abs() < 1e-5, "dw[{j},{t}]");
            }
        }
        let gb = tape.grad(b);
        assert!((gb[0] - dlogit[0]).abs() < 1e-5 && (gb[1] - dlogit[1]).abs() < 1e-5);
    }

    #[test]
    fn quant_ste_passes_gradient_through() {
        // tape A: quantized weights as a leaf; tape B: weights -> STE.
        // Leaf gradients must agree exactly (the STE contract).
        let w = vec![0.9f32, -0.4, 0.1, 0.6, -1.0, 0.3];
        let x = vec![0.5f32, -1.0, 0.25];

        let mut qw = vec![0f32; 6];
        ops::fake_quant_forward(&w, 3.0, Quantizer::RoundClamp, &mut qw);

        let mut ta = Tape::new(None);
        let xa = ta.leaf(Tensor::from_vec(1, 3, x.clone()));
        let wa = ta.leaf(Tensor::from_vec(2, 3, qw));
        let ba = ta.leaf(Tensor::zeros(1, 2));
        let ya = ta.linear(xa, wa, ba);
        let la = ta.softmax_ce(ya, &[0]);
        ta.backward(la.id);

        let mut tb = Tape::new(None);
        let xb = tb.leaf(Tensor::from_vec(1, 3, x));
        let wb = tb.leaf(Tensor::from_vec(2, 3, w));
        let bb = tb.leaf(Tensor::zeros(1, 2));
        let wq = tb.quant_ste(wb, 3.0, Quantizer::RoundClamp);
        let yb = tb.linear(xb, wq, bb);
        let lb = tb.softmax_ce(yb, &[0]);
        tb.backward(lb.id);

        assert_eq!(ta.grad(wa), tb.grad(wb));
    }

    #[test]
    fn conv2d_gradients_match_finite_differences() {
        // 4x4x2 input, 3 filters of 3x3, stride 2, pad 1 -> 2x2x3 map ->
        // CE over the flattened 12 logits' first 3 (via a linear head is
        // overkill: feed the map straight to softmax over 12 "classes")
        let d = Conv2dDesc { in_ch: 2, out_ch: 3, kh: 3, kw: 3, stride: 2, pad: 1 };
        let (in_h, in_w, m) = (4usize, 4usize, 2usize);
        let mut rng = crate::util::prng::Rng::new(42);
        let x: Vec<f32> = (0..m * in_h * in_w * 2).map(|_| rng.normal() * 0.5).collect();
        let w: Vec<f32> = (0..3 * 18).map(|_| rng.normal() * 0.3).collect();
        let labels = [3, 7];

        let loss_at = |wv: &[f32]| -> f32 {
            let mut tape = Tape::new(None);
            let xn = tape.leaf(Tensor::from_vec(m, in_h * in_w * 2, x.clone()));
            let wn = tape.leaf(Tensor::from_vec(3, 18, wv.to_vec()));
            let bn = tape.leaf(Tensor::zeros(1, 3));
            let y = tape.conv2d(xn, wn, bn, d, in_h, in_w);
            tape.softmax_ce(y, &labels).ce_mean
        };

        let mut tape = Tape::new(None);
        let xn = tape.leaf(Tensor::from_vec(m, in_h * in_w * 2, x.clone()));
        let wn = tape.leaf(Tensor::from_vec(3, 18, w.clone()));
        let bn = tape.leaf(Tensor::zeros(1, 3));
        let y = tape.conv2d(xn, wn, bn, d, in_h, in_w);
        assert_eq!(tape.data(y).cols, 2 * 2 * 3);
        let out = tape.softmax_ce(y, &labels);
        tape.backward(out.id);
        let gw = tape.grad(wn).to_vec();
        let gb = tape.grad(bn).to_vec();

        let eps = 1e-2f32;
        for i in (0..w.len()).step_by(5) {
            let mut wp = w.clone();
            wp[i] += eps;
            let mut wm = w.clone();
            wm[i] -= eps;
            let fd = (loss_at(&wp) - loss_at(&wm)) / (2.0 * eps);
            assert!(
                (gw[i] - fd).abs() < 2e-3 + 0.05 * fd.abs(),
                "dw[{i}]: tape {} vs fd {fd}",
                gw[i]
            );
        }
        // bias gradient: mean softmax grad summed over positions is tiny
        // but finite; just check shape and finiteness here (the linear
        // bias path is covered by the exact hand-math test above)
        assert_eq!(gb.len(), 3);
        assert!(gb.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn relu_blocks_negative_paths() {
        let mut tape = Tape::new(None);
        let x = tape.leaf(Tensor::from_vec(1, 2, vec![-1.0, 2.0]));
        let r = tape.relu(x);
        let w = tape.leaf(Tensor::from_vec(2, 2, vec![1.0, 1.0, -1.0, 1.0]));
        let b = tape.leaf(Tensor::zeros(1, 2));
        let y = tape.linear(r, w, b);
        let out = tape.softmax_ce(y, &[0]);
        tape.backward(out.id);
        let gx = tape.grad(x);
        assert_eq!(gx[0], 0.0, "gradient must not flow through a dead relu");
        assert!(gx[1] != 0.0);
    }
}
