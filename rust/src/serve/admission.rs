//! Bounded admission/wait queue in front of a model's batcher.
//!
//! The batcher queue is a hard ring: when it is full, `submit` sheds the
//! request immediately. Under bursty load that turns a few milliseconds
//! of queue pressure into a wall of 429s even though capacity frees up
//! almost instantly. [`Admission`] adds a *wait room* in front of the
//! queue: a request that finds the queue full may wait — bounded both in
//! population (`wait_cap` concurrent waiters) and in time (`deadline`)
//! — retrying until a slot opens. Expired and shed requests still map to
//! 429 + `Retry-After` at the HTTP layer; the difference is that a burst
//! now drains through the deadline budget instead of being rejected at
//! first contact.
//!
//! Conservation invariant: every call to [`Admission::admit`] resolves
//! exactly once — admitted (the submit closure returned `Ok`), expired,
//! shed, or a fatal submit error. The caller records exactly one
//! submit/reject pair per request around this, so
//! `completed + rejected == submitted` holds after drain.
//!
//! With `wait_cap == 0` (the default) the wait room is disabled and
//! behavior is byte-for-byte the legacy immediate shed.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::metrics::LatencyHist;
use crate::util::json::Json;

use super::batcher::SubmitError;

/// How often a waiter re-probes the batcher queue. Coarse on purpose:
/// the queue drains in `max_delay` (ms) quanta, so finer polling buys
/// nothing but wakeups.
const POLL: Duration = Duration::from_millis(1);

/// Admission policy knobs (`--queue-depth`, `--admit-deadline-ms`).
#[derive(Clone, Debug)]
pub struct AdmissionConfig {
    /// Max requests allowed to wait for a queue slot at once.
    /// 0 disables waiting: queue-full sheds immediately (legacy).
    pub wait_cap: usize,
    /// How long a waiter may poll for a slot before expiring with 429.
    pub deadline: Duration,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        AdmissionConfig { wait_cap: 0, deadline: Duration::from_millis(100) }
    }
}

/// Why a request was not admitted.
#[derive(Debug)]
pub enum AdmitError {
    /// Waited the full deadline and the queue never had a slot.
    Expired { waited: Duration, depth: usize, cap: usize },
    /// Wait room full (or waiting disabled) — shed at first contact.
    Shed { depth: usize, cap: usize },
    /// Non-retryable submit failure (shutdown, bad input).
    Fatal(SubmitError),
}

/// Counters for the `msq_admission_*` metric families. All relaxed:
/// these are monotonic telemetry, not synchronization.
#[derive(Default)]
pub struct AdmissionMetrics {
    admitted: AtomicU64,
    waited: AtomicU64,
    expired: AtomicU64,
    shed: AtomicU64,
    waiting: AtomicU64,
    wait_hist: Mutex<LatencyHist>,
}

impl AdmissionMetrics {
    /// Requests admitted (immediately or after waiting).
    pub fn admitted(&self) -> u64 {
        self.admitted.load(Ordering::Relaxed)
    }

    /// Requests admitted only after at least one queue-full retry.
    pub fn waited(&self) -> u64 {
        self.waited.load(Ordering::Relaxed)
    }

    /// Requests that waited the full deadline and were rejected.
    pub fn expired(&self) -> u64 {
        self.expired.load(Ordering::Relaxed)
    }

    /// Requests shed without waiting (wait room full or disabled).
    pub fn shed(&self) -> u64 {
        self.shed.load(Ordering::Relaxed)
    }

    /// Current wait-room population (gauge).
    pub fn waiting(&self) -> u64 {
        self.waiting.load(Ordering::Relaxed)
    }

    /// Snapshot of the wait-duration histogram (seconds; every request
    /// that entered the wait room records on exit, admitted or not).
    pub fn wait_hist(&self) -> LatencyHist {
        self.wait_hist.lock().unwrap().clone()
    }

    /// JSON view for `/debug/stats`.
    pub fn to_json(&self) -> Json {
        let h = self.wait_hist();
        Json::obj(vec![
            ("admitted", Json::Num(self.admitted() as f64)),
            ("waited", Json::Num(self.waited() as f64)),
            ("expired", Json::Num(self.expired() as f64)),
            ("shed", Json::Num(self.shed() as f64)),
            ("waiting", Json::Num(self.waiting() as f64)),
            ("wait_p99_ms", Json::Num(h.percentile(99.0) * 1e3)),
            ("wait_count", Json::Num(h.count() as f64)),
        ])
    }
}

/// The admission gate: one per [`super::Server`].
pub struct Admission {
    cfg: AdmissionConfig,
    pub metrics: AdmissionMetrics,
}

impl Admission {
    pub fn new(cfg: AdmissionConfig) -> Admission {
        Admission { cfg, metrics: AdmissionMetrics::default() }
    }

    pub fn config(&self) -> &AdmissionConfig {
        &self.cfg
    }

    /// Run `try_submit` until it succeeds, the deadline expires, or a
    /// non-retryable error surfaces. `try_submit` must be retryable:
    /// a `QueueFull` result must leave the request replayable (the
    /// batcher's `try_submit` hands the input back for exactly this).
    pub fn admit<T>(
        &self,
        mut try_submit: impl FnMut() -> Result<T, SubmitError>,
    ) -> Result<T, AdmitError> {
        let (mut depth, mut cap) = match try_submit() {
            Ok(t) => {
                self.metrics.admitted.fetch_add(1, Ordering::Relaxed);
                return Ok(t);
            }
            Err(SubmitError::QueueFull { depth, cap }) => (depth, cap),
            Err(e) => return Err(AdmitError::Fatal(e)),
        };
        if self.cfg.wait_cap == 0 || self.cfg.deadline.is_zero() || !self.enter_wait_room() {
            self.metrics.shed.fetch_add(1, Ordering::Relaxed);
            return Err(AdmitError::Shed { depth, cap });
        }
        let t0 = Instant::now();
        let out = loop {
            let waited = t0.elapsed();
            if waited >= self.cfg.deadline {
                break Err(AdmitError::Expired { waited, depth, cap });
            }
            std::thread::sleep(POLL.min(self.cfg.deadline - waited));
            match try_submit() {
                Ok(t) => break Ok(t),
                Err(SubmitError::QueueFull { depth: d, cap: c }) => {
                    depth = d;
                    cap = c;
                }
                Err(e) => break Err(AdmitError::Fatal(e)),
            }
        };
        self.leave_wait_room(t0.elapsed());
        match &out {
            Ok(_) => {
                self.metrics.admitted.fetch_add(1, Ordering::Relaxed);
                self.metrics.waited.fetch_add(1, Ordering::Relaxed);
            }
            Err(AdmitError::Expired { .. }) => {
                self.metrics.expired.fetch_add(1, Ordering::Relaxed);
            }
            Err(_) => {}
        }
        out
    }

    fn enter_wait_room(&self) -> bool {
        let cap = self.cfg.wait_cap as u64;
        self.metrics
            .waiting
            .fetch_update(Ordering::AcqRel, Ordering::Acquire, |w| {
                if w < cap {
                    Some(w + 1)
                } else {
                    None
                }
            })
            .is_ok()
    }

    fn leave_wait_room(&self, waited: Duration) {
        self.metrics.waiting.fetch_sub(1, Ordering::AcqRel);
        self.metrics.wait_hist.lock().unwrap().record(waited.as_secs_f64());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    fn full() -> Result<u32, SubmitError> {
        Err(SubmitError::QueueFull { depth: 4, cap: 4 })
    }

    #[test]
    fn immediate_admit_skips_the_wait_room() {
        let a = Admission::new(AdmissionConfig { wait_cap: 8, deadline: Duration::from_secs(1) });
        assert_eq!(a.admit(|| Ok::<_, SubmitError>(7u32)).unwrap(), 7);
        assert_eq!(a.metrics.admitted(), 1);
        assert_eq!(a.metrics.waited(), 0);
        assert_eq!(a.metrics.wait_hist().count(), 0);
    }

    #[test]
    fn wait_cap_zero_is_legacy_immediate_shed() {
        let a = Admission::new(AdmissionConfig { wait_cap: 0, deadline: Duration::from_secs(1) });
        match a.admit(full) {
            Err(AdmitError::Shed { depth: 4, cap: 4 }) => {}
            other => panic!("expected shed, got {other:?}"),
        }
        assert_eq!(a.metrics.shed(), 1);
        assert_eq!(a.metrics.waiting(), 0);
    }

    #[test]
    fn deadline_expiry_reports_time_waited() {
        let deadline = Duration::from_millis(20);
        let a = Admission::new(AdmissionConfig { wait_cap: 8, deadline });
        let t0 = Instant::now();
        match a.admit(full) {
            Err(AdmitError::Expired { waited, .. }) => assert!(waited >= deadline, "{waited:?}"),
            other => panic!("expected expiry, got {other:?}"),
        }
        assert!(t0.elapsed() >= deadline);
        assert_eq!(a.metrics.expired(), 1);
        assert_eq!(a.metrics.waiting(), 0);
        assert_eq!(a.metrics.wait_hist().count(), 1);
    }

    #[test]
    fn queue_full_then_free_admits_after_wait() {
        let a = Admission::new(AdmissionConfig { wait_cap: 8, deadline: Duration::from_secs(2) });
        let calls = AtomicUsize::new(0);
        let got = a
            .admit(|| {
                if calls.fetch_add(1, Ordering::Relaxed) < 3 {
                    Err(SubmitError::QueueFull { depth: 4, cap: 4 })
                } else {
                    Ok(42u32)
                }
            })
            .unwrap();
        assert_eq!(got, 42);
        assert_eq!(a.metrics.admitted(), 1);
        assert_eq!(a.metrics.waited(), 1);
        assert_eq!(a.metrics.waiting(), 0);
        assert_eq!(a.metrics.wait_hist().count(), 1);
    }

    #[test]
    fn fatal_errors_pass_through_without_retry() {
        let a = Admission::new(AdmissionConfig { wait_cap: 8, deadline: Duration::from_secs(1) });
        let calls = AtomicUsize::new(0);
        match a.admit(|| -> Result<u32, SubmitError> {
            calls.fetch_add(1, Ordering::Relaxed);
            Err(SubmitError::ShuttingDown)
        }) {
            Err(AdmitError::Fatal(SubmitError::ShuttingDown)) => {}
            other => panic!("expected fatal, got {other:?}"),
        }
        assert_eq!(calls.load(Ordering::Relaxed), 1);
        assert_eq!(a.metrics.shed() + a.metrics.expired() + a.metrics.admitted(), 0);
    }

    #[test]
    fn wait_room_population_is_bounded() {
        let a = Admission::new(AdmissionConfig { wait_cap: 2, deadline: Duration::from_secs(1) });
        assert!(a.enter_wait_room());
        assert!(a.enter_wait_room());
        assert!(!a.enter_wait_room(), "third waiter must be refused");
        a.leave_wait_room(Duration::from_millis(1));
        assert!(a.enter_wait_room(), "slot frees after a waiter leaves");
        a.leave_wait_room(Duration::from_millis(1));
        a.leave_wait_room(Duration::from_millis(1));
        assert_eq!(a.metrics.waiting(), 0);
    }
}
