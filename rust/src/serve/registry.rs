//! Model registry: packed `.msqpack` models loaded for serving.
//!
//! A [`ServableModel`] keeps each layer exactly as packed — the n-bit
//! code stream plus `(bits, scale)` metadata — so resident model memory
//! equals the payload the compression ratio advertises (a 2-bit layer
//! really costs 1/16th of FP32 at serve time, not just on disk).
//!
//! Loading builds an **op-graph plan** from the per-layer descriptors
//! (pack v3): each layer is planned as a `linear` (rows × cols matrix
//! whose cols chain from the previous layer's output width) or a
//! `conv2d` (OHWI filters over an NHWC map whose spatial shape chains
//! from the v3 input-shape header), with fused ReLU wherever the
//! descriptor says so. Pre-v3 packs carry no descriptors; the loader
//! synthesizes the dense-MLP chain they implied, so v1/v2 files serve
//! byte-for-byte as before. The input width itself comes from the
//! `.msqpack` header ([`resolve_input_dim`]); an explicit `--input-dim`
//! is an *override* and the only option for v1 packs, which predate the
//! header field.
//!
//! [`ModelRegistry`] is the concurrent name → model map the server and
//! CLI share; models are immutable once loaded (`Arc`), so lookups are
//! lock-cheap and inference never takes the registry lock.

use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, RwLock};

use anyhow::{bail, ensure, Context, Result};

use super::kernels;
use crate::quant::pack::{Conv2dDesc, LayerOp, PackedLayer, PackedModel};
use crate::util::threadpool::ThreadPool;

/// Per-sample activation ceiling (elements). Lying conv headers could
/// otherwise make the executor allocate absurd maps at request time.
const MAX_ACT_ELEMS: usize = 1 << 28;

/// The input width serving should use for `pm`: an explicit override
/// wins; otherwise the `.msqpack` header. v1 packs carry no width, so
/// they *require* the override.
pub fn resolve_input_dim(pm: &PackedModel, override_dim: Option<usize>) -> Result<usize> {
    if let Some(d) = override_dim {
        ensure!(d > 0, "--input-dim must be nonzero");
        return Ok(d);
    }
    if pm.input_dim > 0 {
        return Ok(pm.input_dim);
    }
    bail!("pack has no input-dim header (pre-v2 .msqpack) — pass --input-dim explicitly")
}

/// Chain the MLP layer widths implied by the packed element counts:
/// returns each layer's output width (`rows_l`), so the last entry is
/// the class count. Errors when a layer's weights don't factor, or when
/// the pack carries conv descriptors (no flat dim chain exists).
pub fn chain_dims(pm: &PackedModel, input_dim: usize) -> Result<Vec<usize>> {
    ensure!(input_dim > 0, "input dim must be nonzero");
    ensure!(
        !pm.has_conv(),
        "pack has conv layers — the MLP dim chain is undefined (serve it instead)"
    );
    let mut dims = Vec::with_capacity(pm.layers.len());
    let mut cols = input_dim;
    for l in &pm.layers {
        if l.numel == 0 || l.numel % cols != 0 {
            bail!(
                "layer {:?}: {} weights do not factor over input dim {cols} — wrong input \
                 dim or non-MLP topology",
                l.name,
                l.numel
            );
        }
        let rows = l.numel / cols;
        dims.push(rows);
        cols = rows;
    }
    Ok(dims)
}

/// The hidden widths a packed MLP implies (the dim chain minus the final
/// class count) — what `msq eval-packed` feeds a fresh training backend.
pub fn mlp_hidden_dims(pm: &PackedModel, input_dim: usize) -> Result<Vec<usize>> {
    let mut dims = chain_dims(pm, input_dim)?;
    dims.pop(); // last entry is the class count, not a hidden width
    Ok(dims)
}

/// Activation shape flowing between planned layers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum ActShape {
    /// Flat vector of `dim` features (MLP traffic, post-flatten).
    Flat(usize),
    /// NHWC map of `h × w × c` (conv traffic).
    Spatial(usize, usize, usize),
}

impl ActShape {
    fn elems(self) -> usize {
        match self {
            ActShape::Flat(d) => d,
            ActShape::Spatial(h, w, c) => h * w * c,
        }
    }
}

/// One planned layer: the packed code stream plus the resolved execution
/// shape (what the executor dispatches on).
#[derive(Clone, Debug)]
pub enum LayerKind {
    /// `rows × cols` matrix (`rows` outputs, row-major code stream).
    Linear { rows: usize, cols: usize },
    /// OHWI filters over an `in_h × in_w × in_ch` NHWC map.
    Conv2d { desc: Conv2dDesc, in_h: usize, in_w: usize, out_h: usize, out_w: usize },
}

/// One packed layer plus its resolved plan (`kind`) and fused ReLU flag.
pub struct QuantLayer {
    pub name: String,
    pub bits: u8,
    pub scale: f32,
    pub kind: LayerKind,
    /// ReLU fused after this layer (from the v3 descriptor; implied MLP
    /// chain for pre-v3 packs).
    pub relu: bool,
    data: Vec<u8>,
}

impl QuantLayer {
    /// Plan one packed layer against the incoming activation shape;
    /// returns the layer and the shape it produces.
    fn plan(l: &PackedLayer, shape: ActShape) -> Result<(QuantLayer, ActShape)> {
        l.validate()?;
        ensure!(
            (1..=8).contains(&l.bits),
            "layer {:?}: serving kernels support 1..=8 bits, got {}",
            l.name,
            l.bits
        );
        let (kind, out_shape) = match l.op {
            LayerOp::Linear => {
                let cols = shape.elems();
                ensure!(cols > 0, "layer {:?}: zero input dimension", l.name);
                if l.numel == 0 || l.numel % cols != 0 {
                    bail!(
                        "layer {:?}: {} weights do not factor over input dim {} — wrong \
                         --input-dim or topology",
                        l.name,
                        l.numel,
                        cols
                    );
                }
                let rows = l.numel / cols;
                (LayerKind::Linear { rows, cols }, ActShape::Flat(rows))
            }
            LayerOp::Conv2d(desc) => {
                let ActShape::Spatial(in_h, in_w, c) = shape else {
                    bail!(
                        "layer {:?}: conv2d needs a spatial input — the pack header carries \
                         no input shape (pre-v3 file?) or a linear layer already flattened it",
                        l.name
                    );
                };
                ensure!(
                    c == desc.in_ch,
                    "layer {:?}: conv expects {} input channels, map has {c}",
                    l.name,
                    desc.in_ch
                );
                let (out_h, out_w) = desc
                    .out_hw(in_h, in_w)
                    .with_context(|| format!("layer {:?}", l.name))?;
                let out_elems = out_h
                    .checked_mul(out_w)
                    .and_then(|hw| hw.checked_mul(desc.out_ch))
                    .filter(|&n| n <= MAX_ACT_ELEMS)
                    .with_context(|| {
                        format!("layer {:?}: implausible output map size", l.name)
                    })?;
                debug_assert!(out_elems > 0);
                (
                    LayerKind::Conv2d { desc, in_h, in_w, out_h, out_w },
                    ActShape::Spatial(out_h, out_w, desc.out_ch),
                )
            }
        };
        let q = QuantLayer {
            name: l.name.clone(),
            bits: l.bits,
            scale: l.scale,
            kind,
            relu: l.relu,
            data: l.data.clone(),
        };
        Ok((q, out_shape))
    }

    /// Linear-only constructor kept for hand-built MLP plans (tests, and
    /// pre-v3 compatibility shims).
    pub fn from_packed(l: &PackedLayer, cols: usize) -> Result<QuantLayer> {
        ensure!(
            l.op == LayerOp::Linear,
            "layer {:?}: from_packed is linear-only; load conv packs via ServableModel",
            l.name
        );
        Ok(Self::plan(l, ActShape::Flat(cols))?.0)
    }

    /// Features flowing into this layer (per sample).
    pub fn in_elems(&self) -> usize {
        match self.kind {
            LayerKind::Linear { cols, .. } => cols,
            LayerKind::Conv2d { desc, in_h, in_w, .. } => in_h * in_w * desc.in_ch,
        }
    }

    /// Features flowing out of this layer (per sample).
    pub fn out_elems(&self) -> usize {
        match self.kind {
            LayerKind::Linear { rows, .. } => rows,
            LayerKind::Conv2d { desc, out_h, out_w, .. } => out_h * out_w * desc.out_ch,
        }
    }

    /// Packed weight element count.
    pub fn weight_numel(&self) -> usize {
        match self.kind {
            LayerKind::Linear { rows, cols } => rows * cols,
            LayerKind::Conv2d { desc, .. } => desc.weight_numel().unwrap_or(0),
        }
    }

    pub fn kind_name(&self) -> &'static str {
        match self.kind {
            LayerKind::Linear { .. } => "linear",
            LayerKind::Conv2d { .. } => "conv2d",
        }
    }

    /// Dispatch the layer's quantized kernel: `qgemm` for linear,
    /// `qconv2d` for conv (both decode codes on the fly; see
    /// [`kernels`]). ReLU fusion is applied by the caller.
    pub fn forward(&self, x: &[f32], batch: usize, out: &mut [f32], pool: Option<&ThreadPool>) {
        match &self.kind {
            LayerKind::Linear { rows, cols } => kernels::qgemm(
                &self.data, self.bits, self.scale, *rows, *cols, x, batch, out, pool,
            ),
            LayerKind::Conv2d { desc, in_h, in_w, .. } => kernels::qconv2d(
                &self.data, self.bits, self.scale, desc, *in_h, *in_w, x, batch, out, pool,
            ),
        }
    }

    pub fn payload_bytes(&self) -> usize {
        self.data.len()
    }
}

/// A packed model ready to answer inference requests: the planned op
/// graph over the packed layers, ReLU where the descriptors fuse it,
/// raw logits out of the last layer.
pub struct ServableModel {
    pub name: String,
    pub input_dim: usize,
    pub layers: Vec<QuantLayer>,
}

impl ServableModel {
    /// Plan `pm` for serving with an explicit flat input width (the
    /// override path; conv packs take their spatial shape from the
    /// header, which must agree with `input_dim`).
    pub fn from_packed(name: &str, pm: &PackedModel, input_dim: usize) -> Result<ServableModel> {
        ensure!(!pm.layers.is_empty(), "model {name:?}: packed file has no layers");
        ensure!(input_dim > 0, "model {name:?}: input dim must be nonzero");
        let mut shape = match pm.spatial_input() {
            Some((h, w, c))
                if h.checked_mul(w).and_then(|hw| hw.checked_mul(c)) == Some(input_dim) =>
            {
                ActShape::Spatial(h, w, c)
            }
            // a conv pack with a recorded shape the override contradicts
            // can never plan — say so directly instead of letting the
            // conv layer misdiagnose a "missing" shape header
            Some((h, w, c)) if pm.has_conv() => bail!(
                "model {name:?}: input dim {input_dim} contradicts the pack's recorded \
                 input shape {h}x{w}x{c} (= {}) — drop the --input-dim override",
                h.saturating_mul(w).saturating_mul(c)
            ),
            // an MLP pack with a disagreeing override falls back to flat;
            // the dim chain then accepts or rejects it as before
            _ => ActShape::Flat(input_dim),
        };
        let mut layers = Vec::with_capacity(pm.layers.len());
        for l in &pm.layers {
            let (q, next) =
                QuantLayer::plan(l, shape).with_context(|| format!("model {name:?}"))?;
            shape = next;
            layers.push(q);
        }
        Ok(ServableModel { name: name.to_string(), input_dim, layers })
    }

    /// Like [`ServableModel::from_packed`], but the input width is
    /// resolved from the pack header with `override_dim` winning
    /// (see [`resolve_input_dim`]).
    pub fn from_packed_auto(
        name: &str,
        pm: &PackedModel,
        override_dim: Option<usize>,
    ) -> Result<ServableModel> {
        let dim = resolve_input_dim(pm, override_dim)?;
        Self::from_packed(name, pm, dim)
    }

    /// Load a `.msqpack` from disk; the input width comes from the
    /// header unless `override_dim` is given.
    pub fn load(name: &str, path: &Path, override_dim: Option<usize>) -> Result<ServableModel> {
        let pm = PackedModel::load(path)?;
        Self::from_packed_auto(name, &pm, override_dim)
    }

    pub fn output_dim(&self) -> usize {
        self.layers.last().map(|l| l.out_elems()).unwrap_or(0)
    }

    /// Resident packed weight bytes (equals the `.msqpack` payload).
    pub fn payload_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.payload_bytes()).sum()
    }

    /// What the same weights would cost dense in FP32.
    pub fn fp32_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.weight_numel() * 4).sum()
    }

    pub fn compression(&self) -> f64 {
        self.fp32_bytes() as f64 / self.payload_bytes().max(1) as f64
    }

    /// Batched forward pass: `x` is `batch` rows of `input_dim`,
    /// batch-major (NHWC-flattened for conv models); returns `batch`
    /// rows of `output_dim` logits.
    pub fn infer_batch(
        &self,
        x: &[f32],
        batch: usize,
        pool: Option<&ThreadPool>,
    ) -> Result<Vec<f32>> {
        ensure!(
            x.len() == batch * self.input_dim,
            "model {:?}: got {} activations for batch {} x input dim {}",
            self.name,
            x.len(),
            batch,
            self.input_dim
        );
        let mut cur: Vec<f32> = Vec::new();
        for (i, layer) in self.layers.iter().enumerate() {
            // layer 0 reads the caller's buffer directly (no input copy)
            let src: &[f32] = if i == 0 { x } else { &cur };
            let mut next = vec![0f32; batch * layer.out_elems()];
            layer.forward(src, batch, &mut next, pool);
            if layer.relu {
                for v in next.iter_mut() {
                    *v = v.max(0.0);
                }
            }
            cur = next;
        }
        Ok(cur)
    }
}

/// Concurrent name → model map. Models are `Arc`-shared and immutable;
/// `get` clones the handle and drops the lock before any inference runs.
#[derive(Default)]
pub struct ModelRegistry {
    models: RwLock<HashMap<String, Arc<ServableModel>>>,
}

impl ModelRegistry {
    pub fn new() -> ModelRegistry {
        ModelRegistry::default()
    }

    pub fn insert(&self, model: ServableModel) -> Arc<ServableModel> {
        let m = Arc::new(model);
        self.models.write().unwrap().insert(m.name.clone(), m.clone());
        m
    }

    /// Load a `.msqpack` from disk and register it under `name`. The
    /// input width is inferred from the header; `override_dim` (when
    /// `Some`) wins, and is required for pre-v2 packs.
    pub fn load_file(
        &self,
        name: &str,
        path: &Path,
        override_dim: Option<usize>,
    ) -> Result<Arc<ServableModel>> {
        let m = ServableModel::load(name, path, override_dim)
            .with_context(|| format!("loading {path:?}"))?;
        Ok(self.insert(m))
    }

    pub fn get(&self, name: &str) -> Option<Arc<ServableModel>> {
        self.models.read().unwrap().get(name).cloned()
    }

    pub fn remove(&self, name: &str) -> bool {
        self.models.write().unwrap().remove(name).is_some()
    }

    pub fn names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.models.read().unwrap().keys().cloned().collect();
        v.sort();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::pack::unpack_layer;
    use crate::util::prng::Rng;

    fn rand_vec(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| r.normal() * 0.4).collect()
    }

    /// 2-layer packed MLP: input_dim -> 4-bit hidden -> 3-bit classes.
    fn toy_model(input_dim: usize, hidden: usize, classes: usize) -> PackedModel {
        PackedModel::synth_mlp(&[input_dim, hidden, classes], &[4, 3], 1).unwrap()
    }

    fn linear_dims(m: &ServableModel, i: usize) -> (usize, usize) {
        match m.layers[i].kind {
            LayerKind::Linear { rows, cols } => (rows, cols),
            _ => panic!("layer {i} is not linear"),
        }
    }

    #[test]
    fn shape_inference_chains_dims() {
        let m = ServableModel::from_packed("toy", &toy_model(12, 8, 4), 12).unwrap();
        assert_eq!(linear_dims(&m, 0), (8, 12));
        assert_eq!(linear_dims(&m, 1), (4, 8));
        assert_eq!(m.layers[0].kind_name(), "linear");
        assert!(m.layers[0].relu && !m.layers[1].relu);
        assert_eq!(m.output_dim(), 4);
        assert!(m.compression() > 4.0, "{}", m.compression());
    }

    #[test]
    fn bad_input_dim_is_rejected() {
        let err = ServableModel::from_packed("toy", &toy_model(12, 8, 4), 7).unwrap_err();
        assert!(err.to_string().contains("factor"), "{err}");
    }

    #[test]
    fn infer_matches_float_reference() {
        let pm = toy_model(12, 8, 4);
        let m = ServableModel::from_packed("toy", &pm, 12).unwrap();
        let batch = 5;
        let x = rand_vec(batch * 12, 9);

        // reference: dequantize fully, dense matmuls + ReLU
        let w1 = unpack_layer(&pm.layers[0]).unwrap();
        let w2 = unpack_layer(&pm.layers[1]).unwrap();
        let mut expect = Vec::new();
        for b in 0..batch {
            let xb = &x[b * 12..(b + 1) * 12];
            let h: Vec<f32> = (0..8)
                .map(|r| {
                    let s: f32 = (0..12).map(|j| w1[r * 12 + j] * xb[j]).sum();
                    s.max(0.0)
                })
                .collect();
            for r in 0..4 {
                expect.push((0..8).map(|j| w2[r * 8 + j] * h[j]).sum::<f32>());
            }
        }

        let got = m.infer_batch(&x, batch, None).unwrap();
        assert_eq!(got.len(), expect.len());
        for (i, (g, e)) in got.iter().zip(&expect).enumerate() {
            assert!((g - e).abs() < 1e-3, "idx {i}: {g} vs {e}");
        }
    }

    #[test]
    fn conv_plan_chains_spatial_shapes() {
        // 8x8x3 -> conv(3->4, /2) -> 4x4x4 -> conv(4->6, /2) -> 2x2x6
        // -> linear 24 -> 5
        let pm = PackedModel::synth_conv(8, 8, &[3, 4, 6, 5], &[4, 4, 3], 2).unwrap();
        let m = ServableModel::from_packed_auto("conv", &pm, None).unwrap();
        assert_eq!(m.input_dim, 8 * 8 * 3);
        assert_eq!(m.layers.len(), 3);
        match m.layers[0].kind {
            LayerKind::Conv2d { desc, in_h, in_w, out_h, out_w } => {
                assert_eq!((in_h, in_w, out_h, out_w), (8, 8, 4, 4));
                assert_eq!((desc.in_ch, desc.out_ch), (3, 4));
            }
            _ => panic!("layer 0 should be conv"),
        }
        match m.layers[1].kind {
            LayerKind::Conv2d { out_h, out_w, desc, .. } => {
                assert_eq!((out_h, out_w, desc.out_ch), (2, 2, 6));
            }
            _ => panic!("layer 1 should be conv"),
        }
        assert_eq!(linear_dims(&m, 2), (5, 24));
        assert!(m.layers[0].relu && m.layers[1].relu && !m.layers[2].relu);
        assert_eq!(m.output_dim(), 5);
        assert_eq!(m.layers[0].kind_name(), "conv2d");
        // payload accounting survives the conv plan
        assert_eq!(m.payload_bytes(), pm.payload_bytes());
        assert_eq!(m.fp32_bytes(), pm.fp32_bytes());
    }

    #[test]
    fn conv_infer_matches_dense_reference() {
        let pm = PackedModel::synth_conv(8, 8, &[3, 4, 5], &[5, 4], 7).unwrap();
        let m = ServableModel::from_packed_auto("conv", &pm, None).unwrap();
        let batch = 3;
        let x = rand_vec(batch * m.input_dim, 31);

        // dense f32 reference: the shared conv oracle + ReLU + linear head
        let wc = unpack_layer(&pm.layers[0]).unwrap();
        let wl = unpack_layer(&pm.layers[1]).unwrap();
        let d = match pm.layers[0].op {
            crate::quant::pack::LayerOp::Conv2d(d) => d,
            _ => unreachable!(),
        };
        let (oh, ow) = d.out_hw(8, 8).unwrap();
        let flat = oh * ow * d.out_ch;
        let mut map = kernels::dense_conv_ref(&wc, &d, 8, 8, &x, batch);
        for v in map.iter_mut() {
            *v = v.max(0.0);
        }
        let mut expect = Vec::new();
        for b in 0..batch {
            let mb = &map[b * flat..(b + 1) * flat];
            for r in 0..5 {
                let s: f64 = (0..flat).map(|j| wl[r * flat + j] as f64 * mb[j] as f64).sum();
                expect.push(s as f32);
            }
        }

        let got = m.infer_batch(&x, batch, None).unwrap();
        assert_eq!(got.len(), expect.len());
        for (i, (g, e)) in got.iter().zip(&expect).enumerate() {
            assert!((g - e).abs() < 1e-4, "idx {i}: {g} vs {e}");
        }
        // pooled execution is bit-identical to serial
        let pool = ThreadPool::new(3);
        assert_eq!(m.infer_batch(&x, batch, Some(&pool)).unwrap(), got);
    }

    #[test]
    fn conv_without_spatial_header_is_rejected() {
        let mut pm = PackedModel::synth_conv(8, 8, &[3, 4, 5], &[4, 3], 3).unwrap();
        pm.input_hwc = (0, 0, 0); // strip the shape (hand-assembled pack)
        let err = ServableModel::from_packed_auto("c", &pm, None).unwrap_err();
        assert!(err.to_string().contains("spatial"), "{err}");
        // and chain_dims refuses conv packs outright
        assert!(chain_dims(&pm, 192).unwrap_err().to_string().contains("conv"));
    }

    #[test]
    fn conv_override_contradicting_recorded_shape_says_so() {
        let pm = PackedModel::synth_conv(8, 8, &[3, 4, 5], &[4, 3], 3).unwrap();
        let err = ServableModel::from_packed("c", &pm, 999).unwrap_err();
        let msg = err.to_string();
        assert!(
            msg.contains("contradicts") && msg.contains("8x8x3"),
            "want a pointed override-vs-shape diagnosis, got: {msg}"
        );
    }

    #[test]
    fn conv_channel_mismatch_is_rejected() {
        let mut pm = PackedModel::synth_conv(8, 8, &[3, 4, 5], &[4, 3], 3).unwrap();
        // claim a 4-channel input: h*w*c must match input_dim too
        pm.input_hwc = (8, 6, 4);
        pm.input_dim = 8 * 6 * 4;
        let err = ServableModel::from_packed_auto("c", &pm, None).unwrap_err();
        assert!(err.to_string().contains("channels"), "{err}");
    }

    #[test]
    fn registry_lifecycle() {
        let reg = ModelRegistry::new();
        assert!(reg.get("toy").is_none());
        let pm = toy_model(6, 4, 2);
        let m = ServableModel::from_packed("toy", &pm, 6).unwrap();
        reg.insert(m);
        assert_eq!(reg.names(), vec!["toy"]);
        assert_eq!(reg.get("toy").unwrap().output_dim(), 2);
        assert!(reg.remove("toy"));
        assert!(!reg.remove("toy"));
    }

    #[test]
    fn file_roundtrip_through_registry() {
        let pm = toy_model(10, 6, 3);
        let path = std::env::temp_dir().join("msq_registry_test.msqpack");
        pm.save(&path).unwrap();
        let reg = ModelRegistry::new();
        // no override: the input width comes from the pack header
        let m = reg.load_file("disk", &path, None).unwrap();
        assert_eq!(m.input_dim, 10);
        assert_eq!(m.output_dim(), 3);
        // an explicit override still wins — and a wrong one errors cleanly
        assert!(reg.load_file("bad", &path, Some(7)).is_err());
    }

    #[test]
    fn conv_file_roundtrip_through_registry() {
        let pm = PackedModel::synth_conv(8, 8, &[3, 4, 5], &[4, 3], 11).unwrap();
        let path = std::env::temp_dir().join("msq_registry_conv.msqpack");
        pm.save(&path).unwrap();
        let reg = ModelRegistry::new();
        let m = reg.load_file("conv", &path, None).unwrap();
        assert_eq!(m.input_dim, 192);
        assert_eq!(m.output_dim(), 5);
        assert_eq!(m.layers[0].kind_name(), "conv2d");
        // served logits match the in-memory plan bit-for-bit
        let direct = ServableModel::from_packed_auto("x", &pm, None).unwrap();
        let x = rand_vec(2 * 192, 5);
        assert_eq!(
            m.infer_batch(&x, 2, None).unwrap(),
            direct.infer_batch(&x, 2, None).unwrap()
        );
    }

    #[test]
    fn input_dim_resolution_precedence() {
        let pm = toy_model(12, 8, 4);
        assert_eq!(resolve_input_dim(&pm, None).unwrap(), 12);
        assert_eq!(resolve_input_dim(&pm, Some(6)).unwrap(), 6);
        assert!(resolve_input_dim(&pm, Some(0)).is_err());
        // v1-style pack: no header width, override required
        let v1 = PackedModel { input_dim: 0, layers: pm.layers.clone(), ..Default::default() };
        assert_eq!(resolve_input_dim(&v1, Some(12)).unwrap(), 12);
        let err = resolve_input_dim(&v1, None).unwrap_err();
        assert!(err.to_string().contains("input-dim"), "{err}");
    }

    #[test]
    fn dim_chain_derivation() {
        let pm = toy_model(12, 8, 4);
        assert_eq!(chain_dims(&pm, 12).unwrap(), vec![8, 4]);
        assert_eq!(mlp_hidden_dims(&pm, 12).unwrap(), vec![8]);
        assert!(chain_dims(&pm, 7).is_err());
        assert!(chain_dims(&pm, 0).is_err());
    }
}
