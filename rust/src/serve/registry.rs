//! Model registry: packed `.msqpack` models loaded for serving.
//!
//! A [`ServableModel`] keeps each layer exactly as packed — the n-bit
//! code stream plus `(bits, scale)` metadata — so resident model memory
//! equals the payload the compression ratio advertises (a 2-bit layer
//! really costs 1/16th of FP32 at serve time, not just on disk). Layer
//! shapes are derived MLP-style by chaining dimensions from the input
//! width: `rows_l = numel_l / cols_l`, `cols_{l+1} = rows_l`, rejecting
//! models whose element counts don't factor. The input width itself
//! comes from the `.msqpack` v2 header ([`resolve_input_dim`]); an
//! explicit `--input-dim` is an *override* and the only option for v1
//! packs, which predate the header field.
//!
//! [`ModelRegistry`] is the concurrent name → model map the server and
//! CLI share; models are immutable once loaded (`Arc`), so lookups are
//! lock-cheap and inference never takes the registry lock.

use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, RwLock};

use anyhow::{bail, ensure, Context, Result};

use super::kernels;
use crate::quant::pack::{PackedLayer, PackedModel};
use crate::util::threadpool::ThreadPool;

/// The input width serving should use for `pm`: an explicit override
/// wins; otherwise the `.msqpack` v2 header. v1 packs carry no width, so
/// they *require* the override.
pub fn resolve_input_dim(pm: &PackedModel, override_dim: Option<usize>) -> Result<usize> {
    if let Some(d) = override_dim {
        ensure!(d > 0, "--input-dim must be nonzero");
        return Ok(d);
    }
    if pm.input_dim > 0 {
        return Ok(pm.input_dim);
    }
    bail!("pack has no input-dim header (pre-v2 .msqpack) — pass --input-dim explicitly")
}

/// Chain the MLP layer widths implied by the packed element counts:
/// returns each layer's output width (`rows_l`), so the last entry is
/// the class count. Errors when a layer's weights don't factor.
pub fn chain_dims(pm: &PackedModel, input_dim: usize) -> Result<Vec<usize>> {
    ensure!(input_dim > 0, "input dim must be nonzero");
    let mut dims = Vec::with_capacity(pm.layers.len());
    let mut cols = input_dim;
    for l in &pm.layers {
        if l.numel == 0 || l.numel % cols != 0 {
            bail!(
                "layer {:?}: {} weights do not factor over input dim {cols} — wrong input \
                 dim or non-MLP topology",
                l.name,
                l.numel
            );
        }
        let rows = l.numel / cols;
        dims.push(rows);
        cols = rows;
    }
    Ok(dims)
}

/// The hidden widths a packed MLP implies (the dim chain minus the final
/// class count) — what `msq eval-packed` feeds a fresh training backend.
pub fn mlp_hidden_dims(pm: &PackedModel, input_dim: usize) -> Result<Vec<usize>> {
    let mut dims = chain_dims(pm, input_dim)?;
    dims.pop(); // last entry is the class count, not a hidden width
    Ok(dims)
}

/// One packed layer plus its derived matrix shape (`rows` outputs ×
/// `cols` inputs, row-major code stream).
pub struct QuantLayer {
    pub name: String,
    pub bits: u8,
    pub scale: f32,
    pub rows: usize,
    pub cols: usize,
    data: Vec<u8>,
}

impl QuantLayer {
    pub fn from_packed(l: &PackedLayer, cols: usize) -> Result<QuantLayer> {
        l.validate()?;
        ensure!(
            (1..=8).contains(&l.bits),
            "layer {:?}: serving kernels support 1..=8 bits, got {}",
            l.name,
            l.bits
        );
        ensure!(cols > 0, "layer {:?}: zero input dimension", l.name);
        if l.numel == 0 || l.numel % cols != 0 {
            bail!(
                "layer {:?}: {} weights do not factor over input dim {} — wrong --input-dim \
                 or non-MLP topology",
                l.name,
                l.numel,
                cols
            );
        }
        Ok(QuantLayer {
            name: l.name.clone(),
            bits: l.bits,
            scale: l.scale,
            rows: l.numel / cols,
            cols,
            data: l.data.clone(),
        })
    }

    /// `out[b*rows + r] = Σ_j dequant(codes[r,j]) · x[b*cols + j]`,
    /// decoding codes on the fly (see [`kernels::qgemm`]).
    pub fn forward(&self, x: &[f32], batch: usize, out: &mut [f32], pool: Option<&ThreadPool>) {
        kernels::qgemm(
            &self.data, self.bits, self.scale, self.rows, self.cols, x, batch, out, pool,
        );
    }

    pub fn payload_bytes(&self) -> usize {
        self.data.len()
    }
}

/// A packed model ready to answer inference requests: an MLP over the
/// packed layers with ReLU between hidden layers and raw logits out.
pub struct ServableModel {
    pub name: String,
    pub input_dim: usize,
    pub layers: Vec<QuantLayer>,
}

impl ServableModel {
    pub fn from_packed(name: &str, pm: &PackedModel, input_dim: usize) -> Result<ServableModel> {
        ensure!(!pm.layers.is_empty(), "model {name:?}: packed file has no layers");
        let mut dim = input_dim;
        let mut layers = Vec::with_capacity(pm.layers.len());
        for l in &pm.layers {
            let q = QuantLayer::from_packed(l, dim).with_context(|| format!("model {name:?}"))?;
            dim = q.rows;
            layers.push(q);
        }
        Ok(ServableModel { name: name.to_string(), input_dim, layers })
    }

    /// Like [`ServableModel::from_packed`], but the input width is
    /// resolved from the pack header with `override_dim` winning
    /// (see [`resolve_input_dim`]).
    pub fn from_packed_auto(
        name: &str,
        pm: &PackedModel,
        override_dim: Option<usize>,
    ) -> Result<ServableModel> {
        let dim = resolve_input_dim(pm, override_dim)?;
        Self::from_packed(name, pm, dim)
    }

    /// Load a `.msqpack` from disk; the input width comes from the v2
    /// header unless `override_dim` is given.
    pub fn load(name: &str, path: &Path, override_dim: Option<usize>) -> Result<ServableModel> {
        let pm = PackedModel::load(path)?;
        Self::from_packed_auto(name, &pm, override_dim)
    }

    pub fn output_dim(&self) -> usize {
        self.layers.last().map(|l| l.rows).unwrap_or(0)
    }

    /// Resident packed weight bytes (equals the `.msqpack` payload).
    pub fn payload_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.payload_bytes()).sum()
    }

    /// What the same weights would cost dense in FP32.
    pub fn fp32_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.rows * l.cols * 4).sum()
    }

    pub fn compression(&self) -> f64 {
        self.fp32_bytes() as f64 / self.payload_bytes().max(1) as f64
    }

    /// Batched forward pass: `x` is `batch` rows of `input_dim`,
    /// batch-major; returns `batch` rows of `output_dim` logits.
    pub fn infer_batch(
        &self,
        x: &[f32],
        batch: usize,
        pool: Option<&ThreadPool>,
    ) -> Result<Vec<f32>> {
        ensure!(
            x.len() == batch * self.input_dim,
            "model {:?}: got {} activations for batch {} x input dim {}",
            self.name,
            x.len(),
            batch,
            self.input_dim
        );
        let mut cur: Vec<f32> = Vec::new();
        let last = self.layers.len().saturating_sub(1);
        for (i, layer) in self.layers.iter().enumerate() {
            // layer 0 reads the caller's buffer directly (no input copy)
            let src: &[f32] = if i == 0 { x } else { &cur };
            let mut next = vec![0f32; batch * layer.rows];
            layer.forward(src, batch, &mut next, pool);
            if i < last {
                for v in next.iter_mut() {
                    *v = v.max(0.0); // ReLU on hidden activations
                }
            }
            cur = next;
        }
        Ok(cur)
    }
}

/// Concurrent name → model map. Models are `Arc`-shared and immutable;
/// `get` clones the handle and drops the lock before any inference runs.
#[derive(Default)]
pub struct ModelRegistry {
    models: RwLock<HashMap<String, Arc<ServableModel>>>,
}

impl ModelRegistry {
    pub fn new() -> ModelRegistry {
        ModelRegistry::default()
    }

    pub fn insert(&self, model: ServableModel) -> Arc<ServableModel> {
        let m = Arc::new(model);
        self.models.write().unwrap().insert(m.name.clone(), m.clone());
        m
    }

    /// Load a `.msqpack` from disk and register it under `name`. The
    /// input width is inferred from the v2 header; `override_dim` (when
    /// `Some`) wins, and is required for pre-v2 packs.
    pub fn load_file(
        &self,
        name: &str,
        path: &Path,
        override_dim: Option<usize>,
    ) -> Result<Arc<ServableModel>> {
        let m = ServableModel::load(name, path, override_dim)
            .with_context(|| format!("loading {path:?}"))?;
        Ok(self.insert(m))
    }

    pub fn get(&self, name: &str) -> Option<Arc<ServableModel>> {
        self.models.read().unwrap().get(name).cloned()
    }

    pub fn remove(&self, name: &str) -> bool {
        self.models.write().unwrap().remove(name).is_some()
    }

    pub fn names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.models.read().unwrap().keys().cloned().collect();
        v.sort();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::pack::unpack_layer;
    use crate::util::prng::Rng;

    fn rand_vec(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| r.normal() * 0.4).collect()
    }

    /// 2-layer packed MLP: input_dim -> 4-bit hidden -> 3-bit classes.
    fn toy_model(input_dim: usize, hidden: usize, classes: usize) -> PackedModel {
        PackedModel::synth_mlp(&[input_dim, hidden, classes], &[4, 3], 1).unwrap()
    }

    #[test]
    fn shape_inference_chains_dims() {
        let m = ServableModel::from_packed("toy", &toy_model(12, 8, 4), 12).unwrap();
        assert_eq!(m.layers[0].rows, 8);
        assert_eq!(m.layers[0].cols, 12);
        assert_eq!(m.layers[1].rows, 4);
        assert_eq!(m.layers[1].cols, 8);
        assert_eq!(m.output_dim(), 4);
        assert!(m.compression() > 4.0, "{}", m.compression());
    }

    #[test]
    fn bad_input_dim_is_rejected() {
        let err = ServableModel::from_packed("toy", &toy_model(12, 8, 4), 7).unwrap_err();
        assert!(err.to_string().contains("factor"), "{err}");
    }

    #[test]
    fn infer_matches_float_reference() {
        let pm = toy_model(12, 8, 4);
        let m = ServableModel::from_packed("toy", &pm, 12).unwrap();
        let batch = 5;
        let x = rand_vec(batch * 12, 9);

        // reference: dequantize fully, dense matmuls + ReLU
        let w1 = unpack_layer(&pm.layers[0]).unwrap();
        let w2 = unpack_layer(&pm.layers[1]).unwrap();
        let mut expect = Vec::new();
        for b in 0..batch {
            let xb = &x[b * 12..(b + 1) * 12];
            let h: Vec<f32> = (0..8)
                .map(|r| {
                    let s: f32 = (0..12).map(|j| w1[r * 12 + j] * xb[j]).sum();
                    s.max(0.0)
                })
                .collect();
            for r in 0..4 {
                expect.push((0..8).map(|j| w2[r * 8 + j] * h[j]).sum::<f32>());
            }
        }

        let got = m.infer_batch(&x, batch, None).unwrap();
        assert_eq!(got.len(), expect.len());
        for (i, (g, e)) in got.iter().zip(&expect).enumerate() {
            assert!((g - e).abs() < 1e-3, "idx {i}: {g} vs {e}");
        }
    }

    #[test]
    fn registry_lifecycle() {
        let reg = ModelRegistry::new();
        assert!(reg.get("toy").is_none());
        let pm = toy_model(6, 4, 2);
        let m = ServableModel::from_packed("toy", &pm, 6).unwrap();
        reg.insert(m);
        assert_eq!(reg.names(), vec!["toy"]);
        assert_eq!(reg.get("toy").unwrap().output_dim(), 2);
        assert!(reg.remove("toy"));
        assert!(!reg.remove("toy"));
    }

    #[test]
    fn file_roundtrip_through_registry() {
        let pm = toy_model(10, 6, 3);
        let path = std::env::temp_dir().join("msq_registry_test.msqpack");
        pm.save(&path).unwrap();
        let reg = ModelRegistry::new();
        // no override: the input width comes from the v2 pack header
        let m = reg.load_file("disk", &path, None).unwrap();
        assert_eq!(m.input_dim, 10);
        assert_eq!(m.output_dim(), 3);
        // an explicit override still wins — and a wrong one errors cleanly
        assert!(reg.load_file("bad", &path, Some(7)).is_err());
    }

    #[test]
    fn input_dim_resolution_precedence() {
        let pm = toy_model(12, 8, 4);
        assert_eq!(resolve_input_dim(&pm, None).unwrap(), 12);
        assert_eq!(resolve_input_dim(&pm, Some(6)).unwrap(), 6);
        assert!(resolve_input_dim(&pm, Some(0)).is_err());
        // v1-style pack: no header width, override required
        let v1 = PackedModel { input_dim: 0, layers: pm.layers.clone() };
        assert_eq!(resolve_input_dim(&v1, Some(12)).unwrap(), 12);
        let err = resolve_input_dim(&v1, None).unwrap_err();
        assert!(err.to_string().contains("input-dim"), "{err}");
    }

    #[test]
    fn dim_chain_derivation() {
        let pm = toy_model(12, 8, 4);
        assert_eq!(chain_dims(&pm, 12).unwrap(), vec![8, 4]);
        assert_eq!(mlp_hidden_dims(&pm, 12).unwrap(), vec![8]);
        assert!(chain_dims(&pm, 7).is_err());
        assert!(chain_dims(&pm, 0).is_err());
    }
}
