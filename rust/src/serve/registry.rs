//! Model registry: packed `.msqpack` models loaded for serving.
//!
//! A [`ServableModel`] keeps each layer exactly as packed — the n-bit
//! code stream plus `(bits, scale)` metadata — so resident model memory
//! equals the payload the compression ratio advertises (a 2-bit layer
//! really costs 1/16th of FP32 at serve time, not just on disk).
//!
//! Loading builds an **op-graph plan** from the per-layer descriptors
//! (pack v3/v4): each layer is planned as a `linear` (rows × cols matrix
//! whose cols chain from the previous layer's output width), a
//! `conv2d` (OHWI filters over an NHWC map whose spatial shape chains
//! from the v3 input-shape header), or one of the v4 transformer ops
//! (`seqview` / `layernorm` / `attention` / `residual` / `meanpool`,
//! plus position-wise linears over token sequences), with fused
//! ReLU/GELU wherever the descriptor says so. Attention records
//! *consume* the four projection linears they reference — those fold
//! into the attention plan and never execute standalone. Pre-v3 packs
//! carry no descriptors; the loader synthesizes the dense-MLP chain
//! they implied, so v1/v2 files serve byte-for-byte as before. The
//! input width itself comes from the `.msqpack` header
//! ([`resolve_input_dim`]); an explicit `--input-dim` is an *override*
//! and the only option for v1 packs, which predate the header field.
//!
//! [`ModelRegistry`] is the concurrent name → model map the server and
//! CLI share; models are immutable once loaded (`Arc`), so lookups are
//! lock-cheap and inference never takes the registry lock.

use std::collections::HashMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use anyhow::{bail, ensure, Context, Result};

use super::kernels;
use super::kernels::ProjWeights;
use super::weightcache::{self, CacheKey};
use crate::kernels::{axpy, gelu, layernorm_rows, ActQuant, LN_EPS, MAX_INT_DOT_COLS};
use crate::quant::pack::{BitReader, Conv2dDesc, LayerOp, PackedLayer, PackedModel};
use crate::util::json::Json;
use crate::util::threadpool::ThreadPool;

/// Per-sample activation ceiling (elements). Lying conv headers could
/// otherwise make the executor allocate absurd maps at request time.
const MAX_ACT_ELEMS: usize = 1 << 28;

/// The input width serving should use for `pm`: an explicit override
/// wins; otherwise the `.msqpack` header. v1 packs carry no width, so
/// they *require* the override.
pub fn resolve_input_dim(pm: &PackedModel, override_dim: Option<usize>) -> Result<usize> {
    if let Some(d) = override_dim {
        ensure!(d > 0, "--input-dim must be nonzero");
        return Ok(d);
    }
    if pm.input_dim > 0 {
        return Ok(pm.input_dim);
    }
    bail!("pack has no input-dim header (pre-v2 .msqpack) — pass --input-dim explicitly")
}

/// Chain the MLP layer widths implied by the packed element counts:
/// returns each layer's output width (`rows_l`), so the last entry is
/// the class count. Errors when a layer's weights don't factor, or when
/// the pack carries conv descriptors (no flat dim chain exists).
pub fn chain_dims(pm: &PackedModel, input_dim: usize) -> Result<Vec<usize>> {
    ensure!(input_dim > 0, "input dim must be nonzero");
    ensure!(
        !pm.has_conv(),
        "pack has conv layers — the MLP dim chain is undefined (serve it instead)"
    );
    ensure!(
        !pm.has_transformer(),
        "pack has transformer layers — the MLP dim chain is undefined (serve it instead)"
    );
    let mut dims = Vec::with_capacity(pm.layers.len());
    let mut cols = input_dim;
    for l in &pm.layers {
        if l.numel == 0 || l.numel % cols != 0 {
            bail!(
                "layer {:?}: {} weights do not factor over input dim {cols} — wrong input \
                 dim or non-MLP topology",
                l.name,
                l.numel
            );
        }
        let rows = l.numel / cols;
        dims.push(rows);
        cols = rows;
    }
    Ok(dims)
}

/// The hidden widths a packed MLP implies (the dim chain minus the final
/// class count) — what `msq eval-packed` feeds a fresh training backend.
pub fn mlp_hidden_dims(pm: &PackedModel, input_dim: usize) -> Result<Vec<usize>> {
    let mut dims = chain_dims(pm, input_dim)?;
    dims.pop(); // last entry is the class count, not a hidden width
    Ok(dims)
}

/// Activation shape flowing between planned layers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum ActShape {
    /// Flat vector of `dim` features (MLP traffic, post-flatten).
    Flat(usize),
    /// NHWC map of `h × w × c` (conv traffic).
    Spatial(usize, usize, usize),
    /// Token sequence of `seq × dim` (transformer traffic, v4).
    Seq(usize, usize),
}

impl ActShape {
    fn elems(self) -> usize {
        match self {
            ActShape::Flat(d) => d,
            ActShape::Spatial(h, w, c) => h * w * c,
            ActShape::Seq(s, d) => s * d,
        }
    }
}

/// One planned layer: the packed code stream plus the resolved execution
/// shape (what the executor dispatches on).
#[derive(Clone, Debug)]
pub enum LayerKind {
    /// `rows × cols` matrix (`rows` outputs, row-major code stream).
    Linear { rows: usize, cols: usize },
    /// OHWI filters over an `in_h × in_w × in_ch` NHWC map.
    Conv2d { desc: Conv2dDesc, in_h: usize, in_w: usize, out_h: usize, out_w: usize },
    /// Position-wise `rows × cols` matrix over every token of a
    /// `seq × cols` sequence (v4 transformer traffic).
    LinearSeq { rows: usize, cols: usize, seq: usize },
    /// Reshape `seq·dim` flat features into a `seq × dim` sequence (v4).
    SeqView { seq: usize, dim: usize },
    /// Affine-free LayerNorm over each of `rows` rows of `cols` (v4).
    LayerNorm { rows: usize, cols: usize },
    /// Multi-head self-attention over a `seq × heads·head_dim` sequence;
    /// the four projections were folded out of consumed linear records
    /// at plan time (v4).
    Attention {
        heads: usize,
        head_dim: usize,
        seq: usize,
        q: ProjWeights,
        k: ProjWeights,
        v: ProjWeights,
        proj: ProjWeights,
    },
    /// Elementwise add of planned layer `src`'s saved output (v4). The
    /// executor handles this directly — `forward` is never dispatched.
    Residual { src: usize, elems: usize },
    /// Mean over the sequence axis: `seq × dim → dim` (v4).
    MeanPool { seq: usize, dim: usize },
}

/// One packed layer plus its resolved plan (`kind`) and fused-activation
/// flags.
pub struct QuantLayer {
    pub name: String,
    pub bits: u8,
    pub scale: f32,
    pub kind: LayerKind,
    /// ReLU fused after this layer (from the v3 descriptor; implied MLP
    /// chain for pre-v3 packs).
    pub relu: bool,
    /// GELU fused after this layer (v4; exclusive with `relu`).
    pub gelu: bool,
    /// Static worst-case magnitude of this layer's *input* activations,
    /// assuming unit-bounded model inputs (|x| ≤ 1) and chaining each
    /// layer's analytic amplification (see [`QuantLayer::out_bound`]).
    /// The integer serving path quantizes activations against this when
    /// the live observers have no EMA yet — conservative (wide lattice,
    /// coarser step) but never clips in-spec traffic. Set by
    /// [`ServableModel::from_packed`]; the bare linear constructor leaves
    /// the unit default.
    pub act_bound: f32,
    /// Weight-cache identity `(model generation uid, planned layer
    /// index)`, stamped by [`ServableModel::from_packed`]. `None` for
    /// hand-built layers — those decode fresh on every call.
    cache_id: Option<(u64, u32)>,
    data: Vec<u8>,
}

impl QuantLayer {
    /// Plan one packed layer against the incoming activation shape;
    /// returns the layer and the shape it produces.
    fn plan(l: &PackedLayer, shape: ActShape) -> Result<(QuantLayer, ActShape)> {
        l.validate()?;
        ensure!(
            (1..=8).contains(&l.bits),
            "layer {:?}: serving kernels support 1..=8 bits, got {}",
            l.name,
            l.bits
        );
        let (kind, out_shape) = match l.op {
            LayerOp::Linear => {
                let cols = shape.elems();
                ensure!(cols > 0, "layer {:?}: zero input dimension", l.name);
                if l.numel == 0 || l.numel % cols != 0 {
                    bail!(
                        "layer {:?}: {} weights do not factor over input dim {} — wrong \
                         --input-dim or topology",
                        l.name,
                        l.numel,
                        cols
                    );
                }
                let rows = l.numel / cols;
                (LayerKind::Linear { rows, cols }, ActShape::Flat(rows))
            }
            LayerOp::Conv2d(desc) => {
                let ActShape::Spatial(in_h, in_w, c) = shape else {
                    bail!(
                        "layer {:?}: conv2d needs a spatial input — the pack header carries \
                         no input shape (pre-v3 file?) or a linear layer already flattened it",
                        l.name
                    );
                };
                ensure!(
                    c == desc.in_ch,
                    "layer {:?}: conv expects {} input channels, map has {c}",
                    l.name,
                    desc.in_ch
                );
                let (out_h, out_w) = desc
                    .out_hw(in_h, in_w)
                    .with_context(|| format!("layer {:?}", l.name))?;
                let out_elems = out_h
                    .checked_mul(out_w)
                    .and_then(|hw| hw.checked_mul(desc.out_ch))
                    .filter(|&n| n <= MAX_ACT_ELEMS)
                    .with_context(|| {
                        format!("layer {:?}: implausible output map size", l.name)
                    })?;
                debug_assert!(out_elems > 0);
                (
                    LayerKind::Conv2d { desc, in_h, in_w, out_h, out_w },
                    ActShape::Spatial(out_h, out_w, desc.out_ch),
                )
            }
        };
        let q = QuantLayer {
            name: l.name.clone(),
            bits: l.bits,
            scale: l.scale,
            kind,
            relu: l.relu,
            gelu: l.gelu,
            act_bound: 1.0,
            cache_id: None,
            data: l.data.clone(),
        };
        Ok((q, out_shape))
    }

    /// Graph-aware planner for the v4 ops (delegates flat linear and conv
    /// records to [`QuantLayer::plan`]). `planned_of[i]` maps pack layer
    /// index → planned layer index (`usize::MAX` = not planned yet or
    /// consumed), `out_shapes[p]` is planned layer `p`'s output shape —
    /// both needed to resolve residual sources. The caller has already
    /// run [`PackedModel::validate_graph`], so attention refs are known
    /// to be in-range distinct linears of the right size.
    fn plan_graph(
        l: &PackedLayer,
        shape: ActShape,
        pm: &PackedModel,
        planned_of: &[usize],
        out_shapes: &[ActShape],
    ) -> Result<(QuantLayer, ActShape)> {
        let structural = |kind: LayerKind, out: ActShape| -> (QuantLayer, ActShape) {
            (
                QuantLayer {
                    name: l.name.clone(),
                    bits: l.bits,
                    scale: l.scale,
                    kind,
                    relu: l.relu,
                    gelu: l.gelu,
                    act_bound: 1.0,
                    cache_id: None,
                    data: l.data.clone(),
                },
                out,
            )
        };
        match l.op {
            LayerOp::Conv2d(_) => Self::plan(l, shape),
            LayerOp::Linear => {
                let ActShape::Seq(s, d) = shape else {
                    return Self::plan(l, shape);
                };
                l.validate()?;
                ensure!(
                    (1..=8).contains(&l.bits),
                    "layer {:?}: serving kernels support 1..=8 bits, got {}",
                    l.name,
                    l.bits
                );
                if l.numel == 0 || l.numel % d != 0 {
                    bail!(
                        "layer {:?}: {} weights do not factor over token dim {d}",
                        l.name,
                        l.numel
                    );
                }
                let rows = l.numel / d;
                s.checked_mul(rows)
                    .filter(|&n| n <= MAX_ACT_ELEMS)
                    .with_context(|| format!("layer {:?}: implausible sequence size", l.name))?;
                Ok(structural(
                    LayerKind::LinearSeq { rows, cols: d, seq: s },
                    ActShape::Seq(s, rows),
                ))
            }
            LayerOp::SeqView { seq, dim } => {
                l.validate()?;
                let ActShape::Flat(n) = shape else {
                    bail!("layer {:?}: seqview needs a flat input, got {shape:?}", l.name);
                };
                let prod = seq
                    .checked_mul(dim)
                    .filter(|&p| p <= MAX_ACT_ELEMS)
                    .with_context(|| format!("layer {:?}: implausible seqview size", l.name))?;
                ensure!(
                    prod == n,
                    "layer {:?}: seqview {seq}x{dim} does not match input width {n}",
                    l.name
                );
                Ok(structural(LayerKind::SeqView { seq, dim }, ActShape::Seq(seq, dim)))
            }
            LayerOp::LayerNorm => {
                l.validate()?;
                let (rows, cols) = match shape {
                    ActShape::Seq(s, d) => (s, d),
                    ActShape::Flat(d) => (1, d),
                    ActShape::Spatial(..) => {
                        bail!("layer {:?}: layernorm over a spatial map is not planned", l.name)
                    }
                };
                ensure!(cols > 0, "layer {:?}: zero-width layernorm", l.name);
                Ok(structural(LayerKind::LayerNorm { rows, cols }, shape))
            }
            LayerOp::MeanPool => {
                l.validate()?;
                let ActShape::Seq(s, d) = shape else {
                    bail!("layer {:?}: meanpool needs a token sequence, got {shape:?}", l.name);
                };
                Ok(structural(LayerKind::MeanPool { seq: s, dim: d }, ActShape::Flat(d)))
            }
            LayerOp::Residual { src } => {
                l.validate()?;
                let p = planned_of.get(src).copied().unwrap_or(usize::MAX);
                ensure!(
                    p != usize::MAX,
                    "layer {:?}: residual source {src} is not an executed layer",
                    l.name
                );
                ensure!(
                    out_shapes[p] == shape,
                    "layer {:?}: residual source shape {:?} does not match incoming {shape:?}",
                    l.name,
                    out_shapes[p]
                );
                Ok(structural(LayerKind::Residual { src: p, elems: shape.elems() }, shape))
            }
            LayerOp::Attention(a) => {
                l.validate()?;
                let d = a
                    .model_dim()
                    .with_context(|| format!("layer {:?}: head product overflows", l.name))?;
                let ActShape::Seq(s, dim) = shape else {
                    bail!(
                        "layer {:?}: attention needs a token sequence (seqview first), got \
                         {shape:?}",
                        l.name
                    );
                };
                ensure!(
                    dim == d,
                    "layer {:?}: attention model dim {d} vs incoming token dim {dim}",
                    l.name
                );
                ensure!(
                    s == a.seq_len,
                    "layer {:?}: descriptor seq_len {} vs incoming sequence {s}",
                    l.name,
                    a.seq_len
                );
                // score matrices are heads·s·s floats per sample
                a.num_heads
                    .checked_mul(s)
                    .and_then(|x| x.checked_mul(s))
                    .filter(|&n| n <= MAX_ACT_ELEMS)
                    .with_context(|| {
                        format!("layer {:?}: implausible attention score size", l.name)
                    })?;
                let mk = |r: usize| -> Result<ProjWeights> {
                    let t = &pm.layers[r];
                    t.validate()?;
                    ensure!(
                        (1..=8).contains(&t.bits),
                        "layer {:?}: serving kernels support 1..=8 bits, got {}",
                        t.name,
                        t.bits
                    );
                    Ok(ProjWeights {
                        bits: t.bits,
                        scale: t.scale,
                        data: t.data.clone(),
                        cache_key: None,
                    })
                };
                Ok(structural(
                    LayerKind::Attention {
                        heads: a.num_heads,
                        head_dim: a.head_dim,
                        seq: s,
                        q: mk(a.q_ref)?,
                        k: mk(a.k_ref)?,
                        v: mk(a.v_ref)?,
                        proj: mk(a.proj_ref)?,
                    },
                    shape,
                ))
            }
        }
    }

    /// Linear-only constructor kept for hand-built MLP plans (tests, and
    /// pre-v3 compatibility shims).
    pub fn from_packed(l: &PackedLayer, cols: usize) -> Result<QuantLayer> {
        ensure!(
            l.op == LayerOp::Linear,
            "layer {:?}: from_packed is linear-only; load conv packs via ServableModel",
            l.name
        );
        Ok(Self::plan(l, ActShape::Flat(cols))?.0)
    }

    /// Stamp this layer's weight-cache identity: `(model, layer)` for
    /// the main code stream (slot 0), slots 1..=4 for an attention
    /// layer's consumed q/k/v/proj projections. Called once per layer by
    /// [`ServableModel::from_packed`] after the generation uid is known.
    fn set_cache_id(&mut self, model: u64, layer: u32) {
        self.cache_id = Some((model, layer));
        if let LayerKind::Attention { q, k, v, proj, .. } = &mut self.kind {
            for (slot, p) in [q, k, v, proj].into_iter().enumerate() {
                p.cache_key = Some(CacheKey { model, layer, slot: slot as u8 + 1 });
            }
        }
    }

    /// This layer's main-stream cache key (slot 0), if stamped.
    fn cache_key(&self) -> Option<CacheKey> {
        self.cache_id.map(|(model, layer)| CacheKey { model, layer, slot: 0 })
    }

    /// Features flowing into this layer (per sample).
    pub fn in_elems(&self) -> usize {
        match self.kind {
            LayerKind::Linear { cols, .. } => cols,
            LayerKind::Conv2d { desc, in_h, in_w, .. } => in_h * in_w * desc.in_ch,
            LayerKind::LinearSeq { cols, seq, .. } => seq * cols,
            LayerKind::SeqView { seq, dim } => seq * dim,
            LayerKind::LayerNorm { rows, cols } => rows * cols,
            LayerKind::Attention { heads, head_dim, seq, .. } => seq * heads * head_dim,
            LayerKind::Residual { elems, .. } => elems,
            LayerKind::MeanPool { seq, dim } => seq * dim,
        }
    }

    /// Features flowing out of this layer (per sample).
    pub fn out_elems(&self) -> usize {
        match self.kind {
            LayerKind::Linear { rows, .. } => rows,
            LayerKind::Conv2d { desc, out_h, out_w, .. } => out_h * out_w * desc.out_ch,
            LayerKind::LinearSeq { rows, seq, .. } => seq * rows,
            LayerKind::MeanPool { dim, .. } => dim,
            // the remaining v4 ops are shape-preserving
            _ => self.in_elems(),
        }
    }

    /// Worst-case output magnitude given input magnitudes ≤ `b` — one
    /// step of the static activation-bound chain behind `act_bound`:
    ///
    /// * (position-wise) linear: `|y| ≤ Σ|w||x| ≤ cols · scale · b`
    ///   (every dequantized RoundClamp weight satisfies `|w| ≤ scale`);
    /// * conv2d: the same with `filter_len` taps per output;
    /// * layernorm (affine-free): a normalized row of `cols` elements
    ///   has L2 norm `√cols`, so no element exceeds `√cols` — the input
    ///   bound stops mattering;
    /// * attention: softmax mixes V rows convexly, so the context is
    ///   bounded by the V projection's output (`d · v.scale · b`), and
    ///   the output projection amplifies once more;
    /// * residual: handled by the caller (needs the source layer's
    ///   bound, not just the incoming one);
    /// * seqview / meanpool / fused ReLU / fused GELU never increase a
    ///   magnitude bound (`|gelu(x)| ≤ |x|`).
    ///
    /// Clamped to a sane range so degenerate scales can't produce a zero
    /// or infinite calibration.
    fn out_bound(&self, b: f32) -> f32 {
        let out = match &self.kind {
            LayerKind::Linear { cols, .. } | LayerKind::LinearSeq { cols, .. } => {
                b * self.scale * *cols as f32
            }
            LayerKind::Conv2d { desc, .. } => b * self.scale * desc.filter_len() as f32,
            LayerKind::LayerNorm { cols, .. } => (*cols as f32).sqrt(),
            LayerKind::Attention { heads, head_dim, v, proj, .. } => {
                let d = (heads * head_dim) as f32;
                b * v.scale * d * proj.scale * d
            }
            LayerKind::Residual { .. }
            | LayerKind::SeqView { .. }
            | LayerKind::MeanPool { .. } => b,
        };
        out.clamp(1e-6, 1e12)
    }

    /// Packed weight element count (attention counts its four folded
    /// projections).
    pub fn weight_numel(&self) -> usize {
        match self.kind {
            LayerKind::Linear { rows, cols } | LayerKind::LinearSeq { rows, cols, .. } => {
                rows * cols
            }
            LayerKind::Conv2d { desc, .. } => desc.weight_numel().unwrap_or(0),
            LayerKind::Attention { heads, head_dim, .. } => {
                let d = heads * head_dim;
                4 * d * d
            }
            _ => 0,
        }
    }

    pub fn kind_name(&self) -> &'static str {
        match self.kind {
            LayerKind::Linear { .. } | LayerKind::LinearSeq { .. } => "linear",
            LayerKind::Conv2d { .. } => "conv2d",
            LayerKind::SeqView { .. } => "seqview",
            LayerKind::LayerNorm { .. } => "layernorm",
            LayerKind::Attention { .. } => "attention",
            LayerKind::Residual { .. } => "residual",
            LayerKind::MeanPool { .. } => "meanpool",
        }
    }

    /// Dispatch the layer's kernel: `qgemm` for (position-wise) linear,
    /// `qconv2d` for conv, `qattention` for attention (all decode codes
    /// on the fly; see [`kernels`]). ReLU/GELU fusion is applied by the
    /// caller; `Residual` is resolved by the executor and never reaches
    /// here.
    pub fn forward(&self, x: &[f32], batch: usize, out: &mut [f32], pool: Option<&ThreadPool>) {
        let ck = self.cache_key();
        match &self.kind {
            LayerKind::Linear { rows, cols } => kernels::qgemm_keyed(
                ck, &self.data, self.bits, self.scale, *rows, *cols, x, batch, out, pool,
            ),
            LayerKind::Conv2d { desc, in_h, in_w, .. } => kernels::qconv2d_keyed(
                ck, &self.data, self.bits, self.scale, desc, *in_h, *in_w, x, batch, out, pool,
            ),
            // position-wise linear IS a qgemm with batch·seq rows of cols
            LayerKind::LinearSeq { rows, cols, seq } => kernels::qgemm_keyed(
                ck, &self.data, self.bits, self.scale, *rows, *cols, x, batch * seq, out, pool,
            ),
            LayerKind::SeqView { .. } => out.copy_from_slice(x),
            LayerKind::LayerNorm { rows, cols } => {
                layernorm_rows(x, batch * rows, *cols, LN_EPS, out);
            }
            LayerKind::Attention { heads, head_dim, seq, q, k, v, proj } => {
                kernels::qattention(
                    q, k, v, proj, *heads, *head_dim, *seq, x, batch, out, pool,
                );
            }
            LayerKind::Residual { .. } => {
                unreachable!("residual layers are executed by infer_batch")
            }
            LayerKind::MeanPool { seq, dim } => {
                let inv = 1.0 / *seq as f32;
                for b in 0..batch {
                    let ob = &mut out[b * dim..(b + 1) * dim];
                    ob.fill(0.0);
                    for t in 0..*seq {
                        axpy(1.0, &x[(b * seq + t) * dim..(b * seq + t + 1) * dim], ob);
                    }
                    for o in ob.iter_mut() {
                        *o *= inv;
                    }
                }
            }
        }
    }

    /// Whether the integer path has a kernel for this layer: payload
    /// linears and convs whose reduction length fits the i32 accumulator
    /// ([`MAX_INT_DOT_COLS`]). Structural v4 ops and attention stay on
    /// the float kernels.
    pub fn supports_int(&self) -> bool {
        match &self.kind {
            LayerKind::Linear { cols, .. } | LayerKind::LinearSeq { cols, .. } => {
                *cols <= MAX_INT_DOT_COLS
            }
            LayerKind::Conv2d { desc, .. } => desc.filter_len() <= MAX_INT_DOT_COLS,
            _ => false,
        }
    }

    /// Integer-domain twin of [`QuantLayer::forward`] for the kinds
    /// [`QuantLayer::supports_int`] accepts. The caller picks the
    /// activation quantizer (live EMA calibration or the `act_bound`
    /// fallback — see [`ServableModel::act_quant`]).
    pub fn forward_int(
        &self,
        x: &[f32],
        batch: usize,
        act: &ActQuant,
        out: &mut [f32],
        pool: Option<&ThreadPool>,
    ) {
        let ck = self.cache_key();
        match &self.kind {
            LayerKind::Linear { rows, cols } => kernels::qgemm_int_keyed(
                ck, &self.data, self.bits, self.scale, *rows, *cols, x, batch, act, out, pool,
            ),
            LayerKind::LinearSeq { rows, cols, seq } => kernels::qgemm_int_keyed(
                ck,
                &self.data,
                self.bits,
                self.scale,
                *rows,
                *cols,
                x,
                batch * seq,
                act,
                out,
                pool,
            ),
            LayerKind::Conv2d { desc, in_h, in_w, .. } => kernels::qconv2d_int_keyed(
                ck,
                &self.data,
                self.bits,
                self.scale,
                desc,
                *in_h,
                *in_w,
                x,
                batch,
                act,
                out,
                pool,
            ),
            _ => unreachable!("forward_int on a layer without an integer kernel"),
        }
    }

    /// Resident packed bytes (attention owns its consumed projections).
    pub fn payload_bytes(&self) -> usize {
        let own = self.data.len();
        match &self.kind {
            LayerKind::Attention { q, k, v, proj, .. } => {
                own + q.data.len() + k.data.len() + v.data.len() + proj.data.len()
            }
            _ => own,
        }
    }
}

// ---------------------------------------------------------------------------
// load-time quantization-health analysis

/// One pack record's static quantization analysis, computed once per
/// model generation from the code stream alone (see [`analyze_packed`]).
#[derive(Clone, Debug)]
pub struct LayerAnalysis {
    pub name: String,
    pub kind: &'static str,
    pub bits: u8,
    pub numel: usize,
    pub payload_bytes: usize,
    /// Shannon entropy of the code histogram, bits per code.
    pub entropy_bits: f64,
    /// `entropy_bits / bits` — how much of the allotted width the code
    /// distribution actually uses (1.0 = uniform codes).
    pub entropy_util: f64,
    /// Fraction of codes on a RoundClamp lattice endpoint (0 or
    /// `2^bits − 1`), i.e. weights the clamp flattened. Trivially 1.0
    /// for 1-bit layers, where every code is an endpoint.
    pub sat_frac: f64,
    /// Relative L2 error of requantizing this layer at `bits − 1`,
    /// computed exactly from the code histogram (the per-layer
    /// bit-sensitivity proxy: the original float weights are gone from a
    /// pack, so ‖W − Ŵ‖ against *them* lives in the training telemetry's
    /// `quant_error` events instead). 1.0 for 1-bit layers by
    /// convention — there is no narrower lattice.
    pub qerr_drop_rel: f64,
}

impl LayerAnalysis {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("kind", Json::Str(self.kind.to_string())),
            ("bits", Json::Num(self.bits as f64)),
            ("numel", Json::Num(self.numel as f64)),
            ("payload_bytes", Json::Num(self.payload_bytes as f64)),
            ("entropy_bits", Json::Num(self.entropy_bits)),
            ("entropy_util", Json::Num(self.entropy_util)),
            ("sat_frac", Json::Num(self.sat_frac)),
            ("qerr_drop_rel", Json::Num(self.qerr_drop_rel)),
        ])
    }
}

/// Whole-pack static analysis: the per-record table plus totals.
#[derive(Clone, Debug, Default)]
pub struct ModelAnalysis {
    pub layers: Vec<LayerAnalysis>,
    pub total_payload_bytes: usize,
    pub total_numel: usize,
    /// Element-weighted mean bit-width across payload records.
    pub avg_bits: f64,
}

impl ModelAnalysis {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("layers", Json::Arr(self.layers.iter().map(LayerAnalysis::to_json).collect())),
            ("total_payload_bytes", Json::Num(self.total_payload_bytes as f64)),
            ("total_numel", Json::Num(self.total_numel as f64)),
            ("avg_bits", Json::Num(self.avg_bits)),
        ])
    }
}

/// Relative L2 error of requantizing a code histogram at one bit less:
/// each code `c` of `n` bits sits at unit position `u = c/(2^n − 1)`;
/// dropping to `n − 1` bits moves it to the nearest
/// `round(u·(2^(n−1) − 1))/(2^(n−1) − 1)`. The layer scale cancels out
/// of the ratio, so the histogram determines the answer exactly.
fn qerr_drop_rel(hist: &[u64], bits: u8) -> f64 {
    let total: u64 = hist.iter().sum();
    if total == 0 {
        return 0.0;
    }
    if bits <= 1 {
        return 1.0;
    }
    let hi = (hist.len() - 1) as f64;
    let lo_levels = ((1u64 << (bits - 1)) - 1) as f64;
    let (mut err2, mut mag2) = (0f64, 0f64);
    for (c, &cnt) in hist.iter().enumerate() {
        if cnt == 0 {
            continue;
        }
        let u = c as f64 / hi;
        let w = u - 0.5; // weight magnitude in units of 2s
        let e = (u * lo_levels).round() / lo_levels - u;
        err2 += cnt as f64 * e * e;
        mag2 += cnt as f64 * w * w;
    }
    if mag2 <= 0.0 {
        // all mass at the lattice midpoint: relative error is 0/0 —
        // report 1.0 if the drop moves anything at all, else 0
        if err2 > 0.0 { 1.0 } else { 0.0 }
    } else {
        (err2 / mag2).sqrt()
    }
}

fn analyze_layer(l: &PackedLayer) -> LayerAnalysis {
    let readable = (1..=16).contains(&l.bits) && l.numel > 0;
    let levels = if readable { 1usize << l.bits } else { 1 };
    let mut hist = vec![0u64; levels];
    if readable {
        let mut br = BitReader::new(&l.data);
        for _ in 0..l.numel {
            hist[br.pull(l.bits) as usize] += 1;
        }
    }
    let n = l.numel as f64;
    let mut entropy = 0.0;
    if l.numel > 0 {
        for &c in &hist {
            if c > 0 {
                let p = c as f64 / n;
                entropy -= p * p.log2();
            }
        }
    }
    let sat_frac =
        if readable { (hist[0] + hist[levels - 1]) as f64 / n } else { 0.0 };
    LayerAnalysis {
        name: l.name.clone(),
        kind: l.op.kind_name(),
        bits: l.bits,
        numel: l.numel,
        payload_bytes: l.data.len(),
        entropy_bits: entropy,
        entropy_util: if l.bits > 0 { entropy / l.bits as f64 } else { 0.0 },
        sat_frac,
        qerr_drop_rel: if readable { qerr_drop_rel(&hist, l.bits) } else { 0.0 },
    }
}

/// Static quantization-health analysis of a packed model: per-record
/// bits, code-histogram entropy, endpoint-saturation fraction, one-bit
/// requantization error, and size breakdown. Works on any pack version
/// (no op-graph planning needed — attention records are structural with
/// `numel == 0`; their projections are ordinary records and analyze as
/// such), so `msq inspect` handles v1 files the serving planner also
/// accepts. [`ServableModel::from_packed`] stores the same analysis per
/// generation, which is what `/metrics` and `/debug/model/{name}`
/// serve — the CLI and the gateway agree by construction.
pub fn analyze_packed(pm: &PackedModel) -> ModelAnalysis {
    let mut layers = Vec::with_capacity(pm.layers.len());
    let (mut bytes, mut numel) = (0usize, 0usize);
    let mut bit_elems = 0f64;
    for l in &pm.layers {
        layers.push(analyze_layer(l));
        bytes += l.data.len();
        numel += l.numel;
        bit_elems += l.numel as f64 * l.bits as f64;
    }
    ModelAnalysis {
        layers,
        total_payload_bytes: bytes,
        total_numel: numel,
        avg_bits: if numel > 0 { bit_elems / numel as f64 } else { 0.0 },
    }
}

/// A packed model ready to answer inference requests: the planned op
/// graph over the packed layers, ReLU where the descriptors fuse it,
/// raw logits out of the last layer.
pub struct ServableModel {
    pub name: String,
    /// Process-unique generation id: every load gets a fresh one, so a
    /// hot-reloaded model never collides with its predecessor's decoded
    /// blocks in the shared weight cache. `Drop` evicts this
    /// generation's entries.
    pub uid: u64,
    pub input_dim: usize,
    pub layers: Vec<QuantLayer>,
    /// Static quantization analysis of the source pack, computed once at
    /// load time (one generation = one analysis).
    pub analysis: ModelAnalysis,
    /// Serve int-capable layers through the integer kernels (`--int8`):
    /// activations quantize to u8 against [`ServableModel::act_quant`]'s
    /// calibration and the inner loops accumulate in i32. Off by
    /// default; when off, execution is the float path, bit for bit.
    pub int8: bool,
}

impl ServableModel {
    /// Plan `pm` for serving with an explicit flat input width (the
    /// override path; conv packs take their spatial shape from the
    /// header, which must agree with `input_dim`).
    pub fn from_packed(name: &str, pm: &PackedModel, input_dim: usize) -> Result<ServableModel> {
        ensure!(!pm.layers.is_empty(), "model {name:?}: packed file has no layers");
        ensure!(input_dim > 0, "model {name:?}: input dim must be nonzero");
        let mut shape = match pm.spatial_input() {
            Some((h, w, c))
                if h.checked_mul(w).and_then(|hw| hw.checked_mul(c)) == Some(input_dim) =>
            {
                ActShape::Spatial(h, w, c)
            }
            // a conv pack with a recorded shape the override contradicts
            // can never plan — say so directly instead of letting the
            // conv layer misdiagnose a "missing" shape header
            Some((h, w, c)) if pm.has_conv() => {
                match h.checked_mul(w).and_then(|hw| hw.checked_mul(c)) {
                    Some(n) => bail!(
                        "model {name:?}: input dim {input_dim} contradicts the pack's \
                         recorded input shape {h}x{w}x{c} (= {n}) — drop the --input-dim \
                         override"
                    ),
                    // h·w·c overflowing usize means the header lies; reject
                    // it outright instead of quoting a saturated product
                    None => bail!(
                        "model {name:?}: the pack's recorded input shape {h}x{w}x{c} \
                         overflows the address space — corrupt or forged header"
                    ),
                }
            }
            // an MLP pack with a disagreeing override falls back to flat;
            // the dim chain then accepts or rejects it as before
            _ => ActShape::Flat(input_dim),
        };
        pm.validate_graph().with_context(|| format!("model {name:?}"))?;
        // attention projections are *consumed*: folded into the attention
        // layer's plan, never executed as standalone linears
        let mut consumed = vec![false; pm.layers.len()];
        for l in &pm.layers {
            if let LayerOp::Attention(a) = l.op {
                for r in a.refs() {
                    consumed[r] = true;
                }
            }
        }
        let mut layers = Vec::with_capacity(pm.layers.len());
        let mut planned_of = vec![usize::MAX; pm.layers.len()];
        let mut out_shapes: Vec<ActShape> = Vec::with_capacity(pm.layers.len());
        // static activation-bound chain for the integer path's fallback
        // calibration: model inputs are assumed unit-bounded, each layer
        // amplifies analytically (see QuantLayer::out_bound)
        let mut bound = 1.0f32;
        let mut out_bounds: Vec<f32> = Vec::with_capacity(pm.layers.len());
        for (i, l) in pm.layers.iter().enumerate() {
            if consumed[i] {
                continue;
            }
            let (mut q, next) = QuantLayer::plan_graph(l, shape, pm, &planned_of, &out_shapes)
                .with_context(|| format!("model {name:?}"))?;
            q.act_bound = bound;
            bound = match q.kind {
                // a residual's output is bounded by the sum of both
                // branches' bounds, not by out_bound's single input
                LayerKind::Residual { src, .. } => (bound + out_bounds[src]).clamp(1e-6, 1e12),
                _ => q.out_bound(bound),
            };
            planned_of[i] = layers.len();
            out_shapes.push(next);
            out_bounds.push(bound);
            shape = next;
            layers.push(q);
        }
        // one fresh generation uid per load — reloads of the same name
        // must never alias the old generation's cached decoded blocks
        static NEXT_UID: AtomicU64 = AtomicU64::new(1);
        let uid = NEXT_UID.fetch_add(1, Ordering::Relaxed);
        for (i, q) in layers.iter_mut().enumerate() {
            q.set_cache_id(uid, i as u32);
        }
        Ok(ServableModel {
            name: name.to_string(),
            uid,
            input_dim,
            layers,
            analysis: analyze_packed(pm),
            int8: false,
        })
    }

    /// Like [`ServableModel::from_packed`], but the input width is
    /// resolved from the pack header with `override_dim` winning
    /// (see [`resolve_input_dim`]).
    pub fn from_packed_auto(
        name: &str,
        pm: &PackedModel,
        override_dim: Option<usize>,
    ) -> Result<ServableModel> {
        let dim = resolve_input_dim(pm, override_dim)?;
        Self::from_packed(name, pm, dim)
    }

    /// Load a `.msqpack` from disk; the input width comes from the
    /// header unless `override_dim` is given.
    pub fn load(name: &str, path: &Path, override_dim: Option<usize>) -> Result<ServableModel> {
        let pm = PackedModel::load(path)?;
        Self::from_packed_auto(name, &pm, override_dim)
    }

    pub fn output_dim(&self) -> usize {
        self.layers.last().map(|l| l.out_elems()).unwrap_or(0)
    }

    /// Resident packed weight bytes (equals the `.msqpack` payload).
    pub fn payload_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.payload_bytes()).sum()
    }

    /// What the same weights would cost dense in FP32.
    pub fn fp32_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.weight_numel() * 4).sum()
    }

    pub fn compression(&self) -> f64 {
        self.fp32_bytes() as f64 / self.payload_bytes().max(1) as f64
    }

    /// The activation quantizer the integer path would use for layer
    /// `idx` right now, and whether it came from the live observers
    /// (`true`: qstats EMA absmax under this model's per-layer key) or
    /// from the static `act_bound` fallback (`false`: no samples yet, or
    /// qstats disabled). Re-resolved per batch, so calibration tightens
    /// as traffic accumulates without a reload.
    pub fn act_quant(&self, idx: usize) -> (ActQuant, bool) {
        let layer = &self.layers[idx];
        let qs = crate::obs::qstats::qstats();
        if qs.on() {
            let key = format!("{}/{:02}:{}", self.name, idx, layer.name);
            if let Some(a) = qs.layer(&key).ema_absmax() {
                return (ActQuant::from_absmax(a), true);
            }
        }
        (ActQuant::from_absmax(layer.act_bound), false)
    }

    /// Batched forward pass: `x` is `batch` rows of `input_dim`,
    /// batch-major (NHWC-flattened for conv models); returns `batch`
    /// rows of `output_dim` logits.
    pub fn infer_batch(
        &self,
        x: &[f32],
        batch: usize,
        pool: Option<&ThreadPool>,
    ) -> Result<Vec<f32>> {
        ensure!(
            x.len() == batch * self.input_dim,
            "model {:?}: got {} activations for batch {} x input dim {}",
            self.name,
            x.len(),
            batch,
            self.input_dim
        );
        // activations that later residual layers will add back in: planned
        // index → saved post-activation output
        let mut saved: HashMap<usize, Vec<f32>> = HashMap::new();
        let save_set: Vec<usize> = self
            .layers
            .iter()
            .filter_map(|l| match l.kind {
                LayerKind::Residual { src, .. } => Some(src),
                _ => None,
            })
            .collect();
        // Per-layer profiling: when the global profiler is on (one
        // relaxed load per batch), each layer-forward is timed and the
        // kernel aggregate deltas (decode/matmul ns, bytes, codes) are
        // attributed to this model's per-layer table. Forwards for one
        // model run on a single dispatcher thread, so delta attribution
        // is exact in the single-model case and best-effort when
        // several models infer concurrently.
        let prof = crate::obs::profiler().on();
        let mut kprev = if prof { Some(crate::obs::profiler().kernel_snapshot()) } else { None };
        // Activation-observer attribution rides the same dispatcher
        // thread: kernels merged this layer's observations into the
        // global scratch observer, and draining it right after the
        // forward names them (exact single-model, best-effort with
        // concurrent models — the profiler's caveat exactly).
        let qs_on = crate::obs::qstats::qstats().on();
        let mut cur: Vec<f32> = Vec::new();
        for (i, layer) in self.layers.iter().enumerate() {
            let t0 = if prof { Some(std::time::Instant::now()) } else { None };
            // layer 0 reads the caller's buffer directly (no input copy)
            let src: &[f32] = if i == 0 { x } else { &cur };
            let mut next;
            if let LayerKind::Residual { src: from, elems } = layer.kind {
                let skip = saved
                    .get(&from)
                    .unwrap_or_else(|| panic!("residual source {from} was not saved"));
                debug_assert_eq!(src.len(), batch * elems);
                debug_assert_eq!(skip.len(), batch * elems);
                next = src.to_vec();
                for (n, s) in next.iter_mut().zip(skip.iter()) {
                    *n += s;
                }
            } else {
                next = vec![0f32; batch * layer.out_elems()];
                if self.int8 && layer.supports_int() {
                    let (act, _) = self.act_quant(i);
                    layer.forward_int(src, batch, &act, &mut next, pool);
                } else {
                    layer.forward(src, batch, &mut next, pool);
                }
            }
            if layer.relu {
                for v in next.iter_mut() {
                    *v = v.max(0.0);
                }
            } else if layer.gelu {
                for v in next.iter_mut() {
                    *v = gelu(*v);
                }
            }
            if let (Some(t0), Some(prev)) = (t0, kprev.as_mut()) {
                let total_ns = t0.elapsed().as_nanos() as u64;
                let now = crate::obs::profiler().kernel_snapshot();
                let (d0, m0, b0, c0) = *prev;
                *prev = now;
                crate::obs::profiler().record_layer(
                    &format!("{}/{:02}:{}", self.name, i, layer.name),
                    layer.kind_name(),
                    layer.bits,
                    batch as u64,
                    total_ns,
                    now.0.saturating_sub(d0),
                    now.1.saturating_sub(m0),
                    now.2.saturating_sub(b0),
                    now.3.saturating_sub(c0),
                );
            }
            if qs_on {
                crate::obs::qstats::qstats()
                    .attribute(&format!("{}/{:02}:{}", self.name, i, layer.name));
            }
            if save_set.contains(&i) {
                saved.insert(i, next.clone());
            }
            cur = next;
        }
        Ok(cur)
    }
}

impl Drop for ServableModel {
    /// Retire this generation's decoded blocks from the shared weight
    /// cache — the last `Arc<ServableModel>` handle going away is
    /// exactly when no in-flight inference can touch them anymore.
    fn drop(&mut self) {
        weightcache::cache().invalidate_model(self.uid);
    }
}

/// Concurrent name → model map. Models are `Arc`-shared and immutable;
/// `get` clones the handle and drops the lock before any inference runs.
#[derive(Default)]
pub struct ModelRegistry {
    models: RwLock<HashMap<String, Arc<ServableModel>>>,
}

impl ModelRegistry {
    pub fn new() -> ModelRegistry {
        ModelRegistry::default()
    }

    pub fn insert(&self, model: ServableModel) -> Arc<ServableModel> {
        let m = Arc::new(model);
        self.models.write().unwrap().insert(m.name.clone(), m.clone());
        m
    }

    /// Load a `.msqpack` from disk and register it under `name`. The
    /// input width is inferred from the header; `override_dim` (when
    /// `Some`) wins, and is required for pre-v2 packs.
    pub fn load_file(
        &self,
        name: &str,
        path: &Path,
        override_dim: Option<usize>,
    ) -> Result<Arc<ServableModel>> {
        let m = ServableModel::load(name, path, override_dim)
            .with_context(|| format!("loading {path:?}"))?;
        Ok(self.insert(m))
    }

    pub fn get(&self, name: &str) -> Option<Arc<ServableModel>> {
        self.models.read().unwrap().get(name).cloned()
    }

    pub fn remove(&self, name: &str) -> bool {
        self.models.write().unwrap().remove(name).is_some()
    }

    pub fn names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.models.read().unwrap().keys().cloned().collect();
        v.sort();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::pack::unpack_layer;
    use crate::util::prng::Rng;

    fn rand_vec(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| r.normal() * 0.4).collect()
    }

    /// 2-layer packed MLP: input_dim -> 4-bit hidden -> 3-bit classes.
    fn toy_model(input_dim: usize, hidden: usize, classes: usize) -> PackedModel {
        PackedModel::synth_mlp(&[input_dim, hidden, classes], &[4, 3], 1).unwrap()
    }

    fn linear_dims(m: &ServableModel, i: usize) -> (usize, usize) {
        match m.layers[i].kind {
            LayerKind::Linear { rows, cols } => (rows, cols),
            _ => panic!("layer {i} is not linear"),
        }
    }

    #[test]
    fn shape_inference_chains_dims() {
        let m = ServableModel::from_packed("toy", &toy_model(12, 8, 4), 12).unwrap();
        assert_eq!(linear_dims(&m, 0), (8, 12));
        assert_eq!(linear_dims(&m, 1), (4, 8));
        assert_eq!(m.layers[0].kind_name(), "linear");
        assert!(m.layers[0].relu && !m.layers[1].relu);
        assert_eq!(m.output_dim(), 4);
        assert!(m.compression() > 4.0, "{}", m.compression());
    }

    #[test]
    fn bad_input_dim_is_rejected() {
        let err = ServableModel::from_packed("toy", &toy_model(12, 8, 4), 7).unwrap_err();
        assert!(err.to_string().contains("factor"), "{err}");
    }

    #[test]
    fn infer_matches_float_reference() {
        let pm = toy_model(12, 8, 4);
        let m = ServableModel::from_packed("toy", &pm, 12).unwrap();
        let batch = 5;
        let x = rand_vec(batch * 12, 9);

        // reference: dequantize fully, dense matmuls + ReLU
        let w1 = unpack_layer(&pm.layers[0]).unwrap();
        let w2 = unpack_layer(&pm.layers[1]).unwrap();
        let mut expect = Vec::new();
        for b in 0..batch {
            let xb = &x[b * 12..(b + 1) * 12];
            let h: Vec<f32> = (0..8)
                .map(|r| {
                    let s: f32 = (0..12).map(|j| w1[r * 12 + j] * xb[j]).sum();
                    s.max(0.0)
                })
                .collect();
            for r in 0..4 {
                expect.push((0..8).map(|j| w2[r * 8 + j] * h[j]).sum::<f32>());
            }
        }

        let got = m.infer_batch(&x, batch, None).unwrap();
        assert_eq!(got.len(), expect.len());
        for (i, (g, e)) in got.iter().zip(&expect).enumerate() {
            assert!((g - e).abs() < 1e-3, "idx {i}: {g} vs {e}");
        }
    }

    #[test]
    fn conv_plan_chains_spatial_shapes() {
        // 8x8x3 -> conv(3->4, /2) -> 4x4x4 -> conv(4->6, /2) -> 2x2x6
        // -> linear 24 -> 5
        let pm = PackedModel::synth_conv(8, 8, &[3, 4, 6, 5], &[4, 4, 3], 2).unwrap();
        let m = ServableModel::from_packed_auto("conv", &pm, None).unwrap();
        assert_eq!(m.input_dim, 8 * 8 * 3);
        assert_eq!(m.layers.len(), 3);
        match m.layers[0].kind {
            LayerKind::Conv2d { desc, in_h, in_w, out_h, out_w } => {
                assert_eq!((in_h, in_w, out_h, out_w), (8, 8, 4, 4));
                assert_eq!((desc.in_ch, desc.out_ch), (3, 4));
            }
            _ => panic!("layer 0 should be conv"),
        }
        match m.layers[1].kind {
            LayerKind::Conv2d { out_h, out_w, desc, .. } => {
                assert_eq!((out_h, out_w, desc.out_ch), (2, 2, 6));
            }
            _ => panic!("layer 1 should be conv"),
        }
        assert_eq!(linear_dims(&m, 2), (5, 24));
        assert!(m.layers[0].relu && m.layers[1].relu && !m.layers[2].relu);
        assert_eq!(m.output_dim(), 5);
        assert_eq!(m.layers[0].kind_name(), "conv2d");
        // payload accounting survives the conv plan
        assert_eq!(m.payload_bytes(), pm.payload_bytes());
        assert_eq!(m.fp32_bytes(), pm.fp32_bytes());
    }

    #[test]
    fn conv_infer_matches_dense_reference() {
        let pm = PackedModel::synth_conv(8, 8, &[3, 4, 5], &[5, 4], 7).unwrap();
        let m = ServableModel::from_packed_auto("conv", &pm, None).unwrap();
        let batch = 3;
        let x = rand_vec(batch * m.input_dim, 31);

        // dense f32 reference: the shared conv oracle + ReLU + linear head
        let wc = unpack_layer(&pm.layers[0]).unwrap();
        let wl = unpack_layer(&pm.layers[1]).unwrap();
        let d = match pm.layers[0].op {
            crate::quant::pack::LayerOp::Conv2d(d) => d,
            _ => unreachable!(),
        };
        let (oh, ow) = d.out_hw(8, 8).unwrap();
        let flat = oh * ow * d.out_ch;
        let mut map = kernels::dense_conv_ref(&wc, &d, 8, 8, &x, batch);
        for v in map.iter_mut() {
            *v = v.max(0.0);
        }
        let mut expect = Vec::new();
        for b in 0..batch {
            let mb = &map[b * flat..(b + 1) * flat];
            for r in 0..5 {
                let s: f64 = (0..flat).map(|j| wl[r * flat + j] as f64 * mb[j] as f64).sum();
                expect.push(s as f32);
            }
        }

        let got = m.infer_batch(&x, batch, None).unwrap();
        assert_eq!(got.len(), expect.len());
        for (i, (g, e)) in got.iter().zip(&expect).enumerate() {
            assert!((g - e).abs() < 1e-4, "idx {i}: {g} vs {e}");
        }
        // pooled execution is bit-identical to serial
        let pool = ThreadPool::new(3);
        assert_eq!(m.infer_batch(&x, batch, Some(&pool)).unwrap(), got);
    }

    #[test]
    fn conv_without_spatial_header_is_rejected() {
        let mut pm = PackedModel::synth_conv(8, 8, &[3, 4, 5], &[4, 3], 3).unwrap();
        pm.input_hwc = (0, 0, 0); // strip the shape (hand-assembled pack)
        let err = ServableModel::from_packed_auto("c", &pm, None).unwrap_err();
        assert!(err.to_string().contains("spatial"), "{err}");
        // and chain_dims refuses conv packs outright
        assert!(chain_dims(&pm, 192).unwrap_err().to_string().contains("conv"));
    }

    #[test]
    fn conv_override_contradicting_recorded_shape_says_so() {
        let pm = PackedModel::synth_conv(8, 8, &[3, 4, 5], &[4, 3], 3).unwrap();
        let err = ServableModel::from_packed("c", &pm, 999).unwrap_err();
        let msg = err.to_string();
        assert!(
            msg.contains("contradicts") && msg.contains("8x8x3"),
            "want a pointed override-vs-shape diagnosis, got: {msg}"
        );
    }

    #[test]
    fn forged_overflowing_conv_shape_is_rejected() {
        // a lying v3/v4 header whose h·w·c overflows usize used to be
        // quoted as a saturated usize::MAX product; it must be a load
        // error that names the header as the culprit
        let mut pm = PackedModel::synth_conv(8, 8, &[3, 4, 5], &[4, 3], 3).unwrap();
        let big = u32::MAX as usize;
        pm.input_hwc = (big, big, big);
        let err = ServableModel::from_packed("c", &pm, 999).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("overflows"), "want the forged-header diagnosis, got: {msg}");
    }

    #[test]
    fn act_bound_chains_through_the_plan() {
        // layer 0 sees the unit input assumption; layer 1 sees layer 0's
        // analytic amplification (cols · scale taps per output)
        let pm = toy_model(12, 8, 4);
        let m = ServableModel::from_packed("b", &pm, 12).unwrap();
        assert_eq!(m.layers[0].act_bound, 1.0);
        let want = m.layers[0].scale * 12.0;
        let got = m.layers[1].act_bound;
        assert!((got - want).abs() <= 1e-6 * want, "{got} vs {want}");
        // conv chain: filter_len taps per output
        let cpm = PackedModel::synth_conv(8, 8, &[3, 4, 5], &[4, 3], 3).unwrap();
        let cm = ServableModel::from_packed_auto("cb", &cpm, None).unwrap();
        let flen = match cm.layers[0].kind {
            LayerKind::Conv2d { desc, .. } => desc.filter_len(),
            _ => panic!("layer 0 should be conv"),
        };
        let want = cm.layers[0].scale * flen as f32;
        let got = cm.layers[1].act_bound;
        assert!((got - want).abs() <= 1e-6 * want, "{got} vs {want}");
    }

    #[test]
    fn conv_channel_mismatch_is_rejected() {
        let mut pm = PackedModel::synth_conv(8, 8, &[3, 4, 5], &[4, 3], 3).unwrap();
        // claim a 4-channel input: h*w*c must match input_dim too
        pm.input_hwc = (8, 6, 4);
        pm.input_dim = 8 * 6 * 4;
        let err = ServableModel::from_packed_auto("c", &pm, None).unwrap_err();
        assert!(err.to_string().contains("channels"), "{err}");
    }

    #[test]
    fn registry_lifecycle() {
        let reg = ModelRegistry::new();
        assert!(reg.get("toy").is_none());
        let pm = toy_model(6, 4, 2);
        let m = ServableModel::from_packed("toy", &pm, 6).unwrap();
        reg.insert(m);
        assert_eq!(reg.names(), vec!["toy"]);
        assert_eq!(reg.get("toy").unwrap().output_dim(), 2);
        assert!(reg.remove("toy"));
        assert!(!reg.remove("toy"));
    }

    #[test]
    fn file_roundtrip_through_registry() {
        let pm = toy_model(10, 6, 3);
        let path = std::env::temp_dir().join("msq_registry_test.msqpack");
        pm.save(&path).unwrap();
        let reg = ModelRegistry::new();
        // no override: the input width comes from the pack header
        let m = reg.load_file("disk", &path, None).unwrap();
        assert_eq!(m.input_dim, 10);
        assert_eq!(m.output_dim(), 3);
        // an explicit override still wins — and a wrong one errors cleanly
        assert!(reg.load_file("bad", &path, Some(7)).is_err());
    }

    #[test]
    fn conv_file_roundtrip_through_registry() {
        let pm = PackedModel::synth_conv(8, 8, &[3, 4, 5], &[4, 3], 11).unwrap();
        let path = std::env::temp_dir().join("msq_registry_conv.msqpack");
        pm.save(&path).unwrap();
        let reg = ModelRegistry::new();
        let m = reg.load_file("conv", &path, None).unwrap();
        assert_eq!(m.input_dim, 192);
        assert_eq!(m.output_dim(), 5);
        assert_eq!(m.layers[0].kind_name(), "conv2d");
        // served logits match the in-memory plan bit-for-bit
        let direct = ServableModel::from_packed_auto("x", &pm, None).unwrap();
        let x = rand_vec(2 * 192, 5);
        assert_eq!(
            m.infer_batch(&x, 2, None).unwrap(),
            direct.infer_batch(&x, 2, None).unwrap()
        );
    }

    #[test]
    fn input_dim_resolution_precedence() {
        let pm = toy_model(12, 8, 4);
        assert_eq!(resolve_input_dim(&pm, None).unwrap(), 12);
        assert_eq!(resolve_input_dim(&pm, Some(6)).unwrap(), 6);
        assert!(resolve_input_dim(&pm, Some(0)).is_err());
        // v1-style pack: no header width, override required
        let v1 = PackedModel { input_dim: 0, layers: pm.layers.clone(), ..Default::default() };
        assert_eq!(resolve_input_dim(&v1, Some(12)).unwrap(), 12);
        let err = resolve_input_dim(&v1, None).unwrap_err();
        assert!(err.to_string().contains("input-dim"), "{err}");
    }

    #[test]
    fn dim_chain_derivation() {
        let pm = toy_model(12, 8, 4);
        assert_eq!(chain_dims(&pm, 12).unwrap(), vec![8, 4]);
        assert_eq!(mlp_hidden_dims(&pm, 12).unwrap(), vec![8]);
        assert!(chain_dims(&pm, 7).is_err());
        assert!(chain_dims(&pm, 0).is_err());
    }

    /// 4 tokens of 6 features -> dim 4, 2 heads, hidden 8, 3 classes,
    /// mixed 3..=8-bit payload layers.
    fn toy_transformer(depth: usize, seed: u64) -> PackedModel {
        let bits: Vec<u8> = (0..2 + 6 * depth).map(|q| 3 + (q as u8 % 6)).collect();
        PackedModel::synth_transformer(4, 6, 4, 2, depth, 3, &bits, seed).unwrap()
    }

    #[test]
    fn transformer_plan_chains_shapes() {
        let pm = toy_transformer(2, 11);
        let m = ServableModel::from_packed_auto("vit", &pm, None).unwrap();
        assert_eq!(m.input_dim, 24);
        // 27 records minus 8 consumed attention projections
        assert_eq!(m.layers.len(), 19);
        let kinds: Vec<&str> = m.layers.iter().map(|l| l.kind_name()).collect();
        let block = ["layernorm", "attention", "residual", "layernorm", "linear", "linear",
            "residual"];
        let mut want = vec!["seqview", "linear"];
        want.extend(block);
        want.extend(block);
        want.extend(["layernorm", "meanpool", "linear"]);
        assert_eq!(kinds, want);
        match &m.layers[3].kind {
            LayerKind::Attention { heads, head_dim, seq, .. } => {
                assert_eq!((*heads, *head_dim, *seq), (2, 2, 4));
            }
            k => panic!("layer 3 should be attention, got {k:?}"),
        }
        // block-0 res1 adds the embed output; res2 adds res1's
        match m.layers[4].kind {
            LayerKind::Residual { src, elems } => assert_eq!((src, elems), (1, 16)),
            ref k => panic!("layer 4 should be residual, got {k:?}"),
        }
        match m.layers[8].kind {
            LayerKind::Residual { src, .. } => assert_eq!(src, 4),
            ref k => panic!("layer 8 should be residual, got {k:?}"),
        }
        // fc1 carries the fused GELU, nothing carries ReLU
        assert!(m.layers[6].gelu && !m.layers[6].relu);
        assert!(m.layers.iter().all(|l| !l.relu));
        assert_eq!(m.output_dim(), 3);
        // accounting sees the folded projections exactly once
        assert_eq!(m.payload_bytes(), pm.payload_bytes());
        assert_eq!(m.fp32_bytes(), pm.fp32_bytes());
        // and the MLP dim chain refuses transformer packs outright
        let err = chain_dims(&pm, 24).unwrap_err();
        assert!(err.to_string().contains("transformer"), "{err}");
    }

    fn matmul_ref(w: &[f32], x: &[f64], rows: usize, cols: usize, tokens: usize) -> Vec<f64> {
        let mut out = vec![0f64; tokens * rows];
        for t in 0..tokens {
            for r in 0..rows {
                out[t * rows + r] =
                    (0..cols).map(|j| w[r * cols + j] as f64 * x[t * cols + j]).sum();
            }
        }
        out
    }

    fn ln_ref(x: &[f64], cols: usize) -> Vec<f64> {
        let mut out = vec![0f64; x.len()];
        for (r, row) in x.chunks(cols).enumerate() {
            let mean = row.iter().sum::<f64>() / cols as f64;
            let var = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / cols as f64;
            let inv = 1.0 / (var + LN_EPS as f64).sqrt();
            for (o, v) in out[r * cols..(r + 1) * cols].iter_mut().zip(row) {
                *o = (v - mean) * inv;
            }
        }
        out
    }

    fn gelu_ref(x: f64) -> f64 {
        let c = (2.0 / std::f64::consts::PI).sqrt();
        0.5 * x * (1.0 + (c * (x + 0.044715 * x * x * x)).tanh())
    }

    fn mha_ref(q: &[f64], k: &[f64], v: &[f64], s: usize, heads: usize, hd: usize) -> Vec<f64> {
        let d = heads * hd;
        let mut ctx = vec![0f64; s * d];
        for h in 0..heads {
            let o = h * hd;
            for i in 0..s {
                let mut row = vec![0f64; s];
                for (j, rj) in row.iter_mut().enumerate() {
                    *rj = (0..hd).map(|t| q[i * d + o + t] * k[j * d + o + t]).sum::<f64>()
                        / (hd as f64).sqrt();
                }
                let max = row.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                let exps: Vec<f64> = row.iter().map(|x| (x - max).exp()).collect();
                let z: f64 = exps.iter().sum();
                for t in 0..hd {
                    ctx[i * d + o + t] =
                        exps.iter().enumerate().map(|(j, e)| e / z * v[j * d + o + t]).sum();
                }
            }
        }
        ctx
    }

    #[test]
    fn transformer_infer_matches_dense_reference() {
        let (s, td, d, heads, classes) = (4usize, 6usize, 4usize, 2usize, 3usize);
        let pm = toy_transformer(1, 23);
        let m = ServableModel::from_packed_auto("vit", &pm, None).unwrap();
        let batch = 2;
        let x = rand_vec(batch * s * td, 17);

        // f64 straight-line interpreter over the depth-1 record layout
        let w = |i: usize| unpack_layer(&pm.layers[i]).unwrap();
        let (wemb, wq, wk, wv, wp) = (w(1), w(4), w(5), w(6), w(7));
        let (w1, w2, wh) = (w(10), w(11), w(15));
        let mut expect = Vec::new();
        for b in 0..batch {
            let tok: Vec<f64> =
                x[b * s * td..(b + 1) * s * td].iter().map(|&v| v as f64).collect();
            let e = matmul_ref(&wemb, &tok, d, td, s);
            let n1 = ln_ref(&e, d);
            let qm = matmul_ref(&wq, &n1, d, d, s);
            let km = matmul_ref(&wk, &n1, d, d, s);
            let vm = matmul_ref(&wv, &n1, d, d, s);
            let ctx = mha_ref(&qm, &km, &vm, s, heads, d / heads);
            let a = matmul_ref(&wp, &ctx, d, d, s);
            let r1: Vec<f64> = a.iter().zip(&e).map(|(p, q)| p + q).collect();
            let n2 = ln_ref(&r1, d);
            let mut h1 = matmul_ref(&w1, &n2, 2 * d, d, s);
            for v in h1.iter_mut() {
                *v = gelu_ref(*v);
            }
            let h2 = matmul_ref(&w2, &h1, d, 2 * d, s);
            let r2: Vec<f64> = h2.iter().zip(&r1).map(|(p, q)| p + q).collect();
            let nf = ln_ref(&r2, d);
            let mut pooled = vec![0f64; d];
            for t in 0..s {
                for (j, p) in pooled.iter_mut().enumerate() {
                    *p += nf[t * d + j];
                }
            }
            for p in pooled.iter_mut() {
                *p /= s as f64;
            }
            expect.extend(matmul_ref(&wh, &pooled, classes, d, 1));
        }

        let got = m.infer_batch(&x, batch, None).unwrap();
        assert_eq!(got.len(), expect.len());
        for (i, (g, e)) in got.iter().zip(&expect).enumerate() {
            assert!((*g as f64 - e).abs() < 1e-4, "idx {i}: {g} vs {e}");
        }
        // pooled execution is bit-identical to serial
        let pool = ThreadPool::new(4);
        assert_eq!(m.infer_batch(&x, batch, Some(&pool)).unwrap(), got);
        // and a disk round-trip through the registry serves the same bits
        let path = std::env::temp_dir().join("msq_registry_vit.msqpack");
        pm.save(&path).unwrap();
        let reg = ModelRegistry::new();
        let m2 = reg.load_file("vit", &path, None).unwrap();
        assert_eq!(m2.infer_batch(&x, batch, None).unwrap(), got);
    }

    #[test]
    fn qerr_drop_rel_known_values() {
        // endpoint codes land exactly on the narrower lattice's endpoints
        assert_eq!(qerr_drop_rel(&[10, 0, 0, 7], 2), 0.0);
        // 2-bit code 1 sits at u = 1/3; the 1-bit lattice rounds it to 0:
        // err² = n/9, mag² = n/36 → rel = 2 exactly
        let r = qerr_drop_rel(&[0, 9, 0, 0], 2);
        assert!((r - 2.0).abs() < 1e-12, "{r}");
        // one-bit layers have no narrower lattice
        assert_eq!(qerr_drop_rel(&[5, 5], 1), 1.0);
        assert_eq!(qerr_drop_rel(&[0, 0, 0, 0], 2), 0.0);
    }

    #[test]
    fn analyze_packed_bounds_and_served_model_agreement() {
        let pm = toy_model(12, 8, 4);
        let a = analyze_packed(&pm);
        assert_eq!(a.layers.len(), 2);
        assert_eq!(a.total_numel, 12 * 8 + 8 * 4);
        assert_eq!(a.total_payload_bytes, pm.payload_bytes());
        // element-weighted mean of a 4-bit and a 3-bit record
        let want = (96.0 * 4.0 + 32.0 * 3.0) / 128.0;
        assert!((a.avg_bits - want).abs() < 1e-12, "{}", a.avg_bits);
        for la in &a.layers {
            assert!(la.entropy_bits >= 0.0 && la.entropy_bits <= la.bits as f64 + 1e-9);
            assert!(la.entropy_util <= 1.0 + 1e-9, "{}", la.entropy_util);
            assert!((0.0..=1.0).contains(&la.sat_frac), "{}", la.sat_frac);
            assert!(la.qerr_drop_rel >= 0.0);
            assert_eq!(la.kind, "linear");
        }
        // the served model carries the identical analysis — the contract
        // that makes `msq inspect` match `/debug/model/{name}` exactly
        let m = ServableModel::from_packed("toy", &pm, 12).unwrap();
        assert_eq!(m.analysis.to_json().to_string(), a.to_json().to_string());
    }

    #[test]
    fn analyze_packed_covers_transformer_records() {
        let pm = toy_transformer(1, 7);
        let a = analyze_packed(&pm);
        // analysis is per pack record: structural rows have numel 0, the
        // attention projections appear as ordinary linear records
        assert_eq!(a.layers.len(), pm.layers.len());
        assert_eq!(a.total_payload_bytes, pm.payload_bytes());
        let structural: Vec<&LayerAnalysis> =
            a.layers.iter().filter(|l| l.numel == 0).collect();
        assert!(!structural.is_empty());
        for s in structural {
            assert_eq!(s.payload_bytes, 0);
            assert_eq!(s.entropy_bits, 0.0);
        }
        assert!(a.layers.iter().any(|l| l.kind == "attention"));
        assert!(a.avg_bits >= 3.0 && a.avg_bits <= 8.0, "{}", a.avg_bits);
    }

    #[test]
    fn infer_attributes_qstats_per_layer_and_keeps_logits_identical() {
        let _guard = crate::obs::qstats::test_mutex();
        let pm = toy_model(12, 8, 4);
        let m = ServableModel::from_packed("qsattr", &pm, 12).unwrap();
        let qs = crate::obs::qstats::qstats();
        let x = rand_vec(5 * 12, 3);
        qs.set_rate(1.0);
        qs.enable(true);
        let observed = m.infer_batch(&x, 5, None).unwrap();
        qs.enable(false);
        let abs = qs.absmax_by_prefix("qsattr/");
        assert_eq!(abs.len(), 2, "one entry per planned layer: {abs:?}");
        for key in abs.keys() {
            let l = qs.layer(key);
            // ≥: the global scratch is shared, so a concurrent test's
            // kernels may have contributed extra observations
            assert!(l.obs.snapshot().count >= 60, "{key}");
            assert!(l.ema_absmax().is_some(), "{key}");
        }
        // observation never changes arithmetic
        let plain = m.infer_batch(&x, 5, None).unwrap();
        assert_eq!(observed, plain);
        qs.reset_prefix("qsattr/");
    }

    #[test]
    fn int8_static_fallback_respects_error_bound() {
        // single linear layer, unit-bounded inputs: the static act_bound
        // (1.0) genuinely covers the traffic, so every logit must sit
        // within the per-layer bound n · weight_scale · step/2
        let _guard = crate::obs::qstats::test_mutex();
        let pm = PackedModel::synth_mlp(&[12, 5], &[4], 2).unwrap();
        let mut m = ServableModel::from_packed("int8b", &pm, 12).unwrap();
        let x: Vec<f32> = rand_vec(3 * 12, 21).iter().map(|v| v.clamp(-1.0, 1.0)).collect();
        let f32_logits = m.infer_batch(&x, 3, None).unwrap();
        m.int8 = true;
        let int_logits = m.infer_batch(&x, 3, None).unwrap();
        let (act, from_ema) = m.act_quant(0);
        assert!(!from_ema, "qstats is off — the static fallback must be in effect");
        let bound = 12.0 * m.layers[0].scale * act.step() / 2.0;
        for (i, (g, e)) in int_logits.iter().zip(&f32_logits).enumerate() {
            assert!(
                (g - e).abs() <= bound + 1e-4 * (1.0 + e.abs()),
                "logit {i}: {g} vs {e}, bound {bound}"
            );
        }
    }

    #[test]
    fn int8_off_stays_bit_identical_through_a_toggle() {
        let _guard = crate::obs::qstats::test_mutex();
        let pm = toy_model(12, 8, 4);
        let mut m = ServableModel::from_packed("int8t", &pm, 12).unwrap();
        let x = rand_vec(4 * 12, 33);
        let before = m.infer_batch(&x, 4, None).unwrap();
        m.int8 = true;
        let int = m.infer_batch(&x, 4, None).unwrap();
        assert_ne!(before, int, "the integer path should actually engage");
        m.int8 = false;
        let after = m.infer_batch(&x, 4, None).unwrap();
        assert_eq!(before, after, "toggling int8 off must restore the float bits");
    }

    #[test]
    fn int8_calibration_prefers_observer_ema() {
        let _guard = crate::obs::qstats::test_mutex();
        let pm = toy_model(12, 8, 4);
        let mut m = ServableModel::from_packed("int8c", &pm, 12).unwrap();
        let qs = crate::obs::qstats::qstats();
        let x = rand_vec(5 * 12, 3);
        let (_, from_ema) = m.act_quant(0);
        assert!(!from_ema, "no observations yet — static fallback");
        qs.set_rate(1.0);
        qs.enable(true);
        let f32_logits = m.infer_batch(&x, 5, None).unwrap();
        let (a0, from_ema) = m.act_quant(0);
        assert!(from_ema, "one observed batch is enough to calibrate");
        let (a1, _) = m.act_quant(1);
        m.int8 = true;
        let int_logits = m.infer_batch(&x, 5, None).unwrap();
        qs.enable(false);
        // compositional bound: layer 0 contributes e1 per hidden unit
        // (ReLU is 1-Lipschitz); layer 1 adds its own half-step plus up
        // to e1 of clipping (its EMA saw the *float* hidden values)
        let e1 = 12.0 * m.layers[0].scale * a0.step() / 2.0;
        let bound = 8.0 * m.layers[1].scale * (2.0 * e1 + a1.step() / 2.0);
        for (i, (g, e)) in int_logits.iter().zip(&f32_logits).enumerate() {
            assert!(
                (g - e).abs() <= bound + 1e-4 * (1.0 + e.abs()),
                "logit {i}: {g} vs {e}, bound {bound}"
            );
        }
        qs.reset_prefix("int8c/");
    }

    #[test]
    fn int8_falls_back_to_float_kernels_on_oversized_reductions() {
        // a reduction longer than the i32 accumulator allows must serve
        // through the float kernels even with int8 on — bit-identically
        let cols = MAX_INT_DOT_COLS + 1;
        let pm = PackedModel::synth_mlp(&[cols, 1], &[4], 3).unwrap();
        let mut m = ServableModel::from_packed("int8wide", &pm, cols).unwrap();
        assert!(!m.layers[0].supports_int());
        let x = rand_vec(cols, 5);
        let f = m.infer_batch(&x, 1, None).unwrap();
        m.int8 = true;
        assert_eq!(m.infer_batch(&x, 1, None).unwrap(), f);
    }

    #[test]
    fn int8_conv_pooled_matches_serial() {
        let _guard = crate::obs::qstats::test_mutex();
        let cpm = PackedModel::synth_conv(8, 8, &[3, 4, 5], &[4, 3], 7).unwrap();
        let mut m = ServableModel::from_packed_auto("int8cv", &cpm, None).unwrap();
        m.int8 = true;
        let x = rand_vec(2 * m.input_dim, 13);
        let serial = m.infer_batch(&x, 2, None).unwrap();
        assert!(serial.iter().all(|v| v.is_finite()));
        let pool = ThreadPool::new(3);
        assert_eq!(m.infer_batch(&x, 2, Some(&pool)).unwrap(), serial);
    }

    #[test]
    fn attention_without_seqview_is_rejected() {
        let bits = [8u8; 8];
        let mut pm = PackedModel::synth_transformer(2, 3, 4, 2, 1, 3, &bits, 5).unwrap();
        // strip the reshape: activations stay flat all the way to the
        // attention layer, which must refuse them
        pm.layers[0].op = LayerOp::LayerNorm;
        let err = ServableModel::from_packed_auto("vit", &pm, None).unwrap_err();
        assert!(format!("{err:#}").contains("token sequence"), "{err:#}");
    }

    #[test]
    fn weight_cache_toggle_is_bit_identical() {
        // the ISSUE acceptance gate: served logits with the decoded-weight
        // cache on must be bit-identical to the cache-off path, across
        // linear, attention (all four projections), and structural layers
        let _wc = weightcache::test_mutex();
        let c = weightcache::cache();
        c.clear();
        let bits = [8u8; 8];
        let pm = PackedModel::synth_transformer(4, 6, 4, 2, 1, 3, &bits, 5).unwrap();
        let m = ServableModel::from_packed_auto("wcvit", &pm, None).unwrap();
        let x = rand_vec(2 * m.input_dim, 17);
        let cold = m.infer_batch(&x, 2, None).unwrap();
        c.set_budget_bytes(64 << 20);
        let fill = m.infer_batch(&x, 2, None).unwrap(); // decodes + fills
        let hit = m.infer_batch(&x, 2, None).unwrap(); // served from the arena
        assert_eq!(cold, fill, "cache fill pass must not change the logits");
        assert_eq!(cold, hit, "cache hit pass must not change the logits");
        let lin = m
            .layers
            .iter()
            .position(|l| matches!(l.kind, LayerKind::Linear { .. } | LayerKind::LinearSeq { .. }))
            .expect("transformer plan has a payload linear");
        assert!(
            c.contains(CacheKey { model: m.uid, layer: lin as u32, slot: 0 }),
            "the linear's decoded block must be resident"
        );
        let attn = m
            .layers
            .iter()
            .position(|l| matches!(l.kind, LayerKind::Attention { .. }))
            .expect("transformer plan has an attention layer");
        assert!(
            c.contains(CacheKey { model: m.uid, layer: attn as u32, slot: 1 }),
            "the q projection's decoded block must be resident"
        );
        c.set_budget_bytes(0);
        let off = m.infer_batch(&x, 2, None).unwrap();
        assert_eq!(cold, off, "turning the cache off must restore the legacy path");
    }

    #[test]
    fn weight_cache_covers_conv_and_int8_paths() {
        let _wc = weightcache::test_mutex();
        let _qs = crate::obs::qstats::test_mutex();
        let c = weightcache::cache();
        c.clear();
        let cpm = PackedModel::synth_conv(8, 8, &[3, 4, 5], &[5, 4], 7).unwrap();
        let mut m = ServableModel::from_packed_auto("wcconv", &cpm, None).unwrap();
        let x = rand_vec(2 * m.input_dim, 23);
        let cold = m.infer_batch(&x, 2, None).unwrap();
        c.set_budget_bytes(64 << 20);
        assert_eq!(cold, m.infer_batch(&x, 2, None).unwrap(), "conv fill pass");
        assert_eq!(cold, m.infer_batch(&x, 2, None).unwrap(), "conv hit pass");
        // the int path caches u8 codes under the same keys; a domain
        // mismatch is a miss and the slot is taken over, never a panic
        m.int8 = true;
        let int_cached = m.infer_batch(&x, 2, None).unwrap();
        assert_eq!(int_cached, m.infer_batch(&x, 2, None).unwrap(), "int hit pass");
        c.set_budget_bytes(0);
        let int_plain = m.infer_batch(&x, 2, None).unwrap();
        assert_eq!(int_cached, int_plain, "cached int path must match the legacy int path");
    }

    #[test]
    fn dropping_a_model_retires_its_cache_generation() {
        let _wc = weightcache::test_mutex();
        let c = weightcache::cache();
        c.clear();
        c.set_budget_bytes(64 << 20);
        let pm = toy_model(12, 8, 4);
        let m = ServableModel::from_packed("wcdrop", &pm, 12).unwrap();
        let x = rand_vec(2 * 12, 19);
        let _ = m.infer_batch(&x, 2, None).unwrap();
        let k0 = CacheKey { model: m.uid, layer: 0, slot: 0 };
        assert!(c.contains(k0), "inference must fill the arena");
        drop(m);
        assert!(!c.contains(k0), "drop must invalidate the generation");
        c.set_budget_bytes(0);
    }

    #[test]
    fn residual_shape_mismatch_is_rejected() {
        let bits = [8u8; 8];
        let mut pm = PackedModel::synth_transformer(4, 6, 4, 2, 1, 3, &bits, 5).unwrap();
        // res1 normally adds the embed output (4x4 tokens); point it at
        // the patchify output (4x6 tokens) instead
        pm.layers[8].op = LayerOp::Residual { src: 0 };
        let err = ServableModel::from_packed_auto("vit", &pm, None).unwrap_err();
        assert!(format!("{err:#}").contains("does not match"), "{err:#}");
    }
}
