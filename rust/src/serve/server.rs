//! The serving front end: model + dynamic batcher + metrics.
//!
//! [`Server::start`] owns a [`ServableModel`], a worker [`ThreadPool`]
//! for intra-batch row parallelism, and a [`DynamicBatcher`] whose
//! executor runs the quantized forward pass. Requests are submitted with
//! [`Server::submit`] (async, returns the per-request response channel)
//! or [`Server::infer_blocking`]; every completion feeds
//! [`ServeMetrics`], whose snapshot reports throughput and p50/p95/p99
//! latency through the `metrics` streaming primitives.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::Receiver;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::metrics::{LatencyHist, RateCounter};
use crate::util::json::Json;
use crate::util::stats::Running;
use crate::util::threadpool::ThreadPool;

use super::admission::{Admission, AdmissionConfig, AdmitError};
use super::batcher::{BatchConfig, BatchFn, DynamicBatcher, InferResponse, SubmitError};
use super::registry::ServableModel;

#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub max_batch: usize,
    pub max_delay: Duration,
    pub queue_cap: usize,
    /// Worker threads for row-parallel kernels (0 = machine default).
    pub threads: usize,
    /// Admission wait-room cap (`--queue-depth`): how many requests may
    /// wait for a batcher slot when the queue is full. 0 = legacy
    /// immediate shed.
    pub admit_wait: usize,
    /// How long a waiting request may poll before expiring with 429
    /// (`--admit-deadline-ms`). Only meaningful with `admit_wait > 0`.
    pub admit_deadline: Duration,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_batch: 32,
            max_delay: Duration::from_millis(5),
            queue_cap: 1024,
            threads: 0,
            admit_wait: 0,
            admit_deadline: Duration::from_millis(100),
        }
    }
}

/// Serving metrics: lifetime counters plus streaming latency percentiles
/// and a sliding-window request rate. All methods take `&self`; the
/// histogram sits behind a mutex (recording is O(1) under the lock).
pub struct ServeMetrics {
    start: Instant,
    submitted: AtomicU64,
    rejected: AtomicU64,
    completed: AtomicU64,
    latency: Mutex<LatencyHist>,
    /// Queue-wait slice of each request's latency (the `queue` stage).
    queue_wait: Mutex<LatencyHist>,
    /// Batch-executor slice of each request's latency (the `kernel`
    /// stage — the quantized forward pass its batch ran).
    compute: Mutex<LatencyHist>,
    /// Request-weighted batch occupancy (mean batch a request rode in).
    occupancy: Mutex<Running>,
    rate: Mutex<RateCounter>,
}

impl ServeMetrics {
    pub fn new() -> ServeMetrics {
        ServeMetrics {
            start: Instant::now(),
            submitted: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            latency: Mutex::new(LatencyHist::new()),
            queue_wait: Mutex::new(LatencyHist::new()),
            compute: Mutex::new(LatencyHist::new()),
            occupancy: Mutex::new(Running::new()),
            rate: Mutex::new(RateCounter::new(10)),
        }
    }

    /// Monotonic seconds since server start (the RateCounter clock).
    pub fn now_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn record_submit(&self) {
        self.submitted.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_reject(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_completion(&self, r: &InferResponse) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.latency.lock().unwrap().record(r.latency.as_secs_f64());
        self.queue_wait.lock().unwrap().record(r.queue_wait.as_secs_f64());
        self.compute.lock().unwrap().record(r.compute.as_secs_f64());
        self.occupancy.lock().unwrap().push(r.batch_size as f64);
        self.rate.lock().unwrap().add(self.now_secs(), 1);
    }

    pub fn completed(&self) -> u64 {
        self.completed.load(Ordering::Relaxed)
    }

    pub fn rejected(&self) -> u64 {
        self.rejected.load(Ordering::Relaxed)
    }

    pub fn submitted(&self) -> u64 {
        self.submitted.load(Ordering::Relaxed)
    }

    /// Percentile of request latency in milliseconds.
    pub fn latency_ms(&self, p: f64) -> f64 {
        self.latency.lock().unwrap().percentile(p) * 1e3
    }

    /// Snapshot of the streaming latency histogram (the gateway renders
    /// Prometheus summary quantiles from it without holding the lock).
    pub fn latency_hist(&self) -> LatencyHist {
        self.latency.lock().unwrap().clone()
    }

    /// Snapshot of the queue-wait stage histogram.
    pub fn queue_wait_hist(&self) -> LatencyHist {
        self.queue_wait.lock().unwrap().clone()
    }

    /// Snapshot of the batch-executor (kernel) stage histogram.
    pub fn compute_hist(&self) -> LatencyHist {
        self.compute.lock().unwrap().clone()
    }

    /// Request-weighted mean batch occupancy.
    pub fn mean_batch(&self) -> f64 {
        self.occupancy.lock().unwrap().mean()
    }

    /// Request rate over the sliding window (req/s).
    pub fn window_rps(&self) -> f64 {
        self.rate.lock().unwrap().rate(self.now_secs())
    }

    /// Lifetime mean throughput (completions / uptime).
    pub fn throughput(&self) -> f64 {
        let dt = self.now_secs().max(1e-9);
        self.completed() as f64 / dt
    }

    /// Machine-readable snapshot (written by the bench and the CLI).
    pub fn snapshot(&self, queue_depth: usize) -> Json {
        let lat = self.latency.lock().unwrap();
        Json::obj(vec![
            ("uptime_s", Json::Num(self.now_secs())),
            ("submitted", Json::Num(self.submitted() as f64)),
            ("completed", Json::Num(self.completed() as f64)),
            ("rejected", Json::Num(self.rejected() as f64)),
            ("queue_depth", Json::Num(queue_depth as f64)),
            ("rps_lifetime", Json::Num(self.throughput())),
            ("rps_window", Json::Num(self.rate.lock().unwrap().rate(self.now_secs()))),
            ("p50_ms", Json::Num(lat.percentile(50.0) * 1e3)),
            ("p95_ms", Json::Num(lat.percentile(95.0) * 1e3)),
            ("p99_ms", Json::Num(lat.percentile(99.0) * 1e3)),
            ("mean_ms", Json::Num(lat.mean() * 1e3)),
            ("max_ms", Json::Num(lat.max() * 1e3)),
            ("queue_mean_ms", Json::Num(self.queue_wait.lock().unwrap().mean() * 1e3)),
            ("compute_mean_ms", Json::Num(self.compute.lock().unwrap().mean() * 1e3)),
            ("mean_batch", Json::Num(self.occupancy.lock().unwrap().mean())),
        ])
    }

    /// One-line human summary for logs.
    pub fn report(&self, queue_depth: usize) -> String {
        format!(
            "{} ok / {} shed | {:.0} req/s | p50 {:.2} ms p95 {:.2} ms p99 {:.2} ms | \
             mean batch {:.1} | depth {}",
            self.completed(),
            self.rejected(),
            self.throughput(),
            self.latency_ms(50.0),
            self.latency_ms(95.0),
            self.latency_ms(99.0),
            self.occupancy.lock().unwrap().mean(),
            queue_depth,
        )
    }
}

impl Default for ServeMetrics {
    fn default() -> Self {
        Self::new()
    }
}

/// A running inference server over one packed model.
pub struct Server {
    pub model: Arc<ServableModel>,
    pub metrics: Arc<ServeMetrics>,
    /// The admission gate in front of the batcher queue (public so the
    /// gateway can render `msq_admission_*` from its counters).
    pub admission: Admission,
    batcher: DynamicBatcher,
}

impl Server {
    pub fn start(model: Arc<ServableModel>, cfg: ServerConfig) -> Server {
        let threads = if cfg.threads == 0 { ThreadPool::default_size() } else { cfg.threads };
        // resident workers: par_for dispatches onto the worker queue, so
        // each batch pays a queue push instead of a thread spawn
        let pool = ThreadPool::new(threads);
        let metrics = Arc::new(ServeMetrics::new());
        let out_dim = model.output_dim();
        let in_dim = model.input_dim;
        let m = model.clone();
        let run: Box<BatchFn> = Box::new(move |inputs: Vec<Vec<f32>>| {
            let batch = inputs.len();
            let mut x = Vec::with_capacity(batch * in_dim);
            for inp in &inputs {
                debug_assert_eq!(inp.len(), in_dim); // validated at submit
                x.extend_from_slice(inp);
            }
            match m.infer_batch(&x, batch, Some(&pool)) {
                Ok(logits) => logits.chunks(out_dim).map(|c| c.to_vec()).collect(),
                // unreachable with submit-side validation; degrade loudly
                Err(e) => {
                    eprintln!("[serve] batch of {batch} failed: {e}");
                    vec![vec![f32::NAN; out_dim]; batch]
                }
            }
        });
        let hk = metrics.clone();
        let hook: Box<super::batcher::CompletionHook> =
            Box::new(move |r| hk.record_completion(r));
        let batch_cfg = BatchConfig {
            max_batch: cfg.max_batch.max(1),
            max_delay: cfg.max_delay,
            queue_cap: cfg.queue_cap.max(1),
        };
        let batcher = DynamicBatcher::with_hook(batch_cfg, run, Some(hook));
        let admission = Admission::new(AdmissionConfig {
            wait_cap: cfg.admit_wait,
            deadline: cfg.admit_deadline,
        });
        Server { model, metrics, admission, batcher }
    }

    /// Validate + enqueue; the receiver yields this request's response.
    /// Every presented request counts as `submitted`; failures (bad
    /// input, shed, shutdown) additionally count as `rejected`, so
    /// `completed + rejected == submitted` once the queue drains.
    pub fn submit(&self, input: Vec<f32>) -> Result<Receiver<InferResponse>, SubmitError> {
        self.metrics.record_submit();
        if input.len() != self.model.input_dim {
            self.metrics.record_reject();
            return Err(SubmitError::BadInput { got: input.len(), want: self.model.input_dim });
        }
        self.batcher.submit(input).map_err(|e| {
            self.metrics.record_reject();
            e
        })
    }

    /// [`Self::submit`] behind the admission gate: a queue-full request
    /// may wait (bounded in population and time by the server's
    /// [`AdmissionConfig`]) for a slot instead of shedding instantly.
    /// Expired and shed waiters surface as `QueueFull` so the HTTP
    /// layer's 429 + `Retry-After` mapping is unchanged. With the
    /// default `admit_wait == 0` this is exactly `submit`.
    pub fn submit_admit(&self, input: Vec<f32>) -> Result<Receiver<InferResponse>, SubmitError> {
        self.metrics.record_submit();
        if input.len() != self.model.input_dim {
            self.metrics.record_reject();
            return Err(SubmitError::BadInput { got: input.len(), want: self.model.input_dim });
        }
        let mut held = Some(input);
        let res = self.admission.admit(|| {
            let x = held.take().expect("input is replaced on every retryable failure");
            self.batcher.try_submit(x).map_err(|(e, x)| {
                held = Some(x);
                e
            })
        });
        res.map_err(|e| {
            self.metrics.record_reject();
            match e {
                AdmitError::Expired { depth, cap, .. } | AdmitError::Shed { depth, cap } => {
                    SubmitError::QueueFull { depth, cap }
                }
                AdmitError::Fatal(e) => e,
            }
        })
    }

    /// Submit and wait for the response (closed-loop clients, tests).
    pub fn infer_blocking(&self, input: Vec<f32>) -> Result<InferResponse, SubmitError> {
        let rx = self.submit(input)?;
        rx.recv().map_err(|_| SubmitError::ShuttingDown)
    }

    pub fn queue_depth(&self) -> usize {
        self.batcher.depth()
    }

    /// Stop admitting requests while the dispatcher drains what's queued
    /// (non-consuming — a gateway broadcasts this to every model first,
    /// then drops the handles to join). Submits now fail `ShuttingDown`.
    pub fn close(&self) {
        self.batcher.close();
    }

    /// Drain the queue, stop the dispatcher, join workers.
    pub fn shutdown(self) {
        self.batcher.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::pack::PackedModel;
    use crate::util::prng::Rng;

    fn toy_server(max_batch: usize, queue_cap: usize) -> Server {
        let pm = PackedModel::synth_mlp(&[6, 8, 3], &[4, 3], 3).unwrap();
        let model = Arc::new(ServableModel::from_packed("toy", &pm, 6).unwrap());
        let cfg = ServerConfig {
            max_batch,
            max_delay: Duration::from_millis(2),
            queue_cap,
            threads: 2,
            ..Default::default()
        };
        Server::start(model, cfg)
    }

    fn toy_server_admit(queue_cap: usize, admit_wait: usize) -> Server {
        let pm = PackedModel::synth_mlp(&[6, 8, 3], &[4, 3], 3).unwrap();
        let model = Arc::new(ServableModel::from_packed("toy", &pm, 6).unwrap());
        let cfg = ServerConfig {
            max_batch: 2,
            max_delay: Duration::from_millis(1),
            queue_cap,
            threads: 1,
            admit_wait,
            admit_deadline: Duration::from_millis(500),
        };
        Server::start(model, cfg)
    }

    #[test]
    fn serves_blocking_requests_and_counts_them() {
        let s = toy_server(8, 64);
        let mut r = Rng::new(9);
        for _ in 0..20 {
            let x: Vec<f32> = (0..6).map(|_| r.normal()).collect();
            let resp = s.infer_blocking(x).unwrap();
            assert_eq!(resp.logits.len(), 3);
            assert!(resp.argmax < 3);
            assert!(resp.logits.iter().all(|v| v.is_finite()));
        }
        assert_eq!(s.metrics.completed(), 20);
        assert_eq!(s.metrics.rejected(), 0);
        assert!(s.metrics.latency_ms(99.0) > 0.0);
        s.shutdown();
    }

    #[test]
    fn wrong_input_dim_rejected_before_queue() {
        let s = toy_server(8, 64);
        match s.submit(vec![0.0; 5]) {
            Err(SubmitError::BadInput { got: 5, want: 6 }) => {}
            other => panic!("expected BadInput, got {other:?}"),
        }
        assert_eq!(s.metrics.rejected(), 1);
        assert_eq!(s.metrics.completed(), 0);
        s.shutdown();
    }

    #[test]
    fn admission_rides_out_queue_pressure_and_conserves_counts() {
        // queue of 1 against 4 hammering threads: without the wait room
        // most submits would shed; with it, waiters drain through and
        // the conservation invariant still closes exactly.
        let s = Arc::new(toy_server_admit(1, 16));
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let sv = s.clone();
            handles.push(std::thread::spawn(move || {
                let mut r = Rng::new(40 + t);
                let mut got = 0u32;
                for _ in 0..25 {
                    let x: Vec<f32> = (0..6).map(|_| r.normal()).collect();
                    match sv.submit_admit(x) {
                        Ok(rx) => {
                            rx.recv().expect("admitted request must get its response");
                            got += 1;
                        }
                        Err(SubmitError::QueueFull { .. }) => {}
                        Err(e) => panic!("unexpected: {e:?}"),
                    }
                }
                got
            }));
        }
        let ok: u32 = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert!(ok > 0, "waiters must make progress");
        let m = &s.metrics;
        assert_eq!(m.submitted(), 100);
        assert_eq!(m.completed() + m.rejected(), m.submitted());
        let a = &s.admission.metrics;
        assert_eq!(a.admitted(), u64::from(ok));
        assert_eq!(a.admitted() + a.expired() + a.shed(), 100);
        assert_eq!(a.waiting(), 0, "wait room must be empty after the storm");
        assert_eq!(s.queue_depth(), 0, "every admitted request was drained");
        Arc::try_unwrap(s).ok().expect("all clones joined").shutdown();
    }

    #[test]
    fn concurrent_submitters_all_complete() {
        let s = Arc::new(toy_server(16, 4096));
        let mut handles = Vec::new();
        for t in 0..4 {
            let sv = s.clone();
            handles.push(std::thread::spawn(move || {
                let mut r = Rng::new(100 + t);
                let mut ok = 0u32;
                for _ in 0..50 {
                    let x: Vec<f32> = (0..6).map(|_| r.normal()).collect();
                    if sv.infer_blocking(x).is_ok() {
                        ok += 1;
                    }
                }
                ok
            }));
        }
        let total: u32 = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(total, 200);
        assert_eq!(s.metrics.completed(), 200);
        let snap = s.metrics.snapshot(s.queue_depth()).to_string();
        assert!(snap.contains("\"p99_ms\""), "{snap}");
    }
}
