//! Pure-Rust quantized inference kernels over packed RoundClamp codes.
//!
//! The serving path never materializes an f32 weight tensor: `qgemm` and
//! `qconv2d` stream the n-bit codes (1..=8 bits, non-byte-aligned,
//! LSB-first — the exact `quant::pack` layout) out of the packed payload
//! one weight row (or conv filter) at a time and fold the affine
//! dequantization out of the inner loop:
//!
//! ```text
//! w = (c / (2^n - 1) - 0.5) · 2s          (RoundClamp dequant, Eq. 4)
//! y[b,r] = Σ_j w[r,j] x[b,j]
//!        = α · Σ_j c[r,j] x[b,j] − s · Σ_j x[b,j],   α = 2s / (2^n − 1)
//! ```
//!
//! so the hot loop is a plain code·activation dot product. `qgemm`
//! processes rows in cache-friendly blocks: each block decodes one row
//! at a time into a small scratch buffer and reuses it across the whole
//! batch. `qconv2d` applies the same decode-once trick per *filter*: a
//! filter's `kh·kw·in_ch` codes are decoded once, then the whole batch's
//! output map streams through an im2col-free inner loop whose innermost
//! dot runs over contiguous memory on both sides (OHWI filters against
//! NHWC activations). The `Σ x` correction term becomes a per-position
//! receptive-field sum shared by every output channel. Blocks (rows /
//! filter groups) are independent, so they parallelize over
//! `util::threadpool` with disjoint output cells.

use crate::quant::pack::Conv2dDesc;
use crate::util::threadpool::ThreadPool;

/// Rows per parallel work item. Small enough to balance across cores,
/// large enough that scratch allocation and task dispatch amortize.
const ROW_BLOCK: usize = 32;

/// Conv filters per parallel work item — one filter is a whole output
/// map of work per sample, so blocks are smaller than gemm rows.
const FILTER_BLOCK: usize = 4;

/// Decode `out.len()` consecutive `bits`-wide codes starting at absolute
/// bit offset `bit_off` of `data` (LSB-first within each byte, matching
/// `quant::pack::BitWriter`), widening each code to f32.
///
/// The caller must guarantee `bit_off + out.len() * bits` bits exist in
/// `data` (the registry validates payload sizes at load time).
pub fn decode_codes_f32(data: &[u8], bit_off: usize, bits: u8, out: &mut [f32]) {
    debug_assert!((1..=8).contains(&bits));
    let mut pos = bit_off / 8;
    let phase = (bit_off % 8) as u32;
    if bits == 8 {
        if phase == 0 {
            for (slot, &b) in out.iter_mut().zip(&data[pos..]) {
                *slot = b as f32;
            }
        } else {
            // every code straddles the same two-byte window at a fixed
            // phase: consume the leading partial byte and combine, no
            // bit-buffer loop (the fast path used to bail whenever
            // phase != 0 and fall through to the generic decoder)
            let hi = 8 - phase;
            for slot in out.iter_mut() {
                let c = ((data[pos] as u32) >> phase) | (((data[pos + 1] as u32) << hi) & 0xFF);
                *slot = c as f32;
                pos += 1;
            }
        }
        return;
    }
    let mut cur: u64 = 0;
    let mut nbits: u32 = 0;
    if phase != 0 {
        cur = (data[pos] >> phase) as u64;
        nbits = 8 - phase;
        pos += 1;
    }
    let width = bits as u32;
    let mask = (1u64 << width) - 1;
    for slot in out.iter_mut() {
        while nbits < width {
            cur |= (data[pos] as u64) << nbits;
            pos += 1;
            nbits += 8;
        }
        *slot = (cur & mask) as f32;
        cur >>= width;
        nbits -= width;
    }
}

/// Unrolled dot product with 4 independent accumulators (keeps the FP
/// dependency chain short; identical summation order on every path, so
/// serial and pooled kernels agree bit-for-bit).
#[inline]
fn dot(a: &[f32], b: &[f32]) -> f32 {
    let split = a.len() & !3;
    let (a4, ar) = a.split_at(split);
    let (b4, br) = b.split_at(split);
    let mut acc = [0f32; 4];
    for (ca, cb) in a4.chunks_exact(4).zip(b4.chunks_exact(4)) {
        acc[0] += ca[0] * cb[0];
        acc[1] += ca[1] * cb[1];
        acc[2] += ca[2] * cb[2];
        acc[3] += ca[3] * cb[3];
    }
    let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]);
    for (x, y) in ar.iter().zip(br) {
        s += x * y;
    }
    s
}

/// Raw output pointer smuggled into the scoped parallel-for. Blocks write
/// disjoint `(b, r)` cells, so the aliasing is sound (see SAFETY below).
struct SendPtr(*mut f32);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

/// Quantized GEMM over a packed layer: `out[b*rows + r] = Σ_j w[r,j] ·
/// x[b*cols + j]` with `w` decoded on the fly from `data`.
///
/// `x` is batch-major (`batch` rows of `cols`), `out` is batch-major
/// (`batch` rows of `rows`). With `pool`, row blocks run in parallel;
/// results are identical to the serial path.
#[allow(clippy::too_many_arguments)]
pub fn qgemm(
    data: &[u8],
    bits: u8,
    scale: f32,
    rows: usize,
    cols: usize,
    x: &[f32],
    batch: usize,
    out: &mut [f32],
    pool: Option<&ThreadPool>,
) {
    assert_eq!(x.len(), batch * cols, "qgemm: x shape");
    assert_eq!(out.len(), batch * rows, "qgemm: out shape");
    assert!((1..=8).contains(&bits), "qgemm: bits {bits}");
    if rows == 0 || batch == 0 {
        return;
    }
    let denom = ((1u32 << bits) - 1).max(1) as f32;
    let alpha = 2.0 * scale / denom;
    let xsums: Vec<f32> = (0..batch).map(|b| x[b * cols..(b + 1) * cols].iter().sum()).collect();

    let run_block = |blk: usize, scratch: &mut [f32], write: &mut dyn FnMut(usize, f32)| {
        let r0 = blk * ROW_BLOCK;
        let r1 = (r0 + ROW_BLOCK).min(rows);
        for r in r0..r1 {
            decode_codes_f32(data, r * cols * bits as usize, bits, scratch);
            for b in 0..batch {
                let acc = dot(scratch, &x[b * cols..(b + 1) * cols]);
                write(b * rows + r, alpha * acc - scale * xsums[b]);
            }
        }
    };

    let nblocks = rows.div_ceil(ROW_BLOCK);
    match pool {
        Some(pool) if nblocks > 1 => {
            let optr = SendPtr(out.as_mut_ptr());
            let optr = &optr;
            pool.par_for(nblocks, move |blk| {
                let mut scratch = vec![0f32; cols];
                run_block(blk, &mut scratch[..], &mut |idx, v| {
                    // SAFETY: `idx = b*rows + r` and every row `r` belongs
                    // to exactly one block, so concurrent blocks write
                    // disjoint cells of `out`, which outlives the scoped
                    // par_for. No one reads `out` until par_for returns.
                    unsafe { *optr.0.add(idx) = v }
                });
            });
        }
        _ => {
            let mut scratch = vec![0f32; cols];
            for blk in 0..nblocks {
                run_block(blk, &mut scratch[..], &mut |idx, v| out[idx] = v);
            }
        }
    }
}

/// Kernel-tap bounds for one output index: which `0..k` taps land inside
/// the `in_n`-wide input once `o·stride − pad` anchors the window.
/// Returns `(k0, k1, i0)` — taps `k0..k1` are valid and tap `k0` reads
/// input index `i0` (empty range when the window misses entirely).
/// `pub(crate)` because `native::ops` clips its conv windows with the
/// SAME function — training and serving geometry must never diverge.
#[inline]
pub(crate) fn krange(
    o: usize,
    stride: usize,
    pad: usize,
    k: usize,
    in_n: usize,
) -> (usize, usize, usize) {
    let base = (o * stride) as isize - pad as isize;
    let k0 = (-base).max(0) as usize;
    let k1 = (in_n as isize - base).clamp(0, k as isize) as usize;
    let k1 = k1.max(k0);
    (k0, k1, (base + k0 as isize).max(0) as usize)
}

/// Quantized 2-D convolution over a packed conv layer: NHWC activations
/// against OHWI filters whose codes are decoded once per filter and
/// reused across the whole batch (the conv twin of `qgemm`'s row-block
/// trick — no im2col buffer is ever built).
///
/// `x` is `batch × in_h × in_w × in_ch`, `out` is `batch × out_h ×
/// out_w × out_ch` with `(out_h, out_w) = d.out_hw(in_h, in_w)`. Zero
/// padding is handled by clipping the tap ranges, which is exact for the
/// affine folding because padded positions contribute zero to both the
/// code·activation dot and the receptive-field sum. With `pool`, filter
/// blocks run in parallel; results are bit-identical to the serial path.
#[allow(clippy::too_many_arguments)]
pub fn qconv2d(
    data: &[u8],
    bits: u8,
    scale: f32,
    d: &Conv2dDesc,
    in_h: usize,
    in_w: usize,
    x: &[f32],
    batch: usize,
    out: &mut [f32],
    pool: Option<&ThreadPool>,
) {
    let (out_h, out_w) = d.out_hw(in_h, in_w).expect("qconv2d: invalid geometry");
    let in_elems = in_h * in_w * d.in_ch;
    let out_elems = out_h * out_w * d.out_ch;
    assert_eq!(x.len(), batch * in_elems, "qconv2d: x shape");
    assert_eq!(out.len(), batch * out_elems, "qconv2d: out shape");
    assert!((1..=8).contains(&bits), "qconv2d: bits {bits}");
    if batch == 0 {
        return;
    }
    let denom = ((1u32 << bits) - 1).max(1) as f32;
    let alpha = 2.0 * scale / denom;

    // Σ x over each receptive field (the dequant correction term) —
    // shared by every output channel, so it costs one extra "channel".
    // For small out_ch this pass is a visible fraction of the layer's
    // work, so it parallelizes over samples (disjoint psums rows) rather
    // than running serially ahead of the filter blocks.
    let mut psums = vec![0f32; batch * out_h * out_w];
    let psum_sample = |b: usize, prow: &mut dyn FnMut(usize, f32)| {
        let xb = &x[b * in_elems..(b + 1) * in_elems];
        for oy in 0..out_h {
            let (ky0, ky1, iy0) = krange(oy, d.stride, d.pad, d.kh, in_h);
            for ox in 0..out_w {
                let (kx0, kx1, ix0) = krange(ox, d.stride, d.pad, d.kw, in_w);
                let seg = (kx1 - kx0) * d.in_ch;
                let mut s = 0f32;
                if seg > 0 {
                    // seg == 0 (window fully off the input horizontally,
                    // pad >= kw) would index past the row — and sums 0
                    for ky in ky0..ky1 {
                        let iy = iy0 + (ky - ky0);
                        s += xb[(iy * in_w + ix0) * d.in_ch..][..seg].iter().sum::<f32>();
                    }
                }
                prow((b * out_h + oy) * out_w + ox, s);
            }
        }
    };
    match pool {
        Some(pool) if batch > 1 => {
            let pptr = SendPtr(psums.as_mut_ptr());
            let pptr = &pptr;
            pool.par_for(batch, move |b| {
                // SAFETY: sample `b` writes only indices in
                // [b·out_h·out_w, (b+1)·out_h·out_w) — disjoint per task;
                // `psums` outlives the scoped par_for and is not read
                // until it returns.
                psum_sample(b, &mut |idx, v| unsafe { *pptr.0.add(idx) = v });
            });
        }
        _ => {
            for b in 0..batch {
                psum_sample(b, &mut |idx, v| psums[idx] = v);
            }
        }
    }

    let flen = d.filter_len();
    let run_block = |blk: usize, scratch: &mut [f32], write: &mut dyn FnMut(usize, f32)| {
        let oc0 = blk * FILTER_BLOCK;
        let oc1 = (oc0 + FILTER_BLOCK).min(d.out_ch);
        for oc in oc0..oc1 {
            // decode this filter's kh·kw·in_ch codes exactly once
            decode_codes_f32(data, oc * flen * bits as usize, bits, scratch);
            for b in 0..batch {
                let xb = &x[b * in_elems..(b + 1) * in_elems];
                for oy in 0..out_h {
                    let (ky0, ky1, iy0) = krange(oy, d.stride, d.pad, d.kh, in_h);
                    for ox in 0..out_w {
                        let (kx0, kx1, ix0) = krange(ox, d.stride, d.pad, d.kw, in_w);
                        let seg = (kx1 - kx0) * d.in_ch;
                        let mut acc = 0f32;
                        if seg > 0 {
                            for ky in ky0..ky1 {
                                let iy = iy0 + (ky - ky0);
                                let wrow = &scratch[(ky * d.kw + kx0) * d.in_ch..][..seg];
                                let xrow = &xb[(iy * in_w + ix0) * d.in_ch..][..seg];
                                acc += dot(wrow, xrow);
                            }
                        }
                        let pos = (b * out_h + oy) * out_w + ox;
                        write(pos * d.out_ch + oc, alpha * acc - scale * psums[pos]);
                    }
                }
            }
        }
    };

    let nblocks = d.out_ch.div_ceil(FILTER_BLOCK);
    match pool {
        Some(pool) if nblocks > 1 => {
            let optr = SendPtr(out.as_mut_ptr());
            let optr = &optr;
            pool.par_for(nblocks, move |blk| {
                let mut scratch = vec![0f32; flen];
                run_block(blk, &mut scratch[..], &mut |idx, v| {
                    // SAFETY: `idx = pos·out_ch + oc` and every filter
                    // `oc` belongs to exactly one block, so concurrent
                    // blocks write disjoint cells of `out`, which
                    // outlives the scoped par_for. No one reads `out`
                    // until par_for returns.
                    unsafe { *optr.0.add(idx) = v }
                });
            });
        }
        _ => {
            let mut scratch = vec![0f32; flen];
            for blk in 0..nblocks {
                run_block(blk, &mut scratch[..], &mut |idx, v| out[idx] = v);
            }
        }
    }
}

/// Dense f64 conv oracle over dequantized weights — the reference every
/// quantized conv path is judged against. `doc(hidden) pub` (not
/// `cfg(test)`) so the unit suites, the registry tests AND the
/// integration tests all share exactly ONE statement of the OHWI×NHWC
/// indexing convention; it is test support, not serving API.
#[doc(hidden)]
#[allow(clippy::too_many_arguments)]
pub fn dense_conv_ref(
    wq: &[f32],
    d: &Conv2dDesc,
    in_h: usize,
    in_w: usize,
    x: &[f32],
    batch: usize,
) -> Vec<f32> {
    let (out_h, out_w) = d.out_hw(in_h, in_w).unwrap();
    let mut out = vec![0f32; batch * out_h * out_w * d.out_ch];
    for b in 0..batch {
        for oy in 0..out_h {
            for ox in 0..out_w {
                for oc in 0..d.out_ch {
                    let mut acc = 0f64;
                    for ky in 0..d.kh {
                        let iy = (oy * d.stride + ky) as isize - d.pad as isize;
                        if iy < 0 || iy >= in_h as isize {
                            continue;
                        }
                        for kx in 0..d.kw {
                            let ix = (ox * d.stride + kx) as isize - d.pad as isize;
                            if ix < 0 || ix >= in_w as isize {
                                continue;
                            }
                            for ic in 0..d.in_ch {
                                let wv = wq[((oc * d.kh + ky) * d.kw + kx) * d.in_ch + ic];
                                let xv = x[((b * in_h + iy as usize) * in_w + ix as usize)
                                    * d.in_ch
                                    + ic];
                                acc += wv as f64 * xv as f64;
                            }
                        }
                    }
                    out[((b * out_h + oy) * out_w + ox) * d.out_ch + oc] = acc as f32;
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::pack::{pack_layer, unpack_layer};
    use crate::util::prng::Rng;

    fn rand_vec(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| r.normal() * 0.5).collect()
    }

    #[test]
    fn decode_matches_bitreader_at_any_offset() {
        for bits in 1u8..=8 {
            let cols = 13; // 13*bits is non-byte-aligned for most bits
            let rows = 7;
            let w = rand_vec(rows * cols, bits as u64);
            let p = pack_layer("l", &w, bits);
            // reference: sequential pull of every code
            let mut br = crate::quant::pack::BitReader::new(&p.data);
            let reference: Vec<f32> =
                (0..rows * cols).map(|_| br.pull(bits) as f32).collect();
            // decode each row independently at its bit offset
            let mut row = vec![0f32; cols];
            for r in 0..rows {
                decode_codes_f32(&p.data, r * cols * bits as usize, bits, &mut row);
                assert_eq!(&row[..], &reference[r * cols..(r + 1) * cols], "bits {bits} row {r}");
            }
        }
    }

    /// Bit-level reference: extract the `bits`-wide code at absolute bit
    /// offset `off` straight from the byte stream, one bit at a time.
    fn code_at(data: &[u8], off: usize, bits: u8) -> u32 {
        let mut v = 0u32;
        for i in 0..bits as usize {
            let bit = off + i;
            v |= (((data[bit / 8] >> (bit % 8)) & 1) as u32) << i;
        }
        v
    }

    #[test]
    fn decode_8bit_handles_unaligned_offsets() {
        // regression: the 8-bit fast path used to be skipped whenever the
        // bit offset had a nonzero phase; the fixed path must match the
        // generic decoder at every phase 0..8
        let mut r = Rng::new(77);
        let data: Vec<u8> = (0..64).map(|_| (r.next_u64() & 0xFF) as u8).collect();
        for off in 0..16 {
            let n = 40; // 40 codes of 8 bits from `off`
            let mut out = vec![0f32; n];
            decode_codes_f32(&data, off, 8, &mut out);
            for (i, &got) in out.iter().enumerate() {
                let expect = code_at(&data, off + 8 * i, 8) as f32;
                assert_eq!(got, expect, "off {off} code {i}");
            }
        }
    }

    #[test]
    fn decode_all_bits_at_all_phases() {
        let mut r = Rng::new(78);
        let data: Vec<u8> = (0..96).map(|_| (r.next_u64() & 0xFF) as u8).collect();
        for bits in 1u8..=8 {
            for off in 0..24 {
                let n = 25;
                let mut out = vec![0f32; n];
                decode_codes_f32(&data, off, bits, &mut out);
                for (i, &got) in out.iter().enumerate() {
                    let expect = code_at(&data, off + bits as usize * i, bits) as f32;
                    assert_eq!(got, expect, "bits {bits} off {off} code {i}");
                }
            }
        }
    }

    #[test]
    fn qgemm_matches_dense_reference() {
        for bits in [1u8, 2, 3, 5, 7, 8] {
            let (rows, cols, batch) = (19, 37, 3);
            let w = rand_vec(rows * cols, 100 + bits as u64);
            let p = pack_layer("l", &w, bits);
            let wq = unpack_layer(&p).unwrap(); // dequantized lattice weights
            let x = rand_vec(batch * cols, 200 + bits as u64);

            let mut expect = vec![0f32; batch * rows];
            for b in 0..batch {
                for r in 0..rows {
                    let mut acc = 0f64;
                    for j in 0..cols {
                        acc += wq[r * cols + j] as f64 * x[b * cols + j] as f64;
                    }
                    expect[b * rows + r] = acc as f32;
                }
            }

            let mut got = vec![0f32; batch * rows];
            qgemm(&p.data, bits, p.scale, rows, cols, &x, batch, &mut got, None);
            for (i, (g, e)) in got.iter().zip(&expect).enumerate() {
                assert!((g - e).abs() < 1e-3, "bits {bits} idx {i}: {g} vs {e}");
            }
        }
    }

    #[test]
    fn qgemm_pool_is_bitwise_equal_to_serial() {
        let (rows, cols, batch) = (101, 64, 4); // > ROW_BLOCK: multiple blocks
        let w = rand_vec(rows * cols, 7);
        let p = pack_layer("l", &w, 4);
        let x = rand_vec(batch * cols, 8);
        let mut serial = vec![0f32; batch * rows];
        let mut pooled = vec![0f32; batch * rows];
        qgemm(&p.data, 4, p.scale, rows, cols, &x, batch, &mut serial, None);
        let pool = ThreadPool::new(4);
        qgemm(&p.data, 4, p.scale, rows, cols, &x, batch, &mut pooled, Some(&pool));
        assert_eq!(serial, pooled);
    }

    #[test]
    fn qgemm_empty_batch_and_rows() {
        let p = pack_layer("l", &rand_vec(12, 1), 3);
        let mut out = vec![0f32; 0];
        qgemm(&p.data, 3, p.scale, 4, 3, &[], 0, &mut out, None);
        qgemm(&p.data, 3, p.scale, 0, 3, &[0.0; 3], 1, &mut out, None);
    }

    #[test]
    fn dot_handles_remainders() {
        let a: Vec<f32> = (0..11).map(|i| i as f32).collect();
        let b: Vec<f32> = (0..11).map(|i| (i * 2) as f32).collect();
        let expect: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert_eq!(dot(&a, &b), expect);
    }

    #[test]
    fn qconv2d_matches_dense_reference_across_bits_strides_pads() {
        // bits 1..=8 (unaligned filter offsets for most), every stride/pad
        // combination that yields a valid output map, vs the f64 dense
        // reference on the dequantized lattice weights
        crate::util::prop::check(120, |g| {
            let bits = g.usize_in(1, 8) as u8;
            let d = Conv2dDesc {
                in_ch: g.usize_in(1, 3),
                out_ch: g.usize_in(1, 6),
                kh: g.usize_in(1, 3),
                kw: g.usize_in(1, 3),
                stride: g.usize_in(1, 3),
                pad: g.usize_in(0, 2),
            };
            let in_h = g.usize_in(d.kh.saturating_sub(2 * d.pad).max(1), 7);
            let in_w = g.usize_in(d.kw.saturating_sub(2 * d.pad).max(1), 7);
            if d.out_hw(in_h, in_w).is_err() {
                return Ok(()); // kernel misses the padded input: skip
            }
            let batch = g.usize_in(1, 3);
            let numel = d.weight_numel().unwrap();
            let w = g.vec_normal(numel, 0.2);
            let p = pack_layer("c", &w, bits);
            let wq = unpack_layer(&p).map_err(|e| e.to_string())?;
            let x = g.vec_normal(batch * in_h * in_w * d.in_ch, 0.3);

            let expect = dense_conv_ref(&wq, &d, in_h, in_w, &x, batch);
            let mut got = vec![0f32; expect.len()];
            qconv2d(&p.data, bits, p.scale, &d, in_h, in_w, &x, batch, &mut got, None);
            for (i, (a, e)) in got.iter().zip(&expect).enumerate() {
                crate::util::prop::ensure(
                    (a - e).abs() < 1e-5,
                    format!("bits {bits} {d:?} {in_h}x{in_w} idx {i}: {a} vs {e}"),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn qconv2d_pool_is_bitwise_equal_to_serial() {
        // out_ch 13 > FILTER_BLOCK: several blocks race over the pool
        let d = Conv2dDesc { in_ch: 3, out_ch: 13, kh: 3, kw: 3, stride: 2, pad: 1 };
        let (in_h, in_w, batch) = (9, 11, 4);
        let w = rand_vec(d.weight_numel().unwrap(), 21);
        let p = pack_layer("c", &w, 5);
        let x = rand_vec(batch * in_h * in_w * d.in_ch, 22);
        let (oh, ow) = d.out_hw(in_h, in_w).unwrap();
        let mut serial = vec![0f32; batch * oh * ow * d.out_ch];
        let mut pooled = vec![0f32; serial.len()];
        qconv2d(&p.data, 5, p.scale, &d, in_h, in_w, &x, batch, &mut serial, None);
        let pool = ThreadPool::new(4);
        qconv2d(&p.data, 5, p.scale, &d, in_h, in_w, &x, batch, &mut pooled, Some(&pool));
        assert_eq!(serial, pooled);
    }

    #[test]
    fn qconv2d_empty_batch() {
        let d = Conv2dDesc { in_ch: 2, out_ch: 3, kh: 3, kw: 3, stride: 1, pad: 1 };
        let p = pack_layer("c", &rand_vec(d.weight_numel().unwrap(), 1), 4);
        let mut out = vec![0f32; 0];
        qconv2d(&p.data, 4, p.scale, &d, 4, 4, &[], 0, &mut out, None);
    }

    #[test]
    fn krange_clips_padding_windows() {
        // k=3, stride=1, pad=1 over 4 inputs: first window hangs one tap
        // off the left edge, last one off the right
        assert_eq!(krange(0, 1, 1, 3, 4), (1, 3, 0));
        assert_eq!(krange(1, 1, 1, 3, 4), (0, 3, 0));
        assert_eq!(krange(3, 1, 1, 3, 4), (0, 2, 2));
        // window entirely off the input: empty range
        assert_eq!(krange(0, 1, 5, 3, 4).0, krange(0, 1, 5, 3, 4).1);
    }
}
