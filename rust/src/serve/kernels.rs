//! Pure-Rust quantized inference kernels over packed RoundClamp codes.
//!
//! The serving path never materializes an f32 weight tensor: `qgemm`
//! streams the n-bit codes (1..=8 bits, non-byte-aligned, LSB-first —
//! the exact `quant::pack` layout) out of the packed payload row by row
//! and folds the affine dequantization out of the inner loop:
//!
//! ```text
//! w = (c / (2^n - 1) - 0.5) · 2s          (RoundClamp dequant, Eq. 4)
//! y[b,r] = Σ_j w[r,j] x[b,j]
//!        = α · Σ_j c[r,j] x[b,j] − s · Σ_j x[b,j],   α = 2s / (2^n − 1)
//! ```
//!
//! so the hot loop is a plain code·activation dot product. Rows are
//! processed in cache-friendly blocks: each block decodes one row at a
//! time into a small scratch buffer and reuses it across the whole
//! batch, which is what makes batched serving amortize the bit-decode.
//! Blocks are independent, so they parallelize over `util::threadpool`
//! with disjoint output rows.

use crate::util::threadpool::ThreadPool;

/// Rows per parallel work item. Small enough to balance across cores,
/// large enough that scratch allocation and task dispatch amortize.
const ROW_BLOCK: usize = 32;

/// Decode `out.len()` consecutive `bits`-wide codes starting at absolute
/// bit offset `bit_off` of `data` (LSB-first within each byte, matching
/// `quant::pack::BitWriter`), widening each code to f32.
///
/// The caller must guarantee `bit_off + out.len() * bits` bits exist in
/// `data` (the registry validates payload sizes at load time).
pub fn decode_codes_f32(data: &[u8], bit_off: usize, bits: u8, out: &mut [f32]) {
    debug_assert!((1..=8).contains(&bits));
    let mut pos = bit_off / 8;
    let mut cur: u64 = 0;
    let mut nbits: u32 = 0;
    let phase = (bit_off % 8) as u32;
    if phase != 0 {
        cur = (data[pos] >> phase) as u64;
        nbits = 8 - phase;
        pos += 1;
    }
    if bits == 8 && phase == 0 {
        for (slot, &b) in out.iter_mut().zip(&data[pos..]) {
            *slot = b as f32;
        }
        return;
    }
    let width = bits as u32;
    let mask = (1u64 << width) - 1;
    for slot in out.iter_mut() {
        while nbits < width {
            cur |= (data[pos] as u64) << nbits;
            pos += 1;
            nbits += 8;
        }
        *slot = (cur & mask) as f32;
        cur >>= width;
        nbits -= width;
    }
}

/// Unrolled dot product with 4 independent accumulators (keeps the FP
/// dependency chain short; identical summation order on every path, so
/// serial and pooled `qgemm` agree bit-for-bit).
#[inline]
fn dot(a: &[f32], b: &[f32]) -> f32 {
    let split = a.len() & !3;
    let (a4, ar) = a.split_at(split);
    let (b4, br) = b.split_at(split);
    let mut acc = [0f32; 4];
    for (ca, cb) in a4.chunks_exact(4).zip(b4.chunks_exact(4)) {
        acc[0] += ca[0] * cb[0];
        acc[1] += ca[1] * cb[1];
        acc[2] += ca[2] * cb[2];
        acc[3] += ca[3] * cb[3];
    }
    let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]);
    for (x, y) in ar.iter().zip(br) {
        s += x * y;
    }
    s
}

/// Raw output pointer smuggled into the scoped parallel-for. Blocks write
/// disjoint `(b, r)` cells, so the aliasing is sound (see SAFETY below).
struct SendPtr(*mut f32);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

/// Quantized GEMM over a packed layer: `out[b*rows + r] = Σ_j w[r,j] ·
/// x[b*cols + j]` with `w` decoded on the fly from `data`.
///
/// `x` is batch-major (`batch` rows of `cols`), `out` is batch-major
/// (`batch` rows of `rows`). With `pool`, row blocks run in parallel;
/// results are identical to the serial path.
#[allow(clippy::too_many_arguments)]
pub fn qgemm(
    data: &[u8],
    bits: u8,
    scale: f32,
    rows: usize,
    cols: usize,
    x: &[f32],
    batch: usize,
    out: &mut [f32],
    pool: Option<&ThreadPool>,
) {
    assert_eq!(x.len(), batch * cols, "qgemm: x shape");
    assert_eq!(out.len(), batch * rows, "qgemm: out shape");
    assert!((1..=8).contains(&bits), "qgemm: bits {bits}");
    if rows == 0 || batch == 0 {
        return;
    }
    let denom = ((1u32 << bits) - 1).max(1) as f32;
    let alpha = 2.0 * scale / denom;
    let xsums: Vec<f32> = (0..batch).map(|b| x[b * cols..(b + 1) * cols].iter().sum()).collect();

    let run_block = |blk: usize, scratch: &mut [f32], write: &mut dyn FnMut(usize, f32)| {
        let r0 = blk * ROW_BLOCK;
        let r1 = (r0 + ROW_BLOCK).min(rows);
        for r in r0..r1 {
            decode_codes_f32(data, r * cols * bits as usize, bits, scratch);
            for b in 0..batch {
                let acc = dot(scratch, &x[b * cols..(b + 1) * cols]);
                write(b * rows + r, alpha * acc - scale * xsums[b]);
            }
        }
    };

    let nblocks = rows.div_ceil(ROW_BLOCK);
    match pool {
        Some(pool) if nblocks > 1 => {
            let optr = SendPtr(out.as_mut_ptr());
            let optr = &optr;
            pool.par_for(nblocks, move |blk| {
                let mut scratch = vec![0f32; cols];
                run_block(blk, &mut scratch[..], &mut |idx, v| {
                    // SAFETY: `idx = b*rows + r` and every row `r` belongs
                    // to exactly one block, so concurrent blocks write
                    // disjoint cells of `out`, which outlives the scoped
                    // par_for. No one reads `out` until par_for returns.
                    unsafe { *optr.0.add(idx) = v }
                });
            });
        }
        _ => {
            let mut scratch = vec![0f32; cols];
            for blk in 0..nblocks {
                run_block(blk, &mut scratch[..], &mut |idx, v| out[idx] = v);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::pack::{pack_layer, unpack_layer};
    use crate::util::prng::Rng;

    fn rand_vec(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| r.normal() * 0.5).collect()
    }

    #[test]
    fn decode_matches_bitreader_at_any_offset() {
        for bits in 1u8..=8 {
            let cols = 13; // 13*bits is non-byte-aligned for most bits
            let rows = 7;
            let w = rand_vec(rows * cols, bits as u64);
            let p = pack_layer("l", &w, bits);
            // reference: sequential pull of every code
            let mut br = crate::quant::pack::BitReader::new(&p.data);
            let reference: Vec<f32> =
                (0..rows * cols).map(|_| br.pull(bits) as f32).collect();
            // decode each row independently at its bit offset
            let mut row = vec![0f32; cols];
            for r in 0..rows {
                decode_codes_f32(&p.data, r * cols * bits as usize, bits, &mut row);
                assert_eq!(&row[..], &reference[r * cols..(r + 1) * cols], "bits {bits} row {r}");
            }
        }
    }

    #[test]
    fn qgemm_matches_dense_reference() {
        for bits in [1u8, 2, 3, 5, 7, 8] {
            let (rows, cols, batch) = (19, 37, 3);
            let w = rand_vec(rows * cols, 100 + bits as u64);
            let p = pack_layer("l", &w, bits);
            let wq = unpack_layer(&p).unwrap(); // dequantized lattice weights
            let x = rand_vec(batch * cols, 200 + bits as u64);

            let mut expect = vec![0f32; batch * rows];
            for b in 0..batch {
                for r in 0..rows {
                    let mut acc = 0f64;
                    for j in 0..cols {
                        acc += wq[r * cols + j] as f64 * x[b * cols + j] as f64;
                    }
                    expect[b * rows + r] = acc as f32;
                }
            }

            let mut got = vec![0f32; batch * rows];
            qgemm(&p.data, bits, p.scale, rows, cols, &x, batch, &mut got, None);
            for (i, (g, e)) in got.iter().zip(&expect).enumerate() {
                assert!((g - e).abs() < 1e-3, "bits {bits} idx {i}: {g} vs {e}");
            }
        }
    }

    #[test]
    fn qgemm_pool_is_bitwise_equal_to_serial() {
        let (rows, cols, batch) = (101, 64, 4); // > ROW_BLOCK: multiple blocks
        let w = rand_vec(rows * cols, 7);
        let p = pack_layer("l", &w, 4);
        let x = rand_vec(batch * cols, 8);
        let mut serial = vec![0f32; batch * rows];
        let mut pooled = vec![0f32; batch * rows];
        qgemm(&p.data, 4, p.scale, rows, cols, &x, batch, &mut serial, None);
        let pool = ThreadPool::new(4);
        qgemm(&p.data, 4, p.scale, rows, cols, &x, batch, &mut pooled, Some(&pool));
        assert_eq!(serial, pooled);
    }

    #[test]
    fn qgemm_empty_batch_and_rows() {
        let p = pack_layer("l", &rand_vec(12, 1), 3);
        let mut out = vec![0f32; 0];
        qgemm(&p.data, 3, p.scale, 4, 3, &[], 0, &mut out, None);
        qgemm(&p.data, 3, p.scale, 0, 3, &[0.0; 3], 1, &mut out, None);
    }

    #[test]
    fn dot_handles_remainders() {
        let a: Vec<f32> = (0..11).map(|i| i as f32).collect();
        let b: Vec<f32> = (0..11).map(|i| (i * 2) as f32).collect();
        let expect: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert_eq!(dot(&a, &b), expect);
    }
}
