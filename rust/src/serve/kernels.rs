//! Pure-Rust quantized inference kernels over packed RoundClamp codes.
//!
//! The serving path never materializes an f32 weight tensor: `qgemm` and
//! `qconv2d` stream the n-bit codes (1..=8 bits, non-byte-aligned,
//! LSB-first — the exact `quant::pack` layout) out of the packed payload
//! one weight row (or conv filter) at a time and fold the affine
//! dequantization out of the inner loop:
//!
//! ```text
//! w = (c / (2^n - 1) - 0.5) · 2s          (RoundClamp dequant, Eq. 4)
//! y[b,r] = Σ_j w[r,j] x[b,j]
//!        = α · Σ_j c[r,j] x[b,j] + β · Σ_j x[b,j],   (α, β) = rc_affine
//! ```
//!
//! so the hot loop is a plain code·activation dot product running on the
//! shared kernel core ([`crate::kernels`]): the bit-stream decode, the
//! (α, β) affine, the lane-structured `dot`/`sum` primitives, and the
//! conv window geometry all live there, shared with the native training
//! kernels. `qgemm` processes rows in cache-friendly blocks: each block
//! decodes one row at a time into a small scratch buffer and reuses it
//! across the whole batch. `qconv2d` applies the same decode-once trick
//! per *filter*: a filter's `kh·kw·in_ch` codes are decoded once, then
//! the whole batch's output map streams through an im2col-free inner
//! loop whose innermost dot runs over contiguous memory on both sides
//! (OHWI filters against NHWC activations). The `Σ x` correction term
//! becomes a per-position receptive-field sum shared by every output
//! channel.
//!
//! **Bit-exactness invariant** (property-tested below): blocks (rows /
//! filter groups) partition disjoint output cells and every output
//! element is one lane-structured reduction, so {serial, pooled} ×
//! {scalar, simd} all produce identical logits — see the contract in
//! [`crate::kernels`].
//!
//! **Integer path** (`--int8`): [`qgemm_int`] / [`qconv2d_int`] are the
//! i32-accumulate twins. Activations quantize to u8 against an
//! observer-calibrated [`ActQuant`], weight codes stay u8, and the
//! zero-point correction folds into the same per-output Σ term the
//! float path already carries (see [`crate::kernels::qgemm_int`] for
//! the identity and the `n·scale·step/2` accuracy bound, both
//! property-tested below). Integer sums are order-independent, so
//! serial ≡ pooled holds on this path too.

use crate::kernels::{
    decode_codes_u8, dequant_affine, dot, dot_u8, matmul_bt, mha_forward_sample, par_blocks,
    rc_affine, sum, sum_u8, window_dot, window_dot_u8, window_sum, window_sum_u8, ActQuant,
    SendPtr, MAX_INT_DOT_COLS,
};
use crate::quant::pack::Conv2dDesc;
use crate::util::threadpool::ThreadPool;
use std::sync::Arc;
use std::time::Instant;

use super::weightcache::{self, CacheKey};

// Re-exported for API continuity: the decode primitive and the window
// geometry moved into the shared kernel core, but they remain part of
// this module's public face (tests, benches, and the native ops found
// them here first).
pub use crate::kernels::{decode_codes_f32, krange};

/// Rows per parallel work item. Small enough to balance across cores,
/// large enough that scratch allocation and task dispatch amortize.
const ROW_BLOCK: usize = 32;

/// Conv filters per parallel work item — one filter is a whole output
/// map of work per sample, so blocks are smaller than gemm rows.
const FILTER_BLOCK: usize = 4;

/// Resolve a layer's full decoded f32 code matrix through the shared
/// weight cache: on a miss, `fill` decodes all `total` values (running
/// the *same* per-row/per-filter `decode_codes_f32` calls the scratch
/// path would) and endpoint saturation is tallied once at fill time.
/// Returns `None` when the cache is disabled or the key is absent —
/// callers then run their legacy scratch-decode path.
///
/// Telemetry at fill mirrors one whole-layer decode (bytes/codes
/// accounted once); cache *hits* skip decode profiling and saturation
/// sampling entirely — that, and nothing numeric, is the observable
/// difference between cache on and off.
fn cached_f32(
    key: Option<CacheKey>,
    total: usize,
    max_code: f32,
    prof: bool,
    qsample: bool,
    qs: &'static crate::obs::qstats::QStats,
    bytes: u64,
    fill: impl FnOnce(&mut [f32]),
) -> Option<Arc<Vec<f32>>> {
    let k = key?;
    let mut fill_sat = (0u64, 0u64);
    let mut filled = false;
    let t0 = if prof { Some(Instant::now()) } else { None };
    let got = weightcache::cache().get_or_decode_f32(k, || {
        filled = true;
        let mut w = vec![0f32; total];
        fill(&mut w);
        if qsample {
            // raw codes, pre-affine: endpoint equality is exact
            for &c in w.iter() {
                if c == 0.0 {
                    fill_sat.0 += 1;
                } else if c == max_code {
                    fill_sat.1 += 1;
                }
            }
        }
        w
    });
    if filled {
        if let Some(t) = t0 {
            let dec_ns = t.elapsed().as_nanos() as u64;
            crate::obs::profiler().add_kernel(dec_ns, 0, bytes, total as u64);
        }
        if qsample {
            qs.add_saturation(fill_sat.0, fill_sat.1);
        }
    }
    got
}

/// u8 twin of [`cached_f32`] for the integer path (`decode_codes_u8`
/// fills, same fill-time telemetry contract).
#[allow(clippy::too_many_arguments)]
fn cached_u8(
    key: Option<CacheKey>,
    total: usize,
    max_code: u8,
    prof: bool,
    qsample: bool,
    qs: &'static crate::obs::qstats::QStats,
    bytes: u64,
    fill: impl FnOnce(&mut [u8]),
) -> Option<Arc<Vec<u8>>> {
    let k = key?;
    let mut fill_sat = (0u64, 0u64);
    let mut filled = false;
    let t0 = if prof { Some(Instant::now()) } else { None };
    let got = weightcache::cache().get_or_decode_u8(k, || {
        filled = true;
        let mut w = vec![0u8; total];
        fill(&mut w);
        if qsample {
            for &c in w.iter() {
                if c == 0 {
                    fill_sat.0 += 1;
                } else if c == max_code {
                    fill_sat.1 += 1;
                }
            }
        }
        w
    });
    if filled {
        if let Some(t) = t0 {
            let dec_ns = t.elapsed().as_nanos() as u64;
            crate::obs::profiler().add_kernel(dec_ns, 0, bytes, total as u64);
        }
        if qsample {
            qs.add_saturation(fill_sat.0, fill_sat.1);
        }
    }
    got
}

/// Quantized GEMM over a packed layer: `out[b*rows + r] = Σ_j w[r,j] ·
/// x[b*cols + j]` with `w` decoded on the fly from `data`.
///
/// `x` is batch-major (`batch` rows of `cols`), `out` is batch-major
/// (`batch` rows of `rows`). With `pool`, row blocks run in parallel;
/// results are identical to the serial path.
#[allow(clippy::too_many_arguments)]
pub fn qgemm(
    data: &[u8],
    bits: u8,
    scale: f32,
    rows: usize,
    cols: usize,
    x: &[f32],
    batch: usize,
    out: &mut [f32],
    pool: Option<&ThreadPool>,
) {
    qgemm_keyed(None, data, bits, scale, rows, cols, x, batch, out, pool)
}

/// [`qgemm`] with a weight-cache identity: when `key` is set and the
/// shared cache is enabled, the layer's raw-code f32 matrix is decoded
/// once per (model generation, layer) and row slices are served from the
/// arena instead of per-call scratch. Bit-identical to `qgemm` — the
/// cached rows are produced by the same decode and consumed by the same
/// dot/affine — so the cache is purely a decode-work eliminator.
#[allow(clippy::too_many_arguments)]
pub fn qgemm_keyed(
    key: Option<CacheKey>,
    data: &[u8],
    bits: u8,
    scale: f32,
    rows: usize,
    cols: usize,
    x: &[f32],
    batch: usize,
    out: &mut [f32],
    pool: Option<&ThreadPool>,
) {
    assert_eq!(x.len(), batch * cols, "qgemm: x shape");
    assert_eq!(out.len(), batch * rows, "qgemm: out shape");
    assert!((1..=8).contains(&bits), "qgemm: bits {bits}");
    if rows == 0 || batch == 0 {
        return;
    }
    let (alpha, beta) = rc_affine(bits as f32, scale);
    let xsums: Vec<f32> = (0..batch).map(|b| sum(&x[b * cols..(b + 1) * cols])).collect();

    // One relaxed load per call; when off, no clocks are read in the
    // hot loop (see `obs::Profiler` — zero-cost-when-off contract).
    let prof = crate::obs::profiler().on();
    // Activation observers share the contract: one relaxed load (plus the
    // sampling stride) per call, decided once here so every block of this
    // call agrees, and never touching `out` — bit-exactness holds.
    let qs = crate::obs::qstats::qstats();
    let qsample = qs.sample();
    if qsample {
        qs.observe_input(x);
    }
    let max_code = ((1u32 << bits) - 1) as f32;
    let row_bytes = (cols * bits as usize).div_ceil(8) as u64;
    // Whole-layer raw-code matrix out of the shared arena (None = cache
    // off / unkeyed call → legacy per-row scratch decode below).
    let layer_bytes = rows as u64 * row_bytes;
    let cached = cached_f32(key, rows * cols, max_code, prof, qsample, qs, layer_bytes, |w| {
        for r in 0..rows {
            let row = &mut w[r * cols..(r + 1) * cols];
            decode_codes_f32(data, r * cols * bits as usize, bits, row);
        }
    });
    let cached = &cached;
    let run_block = |blk: usize, scratch: &mut [f32], write: &mut dyn FnMut(usize, f32)| {
        let r0 = blk * ROW_BLOCK;
        let r1 = (r0 + ROW_BLOCK).min(rows);
        let (mut dec_ns, mut mm_ns) = (0u64, 0u64);
        let (mut sat_lo, mut sat_hi) = (0u64, 0u64);
        for r in r0..r1 {
            let wrow: &[f32] = if let Some(w) = cached.as_deref() {
                // same bytes the scratch decode would produce (filled by
                // the identical decode_codes_f32 call at cache-fill time)
                &w[r * cols..(r + 1) * cols]
            } else {
                let t0 = if prof { Some(Instant::now()) } else { None };
                decode_codes_f32(data, r * cols * bits as usize, bits, scratch);
                if let Some(t) = t0 {
                    dec_ns += t.elapsed().as_nanos() as u64;
                }
                if qsample {
                    // scratch holds RAW codes here (the affine folds out at
                    // write time), so endpoint equality is exact integer math
                    for &c in scratch.iter() {
                        if c == 0.0 {
                            sat_lo += 1;
                        } else if c == max_code {
                            sat_hi += 1;
                        }
                    }
                }
                scratch
            };
            let t1 = if prof { Some(Instant::now()) } else { None };
            for b in 0..batch {
                let acc = dot(wrow, &x[b * cols..(b + 1) * cols]);
                write(b * rows + r, alpha * acc + beta * xsums[b]);
            }
            if let Some(t) = t1 {
                mm_ns += t.elapsed().as_nanos() as u64;
            }
        }
        if prof {
            let nrows = (r1 - r0) as u64;
            let (bytes, codes) =
                if cached.is_some() { (0, 0) } else { (nrows * row_bytes, nrows * cols as u64) };
            crate::obs::profiler().add_kernel(dec_ns, mm_ns, bytes, codes);
        }
        if qsample {
            qs.add_saturation(sat_lo, sat_hi);
        }
    };

    let nblocks = rows.div_ceil(ROW_BLOCK);
    match pool {
        Some(pool) if nblocks > 1 => {
            let optr = SendPtr(out.as_mut_ptr());
            let optr = &optr;
            pool.par_for(nblocks, move |blk| {
                let mut scratch = vec![0f32; cols];
                run_block(blk, &mut scratch[..], &mut |idx, v| {
                    // SAFETY: `idx = b*rows + r` and every row `r` belongs
                    // to exactly one block, so concurrent blocks write
                    // disjoint cells of `out`, which outlives the scoped
                    // par_for. No one reads `out` until par_for returns.
                    unsafe { *optr.get().add(idx) = v }
                });
            });
        }
        _ => {
            let mut scratch = vec![0f32; cols];
            for blk in 0..nblocks {
                run_block(blk, &mut scratch[..], &mut |idx, v| out[idx] = v);
            }
        }
    }
}

/// Quantized 2-D convolution over a packed conv layer: NHWC activations
/// against OHWI filters whose codes are decoded once per filter and
/// reused across the whole batch (the conv twin of `qgemm`'s row-block
/// trick — no im2col buffer is ever built).
///
/// `x` is `batch × in_h × in_w × in_ch`, `out` is `batch × out_h ×
/// out_w × out_ch` with `(out_h, out_w) = d.out_hw(in_h, in_w)`. Zero
/// padding is handled by clipping the tap ranges
/// ([`crate::kernels::krange`]), which is exact for the affine folding
/// because padded positions contribute zero to both the
/// code·activation dot and the receptive-field sum. With `pool`, filter
/// blocks run in parallel; results are bit-identical to the serial path.
#[allow(clippy::too_many_arguments)]
pub fn qconv2d(
    data: &[u8],
    bits: u8,
    scale: f32,
    d: &Conv2dDesc,
    in_h: usize,
    in_w: usize,
    x: &[f32],
    batch: usize,
    out: &mut [f32],
    pool: Option<&ThreadPool>,
) {
    qconv2d_keyed(None, data, bits, scale, d, in_h, in_w, x, batch, out, pool)
}

/// [`qconv2d`] with a weight-cache identity — the conv twin of
/// [`qgemm_keyed`]: the layer's full raw-code filter bank decodes once
/// per (model generation, layer) and per-filter slices come out of the
/// shared arena. Bit-identical to the uncached path.
#[allow(clippy::too_many_arguments)]
pub fn qconv2d_keyed(
    key: Option<CacheKey>,
    data: &[u8],
    bits: u8,
    scale: f32,
    d: &Conv2dDesc,
    in_h: usize,
    in_w: usize,
    x: &[f32],
    batch: usize,
    out: &mut [f32],
    pool: Option<&ThreadPool>,
) {
    let (out_h, out_w) = d.out_hw(in_h, in_w).expect("qconv2d: invalid geometry");
    let in_elems = in_h * in_w * d.in_ch;
    let out_elems = out_h * out_w * d.out_ch;
    assert_eq!(x.len(), batch * in_elems, "qconv2d: x shape");
    assert_eq!(out.len(), batch * out_elems, "qconv2d: out shape");
    assert!((1..=8).contains(&bits), "qconv2d: bits {bits}");
    if batch == 0 {
        return;
    }
    let (alpha, beta) = rc_affine(bits as f32, scale);

    // Σ x over each receptive field (the dequant correction term) —
    // shared by every output channel, so it costs one extra "channel".
    // For small out_ch this pass is a visible fraction of the layer's
    // work, so it parallelizes over samples (disjoint psums rows) rather
    // than running serially ahead of the filter blocks.
    let mut psums = vec![0f32; batch * out_h * out_w];
    let psum_sample = |b: usize, prow: &mut dyn FnMut(usize, f32)| {
        let xb = &x[b * in_elems..(b + 1) * in_elems];
        for oy in 0..out_h {
            let (ky0, ky1, iy0) = krange(oy, d.stride, d.pad, d.kh, in_h);
            for ox in 0..out_w {
                let (kx0, kx1, ix0) = krange(ox, d.stride, d.pad, d.kw, in_w);
                let seg = (kx1 - kx0) * d.in_ch;
                let s = window_sum(xb, in_w, d.in_ch, ky0, ky1, iy0, ix0, seg);
                prow((b * out_h + oy) * out_w + ox, s);
            }
        }
    };
    match pool {
        Some(pool) if batch > 1 => {
            let pptr = SendPtr(psums.as_mut_ptr());
            let pptr = &pptr;
            pool.par_for(batch, move |b| {
                // SAFETY: sample `b` writes only indices in
                // [b·out_h·out_w, (b+1)·out_h·out_w) — disjoint per task;
                // `psums` outlives the scoped par_for and is not read
                // until it returns.
                psum_sample(b, &mut |idx, v| unsafe { *pptr.get().add(idx) = v });
            });
        }
        _ => {
            for b in 0..batch {
                psum_sample(b, &mut |idx, v| psums[idx] = v);
            }
        }
    }

    let flen = d.filter_len();
    let prof = crate::obs::profiler().on();
    // Same per-call observation gate as qgemm (see there).
    let qs = crate::obs::qstats::qstats();
    let qsample = qs.sample();
    if qsample {
        qs.observe_input(x);
    }
    let max_code = ((1u32 << bits) - 1) as f32;
    let filter_bytes = (flen * bits as usize).div_ceil(8) as u64;
    let layer_bytes = d.out_ch as u64 * filter_bytes;
    let cached = cached_f32(key, d.out_ch * flen, max_code, prof, qsample, qs, layer_bytes, |w| {
        for oc in 0..d.out_ch {
            let fil = &mut w[oc * flen..(oc + 1) * flen];
            decode_codes_f32(data, oc * flen * bits as usize, bits, fil);
        }
    });
    let cached = &cached;
    let run_block = |blk: usize, scratch: &mut [f32], write: &mut dyn FnMut(usize, f32)| {
        let oc0 = blk * FILTER_BLOCK;
        let oc1 = (oc0 + FILTER_BLOCK).min(d.out_ch);
        let (mut dec_ns, mut mm_ns) = (0u64, 0u64);
        let (mut sat_lo, mut sat_hi) = (0u64, 0u64);
        for oc in oc0..oc1 {
            let wfil: &[f32] = if let Some(w) = cached.as_deref() {
                // cache hit: the arena slice holds the same codes
                // decode_codes_f32 would produce (it was filled by the
                // identical call at cache-fill time)
                &w[oc * flen..(oc + 1) * flen]
            } else {
                // decode this filter's kh·kw·in_ch codes exactly once
                let t0 = if prof { Some(Instant::now()) } else { None };
                decode_codes_f32(data, oc * flen * bits as usize, bits, scratch);
                if let Some(t) = t0 {
                    dec_ns += t.elapsed().as_nanos() as u64;
                }
                if qsample {
                    // raw filter codes, pre-affine — exact endpoint equality
                    for &c in scratch.iter() {
                        if c == 0.0 {
                            sat_lo += 1;
                        } else if c == max_code {
                            sat_hi += 1;
                        }
                    }
                }
                scratch
            };
            let t1 = if prof { Some(Instant::now()) } else { None };
            for b in 0..batch {
                let xb = &x[b * in_elems..(b + 1) * in_elems];
                for oy in 0..out_h {
                    let (ky0, ky1, iy0) = krange(oy, d.stride, d.pad, d.kh, in_h);
                    for ox in 0..out_w {
                        let (kx0, kx1, ix0) = krange(ox, d.stride, d.pad, d.kw, in_w);
                        let seg = (kx1 - kx0) * d.in_ch;
                        let acc = window_dot(
                            wfil, xb, d.kw, in_w, d.in_ch, ky0, ky1, iy0, kx0, ix0, seg,
                        );
                        let pos = (b * out_h + oy) * out_w + ox;
                        write(pos * d.out_ch + oc, alpha * acc + beta * psums[pos]);
                    }
                }
            }
            if let Some(t) = t1 {
                mm_ns += t.elapsed().as_nanos() as u64;
            }
        }
        if prof {
            let nf = (oc1 - oc0) as u64;
            // cached layers already charged their decode bytes/codes at
            // fill time; per-block reports count only fresh decodes.
            let (bytes, codes) = if cached.is_some() {
                (0, 0)
            } else {
                (nf * filter_bytes, nf * flen as u64)
            };
            crate::obs::profiler().add_kernel(dec_ns, mm_ns, bytes, codes);
        }
        if qsample {
            qs.add_saturation(sat_lo, sat_hi);
        }
    };

    let nblocks = d.out_ch.div_ceil(FILTER_BLOCK);
    match pool {
        Some(pool) if nblocks > 1 => {
            let optr = SendPtr(out.as_mut_ptr());
            let optr = &optr;
            pool.par_for(nblocks, move |blk| {
                let mut scratch = vec![0f32; flen];
                run_block(blk, &mut scratch[..], &mut |idx, v| {
                    // SAFETY: `idx = pos·out_ch + oc` and every filter
                    // `oc` belongs to exactly one block, so concurrent
                    // blocks write disjoint cells of `out`, which
                    // outlives the scoped par_for. No one reads `out`
                    // until par_for returns.
                    unsafe { *optr.get().add(idx) = v }
                });
            });
        }
        _ => {
            let mut scratch = vec![0f32; flen];
            for blk in 0..nblocks {
                run_block(blk, &mut scratch[..], &mut |idx, v| out[idx] = v);
            }
        }
    }
}

/// Integer-domain twin of [`qgemm`]: activations quantize once per call
/// to u8 against `act` (observer-calibrated), weight codes decode to u8,
/// and the inner loop is a u8×u8→i32 dot. Dequantization is one fused
/// affine per output element:
///
/// ```text
/// out[b,r] = (α·s)·(Σ_j c[r,j]·q[b,j] − 128·Σ_j c[r,j])
///          + (β·s)·(Σ_j q[b,j] − 128·cols)
/// ```
///
/// Each output differs from [`qgemm`] by at most
/// `cols · scale · act.step()/2` (+ f32 roundoff) when `act` covers the
/// input range — the property tests below pin this. Requires
/// `cols ≤ MAX_INT_DOT_COLS` (i32 accumulation is exact there; the
/// serving planner falls back to the float kernel beyond it). Pooled
/// runs are bit-identical to serial: integer sums are order-independent
/// and the float finalize runs once per element.
#[allow(clippy::too_many_arguments)]
pub fn qgemm_int(
    data: &[u8],
    bits: u8,
    scale: f32,
    rows: usize,
    cols: usize,
    x: &[f32],
    batch: usize,
    act: &ActQuant,
    out: &mut [f32],
    pool: Option<&ThreadPool>,
) {
    qgemm_int_keyed(None, data, bits, scale, rows, cols, x, batch, act, out, pool)
}

/// [`qgemm_int`] with a weight-cache identity: the layer's u8 code
/// matrix decodes once per (model generation, layer) into the shared
/// arena. Bit-identical to the uncached path — the integer row sums and
/// dots read the exact same u8 codes either way.
#[allow(clippy::too_many_arguments)]
pub fn qgemm_int_keyed(
    key: Option<CacheKey>,
    data: &[u8],
    bits: u8,
    scale: f32,
    rows: usize,
    cols: usize,
    x: &[f32],
    batch: usize,
    act: &ActQuant,
    out: &mut [f32],
    pool: Option<&ThreadPool>,
) {
    assert_eq!(x.len(), batch * cols, "qgemm_int: x shape");
    assert_eq!(out.len(), batch * rows, "qgemm_int: out shape");
    assert!((1..=8).contains(&bits), "qgemm_int: bits {bits}");
    assert!(cols <= MAX_INT_DOT_COLS, "qgemm_int: cols {cols} overflows i32 accumulation");
    if rows == 0 || batch == 0 {
        return;
    }
    let (alpha, beta) = rc_affine(bits as f32, scale);
    let (af, bf) = (alpha * act.scale, beta * act.scale);

    // Quantize the whole batch once; fold the zero-point half of the
    // Σx̂ correction into a per-sample constant (the int analog of
    // qgemm's `xsums`).
    let mut qx = vec![0u8; batch * cols];
    let mut xterms = vec![0f32; batch];
    for b in 0..batch {
        let qb = &mut qx[b * cols..(b + 1) * cols];
        act.quantize(&x[b * cols..(b + 1) * cols], qb);
        xterms[b] = bf * (sum_u8(qb) - 128 * cols as i32) as f32;
    }

    // Same per-call observation gates as qgemm (see there). The float
    // input is observed, so calibration keeps tracking the true range
    // while the integer path serves.
    let prof = crate::obs::profiler().on();
    let qs = crate::obs::qstats::qstats();
    let qsample = qs.sample();
    if qsample {
        qs.observe_input(x);
    }
    let max_code = ((1u32 << bits) - 1) as u8;
    let row_bytes = (cols * bits as usize).div_ceil(8) as u64;
    let layer_bytes = rows as u64 * row_bytes;
    let cached = cached_u8(key, rows * cols, max_code, prof, qsample, qs, layer_bytes, |w| {
        for r in 0..rows {
            let row = &mut w[r * cols..(r + 1) * cols];
            decode_codes_u8(data, r * cols * bits as usize, bits, row);
        }
    });
    let cached = &cached;
    let run_block = |blk: usize, scratch: &mut [u8], write: &mut dyn FnMut(usize, f32)| {
        let r0 = blk * ROW_BLOCK;
        let r1 = (r0 + ROW_BLOCK).min(rows);
        let (mut dec_ns, mut mm_ns) = (0u64, 0u64);
        let (mut sat_lo, mut sat_hi) = (0u64, 0u64);
        for r in r0..r1 {
            let wrow: &[u8] = if let Some(w) = cached.as_deref() {
                // cache hit: the arena slice holds the same codes
                // decode_codes_u8 would produce (it was filled by the
                // identical call at cache-fill time)
                &w[r * cols..(r + 1) * cols]
            } else {
                let t0 = if prof { Some(Instant::now()) } else { None };
                decode_codes_u8(data, r * cols * bits as usize, bits, scratch);
                if let Some(t) = t0 {
                    dec_ns += t.elapsed().as_nanos() as u64;
                }
                if qsample {
                    // raw integer codes: endpoint equality is exact
                    for &c in scratch.iter() {
                        if c == 0 {
                            sat_lo += 1;
                        } else if c == max_code {
                            sat_hi += 1;
                        }
                    }
                }
                scratch
            };
            let t1 = if prof { Some(Instant::now()) } else { None };
            // `wsum` is an exact integer sum, so recomputing it from the
            // cached row is bit-identical to the scratch-decode path.
            let wsum = sum_u8(wrow);
            for b in 0..batch {
                let acc = dot_u8(wrow, &qx[b * cols..(b + 1) * cols]);
                write(b * rows + r, af * (acc - 128 * wsum) as f32 + xterms[b]);
            }
            if let Some(t) = t1 {
                mm_ns += t.elapsed().as_nanos() as u64;
            }
        }
        if prof {
            let nrows = (r1 - r0) as u64;
            let (bytes, codes) = if cached.is_some() {
                (0, 0)
            } else {
                (nrows * row_bytes, nrows * cols as u64)
            };
            crate::obs::profiler().add_kernel(dec_ns, mm_ns, bytes, codes);
        }
        if qsample {
            qs.add_saturation(sat_lo, sat_hi);
        }
    };

    let nblocks = rows.div_ceil(ROW_BLOCK);
    match pool {
        Some(pool) if nblocks > 1 => {
            let optr = SendPtr(out.as_mut_ptr());
            let optr = &optr;
            pool.par_for(nblocks, move |blk| {
                let mut scratch = vec![0u8; cols];
                run_block(blk, &mut scratch[..], &mut |idx, v| {
                    // SAFETY: `idx = b*rows + r` and every row `r` belongs
                    // to exactly one block, so concurrent blocks write
                    // disjoint cells of `out`, which outlives the scoped
                    // par_for. No one reads `out` until par_for returns.
                    unsafe { *optr.get().add(idx) = v }
                });
            });
        }
        _ => {
            let mut scratch = vec![0u8; cols];
            for blk in 0..nblocks {
                run_block(blk, &mut scratch[..], &mut |idx, v| out[idx] = v);
            }
        }
    }
}

/// Integer-domain twin of [`qconv2d`]: the same decode-once-per-filter
/// structure with u8 filter codes against the u8-quantized activation
/// map. Because `krange` clipping varies per output position, the code
/// sum `Σ w` comes out of the *same clipped window* as the dot
/// ([`crate::kernels::window_dot_u8`]); the per-position Σq and its tap
/// count fold the zero-point correction into one f32 constant per
/// position (the int analog of `psums`). Accuracy bound and the
/// pooled ≡ serial guarantee are as in [`qgemm_int`], with the
/// receptive-field length in place of `cols`.
#[allow(clippy::too_many_arguments)]
pub fn qconv2d_int(
    data: &[u8],
    bits: u8,
    scale: f32,
    d: &Conv2dDesc,
    in_h: usize,
    in_w: usize,
    x: &[f32],
    batch: usize,
    act: &ActQuant,
    out: &mut [f32],
    pool: Option<&ThreadPool>,
) {
    qconv2d_int_keyed(None, data, bits, scale, d, in_h, in_w, x, batch, act, out, pool)
}

/// [`qconv2d_int`] with a weight-cache identity — see [`qgemm_int_keyed`].
#[allow(clippy::too_many_arguments)]
pub fn qconv2d_int_keyed(
    key: Option<CacheKey>,
    data: &[u8],
    bits: u8,
    scale: f32,
    d: &Conv2dDesc,
    in_h: usize,
    in_w: usize,
    x: &[f32],
    batch: usize,
    act: &ActQuant,
    out: &mut [f32],
    pool: Option<&ThreadPool>,
) {
    let (out_h, out_w) = d.out_hw(in_h, in_w).expect("qconv2d_int: invalid geometry");
    let in_elems = in_h * in_w * d.in_ch;
    let out_elems = out_h * out_w * d.out_ch;
    assert_eq!(x.len(), batch * in_elems, "qconv2d_int: x shape");
    assert_eq!(out.len(), batch * out_elems, "qconv2d_int: out shape");
    assert!((1..=8).contains(&bits), "qconv2d_int: bits {bits}");
    let flen = d.filter_len();
    assert!(flen <= MAX_INT_DOT_COLS, "qconv2d_int: filter {flen} overflows i32 accumulation");
    if batch == 0 {
        return;
    }
    let (alpha, beta) = rc_affine(bits as f32, scale);
    let (af, bf) = (alpha * act.scale, beta * act.scale);

    let mut qx = vec![0u8; batch * in_elems];
    act.quantize(x, &mut qx);

    // Per-position zero-point-corrected Σx̂ term, prefolded to f32:
    // `(β·s)·(Σ q − 128·taps)` over each clipped receptive field —
    // shared by every output channel, parallel over samples like the
    // float path's psums pass.
    let mut xterms = vec![0f32; batch * out_h * out_w];
    let xterm_sample = |b: usize, prow: &mut dyn FnMut(usize, f32)| {
        let qb = &qx[b * in_elems..(b + 1) * in_elems];
        for oy in 0..out_h {
            let (ky0, ky1, iy0) = krange(oy, d.stride, d.pad, d.kh, in_h);
            for ox in 0..out_w {
                let (kx0, kx1, ix0) = krange(ox, d.stride, d.pad, d.kw, in_w);
                let seg = (kx1 - kx0) * d.in_ch;
                let (qsum, taps) = window_sum_u8(qb, in_w, d.in_ch, ky0, ky1, iy0, ix0, seg);
                prow((b * out_h + oy) * out_w + ox, bf * (qsum - 128 * taps) as f32);
            }
        }
    };
    match pool {
        Some(pool) if batch > 1 => {
            let pptr = SendPtr(xterms.as_mut_ptr());
            let pptr = &pptr;
            pool.par_for(batch, move |b| {
                // SAFETY: sample `b` writes only indices in
                // [b·out_h·out_w, (b+1)·out_h·out_w) — disjoint per task;
                // `xterms` outlives the scoped par_for and is not read
                // until it returns.
                xterm_sample(b, &mut |idx, v| unsafe { *pptr.get().add(idx) = v });
            });
        }
        _ => {
            for b in 0..batch {
                xterm_sample(b, &mut |idx, v| xterms[idx] = v);
            }
        }
    }

    let prof = crate::obs::profiler().on();
    // Same per-call observation gate as qgemm (see there).
    let qs = crate::obs::qstats::qstats();
    let qsample = qs.sample();
    if qsample {
        qs.observe_input(x);
    }
    let max_code = ((1u32 << bits) - 1) as u8;
    let filter_bytes = (flen * bits as usize).div_ceil(8) as u64;
    let layer_bytes = d.out_ch as u64 * filter_bytes;
    let cached = cached_u8(key, d.out_ch * flen, max_code, prof, qsample, qs, layer_bytes, |w| {
        for oc in 0..d.out_ch {
            let fil = &mut w[oc * flen..(oc + 1) * flen];
            decode_codes_u8(data, oc * flen * bits as usize, bits, fil);
        }
    });
    let cached = &cached;
    let run_block = |blk: usize, scratch: &mut [u8], write: &mut dyn FnMut(usize, f32)| {
        let oc0 = blk * FILTER_BLOCK;
        let oc1 = (oc0 + FILTER_BLOCK).min(d.out_ch);
        let (mut dec_ns, mut mm_ns) = (0u64, 0u64);
        let (mut sat_lo, mut sat_hi) = (0u64, 0u64);
        for oc in oc0..oc1 {
            let wfil: &[u8] = if let Some(w) = cached.as_deref() {
                // cache hit: same u8 codes the scratch decode would yield
                &w[oc * flen..(oc + 1) * flen]
            } else {
                // decode this filter's kh·kw·in_ch codes exactly once
                let t0 = if prof { Some(Instant::now()) } else { None };
                decode_codes_u8(data, oc * flen * bits as usize, bits, scratch);
                if let Some(t) = t0 {
                    dec_ns += t.elapsed().as_nanos() as u64;
                }
                if qsample {
                    for &c in scratch.iter() {
                        if c == 0 {
                            sat_lo += 1;
                        } else if c == max_code {
                            sat_hi += 1;
                        }
                    }
                }
                scratch
            };
            let t1 = if prof { Some(Instant::now()) } else { None };
            for b in 0..batch {
                let qb = &qx[b * in_elems..(b + 1) * in_elems];
                for oy in 0..out_h {
                    let (ky0, ky1, iy0) = krange(oy, d.stride, d.pad, d.kh, in_h);
                    for ox in 0..out_w {
                        let (kx0, kx1, ix0) = krange(ox, d.stride, d.pad, d.kw, in_w);
                        let seg = (kx1 - kx0) * d.in_ch;
                        let (acc, wsum) = window_dot_u8(
                            wfil, qb, d.kw, in_w, d.in_ch, ky0, ky1, iy0, kx0, ix0, seg,
                        );
                        let pos = (b * out_h + oy) * out_w + ox;
                        write(pos * d.out_ch + oc, af * (acc - 128 * wsum) as f32 + xterms[pos]);
                    }
                }
            }
            if let Some(t) = t1 {
                mm_ns += t.elapsed().as_nanos() as u64;
            }
        }
        if prof {
            let nf = (oc1 - oc0) as u64;
            let (bytes, codes) = if cached.is_some() {
                (0, 0)
            } else {
                (nf * filter_bytes, nf * flen as u64)
            };
            crate::obs::profiler().add_kernel(dec_ns, mm_ns, bytes, codes);
        }
        if qsample {
            qs.add_saturation(sat_lo, sat_hi);
        }
    };

    let nblocks = d.out_ch.div_ceil(FILTER_BLOCK);
    match pool {
        Some(pool) if nblocks > 1 => {
            let optr = SendPtr(out.as_mut_ptr());
            let optr = &optr;
            pool.par_for(nblocks, move |blk| {
                let mut scratch = vec![0u8; flen];
                run_block(blk, &mut scratch[..], &mut |idx, v| {
                    // SAFETY: `idx = pos·out_ch + oc` and every filter
                    // `oc` belongs to exactly one block, so concurrent
                    // blocks write disjoint cells of `out`, which
                    // outlives the scoped par_for. No one reads `out`
                    // until par_for returns.
                    unsafe { *optr.get().add(idx) = v }
                });
            });
        }
        _ => {
            let mut scratch = vec![0u8; flen];
            for blk in 0..nblocks {
                run_block(blk, &mut scratch[..], &mut |idx, v| out[idx] = v);
            }
        }
    }
}

/// One attention projection's packed weights: the n-bit code stream of a
/// `d × d` linear record an attention descriptor references, plus its
/// quant metadata. The serving registry builds these from the consumed
/// records at plan time; `qattention` decodes each exactly once per
/// call.
#[derive(Clone)]
pub struct ProjWeights {
    pub bits: u8,
    pub scale: f32,
    pub data: Vec<u8>,
    /// Weight-cache identity for this projection (slot 1..=4 of the
    /// owning attention layer), stamped by the registry once the model
    /// generation is known; `None` decodes fresh on every call.
    pub cache_key: Option<CacheKey>,
}

impl std::fmt::Debug for ProjWeights {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ProjWeights")
            .field("bits", &self.bits)
            .field("scale", &self.scale)
            .field("payload_bytes", &self.data.len())
            .field("cache_key", &self.cache_key)
            .finish()
    }
}

impl ProjWeights {
    /// Decode the full `d × d` lattice matrix (codes → RoundClamp
    /// weights), through the shared weight cache when this projection
    /// carries a [`CacheKey`] — otherwise one allocation per projection
    /// per `qattention` call (the "decode once per generation"
    /// contract).
    ///
    /// When `sat` is given, endpoint codes (0 and `2^bits − 1`) are
    /// tallied into it *before* the affine is applied — post-affine
    /// float equality would be rounding-unreliable. Cache hits tally
    /// nothing: saturation was already counted when the entry was
    /// filled.
    fn decode(&self, d: usize, mut sat: Option<&mut (u64, u64)>) -> Arc<Vec<f32>> {
        if let Some(key) = self.cache_key {
            if weightcache::cache().enabled() {
                let sat_ref = &mut sat;
                let got = weightcache::cache()
                    .get_or_decode_f32(key, || self.decode_fresh(d, sat_ref.take()));
                if let Some(w) = got {
                    return w;
                }
            }
        }
        Arc::new(self.decode_fresh(d, sat))
    }

    fn decode_fresh(&self, d: usize, sat: Option<&mut (u64, u64)>) -> Vec<f32> {
        let mut w = vec![0f32; d * d];
        decode_codes_f32(&self.data, 0, self.bits, &mut w);
        if let Some(s) = sat {
            let max_code = ((1u32 << self.bits) - 1) as f32;
            for &c in w.iter() {
                if c == 0.0 {
                    s.0 += 1;
                } else if c == max_code {
                    s.1 += 1;
                }
            }
        }
        let (alpha, beta) = rc_affine(self.bits as f32, self.scale);
        dequant_affine(&mut w, alpha, beta);
        w
    }
}

/// Quantized multi-head self-attention over a packed attention record:
/// per sample, project `x` through the four decoded weight matrices
/// (`Q/K/V` then output) with the tiled [`matmul_bt`] core, and stream
/// heads through the shared [`mha_forward_sample`] softmax·V kernel.
///
/// `x` and `out` are `batch × seq × d` row-major with
/// `d = heads · head_dim`. The four projections are decoded exactly once
/// per call and shared by every sample. With `pool`, samples run in
/// parallel (disjoint output slices); a single-sample batch parallelizes
/// inside the matmuls instead — either way results are bit-identical to
/// the serial path, because per-sample work is a fixed serial reduction
/// order and `matmul_bt` is itself pooled≡serial.
#[allow(clippy::too_many_arguments)]
pub fn qattention(
    wq: &ProjWeights,
    wk: &ProjWeights,
    wv: &ProjWeights,
    wo: &ProjWeights,
    heads: usize,
    head_dim: usize,
    seq: usize,
    x: &[f32],
    batch: usize,
    out: &mut [f32],
    pool: Option<&ThreadPool>,
) {
    let d = heads * head_dim;
    assert_eq!(x.len(), batch * seq * d, "qattention: x shape");
    assert_eq!(out.len(), batch * seq * d, "qattention: out shape");
    if batch == 0 {
        return;
    }
    // Same per-call observation gate as qgemm (see there).
    let qs = crate::obs::qstats::qstats();
    let qsample = qs.sample();
    if qsample {
        qs.observe_input(x);
    }
    let mut sat = (0u64, 0u64);
    let prof_t0 = if crate::obs::profiler().on() { Some(Instant::now()) } else { None };
    let mq = wq.decode(d, if qsample { Some(&mut sat) } else { None });
    let mk = wk.decode(d, if qsample { Some(&mut sat) } else { None });
    let mv = wv.decode(d, if qsample { Some(&mut sat) } else { None });
    let mo = wo.decode(d, if qsample { Some(&mut sat) } else { None });
    if qsample {
        qs.add_saturation(sat.0, sat.1);
    }
    let prof_t1 = prof_t0.map(|_| Instant::now());
    // multi-sample batches parallelize across samples; batch == 1 lets
    // the projection matmuls use the pool themselves (no nesting either
    // way — par_blocks runs this closure serially when batch == 1)
    let inner = if batch > 1 { None } else { pool };
    let sample_flops = 4 * seq * d * d + 2 * seq * seq * d;
    let optr = SendPtr(out.as_mut_ptr());
    let optr = &optr;
    par_blocks(pool, batch, batch * sample_flops, |b| {
        let xb = &x[b * seq * d..(b + 1) * seq * d];
        let mut q = vec![0f32; seq * d];
        let mut k = vec![0f32; seq * d];
        let mut v = vec![0f32; seq * d];
        let mut ctx = vec![0f32; seq * d];
        matmul_bt(xb, &mq, None, seq, d, d, &mut q, inner);
        matmul_bt(xb, &mk, None, seq, d, d, &mut k, inner);
        matmul_bt(xb, &mv, None, seq, d, d, &mut v, inner);
        mha_forward_sample(&q, &k, &v, seq, heads, head_dim, &mut ctx, None);
        // SAFETY: sample `b` writes only out[b·s·d, (b+1)·s·d) — disjoint
        // per task; `out` outlives the scoped par_for and is not read
        // until it returns.
        let ob = unsafe { std::slice::from_raw_parts_mut(optr.get().add(b * seq * d), seq * d) };
        matmul_bt(&ctx, &mo, None, seq, d, d, ob, inner);
    });
    if let (Some(t0), Some(t1)) = (prof_t0, prof_t1) {
        let dec_ns = t1.duration_since(t0).as_nanos() as u64;
        let mm_ns = t1.elapsed().as_nanos() as u64;
        let bytes = (wq.data.len() + wk.data.len() + wv.data.len() + wo.data.len()) as u64;
        crate::obs::profiler().add_kernel(dec_ns, mm_ns, bytes, 4 * (d * d) as u64);
    }
}

/// Dense f64 attention oracle over already-dequantized projection
/// weights — the reference `qattention` is judged against. Same
/// `doc(hidden) pub` rationale as [`dense_conv_ref`]: ONE statement of
/// the projection/head indexing convention shared by every test suite.
#[doc(hidden)]
#[allow(clippy::too_many_arguments)]
pub fn dense_attn_ref(
    wq: &[f32],
    wk: &[f32],
    wv: &[f32],
    wo: &[f32],
    heads: usize,
    head_dim: usize,
    seq: usize,
    x: &[f32],
    batch: usize,
) -> Vec<f32> {
    let d = heads * head_dim;
    let proj = |w: &[f32], xb: &[f32]| -> Vec<f64> {
        let mut out = vec![0f64; seq * d];
        for i in 0..seq {
            for r in 0..d {
                out[i * d + r] = (0..d)
                    .map(|j| w[r * d + j] as f64 * xb[i * d + j] as f64)
                    .sum();
            }
        }
        out
    };
    let mut out = vec![0f32; batch * seq * d];
    for b in 0..batch {
        let xf = &x[b * seq * d..(b + 1) * seq * d];
        let q = proj(wq, xf);
        let k = proj(wk, xf);
        let v = proj(wv, xf);
        let mut ctx = vec![0f64; seq * d];
        for h in 0..heads {
            let o = h * head_dim;
            for i in 0..seq {
                let mut row = vec![0f64; seq];
                for (j, rj) in row.iter_mut().enumerate() {
                    let s: f64 =
                        (0..head_dim).map(|t| q[i * d + o + t] * k[j * d + o + t]).sum();
                    *rj = s / (head_dim as f64).sqrt();
                }
                let max = row.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                let exps: Vec<f64> = row.iter().map(|s| (s - max).exp()).collect();
                let z: f64 = exps.iter().sum();
                for t in 0..head_dim {
                    ctx[i * d + o + t] =
                        exps.iter().enumerate().map(|(j, e)| e / z * v[j * d + o + t]).sum();
                }
            }
        }
        for i in 0..seq {
            for r in 0..d {
                out[(b * seq + i) * d + r] =
                    (0..d).map(|j| wo[r * d + j] as f64 * ctx[i * d + j]).sum::<f64>() as f32;
            }
        }
    }
    out
}

/// Dense f64 conv oracle over dequantized weights — the reference every
/// quantized conv path is judged against. `doc(hidden) pub` (not
/// `cfg(test)`) so the unit suites, the registry tests AND the
/// integration tests all share exactly ONE statement of the OHWI×NHWC
/// indexing convention; it is test support, not serving API.
#[doc(hidden)]
#[allow(clippy::too_many_arguments)]
pub fn dense_conv_ref(
    wq: &[f32],
    d: &Conv2dDesc,
    in_h: usize,
    in_w: usize,
    x: &[f32],
    batch: usize,
) -> Vec<f32> {
    let (out_h, out_w) = d.out_hw(in_h, in_w).unwrap();
    let mut out = vec![0f32; batch * out_h * out_w * d.out_ch];
    for b in 0..batch {
        for oy in 0..out_h {
            for ox in 0..out_w {
                for oc in 0..d.out_ch {
                    let mut acc = 0f64;
                    for ky in 0..d.kh {
                        let iy = (oy * d.stride + ky) as isize - d.pad as isize;
                        if iy < 0 || iy >= in_h as isize {
                            continue;
                        }
                        for kx in 0..d.kw {
                            let ix = (ox * d.stride + kx) as isize - d.pad as isize;
                            if ix < 0 || ix >= in_w as isize {
                                continue;
                            }
                            for ic in 0..d.in_ch {
                                let wv = wq[((oc * d.kh + ky) * d.kw + kx) * d.in_ch + ic];
                                let xv = x[((b * in_h + iy as usize) * in_w + ix as usize)
                                    * d.in_ch
                                    + ic];
                                acc += wv as f64 * xv as f64;
                            }
                        }
                    }
                    out[((b * out_h + oy) * out_w + ox) * d.out_ch + oc] = acc as f32;
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::pack::{pack_layer, unpack_layer};
    use crate::util::prng::Rng;

    fn rand_vec(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| r.normal() * 0.5).collect()
    }

    #[test]
    fn qgemm_matches_dense_reference() {
        for bits in [1u8, 2, 3, 5, 7, 8] {
            let (rows, cols, batch) = (19, 37, 3);
            let w = rand_vec(rows * cols, 100 + bits as u64);
            let p = pack_layer("l", &w, bits);
            let wq = unpack_layer(&p).unwrap(); // dequantized lattice weights
            let x = rand_vec(batch * cols, 200 + bits as u64);

            let mut expect = vec![0f32; batch * rows];
            for b in 0..batch {
                for r in 0..rows {
                    let mut acc = 0f64;
                    for j in 0..cols {
                        acc += wq[r * cols + j] as f64 * x[b * cols + j] as f64;
                    }
                    expect[b * rows + r] = acc as f32;
                }
            }

            let mut got = vec![0f32; batch * rows];
            qgemm(&p.data, bits, p.scale, rows, cols, &x, batch, &mut got, None);
            for (i, (g, e)) in got.iter().zip(&expect).enumerate() {
                assert!((g - e).abs() < 1e-3, "bits {bits} idx {i}: {g} vs {e}");
            }
        }
    }

    #[test]
    fn qgemm_pool_is_bitwise_equal_to_serial() {
        // property: across random shapes and widths — including rows >
        // ROW_BLOCK so several blocks race over the pool — pooled and
        // serial execution agree bit-for-bit. The same suite runs under
        // `--features simd` in CI (and kernels::simd pins that the lane
        // primitives compute identical bits in both builds), so this
        // test passing in both matrix entries certifies all four
        // {serial, pooled} × {scalar, simd} configurations.
        let pool = ThreadPool::new(4);
        crate::util::prop::check(25, |g| {
            let bits = g.usize_in(1, 8) as u8;
            let rows = g.usize_in(1, 90);
            let cols = g.usize_in(1, 70);
            let batch = g.usize_in(1, 4);
            let w = g.vec_normal(rows * cols, 0.5);
            let p = pack_layer("l", &w, bits);
            let x = g.vec_normal(batch * cols, 0.5);
            let mut serial = vec![0f32; batch * rows];
            let mut pooled = serial.clone();
            qgemm(&p.data, bits, p.scale, rows, cols, &x, batch, &mut serial, None);
            qgemm(&p.data, bits, p.scale, rows, cols, &x, batch, &mut pooled, Some(&pool));
            crate::util::prop::ensure(
                serial == pooled,
                format!("bits {bits} rows {rows} cols {cols} batch {batch}: pooled != serial"),
            )
        });
    }

    #[test]
    fn qgemm_empty_batch_and_rows() {
        let p = pack_layer("l", &rand_vec(12, 1), 3);
        let mut out = vec![0f32; 0];
        qgemm(&p.data, 3, p.scale, 4, 3, &[], 0, &mut out, None);
        qgemm(&p.data, 3, p.scale, 0, 3, &[0.0; 3], 1, &mut out, None);
    }

    #[test]
    fn qconv2d_matches_dense_reference_across_bits_strides_pads() {
        // bits 1..=8 (unaligned filter offsets for most), every stride/pad
        // combination that yields a valid output map, vs the f64 dense
        // reference on the dequantized lattice weights
        crate::util::prop::check(120, |g| {
            let bits = g.usize_in(1, 8) as u8;
            let d = Conv2dDesc {
                in_ch: g.usize_in(1, 3),
                out_ch: g.usize_in(1, 6),
                kh: g.usize_in(1, 3),
                kw: g.usize_in(1, 3),
                stride: g.usize_in(1, 3),
                pad: g.usize_in(0, 2),
            };
            let in_h = g.usize_in(d.kh.saturating_sub(2 * d.pad).max(1), 7);
            let in_w = g.usize_in(d.kw.saturating_sub(2 * d.pad).max(1), 7);
            if d.out_hw(in_h, in_w).is_err() {
                return Ok(()); // kernel misses the padded input: skip
            }
            let batch = g.usize_in(1, 3);
            let numel = d.weight_numel().unwrap();
            let w = g.vec_normal(numel, 0.2);
            let p = pack_layer("c", &w, bits);
            let wq = unpack_layer(&p).map_err(|e| e.to_string())?;
            let x = g.vec_normal(batch * in_h * in_w * d.in_ch, 0.3);

            let expect = dense_conv_ref(&wq, &d, in_h, in_w, &x, batch);
            let mut got = vec![0f32; expect.len()];
            qconv2d(&p.data, bits, p.scale, &d, in_h, in_w, &x, batch, &mut got, None);
            for (i, (a, e)) in got.iter().zip(&expect).enumerate() {
                crate::util::prop::ensure(
                    (a - e).abs() < 1e-5,
                    format!("bits {bits} {d:?} {in_h}x{in_w} idx {i}: {a} vs {e}"),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn qconv2d_pool_is_bitwise_equal_to_serial() {
        // property twin of the qgemm test: random geometry with out_ch >
        // FILTER_BLOCK so several filter blocks race over the pool (see
        // there for why this also covers the scalar/simd axis)
        let pool = ThreadPool::new(4);
        crate::util::prop::check(20, |g| {
            let bits = g.usize_in(1, 8) as u8;
            let d = Conv2dDesc {
                in_ch: g.usize_in(1, 3),
                out_ch: g.usize_in(5, 13),
                kh: g.usize_in(1, 3),
                kw: g.usize_in(1, 3),
                stride: g.usize_in(1, 2),
                pad: g.usize_in(0, 1),
            };
            let in_h = g.usize_in(d.kh.max(3), 9);
            let in_w = g.usize_in(d.kw.max(3), 9);
            if d.out_hw(in_h, in_w).is_err() {
                return Ok(());
            }
            let batch = g.usize_in(1, 4);
            let w = g.vec_normal(d.weight_numel().unwrap(), 0.3);
            let p = pack_layer("c", &w, bits);
            let x = g.vec_normal(batch * in_h * in_w * d.in_ch, 0.3);
            let (oh, ow) = d.out_hw(in_h, in_w).unwrap();
            let mut serial = vec![0f32; batch * oh * ow * d.out_ch];
            let mut pooled = serial.clone();
            qconv2d(&p.data, bits, p.scale, &d, in_h, in_w, &x, batch, &mut serial, None);
            qconv2d(&p.data, bits, p.scale, &d, in_h, in_w, &x, batch, &mut pooled, Some(&pool));
            crate::util::prop::ensure(
                serial == pooled,
                format!("bits {bits} {d:?} {in_h}x{in_w} batch {batch}: pooled != serial"),
            )
        });
    }

    #[test]
    fn qconv2d_empty_batch() {
        let d = Conv2dDesc { in_ch: 2, out_ch: 3, kh: 3, kw: 3, stride: 1, pad: 1 };
        let p = pack_layer("c", &rand_vec(d.weight_numel().unwrap(), 1), 4);
        let mut out = vec![0f32; 0];
        qconv2d(&p.data, 4, p.scale, &d, 4, 4, &[], 0, &mut out, None);
    }

    #[test]
    fn qgemm_int_within_step_bound_of_f32_core() {
        // property (the tentpole accuracy contract): with calibration
        // covering the true input range, every int8 output differs from
        // the f32 core by at most cols · weight_scale · step/2 — each
        // activation quantizes within step/2 and every lattice weight
        // satisfies |w| ≤ scale — plus f32 roundoff slack.
        crate::util::prop::check(60, |g| {
            let bits = g.usize_in(1, 8) as u8;
            let rows = g.usize_in(1, 70);
            let cols = g.usize_in(1, 120);
            let batch = g.usize_in(1, 4);
            let w = g.vec_normal(rows * cols, 0.5);
            let p = pack_layer("l", &w, bits);
            let x = g.vec_normal(batch * cols, 0.8);
            let absmax = x.iter().fold(0f32, |a, &v| a.max(v.abs()));
            let act = ActQuant::from_absmax(absmax);

            let mut f32_out = vec![0f32; batch * rows];
            let mut int_out = vec![0f32; batch * rows];
            qgemm(&p.data, bits, p.scale, rows, cols, &x, batch, &mut f32_out, None);
            qgemm_int(&p.data, bits, p.scale, rows, cols, &x, batch, &act, &mut int_out, None);
            let bound = cols as f32 * p.scale * act.step() / 2.0;
            for (i, (a, e)) in int_out.iter().zip(&f32_out).enumerate() {
                crate::util::prop::ensure(
                    (a - e).abs() <= bound + 1e-4 * (1.0 + e.abs()),
                    format!(
                        "bits {bits} rows {rows} cols {cols} idx {i}: |{a} - {e}| > {bound}"
                    ),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn qgemm_int_pool_is_bitwise_equal_to_serial() {
        // integer sums are order-independent and the float finalize runs
        // once per element, so the int path keeps the serial ≡ pooled
        // half of the bit-exactness contract
        let pool = ThreadPool::new(4);
        crate::util::prop::check(25, |g| {
            let bits = g.usize_in(1, 8) as u8;
            let rows = g.usize_in(1, 90);
            let cols = g.usize_in(1, 70);
            let batch = g.usize_in(1, 4);
            let w = g.vec_normal(rows * cols, 0.5);
            let p = pack_layer("l", &w, bits);
            let x = g.vec_normal(batch * cols, 0.5);
            let act = ActQuant::from_absmax(x.iter().fold(0f32, |a, &v| a.max(v.abs())));
            let mut serial = vec![0f32; batch * rows];
            let mut pooled = serial.clone();
            qgemm_int(&p.data, bits, p.scale, rows, cols, &x, batch, &act, &mut serial, None);
            qgemm_int(
                &p.data, bits, p.scale, rows, cols, &x, batch, &act, &mut pooled, Some(&pool),
            );
            crate::util::prop::ensure(
                serial == pooled,
                format!("bits {bits} rows {rows} cols {cols} batch {batch}: pooled != serial"),
            )
        });
    }

    #[test]
    fn qconv2d_int_within_step_bound_of_f32_core() {
        // conv twin of the gemm bound, across strides/pads so clipped
        // (padding) windows are exercised: the bound uses the full
        // receptive-field length, an upper bound on every clipped window
        crate::util::prop::check(60, |g| {
            let bits = g.usize_in(1, 8) as u8;
            let d = Conv2dDesc {
                in_ch: g.usize_in(1, 3),
                out_ch: g.usize_in(1, 6),
                kh: g.usize_in(1, 3),
                kw: g.usize_in(1, 3),
                stride: g.usize_in(1, 3),
                pad: g.usize_in(0, 2),
            };
            let in_h = g.usize_in(d.kh.saturating_sub(2 * d.pad).max(1), 7);
            let in_w = g.usize_in(d.kw.saturating_sub(2 * d.pad).max(1), 7);
            if d.out_hw(in_h, in_w).is_err() {
                return Ok(());
            }
            let batch = g.usize_in(1, 3);
            let w = g.vec_normal(d.weight_numel().unwrap(), 0.3);
            let p = pack_layer("c", &w, bits);
            let x = g.vec_normal(batch * in_h * in_w * d.in_ch, 0.5);
            let act = ActQuant::from_absmax(x.iter().fold(0f32, |a, &v| a.max(v.abs())));
            let (oh, ow) = d.out_hw(in_h, in_w).unwrap();
            let mut f32_out = vec![0f32; batch * oh * ow * d.out_ch];
            let mut int_out = f32_out.clone();
            qconv2d(&p.data, bits, p.scale, &d, in_h, in_w, &x, batch, &mut f32_out, None);
            qconv2d_int(
                &p.data, bits, p.scale, &d, in_h, in_w, &x, batch, &act, &mut int_out, None,
            );
            let bound = d.filter_len() as f32 * p.scale * act.step() / 2.0;
            for (i, (a, e)) in int_out.iter().zip(&f32_out).enumerate() {
                crate::util::prop::ensure(
                    (a - e).abs() <= bound + 1e-4 * (1.0 + e.abs()),
                    format!("bits {bits} {d:?} idx {i}: |{a} - {e}| > {bound}"),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn qconv2d_int_pool_is_bitwise_equal_to_serial() {
        let pool = ThreadPool::new(4);
        crate::util::prop::check(20, |g| {
            let bits = g.usize_in(1, 8) as u8;
            let d = Conv2dDesc {
                in_ch: g.usize_in(1, 3),
                out_ch: g.usize_in(5, 13),
                kh: g.usize_in(1, 3),
                kw: g.usize_in(1, 3),
                stride: g.usize_in(1, 2),
                pad: g.usize_in(0, 1),
            };
            let in_h = g.usize_in(d.kh.max(3), 9);
            let in_w = g.usize_in(d.kw.max(3), 9);
            if d.out_hw(in_h, in_w).is_err() {
                return Ok(());
            }
            let batch = g.usize_in(1, 4);
            let w = g.vec_normal(d.weight_numel().unwrap(), 0.3);
            let p = pack_layer("c", &w, bits);
            let x = g.vec_normal(batch * in_h * in_w * d.in_ch, 0.3);
            let act = ActQuant::from_absmax(x.iter().fold(0f32, |a, &v| a.max(v.abs())));
            let (oh, ow) = d.out_hw(in_h, in_w).unwrap();
            let mut serial = vec![0f32; batch * oh * ow * d.out_ch];
            let mut pooled = serial.clone();
            qconv2d_int(
                &p.data, bits, p.scale, &d, in_h, in_w, &x, batch, &act, &mut serial, None,
            );
            qconv2d_int(
                &p.data, bits, p.scale, &d, in_h, in_w, &x, batch, &act, &mut pooled,
                Some(&pool),
            );
            crate::util::prop::ensure(
                serial == pooled,
                format!("bits {bits} {d:?} batch {batch}: pooled != serial"),
            )
        });
    }

    #[test]
    fn qgemm_int_empty_batch_and_rows() {
        let p = pack_layer("l", &rand_vec(12, 1), 3);
        let act = ActQuant::from_absmax(1.0);
        let mut out = vec![0f32; 0];
        qgemm_int(&p.data, 3, p.scale, 4, 3, &[], 0, &act, &mut out, None);
        qgemm_int(&p.data, 3, p.scale, 0, 3, &[0.0; 3], 1, &act, &mut out, None);
    }

    /// Pack a random d×d projection at `bits` and return it alongside its
    /// dequantized lattice weights (the reference input).
    fn rand_proj(
        g: &mut crate::util::prop::Gen,
        d: usize,
        bits: u8,
    ) -> (ProjWeights, Vec<f32>) {
        let w = g.vec_normal(d * d, 0.4);
        let p = pack_layer("p", &w, bits);
        let wq = unpack_layer(&p).unwrap();
        (ProjWeights { bits, scale: p.scale, data: p.data, cache_key: None }, wq)
    }

    #[test]
    fn qattention_matches_f64_reference() {
        // random shapes and per-projection bit-widths 1..=8 vs the dense
        // f64 oracle on the dequantized lattice weights
        crate::util::prop::check(40, |g| {
            let heads = g.usize_in(1, 3);
            let head_dim = g.usize_in(1, 5);
            let seq = g.usize_in(1, 6);
            let batch = g.usize_in(1, 3);
            let d = heads * head_dim;
            let mut projs = Vec::new();
            let mut refs = Vec::new();
            for _ in 0..4 {
                let bits = g.usize_in(1, 8) as u8;
                let (p, wq) = rand_proj(g, d, bits);
                projs.push(p);
                refs.push(wq);
            }
            let x = g.vec_normal(batch * seq * d, 0.5);
            let expect = dense_attn_ref(
                &refs[0], &refs[1], &refs[2], &refs[3], heads, head_dim, seq, &x, batch,
            );
            let mut got = vec![0f32; batch * seq * d];
            qattention(
                &projs[0], &projs[1], &projs[2], &projs[3], heads, head_dim, seq, &x, batch,
                &mut got, None,
            );
            for (i, (a, e)) in got.iter().zip(&expect).enumerate() {
                crate::util::prop::ensure(
                    (a - e).abs() < 1e-4,
                    format!("h{heads} hd{head_dim} s{seq} b{batch} idx {i}: {a} vs {e}"),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn qattention_pool_is_bitwise_equal_to_serial() {
        // property twin of the qgemm/qconv tests: batches > 1 race sample
        // blocks over the pool, batch == 1 exercises the pooled-matmul
        // path — both must equal serial execution bit-for-bit
        let pool = ThreadPool::new(4);
        crate::util::prop::check(25, |g| {
            let heads = g.usize_in(1, 4);
            let head_dim = g.usize_in(1, 6);
            let seq = g.usize_in(1, 7);
            let batch = g.usize_in(1, 5);
            let d = heads * head_dim;
            let mut projs = Vec::new();
            for _ in 0..4 {
                let bits = g.usize_in(1, 8) as u8;
                projs.push(rand_proj(g, d, bits).0);
            }
            let x = g.vec_normal(batch * seq * d, 0.5);
            let mut serial = vec![0f32; batch * seq * d];
            let mut pooled = serial.clone();
            qattention(
                &projs[0], &projs[1], &projs[2], &projs[3], heads, head_dim, seq, &x, batch,
                &mut serial, None,
            );
            qattention(
                &projs[0], &projs[1], &projs[2], &projs[3], heads, head_dim, seq, &x, batch,
                &mut pooled, Some(&pool),
            );
            crate::util::prop::ensure(
                serial == pooled,
                format!("h{heads} hd{head_dim} s{seq} b{batch}: pooled != serial"),
            )
        });
    }

    #[test]
    fn qattention_single_token_reduces_to_projection_chain() {
        // seq = 1: softmax over one score is exactly 1, so the whole op
        // is out = Wo·(Wv·x) regardless of Q/K contents
        crate::util::prop::check(1, |g| {
            let (heads, head_dim) = (2, 3);
            let d = heads * head_dim;
            let mut projs = Vec::new();
            let mut refs = Vec::new();
            for _ in 0..4 {
                let (p, wq) = rand_proj(g, d, 6);
                projs.push(p);
                refs.push(wq);
            }
            let x = rand_vec(d, 77);
            let mut got = vec![0f32; d];
            qattention(
                &projs[0], &projs[1], &projs[2], &projs[3], heads, head_dim, 1, &x, 1, &mut got,
                None,
            );
            // reference: v = Wv x, out = Wo v (f64)
            let v: Vec<f64> = (0..d)
                .map(|r| (0..d).map(|j| refs[2][r * d + j] as f64 * x[j] as f64).sum())
                .collect();
            for r in 0..d {
                let e: f64 = (0..d).map(|j| refs[3][r * d + j] as f64 * v[j]).sum();
                crate::util::prop::ensure(
                    (got[r] as f64 - e).abs() < 1e-5,
                    format!("{r}: {} vs {e}", got[r]),
                )?;
            }
            Ok(())
        });
    }
}
