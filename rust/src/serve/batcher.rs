//! Dynamic request batching with deadline flush and admission control.
//!
//! Requests enter a bounded queue; a dedicated dispatcher thread flushes
//! a batch when either trigger fires:
//!
//! * **size** — `max_batch` requests are waiting, or
//! * **deadline** — the *oldest* waiting request has been queued for
//!   `max_delay` (so a lone request never waits longer than the SLA even
//!   when traffic is too thin to fill a batch).
//!
//! Admission control is at submit time: beyond `queue_cap` waiting
//! requests the submit fails fast with [`SubmitError::QueueFull`]
//! (backpressure — callers retry or shed) instead of growing an
//! unbounded queue. Each request carries its own response channel, so
//! results map back to the issuing request by construction, regardless
//! of how the dispatcher groups batches.
//!
//! The batcher is generic over the batch executor (`BatchFn`), keeping
//! it unit-testable without weights; `serve::Server` plugs in the
//! quantized forward pass.

use std::collections::VecDeque;
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct BatchConfig {
    /// Flush as soon as this many requests are waiting.
    pub max_batch: usize,
    /// Flush when the oldest waiting request reaches this age.
    pub max_delay: Duration,
    /// Admission limit on waiting requests (backpressure threshold).
    pub queue_cap: usize,
}

impl Default for BatchConfig {
    fn default() -> Self {
        BatchConfig { max_batch: 32, max_delay: Duration::from_millis(5), queue_cap: 1024 }
    }
}

/// Completed inference, delivered on the per-request channel.
#[derive(Clone, Debug)]
pub struct InferResponse {
    /// Monotone admission sequence number.
    pub id: u64,
    pub logits: Vec<f32>,
    /// Index of the max logit (the predicted class).
    pub argmax: usize,
    /// How many requests shared the flushed batch.
    pub batch_size: usize,
    /// Queue + compute time, submit to response.
    pub latency: Duration,
    /// Time spent waiting in the queue before the batch flushed (the
    /// `queue` lifecycle stage — see `obs::STAGES`).
    pub queue_wait: Duration,
    /// Time inside the batch executor (the `kernel` lifecycle stage).
    /// Shared by every request in the flushed batch.
    pub compute: Duration,
}

#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// Queue at capacity — shed or retry later.
    QueueFull { depth: usize, cap: usize },
    /// Input length doesn't match the model's input dimension.
    BadInput { got: usize, want: usize },
    /// Batcher is shutting down.
    ShuttingDown,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull { depth, cap } => {
                write!(f, "queue full ({depth}/{cap} waiting)")
            }
            SubmitError::BadInput { got, want } => {
                write!(f, "input has {got} values, model expects {want}")
            }
            SubmitError::ShuttingDown => write!(f, "server shutting down"),
        }
    }
}

/// Batch executor: inputs (one `Vec<f32>` per request, flush order) to
/// logits (same length and order).
pub type BatchFn = dyn Fn(Vec<Vec<f32>>) -> Vec<Vec<f32>> + Send + 'static;

/// Per-response observer (latency/occupancy metrics hook).
pub type CompletionHook = dyn Fn(&InferResponse) + Send + 'static;

struct Pending {
    id: u64,
    input: Vec<f32>,
    enqueued: Instant,
    tx: Sender<InferResponse>,
}

struct State {
    queue: VecDeque<Pending>,
    closed: bool,
    next_id: u64,
}

struct Shared {
    state: Mutex<State>,
    cv: Condvar,
}

pub struct DynamicBatcher {
    shared: Arc<Shared>,
    cfg: BatchConfig,
    worker: Option<thread::JoinHandle<()>>,
}

impl DynamicBatcher {
    pub fn new(cfg: BatchConfig, run: Box<BatchFn>) -> DynamicBatcher {
        Self::with_hook(cfg, run, None)
    }

    pub fn with_hook(
        cfg: BatchConfig,
        run: Box<BatchFn>,
        hook: Option<Box<CompletionHook>>,
    ) -> DynamicBatcher {
        let shared = Arc::new(Shared {
            state: Mutex::new(State { queue: VecDeque::new(), closed: false, next_id: 0 }),
            cv: Condvar::new(),
        });
        let sh = shared.clone();
        let wcfg = cfg.clone();
        let worker = thread::Builder::new()
            .name("msq-serve-batcher".into())
            .spawn(move || dispatcher(sh, wcfg, run, hook))
            .expect("spawn batcher thread");
        DynamicBatcher { shared, cfg, worker: Some(worker) }
    }

    /// Enqueue one request; the returned channel yields its response.
    pub fn submit(&self, input: Vec<f32>) -> Result<Receiver<InferResponse>, SubmitError> {
        self.try_submit(input).map_err(|(e, _)| e)
    }

    /// Like [`Self::submit`], but hands the input back on failure so
    /// retrying callers (the admission wait queue) replay the same
    /// request without cloning the row.
    #[allow(clippy::type_complexity)]
    pub fn try_submit(
        &self,
        input: Vec<f32>,
    ) -> Result<Receiver<InferResponse>, (SubmitError, Vec<f32>)> {
        let (tx, rx) = mpsc::channel();
        let mut st = self.shared.state.lock().unwrap();
        if st.closed {
            return Err((SubmitError::ShuttingDown, input));
        }
        if st.queue.len() >= self.cfg.queue_cap {
            let depth = st.queue.len();
            return Err((SubmitError::QueueFull { depth, cap: self.cfg.queue_cap }, input));
        }
        let id = st.next_id;
        st.next_id += 1;
        st.queue.push_back(Pending { id, input, enqueued: Instant::now(), tx });
        drop(st);
        self.shared.cv.notify_all();
        Ok(rx)
    }

    /// Requests currently waiting (not yet flushed into a batch).
    pub fn depth(&self) -> usize {
        self.shared.state.lock().unwrap().queue.len()
    }

    /// Total requests ever admitted.
    pub fn admitted(&self) -> u64 {
        self.shared.state.lock().unwrap().next_id
    }

    /// Stop admitting new requests (submit returns `ShuttingDown`) while
    /// the dispatcher keeps flushing whatever is queued. Idempotent and
    /// non-consuming — the drain signal a gateway broadcasts to every
    /// model's batcher before joining them one by one.
    pub fn close(&self) {
        self.shared.state.lock().unwrap().closed = true;
        self.shared.cv.notify_all();
    }

    /// Whether `close`/`shutdown` has been signalled.
    pub fn is_closed(&self) -> bool {
        self.shared.state.lock().unwrap().closed
    }

    /// Stop accepting requests, flush what's queued, join the worker.
    pub fn shutdown(mut self) {
        self.close_and_join();
    }

    fn close_and_join(&mut self) {
        self.close();
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

impl Drop for DynamicBatcher {
    fn drop(&mut self) {
        self.close_and_join();
    }
}

fn dispatcher(
    shared: Arc<Shared>,
    cfg: BatchConfig,
    run: Box<BatchFn>,
    hook: Option<Box<CompletionHook>>,
) {
    loop {
        // Phase 1: wait until a flush trigger fires, then drain a batch.
        let mut batch: Vec<Pending> = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.queue.is_empty() {
                    if st.closed {
                        return;
                    }
                    st = shared.cv.wait(st).unwrap();
                    continue;
                }
                if st.queue.len() >= cfg.max_batch || st.closed {
                    break; // size trigger (or final drain on shutdown)
                }
                // Invariant: non-empty here. The `is_empty` check at the
                // top re-runs after every wait (spurious or signalled),
                // we hold the lock, and this dispatcher is the queue's
                // only consumer. Still: a `front()` miss re-enters the
                // wait loop instead of panicking the worker (a poisoned
                // batcher would strand every queued request).
                let Some(oldest) = st.queue.front() else {
                    continue;
                };
                let deadline = oldest.enqueued + cfg.max_delay;
                let now = Instant::now();
                if now >= deadline {
                    break; // deadline trigger
                }
                st = shared.cv.wait_timeout(st, deadline - now).unwrap().0;
            }
            let take = st.queue.len().min(cfg.max_batch);
            st.queue.drain(..take).collect()
        };

        // Phase 2: execute outside the lock — submitters stay unblocked.
        let flushed = Instant::now();
        let inputs: Vec<Vec<f32>> =
            batch.iter_mut().map(|p| std::mem::take(&mut p.input)).collect();
        let n = batch.len();
        let outputs = run(inputs);
        let compute = flushed.elapsed();
        debug_assert_eq!(outputs.len(), n, "BatchFn must preserve arity");
        for (p, logits) in batch.into_iter().zip(outputs) {
            let argmax = logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
                .map(|(i, _)| i)
                .unwrap_or(0);
            let resp = InferResponse {
                id: p.id,
                logits,
                argmax,
                batch_size: n,
                latency: p.enqueued.elapsed(),
                queue_wait: flushed.saturating_duration_since(p.enqueued),
                compute,
            };
            if let Some(h) = &hook {
                h(&resp);
            }
            let _ = p.tx.send(resp); // receiver may have gone away; fine
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Echo executor: logits = input, so tests can verify request↔response
    /// mapping end to end.
    fn echo() -> Box<BatchFn> {
        Box::new(|inputs| inputs)
    }

    fn recv(rx: &Receiver<InferResponse>) -> InferResponse {
        rx.recv_timeout(Duration::from_secs(10)).expect("response within 10s")
    }

    #[test]
    fn size_trigger_flushes_full_batch() {
        // deadline far away: only the size trigger can flush
        let cfg = BatchConfig {
            max_batch: 4,
            max_delay: Duration::from_secs(600),
            queue_cap: 64,
        };
        let b = DynamicBatcher::new(cfg, echo());
        let rxs: Vec<_> = (0..4).map(|i| b.submit(vec![i as f32]).unwrap()).collect();
        for (i, rx) in rxs.iter().enumerate() {
            let r = recv(rx);
            assert_eq!(r.batch_size, 4);
            assert_eq!(r.logits, vec![i as f32]);
        }
    }

    #[test]
    fn deadline_flushes_partial_batch() {
        // batch can hold 1000: only the deadline can flush 2 requests
        let cfg = BatchConfig {
            max_batch: 1000,
            max_delay: Duration::from_millis(100),
            queue_cap: 64,
        };
        let b = DynamicBatcher::new(cfg, echo());
        let rx1 = b.submit(vec![1.0]).unwrap();
        let rx2 = b.submit(vec![2.0]).unwrap();
        let r1 = recv(&rx1);
        let r2 = recv(&rx2);
        assert_eq!(r1.batch_size, 2);
        assert_eq!(r2.batch_size, 2);
        assert!(r1.latency >= Duration::from_millis(90), "flushed early: {:?}", r1.latency);
        assert_eq!(r1.logits, vec![1.0]);
        assert_eq!(r2.logits, vec![2.0]);
    }

    #[test]
    fn stage_fields_partition_latency() {
        // deadline flush + slow executor: queue_wait covers the deadline
        // wait, compute covers the executor, and both fit inside the
        // end-to-end latency (argmax/delivery is the only remainder).
        let run: Box<BatchFn> = Box::new(|inputs| {
            thread::sleep(Duration::from_millis(5));
            inputs
        });
        let cfg = BatchConfig {
            max_batch: 1000,
            max_delay: Duration::from_millis(20),
            queue_cap: 64,
        };
        let b = DynamicBatcher::new(cfg, run);
        let rx = b.submit(vec![1.0]).unwrap();
        let r = recv(&rx);
        assert!(r.queue_wait >= Duration::from_millis(15), "queue_wait {:?}", r.queue_wait);
        assert!(r.compute >= Duration::from_millis(5), "compute {:?}", r.compute);
        assert!(
            r.queue_wait + r.compute <= r.latency,
            "stages exceed e2e: {:?} + {:?} > {:?}",
            r.queue_wait,
            r.compute,
            r.latency
        );
    }

    #[test]
    fn backpressure_rejects_beyond_capacity() {
        // executor blocks until released, pinning the worker mid-batch
        let (gate_tx, gate_rx) = mpsc::channel::<()>();
        let (started_tx, started_rx) = mpsc::channel::<()>();
        let gate_rx = Mutex::new(gate_rx);
        let run: Box<BatchFn> = Box::new(move |inputs| {
            started_tx.send(()).unwrap();
            gate_rx.lock().unwrap().recv().unwrap();
            inputs
        });
        let cfg = BatchConfig { max_batch: 1, max_delay: Duration::ZERO, queue_cap: 2 };
        let b = DynamicBatcher::with_hook(cfg, run, None);

        let rx_a = b.submit(vec![0.0]).unwrap();
        started_rx.recv_timeout(Duration::from_secs(10)).unwrap(); // worker busy on A
        let rx_b = b.submit(vec![1.0]).unwrap();
        let rx_c = b.submit(vec![2.0]).unwrap();
        assert_eq!(b.depth(), 2);
        // queue at cap while the worker is pinned: next submit is shed
        match b.submit(vec![3.0]) {
            Err(SubmitError::QueueFull { depth: 2, cap: 2 }) => {}
            other => panic!("expected QueueFull, got {other:?}"),
        }
        // release A, then B and C (each flush re-blocks on the gate)
        for _ in 0..3 {
            gate_tx.send(()).unwrap();
            let _ = started_rx.recv_timeout(Duration::from_secs(10));
        }
        assert_eq!(recv(&rx_a).logits, vec![0.0]);
        assert_eq!(recv(&rx_b).logits, vec![1.0]);
        assert_eq!(recv(&rx_c).logits, vec![2.0]);
    }

    #[test]
    fn try_submit_hands_the_input_back_on_failure() {
        // no flush trigger can fire: the queued request pins the queue
        let cfg = BatchConfig {
            max_batch: 1000,
            max_delay: Duration::from_secs(600),
            queue_cap: 1,
        };
        let b = DynamicBatcher::new(cfg, echo());
        let _rx = b.submit(vec![1.0]).unwrap();
        match b.try_submit(vec![2.0, 3.0]) {
            Err((SubmitError::QueueFull { depth: 1, cap: 1 }, input)) => {
                assert_eq!(input, vec![2.0, 3.0], "input must come back intact");
            }
            other => panic!("expected QueueFull with input, got {:?}", other.map(|_| ())),
        }
        b.close();
        match b.try_submit(vec![4.0]) {
            Err((SubmitError::ShuttingDown, input)) => assert_eq!(input, vec![4.0]),
            other => panic!("expected ShuttingDown with input, got {:?}", other.map(|_| ())),
        }
    }

    #[test]
    fn responses_map_to_issuing_request_in_order() {
        let cfg = BatchConfig {
            max_batch: 4,
            max_delay: Duration::from_millis(2),
            queue_cap: 256,
        };
        // executor returns input[0] * 2 so mixups are detectable
        let run: Box<BatchFn> =
            Box::new(|inputs| inputs.iter().map(|x| vec![x[0] * 2.0]).collect());
        let b = DynamicBatcher::new(cfg, run);
        let rxs: Vec<_> = (0..21).map(|i| b.submit(vec![i as f32]).unwrap()).collect();
        let mut ids = Vec::new();
        for (i, rx) in rxs.iter().enumerate() {
            let r = recv(rx);
            assert_eq!(r.logits, vec![i as f32 * 2.0], "response crossed requests");
            ids.push(r.id);
        }
        // admission ids are the submit order
        let expect: Vec<u64> = (0..21).collect();
        assert_eq!(ids, expect);
    }

    #[test]
    fn shutdown_flushes_pending_and_rejects_new() {
        let cfg = BatchConfig {
            max_batch: 1000,
            max_delay: Duration::from_secs(600),
            queue_cap: 64,
        };
        let b = DynamicBatcher::new(cfg, echo());
        let rx = b.submit(vec![7.0]).unwrap();
        b.shutdown(); // must not strand the queued request
        let r = rx.recv_timeout(Duration::from_secs(1)).expect("flush on shutdown");
        assert_eq!(r.logits, vec![7.0]);
    }

    #[test]
    fn concurrent_submitters_saturating_queue_account_exactly() {
        // slow executor + tiny queue: submits race each other into
        // saturation, and every request must end as exactly one of
        // {response delivered, QueueFull} — nothing lost, nothing double.
        let run: Box<BatchFn> = Box::new(|inputs| {
            thread::sleep(Duration::from_millis(1));
            inputs
        });
        let cfg = BatchConfig { max_batch: 2, max_delay: Duration::ZERO, queue_cap: 4 };
        let b = DynamicBatcher::new(cfg, run);
        let threads = 4;
        let per_thread = 50;
        let completed = std::sync::atomic::AtomicUsize::new(0);
        let shed = std::sync::atomic::AtomicUsize::new(0);
        thread::scope(|s| {
            for t in 0..threads {
                let b = &b;
                let completed = &completed;
                let shed = &shed;
                s.spawn(move || {
                    for i in 0..per_thread {
                        match b.submit(vec![(t * per_thread + i) as f32]) {
                            Ok(rx) => {
                                let r = recv(&rx);
                                assert_eq!(r.logits, vec![(t * per_thread + i) as f32]);
                                completed.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                            }
                            Err(SubmitError::QueueFull { depth, cap }) => {
                                assert!(depth >= cap, "shed below capacity: {depth}/{cap}");
                                shed.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                            }
                            Err(e) => panic!("unexpected submit error: {e:?}"),
                        }
                    }
                });
            }
        });
        let done = completed.load(std::sync::atomic::Ordering::Relaxed);
        let lost = shed.load(std::sync::atomic::Ordering::Relaxed);
        assert_eq!(done + lost, threads * per_thread);
        assert!(done > 0, "closed-loop clients must make progress");
        assert_eq!(b.depth(), 0);
    }

    #[test]
    fn close_drains_every_admitted_request() {
        // submitters race a concurrent close(): whatever was admitted
        // before the flag flipped must still receive its response —
        // shutdown drains in-flight receivers instead of stranding them.
        let cfg = BatchConfig {
            max_batch: 8,
            max_delay: Duration::from_millis(1),
            queue_cap: 4096,
        };
        let b = DynamicBatcher::new(cfg, echo());
        let admitted = Mutex::new(Vec::new());
        thread::scope(|s| {
            for t in 0..4 {
                let b = &b;
                let admitted = &admitted;
                s.spawn(move || {
                    for i in 0..200 {
                        match b.submit(vec![(t * 1000 + i) as f32]) {
                            Ok(rx) => admitted.lock().unwrap().push((t * 1000 + i, rx)),
                            Err(SubmitError::ShuttingDown) => break,
                            Err(e) => panic!("unexpected: {e:?}"),
                        }
                    }
                });
            }
            // flip the flag mid-race (no sleep needed: admits above race this)
            b.close();
            assert!(b.is_closed());
        });
        // every admitted request still gets its own response
        for (tag, rx) in admitted.into_inner().unwrap() {
            let r = rx.recv_timeout(Duration::from_secs(10)).expect("drained on close");
            assert_eq!(r.logits, vec![tag as f32]);
        }
        // post-close admission is refused
        match b.submit(vec![0.0]) {
            Err(SubmitError::ShuttingDown) => {}
            other => panic!("expected ShuttingDown, got {other:?}"),
        }
        b.shutdown();
    }

    #[test]
    fn deadline_path_survives_wakeup_storms() {
        // max_batch is unreachable, so every flush goes through the
        // deadline arm — the one that inspects `queue.front()`. Racing
        // submitters notify_all on every admit and concurrent depth()
        // polls contend for the state lock, so the dispatcher re-runs
        // its wait loop under heavy (including spurious-equivalent)
        // wakeups. Every admitted request must still complete.
        let cfg = BatchConfig {
            max_batch: 1000,
            max_delay: Duration::from_micros(200),
            queue_cap: 4096,
        };
        let b = DynamicBatcher::new(cfg, echo());
        thread::scope(|s| {
            for t in 0..4 {
                let b = &b;
                s.spawn(move || {
                    for i in 0..100 {
                        let rx = b.submit(vec![(t * 100 + i) as f32]).unwrap();
                        if i % 3 == 0 {
                            // lock-contending poll between submits
                            let _ = b.depth();
                        }
                        let r = recv(&rx);
                        assert_eq!(r.logits, vec![(t * 100 + i) as f32]);
                    }
                });
            }
        });
        assert_eq!(b.admitted(), 400);
        assert_eq!(b.depth(), 0);
    }

    #[test]
    fn completion_hook_sees_every_response() {
        let seen = Arc::new(Mutex::new(Vec::new()));
        let s2 = seen.clone();
        let hook: Box<CompletionHook> = Box::new(move |r| s2.lock().unwrap().push(r.id));
        let cfg = BatchConfig {
            max_batch: 3,
            max_delay: Duration::from_millis(2),
            queue_cap: 64,
        };
        let b = DynamicBatcher::with_hook(cfg, echo(), Some(hook));
        let rxs: Vec<_> = (0..7).map(|i| b.submit(vec![i as f32]).unwrap()).collect();
        for rx in &rxs {
            recv(rx);
        }
        let mut ids = seen.lock().unwrap().clone();
        ids.sort_unstable();
        assert_eq!(ids, (0..7).collect::<Vec<u64>>());
    }
}
