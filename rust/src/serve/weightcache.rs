//! Shared decoded-weight cache: decode each hot layer once per model
//! generation, not once per request block.
//!
//! Serving kernels decode n-bit weight codes on every call — `qgemm`
//! re-inflates each row per request, `qattention` re-inflates four
//! projection matrices per batch. With N accept-loop replicas hammering
//! the same models, that decode work is pure duplication. This module is
//! a process-wide arena keyed by `(model generation uid, layer, slot)`:
//! the float path caches the raw-code f32 matrix (pre-affine, exactly
//! the bytes the per-row decode would have produced), the `--int8` path
//! caches the u8 code matrix, and attention caches each projection's
//! post-affine weights. Entries are LRU-evicted under a byte budget
//! (`--weight-cache-mb`); a model's entries die with it via
//! `invalidate_model` from `ServableModel::drop`, so a hot reload never
//! serves stale weights.
//!
//! Bit-identity: a cached matrix is filled by the *same*
//! `decode_codes_f32` / `decode_codes_u8` calls the uncached path runs,
//! and consumers read the same row slices they would have decoded into
//! scratch — the arithmetic downstream is unchanged, so cache on/off
//! logits are bit-identical (pinned by a registry toggle test). The only
//! observable difference is telemetry: decode-time profiling and
//! saturation sampling happen at fill, not on every hit.
//!
//! Budget 0 (the default) disables the cache entirely: `get_*` returns
//! `None` without taking any lock and kernels run their legacy path.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, OnceLock, RwLock};

use crate::metrics::Prom;
use crate::util::json::Json;

/// Identity of one cacheable weight block.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct CacheKey {
    /// Model generation uid (`ServableModel::uid`) — fresh per load, so
    /// a hot reload changes every key and never aliases old weights.
    pub model: u64,
    /// Planned layer index within the model.
    pub layer: u32,
    /// Sub-slot: 0 = the layer's main payload, 1..=4 = attention
    /// q/k/v/proj projections.
    pub slot: u8,
}

enum CacheVal {
    F32(Arc<Vec<f32>>),
    U8(Arc<Vec<u8>>),
}

impl CacheVal {
    fn bytes(&self) -> usize {
        match self {
            CacheVal::F32(v) => v.len() * std::mem::size_of::<f32>(),
            CacheVal::U8(v) => v.len(),
        }
    }
}

struct Entry {
    val: CacheVal,
    /// Last-touch tick for LRU eviction (global monotonic counter).
    tick: AtomicU64,
}

#[derive(Default)]
struct Arena {
    map: HashMap<CacheKey, Entry>,
    bytes: usize,
}

/// The process-wide decoded-weight arena. Obtain via [`cache`].
pub struct WeightCache {
    inner: RwLock<Arena>,
    /// Byte budget; 0 = disabled (checked lock-free on the hot path).
    budget: AtomicUsize,
    tick: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
    inserts: AtomicU64,
    evictions: AtomicU64,
}

/// Point-in-time counters for `/debug/stats` and tests.
#[derive(Clone, Copy, Debug, Default)]
pub struct WeightCacheStats {
    pub hits: u64,
    pub misses: u64,
    pub inserts: u64,
    pub evictions: u64,
    pub entries: usize,
    pub bytes: usize,
    pub budget: usize,
}

/// The global cache singleton (same idiom as `obs::profiler`).
pub fn cache() -> &'static WeightCache {
    static CACHE: OnceLock<WeightCache> = OnceLock::new();
    CACHE.get_or_init(|| WeightCache {
        inner: RwLock::new(Arena::default()),
        budget: AtomicUsize::new(0),
        tick: AtomicU64::new(0),
        hits: AtomicU64::new(0),
        misses: AtomicU64::new(0),
        inserts: AtomicU64::new(0),
        evictions: AtomicU64::new(0),
    })
}

impl WeightCache {
    /// Set the byte budget. Shrinking (including to 0 = off) evicts down
    /// to the new budget immediately.
    pub fn set_budget_bytes(&self, bytes: usize) {
        self.budget.store(bytes, Ordering::Release);
        let mut g = self.inner.write().unwrap();
        while g.bytes > bytes {
            self.evict_lru(&mut g);
        }
    }

    /// Convenience for the `--weight-cache-mb` flag.
    pub fn set_budget_mb(&self, mb: usize) {
        self.set_budget_bytes(mb.saturating_mul(1 << 20));
    }

    /// Lock-free fast gate: is caching on at all?
    pub fn enabled(&self) -> bool {
        self.budget.load(Ordering::Acquire) > 0
    }

    /// Fetch the f32 block for `key`, decoding via `make` on a miss.
    /// Returns `None` when the cache is disabled (caller runs its
    /// legacy scratch-decode path). `make` runs outside any lock, so
    /// two concurrent misses may both decode; last insert wins.
    pub fn get_or_decode_f32(
        &self,
        key: CacheKey,
        make: impl FnOnce() -> Vec<f32>,
    ) -> Option<Arc<Vec<f32>>> {
        if !self.enabled() {
            return None;
        }
        if let Some(CacheVal::F32(v)) = self.lookup(key, |v| matches!(v, CacheVal::F32(_))) {
            return Some(v);
        }
        let v = Arc::new(make());
        self.insert(key, CacheVal::F32(v.clone()));
        Some(v)
    }

    /// u8 twin of [`Self::get_or_decode_f32`] for the `--int8` path.
    pub fn get_or_decode_u8(
        &self,
        key: CacheKey,
        make: impl FnOnce() -> Vec<u8>,
    ) -> Option<Arc<Vec<u8>>> {
        if !self.enabled() {
            return None;
        }
        if let Some(CacheVal::U8(v)) = self.lookup(key, |v| matches!(v, CacheVal::U8(_))) {
            return Some(v);
        }
        let v = Arc::new(make());
        self.insert(key, CacheVal::U8(v.clone()));
        Some(v)
    }

    /// Drop every entry belonging to model generation `model`. Called
    /// from `ServableModel::drop`; cheap no-op when the arena is empty.
    pub fn invalidate_model(&self, model: u64) {
        {
            let g = self.inner.read().unwrap();
            if g.map.is_empty() {
                return;
            }
        }
        let mut g = self.inner.write().unwrap();
        let dead: Vec<CacheKey> = g.map.keys().filter(|k| k.model == model).copied().collect();
        for k in dead {
            if let Some(e) = g.map.remove(&k) {
                g.bytes -= e.val.bytes();
            }
        }
    }

    /// Drop everything (budget unchanged). Test hygiene.
    pub fn clear(&self) {
        let mut g = self.inner.write().unwrap();
        g.map.clear();
        g.bytes = 0;
    }

    pub fn stats(&self) -> WeightCacheStats {
        let g = self.inner.read().unwrap();
        WeightCacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            inserts: self.inserts.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            entries: g.map.len(),
            bytes: g.bytes,
            budget: self.budget.load(Ordering::Acquire),
        }
    }

    /// Render the `msq_weight_cache_*` families into a scrape.
    pub fn render(&self, p: &mut Prom) {
        let s = self.stats();
        p.family(
            "msq_weight_cache_enabled",
            "gauge",
            "1 when a decoded-weight cache budget is set",
        );
        p.sample("msq_weight_cache_enabled", &[], if s.budget > 0 { 1.0 } else { 0.0 });
        p.family("msq_weight_cache_budget_bytes", "gauge", "Decoded-weight cache byte budget");
        p.sample("msq_weight_cache_budget_bytes", &[], s.budget as f64);
        p.family("msq_weight_cache_bytes", "gauge", "Decoded-weight cache resident bytes");
        p.sample("msq_weight_cache_bytes", &[], s.bytes as f64);
        p.family("msq_weight_cache_entries", "gauge", "Decoded-weight cache resident entries");
        p.sample("msq_weight_cache_entries", &[], s.entries as f64);
        p.family("msq_weight_cache_hits_total", "counter", "Decoded-weight cache hits");
        p.sample("msq_weight_cache_hits_total", &[], s.hits as f64);
        p.family(
            "msq_weight_cache_misses_total",
            "counter",
            "Decoded-weight cache misses (decode + fill)",
        );
        p.sample("msq_weight_cache_misses_total", &[], s.misses as f64);
        p.family(
            "msq_weight_cache_evictions_total",
            "counter",
            "Decoded-weight cache LRU evictions",
        );
        p.sample("msq_weight_cache_evictions_total", &[], s.evictions as f64);
        p.family("msq_weight_cache_inserts_total", "counter", "Decoded-weight cache fills");
        p.sample("msq_weight_cache_inserts_total", &[], s.inserts as f64);
    }

    /// JSON view for `/debug/stats`.
    pub fn to_json(&self) -> Json {
        let s = self.stats();
        Json::obj(vec![
            ("enabled", Json::Bool(s.budget > 0)),
            ("budget_bytes", Json::Num(s.budget as f64)),
            ("bytes", Json::Num(s.bytes as f64)),
            ("entries", Json::Num(s.entries as f64)),
            ("hits", Json::Num(s.hits as f64)),
            ("misses", Json::Num(s.misses as f64)),
            ("evictions", Json::Num(s.evictions as f64)),
            ("inserts", Json::Num(s.inserts as f64)),
        ])
    }

    /// Whether `key` is resident right now (no LRU touch, no counter
    /// bumps). Test-only observability — concurrent tests make global
    /// entry counts racy, but a specific key's residency is exact.
    #[doc(hidden)]
    pub fn contains(&self, key: CacheKey) -> bool {
        self.inner.read().unwrap().map.contains_key(&key)
    }

    fn lookup(&self, key: CacheKey, want: impl Fn(&CacheVal) -> bool) -> Option<CacheVal> {
        let g = self.inner.read().unwrap();
        if let Some(e) = g.map.get(&key) {
            if want(&e.val) {
                e.tick.store(self.tick.fetch_add(1, Ordering::Relaxed) + 1, Ordering::Relaxed);
                self.hits.fetch_add(1, Ordering::Relaxed);
                return Some(match &e.val {
                    CacheVal::F32(v) => CacheVal::F32(v.clone()),
                    CacheVal::U8(v) => CacheVal::U8(v.clone()),
                });
            }
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        None
    }

    fn insert(&self, key: CacheKey, val: CacheVal) {
        let budget = self.budget.load(Ordering::Acquire);
        let bytes = val.bytes();
        if bytes > budget {
            // Uncacheable block: bigger than the whole budget. The
            // caller still gets its Arc; we just never retain it.
            return;
        }
        let mut g = self.inner.write().unwrap();
        if let Some(old) = g.map.remove(&key) {
            g.bytes -= old.val.bytes();
        }
        while g.bytes + bytes > budget {
            self.evict_lru(&mut g);
        }
        g.bytes += bytes;
        let tick = self.tick.fetch_add(1, Ordering::Relaxed) + 1;
        g.map.insert(key, Entry { val, tick: AtomicU64::new(tick) });
        self.inserts.fetch_add(1, Ordering::Relaxed);
    }

    fn evict_lru(&self, g: &mut Arena) {
        let victim = g
            .map
            .iter()
            .min_by_key(|(_, e)| e.tick.load(Ordering::Relaxed))
            .map(|(k, _)| *k);
        match victim {
            Some(k) => {
                if let Some(e) = g.map.remove(&k) {
                    g.bytes -= e.val.bytes();
                    self.evictions.fetch_add(1, Ordering::Relaxed);
                }
            }
            None => g.bytes = 0,
        }
    }
}

/// Serializes tests that flip the global cache budget; same idiom as
/// `obs::qstats::test_mutex`. Production code never calls this.
#[doc(hidden)]
pub fn test_mutex() -> std::sync::MutexGuard<'static, ()> {
    static M: std::sync::Mutex<()> = std::sync::Mutex::new(());
    M.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(model: u64, layer: u32) -> CacheKey {
        CacheKey { model, layer, slot: 0 }
    }

    /// Reset to a known state under the test mutex.
    fn fresh(budget: usize) -> &'static WeightCache {
        let c = cache();
        c.clear();
        c.set_budget_bytes(budget);
        c
    }

    #[test]
    fn disabled_cache_returns_none_and_decodes_nothing() {
        let _g = test_mutex();
        let c = fresh(0);
        let mut ran = false;
        let got = c.get_or_decode_f32(key(1, 0), || {
            ran = true;
            vec![1.0]
        });
        assert!(got.is_none());
        assert!(!ran, "make must not run when the cache is off");
    }

    #[test]
    fn second_lookup_hits_without_redecoding() {
        let _g = test_mutex();
        let c = fresh(1 << 20);
        let h0 = c.stats().hits;
        let a = c.get_or_decode_f32(key(2, 0), || vec![1.0, 2.0]).unwrap();
        let b = c.get_or_decode_f32(key(2, 0), || panic!("hit must not decode")).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "hit returns the shared arc");
        assert_eq!(c.stats().hits, h0 + 1);
        c.set_budget_bytes(0);
    }

    #[test]
    fn lru_evicts_coldest_under_budget_pressure() {
        let _g = test_mutex();
        // room for two 40-byte entries
        let c = fresh(80);
        c.get_or_decode_f32(key(3, 0), || vec![0.0; 10]).unwrap();
        c.get_or_decode_f32(key(3, 1), || vec![0.0; 10]).unwrap();
        // touch layer 0 so layer 1 is coldest
        c.get_or_decode_f32(key(3, 0), || panic!("must hit")).unwrap();
        c.get_or_decode_f32(key(3, 2), || vec![0.0; 10]).unwrap();
        let s = c.stats();
        assert_eq!(s.entries, 2);
        assert!(s.bytes <= 80);
        // layer 1 was evicted; layer 0 survives
        c.get_or_decode_f32(key(3, 0), || panic!("hot entry was evicted")).unwrap();
        c.get_or_decode_f32(key(3, 1), || vec![0.0; 10]).unwrap(); // refill = miss
        assert!(c.stats().evictions >= 2);
        c.set_budget_bytes(0);
    }

    #[test]
    fn invalidate_model_drops_only_that_generation() {
        let _g = test_mutex();
        let c = fresh(1 << 20);
        c.get_or_decode_f32(key(10, 0), || vec![0.0; 4]).unwrap();
        c.get_or_decode_u8(CacheKey { model: 10, layer: 1, slot: 0 }, || vec![0u8; 4]).unwrap();
        c.get_or_decode_f32(key(11, 0), || vec![0.0; 4]).unwrap();
        c.invalidate_model(10);
        let s = c.stats();
        assert_eq!(s.entries, 1);
        assert_eq!(s.bytes, 16);
        c.get_or_decode_f32(key(11, 0), || panic!("other model must survive")).unwrap();
        c.set_budget_bytes(0);
    }

    #[test]
    fn domain_mismatch_is_a_miss_not_a_panic() {
        let _g = test_mutex();
        let c = fresh(1 << 20);
        c.get_or_decode_f32(key(20, 0), || vec![1.0; 4]).unwrap();
        // same key, int domain: must re-decode and take over the slot
        let v = c.get_or_decode_u8(key(20, 0), || vec![7u8; 4]).unwrap();
        assert_eq!(v.as_slice(), &[7u8; 4]);
        c.set_budget_bytes(0);
    }

    #[test]
    fn oversize_blocks_pass_through_without_insert() {
        let _g = test_mutex();
        let c = fresh(16);
        let v = c.get_or_decode_f32(key(30, 0), || vec![0.0; 100]).unwrap();
        assert_eq!(v.len(), 100);
        assert_eq!(c.stats().entries, 0);
        c.set_budget_bytes(0);
    }

    #[test]
    fn shrinking_budget_evicts_immediately() {
        let _g = test_mutex();
        let c = fresh(1 << 20);
        for l in 0..4 {
            c.get_or_decode_f32(key(40, l), || vec![0.0; 10]).unwrap();
        }
        assert_eq!(c.stats().entries, 4);
        c.set_budget_bytes(80);
        assert!(c.stats().bytes <= 80);
        c.set_budget_bytes(0);
        assert_eq!(c.stats().entries, 0);
    }
}
