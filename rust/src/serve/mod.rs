//! `msq serve` (S16): batched inference serving over packed MSQ models.
//!
//! The training stack produces `.msqpack` artifacts — each layer's
//! weights bit-packed to their mixed-precision RoundClamp codes
//! (`quant::pack`). This subsystem turns those artifacts into a request
//! server with **zero XLA/PJRT linkage**, so the deployment story
//! matches the paper's motivation: mixed-precision models small enough
//! and cheap enough to execute on resource-constrained hosts.
//!
//! Four pieces, composed by [`server::Server`]:
//!
//! * [`registry`] — loads `.msqpack` files, plans an op graph from the
//!   per-layer descriptors (linear / conv2d + fused ReLU, pack v3), and
//!   keeps models resident in packed form (RAM cost = payload bytes);
//! * [`kernels`] — quantized matmul + conv2d that decode the n-bit code
//!   stream on the fly (1..=8 bits, non-byte-aligned), blocked per row /
//!   per filter and parallelized over `util::threadpool`. The inner
//!   loops run on the shared kernel core ([`crate::kernels`]), whose
//!   lane-structured primitives guarantee bit-identical logits across
//!   {serial, pooled} × {scalar, simd} configurations;
//! * [`batcher`] — dynamic batching with size- and deadline-triggered
//!   flush plus queue-capacity admission control;
//! * [`admission`] — a bounded wait room with per-request deadlines in
//!   front of the batcher queue, so bursts drain instead of shedding at
//!   first contact;
//! * [`weightcache`] — a process-wide LRU arena of decoded weight
//!   blocks keyed by (model generation, layer), shared across gateway
//!   replicas under a byte budget;
//! * [`server`] — the front end wiring model + batcher + [`ServeMetrics`]
//!   (throughput, p50/p95/p99 latency via `metrics::LatencyHist`).
//!
//! ```text
//! submit(x) ─► admission gate ─► bounded queue ─► dispatcher ─► qgemm
//!                  │ (wait ≤ deadline) │ (cap)        │ (size | deadline)
//!                  ▼                   ▼              ▼
//!          429 expired/shed        QueueFull     batch of ≤ max_batch
//! ```
//!
//! Entry points: `msq serve --model mlp --packed model.msqpack` (CLI,
//! stdin JSONL or synthetic load) and the `serve_throughput` bench.

pub mod admission;
pub mod batcher;
pub mod kernels;
pub mod registry;
pub mod server;
pub mod weightcache;

pub use admission::{Admission, AdmissionConfig, AdmitError};
pub use batcher::{BatchConfig, DynamicBatcher, InferResponse, SubmitError};
pub use registry::{
    analyze_packed, resolve_input_dim, LayerAnalysis, LayerKind, ModelAnalysis, ModelRegistry,
    QuantLayer, ServableModel,
};
pub use server::{ServeMetrics, Server, ServerConfig};
pub use weightcache::{CacheKey, WeightCache, WeightCacheStats};
