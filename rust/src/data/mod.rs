//! Synthetic dataset substrate (S12) + the batch pipeline.
//!
//! The paper trains on CIFAR-10 / ImageNet; offline we generate
//! *procedural* class-conditional image datasets with enough structure
//! that quantized CNNs/ViTs must learn real multi-scale features (see
//! `synthetic.rs`). Every method sees the identical deterministic stream,
//! which is what the paper's comparisons require (DESIGN.md
//! §Substitutions).

pub mod batcher;
pub mod synthetic;

pub use batcher::{Batch, Batcher};
pub use synthetic::{Dataset, DatasetSpec};
