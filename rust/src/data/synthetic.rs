//! Procedural class-conditional image generator.
//!
//! Each class is defined by a random but fixed "prototype program": a set
//! of oriented Gabor-like gratings + soft blobs with class-specific
//! frequencies, orientations, colors and positions. A sample draws the
//! class program and perturbs every component (jitter, amplitude noise,
//! global illumination, additive pixel noise), so intra-class variance is
//! real and inter-class separation requires learning oriented multi-scale
//! features — the same inductive load CIFAR puts on a small CNN, at the
//! same shapes (32×32×3 / 64×64×3).
//!
//! Generation is deterministic in (seed, split, index) and parallelized
//! over the thread pool; images are standardized per-channel.

use crate::util::prng::Rng;
use crate::util::threadpool::ThreadPool;

#[derive(Clone, Debug)]
pub struct DatasetSpec {
    pub name: String,
    pub height: usize,
    pub width: usize,
    pub channels: usize,
    pub classes: usize,
    pub train_size: usize,
    pub test_size: usize,
    pub seed: u64,
}

impl DatasetSpec {
    /// CIFAR-10-shaped: 32×32×3, 10 classes.
    pub fn cifar_syn(train_size: usize, test_size: usize, seed: u64) -> Self {
        DatasetSpec {
            name: "cifar-syn".into(),
            height: 32,
            width: 32,
            channels: 3,
            classes: 10,
            train_size,
            test_size,
            seed,
        }
    }

    /// Scaled-ImageNet-shaped: 64×64×3, 100 classes.
    pub fn in64_syn(train_size: usize, test_size: usize, seed: u64) -> Self {
        DatasetSpec {
            name: "in64-syn".into(),
            height: 64,
            width: 64,
            channels: 3,
            classes: 100,
            train_size,
            test_size,
            seed,
        }
    }

    pub fn image_elems(&self) -> usize {
        self.height * self.width * self.channels
    }

    /// Flattened input width an MLP-shaped model sees (alias of
    /// [`image_elems`](Self::image_elems); the native backend and the
    /// serve registry both chain shapes from this number).
    pub fn input_dim(&self) -> usize {
        self.image_elems()
    }
}

/// One Gabor/blob component of a class prototype.
#[derive(Clone, Debug)]
struct Component {
    cx: f32,
    cy: f32,
    sigma: f32,
    freq: f32,
    theta: f32,
    phase: f32,
    color: [f32; 3],
    amp: f32,
    blob: bool, // blob (low-pass) vs grating (band-pass)
}

/// A class prototype: 3–6 components.
#[derive(Clone, Debug)]
struct Prototype {
    comps: Vec<Component>,
    bg: [f32; 3],
}

fn make_prototype(rng: &mut Rng) -> Prototype {
    let ncomp = 3 + rng.below(4);
    let comps = (0..ncomp)
        .map(|_| Component {
            cx: rng.range(0.2, 0.8),
            cy: rng.range(0.2, 0.8),
            sigma: rng.range(0.08, 0.35),
            freq: rng.range(2.0, 12.0),
            theta: rng.range(0.0, std::f32::consts::PI),
            phase: rng.range(0.0, std::f32::consts::TAU),
            color: [rng.range(-1.0, 1.0), rng.range(-1.0, 1.0), rng.range(-1.0, 1.0)],
            amp: rng.range(0.5, 1.2),
            blob: rng.next_u64() & 3 == 0,
        })
        .collect();
    Prototype {
        comps,
        bg: [rng.range(-0.3, 0.3), rng.range(-0.3, 0.3), rng.range(-0.3, 0.3)],
    }
}

/// In-memory dataset: images NHWC f32 (standardized), labels i32.
pub struct Dataset {
    pub spec: DatasetSpec,
    pub train_x: Vec<f32>,
    pub train_y: Vec<i32>,
    pub test_x: Vec<f32>,
    pub test_y: Vec<i32>,
}

impl Dataset {
    /// Generate the full dataset (parallel over `pool`).
    pub fn generate(spec: DatasetSpec, pool: &ThreadPool) -> Dataset {
        let mut proto_rng = Rng::new(spec.seed ^ 0xC1A5_5EED);
        let protos: Vec<Prototype> =
            (0..spec.classes).map(|_| make_prototype(&mut proto_rng)).collect();

        let gen_split = |split_tag: u64, count: usize| {
            let elems = spec.image_elems();
            let mut xs = vec![0f32; count * elems];
            let mut ys = vec![0i32; count];
            // labels: balanced round-robin then shuffled deterministically
            for (i, y) in ys.iter_mut().enumerate() {
                *y = (i % spec.classes) as i32;
            }
            let mut sh = Rng::new(spec.seed ^ split_tag ^ 0x5375_FF1E);
            sh.shuffle(&mut ys);
            let ys_ref = &ys;
            let protos_ref = &protos;
            let spec_ref = &spec;
            // parallel render; each image owns a disjoint slice
            let xs_ptr = SendPtr(xs.as_mut_ptr());
            let xs_ref = &xs_ptr;
            pool.par_for(count, |i| {
                let y = ys_ref[i] as usize;
                let mut rng = Rng::new(
                    spec_ref.seed ^ split_tag ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
                );
                let out = unsafe {
                    std::slice::from_raw_parts_mut(xs_ref.get().add(i * elems), elems)
                };
                render(spec_ref, &protos_ref[y], &mut rng, out);
            });
            (xs, ys)
        };

        let (train_x, train_y) = gen_split(0x7121, spec.train_size);
        let (test_x, test_y) = gen_split(0x7E57, spec.test_size);
        let mut ds = Dataset { spec, train_x, train_y, test_x, test_y };
        ds.standardize();
        ds
    }

    /// Per-channel standardization using train statistics (applied to both
    /// splits, like CIFAR preprocessing).
    fn standardize(&mut self) {
        let c = self.spec.channels;
        let mut mean = vec![0f64; c];
        let mut var = vec![0f64; c];
        let n = (self.train_x.len() / c) as f64;
        for (i, &v) in self.train_x.iter().enumerate() {
            mean[i % c] += v as f64;
        }
        for m in mean.iter_mut() {
            *m /= n;
        }
        for (i, &v) in self.train_x.iter().enumerate() {
            let d = v as f64 - mean[i % c];
            var[i % c] += d * d;
        }
        for v in var.iter_mut() {
            *v = (*v / n).sqrt().max(1e-6);
        }
        for (i, v) in self.train_x.iter_mut().enumerate() {
            *v = ((*v as f64 - mean[i % c]) / var[i % c]) as f32;
        }
        for (i, v) in self.test_x.iter_mut().enumerate() {
            *v = ((*v as f64 - mean[i % c]) / var[i % c]) as f32;
        }
    }

    pub fn image(&self, split_train: bool, i: usize) -> &[f32] {
        let e = self.spec.image_elems();
        if split_train {
            &self.train_x[i * e..(i + 1) * e]
        } else {
            &self.test_x[i * e..(i + 1) * e]
        }
    }
}

struct SendPtr(*mut f32);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

impl SendPtr {
    fn get(&self) -> *mut f32 {
        self.0
    }
}

/// Render one sample of a class prototype into `out` (HWC).
fn render(spec: &DatasetSpec, proto: &Prototype, rng: &mut Rng, out: &mut [f32]) {
    let (h, w, c) = (spec.height, spec.width, spec.channels);
    let illum = rng.range(0.8, 1.2);
    // start from background + low-frequency illumination gradient
    let gx = rng.range(-0.2, 0.2);
    let gy = rng.range(-0.2, 0.2);
    for y in 0..h {
        for x in 0..w {
            let fx = x as f32 / w as f32;
            let fy = y as f32 / h as f32;
            let g = gx * (fx - 0.5) + gy * (fy - 0.5);
            for ch in 0..c {
                out[(y * w + x) * c + ch] = proto.bg[ch % 3] * illum + g;
            }
        }
    }
    // jittered components
    for comp in &proto.comps {
        let cx = comp.cx + rng.range(-0.08, 0.08);
        let cy = comp.cy + rng.range(-0.08, 0.08);
        let amp = comp.amp * rng.range(0.7, 1.3);
        let theta = comp.theta + rng.range(-0.15, 0.15);
        let phase = comp.phase + rng.range(-0.5, 0.5);
        let (st, ct) = theta.sin_cos();
        let inv2s2 = 1.0 / (2.0 * comp.sigma * comp.sigma);
        for y in 0..h {
            let fy = y as f32 / h as f32 - cy;
            for x in 0..w {
                let fx = x as f32 / w as f32 - cx;
                let r2 = fx * fx + fy * fy;
                let env = (-r2 * inv2s2).exp();
                if env < 1e-3 {
                    continue;
                }
                let carrier = if comp.blob {
                    1.0
                } else {
                    (comp.freq * std::f32::consts::TAU * (fx * ct + fy * st) + phase).sin()
                };
                let v = amp * env * carrier;
                let idx = (y * w + x) * c;
                for ch in 0..c {
                    out[idx + ch] += v * comp.color[ch % 3];
                }
            }
        }
    }
    // pixel noise
    for v in out.iter_mut() {
        *v += rng.normal() * 0.08;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        let pool = ThreadPool::new(2);
        Dataset::generate(DatasetSpec::cifar_syn(200, 80, 42), &pool)
    }

    #[test]
    fn shapes_and_labels() {
        let ds = tiny();
        assert_eq!(ds.train_x.len(), 200 * 32 * 32 * 3);
        assert_eq!(ds.test_y.len(), 80);
        assert!(ds.train_y.iter().all(|&y| (0..10).contains(&y)));
    }

    #[test]
    fn deterministic() {
        let a = tiny();
        let b = tiny();
        assert_eq!(a.train_x, b.train_x);
        assert_eq!(a.train_y, b.train_y);
    }

    #[test]
    fn balanced_classes() {
        let ds = tiny();
        let mut counts = [0usize; 10];
        for &y in &ds.train_y {
            counts[y as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c == 20), "{counts:?}");
    }

    #[test]
    fn standardized() {
        let ds = tiny();
        let mean: f64 =
            ds.train_x.iter().map(|&v| v as f64).sum::<f64>() / ds.train_x.len() as f64;
        let var: f64 = ds.train_x.iter().map(|&v| (v as f64 - mean).powi(2)).sum::<f64>()
            / ds.train_x.len() as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.15, "var {var}");
    }

    #[test]
    fn classes_are_separable_by_template_matching() {
        // nearest-class-mean on raw pixels should beat chance by a wide
        // margin — the generator encodes real class structure.
        let ds = tiny();
        let e = ds.spec.image_elems();
        let mut means = vec![vec![0f32; e]; 10];
        let mut counts = [0usize; 10];
        for i in 0..ds.train_y.len() {
            let y = ds.train_y[i] as usize;
            counts[y] += 1;
            for (m, &v) in means[y].iter_mut().zip(ds.image(true, i)) {
                *m += v;
            }
        }
        for (m, &ct) in means.iter_mut().zip(&counts) {
            for v in m.iter_mut() {
                *v /= ct as f32;
            }
        }
        let mut correct = 0;
        for i in 0..ds.test_y.len() {
            let img = ds.image(false, i);
            let best = (0..10)
                .min_by(|&a, &b| {
                    let da: f32 = means[a].iter().zip(img).map(|(m, v)| (m - v).powi(2)).sum();
                    let db: f32 = means[b].iter().zip(img).map(|(m, v)| (m - v).powi(2)).sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if best as i32 == ds.test_y[i] {
                correct += 1;
            }
        }
        let acc = correct as f32 / ds.test_y.len() as f32;
        assert!(acc > 0.5, "template-matching acc {acc} — classes not separable");
    }
}
