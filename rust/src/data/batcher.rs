//! Epoch batcher: deterministic shuffling, augmentation, fixed-size
//! batches (AOT artifacts have static batch dims — the tail partial batch
//! is wrapped around, standard for synthetic/epoch-based training).

use super::synthetic::Dataset;
use crate::util::prng::Rng;

/// One training batch, NHWC images + labels, ready for the PJRT bridge.
pub struct Batch {
    pub x: Vec<f32>,
    pub y: Vec<i32>,
    pub size: usize,
}

pub struct Batcher<'d> {
    ds: &'d Dataset,
    batch: usize,
    order: Vec<usize>,
    cursor: usize,
    epoch: u64,
    seed: u64,
    augment: bool,
}

impl<'d> Batcher<'d> {
    pub fn new(ds: &'d Dataset, batch: usize, seed: u64, augment: bool) -> Self {
        let mut b = Batcher {
            ds,
            batch,
            order: (0..ds.train_y.len()).collect(),
            cursor: 0,
            epoch: 0,
            seed,
            augment,
        };
        b.reshuffle();
        b
    }

    fn reshuffle(&mut self) {
        let mut rng = Rng::new(self.seed ^ self.epoch.wrapping_mul(0xA55A_5AA5));
        self.order = (0..self.ds.train_y.len()).collect();
        rng.shuffle(&mut self.order);
        self.cursor = 0;
    }

    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Batches per epoch (ceil).
    pub fn batches_per_epoch(&self) -> usize {
        self.ds.train_y.len().div_ceil(self.batch)
    }

    /// Next batch; advances the epoch (with reshuffle) when exhausted.
    pub fn next(&mut self) -> Batch {
        let spec = &self.ds.spec;
        let e = spec.image_elems();
        let mut x = Vec::with_capacity(self.batch * e);
        let mut y = Vec::with_capacity(self.batch);
        let mut aug_rng = Rng::new(
            self.seed ^ 0xAE61 ^ self.epoch.wrapping_mul(31).wrapping_add(self.cursor as u64),
        );
        for j in 0..self.batch {
            let idx = self.order[(self.cursor + j) % self.order.len()];
            y.push(self.ds.train_y[idx]);
            let img = self.ds.image(true, idx);
            if self.augment {
                push_augmented(img, spec.height, spec.width, spec.channels, &mut aug_rng, &mut x);
            } else {
                x.extend_from_slice(img);
            }
        }
        self.cursor += self.batch;
        if self.cursor >= self.order.len() {
            self.epoch += 1;
            self.reshuffle();
        }
        Batch { x, y, size: self.batch }
    }

    /// Iterate the *test* split in fixed-size batches (tail wrapped).
    pub fn test_batches(&self, batch: usize) -> Vec<Batch> {
        let spec = &self.ds.spec;
        let e = spec.image_elems();
        let n = self.ds.test_y.len();
        let mut out = Vec::new();
        let mut i = 0;
        while i < n {
            let mut x = Vec::with_capacity(batch * e);
            let mut y = Vec::with_capacity(batch);
            for j in 0..batch {
                let idx = (i + j) % n;
                x.extend_from_slice(self.ds.image(false, idx));
                y.push(self.ds.test_y[idx]);
            }
            // only the first (n - i).min(batch) entries are fresh
            out.push(Batch { x, y, size: batch });
            i += batch;
        }
        out
    }
}

/// Random horizontal flip + ±2px shift with edge padding (CIFAR-style).
fn push_augmented(img: &[f32], h: usize, w: usize, c: usize, rng: &mut Rng, out: &mut Vec<f32>) {
    let flip = rng.next_u64() & 1 == 1;
    let dx = rng.below(5) as isize - 2;
    let dy = rng.below(5) as isize - 2;
    for y in 0..h {
        let sy = (y as isize + dy).clamp(0, h as isize - 1) as usize;
        for x in 0..w {
            let xx = if flip { w - 1 - x } else { x };
            let sx = (xx as isize + dx).clamp(0, w as isize - 1) as usize;
            let base = (sy * w + sx) * c;
            out.extend_from_slice(&img[base..base + c]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::DatasetSpec;
    use crate::util::threadpool::ThreadPool;

    fn ds() -> Dataset {
        let pool = ThreadPool::new(2);
        Dataset::generate(DatasetSpec::cifar_syn(100, 40, 7), &pool)
    }

    #[test]
    fn batch_shapes() {
        let d = ds();
        let mut b = Batcher::new(&d, 32, 1, false);
        let batch = b.next();
        assert_eq!(batch.x.len(), 32 * 32 * 32 * 3);
        assert_eq!(batch.y.len(), 32);
    }

    #[test]
    fn epoch_advances_and_reshuffles() {
        let d = ds();
        let mut b = Batcher::new(&d, 50, 1, false);
        assert_eq!(b.batches_per_epoch(), 2);
        let e0b0 = b.next();
        let _ = b.next();
        assert_eq!(b.epoch(), 1);
        let e1b0 = b.next();
        assert_ne!(e0b0.y, e1b0.y); // different shuffle
    }

    #[test]
    fn augmentation_preserves_shape_and_changes_pixels() {
        let d = ds();
        let mut plain = Batcher::new(&d, 8, 1, false);
        let mut aug = Batcher::new(&d, 8, 1, true);
        let bp = plain.next();
        let ba = aug.next();
        assert_eq!(bp.x.len(), ba.x.len());
        assert_eq!(bp.y, ba.y); // same order, same labels
        assert_ne!(bp.x, ba.x); // pixels moved
    }

    #[test]
    fn test_batches_cover_split() {
        let d = ds();
        let b = Batcher::new(&d, 16, 1, false);
        let tbs = b.test_batches(16);
        assert_eq!(tbs.len(), 3); // ceil(40 / 16)
        assert!(tbs.iter().all(|t| t.y.len() == 16));
    }

    #[test]
    fn deterministic_stream() {
        let d = ds();
        let mut b1 = Batcher::new(&d, 16, 9, true);
        let mut b2 = Batcher::new(&d, 16, 9, true);
        for _ in 0..5 {
            let x1 = b1.next();
            let x2 = b2.next();
            assert_eq!(x1.x, x2.x);
            assert_eq!(x1.y, x2.y);
        }
    }
}
