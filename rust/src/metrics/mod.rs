//! Metrics sinks (S13): CSV + JSONL writers and the run report.
//!
//! Every experiment writes machine-readable rows under `results/` so the
//! paper tables/figures regenerate from files, plus a human-readable
//! summary on stdout.

use std::fs::{self, File};
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Append-only CSV writer with a fixed header.
pub struct Csv {
    w: BufWriter<File>,
    cols: usize,
}

impl Csv {
    pub fn create(path: &Path, header: &[&str]) -> Result<Csv> {
        if let Some(dir) = path.parent() {
            fs::create_dir_all(dir)?;
        }
        let mut w = BufWriter::new(File::create(path).with_context(|| format!("{path:?}"))?);
        writeln!(w, "{}", header.join(","))?;
        Ok(Csv { w, cols: header.len() })
    }

    pub fn row(&mut self, values: &[String]) -> Result<()> {
        debug_assert_eq!(values.len(), self.cols);
        writeln!(self.w, "{}", values.join(","))?;
        Ok(())
    }

    pub fn rowf(&mut self, values: &[f64]) -> Result<()> {
        let s: Vec<String> = values.iter().map(|v| format!("{v}")).collect();
        self.row(&s)
    }

    pub fn flush(&mut self) -> Result<()> {
        self.w.flush()?;
        Ok(())
    }
}

/// Append-only JSONL writer.
pub struct Jsonl {
    w: BufWriter<File>,
}

impl Jsonl {
    pub fn create(path: &Path) -> Result<Jsonl> {
        if let Some(dir) = path.parent() {
            fs::create_dir_all(dir)?;
        }
        Ok(Jsonl { w: BufWriter::new(File::create(path).with_context(|| format!("{path:?}"))?) })
    }

    pub fn write(&mut self, v: &Json) -> Result<()> {
        writeln!(self.w, "{}", v.to_string())?;
        Ok(())
    }

    pub fn flush(&mut self) -> Result<()> {
        self.w.flush()?;
        Ok(())
    }
}

/// Results directory: `$MSQ_RESULTS` or `./results`.
pub fn results_dir() -> PathBuf {
    std::env::var("MSQ_RESULTS").map(PathBuf::from).unwrap_or_else(|_| "results".into())
}

/// Format seconds as h/m/s for table output.
pub fn fmt_duration(secs: f64) -> String {
    if secs >= 3600.0 {
        format!("{:.2}h", secs / 3600.0)
    } else if secs >= 60.0 {
        format!("{:.1}m", secs / 60.0)
    } else {
        format!("{secs:.1}s")
    }
}

/// Simple fixed-width table printer for paper-style rows.
pub struct Table {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                if i < widths.len() {
                    widths[i] = widths[i].max(c.len());
                }
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("| {:width$} ", c, width = widths.get(i).copied().unwrap_or(4)));
            }
            s.push('|');
            s
        };
        println!("{}", line(&self.header));
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        println!("{}", line(&sep));
        for r in &self.rows {
            println!("{}", line(r));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_roundtrip() {
        let dir = std::env::temp_dir().join("msq_csv_test");
        let path = dir.join("t.csv");
        let mut c = Csv::create(&path, &["a", "b"]).unwrap();
        c.rowf(&[1.0, 2.5]).unwrap();
        c.flush().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n1,2.5\n");
    }

    #[test]
    fn duration_formats() {
        assert_eq!(fmt_duration(5.0), "5.0s");
        assert_eq!(fmt_duration(120.0), "2.0m");
        assert_eq!(fmt_duration(7200.0), "2.00h");
    }
}
