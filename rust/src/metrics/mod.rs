//! Metrics sinks (S13): CSV + JSONL writers and the run report.
//!
//! Every experiment writes machine-readable rows under `results/` so the
//! paper tables/figures regenerate from files, plus a human-readable
//! summary on stdout.
//!
//! The serving subsystem adds two streaming primitives: [`LatencyHist`]
//! (log-bucketed histogram answering p50/p95/p99 in O(1) memory) and
//! [`RateCounter`] (sliding-window event rate). Both are plain data —
//! `serve::ServeMetrics` wraps them in the locks it needs.
//!
//! The gateway scrapes everything through [`Prom`], a Prometheus
//! text-format (0.0.4) builder: `# TYPE` headers, label escaping, and
//! summary quantiles rendered from a [`LatencyHist`].

use std::fs::{self, File};
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Append-only CSV writer with a fixed header.
pub struct Csv {
    w: BufWriter<File>,
    cols: usize,
}

impl Csv {
    pub fn create(path: &Path, header: &[&str]) -> Result<Csv> {
        if let Some(dir) = path.parent() {
            fs::create_dir_all(dir)?;
        }
        let mut w = BufWriter::new(File::create(path).with_context(|| format!("{path:?}"))?);
        writeln!(w, "{}", header.join(","))?;
        Ok(Csv { w, cols: header.len() })
    }

    pub fn row(&mut self, values: &[String]) -> Result<()> {
        debug_assert_eq!(values.len(), self.cols);
        writeln!(self.w, "{}", values.join(","))?;
        Ok(())
    }

    pub fn rowf(&mut self, values: &[f64]) -> Result<()> {
        let s: Vec<String> = values.iter().map(|v| format!("{v}")).collect();
        self.row(&s)
    }

    pub fn flush(&mut self) -> Result<()> {
        self.w.flush()?;
        Ok(())
    }
}

/// Append-only JSONL writer.
pub struct Jsonl {
    w: BufWriter<File>,
}

impl Jsonl {
    pub fn create(path: &Path) -> Result<Jsonl> {
        if let Some(dir) = path.parent() {
            fs::create_dir_all(dir)?;
        }
        Ok(Jsonl { w: BufWriter::new(File::create(path).with_context(|| format!("{path:?}"))?) })
    }

    pub fn write(&mut self, v: &Json) -> Result<()> {
        writeln!(self.w, "{}", v.to_string())?;
        Ok(())
    }

    pub fn flush(&mut self) -> Result<()> {
        self.w.flush()?;
        Ok(())
    }
}

/// Results directory: `$MSQ_RESULTS` or `./results`.
pub fn results_dir() -> PathBuf {
    std::env::var("MSQ_RESULTS").map(PathBuf::from).unwrap_or_else(|_| "results".into())
}

/// Format seconds as h/m/s for table output.
pub fn fmt_duration(secs: f64) -> String {
    if secs >= 3600.0 {
        format!("{:.2}h", secs / 3600.0)
    } else if secs >= 60.0 {
        format!("{:.1}m", secs / 60.0)
    } else {
        format!("{secs:.1}s")
    }
}

/// Simple fixed-width table printer for paper-style rows.
pub struct Table {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                if i < widths.len() {
                    widths[i] = widths[i].max(c.len());
                }
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("| {:width$} ", c, width = widths.get(i).copied().unwrap_or(4)));
            }
            s.push('|');
            s
        };
        println!("{}", line(&self.header));
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        println!("{}", line(&sep));
        for r in &self.rows {
            println!("{}", line(r));
        }
    }
}

// ---------------------------------------------------------------------------
// Streaming percentiles + rates (serving metrics)
// ---------------------------------------------------------------------------

/// Number of log-spaced sub-buckets per octave (2^(1/4) ≈ 19% worst-case
/// relative error on a reported percentile — HDR-histogram style).
const HIST_SUB: f64 = 4.0;
/// Bucket 0 floor: 1 µs. 112 buckets * 1/4 octave ≈ 2^28 µs ≈ 268 s cap.
const HIST_BUCKETS: usize = 112;

/// Log-bucketed streaming histogram over positive durations (seconds).
///
/// `record` is O(1) and allocation-free; `percentile` walks the fixed
/// bucket array. Exact min/max are tracked so single-value and tail
/// queries clamp to observed data rather than bucket midpoints.
#[derive(Clone, Debug)]
pub struct LatencyHist {
    buckets: [u64; HIST_BUCKETS],
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for LatencyHist {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHist {
    pub fn new() -> Self {
        LatencyHist {
            buckets: [0; HIST_BUCKETS],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    fn bucket_of(seconds: f64) -> usize {
        let us = seconds * 1e6;
        if us <= 1.0 {
            return 0;
        }
        ((us.log2() * HIST_SUB) as usize).min(HIST_BUCKETS - 1)
    }

    /// Geometric representative value (seconds) of bucket `i`.
    fn bucket_value(i: usize) -> f64 {
        2f64.powf((i as f64 + 0.5) / HIST_SUB) * 1e-6
    }

    pub fn record(&mut self, seconds: f64) {
        if !seconds.is_finite() || seconds < 0.0 {
            return;
        }
        self.buckets[Self::bucket_of(seconds)] += 1;
        self.count += 1;
        self.sum += seconds;
        self.min = self.min.min(seconds);
        self.max = self.max.max(seconds);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    /// Sum of all recorded values (the Prometheus summary `_sum`).
    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    pub fn min(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Streaming percentile estimate (p in [0, 100]), seconds. Worst-case
    /// relative error is one sub-bucket (≈19%); exact for 0/1 samples and
    /// for p = 0 / p = 100 (tracked min/max).
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        if p <= 0.0 {
            return self.min;
        }
        if p >= 100.0 {
            return self.max;
        }
        let rank = ((p / 100.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &b) in self.buckets.iter().enumerate() {
            seen += b;
            if seen >= rank {
                return Self::bucket_value(i).clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// Fold another histogram into this one. Equivalent to having
    /// recorded every one of `other`'s observations here (buckets are
    /// aligned by construction): counts and sums add, min/max combine.
    /// The `INFINITY`/`NEG_INFINITY` empty-state sentinels make merging
    /// an empty histogram the identity in either direction, and the
    /// operation is associative — replica shards can be folded in any
    /// order (modulo float-addition rounding of `sum`).
    pub fn merge(&mut self, other: &LatencyHist) {
        for (b, o) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *b += o;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Sliding-window event-rate counter: events/sec averaged over the last
/// `window` whole seconds. Timestamps are caller-supplied monotonic
/// seconds (e.g. `Instant::elapsed().as_secs_f64()` from a fixed epoch),
/// which keeps the type deterministic under test.
#[derive(Clone, Debug)]
pub struct RateCounter {
    window: usize,
    /// (absolute second, count) — a slot is live iff its second is within
    /// the query window, so stale slots need no eager zeroing.
    slots: Vec<(u64, u64)>,
    total: u64,
    /// Second of the first event ever recorded (`u64::MAX` = none yet):
    /// early-life rates divide by the seconds actually elapsed, not the
    /// full window, so a warm-up scrape isn't silently deflated.
    first: u64,
}

impl RateCounter {
    pub fn new(window_secs: usize) -> Self {
        let window = window_secs.max(1);
        RateCounter { window, slots: vec![(u64::MAX, 0); window], total: 0, first: u64::MAX }
    }

    pub fn add(&mut self, t_secs: f64, n: u64) {
        let sec = t_secs.max(0.0) as u64;
        let slot = (sec as usize) % self.window;
        if self.slots[slot].0 != sec {
            self.slots[slot] = (sec, 0);
        }
        self.slots[slot].1 += n;
        self.total += n;
        self.first = self.first.min(sec);
    }

    /// Events/sec over the window ending at `t_secs` (inclusive second).
    ///
    /// The divisor is `min(window, seconds elapsed since the first
    /// event)`, so a counter queried before a full window has passed
    /// reports the true average over its lifetime instead of deflating
    /// the sum by the not-yet-elapsed tail of the window.
    pub fn rate(&self, t_secs: f64) -> f64 {
        let now = t_secs.max(0.0) as u64;
        let lo = (now + 1).saturating_sub(self.window as u64);
        let sum: u64 = self
            .slots
            .iter()
            .filter(|(s, _)| *s >= lo && *s <= now)
            .map(|(_, c)| c)
            .sum();
        let elapsed = if self.first == u64::MAX {
            self.window as u64
        } else {
            ((now + 1).saturating_sub(self.first)).clamp(1, self.window as u64)
        };
        sum as f64 / elapsed as f64
    }

    /// Lifetime event count (not windowed).
    pub fn total(&self) -> u64 {
        self.total
    }
}

// ---------------------------------------------------------------------------
// Prometheus text exposition (gateway /metrics)
// ---------------------------------------------------------------------------

/// Prometheus text-format (0.0.4) builder. One `Prom` renders one scrape:
/// declare each family once with [`Prom::family`], then emit samples.
///
/// ```ignore
/// let mut p = Prom::new();
/// p.family("msq_requests_total", "counter", "Requests admitted");
/// p.sample("msq_requests_total", &[("model", "mlp")], 42.0);
/// let body = p.finish(); // text/plain; version=0.0.4
/// ```
#[derive(Default)]
pub struct Prom {
    out: String,
}

impl Prom {
    pub fn new() -> Prom {
        Prom::default()
    }

    /// `# HELP` + `# TYPE` lines for a metric family. `kind` is one of
    /// `counter`, `gauge`, `summary`, `histogram`, `untyped`.
    pub fn family(&mut self, name: &str, kind: &str, help: &str) {
        self.out.push_str("# HELP ");
        self.out.push_str(name);
        self.out.push(' ');
        self.out.push_str(&help.replace('\\', "\\\\").replace('\n', "\\n"));
        self.out.push_str("\n# TYPE ");
        self.out.push_str(name);
        self.out.push(' ');
        self.out.push_str(kind);
        self.out.push('\n');
    }

    /// One sample line: `name{labels} value`.
    pub fn sample(&mut self, name: &str, labels: &[(&str, &str)], value: f64) {
        self.out.push_str(name);
        if !labels.is_empty() {
            self.out.push('{');
            for (i, (k, v)) in labels.iter().enumerate() {
                if i > 0 {
                    self.out.push(',');
                }
                self.out.push_str(k);
                self.out.push_str("=\"");
                self.out.push_str(&Self::escape_label(v));
                self.out.push('"');
            }
            self.out.push('}');
        }
        self.out.push(' ');
        self.out.push_str(&Self::fmt_value(value));
        self.out.push('\n');
    }

    /// Render a [`LatencyHist`] as a Prometheus *summary*: one
    /// `{quantile="…"}` sample per requested quantile plus the `_sum` and
    /// `_count` series, all in seconds.
    pub fn summary(
        &mut self,
        name: &str,
        labels: &[(&str, &str)],
        hist: &LatencyHist,
        quantiles: &[f64],
    ) {
        for &q in quantiles {
            let qs = Self::fmt_value(q);
            let mut ls: Vec<(&str, &str)> = labels.to_vec();
            ls.push(("quantile", &qs));
            self.sample(name, &ls, hist.percentile(q * 100.0));
        }
        self.sample(&format!("{name}_sum"), labels, hist.sum());
        self.sample(&format!("{name}_count"), labels, hist.count() as f64);
    }

    pub fn finish(self) -> String {
        self.out
    }

    fn escape_label(v: &str) -> String {
        v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
    }

    /// Prometheus floats: integral values render without a fraction,
    /// non-finite values by name.
    fn fmt_value(v: f64) -> String {
        if v.is_nan() {
            "NaN".into()
        } else if v.is_infinite() {
            if v > 0.0 { "+Inf".into() } else { "-Inf".into() }
        } else if v == v.trunc() && v.abs() < 1e15 {
            format!("{}", v as i64)
        } else {
            format!("{v}")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_roundtrip() {
        let dir = std::env::temp_dir().join("msq_csv_test");
        let path = dir.join("t.csv");
        let mut c = Csv::create(&path, &["a", "b"]).unwrap();
        c.rowf(&[1.0, 2.5]).unwrap();
        c.flush().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n1,2.5\n");
    }

    #[test]
    fn duration_formats() {
        assert_eq!(fmt_duration(5.0), "5.0s");
        assert_eq!(fmt_duration(120.0), "2.0m");
        assert_eq!(fmt_duration(7200.0), "2.00h");
    }

    #[test]
    fn latency_hist_empty_and_single() {
        let mut h = LatencyHist::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.percentile(50.0), 0.0);
        h.record(0.025);
        // one sample: every percentile clamps to the exact observation
        assert_eq!(h.percentile(1.0), 0.025);
        assert_eq!(h.percentile(50.0), 0.025);
        assert_eq!(h.percentile(99.0), 0.025);
        assert_eq!(h.count(), 1);
    }

    #[test]
    fn latency_hist_percentiles_within_resolution() {
        let mut h = LatencyHist::new();
        // uniform 1..=1000 ms
        for i in 1..=1000 {
            h.record(i as f64 * 1e-3);
        }
        let p50 = h.percentile(50.0);
        let p95 = h.percentile(95.0);
        let p99 = h.percentile(99.0);
        assert!((p50 - 0.5).abs() / 0.5 < 0.25, "p50 {p50}");
        assert!((p95 - 0.95).abs() / 0.95 < 0.25, "p95 {p95}");
        assert!((p99 - 0.99).abs() / 0.99 < 0.25, "p99 {p99}");
        assert!(p50 <= p95 && p95 <= p99, "{p50} {p95} {p99}");
        assert_eq!(h.percentile(100.0), 1.0); // exact max
        assert!((h.mean() - 0.5005).abs() < 1e-9);
    }

    #[test]
    fn latency_hist_merge_empty_is_identity_both_ways() {
        let mut a = LatencyHist::new();
        a.record(0.25);
        a.record(0.5);
        let before = (a.count(), a.sum(), a.min(), a.max(), a.percentile(50.0));
        a.merge(&LatencyHist::new());
        assert_eq!((a.count(), a.sum(), a.min(), a.max(), a.percentile(50.0)), before);
        // merging into an empty histogram reproduces the source exactly
        let mut e = LatencyHist::new();
        e.merge(&a);
        assert_eq!((e.count(), e.sum(), e.min(), e.max(), e.percentile(50.0)), before);
        assert_eq!(e.percentile(99.0), a.percentile(99.0));
        // two empties stay empty (the sentinel min/max never leak out)
        let mut z = LatencyHist::new();
        z.merge(&LatencyHist::new());
        assert_eq!(z.count(), 0);
        assert_eq!(z.min(), 0.0);
        assert_eq!(z.max(), 0.0);
        assert_eq!(z.percentile(50.0), 0.0);
    }

    #[test]
    fn latency_hist_merge_is_associative() {
        // binary-exact values (multiples of 2^-10) so the float sums
        // compare with == regardless of fold order
        let mk = |vals: &[f64]| {
            let mut h = LatencyHist::new();
            for &v in vals {
                h.record(v);
            }
            h
        };
        let a = mk(&[0.25, 0.0009765625]);
        let b = mk(&[0.5]);
        let c = mk(&[0.125, 2.0, 0.03125]);
        // (a ∪ b) ∪ c
        let mut left = a.clone();
        left.merge(&b);
        left.merge(&c);
        // a ∪ (b ∪ c)
        let mut bc = b.clone();
        bc.merge(&c);
        let mut right = a.clone();
        right.merge(&bc);
        assert_eq!(left.count(), right.count());
        assert_eq!(left.sum(), right.sum());
        assert_eq!(left.min(), right.min());
        assert_eq!(left.max(), right.max());
        for p in [0.0, 25.0, 50.0, 95.0, 100.0] {
            assert_eq!(left.percentile(p), right.percentile(p), "p{p}");
        }
        // and the merged view equals recording everything in one pass
        let all = mk(&[0.25, 0.0009765625, 0.5, 0.125, 2.0, 0.03125]);
        assert_eq!(left.count(), all.count());
        assert_eq!(left.percentile(50.0), all.percentile(50.0));
    }

    #[test]
    fn latency_hist_ignores_garbage() {
        let mut h = LatencyHist::new();
        h.record(f64::NAN);
        h.record(-1.0);
        h.record(f64::INFINITY);
        assert_eq!(h.count(), 0);
        h.record(1e9); // clamps into the last bucket, still counted
        assert_eq!(h.count(), 1);
        assert_eq!(h.percentile(50.0), 1e9);
    }

    #[test]
    fn prom_renders_families_and_samples() {
        let mut p = Prom::new();
        p.family("msq_up", "gauge", "Is the gateway up");
        p.sample("msq_up", &[], 1.0);
        p.family("msq_http_requests_total", "counter", "HTTP responses by code");
        p.sample("msq_http_requests_total", &[("code", "200"), ("model", "a\"b")], 12.0);
        let text = p.finish();
        assert!(text.contains("# TYPE msq_up gauge\n"), "{text}");
        assert!(text.contains("msq_up 1\n"), "{text}");
        assert!(
            text.contains("msq_http_requests_total{code=\"200\",model=\"a\\\"b\"} 12\n"),
            "{text}"
        );
    }

    #[test]
    fn prom_summary_from_latency_hist() {
        let mut h = LatencyHist::new();
        for _ in 0..4 {
            h.record(0.25); // binary-exact: the _sum renders as exactly 1
        }
        let mut p = Prom::new();
        p.family("msq_latency_seconds", "summary", "Request latency");
        p.summary("msq_latency_seconds", &[("model", "m")], &h, &[0.5, 0.99]);
        let text = p.finish();
        let q50 = "msq_latency_seconds{model=\"m\",quantile=\"0.5\"} 0.25\n";
        let q99 = "msq_latency_seconds{model=\"m\",quantile=\"0.99\"} 0.25\n";
        assert!(text.contains(q50), "{text}");
        assert!(text.contains(q99), "{text}");
        assert!(text.contains("msq_latency_seconds_count{model=\"m\"} 4\n"), "{text}");
        assert!(text.contains("msq_latency_seconds_sum{model=\"m\"} 1\n"), "{text}");
    }

    #[test]
    fn prom_value_formatting() {
        assert_eq!(Prom::fmt_value(3.0), "3");
        assert_eq!(Prom::fmt_value(0.5), "0.5");
        assert_eq!(Prom::fmt_value(f64::INFINITY), "+Inf");
        assert_eq!(Prom::fmt_value(f64::NAN), "NaN");
    }

    #[test]
    fn rate_counter_window() {
        let mut r = RateCounter::new(10);
        for s in 0..10 {
            r.add(s as f64 + 0.5, 5); // 5 events/sec for 10 s
        }
        assert_eq!(r.total(), 50);
        assert!((r.rate(9.5) - 5.0).abs() < 1e-9);
        // 5 seconds idle: half the window has aged out
        assert!((r.rate(14.5) - 2.5).abs() < 1e-9);
        // far future: everything aged out
        assert_eq!(r.rate(1000.0), 0.0);
    }

    #[test]
    fn rate_counter_cold_start_uses_elapsed_seconds() {
        // regression: a counter younger than its window used to divide
        // by the full window, deflating warm-up rates — 5 events in the
        // first second of a 10 s window reported 0.5/s instead of 5/s.
        let mut r = RateCounter::new(10);
        r.add(0.2, 5);
        assert!((r.rate(0.9) - 5.0).abs() < 1e-9, "t=0: {}", r.rate(0.9));
        for s in 1..10 {
            r.add(s as f64 + 0.5, 5);
            // constant 5/s load must read 5/s at every age t=1..window
            let got = r.rate(s as f64 + 0.9);
            assert!((got - 5.0).abs() < 1e-9, "t={s}: {got}");
        }
        // beyond the first full window the divisor clamps at `window`
        assert!((r.rate(9.5) - 5.0).abs() < 1e-9);
        assert!((r.rate(14.5) - 2.5).abs() < 1e-9);
        // empty counter stays 0 without dividing by zero
        assert_eq!(RateCounter::new(10).rate(0.0), 0.0);
    }

    #[test]
    fn rate_counter_slot_reuse() {
        let mut r = RateCounter::new(2);
        r.add(0.0, 3);
        r.add(2.0, 4); // same slot as t=0 (2 % 2 == 0), must overwrite
        assert!((r.rate(2.9) - 2.0).abs() < 1e-9); // only the 4 in window, /2
        assert_eq!(r.total(), 7);
    }
}
