//! Micro-bench harness (criterion is unavailable offline).
//!
//! `cargo bench` targets use `harness = false` and call [`bench`] /
//! [`bench_n`]: warmup, timed iterations, mean / p50 / p95 / throughput
//! reporting, and a CSV row under `results/bench/` for regression diffing.

use std::time::Instant;

use crate::util::stats::percentile;

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
}

impl BenchResult {
    pub fn report(&self, unit_per_iter: Option<(f64, &str)>) {
        let thr = unit_per_iter
            .map(|(n, u)| format!("  {:>10.1} {u}/s", n / self.mean_s))
            .unwrap_or_default();
        println!(
            "{:<44} {:>10.3} ms/iter  (p50 {:.3}, p95 {:.3}, min {:.3}){}",
            self.name,
            self.mean_s * 1e3,
            self.p50_s * 1e3,
            self.p95_s * 1e3,
            self.min_s * 1e3,
            thr
        );
    }

    pub fn csv_row(&self) -> String {
        format!(
            "{},{},{:.6e},{:.6e},{:.6e}",
            self.name, self.iters, self.mean_s, self.p50_s, self.p95_s
        )
    }
}

/// Run `f` for `warmup` + `iters` timed iterations.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        times.push(t.elapsed().as_secs_f64());
    }
    let mean = times.iter().sum::<f64>() / times.len().max(1) as f64;
    BenchResult {
        name: name.to_string(),
        iters,
        mean_s: mean,
        p50_s: percentile(&times, 50.0),
        p95_s: percentile(&times, 95.0),
        min_s: times.iter().copied().fold(f64::INFINITY, f64::min),
    }
}

/// Write a set of results to `results/bench/<file>.csv`.
pub fn save(file: &str, results: &[BenchResult]) {
    let dir = crate::metrics::results_dir().join("bench");
    let _ = std::fs::create_dir_all(&dir);
    let mut s = String::from("name,iters,mean_s,p50_s,p95_s\n");
    for r in results {
        s.push_str(&r.csv_row());
        s.push('\n');
    }
    let _ = std::fs::write(dir.join(file), s);
}
