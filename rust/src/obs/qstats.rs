//! qstats — quantization-health activation observers for the serving
//! kernels (the numeric twin of the kernel [`super::Profiler`]).
//!
//! The profiler answers "where does the time go"; this module answers
//! "what do the *numbers* look like while a quantized model serves
//! traffic": per-layer activation ranges (running min/max), an EMA of
//! the per-batch absolute maximum (the calibration statistic an
//! integer-domain pipeline would consume), a log-bucketed magnitude
//! histogram, and weight-code saturation counters (codes sitting on the
//! RoundClamp lattice endpoints, i.e. values the clamp flattened).
//!
//! The design mirrors the profiler's zero-cost-when-off contract:
//!
//! * **Disabled** (default): each kernel call pays one relaxed
//!   `AtomicBool` load and a branch — no clocks, no allocation, no
//!   per-element work (pinned by `tests/qstats_alloc.rs` and the
//!   `serve_throughput` bench's qstats section).
//! * **Enabled** (`msq gateway --qstats[=RATE]`): kernels fold
//!   observations into a stack-local [`LocalObs`] and merge it into the
//!   shared scratch [`Observer`] once per call / per work block, so
//!   atomic traffic stays per-block, not per-element. Sampling
//!   (`RATE < 1`) deterministically observes every Nth kernel call.
//!
//! Observation never changes arithmetic — the {serial, pooled} ×
//! {scalar, simd} bit-exactness invariant holds with qstats on.
//!
//! Per-layer attribution works like the profiler's: kernels write into
//! one global scratch observer, and `ServableModel::infer_batch` drains
//! it after each layer forward into a named [`LayerStats`] keyed
//! `"model/NN:layer"`. Exact for a single-model gateway; best-effort
//! when several models infer concurrently (the process-wide totals stay
//! exact either way).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, OnceLock, RwLock};

use crate::metrics::Prom;
use crate::util::json::Json;

/// Log-magnitude histogram buckets: one per group of four consecutive
/// binary exponents. Bucket `b` covers `|v| ∈ [2^(4b−127), 2^(4b−123))`;
/// bucket 0 also holds zeros and subnormals, bucket 63 holds infinities
/// and NaNs.
pub const BUCKETS: usize = 64;

/// EMA smoothing for the per-layer absmax statistic: one update per
/// observed batch, `ema ← (1−λ)·ema + λ·absmax`.
pub const EMA_LAMBDA: f32 = 0.1;

/// Sentinel bit pattern for "EMA not seeded yet" (an all-ones NaN no
/// finite absmax can produce — non-finite batch maxima are dropped).
const EMA_UNSET: u32 = u32::MAX;

/// Histogram bucket of a value: the top six bits of the biased f32
/// exponent (`|v|`'s exponent divided by four). Branch-free and exact.
#[inline]
pub fn bucket_of(v: f32) -> usize {
    (((v.to_bits() & 0x7fff_ffff) >> 25) & 0x3f) as usize
}

// ---------------------------------------------------------------------------
// stack-local fold

/// Stack-local observation accumulator: kernels fold every element here
/// (plain scalar work, no atomics) and merge into a shared [`Observer`]
/// once per call, keeping the contended traffic O(blocks) not O(elems).
#[derive(Clone, Debug)]
pub struct LocalObs {
    pub min: f32,
    pub max: f32,
    pub count: u64,
    pub buckets: [u64; BUCKETS],
}

impl Default for LocalObs {
    fn default() -> Self {
        LocalObs::new()
    }
}

impl LocalObs {
    pub fn new() -> LocalObs {
        LocalObs {
            min: f32::INFINITY,
            max: f32::NEG_INFINITY,
            count: 0,
            buckets: [0; BUCKETS],
        }
    }

    /// Fold one value. NaNs never become the min/max (comparisons are
    /// false) but still count and land in the top bucket, so poisoned
    /// activations remain visible in the histogram.
    #[inline]
    pub fn observe(&mut self, v: f32) {
        if v < self.min {
            self.min = v;
        }
        if v > self.max {
            self.max = v;
        }
        self.count += 1;
        self.buckets[bucket_of(v)] += 1;
    }

    pub fn observe_slice(&mut self, xs: &[f32]) {
        for &v in xs {
            self.observe(v);
        }
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }
}

// ---------------------------------------------------------------------------
// shared observer

/// Lock-free shared observer: running min/max (f32 bit-CAS), an element
/// count, endpoint-saturation counters, and the bucketed magnitude
/// histogram — all relaxed atomics, mergeable from any number of pool
/// workers without locks.
pub struct Observer {
    min_bits: AtomicU32,
    max_bits: AtomicU32,
    count: AtomicU64,
    sat_low: AtomicU64,
    sat_high: AtomicU64,
    buckets: [AtomicU64; BUCKETS],
}

impl Default for Observer {
    fn default() -> Self {
        Observer::new()
    }
}

/// Point-in-time copy of an [`Observer`] (also what [`Observer::take`]
/// drains into).
#[derive(Clone, Debug)]
pub struct ObsSnapshot {
    /// Smallest observed value (`+∞` when nothing was observed).
    pub min: f32,
    /// Largest observed value (`−∞` when nothing was observed).
    pub max: f32,
    pub count: u64,
    pub sat_low: u64,
    pub sat_high: u64,
    pub buckets: [u64; BUCKETS],
}

impl ObsSnapshot {
    pub fn is_empty(&self) -> bool {
        self.count == 0 && self.sat_low == 0 && self.sat_high == 0
    }

    /// Largest observed magnitude; 0 when nothing was observed.
    pub fn absmax(&self) -> f32 {
        if self.count == 0 {
            return 0.0;
        }
        self.min.abs().max(self.max.abs())
    }

    /// JSON view shared by `/debug/stats` and `/debug/model/{name}`:
    /// range, counts, and the nonzero histogram buckets as
    /// `[bucket, count]` pairs (64 mostly-zero entries would bloat every
    /// dump).
    pub fn to_json(&self) -> Json {
        let hist: Vec<Json> = self
            .buckets
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(b, &c)| Json::Arr(vec![Json::Num(b as f64), Json::Num(c as f64)]))
            .collect();
        Json::obj(vec![
            ("count", Json::Num(self.count as f64)),
            ("min", Json::Num(if self.count > 0 { self.min as f64 } else { 0.0 })),
            ("max", Json::Num(if self.count > 0 { self.max as f64 } else { 0.0 })),
            ("absmax", Json::Num(self.absmax() as f64)),
            ("sat_low", Json::Num(self.sat_low as f64)),
            ("sat_high", Json::Num(self.sat_high as f64)),
            ("hist", Json::Arr(hist)),
        ])
    }
}

impl Observer {
    pub fn new() -> Observer {
        Observer {
            min_bits: AtomicU32::new(f32::INFINITY.to_bits()),
            max_bits: AtomicU32::new(f32::NEG_INFINITY.to_bits()),
            count: AtomicU64::new(0),
            sat_low: AtomicU64::new(0),
            sat_high: AtomicU64::new(0),
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }

    /// Merge a stack-local fold: one CAS loop each for min/max, one add
    /// per touched bucket — the per-block cost the kernels pay.
    pub fn merge(&self, local: &LocalObs) {
        if local.count == 0 {
            return;
        }
        self.update_min(local.min);
        self.update_max(local.max);
        self.count.fetch_add(local.count, Ordering::Relaxed);
        for (slot, &c) in self.buckets.iter().zip(local.buckets.iter()) {
            if c > 0 {
                slot.fetch_add(c, Ordering::Relaxed);
            }
        }
    }

    /// Merge a drained snapshot (per-layer attribution path).
    pub fn merge_snapshot(&self, s: &ObsSnapshot) {
        if s.count > 0 {
            self.update_min(s.min);
            self.update_max(s.max);
            self.count.fetch_add(s.count, Ordering::Relaxed);
            for (slot, &c) in self.buckets.iter().zip(s.buckets.iter()) {
                if c > 0 {
                    slot.fetch_add(c, Ordering::Relaxed);
                }
            }
        }
        if s.sat_low > 0 {
            self.sat_low.fetch_add(s.sat_low, Ordering::Relaxed);
        }
        if s.sat_high > 0 {
            self.sat_high.fetch_add(s.sat_high, Ordering::Relaxed);
        }
    }

    /// Count codes that sat on the lattice endpoints (clamped weights).
    pub fn add_saturation(&self, low: u64, high: u64) {
        if low > 0 {
            self.sat_low.fetch_add(low, Ordering::Relaxed);
        }
        if high > 0 {
            self.sat_high.fetch_add(high, Ordering::Relaxed);
        }
    }

    fn update_min(&self, v: f32) {
        let _ = self.min_bits.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |cur| {
            (v < f32::from_bits(cur)).then(|| v.to_bits())
        });
    }

    fn update_max(&self, v: f32) {
        let _ = self.max_bits.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |cur| {
            (v > f32::from_bits(cur)).then(|| v.to_bits())
        });
    }

    /// Non-destructive copy.
    pub fn snapshot(&self) -> ObsSnapshot {
        ObsSnapshot {
            min: f32::from_bits(self.min_bits.load(Ordering::Relaxed)),
            max: f32::from_bits(self.max_bits.load(Ordering::Relaxed)),
            count: self.count.load(Ordering::Relaxed),
            sat_low: self.sat_low.load(Ordering::Relaxed),
            sat_high: self.sat_high.load(Ordering::Relaxed),
            buckets: std::array::from_fn(|b| self.buckets[b].load(Ordering::Relaxed)),
        }
    }

    /// Drain: swap every field back to its identity and return what was
    /// there. Concurrent merges are never lost (each merge lands either
    /// in the taken snapshot or in the reset observer), though a merge
    /// racing the swap can straddle the two — per-layer attribution is
    /// best-effort under concurrency, exact single-threaded.
    pub fn take(&self) -> ObsSnapshot {
        ObsSnapshot {
            min: f32::from_bits(
                self.min_bits.swap(f32::INFINITY.to_bits(), Ordering::Relaxed),
            ),
            max: f32::from_bits(
                self.max_bits.swap(f32::NEG_INFINITY.to_bits(), Ordering::Relaxed),
            ),
            count: self.count.swap(0, Ordering::Relaxed),
            sat_low: self.sat_low.swap(0, Ordering::Relaxed),
            sat_high: self.sat_high.swap(0, Ordering::Relaxed),
            buckets: std::array::from_fn(|b| self.buckets[b].swap(0, Ordering::Relaxed)),
        }
    }
}

// ---------------------------------------------------------------------------
// per-layer stats

/// One named layer's cumulative observations plus the EMA absmax
/// calibration statistic (seeded by the first observed batch).
pub struct LayerStats {
    pub obs: Observer,
    ema_bits: AtomicU32,
    batches: AtomicU64,
}

impl Default for LayerStats {
    fn default() -> Self {
        LayerStats {
            obs: Observer::new(),
            ema_bits: AtomicU32::new(EMA_UNSET),
            batches: AtomicU64::new(0),
        }
    }
}

impl LayerStats {
    /// Fold one drained batch snapshot into the cumulative observer and
    /// advance the EMA by its absmax.
    pub fn absorb(&self, s: &ObsSnapshot) {
        self.obs.merge_snapshot(s);
        if s.count == 0 {
            return;
        }
        let absmax = s.absmax();
        if !absmax.is_finite() {
            return;
        }
        self.batches.fetch_add(1, Ordering::Relaxed);
        let _ = self.ema_bits.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |cur| {
            let next = if cur == EMA_UNSET {
                absmax
            } else {
                (1.0 - EMA_LAMBDA) * f32::from_bits(cur) + EMA_LAMBDA * absmax
            };
            Some(next.to_bits())
        });
    }

    /// EMA of the per-batch absolute maximum; `None` before the first
    /// observed batch.
    pub fn ema_absmax(&self) -> Option<f32> {
        match self.ema_bits.load(Ordering::Relaxed) {
            EMA_UNSET => None,
            bits => Some(f32::from_bits(bits)),
        }
    }

    pub fn batches(&self) -> u64 {
        self.batches.load(Ordering::Relaxed)
    }

    fn reset(&self) {
        let _ = self.obs.take();
        self.ema_bits.store(EMA_UNSET, Ordering::Relaxed);
        self.batches.store(0, Ordering::Relaxed);
    }

    pub fn to_json(&self) -> Json {
        let mut j = match self.obs.snapshot().to_json() {
            Json::Obj(m) => m,
            _ => unreachable!("snapshot json is an object"),
        };
        j.insert(
            "absmax_ema".into(),
            self.ema_absmax().map(|v| Json::Num(v as f64)).unwrap_or(Json::Null),
        );
        j.insert("batches".into(), Json::Num(self.batches() as f64));
        Json::Obj(j)
    }
}

// ---------------------------------------------------------------------------
// the process-wide switchboard

/// Process-global activation-observer state: the enable flag + sampling
/// stride the kernels check, the scratch observer they merge into, and
/// the named per-layer table `infer_batch` attributes the scratch to.
pub struct QStats {
    enabled: AtomicBool,
    /// Observe one kernel call in `every` (1 = all).
    every: AtomicU64,
    seq: AtomicU64,
    scratch: Observer,
    layers: RwLock<BTreeMap<String, Arc<LayerStats>>>,
}

impl Default for QStats {
    fn default() -> Self {
        QStats {
            enabled: AtomicBool::new(false),
            every: AtomicU64::new(1),
            seq: AtomicU64::new(0),
            scratch: Observer::new(),
            layers: RwLock::new(BTreeMap::new()),
        }
    }
}

impl QStats {
    pub fn enable(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    /// The disabled-path guard: one relaxed load, nothing else.
    #[inline]
    pub fn on(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Set the sampling rate in `(0, 1]`: rate 1 observes every kernel
    /// call, rate `r` observes one call in `round(1/r)` (deterministic
    /// stride, so sampled statistics are reproducible under serial
    /// execution).
    pub fn set_rate(&self, rate: f32) {
        let every = if rate >= 1.0 {
            1
        } else if rate > 0.0 {
            (1.0 / rate as f64).round().max(1.0) as u64
        } else {
            u64::MAX
        };
        self.every.store(every, Ordering::Relaxed);
    }

    pub fn sample_every(&self) -> u64 {
        self.every.load(Ordering::Relaxed)
    }

    /// Per-kernel-call gate: enabled AND this call is on the sampling
    /// stride. Kernels call this once and reuse the bool for both the
    /// input observation and the per-block saturation count.
    #[inline]
    pub fn sample(&self) -> bool {
        if !self.on() {
            return false;
        }
        let every = self.every.load(Ordering::Relaxed);
        every <= 1 || self.seq.fetch_add(1, Ordering::Relaxed) % every == 0
    }

    /// Fold a kernel input into the scratch observer (one merge).
    pub fn observe_input(&self, x: &[f32]) {
        let mut local = LocalObs::new();
        local.observe_slice(x);
        self.scratch.merge(&local);
    }

    /// Count weight codes that decoded to a lattice endpoint.
    pub fn add_saturation(&self, low: u64, high: u64) {
        self.scratch.add_saturation(low, high);
    }

    /// Drain the scratch observer and attribute it to `key`
    /// (`"model/NN:layer"`). No-op when nothing was observed since the
    /// last drain, so layers whose kernels did not sample cost one swap.
    pub fn attribute(&self, key: &str) {
        let snap = self.scratch.take();
        if snap.is_empty() {
            return;
        }
        let layer = self.layer(key);
        layer.absorb(&snap);
    }

    /// Get-or-create the named layer entry.
    pub fn layer(&self, key: &str) -> Arc<LayerStats> {
        if let Some(l) = self.layers.read().unwrap().get(key) {
            return l.clone();
        }
        let mut w = self.layers.write().unwrap();
        w.entry(key.to_string()).or_default().clone()
    }

    /// Largest observed magnitude per layer key under `prefix`, for
    /// layers that saw at least one value — the reload drift baseline.
    pub fn absmax_by_prefix(&self, prefix: &str) -> BTreeMap<String, f32> {
        let layers = self.layers.read().unwrap();
        let mut out = BTreeMap::new();
        for (k, l) in layers.range(prefix.to_string()..) {
            if !k.starts_with(prefix) {
                break;
            }
            let s = l.obs.snapshot();
            if s.count > 0 {
                out.insert(k.clone(), s.absmax());
            }
        }
        out
    }

    /// Reset every layer observer under `prefix` (post-reload: the new
    /// generation accumulates fresh ranges against the drift baseline).
    pub fn reset_prefix(&self, prefix: &str) {
        let layers = self.layers.read().unwrap();
        for (k, l) in layers.range(prefix.to_string()..) {
            if !k.starts_with(prefix) {
                break;
            }
            l.reset();
        }
    }

    /// Drop all state (tests and benches; not used by serving).
    pub fn reset_all(&self) {
        self.layers.write().unwrap().clear();
        let _ = self.scratch.take();
        self.seq.store(0, Ordering::Relaxed);
    }

    /// Per-layer JSON table for keys under `prefix` (`""` = all).
    pub fn layers_json(&self, prefix: &str) -> Json {
        let layers = self.layers.read().unwrap();
        let mut out = BTreeMap::new();
        for (k, l) in layers.iter() {
            if k.starts_with(prefix) {
                out.insert(k.clone(), l.to_json());
            }
        }
        Json::Obj(out)
    }

    /// The `/debug/stats` `"qstats"` section.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("enabled", Json::Bool(self.on())),
            ("sample_every", Json::Num(self.sample_every() as f64)),
            ("layers", self.layers_json("")),
        ])
    }

    /// Render the per-layer activation series onto `/metrics`. Layer
    /// cardinality is bounded by the loaded models' depth, so unlike the
    /// profiler's per-layer *timing* table these do fit a scrape page.
    pub fn render(&self, p: &mut Prom) {
        p.family("msq_qstats_enabled", "gauge", "1 when activation observers are on");
        p.sample("msq_qstats_enabled", &[], if self.on() { 1.0 } else { 0.0 });
        let layers = self.layers.read().unwrap();
        let rows: Vec<(String, ObsSnapshot, Option<f32>)> = layers
            .iter()
            .map(|(k, l)| (k.clone(), l.obs.snapshot(), l.ema_absmax()))
            .collect();
        drop(layers);
        p.family(
            "msq_layer_act_range",
            "gauge",
            "Observed activation range per layer (bound=min|max)",
        );
        for (k, s, _) in rows.iter().filter(|(_, s, _)| s.count > 0) {
            let l = [("layer", k.as_str()), ("bound", "min")];
            p.sample("msq_layer_act_range", &l, s.min as f64);
            let l = [("layer", k.as_str()), ("bound", "max")];
            p.sample("msq_layer_act_range", &l, s.max as f64);
        }
        p.family(
            "msq_layer_act_absmax_ema",
            "gauge",
            "EMA of the per-batch activation absolute maximum",
        );
        for (k, _, ema) in rows.iter() {
            if let Some(e) = ema {
                p.sample("msq_layer_act_absmax_ema", &[("layer", k.as_str())], *e as f64);
            }
        }
        p.family(
            "msq_layer_act_observations_total",
            "counter",
            "Activation elements folded into each layer observer",
        );
        for (k, s, _) in rows.iter() {
            p.sample("msq_layer_act_observations_total", &[("layer", k.as_str())], s.count as f64);
        }
        p.family(
            "msq_layer_weight_saturation_total",
            "counter",
            "Decoded weight codes observed on a RoundClamp lattice endpoint",
        );
        for (k, s, _) in rows.iter() {
            p.sample(
                "msq_layer_weight_saturation_total",
                &[("layer", k.as_str())],
                (s.sat_low + s.sat_high) as f64,
            );
        }
    }
}

/// The process-wide activation observer switchboard (off by default).
pub fn qstats() -> &'static QStats {
    static QS: OnceLock<QStats> = OnceLock::new();
    QS.get_or_init(QStats::default)
}

/// Serializes tests that flip the global [`qstats`] switch. Production
/// code never calls this; without it, parallel unit tests that enable
/// and disable the singleton would race each other's assertions.
#[doc(hidden)]
pub fn test_mutex() -> std::sync::MutexGuard<'static, ()> {
    static M: std::sync::Mutex<()> = std::sync::Mutex::new(());
    M.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fold(xs: &[f32]) -> LocalObs {
        let mut l = LocalObs::new();
        l.observe_slice(xs);
        l
    }

    #[test]
    fn bucket_mapping_tracks_exponent_quads() {
        // bucket = biased exponent / 4, exactly
        for (v, want) in [
            (0.0f32, 0usize),
            (f32::MIN_POSITIVE / 2.0, 0), // subnormal
            (1.0, 31),                    // exponent 127
            (-1.0, 31),                   // sign is ignored
            (16.0, 32),                   // exponent 131
            (f32::MAX, 63),
            (f32::INFINITY, 63),
            (f32::NAN, 63),
        ] {
            assert_eq!(bucket_of(v), want, "bucket_of({v})");
        }
        // exhaustive vs the arithmetic definition over magnitudes
        for e in 0..=60 {
            let v = 2f32.powi(e - 30);
            let exp = ((v.to_bits() >> 23) & 0xff) as usize;
            assert_eq!(bucket_of(v), exp / 4, "v = {v}");
        }
    }

    #[test]
    fn observer_merge_is_associative_across_groupings() {
        // folding the same stream in different block groupings must
        // produce identical shared state — the pool-worker contract
        let xs: Vec<f32> = (0..1000).map(|i| ((i * 37 % 211) as f32 - 100.0) * 0.3).collect();
        let grouped = |chunks: usize| -> ObsSnapshot {
            let o = Observer::new();
            for c in xs.chunks(xs.len().div_ceil(chunks)) {
                o.merge(&fold(c));
            }
            o.snapshot()
        };
        let a = grouped(1);
        for chunks in [2, 3, 7, 1000] {
            let b = grouped(chunks);
            assert_eq!(a.min.to_bits(), b.min.to_bits(), "{chunks} chunks");
            assert_eq!(a.max.to_bits(), b.max.to_bits(), "{chunks} chunks");
            assert_eq!(a.count, b.count, "{chunks} chunks");
            assert_eq!(a.buckets, b.buckets, "{chunks} chunks");
        }
    }

    #[test]
    fn concurrent_merges_are_lossless() {
        const THREADS: usize = 8;
        const PER: usize = 500;
        let o = Observer::new();
        std::thread::scope(|s| {
            for t in 0..THREADS {
                let o = &o;
                s.spawn(move || {
                    for i in 0..PER {
                        let v = (t * PER + i) as f32 * 0.01 - 10.0;
                        o.merge(&fold(&[v]));
                        o.add_saturation(1, 2);
                    }
                });
            }
        });
        let s = o.snapshot();
        assert_eq!(s.count, (THREADS * PER) as u64);
        assert_eq!(s.buckets.iter().sum::<u64>(), (THREADS * PER) as u64);
        assert_eq!(s.sat_low, (THREADS * PER) as u64);
        assert_eq!(s.sat_high, 2 * (THREADS * PER) as u64);
        assert_eq!(s.min, -10.0);
        assert_eq!(s.max, (THREADS * PER - 1) as f32 * 0.01 - 10.0);
    }

    #[test]
    fn take_drains_to_identity_and_loses_nothing() {
        let o = Observer::new();
        o.merge(&fold(&[1.0, -2.0, 3.0]));
        o.add_saturation(4, 5);
        let s = o.take();
        assert_eq!((s.count, s.sat_low, s.sat_high), (3, 4, 5));
        assert_eq!((s.min, s.max), (-2.0, 3.0));
        assert_eq!(s.absmax(), 3.0);
        let empty = o.take();
        assert!(empty.is_empty());
        assert_eq!(empty.absmax(), 0.0);
        // a drained snapshot re-merges exactly
        o.merge_snapshot(&s);
        let back = o.snapshot();
        assert_eq!((back.count, back.sat_low, back.sat_high), (3, 4, 5));
        assert_eq!((back.min, back.max), (-2.0, 3.0));
    }

    #[test]
    fn ema_seeds_then_converges_toward_stationary_absmax() {
        let l = LayerStats::default();
        assert!(l.ema_absmax().is_none());
        let batch = |v: f32| {
            let o = Observer::new();
            o.merge(&fold(&[v, -v / 2.0]));
            l.absorb(&o.take());
        };
        batch(4.0);
        assert_eq!(l.ema_absmax(), Some(4.0), "first batch seeds the EMA");
        for _ in 0..200 {
            batch(1.0);
        }
        let ema = l.ema_absmax().unwrap();
        assert!((ema - 1.0).abs() < 1e-3, "EMA {ema} should approach 1.0");
        assert_eq!(l.batches(), 201);
    }

    #[test]
    fn sampling_stride_observes_one_call_in_n() {
        let qs = QStats::default();
        qs.enable(true);
        qs.set_rate(0.25);
        assert_eq!(qs.sample_every(), 4);
        let hits = (0..100).filter(|_| qs.sample()).count();
        assert_eq!(hits, 25, "deterministic 1-in-4 stride");
        qs.set_rate(1.0);
        assert_eq!(qs.sample_every(), 1);
        assert!((0..10).all(|_| qs.sample()));
        qs.enable(false);
        assert!(!qs.sample());
    }

    #[test]
    fn sampled_stats_agree_with_full_within_bounds() {
        // the sampled stream is a subset: min/max within the full range,
        // count exactly count/ every (deterministic stride), absmax ≤ full
        let xs: Vec<f32> = (0..4000).map(|i| ((i * 73 % 997) as f32 - 500.0) * 0.01).collect();
        let full = QStats::default();
        full.enable(true);
        full.set_rate(1.0);
        let sampled = QStats::default();
        sampled.enable(true);
        sampled.set_rate(0.5);
        for chunk in xs.chunks(40) {
            if full.sample() {
                full.observe_input(chunk);
            }
            if sampled.sample() {
                sampled.observe_input(chunk);
            }
        }
        full.attribute("m/00:l");
        sampled.attribute("m/00:l");
        let f = full.layer("m/00:l").obs.snapshot();
        let s = sampled.layer("m/00:l").obs.snapshot();
        assert_eq!(f.count, xs.len() as u64);
        assert_eq!(s.count, xs.len() as u64 / 2);
        assert!(s.min >= f.min && s.max <= f.max, "sampled range escapes full range");
        assert!(s.absmax() <= f.absmax() + f32::EPSILON);
    }

    #[test]
    fn attribute_routes_scratch_to_named_layers() {
        let qs = QStats::default();
        qs.enable(true);
        qs.observe_input(&[1.0, -3.0]);
        qs.add_saturation(2, 1);
        qs.attribute("m/00:fc1");
        qs.observe_input(&[0.5]);
        qs.attribute("m/01:fc2");
        // draining an empty scratch is a no-op, not a new layer entry
        qs.attribute("m/02:head");
        let j = qs.to_json();
        assert_eq!(j.get("enabled").and_then(Json::as_bool), Some(true));
        let l0 = j.path(&["layers", "m/00:fc1"]).expect("fc1 row");
        assert_eq!(l0.get("count").and_then(Json::as_f64), Some(2.0));
        assert_eq!(l0.get("min").and_then(Json::as_f64), Some(-3.0));
        assert_eq!(l0.get("sat_low").and_then(Json::as_f64), Some(2.0));
        assert_eq!(l0.get("absmax_ema").and_then(Json::as_f64), Some(3.0));
        assert!(j.path(&["layers", "m/01:fc2"]).is_some());
        assert!(j.path(&["layers", "m/02:head"]).is_none(), "empty drain made a layer");
        // prefix queries see only the asked-for model
        let abs = qs.absmax_by_prefix("m/");
        assert_eq!(abs.len(), 2);
        assert_eq!(abs["m/00:fc1"], 3.0);
        assert!(qs.absmax_by_prefix("other/").is_empty());
        qs.reset_prefix("m/");
        assert!(qs.absmax_by_prefix("m/").is_empty(), "reset cleared the observers");
        assert!(qs.layer("m/00:fc1").ema_absmax().is_none());
    }

    #[test]
    fn prometheus_render_exposes_layer_series() {
        let qs = QStats::default();
        qs.enable(true);
        qs.observe_input(&[2.0, -1.0]);
        qs.add_saturation(3, 4);
        qs.attribute("toy/00:fc1");
        let mut p = Prom::new();
        qs.render(&mut p);
        let text = p.finish();
        assert!(text.contains("msq_qstats_enabled 1"), "{text}");
        assert!(
            text.contains("msq_layer_act_range{layer=\"toy/00:fc1\",bound=\"min\"} -1"),
            "{text}"
        );
        assert!(
            text.contains("msq_layer_act_range{layer=\"toy/00:fc1\",bound=\"max\"} 2"),
            "{text}"
        );
        assert!(
            text.contains("msq_layer_act_observations_total{layer=\"toy/00:fc1\"} 2"),
            "{text}"
        );
        assert!(
            text.contains("msq_layer_weight_saturation_total{layer=\"toy/00:fc1\"} 7"),
            "{text}"
        );
        assert!(text.contains("msq_layer_act_absmax_ema{layer=\"toy/00:fc1\"} 2"), "{text}");
        qs.enable(false);
    }
}
