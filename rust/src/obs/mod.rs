//! obs — dependency-free, low-overhead observability core shared by the
//! serving and training paths.
//!
//! Four pieces, all pure `std`:
//!
//! * a [`Registry`] of named counters / gauges / histograms with
//!   Prometheus-style label sets. Counters and gauges are lock-free
//!   atomics; histograms wrap the log-bucketed
//!   [`crate::metrics::LatencyHist`] behind a mutex (the same idiom
//!   `serve::ServeMetrics` uses). A registry renders itself into a
//!   [`crate::metrics::Prom`] page alongside the existing hand-rolled
//!   series, and dumps to JSON for `GET /debug/stats`.
//! * a [`Span`] RAII timer: `Span::enter(hist)` starts a monotonic
//!   clock, and the drop (including drop during unwind) records the
//!   elapsed seconds into the histogram — so a panic inside a span
//!   still leaves a sample behind.
//! * a [`Profiler`] handle for kernel-level cost accounting
//!   (decode-vs-matmul nanoseconds, bytes decoded, codes consumed, and
//!   a per-model per-layer table). It is **zero-cost when off**: the
//!   serving kernels load one relaxed `AtomicBool` per call and skip
//!   every clock read when disabled — guarded by a bench section in
//!   `benches/serve_throughput.rs`.
//! * the [`qstats`] activation observers (per-layer min/max, EMA absmax,
//!   magnitude histogram, weight-code saturation) — the *numeric* twin
//!   of the profiler, under the same zero-cost-when-off contract.
//!
//! The request-lifecycle **stage taxonomy** (see `docs/OBSERVABILITY.md`)
//! hangs off [`STAGES`]: parse → queue → batch → decode → kernel →
//! serialize, each an entry of the `msq_stage_duration_seconds` summary
//! family keyed by a `stage` label.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock, RwLock};
use std::time::{Duration, Instant};

use crate::metrics::{LatencyHist, Prom};
use crate::util::json::Json;

pub mod qstats;

/// The request-lifecycle stages, in pipeline order. Every stage is one
/// `{stage="…"}` series of the `msq_stage_duration_seconds` family.
pub const STAGES: [&str; 6] = ["parse", "queue", "batch", "decode", "kernel", "serialize"];

/// Metric family name for the per-stage request-lifecycle histograms.
pub const STAGE_FAMILY: &str = "msq_stage_duration_seconds";

/// Quantiles rendered for every histogram family on `/metrics`.
pub const QUANTILES: [f64; 4] = [0.5, 0.9, 0.95, 0.99];

// ---------------------------------------------------------------------------
// primitive metrics

/// Monotonically increasing lock-free counter.
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins f64 gauge (bit-cast into an atomic word).
#[derive(Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Thread-safe histogram of seconds: a mutex around the log-bucketed
/// [`LatencyHist`]. `record` is O(1); contention is one short critical
/// section per sample, matching the `ServeMetrics` latency path.
#[derive(Default)]
pub struct Hist {
    inner: Mutex<LatencyHist>,
}

impl Hist {
    fn lock(&self) -> MutexGuard<'_, LatencyHist> {
        // A panic while holding the lock cannot corrupt a LatencyHist
        // (its record is a pair of integer bumps), so poisoning is
        // recoverable by construction.
        self.inner.lock().unwrap_or_else(|p| p.into_inner())
    }

    pub fn record(&self, seconds: f64) {
        self.lock().record(seconds);
    }

    pub fn record_duration(&self, d: Duration) {
        self.record(d.as_secs_f64());
    }

    pub fn count(&self) -> u64 {
        self.lock().count()
    }

    pub fn sum(&self) -> f64 {
        self.lock().sum()
    }

    /// Clone-out snapshot for rendering without holding the lock.
    pub fn snapshot(&self) -> LatencyHist {
        self.lock().clone()
    }
}

// ---------------------------------------------------------------------------
// spans

/// RAII timer over a [`Hist`]: started by [`Span::enter`], it records
/// the elapsed monotonic time on drop — **including drops that happen
/// during a panic unwind**, so instrumented sections never lose their
/// sample to an error path. Nesting is plain lexical scoping: an inner
/// span records into its own histogram independently of the outer one.
pub struct Span {
    hist: Arc<Hist>,
    start: Instant,
    done: bool,
}

impl Span {
    pub fn enter(hist: Arc<Hist>) -> Span {
        Span { hist, start: Instant::now(), done: false }
    }

    /// Elapsed time so far, without ending the span.
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    /// End the span now and return the recorded duration.
    pub fn stop(mut self) -> Duration {
        let d = self.start.elapsed();
        self.hist.record(d.as_secs_f64());
        self.done = true;
        d
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if !self.done {
            self.hist.record(self.start.elapsed().as_secs_f64());
        }
    }
}

// ---------------------------------------------------------------------------
// registry

#[derive(Clone, PartialEq, Eq, PartialOrd, Ord)]
struct Key {
    name: String,
    labels: Vec<(String, String)>,
}

impl Key {
    fn new(name: &str, labels: &[(&str, &str)]) -> Key {
        Key {
            name: name.to_string(),
            labels: labels.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect(),
        }
    }
}

enum Slot {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Hist(Arc<Hist>),
}

impl Slot {
    fn kind(&self) -> &'static str {
        match self {
            Slot::Counter(_) => "counter",
            Slot::Gauge(_) => "gauge",
            Slot::Hist(_) => "summary",
        }
    }
}

/// Named metric store: get-or-create handles by `(family, labels)` key,
/// concurrent updates through the returned `Arc`s, and one-call
/// rendering into Prometheus text or `/debug/stats` JSON.
///
/// Families are implicitly typed by their first registration; asking
/// for the same key as a different type is a programming error and
/// panics (metric names are compile-time constants in this codebase).
#[derive(Default)]
pub struct Registry {
    slots: RwLock<BTreeMap<Key, Slot>>,
    help: RwLock<BTreeMap<String, String>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Attach `# HELP` text to a family name.
    pub fn describe(&self, name: &str, help: &str) {
        self.help.write().unwrap().insert(name.to_string(), help.to_string());
    }

    pub fn counter(&self, name: &str, labels: &[(&str, &str)]) -> Arc<Counter> {
        match self.slot(name, labels, || Slot::Counter(Arc::new(Counter::default()))) {
            Slot::Counter(c) => c,
            s => panic!("obs: {name} already registered as a {}", s.kind()),
        }
    }

    pub fn gauge(&self, name: &str, labels: &[(&str, &str)]) -> Arc<Gauge> {
        match self.slot(name, labels, || Slot::Gauge(Arc::new(Gauge::default()))) {
            Slot::Gauge(g) => g,
            s => panic!("obs: {name} already registered as a {}", s.kind()),
        }
    }

    pub fn hist(&self, name: &str, labels: &[(&str, &str)]) -> Arc<Hist> {
        match self.slot(name, labels, || Slot::Hist(Arc::new(Hist::default()))) {
            Slot::Hist(h) => h,
            s => panic!("obs: {name} already registered as a {}", s.kind()),
        }
    }

    /// Enter a span over the named histogram (get-or-create).
    pub fn span(&self, name: &str, labels: &[(&str, &str)]) -> Span {
        Span::enter(self.hist(name, labels))
    }

    /// Histogram handle for one request-lifecycle stage (see [`STAGES`]).
    pub fn stage(&self, stage: &str) -> Arc<Hist> {
        self.hist(STAGE_FAMILY, &[("stage", stage)])
    }

    /// Pre-register every lifecycle stage so `/metrics` exposes all six
    /// `{stage="…"}` series from the first scrape, samples or not.
    pub fn init_stages(&self) {
        self.describe(
            STAGE_FAMILY,
            "Per-stage request lifecycle time (parse/queue/batch/decode/kernel/serialize)",
        );
        for s in STAGES {
            let _ = self.stage(s);
        }
    }

    fn slot(&self, name: &str, labels: &[(&str, &str)], make: impl FnOnce() -> Slot) -> Slot {
        let key = Key::new(name, labels);
        if let Some(s) = self.slots.read().unwrap().get(&key) {
            return clone_slot(s);
        }
        let mut w = self.slots.write().unwrap();
        clone_slot(w.entry(key).or_insert_with(make))
    }

    /// Render every family into a Prometheus page: `# HELP`/`# TYPE`
    /// once per family (the BTreeMap keeps label sets of one family
    /// contiguous), then one sample per counter/gauge and a
    /// quantile+`_sum`+`_count` block per histogram.
    pub fn render(&self, p: &mut Prom, quantiles: &[f64]) {
        let slots = self.slots.read().unwrap();
        let help = self.help.read().unwrap();
        let mut last_family = String::new();
        for (key, slot) in slots.iter() {
            if key.name != last_family {
                let h = help.get(&key.name).map(String::as_str).unwrap_or("");
                p.family(&key.name, slot.kind(), h);
                last_family.clone_from(&key.name);
            }
            let labels: Vec<(&str, &str)> =
                key.labels.iter().map(|(k, v)| (k.as_str(), v.as_str())).collect();
            match slot {
                Slot::Counter(c) => p.sample(&key.name, &labels, c.get() as f64),
                Slot::Gauge(g) => p.sample(&key.name, &labels, g.get()),
                Slot::Hist(h) => p.summary(&key.name, &labels, &h.snapshot(), quantiles),
            }
        }
    }

    /// JSON dump for `GET /debug/stats`: counters and gauges as numbers,
    /// histograms as `{count, sum_s, mean_ms, p50_ms, p95_ms, p99_ms,
    /// max_ms}` objects, keyed by `family{label="…"}`.
    pub fn to_json(&self) -> Json {
        let slots = self.slots.read().unwrap();
        let mut out = BTreeMap::new();
        for (key, slot) in slots.iter() {
            let mut name = key.name.clone();
            if !key.labels.is_empty() {
                name.push('{');
                for (i, (k, v)) in key.labels.iter().enumerate() {
                    if i > 0 {
                        name.push(',');
                    }
                    name.push_str(&format!("{k}=\"{v}\""));
                }
                name.push('}');
            }
            let v = match slot {
                Slot::Counter(c) => Json::Num(c.get() as f64),
                Slot::Gauge(g) => Json::Num(g.get()),
                Slot::Hist(h) => {
                    let s = h.snapshot();
                    Json::obj(vec![
                        ("count", Json::Num(s.count() as f64)),
                        ("sum_s", Json::Num(s.sum())),
                        ("mean_ms", Json::Num(s.mean() * 1e3)),
                        ("p50_ms", Json::Num(s.percentile(50.0) * 1e3)),
                        ("p95_ms", Json::Num(s.percentile(95.0) * 1e3)),
                        ("p99_ms", Json::Num(s.percentile(99.0) * 1e3)),
                        ("max_ms", Json::Num(s.max() * 1e3)),
                    ])
                }
            };
            out.insert(name, v);
        }
        Json::Obj(out)
    }
}

fn clone_slot(s: &Slot) -> Slot {
    match s {
        Slot::Counter(c) => Slot::Counter(c.clone()),
        Slot::Gauge(g) => Slot::Gauge(g.clone()),
        Slot::Hist(h) => Slot::Hist(h.clone()),
    }
}

/// The process-wide registry. Serving attaches a *per-gateway* registry
/// to `AppState` (so unit tests don't cross-talk); the global one holds
/// process-singleton series — kernel profiler aggregates and training
/// spans.
pub fn global() -> &'static Registry {
    static GLOBAL: OnceLock<Registry> = OnceLock::new();
    GLOBAL.get_or_init(Registry::new)
}

// ---------------------------------------------------------------------------
// kernel profiler

/// Per-layer cost row of the profiler table (all times monotonic ns).
#[derive(Clone, Default)]
pub struct LayerStat {
    pub kind: String,
    pub bits: u8,
    pub calls: u64,
    pub rows: u64,
    pub total_ns: u64,
    pub decode_ns: u64,
    pub matmul_ns: u64,
    pub bytes: u64,
    pub codes: u64,
}

/// Zero-cost-when-off kernel profiler. The serving kernels
/// (`serve::kernels::{qgemm, qconv2d, qattention}`) check [`Profiler::on`]
/// once per call (one relaxed atomic load) and, only when enabled, time
/// their bit-stream decode separately from the code·activation matmul,
/// accumulating into lock-free aggregate counters. `ServableModel::
/// infer_batch` additionally attributes the deltas to a per-model
/// per-layer table (one mutex lock per layer per batch, again only when
/// enabled).
///
/// Timing never changes the arithmetic, so the {serial, pooled} ×
/// {scalar, simd} bit-exactness contract is untouched either way.
#[derive(Default)]
pub struct Profiler {
    enabled: AtomicBool,
    decode_ns: AtomicU64,
    matmul_ns: AtomicU64,
    bytes: AtomicU64,
    codes: AtomicU64,
    layers: Mutex<BTreeMap<String, LayerStat>>,
}

/// Aggregate kernel counters: (decode_ns, matmul_ns, bytes, codes).
pub type KernelSnapshot = (u64, u64, u64, u64);

impl Profiler {
    pub fn enable(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    #[inline]
    pub fn on(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Fold one kernel call's costs into the aggregates. Kernels batch
    /// this per work block, not per row, to keep atomic traffic low.
    pub fn add_kernel(&self, decode_ns: u64, matmul_ns: u64, bytes: u64, codes: u64) {
        self.decode_ns.fetch_add(decode_ns, Ordering::Relaxed);
        self.matmul_ns.fetch_add(matmul_ns, Ordering::Relaxed);
        self.bytes.fetch_add(bytes, Ordering::Relaxed);
        self.codes.fetch_add(codes, Ordering::Relaxed);
    }

    pub fn kernel_snapshot(&self) -> KernelSnapshot {
        (
            self.decode_ns.load(Ordering::Relaxed),
            self.matmul_ns.load(Ordering::Relaxed),
            self.bytes.load(Ordering::Relaxed),
            self.codes.load(Ordering::Relaxed),
        )
    }

    /// Attribute one layer-forward to the per-layer table. `key` should
    /// order layers within a model, e.g. `"model/03:fc2"`.
    #[allow(clippy::too_many_arguments)]
    pub fn record_layer(
        &self,
        key: &str,
        kind: &str,
        bits: u8,
        rows: u64,
        total_ns: u64,
        decode_ns: u64,
        matmul_ns: u64,
        bytes: u64,
        codes: u64,
    ) {
        let mut t = self.layers.lock().unwrap_or_else(|p| p.into_inner());
        let e = t.entry(key.to_string()).or_default();
        e.kind = kind.to_string();
        e.bits = bits;
        e.calls += 1;
        e.rows += rows;
        e.total_ns += total_ns;
        e.decode_ns += decode_ns;
        e.matmul_ns += matmul_ns;
        e.bytes += bytes;
        e.codes += codes;
    }

    /// Clear both the aggregates and the per-layer table (does not
    /// change the enabled flag).
    pub fn reset(&self) {
        self.decode_ns.store(0, Ordering::Relaxed);
        self.matmul_ns.store(0, Ordering::Relaxed);
        self.bytes.store(0, Ordering::Relaxed);
        self.codes.store(0, Ordering::Relaxed);
        self.layers.lock().unwrap_or_else(|p| p.into_inner()).clear();
    }

    /// JSON view for `/debug/stats`: the aggregate decode/matmul split
    /// plus the per-model per-layer table (layer time, decode share,
    /// bytes decoded, codes/sec).
    pub fn to_json(&self) -> Json {
        let (dec, mm, bytes, codes) = self.kernel_snapshot();
        let layers = self.layers.lock().unwrap_or_else(|p| p.into_inner());
        let mut table = BTreeMap::new();
        for (key, s) in layers.iter() {
            let total_s = s.total_ns as f64 / 1e9;
            table.insert(
                key.clone(),
                Json::obj(vec![
                    ("kind", Json::Str(s.kind.clone())),
                    ("bits", Json::Num(s.bits as f64)),
                    ("calls", Json::Num(s.calls as f64)),
                    ("rows", Json::Num(s.rows as f64)),
                    ("total_ms", Json::Num(s.total_ns as f64 / 1e6)),
                    ("decode_ms", Json::Num(s.decode_ns as f64 / 1e6)),
                    ("matmul_ms", Json::Num(s.matmul_ns as f64 / 1e6)),
                    ("bytes_decoded", Json::Num(s.bytes as f64)),
                    (
                        "codes_per_sec",
                        Json::Num(if total_s > 0.0 { s.codes as f64 / total_s } else { 0.0 }),
                    ),
                ]),
            );
        }
        Json::obj(vec![
            ("enabled", Json::Bool(self.on())),
            ("decode_ms", Json::Num(dec as f64 / 1e6)),
            ("matmul_ms", Json::Num(mm as f64 / 1e6)),
            ("bytes_decoded", Json::Num(bytes as f64)),
            ("codes", Json::Num(codes as f64)),
            ("layers", Json::Obj(table)),
        ])
    }

    /// Render the aggregate counters as Prometheus series (the
    /// per-layer table stays on `/debug/stats` — unbounded label sets
    /// don't belong on a scrape page).
    pub fn render(&self, p: &mut Prom) {
        let (dec, mm, bytes, codes) = self.kernel_snapshot();
        p.family("msq_profiler_enabled", "gauge", "1 when kernel profiling is on");
        p.sample("msq_profiler_enabled", &[], if self.on() { 1.0 } else { 0.0 });
        p.family(
            "msq_kernel_seconds_total",
            "counter",
            "Cumulative kernel time split by phase (decode vs matmul)",
        );
        p.sample("msq_kernel_seconds_total", &[("phase", "decode")], dec as f64 / 1e9);
        p.sample("msq_kernel_seconds_total", &[("phase", "matmul")], mm as f64 / 1e9);
        p.family(
            "msq_kernel_bytes_decoded_total",
            "counter",
            "Packed payload bytes streamed through the bit-stream decoder",
        );
        p.sample("msq_kernel_bytes_decoded_total", &[], bytes as f64);
        p.family(
            "msq_kernel_codes_total",
            "counter",
            "Quantized weight codes consumed by the serving kernels",
        );
        p.sample("msq_kernel_codes_total", &[], codes as f64);
    }
}

/// The process-wide kernel profiler (off by default).
pub fn profiler() -> &'static Profiler {
    static PROF: OnceLock<Profiler> = OnceLock::new();
    PROF.get_or_init(Profiler::default)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn concurrent_counter_and_hist_updates_are_lossless() {
        // N threads × M updates through shared handles: nothing dropped.
        const THREADS: usize = 8;
        const PER: usize = 1000;
        let reg = Arc::new(Registry::new());
        let c = reg.counter("msq_test_total", &[]);
        let h = reg.hist("msq_test_seconds", &[]);
        std::thread::scope(|s| {
            for t in 0..THREADS {
                let (c, h) = (c.clone(), h.clone());
                s.spawn(move || {
                    for i in 0..PER {
                        c.inc();
                        h.record(1e-6 * (t * PER + i + 1) as f64);
                    }
                });
            }
        });
        assert_eq!(c.get(), (THREADS * PER) as u64);
        assert_eq!(h.count(), (THREADS * PER) as u64);
        assert!(h.sum() > 0.0);
        // get-or-create returns the same underlying metric
        assert_eq!(reg.counter("msq_test_total", &[]).get(), (THREADS * PER) as u64);
    }

    #[test]
    fn span_nesting_records_each_level() {
        let reg = Registry::new();
        let outer_h = reg.hist("outer_seconds", &[]);
        let inner_h = reg.hist("inner_seconds", &[]);
        let outer = Span::enter(outer_h.clone());
        {
            let inner = Span::enter(inner_h.clone());
            std::thread::sleep(Duration::from_millis(2));
            drop(inner);
        }
        std::thread::sleep(Duration::from_millis(1));
        let total = outer.stop();
        assert_eq!(outer_h.count(), 1);
        assert_eq!(inner_h.count(), 1);
        // inner elapsed is a strict subset of outer elapsed
        assert!(inner_h.sum() <= total.as_secs_f64() + 1e-9);
        assert!(outer_h.sum() >= inner_h.sum());
    }

    #[test]
    fn span_records_on_panic_unwind() {
        let reg = Registry::new();
        let h = reg.hist("panicky_seconds", &[]);
        let h2 = h.clone();
        let r = std::panic::catch_unwind(move || {
            let _span = Span::enter(h2);
            panic!("boom");
        });
        assert!(r.is_err());
        assert_eq!(h.count(), 1, "span must record during unwind");
    }

    #[test]
    fn registry_renders_well_formed_prometheus_text() {
        let reg = Registry::new();
        reg.describe("msq_widgets_total", "Widgets made");
        reg.counter("msq_widgets_total", &[("kind", "a")]).add(3);
        reg.counter("msq_widgets_total", &[("kind", "b")]).inc();
        reg.gauge("msq_depth", &[]).set(2.5);
        reg.init_stages();
        reg.stage("parse").record(0.004);

        let mut p = Prom::new();
        reg.render(&mut p, &QUANTILES);
        let text = p.finish();

        // one family header per family, in sorted order, each before its samples
        assert_eq!(text.matches("# TYPE msq_widgets_total counter").count(), 1);
        assert_eq!(text.matches("# TYPE msq_depth gauge").count(), 1);
        assert_eq!(text.matches(&format!("# TYPE {STAGE_FAMILY} summary")).count(), 1);
        assert!(text.contains("# HELP msq_widgets_total Widgets made"));
        assert!(text.contains("msq_widgets_total{kind=\"a\"} 3"));
        assert!(text.contains("msq_widgets_total{kind=\"b\"} 1"));
        assert!(text.contains("msq_depth 2.5"));
        // all six stages render series even when empty
        for s in STAGES {
            assert!(
                text.contains(&format!("{STAGE_FAMILY}_count{{stage=\"{s}\"}}")),
                "missing stage series {s}:\n{text}"
            );
        }
        assert!(text.contains(&format!("{STAGE_FAMILY}{{stage=\"parse\",quantile=\"0.5\"}}")));
        // every non-comment line is `name{...} value` with a parseable value
        for line in text.lines().filter(|l| !l.starts_with('#') && !l.is_empty()) {
            let (_, val) = line.rsplit_once(' ').expect("sample line has a value");
            assert!(
                val.parse::<f64>().is_ok() || val == "+Inf" || val == "-Inf" || val == "NaN",
                "bad sample value in line: {line}"
            );
        }
    }

    #[test]
    fn registry_json_dump_shape() {
        let reg = Registry::new();
        reg.counter("msq_c_total", &[]).add(7);
        reg.stage("kernel").record(0.010);
        let j = reg.to_json();
        assert_eq!(j.get("msq_c_total").and_then(Json::as_f64), Some(7.0));
        let k = j.get(&format!("{STAGE_FAMILY}{{stage=\"kernel\"}}")).expect("stage entry");
        assert_eq!(k.get("count").and_then(Json::as_f64), Some(1.0));
        assert!(k.get("sum_s").and_then(Json::as_f64).unwrap() > 0.0);
    }

    #[test]
    fn profiler_accumulates_and_resets() {
        let p = Profiler::default();
        assert!(!p.on());
        p.enable(true);
        p.add_kernel(100, 200, 32, 64);
        p.add_kernel(50, 100, 16, 32);
        p.record_layer("m/00:fc1", "linear", 4, 8, 450, 150, 300, 48, 96);
        assert_eq!(p.kernel_snapshot(), (150, 300, 48, 96));
        let j = p.to_json();
        assert_eq!(j.get("enabled").and_then(Json::as_bool), Some(true));
        let layer = j.path(&["layers", "m/00:fc1"]).expect("layer row");
        assert_eq!(layer.get("calls").and_then(Json::as_f64), Some(1.0));
        assert_eq!(layer.get("bits").and_then(Json::as_f64), Some(4.0));
        p.reset();
        assert_eq!(p.kernel_snapshot(), (0, 0, 0, 0));
        assert!(p.to_json().path(&["layers", "m/00:fc1"]).is_none());
        p.enable(false);
    }

    #[test]
    fn profiler_prom_render_has_phase_split() {
        let p = Profiler::default();
        p.add_kernel(2_000_000_000, 4_000_000_000, 1024, 2048);
        let mut prom = Prom::new();
        p.render(&mut prom);
        let text = prom.finish();
        assert!(text.contains("msq_kernel_seconds_total{phase=\"decode\"} 2"));
        assert!(text.contains("msq_kernel_seconds_total{phase=\"matmul\"} 4"));
        assert!(text.contains("msq_kernel_bytes_decoded_total 1024"));
    }
}
