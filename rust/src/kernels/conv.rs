//! Conv2d window geometry + receptive-field microkernels, shared by the
//! quantized serving kernel (`serve::kernels::qconv2d`) and the native
//! training kernels (`native::ops::conv2d_*`).
//!
//! Everything here is phrased over the one conv layout the repo speaks:
//! NHWC activations against OHWI filters (`quant::pack::Conv2dDesc`), so
//! the innermost dot of every window runs over `(kx1−kx0)·in_ch`
//! *contiguous* elements on both sides and vectorizes through
//! [`super::simd::dot`]. Zero padding is handled by [`krange`]-clipping
//! the tap ranges instead of materializing padded inputs — exact for the
//! serving path's affine folding because padded positions contribute
//! zero to both the code·activation dot and the receptive-field sum.
//!
//! Training and serving geometry must never diverge (a `.msqpack` export
//! is byte-faithful to what the serve kernels execute), which is why
//! this module is the only place window clipping is written down.

use crate::quant::pack::Conv2dDesc;

use super::simd::{dot, sum};

/// Kernel-tap bounds for one output index: which `0..k` taps land inside
/// the `in_n`-wide input once `o·stride − pad` anchors the window.
/// Returns `(k0, k1, i0)` — taps `k0..k1` are valid and tap `k0` reads
/// input index `i0` (empty range when the window misses entirely).
#[inline]
pub fn krange(o: usize, stride: usize, pad: usize, k: usize, in_n: usize) -> (usize, usize, usize) {
    let base = (o * stride) as isize - pad as isize;
    let k0 = (-base).max(0) as usize;
    let k1 = (in_n as isize - base).clamp(0, k as isize) as usize;
    let k1 = k1.max(k0);
    (k0, k1, (base + k0 as isize).max(0) as usize)
}

/// Dot of one filter against one clipped receptive field: `ky0..ky1` are
/// the valid vertical taps (tap `ky0` reads input row `iy0`), and each
/// row contributes `seg = (kx1−kx0)·in_ch` contiguous elements starting
/// at horizontal tap `kx0` / input column `ix0`. `wf` is one OHWI filter
/// (`kh·kw·in_ch`), `xb` one NHWC sample. Returns 0 for windows that
/// miss the input entirely (`seg == 0` or an empty tap range) without
/// touching memory — `pad ≥ kernel` edge windows would otherwise index
/// past the row.
#[inline]
#[allow(clippy::too_many_arguments)]
pub fn window_dot(
    wf: &[f32],
    xb: &[f32],
    kw: usize,
    in_w: usize,
    in_ch: usize,
    ky0: usize,
    ky1: usize,
    iy0: usize,
    kx0: usize,
    ix0: usize,
    seg: usize,
) -> f32 {
    if seg == 0 {
        return 0.0;
    }
    let mut acc = 0f32;
    for ky in ky0..ky1 {
        let iy = iy0 + (ky - ky0);
        acc += dot(&wf[(ky * kw + kx0) * in_ch..][..seg], &xb[(iy * in_w + ix0) * in_ch..][..seg]);
    }
    acc
}

/// `Σ x` over one clipped receptive field (the serving kernels' dequant
/// correction term) — same clipping contract as [`window_dot`].
#[inline]
#[allow(clippy::too_many_arguments)]
pub fn window_sum(
    xb: &[f32],
    in_w: usize,
    in_ch: usize,
    ky0: usize,
    ky1: usize,
    iy0: usize,
    ix0: usize,
    seg: usize,
) -> f32 {
    if seg == 0 {
        return 0.0;
    }
    let mut s = 0f32;
    for ky in ky0..ky1 {
        let iy = iy0 + (ky - ky0);
        s += sum(&xb[(iy * in_w + ix0) * in_ch..][..seg]);
    }
    s
}

/// Dense conv2d forward for ONE sample: `xi` is `in_h × in_w × in_ch`
/// (NHWC), `w` is `out_ch × kh·kw·in_ch` (OHWI), `orow` is `out_h ×
/// out_w × out_ch`. The native trainer parallelizes over samples and
/// calls this per row; the vertical tap range hoists out of the `ox`
/// loop so window clipping costs a handful of integer ops per position.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_forward_sample(
    xi: &[f32],
    w: &[f32],
    b: &[f32],
    d: &Conv2dDesc,
    in_h: usize,
    in_w: usize,
    out_h: usize,
    out_w: usize,
    orow: &mut [f32],
) {
    let flen = d.filter_len();
    debug_assert_eq!(xi.len(), in_h * in_w * d.in_ch);
    debug_assert_eq!(w.len(), d.out_ch * flen);
    debug_assert_eq!(b.len(), d.out_ch);
    debug_assert_eq!(orow.len(), out_h * out_w * d.out_ch);
    for oy in 0..out_h {
        let (ky0, ky1, iy0) = krange(oy, d.stride, d.pad, d.kh, in_h);
        for ox in 0..out_w {
            let (kx0, kx1, ix0) = krange(ox, d.stride, d.pad, d.kw, in_w);
            let seg = (kx1 - kx0) * d.in_ch;
            for oc in 0..d.out_ch {
                let wf = &w[oc * flen..(oc + 1) * flen];
                orow[(oy * out_w + ox) * d.out_ch + oc] =
                    window_dot(wf, xi, d.kw, in_w, d.in_ch, ky0, ky1, iy0, kx0, ix0, seg) + b[oc];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn rand(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| r.normal()).collect()
    }

    #[test]
    fn krange_clips_padding_windows() {
        // k=3, stride=1, pad=1 over 4 inputs: first window hangs one tap
        // off the left edge, last one off the right
        assert_eq!(krange(0, 1, 1, 3, 4), (1, 3, 0));
        assert_eq!(krange(1, 1, 1, 3, 4), (0, 3, 0));
        assert_eq!(krange(3, 1, 1, 3, 4), (0, 2, 2));
        // window entirely off the input: empty range
        assert_eq!(krange(0, 1, 5, 3, 4).0, krange(0, 1, 5, 3, 4).1);
    }

    #[test]
    fn window_dot_matches_naive_clipped_window() {
        let d = Conv2dDesc { in_ch: 3, out_ch: 1, kh: 3, kw: 3, stride: 1, pad: 1 };
        let (in_h, in_w) = (5, 4);
        let xb = rand(in_h * in_w * d.in_ch, 1);
        let wf = rand(d.filter_len(), 2);
        for oy in 0..in_h {
            let (ky0, ky1, iy0) = krange(oy, d.stride, d.pad, d.kh, in_h);
            for ox in 0..in_w {
                let (kx0, kx1, ix0) = krange(ox, d.stride, d.pad, d.kw, in_w);
                let seg = (kx1 - kx0) * d.in_ch;
                let got =
                    window_dot(&wf, &xb, d.kw, in_w, d.in_ch, ky0, ky1, iy0, kx0, ix0, seg);
                let mut want = 0f64;
                for ky in 0..d.kh {
                    let iy = oy as isize + ky as isize - d.pad as isize;
                    if iy < 0 || iy >= in_h as isize {
                        continue;
                    }
                    for kx in 0..d.kw {
                        let ix = ox as isize + kx as isize - d.pad as isize;
                        if ix < 0 || ix >= in_w as isize {
                            continue;
                        }
                        for ic in 0..d.in_ch {
                            want += wf[(ky * d.kw + kx) * d.in_ch + ic] as f64
                                * xb[((iy as usize) * in_w + ix as usize) * d.in_ch + ic] as f64;
                        }
                    }
                }
                assert!((got as f64 - want).abs() < 1e-4, "({oy},{ox}): {got} vs {want}");
            }
        }
    }

    #[test]
    fn window_helpers_survive_pad_wider_than_kernel() {
        // pad 5 > kw 3: corner windows miss the input entirely; the
        // helpers must return 0 without touching memory
        let (ky0, ky1, iy0) = krange(0, 1, 5, 3, 4);
        assert_eq!(ky0, ky1);
        let xb = [1.0f32; 8];
        let wf = [1.0f32; 9];
        assert_eq!(window_dot(&wf, &xb, 3, 4, 1, ky0, ky1, iy0, 0, 0, 0), 0.0);
        assert_eq!(window_sum(&xb, 4, 1, ky0, ky1, iy0, 0, 0), 0.0);
    }

    #[test]
    fn forward_sample_identity_kernel_passes_input_through() {
        // 3x3 single-channel kernel with only the centre tap set, pad 1,
        // stride 1: output map == input map
        let d = Conv2dDesc { in_ch: 1, out_ch: 1, kh: 3, kw: 3, stride: 1, pad: 1 };
        let (h, w) = (5, 4);
        let x = rand(h * w, 13);
        let mut kern = vec![0f32; 9];
        kern[4] = 1.0; // centre tap (ky=1, kx=1)
        let mut out = vec![0f32; h * w];
        conv2d_forward_sample(&x, &kern, &[0.0], &d, h, w, h, w, &mut out);
        for (a, e) in out.iter().zip(&x) {
            assert!((a - e).abs() < 1e-6, "{a} vs {e}");
        }
    }
}
