//! The shared dequantization primitive: n-bit code stream → f32.
//!
//! `.msqpack` payloads store each layer's weights as consecutive
//! `bits`-wide RoundClamp integer codes, LSB-first within each byte and
//! with no padding between elements (`quant::pack::BitWriter`'s layout —
//! see `docs/MSQPACK.md` for the normative spec). Everything that
//! touches those codes — `serve::kernels::qgemm` row blocks,
//! `serve::kernels::qconv2d` filter decodes, and the native trainer's
//! RoundClamp fake-quant — goes through this module, so there is exactly
//! one statement of the bit layout and one statement of the RoundClamp
//! affine (`w = α·c + β`, [`rc_affine`]) in the codebase.
//!
//! [`decode_codes_f32`] is fast-pathed for the widths that dominate real
//! packs (8-bit at any phase, nibble-aligned 4-bit, byte-aligned 1-bit)
//! and falls back to a generic bit-buffer loop for everything else. The
//! fast paths are *pure specializations*: an exhaustive (bits 1..=8 ×
//! phase 0..=7) cross-check against the generic path lives in this
//! module's tests. Decoding widens integer codes exactly (codes < 2²⁴),
//! so decode results carry no rounding at all — every numeric choice
//! happens later, in the affine. [`decode_codes_u8`] is the same decode
//! landing in raw `u8` codes for the integer serving path
//! (`serve::kernels::qgemm_int`); both are monomorphizations of one
//! shared core, so the bit layout still has a single statement.
//!
//! All decoders are *total* over `data`: bits past the end of the buffer
//! decode as zero, matching `quant::pack::BitReader::pull`. A truncated
//! payload therefore yields zero codes for the missing tail instead of a
//! panic (the serve registry still rejects short payloads at load time —
//! zero-extension is the belt under that suspender).

/// One decoded code's destination type: `f32` for the float kernels,
/// `u8` for the integer path. Code values fit u8 (`bits` ≤ 8).
trait Code: Copy + Default {
    fn from_code(c: u32) -> Self;
}

impl Code for f32 {
    #[inline(always)]
    fn from_code(c: u32) -> f32 {
        c as f32
    }
}

impl Code for u8 {
    #[inline(always)]
    fn from_code(c: u32) -> u8 {
        c as u8
    }
}

/// Byte `pos` of `data`, zero-extended past the end.
#[inline(always)]
fn byte(data: &[u8], pos: usize) -> u8 {
    data.get(pos).copied().unwrap_or(0)
}

/// Decode `out.len()` consecutive `bits`-wide codes starting at absolute
/// bit offset `bit_off` of `data` (LSB-first within each byte, matching
/// `quant::pack::BitWriter`), widening each code to f32.
///
/// Total over `data`: bits beyond `bit_off + 8·data.len()` decode as
/// zero (the serve registry validates payload sizes at load time, so a
/// well-formed pack never exercises the extension).
pub fn decode_codes_f32(data: &[u8], bit_off: usize, bits: u8, out: &mut [f32]) {
    decode_codes(data, bit_off, bits, out);
}

/// [`decode_codes_f32`]'s integer twin: the same bit layout and the same
/// zero-extension, landing raw codes in `u8` for the i32-accumulate
/// serving kernels. Requires `bits` ∈ 1..=8 (codes fit a byte).
pub fn decode_codes_u8(data: &[u8], bit_off: usize, bits: u8, out: &mut [u8]) {
    decode_codes(data, bit_off, bits, out);
}

fn decode_codes<T: Code>(data: &[u8], bit_off: usize, bits: u8, out: &mut [T]) {
    debug_assert!((1..=8).contains(&bits));
    let mut pos = bit_off / 8;
    let phase = (bit_off % 8) as u32;
    if bits == 8 {
        if phase == 0 {
            let n = out.len().min(data.len().saturating_sub(pos));
            for (slot, &b) in out[..n].iter_mut().zip(&data[pos..]) {
                *slot = T::from_code(b as u32);
            }
            // truncated tail: zero-extend, matching BitReader::pull
            for slot in out[n..].iter_mut() {
                *slot = T::from_code(0);
            }
        } else {
            // every code straddles the same two-byte window at a fixed
            // phase: consume the leading partial byte and combine, no
            // bit-buffer loop. The final code's straddle byte may sit
            // one past the end of an exact-tail stream — `byte` reads
            // it as zero instead of panicking.
            let hi = 8 - phase;
            for slot in out.iter_mut() {
                let c = ((byte(data, pos) as u32) >> phase)
                    | (((byte(data, pos + 1) as u32) << hi) & 0xFF);
                *slot = T::from_code(c);
                pos += 1;
            }
        }
        return;
    }
    if bits == 4 && phase % 4 == 0 {
        // nibble-aligned: two codes per byte (a leading high nibble when
        // the offset lands mid-byte, a trailing low nibble when the
        // count is odd)
        let mut i = 0;
        if phase == 4 && !out.is_empty() {
            out[0] = T::from_code((byte(data, pos) >> 4) as u32);
            pos += 1;
            i = 1;
        }
        while i + 2 <= out.len() {
            let b = byte(data, pos);
            pos += 1;
            out[i] = T::from_code((b & 0x0F) as u32);
            out[i + 1] = T::from_code((b >> 4) as u32);
            i += 2;
        }
        if i < out.len() {
            out[i] = T::from_code((byte(data, pos) & 0x0F) as u32);
        }
        return;
    }
    if bits == 1 && phase == 0 {
        // byte-aligned 1-bit (the extreme-sparsification case): eight
        // codes per byte, unrolled
        let mut chunks = out.chunks_exact_mut(8);
        for ch in &mut chunks {
            let b = byte(data, pos);
            pos += 1;
            for (l, slot) in ch.iter_mut().enumerate() {
                *slot = T::from_code(((b >> l) & 1) as u32);
            }
        }
        let rem = chunks.into_remainder();
        if !rem.is_empty() {
            let b = byte(data, pos);
            for (l, slot) in rem.iter_mut().enumerate() {
                *slot = T::from_code(((b >> l) & 1) as u32);
            }
        }
        return;
    }
    decode_codes_generic(data, bit_off, bits, out);
}

/// The generic bit-buffer decoder: correct for every `bits` ∈ 1..=8 at
/// every phase, with no specializations. The fast paths above must agree
/// with it bit-for-bit on their whole domain (pinned exhaustively in
/// this module's tests) — it is the semantic definition of the layout,
/// including the zero-extension past the end of `data`.
fn decode_codes_generic<T: Code>(data: &[u8], bit_off: usize, bits: u8, out: &mut [T]) {
    let mut pos = bit_off / 8;
    let phase = (bit_off % 8) as u32;
    let mut cur: u64 = 0;
    let mut nbits: u32 = 0;
    if phase != 0 {
        cur = (byte(data, pos) >> phase) as u64;
        nbits = 8 - phase;
        pos += 1;
    }
    let width = bits as u32;
    let mask = (1u64 << width) - 1;
    for slot in out.iter_mut() {
        while nbits < width {
            cur |= (byte(data, pos) as u64) << nbits;
            pos += 1;
            nbits += 8;
        }
        *slot = T::from_code((cur & mask) as u32);
        cur >>= width;
        nbits -= width;
    }
}

/// The RoundClamp dequantization affine, `w = α·c + β` with
/// `α = 2s / (2ⁿ − 1)` and `β = −s` (paper Eq. 4 rearranged around the
/// integer code). Returns `(α, β)`.
///
/// This is THE statement of the code → weight map: `qgemm`/`qconv2d`
/// fold it out of their inner loops (`y = α·Σ c·x + β·Σ x`), the native
/// trainer's fake-quant applies it elementwise via [`dequant_affine`],
/// and `quant::pack::unpack_layer`'s closed form is equal to it up to
/// one ulp of association. `bits` is f32 because bit-widths are runtime
/// tensors in the training path; for the integral 1..=8 the serving path
/// uses, `2ⁿ − 1` is exact in f32, so serving and training agree on α
/// exactly.
#[inline]
pub fn rc_affine(bits: f32, scale: f32) -> (f32, f32) {
    // Integral widths — the serving path, and every real training
    // schedule — take the exact integer denominator: `f32::exp2`'s
    // precision is platform-dependent per the Rust docs, and the
    // serving lattice must be identical on every host. exp2 only
    // serves fractional runtime widths.
    let denom = if bits.fract() == 0.0 && (1.0..=24.0).contains(&bits) {
        ((1u64 << bits as u32) - 1) as f32
    } else {
        (bits.exp2() - 1.0).max(1.0)
    };
    (2.0 * scale / denom, -scale)
}

/// Apply a dequantization affine in place: `codes[i] = α·codes[i] + β`.
/// Elementwise, so bit-identical across scalar/SIMD builds for free.
#[inline]
pub fn dequant_affine(codes: &mut [f32], alpha: f32, beta: f32) {
    for c in codes.iter_mut() {
        *c = alpha * *c + beta;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::pack::pack_layer;
    use crate::util::prng::Rng;

    fn rand_vec(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| r.normal() * 0.5).collect()
    }

    /// Bit-level reference: extract the `bits`-wide code at absolute bit
    /// offset `off` straight from the byte stream, one bit at a time.
    /// Zero-extended past the end of `data` — the normative totality
    /// semantics every decode path must match.
    fn code_at(data: &[u8], off: usize, bits: u8) -> u32 {
        let mut v = 0u32;
        for i in 0..bits as usize {
            let bit = off + i;
            let b = data.get(bit / 8).copied().unwrap_or(0);
            v |= (((b >> (bit % 8)) & 1) as u32) << i;
        }
        v
    }

    #[test]
    fn decode_matches_bitreader_at_any_offset() {
        for bits in 1u8..=8 {
            let cols = 13; // 13*bits is non-byte-aligned for most bits
            let rows = 7;
            let w = rand_vec(rows * cols, bits as u64);
            let p = pack_layer("l", &w, bits);
            // reference: sequential pull of every code
            let mut br = crate::quant::pack::BitReader::new(&p.data);
            let reference: Vec<f32> = (0..rows * cols).map(|_| br.pull(bits) as f32).collect();
            // decode each row independently at its bit offset
            let mut row = vec![0f32; cols];
            for r in 0..rows {
                decode_codes_f32(&p.data, r * cols * bits as usize, bits, &mut row);
                assert_eq!(&row[..], &reference[r * cols..(r + 1) * cols], "bits {bits} row {r}");
            }
        }
    }

    #[test]
    fn decode_8bit_handles_unaligned_offsets() {
        // regression: the 8-bit fast path used to be skipped whenever the
        // bit offset had a nonzero phase; the fixed path must match the
        // generic decoder at every phase 0..8
        let mut r = Rng::new(77);
        let data: Vec<u8> = (0..64).map(|_| (r.next_u64() & 0xFF) as u8).collect();
        for off in 0..16 {
            let n = 40; // 40 codes of 8 bits from `off`
            let mut out = vec![0f32; n];
            decode_codes_f32(&data, off, 8, &mut out);
            for (i, &got) in out.iter().enumerate() {
                let expect = code_at(&data, off + 8 * i, 8) as f32;
                assert_eq!(got, expect, "off {off} code {i}");
            }
        }
    }

    #[test]
    fn decode_all_bits_at_all_phases() {
        let mut r = Rng::new(78);
        let data: Vec<u8> = (0..96).map(|_| (r.next_u64() & 0xFF) as u8).collect();
        for bits in 1u8..=8 {
            for off in 0..24 {
                let n = 25;
                let mut out = vec![0f32; n];
                decode_codes_f32(&data, off, bits, &mut out);
                for (i, &got) in out.iter().enumerate() {
                    let expect = code_at(&data, off + bits as usize * i, bits) as f32;
                    assert_eq!(got, expect, "bits {bits} off {off} code {i}");
                }
            }
        }
    }

    #[test]
    fn fast_paths_agree_with_generic_on_every_bits_phase_pair() {
        // exhaustive (bits 1..=8) × (phase 0..=7) × assorted counts —
        // including 0, 1, and odd counts that end mid-byte — so every
        // specialized branch above is checked against the generic
        // bit-buffer decoder over its whole dispatch domain
        let mut r = Rng::new(79);
        let data: Vec<u8> = (0..128).map(|_| (r.next_u64() & 0xFF) as u8).collect();
        for bits in 1u8..=8 {
            for phase in 0usize..8 {
                for n in [0usize, 1, 2, 7, 8, 9, 25, 40] {
                    let mut fast = vec![0f32; n];
                    let mut generic = vec![0f32; n];
                    decode_codes_f32(&data, phase, bits, &mut fast);
                    decode_codes_generic(&data, phase, bits, &mut generic);
                    assert_eq!(fast, generic, "bits {bits} phase {phase} n {n}");
                }
            }
        }
    }

    #[test]
    fn truncated_tail_8bit_phase_decodes_instead_of_panicking() {
        // regression: the 8-bit nonzero-phase fast path read
        // `data[pos + 1]` unguarded, so a stream whose final straddled
        // code ended exactly at the last byte panicked. The fixed path
        // zero-extends: the low `8 - phase` bits of the last code come
        // from the final byte, the high bits decode as zero.
        let mut r = Rng::new(80);
        for phase in 1usize..8 {
            for n in [1usize, 2, 5, 16] {
                // exactly n bytes: bits phase..8n present, the final
                // code's top `phase` bits fall past the end
                let data: Vec<u8> = (0..n).map(|_| (r.next_u64() & 0xFF) as u8).collect();
                let mut out = vec![f32::NAN; n];
                decode_codes_f32(&data, phase, 8, &mut out);
                for (i, &got) in out.iter().enumerate() {
                    let expect = code_at(&data, phase + 8 * i, 8) as f32;
                    assert_eq!(got, expect, "phase {phase} n {n} code {i}");
                }
            }
        }
    }

    #[test]
    fn every_path_is_total_on_short_buffers() {
        // exact-tail and shorter-than-contract buffers for every
        // (bits, phase): fast and generic decoders must agree with the
        // zero-extended bit-level reference, never panic
        let mut r = Rng::new(81);
        let full: Vec<u8> = (0..64).map(|_| (r.next_u64() & 0xFF) as u8).collect();
        for bits in 1u8..=8 {
            for phase in 0usize..8 {
                for n in [1usize, 2, 7, 8, 9, 25] {
                    let contract_bytes = (phase + bits as usize * n).div_ceil(8);
                    // trim to the contract boundary and then below it
                    for len in (0..=contract_bytes).rev().take(4) {
                        let data = &full[..len];
                        let mut fast = vec![f32::NAN; n];
                        let mut generic = vec![f32::NAN; n];
                        decode_codes_f32(data, phase, bits, &mut fast);
                        decode_codes_generic(data, phase, bits, &mut generic);
                        for i in 0..n {
                            let expect = code_at(data, phase + bits as usize * i, bits) as f32;
                            assert_eq!(
                                fast[i], expect,
                                "fast: bits {bits} phase {phase} n {n} len {len} code {i}"
                            );
                            assert_eq!(
                                generic[i], expect,
                                "generic: bits {bits} phase {phase} n {n} len {len} code {i}"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn u8_decode_matches_f32_decode_everywhere() {
        // decode_codes_u8 is the integer-path twin: same layout, same
        // zero-extension — exhaustively identical to the f32 decode
        let mut r = Rng::new(82);
        let data: Vec<u8> = (0..96).map(|_| (r.next_u64() & 0xFF) as u8).collect();
        for bits in 1u8..=8 {
            for phase in 0usize..8 {
                for n in [0usize, 1, 2, 7, 8, 9, 25, 40] {
                    let mut f = vec![0f32; n];
                    let mut u = vec![0u8; n];
                    decode_codes_f32(&data, phase, bits, &mut f);
                    decode_codes_u8(&data, phase, bits, &mut u);
                    for i in 0..n {
                        assert_eq!(f[i], u[i] as f32, "bits {bits} phase {phase} n {n} code {i}");
                    }
                    // truncated view too
                    let short = &data[..(phase + bits as usize * n).div_ceil(8).saturating_sub(1)];
                    decode_codes_f32(short, phase, bits, &mut f);
                    decode_codes_u8(short, phase, bits, &mut u);
                    for i in 0..n {
                        assert_eq!(f[i], u[i] as f32, "short: bits {bits} phase {phase} code {i}");
                    }
                }
            }
        }
    }

    #[test]
    fn rc_affine_matches_integer_denominator_exactly() {
        // serving computes 2s/(2ⁿ−1) from the integer denominator; the
        // shared affine takes f32 bits (runtime tensors) — for every
        // integral width the serving path accepts they must be the SAME
        // f32, or serving and training would disagree on the lattice
        for bits in 1u8..=8 {
            for scale in [0.25f32, 1.0, 1.7] {
                let (alpha, beta) = rc_affine(bits as f32, scale);
                let denom = ((1u32 << bits) - 1).max(1) as f32;
                assert_eq!(alpha, 2.0 * scale / denom, "bits {bits}");
                assert_eq!(beta, -scale);
            }
        }
    }

    #[test]
    fn dequant_affine_matches_unpack_lattice() {
        // α·c + β must land on the same lattice as pack's closed-form
        // dequant (from_unit(c/(2ⁿ−1))) up to association error
        for bits in [1u8, 3, 8] {
            let w = rand_vec(64, 40 + bits as u64);
            let p = pack_layer("l", &w, bits);
            let wq = crate::quant::pack::unpack_layer(&p).unwrap();
            let mut codes = vec![0f32; 64];
            decode_codes_f32(&p.data, 0, bits, &mut codes);
            let (alpha, beta) = rc_affine(bits as f32, p.scale);
            dequant_affine(&mut codes, alpha, beta);
            for (i, (a, e)) in codes.iter().zip(&wq).enumerate() {
                assert!(
                    (a - e).abs() <= 1e-6 * p.scale.max(1.0),
                    "bits {bits} idx {i}: {a} vs {e}"
                );
            }
        }
    }
}
