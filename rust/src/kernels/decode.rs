//! The shared dequantization primitive: n-bit code stream → f32.
//!
//! `.msqpack` payloads store each layer's weights as consecutive
//! `bits`-wide RoundClamp integer codes, LSB-first within each byte and
//! with no padding between elements (`quant::pack::BitWriter`'s layout —
//! see `docs/MSQPACK.md` for the normative spec). Everything that
//! touches those codes — `serve::kernels::qgemm` row blocks,
//! `serve::kernels::qconv2d` filter decodes, and the native trainer's
//! RoundClamp fake-quant — goes through this module, so there is exactly
//! one statement of the bit layout and one statement of the RoundClamp
//! affine (`w = α·c + β`, [`rc_affine`]) in the codebase.
//!
//! [`decode_codes_f32`] is fast-pathed for the widths that dominate real
//! packs (8-bit at any phase, nibble-aligned 4-bit, byte-aligned 1-bit)
//! and falls back to a generic bit-buffer loop for everything else. The
//! fast paths are *pure specializations*: an exhaustive (bits 1..=8 ×
//! phase 0..=7) cross-check against the generic path lives in this
//! module's tests. Decoding widens integer codes exactly (codes < 2²⁴),
//! so decode results carry no rounding at all — every numeric choice
//! happens later, in the affine.

/// Decode `out.len()` consecutive `bits`-wide codes starting at absolute
/// bit offset `bit_off` of `data` (LSB-first within each byte, matching
/// `quant::pack::BitWriter`), widening each code to f32.
///
/// The caller must guarantee `bit_off + out.len() * bits` bits exist in
/// `data` (the serve registry validates payload sizes at load time).
pub fn decode_codes_f32(data: &[u8], bit_off: usize, bits: u8, out: &mut [f32]) {
    debug_assert!((1..=8).contains(&bits));
    let mut pos = bit_off / 8;
    let phase = (bit_off % 8) as u32;
    if bits == 8 {
        if phase == 0 {
            for (slot, &b) in out.iter_mut().zip(&data[pos..]) {
                *slot = b as f32;
            }
        } else {
            // every code straddles the same two-byte window at a fixed
            // phase: consume the leading partial byte and combine, no
            // bit-buffer loop (the fast path used to bail whenever
            // phase != 0 and fall through to the generic decoder)
            let hi = 8 - phase;
            for slot in out.iter_mut() {
                let c = ((data[pos] as u32) >> phase) | (((data[pos + 1] as u32) << hi) & 0xFF);
                *slot = c as f32;
                pos += 1;
            }
        }
        return;
    }
    if bits == 4 && phase % 4 == 0 {
        // nibble-aligned: two codes per byte (a leading high nibble when
        // the offset lands mid-byte, a trailing low nibble when the
        // count is odd)
        let mut i = 0;
        if phase == 4 && !out.is_empty() {
            out[0] = (data[pos] >> 4) as f32;
            pos += 1;
            i = 1;
        }
        while i + 2 <= out.len() {
            let b = data[pos];
            pos += 1;
            out[i] = (b & 0x0F) as f32;
            out[i + 1] = (b >> 4) as f32;
            i += 2;
        }
        if i < out.len() {
            out[i] = (data[pos] & 0x0F) as f32;
        }
        return;
    }
    if bits == 1 && phase == 0 {
        // byte-aligned 1-bit (the extreme-sparsification case): eight
        // codes per byte, unrolled
        let mut chunks = out.chunks_exact_mut(8);
        for ch in &mut chunks {
            let b = data[pos];
            pos += 1;
            for (l, slot) in ch.iter_mut().enumerate() {
                *slot = ((b >> l) & 1) as f32;
            }
        }
        let rem = chunks.into_remainder();
        if !rem.is_empty() {
            let b = data[pos];
            for (l, slot) in rem.iter_mut().enumerate() {
                *slot = ((b >> l) & 1) as f32;
            }
        }
        return;
    }
    decode_codes_generic(data, bit_off, bits, out);
}

/// The generic bit-buffer decoder: correct for every `bits` ∈ 1..=8 at
/// every phase, with no specializations. The fast paths above must agree
/// with it bit-for-bit on their whole domain (pinned exhaustively in
/// this module's tests) — it is the semantic definition of the layout.
fn decode_codes_generic(data: &[u8], bit_off: usize, bits: u8, out: &mut [f32]) {
    let mut pos = bit_off / 8;
    let phase = (bit_off % 8) as u32;
    let mut cur: u64 = 0;
    let mut nbits: u32 = 0;
    if phase != 0 {
        cur = (data[pos] >> phase) as u64;
        nbits = 8 - phase;
        pos += 1;
    }
    let width = bits as u32;
    let mask = (1u64 << width) - 1;
    for slot in out.iter_mut() {
        while nbits < width {
            cur |= (data[pos] as u64) << nbits;
            pos += 1;
            nbits += 8;
        }
        *slot = (cur & mask) as f32;
        cur >>= width;
        nbits -= width;
    }
}

/// The RoundClamp dequantization affine, `w = α·c + β` with
/// `α = 2s / (2ⁿ − 1)` and `β = −s` (paper Eq. 4 rearranged around the
/// integer code). Returns `(α, β)`.
///
/// This is THE statement of the code → weight map: `qgemm`/`qconv2d`
/// fold it out of their inner loops (`y = α·Σ c·x + β·Σ x`), the native
/// trainer's fake-quant applies it elementwise via [`dequant_affine`],
/// and `quant::pack::unpack_layer`'s closed form is equal to it up to
/// one ulp of association. `bits` is f32 because bit-widths are runtime
/// tensors in the training path; for the integral 1..=8 the serving path
/// uses, `2ⁿ − 1` is exact in f32, so serving and training agree on α
/// exactly.
#[inline]
pub fn rc_affine(bits: f32, scale: f32) -> (f32, f32) {
    // Integral widths — the serving path, and every real training
    // schedule — take the exact integer denominator: `f32::exp2`'s
    // precision is platform-dependent per the Rust docs, and the
    // serving lattice must be identical on every host. exp2 only
    // serves fractional runtime widths.
    let denom = if bits.fract() == 0.0 && (1.0..=24.0).contains(&bits) {
        ((1u64 << bits as u32) - 1) as f32
    } else {
        (bits.exp2() - 1.0).max(1.0)
    };
    (2.0 * scale / denom, -scale)
}

/// Apply a dequantization affine in place: `codes[i] = α·codes[i] + β`.
/// Elementwise, so bit-identical across scalar/SIMD builds for free.
#[inline]
pub fn dequant_affine(codes: &mut [f32], alpha: f32, beta: f32) {
    for c in codes.iter_mut() {
        *c = alpha * *c + beta;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::pack::pack_layer;
    use crate::util::prng::Rng;

    fn rand_vec(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| r.normal() * 0.5).collect()
    }

    /// Bit-level reference: extract the `bits`-wide code at absolute bit
    /// offset `off` straight from the byte stream, one bit at a time.
    fn code_at(data: &[u8], off: usize, bits: u8) -> u32 {
        let mut v = 0u32;
        for i in 0..bits as usize {
            let bit = off + i;
            v |= (((data[bit / 8] >> (bit % 8)) & 1) as u32) << i;
        }
        v
    }

    #[test]
    fn decode_matches_bitreader_at_any_offset() {
        for bits in 1u8..=8 {
            let cols = 13; // 13*bits is non-byte-aligned for most bits
            let rows = 7;
            let w = rand_vec(rows * cols, bits as u64);
            let p = pack_layer("l", &w, bits);
            // reference: sequential pull of every code
            let mut br = crate::quant::pack::BitReader::new(&p.data);
            let reference: Vec<f32> = (0..rows * cols).map(|_| br.pull(bits) as f32).collect();
            // decode each row independently at its bit offset
            let mut row = vec![0f32; cols];
            for r in 0..rows {
                decode_codes_f32(&p.data, r * cols * bits as usize, bits, &mut row);
                assert_eq!(&row[..], &reference[r * cols..(r + 1) * cols], "bits {bits} row {r}");
            }
        }
    }

    #[test]
    fn decode_8bit_handles_unaligned_offsets() {
        // regression: the 8-bit fast path used to be skipped whenever the
        // bit offset had a nonzero phase; the fixed path must match the
        // generic decoder at every phase 0..8
        let mut r = Rng::new(77);
        let data: Vec<u8> = (0..64).map(|_| (r.next_u64() & 0xFF) as u8).collect();
        for off in 0..16 {
            let n = 40; // 40 codes of 8 bits from `off`
            let mut out = vec![0f32; n];
            decode_codes_f32(&data, off, 8, &mut out);
            for (i, &got) in out.iter().enumerate() {
                let expect = code_at(&data, off + 8 * i, 8) as f32;
                assert_eq!(got, expect, "off {off} code {i}");
            }
        }
    }

    #[test]
    fn decode_all_bits_at_all_phases() {
        let mut r = Rng::new(78);
        let data: Vec<u8> = (0..96).map(|_| (r.next_u64() & 0xFF) as u8).collect();
        for bits in 1u8..=8 {
            for off in 0..24 {
                let n = 25;
                let mut out = vec![0f32; n];
                decode_codes_f32(&data, off, bits, &mut out);
                for (i, &got) in out.iter().enumerate() {
                    let expect = code_at(&data, off + bits as usize * i, bits) as f32;
                    assert_eq!(got, expect, "bits {bits} off {off} code {i}");
                }
            }
        }
    }

    #[test]
    fn fast_paths_agree_with_generic_on_every_bits_phase_pair() {
        // exhaustive (bits 1..=8) × (phase 0..=7) × assorted counts —
        // including 0, 1, and odd counts that end mid-byte — so every
        // specialized branch above is checked against the generic
        // bit-buffer decoder over its whole dispatch domain
        let mut r = Rng::new(79);
        let data: Vec<u8> = (0..128).map(|_| (r.next_u64() & 0xFF) as u8).collect();
        for bits in 1u8..=8 {
            for phase in 0usize..8 {
                for n in [0usize, 1, 2, 7, 8, 9, 25, 40] {
                    let mut fast = vec![0f32; n];
                    let mut generic = vec![0f32; n];
                    decode_codes_f32(&data, phase, bits, &mut fast);
                    decode_codes_generic(&data, phase, bits, &mut generic);
                    assert_eq!(fast, generic, "bits {bits} phase {phase} n {n}");
                }
            }
        }
    }

    #[test]
    fn rc_affine_matches_integer_denominator_exactly() {
        // serving computes 2s/(2ⁿ−1) from the integer denominator; the
        // shared affine takes f32 bits (runtime tensors) — for every
        // integral width the serving path accepts they must be the SAME
        // f32, or serving and training would disagree on the lattice
        for bits in 1u8..=8 {
            for scale in [0.25f32, 1.0, 1.7] {
                let (alpha, beta) = rc_affine(bits as f32, scale);
                let denom = ((1u32 << bits) - 1).max(1) as f32;
                assert_eq!(alpha, 2.0 * scale / denom, "bits {bits}");
                assert_eq!(beta, -scale);
            }
        }
    }

    #[test]
    fn dequant_affine_matches_unpack_lattice() {
        // α·c + β must land on the same lattice as pack's closed-form
        // dequant (from_unit(c/(2ⁿ−1))) up to association error
        for bits in [1u8, 3, 8] {
            let w = rand_vec(64, 40 + bits as u64);
            let p = pack_layer("l", &w, bits);
            let wq = crate::quant::pack::unpack_layer(&p).unwrap();
            let mut codes = vec![0f32; 64];
            decode_codes_f32(&p.data, 0, bits, &mut codes);
            let (alpha, beta) = rc_affine(bits as f32, p.scale);
            dequant_affine(&mut codes, alpha, beta);
            for (i, (a, e)) in codes.iter().zip(&wq).enumerate() {
                assert!(
                    (a - e).abs() <= 1e-6 * p.scale.max(1.0),
                    "bits {bits} idx {i}: {a} vs {e}"
                );
            }
        }
    }
}
