//! The shared SIMD/tiled kernel core under every hot loop in the repo.
//!
//! Before this module existed, the serving kernels
//! (`serve::kernels::{qgemm, qconv2d}`) and the native training kernels
//! (`native::ops`) each carried their own scalar-unrolled inner loops
//! and their own copy of the bit-stream decode. Now both sit on one
//! core:
//!
//! * [`simd`] — lane-structured `dot` / `sum` / `axpy` primitives:
//!   `std::simd` vectors behind the `simd` cargo feature (nightly), a
//!   scalar twin otherwise, **bit-identical by construction** (same
//!   lanes, same reduction tree, same remainder handling);
//! * [`decode`] — the one statement of the `.msqpack` n-bit code layout
//!   (`decode_codes_f32`, fast-pathed for 8/4/1-bit) and of the
//!   RoundClamp dequant affine (`rc_affine` / `dequant_affine`) shared
//!   by qgemm, qconv2d, and the native fake-quant forward;
//! * [`gemm`] — cache-blocked transposed-B matmul microkernels
//!   (forward + both backward accumulations) tiled over
//!   [`gemm::ROW_TILE`]×[`gemm::COL_TILE`] blocks;
//! * [`conv`] — conv2d window geometry ([`conv::krange`] clipping) and
//!   receptive-field microkernels over NHWC×OHWI, shared verbatim by
//!   serving and training so exported packs stay byte-faithful to what
//!   the serve kernels execute;
//! * [`norm`] — softmax / affine-free LayerNorm / GELU microkernels:
//!   transcendentals scalar per element, reductions through [`simd`];
//! * [`qgemm_int`] — the integer-domain (`--int8`) primitives:
//!   observer-calibrated activation quantization ([`ActQuant`]),
//!   u8×u8→i32 dot/sum, and the u8 twins of the conv window
//!   microkernels, with the zero-point correction folded into the
//!   per-output Σx term;
//! * [`attn`] — the multi-head self-attention core over projected
//!   Q/K/V activations, shared by `serve::kernels::qattention` and the
//!   native ViT trainer.
//!
//! **Bit-exactness contract.** Kernels parallelize by partitioning
//! *output cells* across thread-pool tasks and tile only to re-schedule
//! whole per-element reductions; every output element is produced by
//! exactly one lane-structured reduction whose operation order is fixed
//! in [`simd`]. Consequently, for every kernel in this tree:
//! {serial, pooled} × {scalar, simd} all produce identical bits. The
//! serving path's property tests assert the pooled/serial half directly;
//! the scalar/simd half is pinned by [`simd`]'s lane-reference tests
//! running unchanged under both CI matrix entries.
//!
//! Threading model: callers pass `Option<&ThreadPool>`; `None` (or a
//! problem under the `PAR_MIN_FLOPS` threshold) runs serially on the
//! caller's thread. Parallel tasks write disjoint output rows through a
//! raw pointer (`SendPtr`) — sound because blocks never overlap and the
//! output buffer outlives the scoped `par_for`.

pub mod attn;
pub mod conv;
pub mod decode;
pub mod gemm;
pub mod norm;
pub mod qgemm_int;
pub mod simd;

pub use attn::mha_forward_sample;
pub use conv::{conv2d_forward_sample, krange, window_dot, window_sum};
pub use decode::{decode_codes_f32, decode_codes_u8, dequant_affine, rc_affine};
pub use gemm::{matmul_acc, matmul_bt, matmul_t_acc};
pub use norm::{gelu, gelu_grad, gelu_slice, layernorm_row, layernorm_rows, softmax_rows, LN_EPS};
pub use qgemm_int::{dot_u8, sum_u8, window_dot_u8, window_sum_u8, ActQuant, MAX_INT_DOT_COLS};
pub use simd::{axpy, dot, sum, LANES};

use crate::util::threadpool::ThreadPool;

/// Problems under this many flops run serially even when a pool is
/// offered — a dispatch round-trip costs more than the work.
pub(crate) const PAR_MIN_FLOPS: usize = 16_384;

/// Raw output pointer smuggled into scoped parallel-fors. Tasks write
/// disjoint cells (each kernel's SAFETY comment states the partition),
/// so the aliasing is sound.
pub(crate) struct SendPtr(pub *mut f32);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

impl SendPtr {
    pub(crate) fn get(&self) -> *mut f32 {
        self.0
    }
}

/// Dispatch `f(0..nblocks)` over the pool's resident workers, or run it
/// serially when no pool is given, the problem is a single block, or the
/// work is too small to amortize dispatch. The SAME closure runs on both
/// paths, which is how every kernel keeps pooled == serial bitwise.
pub(crate) fn par_blocks(
    pool: Option<&ThreadPool>,
    nblocks: usize,
    min_flops: usize,
    f: impl Fn(usize) + Sync,
) {
    match pool {
        Some(p) if nblocks > 1 && min_flops >= PAR_MIN_FLOPS => p.par_for(nblocks, f),
        _ => {
            for b in 0..nblocks {
                f(b);
            }
        }
    }
}
