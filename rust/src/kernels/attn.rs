//! Multi-head self-attention core shared by the serving kernel
//! (`serve::kernels::qattention`) and the native trainer.
//!
//! Operates on one sample's already-projected Q/K/V activations, each a
//! row-major `s × d` matrix with `d = heads · head_dim` and heads
//! concatenated along the feature axis — so the per-head row slice
//! `q[i·d + h·hd .. +hd]` is **contiguous**, and every score reduction
//! runs through the shared lane-structured [`super::simd::dot`]. The
//! probability-weighted context accumulates through [`super::simd::axpy`]
//! in fixed ascending-key order. Together with the scalar softmax in
//! [`super::norm`], that makes the whole attention block bit-identical
//! across {serial, pooled} × {scalar, simd} — callers parallelize over
//! samples (disjoint outputs) only.

use super::norm::softmax_rows;
use super::simd::{axpy, dot};

/// Self-attention for one sample: `ctx = softmax(Q·Kᵀ/√hd)·V` per head,
/// heads concatenated back to `s × d`. `q`/`k`/`v`/`ctx` are all
/// `s × d` row-major with `d = heads · head_dim`. When `probs_out` is
/// given (training cache) it receives the `heads · s · s` softmax
/// matrices, head-major.
pub fn mha_forward_sample(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    s: usize,
    heads: usize,
    head_dim: usize,
    ctx: &mut [f32],
    mut probs_out: Option<&mut [f32]>,
) {
    let d = heads * head_dim;
    assert_eq!(q.len(), s * d, "mha: q is {} for {s}x{d}", q.len());
    assert_eq!(k.len(), s * d);
    assert_eq!(v.len(), s * d);
    assert_eq!(ctx.len(), s * d);
    if let Some(p) = probs_out.as_deref() {
        assert_eq!(p.len(), heads * s * s, "mha: probs cache is {}", p.len());
    }
    let scale = 1.0 / (head_dim as f32).sqrt();
    let mut scores = vec![0f32; s * s];
    for h in 0..heads {
        let o = h * head_dim;
        for i in 0..s {
            let qi = &q[i * d + o..i * d + o + head_dim];
            for j in 0..s {
                scores[i * s + j] = dot(qi, &k[j * d + o..j * d + o + head_dim]) * scale;
            }
        }
        softmax_rows(&mut scores, s, s);
        for i in 0..s {
            let out = &mut ctx[i * d + o..i * d + o + head_dim];
            out.fill(0.0);
            for j in 0..s {
                axpy(scores[i * s + j], &v[j * d + o..j * d + o + head_dim], out);
            }
        }
        if let Some(p) = probs_out.as_deref_mut() {
            p[h * s * s..(h + 1) * s * s].copy_from_slice(&scores);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    /// Straight-line f64 reference with naive reductions.
    fn ref_mha(q: &[f32], k: &[f32], v: &[f32], s: usize, heads: usize, hd: usize) -> Vec<f64> {
        let d = heads * hd;
        let mut ctx = vec![0f64; s * d];
        for h in 0..heads {
            let o = h * hd;
            for i in 0..s {
                let mut row = vec![0f64; s];
                for (j, rj) in row.iter_mut().enumerate() {
                    let mut acc = 0f64;
                    for t in 0..hd {
                        acc += q[i * d + o + t] as f64 * k[j * d + o + t] as f64;
                    }
                    *rj = acc / (hd as f64).sqrt();
                }
                let max = row.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                let exps: Vec<f64> = row.iter().map(|x| (x - max).exp()).collect();
                let z: f64 = exps.iter().sum();
                for t in 0..hd {
                    let mut acc = 0f64;
                    for (j, e) in exps.iter().enumerate() {
                        acc += e / z * v[j * d + o + t] as f64;
                    }
                    ctx[i * d + o + t] = acc;
                }
            }
        }
        ctx
    }

    #[test]
    fn matches_f64_reference() {
        let (s, heads, hd) = (5, 2, 4);
        let d = heads * hd;
        let mut rng = Rng::new(31);
        let q: Vec<f32> = (0..s * d).map(|_| rng.normal()).collect();
        let k: Vec<f32> = (0..s * d).map(|_| rng.normal()).collect();
        let v: Vec<f32> = (0..s * d).map(|_| rng.normal()).collect();
        let mut ctx = vec![0f32; s * d];
        mha_forward_sample(&q, &k, &v, s, heads, hd, &mut ctx, None);
        let want = ref_mha(&q, &k, &v, s, heads, hd);
        for (a, b) in ctx.iter().zip(&want) {
            assert!((*a as f64 - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn probs_cache_rows_sum_to_one() {
        let (s, heads, hd) = (4, 3, 2);
        let d = heads * hd;
        let mut rng = Rng::new(7);
        let q: Vec<f32> = (0..s * d).map(|_| rng.normal()).collect();
        let k: Vec<f32> = (0..s * d).map(|_| rng.normal()).collect();
        let v: Vec<f32> = (0..s * d).map(|_| rng.normal()).collect();
        let mut ctx = vec![0f32; s * d];
        let mut probs = vec![0f32; heads * s * s];
        mha_forward_sample(&q, &k, &v, s, heads, hd, &mut ctx, Some(&mut probs));
        for h in 0..heads {
            for i in 0..s {
                let sum: f32 = probs[h * s * s + i * s..h * s * s + (i + 1) * s].iter().sum();
                assert!((sum - 1.0).abs() < 1e-5, "head {h} row {i}: {sum}");
            }
        }
    }

    #[test]
    fn single_token_attention_is_identity_on_v() {
        // s = 1: softmax over one score is 1.0, so ctx == v exactly
        let (heads, hd) = (2, 3);
        let d = heads * hd;
        let q = vec![0.5f32; d];
        let k = vec![-0.25f32; d];
        let v: Vec<f32> = (0..d).map(|i| i as f32 - 2.0).collect();
        let mut ctx = vec![0f32; d];
        mha_forward_sample(&q, &k, &v, 1, heads, hd, &mut ctx, None);
        assert_eq!(ctx, v);
    }

    #[test]
    fn huge_projected_values_stay_finite() {
        // large Q·K products exercise the softmax stability path end-to-end
        let (s, heads, hd) = (3, 1, 8);
        let d = hd;
        let q = vec![1e18f32; s * d];
        let k = vec![1e18f32; s * d];
        let v = vec![0.5f32; s * d];
        let mut ctx = vec![0f32; s * d];
        mha_forward_sample(&q, &k, &v, s, heads, hd, &mut ctx, None);
        assert!(ctx.iter().all(|x| x.is_finite()), "{ctx:?}");
    }
}
