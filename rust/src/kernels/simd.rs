//! Portable SIMD lane primitives — `dot`, `sum`, `axpy` — with a scalar
//! fallback that is **bit-identical** to the vector path.
//!
//! Every reduction in the kernel core is *lane-structured*: inputs are
//! consumed in chunks of [`LANES`] elements, each lane keeps its own
//! f32 accumulator, the lane accumulators collapse through one fixed
//! reduction tree (`reduce`'s `((l0+l1)+(l2+l3)) + ((l4+l5)+(l6+l7))`
//! shape), and the sub-[`LANES`] remainder is folded in serially. Both
//! implementations below perform *exactly* this sequence of IEEE-754
//! operations:
//!
//! * with `--features simd` (nightly, `std::simd`), the lane
//!   accumulators live in one `f32x8` register and the per-lane
//!   multiply/add happen as vector ops;
//! * in the default build, the lane accumulators are a `[f32; 8]`
//!   array and the compiler's autovectorizer is free to (and usually
//!   does) emit the same vector code.
//!
//! Per-lane IEEE arithmetic is deterministic and Rust never contracts
//! `a * b + c` into an FMA, so the two builds compute identical bits
//! for every input. That guarantee is what lets the quantized serving
//! path promise bit-identical logits across {serial, pooled} ×
//! {scalar, simd} configurations: parallelism partitions *outputs*
//! (never a reduction), and each output's reduction order is fixed
//! here. The tests at the bottom pin the lane structure itself — they
//! compare against an explicitly lane-structured reference that is
//! feature-independent, so the suite passing under both CI matrix
//! entries certifies cross-build equality.

/// Lane width of the kernel core's reduction structure. Fixed at 8
/// (256-bit f32 vectors) regardless of target: changing it would change
/// summation order, i.e. the numerical identity of every kernel.
pub const LANES: usize = 8;

/// The one reduction tree lane accumulators collapse through.
#[inline]
fn reduce(a: [f32; LANES]) -> f32 {
    ((a[0] + a[1]) + (a[2] + a[3])) + ((a[4] + a[5]) + (a[6] + a[7]))
}

/// Lane-structured dot product: `Σ_i a[i]·b[i]` with [`LANES`]
/// accumulators and the fixed `reduce` tree. The slices must have equal
/// lengths (every kernel-core caller guarantees it).
#[cfg(feature = "simd")]
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    use std::simd::f32x8;
    debug_assert_eq!(a.len(), b.len());
    let split = a.len() & !(LANES - 1);
    let (av, ar) = a.split_at(split);
    let (bv, br) = b.split_at(split);
    let mut acc = f32x8::splat(0.0);
    for (ca, cb) in av.chunks_exact(LANES).zip(bv.chunks_exact(LANES)) {
        acc += f32x8::from_slice(ca) * f32x8::from_slice(cb);
    }
    let mut s = reduce(acc.to_array());
    for (x, y) in ar.iter().zip(br) {
        s += x * y;
    }
    s
}

/// Scalar twin of the SIMD `dot`: same lanes, same tree, same bits.
#[cfg(not(feature = "simd"))]
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let split = a.len() & !(LANES - 1);
    let (av, ar) = a.split_at(split);
    let (bv, br) = b.split_at(split);
    let mut acc = [0f32; LANES];
    for (ca, cb) in av.chunks_exact(LANES).zip(bv.chunks_exact(LANES)) {
        for ((l, &x), &y) in acc.iter_mut().zip(ca).zip(cb) {
            *l += x * y;
        }
    }
    let mut s = reduce(acc);
    for (x, y) in ar.iter().zip(br) {
        s += x * y;
    }
    s
}

/// Lane-structured horizontal sum: `Σ_i x[i]`, same lane/tree shape as
/// [`dot`] (the serving kernels use it for the dequant `Σ x` correction,
/// which must stay bit-identical across builds too).
#[cfg(feature = "simd")]
#[inline]
pub fn sum(x: &[f32]) -> f32 {
    use std::simd::f32x8;
    let split = x.len() & !(LANES - 1);
    let (xv, xr) = x.split_at(split);
    let mut acc = f32x8::splat(0.0);
    for c in xv.chunks_exact(LANES) {
        acc += f32x8::from_slice(c);
    }
    let mut s = reduce(acc.to_array());
    for v in xr {
        s += v;
    }
    s
}

/// Scalar twin of the SIMD `sum`: same lanes, same tree, same bits.
#[cfg(not(feature = "simd"))]
#[inline]
pub fn sum(x: &[f32]) -> f32 {
    let split = x.len() & !(LANES - 1);
    let (xv, xr) = x.split_at(split);
    let mut acc = [0f32; LANES];
    for c in xv.chunks_exact(LANES) {
        for (l, &v) in acc.iter_mut().zip(c) {
            *l += v;
        }
    }
    let mut s = reduce(acc);
    for v in xr {
        s += v;
    }
    s
}

/// `out[i] += g · x[i]`. Elementwise — each output element sees exactly
/// one multiply and one add regardless of chunking, so the SIMD and
/// scalar versions are trivially bit-identical. The backward kernels
/// (`dx += g·w` row scatters, `dw += g·x` outer accumulations) are built
/// from this.
#[cfg(feature = "simd")]
#[inline]
pub fn axpy(g: f32, x: &[f32], out: &mut [f32]) {
    use std::simd::f32x8;
    debug_assert_eq!(x.len(), out.len());
    let split = x.len() & !(LANES - 1);
    let (xv, xr) = x.split_at(split);
    let (ov, or) = out.split_at_mut(split);
    let vg = f32x8::splat(g);
    for (co, cx) in ov.chunks_exact_mut(LANES).zip(xv.chunks_exact(LANES)) {
        let r = f32x8::from_slice(co) + vg * f32x8::from_slice(cx);
        r.copy_to_slice(co);
    }
    for (o, &v) in or.iter_mut().zip(xr) {
        *o += g * v;
    }
}

/// Scalar twin of the SIMD `axpy` (elementwise, so identity is free).
#[cfg(not(feature = "simd"))]
#[inline]
pub fn axpy(g: f32, x: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len(), out.len());
    for (o, &v) in out.iter_mut().zip(x) {
        *o += g * v;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn rand(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| r.normal()).collect()
    }

    /// Feature-independent statement of the lane contract: LANES
    /// accumulators over full chunks, the fixed reduction tree, serial
    /// remainder. Both the scalar and the SIMD `dot`/`sum` must equal
    /// this *bitwise* — the same reference compiles identically in both
    /// builds, so the suite passing under `--features simd` and the
    /// default build proves the two builds agree with each other.
    fn lane_dot_ref(a: &[f32], b: &[f32]) -> f32 {
        let split = a.len() & !(LANES - 1);
        let mut acc = [0f32; LANES];
        for i in (0..split).step_by(LANES) {
            for l in 0..LANES {
                acc[l] += a[i + l] * b[i + l];
            }
        }
        let mut s = ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
        for i in split..a.len() {
            s += a[i] * b[i];
        }
        s
    }

    fn lane_sum_ref(x: &[f32]) -> f32 {
        let split = x.len() & !(LANES - 1);
        let mut acc = [0f32; LANES];
        for i in (0..split).step_by(LANES) {
            for l in 0..LANES {
                acc[l] += x[i + l];
            }
        }
        let mut s = ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
        for v in &x[split..] {
            s += v;
        }
        s
    }

    #[test]
    fn dot_matches_lane_reference_at_every_remainder() {
        for n in 0..40 {
            let a = rand(n, 100 + n as u64);
            let b = rand(n, 200 + n as u64);
            assert_eq!(dot(&a, &b), lane_dot_ref(&a, &b), "len {n}");
        }
    }

    #[test]
    fn dot_is_accurate() {
        let a: Vec<f32> = (0..11).map(|i| i as f32).collect();
        let b: Vec<f32> = (0..11).map(|i| (i * 2) as f32).collect();
        let expect: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert_eq!(dot(&a, &b), expect); // integers: every order is exact
    }

    #[test]
    fn sum_matches_lane_reference_at_every_remainder() {
        for n in 0..40 {
            let x = rand(n, 300 + n as u64);
            assert_eq!(sum(&x), lane_sum_ref(&x), "len {n}");
        }
    }

    #[test]
    fn axpy_is_elementwise_exact() {
        for n in 0..40 {
            let x = rand(n, 400 + n as u64);
            let base = rand(n, 500 + n as u64);
            let g = 0.37f32;
            let mut out = base.clone();
            axpy(g, &x, &mut out);
            for i in 0..n {
                assert_eq!(out[i], base[i] + g * x[i], "len {n} idx {i}");
            }
        }
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(dot(&[], &[]), 0.0);
        assert_eq!(sum(&[]), 0.0);
        let mut out: Vec<f32> = vec![];
        axpy(1.0, &[], &mut out);
    }
}
