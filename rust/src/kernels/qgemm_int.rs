//! Integer-domain kernel primitives for the `--int8` serving path.
//!
//! MSQ weights already live on a small-integer lattice — the float
//! kernels widen every `bits`-wide code to f32 only to multiply it by a
//! float activation. This module keeps the inner loop in integers:
//! activations are affine-quantized to u8 against an observer-calibrated
//! scale ([`ActQuant`]), weight codes stay u8 (via
//! `decode::decode_codes_u8`), and dot products accumulate in i32. The
//! zero-point correction folds into the same per-output Σx term the
//! float path already carries, so dequantization is one fused affine per
//! output element:
//!
//! ```text
//! x̂_j = s · (q_j − 128)                  (activation dequant)
//! y_r = α·Σ_j c_rj·x̂_j + β·Σ_j x̂_j      (the float path's identity)
//!     = (α·s)·(Σ c_rj·q_j − 128·Σ c_rj) + (β·s)·(Σ q_j − 128·n)
//! ```
//!
//! `Σ c·q` and the code sum `Σ c` come out of one i32 pass over the
//! decoded row; `Σ q` is one i32 pass per activation row. Integer sums
//! are order-independent, so serial ≡ pooled holds on this path without
//! any lane discipline — the float finalize runs exactly once per output
//! element.
//!
//! Accuracy: with calibration absmax `a ≥ max|x|`, each activation's
//! quantization error is ≤ `s/2 = a/254`, and since every dequantized
//! weight satisfies `|w| ≤ scale`, each output differs from the f32
//! kernel by at most `n · scale · s/2` (plus f32 roundoff) — the bound
//! the serving property tests pin.
//!
//! Overflow: `|Σ c·q| ≤ 255·255·n`, so i32 accumulation is exact for
//! `n ≤` [`MAX_INT_DOT_COLS`] (= 32768); the serving layer planner falls
//! back to the float kernels beyond that.

/// Largest reduction length the i32 accumulator handles without
/// overflow: `255 · 255 · 32768 < 2³¹`.
pub const MAX_INT_DOT_COLS: usize = 32_768;

/// Floor on the calibrated absmax so an all-zero calibration still
/// yields a usable (if meaningless) lattice instead of a zero scale.
const MIN_ABSMAX: f32 = 1e-12;

/// Observer-calibrated activation quantizer: symmetric range `[−a, a]`
/// mapped to u8 with a fixed zero point of 128, i.e.
/// `q = clamp(round(x/s) + 128, 0, 255)` with `s = a/127`.
///
/// The zero point is a constant by construction (symmetric calibration
/// — qstats tracks EMA *absmax*), which is what lets the correction
/// fold into the per-output sums instead of a per-lane subtraction.
/// `x = 0` maps to exactly 128 and back to exactly 0.
#[derive(Clone, Copy, Debug)]
pub struct ActQuant {
    /// Activation step `s = absmax/127` (> 0).
    pub scale: f32,
}

impl ActQuant {
    /// Quantizer covering `[−absmax, absmax]`.
    pub fn from_absmax(absmax: f32) -> ActQuant {
        ActQuant { scale: absmax.max(MIN_ABSMAX) / 127.0 }
    }

    /// The quantization step: inputs within the calibrated range
    /// round-trip within `step()/2` per element.
    pub fn step(&self) -> f32 {
        self.scale
    }

    /// Quantize one activation: `clamp(round(x/s) + 128, 0, 255)`.
    #[inline]
    pub fn quantize_one(&self, x: f32) -> u8 {
        ((x / self.scale).round() + 128.0).clamp(0.0, 255.0) as u8
    }

    /// Quantize a row of activations into `q` (same length).
    pub fn quantize(&self, x: &[f32], q: &mut [u8]) {
        debug_assert_eq!(x.len(), q.len());
        for (slot, &v) in q.iter_mut().zip(x) {
            *slot = self.quantize_one(v);
        }
    }

    /// Dequantize one code (test/debug helper): `s · (q − 128)`.
    pub fn dequantize_one(&self, q: u8) -> f32 {
        self.scale * (q as i32 - 128) as f32
    }
}

/// i32 dot product of two u8 rows. Exact for `a.len()` ≤
/// [`MAX_INT_DOT_COLS`]; order-independent, so pooled ≡ serial for free.
#[inline]
pub fn dot_u8(a: &[u8], b: &[u8]) -> i32 {
    debug_assert_eq!(a.len(), b.len());
    debug_assert!(a.len() <= MAX_INT_DOT_COLS);
    let mut acc = 0i32;
    for (&x, &y) in a.iter().zip(b) {
        acc += x as i32 * y as i32;
    }
    acc
}

/// i32 sum of a u8 row (code sums and activation `Σ q`).
#[inline]
pub fn sum_u8(a: &[u8]) -> i32 {
    let mut acc = 0i32;
    for &x in a {
        acc += x as i32;
    }
    acc
}

/// Integer twin of `conv::window_dot`: Σ w·q and Σ w over one clipped
/// receptive-field window of a u8 filter `wf` (OHWI row-major) against a
/// u8 activation map `qb` (NHWC, one sample). Geometry arguments match
/// `conv::window_dot` exactly — `seg == 0` yields `(0, 0)`.
///
/// The code sum must come from the *same clipped window* as the dot:
/// `krange` clipping varies per output position, so Σ w is per
/// (position, filter), not per filter.
#[allow(clippy::too_many_arguments)]
#[inline]
pub fn window_dot_u8(
    wf: &[u8],
    qb: &[u8],
    kw: usize,
    in_w: usize,
    in_ch: usize,
    ky0: usize,
    ky1: usize,
    iy0: usize,
    kx0: usize,
    ix0: usize,
    seg: usize,
) -> (i32, i32) {
    let (mut acc, mut wsum) = (0i32, 0i32);
    if seg == 0 {
        return (acc, wsum);
    }
    for ky in ky0..ky1 {
        let wrow = &wf[(ky * kw + kx0) * in_ch..][..seg];
        let xrow = &qb[((iy0 + (ky - ky0)) * in_w + ix0) * in_ch..][..seg];
        acc += dot_u8(wrow, xrow);
        wsum += sum_u8(wrow);
    }
    (acc, wsum)
}

/// Integer twin of `conv::window_sum`: Σ q and the tap count over one
/// clipped window of a u8 activation map — the per-position Σx̂ term
/// (and its element count for the zero-point correction).
#[allow(clippy::too_many_arguments)]
#[inline]
pub fn window_sum_u8(
    qb: &[u8],
    in_w: usize,
    in_ch: usize,
    ky0: usize,
    ky1: usize,
    iy0: usize,
    ix0: usize,
    seg: usize,
) -> (i32, i32) {
    let (mut qsum, mut count) = (0i32, 0i32);
    if seg == 0 {
        return (qsum, count);
    }
    for ky in ky0..ky1 {
        let xrow = &qb[((iy0 + (ky - ky0)) * in_w + ix0) * in_ch..][..seg];
        qsum += sum_u8(xrow);
        count += seg as i32;
    }
    (qsum, count)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::conv::{window_dot, window_sum};
    use crate::util::prng::Rng;

    #[test]
    fn act_quant_round_trips_within_half_step() {
        let aq = ActQuant::from_absmax(3.0);
        assert_eq!(aq.quantize_one(0.0), 128);
        assert_eq!(aq.dequantize_one(128), 0.0);
        assert_eq!(aq.quantize_one(3.0), 255);
        assert_eq!(aq.quantize_one(-3.0), 1);
        let mut r = Rng::new(9);
        for _ in 0..2000 {
            let x = r.normal().clamp(-3.0, 3.0);
            let back = aq.dequantize_one(aq.quantize_one(x));
            assert!(
                (back - x).abs() <= aq.step() / 2.0 + 1e-7,
                "{x} -> {back} (step {})",
                aq.step()
            );
        }
    }

    #[test]
    fn act_quant_clamps_out_of_range() {
        let aq = ActQuant::from_absmax(1.0);
        assert_eq!(aq.quantize_one(50.0), 255);
        assert_eq!(aq.quantize_one(-50.0), 0);
        assert_eq!(aq.quantize_one(f32::NAN), 0); // `as u8` saturates NaN to 0
        // zero-scale guard: absmax 0 still yields a positive step
        assert!(ActQuant::from_absmax(0.0).scale > 0.0);
    }

    #[test]
    fn integer_dot_matches_f32_reference() {
        let mut r = Rng::new(10);
        for n in [0usize, 1, 7, 64, 300] {
            let a: Vec<u8> = (0..n).map(|_| (r.next_u64() & 0xFF) as u8).collect();
            let b: Vec<u8> = (0..n).map(|_| (r.next_u64() & 0xFF) as u8).collect();
            let expect: i64 = a.iter().zip(&b).map(|(&x, &y)| x as i64 * y as i64).sum();
            assert_eq!(dot_u8(&a, &b) as i64, expect);
            let esum: i64 = a.iter().map(|&x| x as i64).sum();
            assert_eq!(sum_u8(&a) as i64, esum);
        }
    }

    #[test]
    fn window_twins_match_f32_windows() {
        // same geometry, u8 payloads widened to f32 for the reference —
        // the integer windows must agree exactly (values ≤ 255 are exact
        // in f32, so both sides are exact integers)
        let mut r = Rng::new(11);
        let (kh, kw, in_ch) = (3usize, 3usize, 4usize);
        let (in_h, in_w) = (5usize, 6usize);
        let wf_u8: Vec<u8> = (0..kh * kw * in_ch).map(|_| (r.next_u64() & 0xFF) as u8).collect();
        let qb_u8: Vec<u8> = (0..in_h * in_w * in_ch).map(|_| (r.next_u64() & 0xFF) as u8).collect();
        let wf_f: Vec<f32> = wf_u8.iter().map(|&v| v as f32).collect();
        let qb_f: Vec<f32> = qb_u8.iter().map(|&v| v as f32).collect();
        for (stride, pad) in [(1usize, 1usize), (2, 1), (1, 0)] {
            for oy in 0..in_h.div_ceil(stride) {
                for ox in 0..in_w.div_ceil(stride) {
                    let (ky0, ky1, iy0) = crate::kernels::krange(oy, stride, pad, kh, in_h);
                    let (kx0, kx1, ix0) = crate::kernels::krange(ox, stride, pad, kw, in_w);
                    let seg = (kx1 - kx0) * in_ch;
                    let (acc, wsum) = window_dot_u8(
                        &wf_u8, &qb_u8, kw, in_w, in_ch, ky0, ky1, iy0, kx0, ix0, seg,
                    );
                    let facc =
                        window_dot(&wf_f, &qb_f, kw, in_w, in_ch, ky0, ky1, iy0, kx0, ix0, seg);
                    assert_eq!(acc as f32, facc, "dot at ({oy},{ox}) s{stride} p{pad}");
                    let (qsum, count) =
                        window_sum_u8(&qb_u8, in_w, in_ch, ky0, ky1, iy0, ix0, seg);
                    let fsum = window_sum(&qb_f, in_w, in_ch, ky0, ky1, iy0, ix0, seg);
                    assert_eq!(qsum as f32, fsum, "sum at ({oy},{ox})");
                    assert_eq!(count as usize, (ky1 - ky0) * seg);
                    // wsum is the same clipped window's code sum
                    let mut expect_wsum = 0i32;
                    for ky in ky0..ky1 {
                        expect_wsum +=
                            sum_u8(&wf_u8[(ky * kw + kx0) * in_ch..][..seg]);
                    }
                    assert_eq!(wsum, expect_wsum);
                }
            }
        }
    }
}
