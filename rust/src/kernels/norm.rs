//! Softmax, LayerNorm, and GELU microkernels for the transformer ops.
//!
//! Same bit-exactness contract as the rest of the kernel core: every
//! reduction goes through the lane-structured [`super::simd`] primitives
//! (`sum` for denominators and means, `dot` for variances), every
//! transcendental (`exp`, `sqrt`, `tanh`) is applied scalar per element
//! in a fixed order, and nothing here branches on the `simd` feature —
//! so {serial, pooled} × {scalar, simd} all compute identical bits.
//! Callers parallelize over *rows* (disjoint outputs) only.

use super::simd::{dot, sum};

/// In-place numerically stable softmax over each of `rows` rows of
/// `cols` elements: subtract the row max before exponentiating, so
/// arbitrarily large logits never overflow (`exp(x - max) <= 1`).
/// Rows of `-inf`-free input always produce finite probabilities that
/// sum to ~1.
pub fn softmax_rows(x: &mut [f32], rows: usize, cols: usize) {
    assert_eq!(x.len(), rows * cols, "softmax_rows: {rows}x{cols} over {}", x.len());
    for r in 0..rows {
        let row = &mut x[r * cols..(r + 1) * cols];
        let max = row.iter().fold(f32::NEG_INFINITY, |a, &v| a.max(v));
        for v in row.iter_mut() {
            *v = (*v - max).exp();
        }
        let denom = sum(row);
        let inv = 1.0 / denom;
        for v in row.iter_mut() {
            *v *= inv;
        }
    }
}

/// Affine-free LayerNorm of one row: `out = (x − mean) / √(var + eps)`.
/// Returns `1/√(var + eps)` (training backwards cache). The packed
/// format stores no γ/β (it is bias-free by design), so the serving and
/// native paths both run the normalization alone.
pub fn layernorm_row(x: &[f32], eps: f32, out: &mut [f32]) -> f32 {
    let d = x.len();
    assert_eq!(out.len(), d, "layernorm_row: out {} for {d} inputs", out.len());
    if d == 0 {
        return 0.0;
    }
    let mean = sum(x) / d as f32;
    for (o, &v) in out.iter_mut().zip(x) {
        *o = v - mean;
    }
    let var = dot(out, out) / d as f32;
    let inv = 1.0 / (var + eps).sqrt();
    for o in out.iter_mut() {
        *o *= inv;
    }
    inv
}

/// Row-batched [`layernorm_row`] (serving path; the per-row `inv` is
/// discarded).
pub fn layernorm_rows(x: &[f32], rows: usize, cols: usize, eps: f32, out: &mut [f32]) {
    assert_eq!(x.len(), rows * cols, "layernorm_rows: {rows}x{cols} over {}", x.len());
    assert_eq!(out.len(), x.len());
    for r in 0..rows {
        layernorm_row(&x[r * cols..(r + 1) * cols], eps, &mut out[r * cols..(r + 1) * cols]);
    }
}

/// The LayerNorm epsilon both the serving executor and the native
/// trainer use — exported packs must normalize exactly as training did.
pub const LN_EPS: f32 = 1e-5;

/// GELU, tanh approximation (the ViT/BERT standard):
/// `0.5·x·(1 + tanh(√(2/π)·(x + 0.044715·x³)))`. Scalar per element.
#[inline]
pub fn gelu(x: f32) -> f32 {
    const C: f32 = 0.797_884_6; // √(2/π)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

/// d/dx of [`gelu`] (tanh approximation), used by the training backward.
#[inline]
pub fn gelu_grad(x: f32) -> f32 {
    const C: f32 = 0.797_884_6;
    let u = C * (x + 0.044715 * x * x * x);
    let t = u.tanh();
    let du = C * (1.0 + 3.0 * 0.044715 * x * x);
    0.5 * (1.0 + t) + 0.5 * x * (1.0 - t * t) * du
}

/// Apply [`gelu`] over a slice.
pub fn gelu_slice(x: &mut [f32]) {
    for v in x.iter_mut() {
        *v = gelu(*v);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut x = vec![0.1f32, 2.0, -1.0, 3.0, 0.0, 0.5];
        softmax_rows(&mut x, 2, 3);
        for r in 0..2 {
            let s: f32 = x[r * 3..(r + 1) * 3].iter().sum();
            assert!((s - 1.0).abs() < 1e-5, "row {r} sums to {s}");
            assert!(x[r * 3..(r + 1) * 3].iter().all(|&p| (0.0..=1.0).contains(&p)));
        }
    }

    #[test]
    fn softmax_is_stable_under_huge_logits() {
        // raw exp would overflow f32 at ~88; max-subtraction must keep
        // everything finite for logits far beyond that, both signs
        for &scale in &[100.0f32, 1e4, 1e8, 3e38] {
            let mut x = vec![scale, scale - 1.0, scale - 2.0, -scale];
            softmax_rows(&mut x, 1, 4);
            assert!(x.iter().all(|v| v.is_finite()), "scale {scale}: {x:?}");
            let s: f32 = x.iter().sum();
            assert!((s - 1.0).abs() < 1e-5, "scale {scale}: sum {s}");
            assert!(x[0] > x[1] && x[1] > x[2], "ordering lost at {scale}: {x:?}");
        }
    }

    #[test]
    fn softmax_matches_f64_reference() {
        let logits = [0.3f32, -1.2, 2.5, 0.0, 1.1];
        let mut x = logits.to_vec();
        softmax_rows(&mut x, 1, 5);
        let max = logits.iter().cloned().fold(f64::NEG_INFINITY, |a, v| a.max(v as f64));
        let exps: Vec<f64> = logits.iter().map(|&v| ((v as f64) - max).exp()).collect();
        let z: f64 = exps.iter().sum();
        for (got, want) in x.iter().zip(exps.iter().map(|e| e / z)) {
            assert!((*got as f64 - want).abs() < 1e-6, "{got} vs {want}");
        }
    }

    #[test]
    fn layernorm_zero_mean_unit_var() {
        let x = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0];
        let mut out = vec![0f32; 8];
        let inv = layernorm_row(&x, LN_EPS, &mut out);
        assert!(inv > 0.0);
        let mean: f32 = out.iter().sum::<f32>() / 8.0;
        let var: f32 = out.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 8.0;
        assert!(mean.abs() < 1e-6, "mean {mean}");
        assert!((var - 1.0).abs() < 1e-3, "var {var}");
    }

    #[test]
    fn layernorm_constant_row_is_finite() {
        // zero variance: eps keeps the inverse finite, output all zeros
        let x = vec![3.5f32; 6];
        let mut out = vec![1.0f32; 6];
        layernorm_row(&x, LN_EPS, &mut out);
        assert!(out.iter().all(|v| v.is_finite() && v.abs() < 1e-3), "{out:?}");
    }

    #[test]
    fn gelu_known_values_and_limits() {
        assert_eq!(gelu(0.0), 0.0);
        // gelu(x) → x for large x, → 0 for very negative x
        assert!((gelu(10.0) - 10.0).abs() < 1e-4);
        assert!(gelu(-10.0).abs() < 1e-4);
        // tanh-approx reference value at 1.0: 0.5·(1 + tanh(0.8412)) ≈ 0.8412
        assert!((gelu(1.0) - 0.841192).abs() < 1e-4, "{}", gelu(1.0));
        // monotone on a coarse grid
        let mut prev = f32::NEG_INFINITY;
        for i in -40..=40 {
            let v = gelu(i as f32 * 0.25);
            assert!(v >= prev - 1e-6);
            prev = v;
        }
    }

    #[test]
    fn gelu_grad_matches_fd() {
        for i in -20..=20 {
            let x = i as f32 * 0.3;
            let eps = 1e-3f32;
            let fd = (gelu(x + eps) - gelu(x - eps)) / (2.0 * eps);
            let an = gelu_grad(x);
            assert!((fd - an).abs() < 1e-3, "x={x}: fd {fd} vs {an}");
        }
    }
}
