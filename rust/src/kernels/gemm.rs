//! Cache-blocked f32 matmul microkernels (transposed-B convention).
//!
//! All three kernels share the layout every layer in this repo uses:
//! activations `m × k` batch-major, weights `n × k` row-major (`n`
//! outputs, `k` inputs — the pack/serve layout), so the innermost loop
//! always runs over contiguous memory on both sides and vectorizes
//! through [`super::simd`]'s lane primitives.
//!
//! Tiling: work parallelizes over blocks of [`ROW_TILE`] *output* rows
//! (disjoint writes, so pooled and serial execution are bit-identical by
//! construction), and within a block the `n`-side streams in
//! [`COL_TILE`]-row tiles so each weight row loaded into cache is reused
//! across the whole row block before being evicted. Tiling and
//! parallelism only re-*schedule* whole per-element reductions — each
//! output element is still produced by exactly one lane-structured
//! [`dot`] (or a fixed sequence of [`axpy`]s in the accumulating
//! kernels), so blocking never changes a single bit of the result.
//!
//! Used by `native::ops::{linear_forward, linear_backward_input,
//! linear_backward_weight}` (the training hot path) and benchmarked
//! head-to-head against a naive scalar triple loop in
//! `benches/train_throughput.rs`.

use crate::util::threadpool::ThreadPool;

use super::simd::{axpy, dot};
use super::{par_blocks, SendPtr};

/// Output rows per parallel task (and per cache tile): big enough to
/// amortize dispatch, small enough to balance across cores.
pub const ROW_TILE: usize = 8;

/// Weight rows per inner tile: `COL_TILE · k` floats of `w` stay hot
/// while a row block consumes them.
pub const COL_TILE: usize = 64;

/// `out[i,j] = Σ_t x[i,t]·w[j,t] (+ bias[j])` — `x` is `m×k`, `w` is
/// `n×k`, `out` is `m×n`. With `pool`, row blocks run in parallel;
/// results are bit-identical to the serial path.
#[allow(clippy::too_many_arguments)]
pub fn matmul_bt(
    x: &[f32],
    w: &[f32],
    bias: Option<&[f32]>,
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
    pool: Option<&ThreadPool>,
) {
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(w.len(), n * k);
    debug_assert_eq!(out.len(), m * n);
    if let Some(b) = bias {
        debug_assert_eq!(b.len(), n);
    }
    let optr = SendPtr(out.as_mut_ptr());
    let optr = &optr;
    par_blocks(pool, m.div_ceil(ROW_TILE), m * n * k, |blk| {
        let i0 = blk * ROW_TILE;
        let i1 = (i0 + ROW_TILE).min(m);
        // SAFETY: rows i0..i1 of `out` belong to exactly this block, so
        // concurrent blocks write disjoint cells; `out` outlives the
        // scoped par_for and nobody reads it until par_blocks returns.
        let orows =
            unsafe { std::slice::from_raw_parts_mut(optr.get().add(i0 * n), (i1 - i0) * n) };
        let mut j0 = 0;
        while j0 < n {
            let j1 = (j0 + COL_TILE).min(n);
            for j in j0..j1 {
                let wj = &w[j * k..(j + 1) * k];
                let bj = bias.map_or(0.0, |b| b[j]);
                for i in i0..i1 {
                    orows[(i - i0) * n + j] = dot(&x[i * k..(i + 1) * k], wj) + bj;
                }
            }
            j0 = j1;
        }
    });
}

/// `dx[i,t] += Σ_j dy[i,j]·w[j,t]` — `dy` is `m×n`, `w` is `n×k`, `dx`
/// is `m×k` (the linear backward-input kernel). Rows of `dx` are
/// disjoint across blocks; within a row, contributions land in ascending
/// `j` order on every path, so pooled == serial bitwise.
pub fn matmul_acc(
    dy: &[f32],
    w: &[f32],
    m: usize,
    k: usize,
    n: usize,
    dx: &mut [f32],
    pool: Option<&ThreadPool>,
) {
    debug_assert_eq!(dy.len(), m * n);
    debug_assert_eq!(w.len(), n * k);
    debug_assert_eq!(dx.len(), m * k);
    let dxp = SendPtr(dx.as_mut_ptr());
    let dxp = &dxp;
    par_blocks(pool, m.div_ceil(ROW_TILE), m * n * k, |blk| {
        let i0 = blk * ROW_TILE;
        let i1 = (i0 + ROW_TILE).min(m);
        // SAFETY: rows i0..i1 of `dx` are written only by this block (see
        // matmul_bt)
        let dxrows =
            unsafe { std::slice::from_raw_parts_mut(dxp.get().add(i0 * k), (i1 - i0) * k) };
        // j outer so each weight row is reused across the whole row
        // block while hot
        for j in 0..n {
            let wj = &w[j * k..(j + 1) * k];
            for i in i0..i1 {
                let g = dy[i * n + j];
                if g != 0.0 {
                    axpy(g, wj, &mut dxrows[(i - i0) * k..(i - i0 + 1) * k]);
                }
            }
        }
    });
}

/// `dw[j,t] += Σ_i dy[i,j]·x[i,t]` — `dy` is `m×n`, `x` is `m×k`, `dw`
/// is `n×k` (the linear backward-weight kernel). The parallel axis is
/// `j` (filter rows); within a row, contributions land in ascending `i`
/// order on every path, so pooled == serial bitwise.
pub fn matmul_t_acc(
    dy: &[f32],
    x: &[f32],
    m: usize,
    k: usize,
    n: usize,
    dw: &mut [f32],
    pool: Option<&ThreadPool>,
) {
    debug_assert_eq!(dy.len(), m * n);
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(dw.len(), n * k);
    let dwp = SendPtr(dw.as_mut_ptr());
    let dwp = &dwp;
    par_blocks(pool, n.div_ceil(ROW_TILE), m * n * k, |blk| {
        let j0 = blk * ROW_TILE;
        let j1 = (j0 + ROW_TILE).min(n);
        // SAFETY: rows j0..j1 of `dw` are written only by this block (see
        // matmul_bt)
        let dwrows =
            unsafe { std::slice::from_raw_parts_mut(dwp.get().add(j0 * k), (j1 - j0) * k) };
        // i outer so each activation row is reused across the whole
        // filter block while hot
        for i in 0..m {
            let xi = &x[i * k..(i + 1) * k];
            for j in j0..j1 {
                let g = dy[i * n + j];
                if g != 0.0 {
                    axpy(g, xi, &mut dwrows[(j - j0) * k..(j - j0 + 1) * k]);
                }
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn rand(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| r.normal()).collect()
    }

    #[test]
    fn matmul_bt_matches_naive() {
        // shapes straddling the tile boundaries: m < ROW_TILE, m not a
        // multiple of ROW_TILE, n < and > COL_TILE
        for (m, k, n) in [(3, 5, 4), (9, 17, 70), (16, 8, 64), (1, 1, 1)] {
            let x = rand(m * k, 1);
            let w = rand(n * k, 2);
            let b = rand(n, 3);
            let mut out = vec![0f32; m * n];
            matmul_bt(&x, &w, Some(&b), m, k, n, &mut out, None);
            for i in 0..m {
                for j in 0..n {
                    let want: f64 = (0..k)
                        .map(|t| x[i * k + t] as f64 * w[j * k + t] as f64)
                        .sum::<f64>()
                        + b[j] as f64;
                    let got = out[i * n + j] as f64;
                    assert!((got - want).abs() < 1e-4, "({m},{k},{n}) [{i},{j}]: {got} vs {want}");
                }
            }
        }
    }

    #[test]
    fn matmul_bt_without_bias() {
        let (m, k, n) = (2, 3, 2);
        let x = rand(m * k, 4);
        let w = rand(n * k, 5);
        let mut with = vec![0f32; m * n];
        let mut without = vec![0f32; m * n];
        matmul_bt(&x, &w, Some(&[0.0, 0.0]), m, k, n, &mut with, None);
        matmul_bt(&x, &w, None, m, k, n, &mut without, None);
        assert_eq!(with, without); // + 0.0 is exact for finite dots
    }

    #[test]
    fn all_three_pooled_match_serial_bitwise() {
        let (m, k, n) = (37, 96, 70); // several ROW_TILE blocks, 2 COL_TILEs
        let x = rand(m * k, 6);
        let w = rand(n * k, 7);
        let b = rand(n, 8);
        let dy = rand(m * n, 9);
        let pool = ThreadPool::new(4);

        let mut serial = vec![0f32; m * n];
        let mut pooled = serial.clone();
        matmul_bt(&x, &w, Some(&b), m, k, n, &mut serial, None);
        matmul_bt(&x, &w, Some(&b), m, k, n, &mut pooled, Some(&pool));
        assert_eq!(serial, pooled);

        let mut dxs = rand(m * k, 10); // nonzero base: += must preserve it
        let mut dxp = dxs.clone();
        matmul_acc(&dy, &w, m, k, n, &mut dxs, None);
        matmul_acc(&dy, &w, m, k, n, &mut dxp, Some(&pool));
        assert_eq!(dxs, dxp);

        let mut dws = rand(n * k, 11);
        let mut dwp = dws.clone();
        matmul_t_acc(&dy, &x, m, k, n, &mut dws, None);
        matmul_t_acc(&dy, &x, m, k, n, &mut dwp, Some(&pool));
        assert_eq!(dws, dwp);
    }

    #[test]
    fn acc_kernels_match_naive_accumulation() {
        let (m, k, n) = (5, 11, 9);
        let dy = rand(m * n, 12);
        let w = rand(n * k, 13);
        let x = rand(m * k, 14);

        let mut dx = vec![0f32; m * k];
        matmul_acc(&dy, &w, m, k, n, &mut dx, None);
        for i in 0..m {
            for t in 0..k {
                let want: f64 =
                    (0..n).map(|j| dy[i * n + j] as f64 * w[j * k + t] as f64).sum();
                assert!((dx[i * k + t] as f64 - want).abs() < 1e-4, "dx[{i},{t}]");
            }
        }

        let mut dw = vec![0f32; n * k];
        matmul_t_acc(&dy, &x, m, k, n, &mut dw, None);
        for j in 0..n {
            for t in 0..k {
                let want: f64 =
                    (0..m).map(|i| dy[i * n + j] as f64 * x[i * k + t] as f64).sum();
                assert!((dw[j * k + t] as f64 - want).abs() < 1e-4, "dw[{j},{t}]");
            }
        }
    }

    #[test]
    fn empty_shapes() {
        let mut out: Vec<f32> = vec![];
        matmul_bt(&[], &[], None, 0, 3, 0, &mut out, None);
        matmul_acc(&[], &[], 0, 3, 0, &mut out, None);
        matmul_t_acc(&[], &[], 0, 3, 0, &mut out, None);
    }
}
