//! The [`Backend`] trait: everything Algorithm 1 needs from an execution
//! engine, extracted from the PJRT `Engine`/`ModelState` pair so the
//! coordinator's `Trainer` runs unchanged against either the XLA runtime
//! (`--features pjrt`) or the pure-Rust native backend (`native::NativeBackend`,
//! the default build).
//!
//! The trait speaks host types only — flat `&[f32]` batches, `i32`
//! labels, per-layer `bits`/`ks` vectors — mirroring the runtime-input
//! design of the AOT artifacts (precision is data, not code). Each
//! implementation owns its parameters, momenta, and whatever device
//! state it needs; the trainer owns the schedule, the bit-state, and the
//! pruning policy.

use anyhow::Result;

/// Scalars returned by one optimization step.
#[derive(Clone, Copy, Debug, Default)]
pub struct StepStats {
    /// full loss: CE + λ·Σ_l mean|B_k|
    pub loss: f32,
    /// cross-entropy term alone
    pub ce: f32,
    /// correct top-1 predictions in the batch
    pub correct: f32,
}

/// Per-layer statistics for a pruning round (each `Vec` has length Lq).
#[derive(Clone, Debug, Default)]
pub struct LayerStats {
    /// β_l: fraction of weights whose k LSBs are nonzero (paper Eq. 6)
    pub beta: Vec<f32>,
    /// ‖W_n − W‖² quantization error (the Ω factor, paper Eq. 9)
    pub qerr: Vec<f32>,
    /// mean |B_k| regularizer magnitude (diagnostic)
    pub reg: Vec<f32>,
}

/// One record in a backend's export layout (see
/// [`Backend::export_records`]): either "pack quantized layer `q` here"
/// or a pre-built structural record (SeqView / LayerNorm / Attention /
/// Residual / MeanPool) emitted verbatim.
pub enum ExportRecord {
    /// Quantize-and-pack layer `q`'s float weights at this position;
    /// `gelu` stamps the fused-GELU flag on the record.
    Quantized { q: usize, gelu: bool },
    /// Emit this payload-free structural record as-is.
    Structural(crate::quant::pack::PackedLayer),
}

/// One training/eval engine the coordinator can drive.
pub trait Backend {
    /// "native" | "pjrt" — for logs and reports.
    fn kind(&self) -> &'static str;
    /// Fixed batch size of `train_step` inputs.
    fn batch(&self) -> usize;
    /// Fixed batch size of `eval_step` inputs.
    fn eval_batch(&self) -> usize {
        self.batch()
    }
    /// Batch size `hessian_step` consumes (probe batches are truncated
    /// to this length).
    fn hess_batch(&self) -> usize {
        self.batch()
    }
    /// Flattened elements per input sample (e.g. H·W·C).
    fn input_elems(&self) -> usize;
    /// Spatial input shape `(h, w, c)`; `(0, 0, 0)` when the backend is
    /// flat/MLP-shaped. Stamped into the `.msqpack` v3 header so conv
    /// executors can chain output maps.
    fn input_shape(&self) -> (usize, usize, usize) {
        (0, 0, 0)
    }
    fn num_q_layers(&self) -> usize;
    fn q_layer_name(&self, q: usize) -> String;
    /// Op descriptor of quantized layer `q` — stamped into the pack v3
    /// layer record so serving rebuilds the exact op graph. Defaults to
    /// `Linear` (the pre-v3 MLP assumption).
    fn q_layer_op(&self, _q: usize) -> crate::quant::pack::LayerOp {
        crate::quant::pack::LayerOp::Linear
    }
    /// Whether layer `q` is followed by a fused ReLU in the serving
    /// graph. Defaults to the classic MLP chain: every layer but the
    /// last.
    fn q_layer_relu(&self, q: usize) -> bool {
        q + 1 < self.num_q_layers()
    }
    /// Full `.msqpack` record layout for export, in record order. `None`
    /// (the default) means the classic chain: one `Quantized` record per
    /// q-layer, no structural records, no GELU. Backends whose serving
    /// graph interleaves structural ops (the ViT topology's SeqView /
    /// LayerNorm / Attention / Residual / MeanPool records) override
    /// this; `Trainer::export_packed` walks the list.
    fn export_records(&self) -> Option<Vec<ExportRecord>> {
        None
    }
    /// Per-quantized-layer weight counts (compression accounting).
    fn q_sizes(&self) -> Vec<usize>;
    fn trainable_params(&self) -> usize;
    /// Float weights of quantized layer `q` (export path).
    fn q_weights(&self, q: usize) -> Result<Vec<f32>>;
    /// Replace the float weights of quantized layer `q` (packed-model
    /// re-import path).
    fn set_q_weights(&mut self, q: usize, w: &[f32]) -> Result<()>;

    /// One SGD step at the given per-layer precisions: forward with the
    /// quantizer's STE, LSB L1 regularizer at strength `lam`, parameter
    /// and momentum update at `lr`.
    #[allow(clippy::too_many_arguments)]
    fn train_step(
        &mut self,
        bits: &[f32],
        ks: &[f32],
        lam: f32,
        lr: f32,
        n_act: f32,
        x: &[f32],
        y: &[i32],
    ) -> Result<StepStats>;

    /// Evaluate one batch; returns `(ce_sum, correct_count)`.
    fn eval_step(&mut self, bits: &[f32], n_act: f32, x: &[f32], y: &[i32]) -> Result<(f32, f32)>;

    /// Whether `stats_step` is available (pruning rounds are skipped
    /// otherwise, matching the old stats-artifact-missing behavior).
    fn supports_stats(&self) -> bool;
    fn stats_step(&mut self, bits: &[f32], ks: &[f32]) -> Result<LayerStats>;

    /// Whether `hessian_step` is available (Ω falls back to uniform).
    fn supports_hessian(&self) -> bool;
    /// One Hutchinson probe on the float network: per-layer vᵀHv.
    fn hessian_step(&mut self, x: &[f32], y: &[i32], seed: u64) -> Result<Vec<f32>>;
}

// ---------------------------------------------------------------------------
// PJRT adapter: the original Engine/ModelState path behind the trait
// ---------------------------------------------------------------------------

#[cfg(feature = "pjrt")]
pub use pjrt_backend::PjrtBackend;

#[cfg(feature = "pjrt")]
mod pjrt_backend {
    use anyhow::{Context, Result};

    use super::{Backend, LayerStats, StepStats};
    use crate::runtime::artifacts::ArtifactMeta;
    use crate::runtime::engine::{self, Engine};
    use crate::runtime::state::ModelState;

    /// XLA-backed [`Backend`]: compiled AOT artifacts driven through the
    /// PJRT engine, host state in `ModelState` literals.
    pub struct PjrtBackend<'e> {
        pub eng: &'e Engine,
        pub state: ModelState,
        pub train_meta: ArtifactMeta,
        pub eval_meta: ArtifactMeta,
        pub stats_meta: Option<ArtifactMeta>,
        pub hess_meta: Option<ArtifactMeta>,
    }

    impl<'e> PjrtBackend<'e> {
        /// Resolve the artifact family for `(model, method)` at `batch`.
        pub fn new(
            eng: &'e Engine,
            model: &str,
            method: &str,
            batch: usize,
        ) -> Result<PjrtBackend<'e>> {
            let train_meta = eng
                .manifest
                .find_batch(model, method, "train", batch)
                .or_else(|_| eng.manifest.find(model, method, "train"))?
                .clone();
            let eval_meta = eng.manifest.find(model, method, "eval")?.clone();
            let stats_meta = eng.manifest.find(model, method, "stats").ok().cloned();
            let hess_meta = eng.manifest.find(model, "msq", "hessian").ok().cloned();
            let state = ModelState::init(&eng.manifest, &train_meta)?;
            Ok(PjrtBackend { eng, state, train_meta, eval_meta, stats_meta, hess_meta })
        }

        fn lit_batch(
            &self,
            meta: &ArtifactMeta,
            x: &[f32],
            y: &[i32],
        ) -> Result<(xla::Literal, xla::Literal)> {
            let img = &meta.image;
            let xl = engine::lit_f32(x, &[meta.batch, img[0], img[1], img[2]])?;
            let yl = engine::lit_i32(y, &[meta.batch])?;
            Ok((xl, yl))
        }
    }

    impl Backend for PjrtBackend<'_> {
        fn kind(&self) -> &'static str {
            "pjrt"
        }

        fn batch(&self) -> usize {
            self.train_meta.batch
        }

        fn eval_batch(&self) -> usize {
            self.eval_meta.batch
        }

        fn hess_batch(&self) -> usize {
            self.hess_meta.as_ref().map(|m| m.batch).unwrap_or(8)
        }

        fn input_elems(&self) -> usize {
            self.train_meta.image.iter().product()
        }

        fn input_shape(&self) -> (usize, usize, usize) {
            let img = &self.train_meta.image;
            if img.len() == 3 {
                (img[0], img[1], img[2])
            } else {
                (0, 0, 0)
            }
        }

        fn num_q_layers(&self) -> usize {
            self.train_meta.num_q_layers
        }

        fn q_layer_name(&self, q: usize) -> String {
            self.train_meta.q_layers.get(q).map(|l| l.name.clone()).unwrap_or_else(|| {
                format!("q{q}")
            })
        }

        fn q_sizes(&self) -> Vec<usize> {
            self.train_meta.q_sizes()
        }

        fn trainable_params(&self) -> usize {
            self.state.trainable_params()
        }

        fn q_weights(&self, q: usize) -> Result<Vec<f32>> {
            self.state.q_weights(q)
        }

        fn set_q_weights(&mut self, q: usize, w: &[f32]) -> Result<()> {
            self.state.set_q_weights(q, w)
        }

        #[allow(clippy::too_many_arguments)]
        fn train_step(
            &mut self,
            bits: &[f32],
            ks: &[f32],
            lam: f32,
            lr: f32,
            n_act: f32,
            x: &[f32],
            y: &[i32],
        ) -> Result<StepStats> {
            let meta = self.train_meta.clone();
            let bits_l = engine::lit_f32(bits, &[bits.len()])?;
            let ks_l = engine::lit_f32(ks, &[ks.len()])?;
            let (xl, yl) = self.lit_batch(&meta, x, y)?;
            let (loss, ce, correct) = self
                .state
                .train_step(self.eng, &meta, &bits_l, &ks_l, lam, lr, 1.0, n_act, &xl, &yl)?;
            Ok(StepStats { loss, ce, correct })
        }

        fn eval_step(
            &mut self,
            bits: &[f32],
            n_act: f32,
            x: &[f32],
            y: &[i32],
        ) -> Result<(f32, f32)> {
            let meta = self.eval_meta.clone();
            let bits_l = engine::lit_f32(bits, &[bits.len()])?;
            let (xl, yl) = self.lit_batch(&meta, x, y)?;
            self.state.eval_step(self.eng, &meta, &bits_l, 1.0, n_act, &xl, &yl)
        }

        fn supports_stats(&self) -> bool {
            self.stats_meta.is_some()
        }

        fn stats_step(&mut self, bits: &[f32], ks: &[f32]) -> Result<LayerStats> {
            let meta = self.stats_meta.clone().context("no stats artifact")?;
            let bits_l = engine::lit_f32(bits, &[bits.len()])?;
            let ks_l = engine::lit_f32(ks, &[ks.len()])?;
            let (beta, qerr, reg) = self.state.stats_step(self.eng, &meta, &bits_l, &ks_l)?;
            Ok(LayerStats { beta, qerr, reg })
        }

        fn supports_hessian(&self) -> bool {
            self.hess_meta.is_some()
        }

        fn hessian_step(&mut self, x: &[f32], y: &[i32], seed: u64) -> Result<Vec<f32>> {
            let meta = self.hess_meta.clone().context("no hessian artifact")?;
            let (xl, yl) = self.lit_batch(&meta, x, y)?;
            let seed = (seed & 0x7FFF_FFFF) as i32;
            self.state.hessian_step(self.eng, &meta, &xl, &yl, seed)
        }
    }
}
