//! Artifact manifest: typed view of `artifacts/manifest.json`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::{self, Json};

/// One input/output descriptor of an artifact.
#[derive(Clone, Debug)]
pub struct IoDesc {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String, // "f32" | "i32"
    pub role: String,  // param | const | momentum | bits | ks | hyper | data | seed | metric
    pub kind: String,  // qw | plane | wscale | gate | f | sign | ""
    pub q_index: i64,
}

impl IoDesc {
    pub fn numel(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    fn from_json(j: &Json) -> Result<IoDesc> {
        Ok(IoDesc {
            name: j.get("name").and_then(Json::as_str).unwrap_or_default().to_string(),
            shape: j
                .get("shape")
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(Json::as_usize).collect())
                .unwrap_or_default(),
            dtype: j.get("dtype").and_then(Json::as_str).unwrap_or("f32").to_string(),
            role: j.get("role").and_then(Json::as_str).unwrap_or_default().to_string(),
            kind: j.get("kind").and_then(Json::as_str).unwrap_or_default().to_string(),
            q_index: j.get("q_index").and_then(Json::as_i64).unwrap_or(-1),
        })
    }
}

/// One quantized layer of a model (ordering = layer index everywhere).
#[derive(Clone, Debug)]
pub struct QLayer {
    pub name: String,
    pub shape: Vec<usize>,
    pub numel: usize,
}

/// One AOT artifact (a single XLA program).
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub model: String,
    pub method: String,
    pub fn_kind: String,
    pub batch: usize,
    pub image: Vec<usize>,
    pub classes: usize,
    pub num_q_layers: usize,
    pub q_layers: Vec<QLayer>,
    pub trainable_params: usize,
    pub num_trainable: usize,
    pub num_consts: usize,
    pub inputs: Vec<IoDesc>,
    pub outputs: Vec<IoDesc>,
    pub use_pallas: bool,
}

impl ArtifactMeta {
    fn from_json(j: &Json) -> Result<ArtifactMeta> {
        let get_str = |k: &str| j.get(k).and_then(Json::as_str).unwrap_or_default().to_string();
        let get_usize = |k: &str| j.get(k).and_then(Json::as_usize).unwrap_or(0);
        let ios = |k: &str| -> Result<Vec<IoDesc>> {
            j.get(k)
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("artifact missing {k}"))?
                .iter()
                .map(IoDesc::from_json)
                .collect()
        };
        Ok(ArtifactMeta {
            name: get_str("name"),
            file: get_str("file"),
            model: get_str("model"),
            method: get_str("method"),
            fn_kind: get_str("fn"),
            batch: get_usize("batch"),
            image: j
                .get("image")
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(Json::as_usize).collect())
                .unwrap_or_default(),
            classes: get_usize("classes"),
            num_q_layers: get_usize("num_q_layers"),
            q_layers: j
                .get("q_layers")
                .and_then(Json::as_arr)
                .map(|a| {
                    a.iter()
                        .map(|q| QLayer {
                            name: q.get("name").and_then(Json::as_str).unwrap_or("").to_string(),
                            shape: q
                                .get("shape")
                                .and_then(Json::as_arr)
                                .map(|s| s.iter().filter_map(Json::as_usize).collect())
                                .unwrap_or_default(),
                            numel: q.get("numel").and_then(Json::as_usize).unwrap_or(0),
                        })
                        .collect()
                })
                .unwrap_or_default(),
            trainable_params: get_usize("trainable_params"),
            num_trainable: get_usize("num_trainable"),
            num_consts: get_usize("num_consts"),
            inputs: ios("inputs")?,
            outputs: ios("outputs")?,
            use_pallas: j.get("use_pallas").and_then(Json::as_bool).unwrap_or(false),
        })
    }

    /// Input index where a given role region starts + its length, by role.
    pub fn role_range(&self, role: &str) -> (usize, usize) {
        let start = self.inputs.iter().position(|d| d.role == role);
        match start {
            None => (0, 0),
            Some(s) => {
                let len = self.inputs[s..].iter().take_while(|d| d.role == role).count();
                (s, len)
            }
        }
    }

    /// Per-q-layer parameter sizes (for compression accounting).
    pub fn q_sizes(&self) -> Vec<usize> {
        self.q_layers.iter().map(|q| q.numel).collect()
    }
}

/// The whole manifest.
#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: BTreeMap<String, ArtifactMeta>,
    pub inits: BTreeMap<String, String>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let j = json::parse_file(&dir.join("manifest.json"))
            .map_err(|e| anyhow!("manifest: {e}"))
            .context("run `make artifacts` first")?;
        let mut artifacts = BTreeMap::new();
        for a in j.get("artifacts").and_then(Json::as_arr).unwrap_or(&[]) {
            let m = ArtifactMeta::from_json(a)?;
            artifacts.insert(m.name.clone(), m);
        }
        let mut inits = BTreeMap::new();
        if let Some(Json::Obj(m)) = j.get("inits") {
            for (k, v) in m {
                if let Some(s) = v.as_str() {
                    inits.insert(k.clone(), s.to_string());
                }
            }
        }
        Ok(Manifest { dir: dir.to_path_buf(), artifacts, inits })
    }

    /// Default artifacts dir: `$MSQ_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("MSQ_ARTIFACTS").map(PathBuf::from).unwrap_or_else(|_| "artifacts".into())
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactMeta> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name:?} not in manifest ({} known)", self.artifacts.len()))
    }

    /// Find by (model, method, fn) at the default batch.
    pub fn find(&self, model: &str, method: &str, fn_kind: &str) -> Result<&ArtifactMeta> {
        let mut candidates: Vec<&ArtifactMeta> = self
            .artifacts
            .values()
            .filter(|a| a.model == model && a.method == method && a.fn_kind == fn_kind && !a.use_pallas)
            .collect();
        if candidates.is_empty() {
            bail!("no artifact for {model}/{method}/{fn_kind}");
        }
        candidates.sort_by_key(|a| a.batch);
        // default batch = the one registered by models.py (the manifest has
        // extra batch variants only for fig6; pick the most common batch)
        Ok(candidates[candidates.len() / 2])
    }

    /// Find by (model, method, fn, batch).
    pub fn find_batch(
        &self,
        model: &str,
        method: &str,
        fn_kind: &str,
        batch: usize,
    ) -> Result<&ArtifactMeta> {
        self.artifacts
            .values()
            .find(|a| {
                a.model == model
                    && a.method == method
                    && a.fn_kind == fn_kind
                    && a.batch == batch
                    && !a.use_pallas
            })
            .ok_or_else(|| anyhow!("no artifact {model}/{method}/{fn_kind} b{batch}"))
    }

    pub fn hlo_path(&self, meta: &ArtifactMeta) -> PathBuf {
        self.dir.join(&meta.file)
    }

    pub fn init_path(&self, model: &str, method: &str) -> Result<PathBuf> {
        let key = format!("{model}_{method}");
        let f = self
            .inits
            .get(&key)
            .ok_or_else(|| anyhow!("no init for {key}"))?;
        Ok(self.dir.join(f))
    }
}
