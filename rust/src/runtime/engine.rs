//! Compile-and-execute engine over the PJRT CPU client.
//!
//! `Engine` owns the `PjRtClient` and a cache of compiled executables
//! keyed by artifact name. `run()` takes borrowed input literals (zero
//! assembly copies) and returns the decomposed output tuple.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use anyhow::{anyhow, Context, Result};

use super::artifacts::{ArtifactMeta, Manifest};

pub struct Engine {
    pub client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    pub compile_seconds: RefCell<f64>,
}

impl Engine {
    /// CPU client over the artifacts in `Manifest::default_dir()`.
    pub fn new() -> Result<Engine> {
        Self::with_dir(&Manifest::default_dir())
    }

    pub fn with_dir(dir: &std::path::Path) -> Result<Engine> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        let manifest = Manifest::load(dir)?;
        Ok(Engine {
            client,
            manifest,
            cache: RefCell::new(HashMap::new()),
            compile_seconds: RefCell::new(0.0),
        })
    }

    /// Compile (or fetch cached) an artifact's executable.
    pub fn load(&self, meta: &ArtifactMeta) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(&meta.name) {
            return Ok(exe.clone());
        }
        let path = self.manifest.hlo_path(meta);
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("bad path"))?,
        )
        .map_err(|e| anyhow!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {}: {e:?}", meta.name))?;
        *self.compile_seconds.borrow_mut() += t0.elapsed().as_secs_f64();
        let exe = Rc::new(exe);
        self.cache.borrow_mut().insert(meta.name.clone(), exe.clone());
        Ok(exe)
    }

    /// Execute an artifact on borrowed literals; returns the flat output
    /// tuple (the AOT pipeline lowers everything with `return_tuple=True`).
    pub fn run(&self, meta: &ArtifactMeta, args: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        if args.len() != meta.inputs.len() {
            anyhow::bail!(
                "{}: got {} args, artifact expects {}",
                meta.name,
                args.len(),
                meta.inputs.len()
            );
        }
        let exe = self.load(meta)?;
        let out = exe
            .execute::<&xla::Literal>(args)
            .map_err(|e| anyhow!("execute {}: {e:?}", meta.name))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch {}: {e:?}", meta.name))?;
        let parts = lit.to_tuple().map_err(|e| anyhow!("untuple {}: {e:?}", meta.name))?;
        if parts.len() != meta.outputs.len() {
            anyhow::bail!(
                "{}: got {} outputs, manifest says {}",
                meta.name,
                parts.len(),
                meta.outputs.len()
            );
        }
        Ok(parts)
    }

    /// Drop a cached executable (memory control for batch sweeps).
    pub fn evict(&self, name: &str) {
        self.cache.borrow_mut().remove(name);
    }

    pub fn cached_count(&self) -> usize {
        self.cache.borrow().len()
    }
}

// ---------------------------------------------------------------------------
// Literal helpers
// ---------------------------------------------------------------------------

/// f32 tensor literal with shape.
pub fn lit_f32(v: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(v)
        .reshape(&dims)
        .map_err(|e| anyhow!("reshape {shape:?}: {e:?}"))
}

/// i32 tensor literal with shape.
pub fn lit_i32(v: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(v)
        .reshape(&dims)
        .map_err(|e| anyhow!("reshape {shape:?}: {e:?}"))
}

pub fn lit_scalar_f32(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

pub fn lit_scalar_i32(v: i32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Read a scalar f32 out of a literal.
pub fn scalar_f32(l: &xla::Literal) -> Result<f32> {
    l.to_vec::<f32>()
        .map_err(|e| anyhow!("scalar read: {e:?}"))?
        .first()
        .copied()
        .context("empty literal")
}

/// Read a full f32 vector out of a literal.
pub fn vec_f32(l: &xla::Literal) -> Result<Vec<f32>> {
    l.to_vec::<f32>().map_err(|e| anyhow!("vec read: {e:?}"))
}
