//! Host-side model state: parameter / const / momentum literals plus the
//! runtime bit-state vectors, assembled into artifact argument lists.

use anyhow::{anyhow, Context, Result};
use xla::FromRawBytes;

use super::artifacts::{ArtifactMeta, Manifest};
use super::engine::{self, Engine};

/// The trainable state of one model under one method.
pub struct ModelState {
    pub model: String,
    pub method: String,
    pub params: Vec<xla::Literal>,
    pub consts: Vec<xla::Literal>,
    pub momenta: Vec<xla::Literal>,
    /// param specs (from the train artifact's input descriptors)
    pub param_descs: Vec<super::IoDesc>,
}

impl ModelState {
    /// Load initial parameters from the artifact init npz; momenta zeroed.
    pub fn init(manifest: &Manifest, train_meta: &ArtifactMeta) -> Result<ModelState> {
        let path = manifest.init_path(&train_meta.model, &train_meta.method)?;
        let entries = xla::Literal::read_npz(&path, &())
            .map_err(|e| anyhow!("read {path:?}: {e:?}"))?;
        let mut params = Vec::new();
        let mut consts = Vec::new();
        for (name, lit) in entries {
            if name.starts_with('t') {
                params.push(lit);
            } else if name.starts_with('c') {
                consts.push(lit);
            }
        }
        if params.len() != train_meta.num_trainable || consts.len() != train_meta.num_consts {
            anyhow::bail!(
                "{}: init npz has {}/{} tensors, artifact wants {}/{}",
                train_meta.name,
                params.len(),
                consts.len(),
                train_meta.num_trainable,
                train_meta.num_consts
            );
        }
        let momenta = params
            .iter()
            .enumerate()
            .map(|(i, p)| {
                let shape: Vec<usize> = train_meta.inputs[i].shape.clone();
                let numel: usize = shape.iter().product::<usize>().max(1);
                engine::lit_f32(&vec![0f32; numel], &shape).with_context(|| format!("momentum {i}"))
            })
            .collect::<Result<Vec<_>>>()?;
        let _ = &momenta; // shapes validated against descs below
        let param_descs = train_meta.inputs[..train_meta.num_trainable].to_vec();
        Ok(ModelState {
            model: train_meta.model.clone(),
            method: train_meta.method.clone(),
            params,
            consts,
            momenta,
            param_descs,
        })
    }

    /// Total trainable parameter count (Table 1 "Params").
    pub fn trainable_params(&self) -> usize {
        self.param_descs.iter().map(|d| d.numel()).sum()
    }

    /// Collect the float weights of quantized layer `q` (kind == "qw").
    pub fn q_weights(&self, q: usize) -> Result<Vec<f32>> {
        for (i, d) in self.param_descs.iter().enumerate() {
            if d.kind == "qw" && d.q_index == q as i64 {
                return engine::vec_f32(&self.params[i]);
            }
        }
        anyhow::bail!("no qw param for layer {q}")
    }

    /// Replace the float weights of quantized layer `q` (packed-model
    /// re-import path).
    pub fn set_q_weights(&mut self, q: usize, w: &[f32]) -> Result<()> {
        for (i, d) in self.param_descs.iter().enumerate() {
            if d.kind == "qw" && d.q_index == q as i64 {
                anyhow::ensure!(w.len() == d.numel(), "layer {q}: {} != {}", w.len(), d.numel());
                self.params[i] = engine::lit_f32(w, &d.shape)?;
                return Ok(());
            }
        }
        anyhow::bail!("no qw param for layer {q}")
    }

    /// Run one training step; updates params/momenta in place, returns
    /// (loss, ce, correct).
    #[allow(clippy::too_many_arguments)]
    pub fn train_step(
        &mut self,
        eng: &Engine,
        meta: &ArtifactMeta,
        bits: &xla::Literal,
        ks: &xla::Literal,
        lam: f32,
        lr: f32,
        temp: f32,
        n_act: f32,
        x: &xla::Literal,
        y: &xla::Literal,
    ) -> Result<(f32, f32, f32)> {
        let lam_l = engine::lit_scalar_f32(lam);
        let lr_l = engine::lit_scalar_f32(lr);
        let temp_l = engine::lit_scalar_f32(temp);
        let na_l = engine::lit_scalar_f32(n_act);
        let mut args: Vec<&xla::Literal> =
            Vec::with_capacity(self.params.len() * 2 + self.consts.len() + 8);
        args.extend(self.params.iter());
        args.extend(self.consts.iter());
        args.extend(self.momenta.iter());
        args.extend([bits, ks, &lam_l, &lr_l, &temp_l, &na_l, x, y]);
        let mut out = eng.run(meta, &args)?;
        let nt = self.params.len();
        let correct = engine::scalar_f32(&out[2 * nt + 2])?;
        let ce = engine::scalar_f32(&out[2 * nt + 1])?;
        let loss = engine::scalar_f32(&out[2 * nt])?;
        // move new params/momenta into place (reverse order pops nothing;
        // drain keeps ordering)
        let mut it = out.drain(..);
        for p in self.params.iter_mut() {
            *p = it.next().context("missing param output")?;
        }
        for m in self.momenta.iter_mut() {
            *m = it.next().context("missing momentum output")?;
        }
        Ok((loss, ce, correct))
    }

    /// Evaluate on one batch: returns (ce_sum, correct).
    #[allow(clippy::too_many_arguments)]
    pub fn eval_step(
        &self,
        eng: &Engine,
        meta: &ArtifactMeta,
        bits: &xla::Literal,
        temp: f32,
        n_act: f32,
        x: &xla::Literal,
        y: &xla::Literal,
    ) -> Result<(f32, f32)> {
        let temp_l = engine::lit_scalar_f32(temp);
        let na_l = engine::lit_scalar_f32(n_act);
        let mut args: Vec<&xla::Literal> = Vec::new();
        args.extend(self.params.iter());
        args.extend(self.consts.iter());
        args.extend([bits, &temp_l, &na_l, x, y]);
        let out = eng.run(meta, &args)?;
        Ok((engine::scalar_f32(&out[0])?, engine::scalar_f32(&out[1])?))
    }

    /// Per-layer stats (msq/dorefa): (beta, qerr, reg) each of len Lq.
    pub fn stats_step(
        &self,
        eng: &Engine,
        meta: &ArtifactMeta,
        bits: &xla::Literal,
        ks: &xla::Literal,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let mut args: Vec<&xla::Literal> = Vec::new();
        args.extend(self.params.iter());
        args.extend(self.consts.iter());
        args.extend([bits, ks]);
        let out = eng.run(meta, &args)?;
        Ok((
            engine::vec_f32(&out[0])?,
            engine::vec_f32(&out[1])?,
            engine::vec_f32(&out[2])?,
        ))
    }

    /// Per-(layer, plane) nonzero rates for bsq/csq: shape (Lq, N0) flat.
    pub fn plane_stats_step(
        &self,
        eng: &Engine,
        meta: &ArtifactMeta,
        bits: &xla::Literal,
        temp: f32,
    ) -> Result<Vec<f32>> {
        let temp_l = engine::lit_scalar_f32(temp);
        let mut args: Vec<&xla::Literal> = Vec::new();
        args.extend(self.params.iter());
        args.extend(self.consts.iter());
        args.extend([bits, &temp_l]);
        let out = eng.run(meta, &args)?;
        engine::vec_f32(&out[0])
    }

    /// One Hutchinson probe: per-layer vᵀHv (len Lq).
    pub fn hessian_step(
        &self,
        eng: &Engine,
        meta: &ArtifactMeta,
        x: &xla::Literal,
        y: &xla::Literal,
        seed: i32,
    ) -> Result<Vec<f32>> {
        let seed_l = engine::lit_scalar_i32(seed);
        let mut args: Vec<&xla::Literal> = Vec::new();
        args.extend(self.params.iter());
        args.extend([x, y, &seed_l]);
        let out = eng.run(meta, &args)?;
        engine::vec_f32(&out[0])
    }
}
