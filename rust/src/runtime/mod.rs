//! PJRT runtime (S10): loads the HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//!
//! This is the *only* place the stack touches XLA; the coordinator above
//! it deals in `ModelState` (host parameter literals) and flat metric
//! vectors. One compiled executable per artifact, cached for the process
//! lifetime — precision changes are runtime inputs, so the whole training
//! schedule reuses a single compilation per step-function.

pub mod artifacts;
#[cfg(feature = "pjrt")]
pub mod engine;
#[cfg(feature = "pjrt")]
pub mod state;

pub use artifacts::{ArtifactMeta, IoDesc, Manifest, QLayer};
#[cfg(feature = "pjrt")]
pub use engine::Engine;
#[cfg(feature = "pjrt")]
pub use state::ModelState;
