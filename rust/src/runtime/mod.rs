//! PJRT runtime (S10): loads the HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//!
//! This is the *only* place the stack touches XLA; the coordinator above
//! it deals in the [`backend::Backend`] trait (flat host slices in, flat
//! metric vectors out), which the PJRT engine implements alongside the
//! pure-Rust `native` backend. One compiled executable per artifact,
//! cached for the process
//! lifetime — precision changes are runtime inputs, so the whole training
//! schedule reuses a single compilation per step-function.

pub mod artifacts;
pub mod backend;
#[cfg(feature = "pjrt")]
pub mod engine;
#[cfg(feature = "pjrt")]
pub mod state;

pub use artifacts::{ArtifactMeta, IoDesc, Manifest, QLayer};
pub use backend::{Backend, ExportRecord, LayerStats, StepStats};
#[cfg(feature = "pjrt")]
pub use backend::PjrtBackend;
#[cfg(feature = "pjrt")]
pub use engine::Engine;
#[cfg(feature = "pjrt")]
pub use state::ModelState;
