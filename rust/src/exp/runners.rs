//! Shared experiment runners: full training runs and step-cost probes.

use anyhow::Result;

use crate::coordinator::bsq::BsqTrainer;
use crate::coordinator::csq::CsqTrainer;
use crate::coordinator::{MsqConfig, RunReport, Trainer};
use crate::data::{Batcher, Dataset};
use crate::runtime::{engine, Engine, ModelState};
use crate::util::timer::{peak_rss_bytes, Timer};

/// Run one full training with the right trainer for `cfg.method`.
pub fn run_method(eng: &Engine, cfg: MsqConfig, ds: &Dataset) -> Result<RunReport> {
    match cfg.method.as_str() {
        "bsq" => BsqTrainer::new(eng, cfg)?.run(ds),
        "csq" => CsqTrainer::new(eng, cfg)?.run(ds),
        _ => Trainer::new(eng, cfg)?.run(ds),
    }
}

/// Step-cost probe result (Table 1 / Fig. 6 raw material).
#[derive(Clone, Debug)]
pub struct StepCost {
    pub model: String,
    pub method: String,
    pub batch: usize,
    pub trainable_params: usize,
    pub step_seconds: f64,
    pub steps_measured: usize,
    pub peak_rss_bytes: u64,
    pub compile_seconds: f64,
}

impl StepCost {
    pub fn time_per_epoch(&self, train_size: usize) -> f64 {
        self.step_seconds * (train_size as f64 / self.batch as f64).ceil()
    }

    pub fn images_per_second(&self) -> f64 {
        self.batch as f64 / self.step_seconds.max(1e-12)
    }
}

/// Measure the steady-state train-step cost of (model, method, batch):
/// `warmup` discarded steps, then `steps` timed steps on real batches.
pub fn measure_steps(
    eng: &Engine,
    model: &str,
    method: &str,
    batch: usize,
    ds: &Dataset,
    warmup: usize,
    steps: usize,
) -> Result<StepCost> {
    let meta = eng
        .manifest
        .find_batch(model, method, "train", batch)
        .or_else(|_| eng.manifest.find(model, method, "train"))?
        .clone();
    let batch = meta.batch;
    let mut state = ModelState::init(&eng.manifest, &meta)?;
    let lq = meta.num_q_layers;
    let bits = engine::lit_f32(&vec![8.0; lq], &[lq])?;
    let ks = engine::lit_f32(&vec![1.0; lq], &[lq])?;
    let mut batcher = Batcher::new(ds, batch, 7, false);
    let img = meta.image.clone();
    let compile_before = *eng.compile_seconds.borrow();

    let mut run_one = |state: &mut ModelState| -> Result<f64> {
        let b = batcher.next();
        let x = engine::lit_f32(&b.x, &[batch, img[0], img[1], img[2]])?;
        let y = engine::lit_i32(&b.y, &[batch])?;
        let t = Timer::start();
        state.train_step(eng, &meta, &bits, &ks, 5e-5, 0.01, 1.0, 0.0, &x, &y)?;
        Ok(t.seconds())
    };

    for _ in 0..warmup {
        run_one(&mut state)?;
    }
    let mut total = 0.0;
    for _ in 0..steps {
        total += run_one(&mut state)?;
    }
    Ok(StepCost {
        model: model.into(),
        method: method.into(),
        batch,
        trainable_params: meta.trainable_params,
        step_seconds: total / steps.max(1) as f64,
        steps_measured: steps,
        peak_rss_bytes: peak_rss_bytes().unwrap_or(0),
        compile_seconds: *eng.compile_seconds.borrow() - compile_before,
    })
}
