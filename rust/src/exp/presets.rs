//! Run-scale presets.
//!
//! The paper trains 400 CIFAR epochs / 100 ImageNet epochs on GPUs; the
//! CPU-PJRT testbed regenerates every table/figure at reduced scale
//! (identical schedule *shape*: regularize → prune every I → QAT tail).
//! `quick` is what `cargo bench`/CI use; `full` is the EXPERIMENTS.md
//! headline setting.

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Preset {
    /// seconds-scale smoke (benches, tests)
    Smoke,
    /// minutes-scale (default for `experiments`)
    Quick,
    /// tens-of-minutes (EXPERIMENTS.md headline runs)
    Full,
}

impl Preset {
    pub fn parse(s: &str) -> Preset {
        match s {
            "smoke" => Preset::Smoke,
            "full" => Preset::Full,
            _ => Preset::Quick,
        }
    }

    /// (train_size, test_size, epochs, interval) for CIFAR-shaped runs.
    pub fn cifar(self) -> (usize, usize, usize, usize) {
        match self {
            Preset::Smoke => (512, 256, 4, 1),
            Preset::Quick => (5_120, 1_024, 24, 4),
            Preset::Full => (10_240, 2_048, 48, 8),
        }
    }

    /// (train_size, test_size, epochs, interval) for in64-shaped runs.
    pub fn in64(self) -> (usize, usize, usize, usize) {
        match self {
            Preset::Smoke => (256, 128, 2, 1),
            Preset::Quick => (2_048, 512, 10, 2),
            Preset::Full => (4_096, 1_024, 20, 4),
        }
    }

    /// λ multiplier vs the paper's value. The paper's λ is calibrated for
    /// 400-epoch CIFAR runs; the LSB drift per step is ∝ λ·lr, so reaching
    /// the same β at our compressed schedules requires scaling λ by
    /// roughly (paper steps / our steps). Recorded per-run in results/.
    pub fn lam_mult(self) -> f32 {
        match self {
            Preset::Smoke => 40.0,
            Preset::Quick => 10.0,
            Preset::Full => 4.0,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Preset::Smoke => "smoke",
            Preset::Quick => "quick",
            Preset::Full => "full",
        }
    }
}
