//! Experiment harness (S15): shared runners behind the `experiments`
//! binary and the benches. One function per paper table/figure, each
//! writing machine-readable rows under `results/` and printing the
//! paper-style table.

pub mod presets;
pub mod runners;
pub mod tables;

pub use presets::Preset;
pub use runners::{measure_steps, run_method, StepCost};
