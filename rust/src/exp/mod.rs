//! Experiment harness (S15): shared runners behind the `experiments`
//! binary and the benches. One function per paper table/figure, each
//! writing machine-readable rows under `results/` and printing the
//! paper-style table.

pub mod presets;
#[cfg(feature = "pjrt")]
pub mod runners;
#[cfg(feature = "pjrt")]
pub mod tables;

pub use presets::Preset;
#[cfg(feature = "pjrt")]
pub use runners::{measure_steps, run_method, StepCost};
