//! One function per paper table/figure (DESIGN.md per-experiment index).
//!
//! Each writes CSV/JSON rows under `results/` and prints the paper-style
//! table; EXPERIMENTS.md records paper-vs-measured for each.

use anyhow::Result;

use super::presets::Preset;
use super::runners::{measure_steps, run_method};
use crate::coordinator::MsqConfig;
use crate::data::{Dataset, DatasetSpec};
use crate::metrics::{fmt_duration, results_dir, Csv, Table};
use crate::quant;
use crate::runtime::{Backend, Engine};
use crate::util::stats::Histogram;
use crate::util::threadpool::ThreadPool;

fn cifar_ds(preset: Preset, seed: u64) -> Dataset {
    let (train, test, _, _) = preset.cifar();
    let pool = ThreadPool::new(ThreadPool::default_size());
    Dataset::generate(DatasetSpec::cifar_syn(train, test, seed), &pool)
}

fn in64_ds(preset: Preset, seed: u64) -> Dataset {
    let (train, test, _, _) = preset.in64();
    let pool = ThreadPool::new(ThreadPool::default_size());
    Dataset::generate(DatasetSpec::in64_syn(train, test, seed), &pool)
}

fn base_cfg(model: &str, method: &str, preset: Preset) -> MsqConfig {
    let cifar = matches!(model, "resnet20" | "mlp");
    let (_, _, epochs, interval) = if cifar { preset.cifar() } else { preset.in64() };
    MsqConfig {
        model: model.into(),
        method: method.into(),
        epochs,
        interval,
        batch: if cifar { 256 } else { 64 },
        lr0: if cifar { 0.1 } else { 0.01 },
        lam: preset.lam_mult()
            * if model.starts_with("vit") || model == "swinlite" { 8e-6 } else { 5e-5 },
        alpha: if model.starts_with("vit") || model == "swinlite" { 0.35 } else { 0.3 },
        n_act: if model.starts_with("vit") || model == "swinlite" { 8.0 } else { 0.0 },
        eval_every: (epochs / 4).max(1),
        hessian_probes: match preset {
            Preset::Smoke => 1,
            _ => 4,
        },
        verbose: true,
        ..Default::default()
    }
}

// ---------------------------------------------------------------------------
// Table 1 — training resource usage per method
// ---------------------------------------------------------------------------

pub fn table1(eng: &Engine, preset: Preset) -> Result<()> {
    println!("== Table 1: training resource usage (BSQ / CSQ / MSQ) ==");
    let mut csv = Csv::create(
        &results_dir().join("table1_resources.csv"),
        &["model", "method", "batch", "params_m", "step_seconds", "time_per_epoch_s", "peak_rss_gb"],
    )?;
    let mut tbl = Table::new(&["Network", "Method", "Batch", "Params (M)", "s/step", "s/epoch", "PeakMem (GB)"]);
    let models: &[(&str, bool)] = match preset {
        Preset::Smoke => &[("resnet20", true)],
        _ => &[("resnet20", true), ("resnet18s", false), ("resnet50s", false)],
    };
    let (warm, steps) = match preset {
        Preset::Smoke => (1, 2),
        Preset::Quick => (2, 5),
        Preset::Full => (3, 10),
    };
    for &(model, cifar) in models {
        let ds = if cifar { cifar_ds(Preset::Smoke, 42) } else { in64_ds(Preset::Smoke, 42) };
        let train_size = if cifar { preset.cifar().0 } else { preset.in64().0 };
        for method in ["bsq", "csq", "msq"] {
            let c = measure_steps(eng, model, method, if cifar { 256 } else { 64 }, &ds, warm, steps)?;
            let epoch_s = c.time_per_epoch(train_size);
            csv.row(&[
                model.into(),
                method.into(),
                c.batch.to_string(),
                format!("{:.2}", c.trainable_params as f64 / 1e6),
                format!("{:.4}", c.step_seconds),
                format!("{:.2}", epoch_s),
                format!("{:.2}", c.peak_rss_bytes as f64 / 1e9),
            ])?;
            tbl.row(&[
                model.into(),
                method.to_uppercase(),
                c.batch.to_string(),
                format!("{:.2}", c.trainable_params as f64 / 1e6),
                format!("{:.3}", c.step_seconds),
                format!("{:.1}", epoch_s),
                format!("{:.2}", c.peak_rss_bytes as f64 / 1e9),
            ]);
        }
    }
    csv.flush()?;
    tbl.print();
    println!("(paper: MSQ has ~8x fewer trainable params and the lowest step time; \
              BSQ/CSQ params multiply by the initial bit-width)");
    Ok(())
}

// ---------------------------------------------------------------------------
// Table 2 — ResNet-20 on CIFAR-syn: accuracy vs compression, A-bits sweep
// ---------------------------------------------------------------------------

pub fn table2(eng: &Engine, preset: Preset) -> Result<()> {
    println!("== Table 2: ResNet-20 @ cifar-syn — acc/comp per method, A-bits in {{32,3,2}} ==");
    let ds = cifar_ds(preset, 42);
    let mut csv = Csv::create(
        &results_dir().join("table2_resnet20.csv"),
        &["method", "a_bits", "w_bits", "comp", "acc"],
    )?;
    let mut tbl = Table::new(&["Method", "A-Bits", "W-Bits", "Comp", "Acc"]);
    let a_bits_list: &[f32] = match preset {
        Preset::Smoke => &[0.0],
        _ => &[0.0, 3.0, 2.0],
    };

    // FP reference: 16-bit weights ≈ lossless, λ=0, no pruning
    {
        let mut cfg = base_cfg("resnet20", "msq", preset);
        cfg.lam = 0.0;
        cfg.gamma = 0.0;
        cfg.fixed_bits = Some(16);
        cfg.n_act = 0.0;
        let r = run_method(eng, cfg, &ds)?;
        csv.row(&["fp".into(), "32".into(), "16(≈fp)".into(), "1.00".into(), format!("{:.4}", r.final_acc)])?;
        tbl.row(&["FP".into(), "32".into(), "32".into(), "1.00".into(), format!("{:.2}%", r.final_acc * 100.0)]);
    }

    for &a in a_bits_list {
        let a_label = if a == 0.0 { "32".to_string() } else { format!("{}", a as u32) };
        // uniform DoReFa baselines at 3 and 2 bits
        for wb in [3u8, 2u8] {
            let mut cfg = base_cfg("resnet20", "dorefa", preset);
            cfg.lam = 0.0;
            cfg.gamma = 0.0;
            cfg.fixed_bits = Some(wb);
            cfg.n_act = a;
            let r = run_method(eng, cfg, &ds)?;
            let comp = 32.0 / wb as f64;
            csv.row(&["dorefa".into(), a_label.clone(), wb.to_string(), format!("{comp:.2}"), format!("{:.4}", r.final_acc)])?;
            tbl.row(&["DoReFa".into(), a_label.clone(), wb.to_string(), format!("{comp:.2}"), format!("{:.2}%", r.final_acc * 100.0)]);
        }
        // BSQ / CSQ / MSQ mixed-precision at Γ = 16
        for method in ["bsq", "csq", "msq"] {
            let mut cfg = base_cfg("resnet20", method, preset);
            cfg.gamma = 16.0;
            cfg.n_act = a;
            let r = run_method(eng, cfg.clone(), &ds)?;
            csv.row(&[method.into(), a_label.clone(), "MP".into(), format!("{:.2}", r.final_compression), format!("{:.4}", r.final_acc)])?;
            tbl.row(&[method.to_uppercase(), a_label.clone(), "MP".into(), format!("{:.2}", r.final_compression), format!("{:.2}%", r.final_acc * 100.0)]);
            r.save(&results_dir().join(format!("table2_{}_a{}.json", method, a_label)))?;
        }
    }
    csv.flush()?;
    tbl.print();
    Ok(())
}

// ---------------------------------------------------------------------------
// Table 3 / Table 5 — scaled ImageNet models
// ---------------------------------------------------------------------------

pub fn table3(eng: &Engine, preset: Preset) -> Result<()> {
    println!("== Table 3: ResNet-18s / ResNet-50s @ in64-syn ==");
    in64_table(eng, preset, &["resnet18s", "resnet50s"], "table3_resnets.csv", 10.67, &[4, 3])
}

pub fn table5(eng: &Engine, preset: Preset) -> Result<()> {
    println!("== Table 5: MobileNetV3s @ in64-syn ==");
    in64_table(eng, preset, &["mbv3s"], "table5_mbv3.csv", 10.0, &[8, 4])
}

fn in64_table(
    eng: &Engine,
    preset: Preset,
    models: &[&str],
    csv_name: &str,
    gamma: f64,
    dorefa_bits: &[u8],
) -> Result<()> {
    let ds = in64_ds(preset, 42);
    let mut csv = Csv::create(
        &results_dir().join(csv_name),
        &["model", "method", "w_bits", "comp", "acc"],
    )?;
    let mut tbl = Table::new(&["Model", "Method", "W-Bits", "Comp", "Acc"]);
    for &model in models {
        // FP-ish reference
        let mut cfg = base_cfg(model, "msq", preset);
        cfg.lam = 0.0;
        cfg.gamma = 0.0;
        cfg.fixed_bits = Some(16);
        let r = run_method(eng, cfg, &ds)?;
        tbl.row(&[model.into(), "FP".into(), "32".into(), "1.00".into(), format!("{:.2}%", r.final_acc * 100.0)]);
        csv.row(&[model.into(), "fp".into(), "32".into(), "1.00".into(), format!("{:.4}", r.final_acc)])?;
        // uniform DoReFa
        for &wb in dorefa_bits {
            let mut cfg = base_cfg(model, "dorefa", preset);
            cfg.lam = 0.0;
            cfg.gamma = 0.0;
            cfg.fixed_bits = Some(wb);
            let r = run_method(eng, cfg, &ds)?;
            let comp = 32.0 / wb as f64;
            tbl.row(&[model.into(), "DoReFa".into(), wb.to_string(), format!("{comp:.2}"), format!("{:.2}%", r.final_acc * 100.0)]);
            csv.row(&[model.into(), "dorefa".into(), wb.to_string(), format!("{comp:.2}"), format!("{:.4}", r.final_acc)])?;
        }
        // MSQ mixed precision
        let mut cfg = base_cfg(model, "msq", preset);
        cfg.gamma = gamma;
        let r = run_method(eng, cfg, &ds)?;
        tbl.row(&[model.into(), "MSQ".into(), "MP".into(), format!("{:.2}", r.final_compression), format!("{:.2}%", r.final_acc * 100.0)]);
        csv.row(&[model.into(), "msq".into(), "MP".into(), format!("{:.2}", r.final_compression), format!("{:.4}", r.final_acc)])?;
        r.save(&results_dir().join(format!("{}_msq.json", model)))?;
    }
    csv.flush()?;
    tbl.print();
    Ok(())
}

// ---------------------------------------------------------------------------
// Table 4 — ViT family
// ---------------------------------------------------------------------------

pub fn table4(eng: &Engine, preset: Preset) -> Result<()> {
    println!("== Table 4: DeiT-T/S + Swin-T proxies @ in64-syn (8-bit activations) ==");
    let ds = in64_ds(preset, 42);
    let mut csv = Csv::create(
        &results_dir().join("table4_vit.csv"),
        &["model", "method", "w_bits", "comp", "acc"],
    )?;
    let mut tbl = Table::new(&["Model", "Method", "W-Bits", "Comp", "Acc"]);
    let models: &[&str] = match preset {
        Preset::Smoke => &["vit_t"],
        _ => &["vit_t", "vit_s", "swinlite"],
    };
    for &model in models {
        // LSQ-like uniform 3-bit baseline (roundclamp fixed-bit QAT)
        let mut cfg = base_cfg(model, "msq", preset);
        cfg.lam = 0.0;
        cfg.gamma = 0.0;
        cfg.fixed_bits = Some(3);
        let r = run_method(eng, cfg, &ds)?;
        tbl.row(&[model.into(), "Uniform3".into(), "3".into(), "10.67".into(), format!("{:.2}%", r.final_acc * 100.0)]);
        csv.row(&[model.into(), "uniform3".into(), "3".into(), "10.67".into(), format!("{:.4}", r.final_acc)])?;
        // MSQ mixed precision toward Γ ≈ 10
        let mut cfg = base_cfg(model, "msq", preset);
        cfg.gamma = 10.0;
        let r = run_method(eng, cfg, &ds)?;
        tbl.row(&[model.into(), "MSQ".into(), "MP".into(), format!("{:.2}", r.final_compression), format!("{:.2}%", r.final_acc * 100.0)]);
        csv.row(&[model.into(), "msq".into(), "MP".into(), format!("{:.2}", r.final_compression), format!("{:.4}", r.final_acc)])?;
        r.save(&results_dir().join(format!("table4_{model}.json")))?;
    }
    csv.flush()?;
    tbl.print();
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 3 — analytic quantizer bin maps
// ---------------------------------------------------------------------------

pub fn fig3(_eng: &Engine) -> Result<()> {
    println!("== Fig 3: DoReFa vs RoundClamp 3-bit/2-bit mapping ==");
    let mut csv = Csv::create(
        &results_dir().join("fig3_quantizer_map.csv"),
        &["w", "dorefa_q3", "dorefa_q2", "dorefa_b1", "rc_q3", "rc_q2", "rc_b1"],
    )?;
    let n = 3.0;
    let k = 1.0;
    let mut mismatch_df = 0;
    let mut mismatch_rc = 0;
    for i in 0..=1000 {
        let w = i as f32 / 1000.0;
        let dq3 = quant::dorefa01(w, n);
        let dq2 = quant::dorefa01(w, n - k);
        let db = quant::lsb_proxy_dorefa(w, n, k);
        let rq3 = quant::roundclamp01(w, n);
        let rq2 = quant::roundclamp01(w, n - k);
        let rb = quant::lsb_proxy_roundclamp(w, n, k);
        csv.rowf(&[w as f64, dq3 as f64, dq2 as f64, db as f64, rq3 as f64, rq2 as f64, rb as f64])?;
        // bin-boundary alignment check (the paper's "110 -> 10 vs 11" error)
        let code3_df = (quant::round_ties_even((2f32.powf(n) - 1.0) * w)) as u32;
        let code2_df = (quant::round_ties_even((2f32.powf(n - k) - 1.0) * w)) as u32;
        if code3_df % 2 == 0 && code3_df / 2 != code2_df {
            mismatch_df += 1;
        }
        let code3_rc = quant::roundclamp_code(w, n);
        let code2_rc = quant::roundclamp_code(w, n - k);
        if code3_rc % 2 == 0 && code3_rc / 2 != code2_rc {
            mismatch_rc += 1;
        }
    }
    csv.flush()?;
    println!(
        "MSB-code mismatches on LSB-zero weights over [0,1]: dorefa {} / roundclamp {} (paper: \
         dorefa misaligned, roundclamp aligned)",
        mismatch_df, mismatch_rc
    );
    anyhow::ensure!(mismatch_rc == 0, "roundclamp must be exactly aligned");
    anyhow::ensure!(mismatch_df > 0, "dorefa must show the misalignment");
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 4 — post-training weight distributions per quantizer
// ---------------------------------------------------------------------------

pub fn fig4(eng: &Engine, preset: Preset) -> Result<()> {
    println!("== Fig 4: weight distribution after training, DoReFa vs RoundClamp reg ==");
    let ds = cifar_ds(preset, 42);
    let mut csv = Csv::create(
        &results_dir().join("fig4_weight_dist.csv"),
        &["quantizer", "bin_center", "count"],
    )?;
    for method in ["dorefa", "msq"] {
        let mut cfg = base_cfg("resnet20", method, preset);
        cfg.gamma = 0.0; // no pruning: Fig 4 is "right before pruning"
        cfg.lam = 5e-4; // strong reg to make the shape visible at short scale
        let mut tr = crate::coordinator::Trainer::new(eng, cfg)?;
        let report = tr.run(&ds)?;
        let _ = report;
        // histogram of a mid-network layer's weights in [0,1] scale
        let l = tr.bitstate.num_layers() / 2;
        let w = tr.backend.q_weights(l)?;
        let scale = w.iter().fold(0f32, |a, &x| a.max(x.abs())) + 1e-8;
        let mut h = Histogram::new(0.0, 1.0, 64);
        for &x in &w {
            h.push(quant::to_unit(x, scale) as f64);
        }
        let centers = h.centers();
        for (c, &b) in centers.iter().zip(&h.bins) {
            csv.row(&[method.into(), format!("{c:.4}"), b.to_string()])?;
        }
        println!("{method:>7}: {}", h.sparkline());
    }
    csv.flush()?;
    println!("(paper: dorefa spikes at zero; roundclamp density concentrates at LSB-zero bins)");
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 5 / supp Fig. 1 — Ω per layer across pruning steps
// ---------------------------------------------------------------------------

pub fn fig5(eng: &Engine, preset: Preset) -> Result<()> {
    println!("== Fig 5: Omega per layer, first vs last pruning step ==");
    let ds = cifar_ds(preset, 42);
    let mut cfg = base_cfg("resnet20", "msq", preset);
    cfg.gamma = 16.0;
    let r = run_method(eng, cfg, &ds)?;
    anyhow::ensure!(!r.prune_events.is_empty(), "no pruning events recorded");
    let mut csv = Csv::create(
        &results_dir().join("fig5_omega.csv"),
        &["prune_step", "epoch", "layer", "omega", "beta", "bits_after", "prune_bits"],
    )?;
    for (si, e) in r.prune_events.iter().enumerate() {
        for l in 0..e.omega.len() {
            csv.row(&[
                si.to_string(),
                e.epoch.to_string(),
                l.to_string(),
                format!("{:.6e}", e.omega[l]),
                format!("{:.4}", e.beta[l]),
                e.bits_after[l].to_string(),
                e.prune_bits[l].to_string(),
            ])?;
        }
    }
    csv.flush()?;
    let first = &r.prune_events[0];
    let last = r.prune_events.last().unwrap();
    let mean_first = first.omega.iter().sum::<f32>() / first.omega.len() as f32;
    println!("first prune step (epoch {}): mean Ω {:.3e}, p=2 layers: {}",
        first.epoch, mean_first, first.prune_bits.iter().filter(|&&p| p == 2).count());
    println!("last prune step (epoch {}): comp {:.2}x, p=2 layers: {}",
        last.epoch, last.compression, last.prune_bits.iter().filter(|&&p| p == 2).count());
    r.save(&results_dir().join("fig5_run.json"))?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 6 — time/epoch vs batch size per method
// ---------------------------------------------------------------------------

pub fn fig6(eng: &Engine, preset: Preset) -> Result<()> {
    println!("== Fig 6: training time per epoch vs batch size (resnet20) ==");
    let ds = cifar_ds(Preset::Smoke, 42);
    let train_size = preset.cifar().0;
    let mut csv = Csv::create(
        &results_dir().join("fig6_batch_sweep.csv"),
        &["method", "batch", "params_m", "step_seconds", "time_per_epoch_s", "imgs_per_s"],
    )?;
    let batches: &[usize] = match preset {
        Preset::Smoke => &[64, 256],
        _ => &[64, 128, 256, 512, 1024],
    };
    let (warm, steps) = if preset == Preset::Smoke { (1, 2) } else { (2, 5) };
    let mut tbl = Table::new(&["Method", "Batch", "s/epoch", "img/s", "Params (M)"]);
    for method in ["bsq", "csq", "msq"] {
        for &b in batches {
            let c = match measure_steps(eng, "resnet20", method, b, &ds, warm, steps) {
                Ok(c) => c,
                Err(e) => {
                    println!("  (skip {method} b{b}: {e})");
                    continue;
                }
            };
            if c.batch != b {
                continue; // fell back to a different artifact; not this point
            }
            csv.row(&[
                method.into(),
                b.to_string(),
                format!("{:.2}", c.trainable_params as f64 / 1e6),
                format!("{:.4}", c.step_seconds),
                format!("{:.2}", c.time_per_epoch(train_size)),
                format!("{:.1}", c.images_per_second()),
            ])?;
            tbl.row(&[
                method.to_uppercase(),
                b.to_string(),
                format!("{:.2}", c.time_per_epoch(train_size)),
                format!("{:.0}", c.images_per_second()),
                format!("{:.2}", c.trainable_params as f64 / 1e6),
            ]);
        }
    }
    csv.flush()?;
    tbl.print();
    println!("(paper: MSQ sustains larger batches and the lowest time/epoch; circle size = params)");
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 7 + Fig. 8 — Hessian ablation
// ---------------------------------------------------------------------------

pub fn fig78(eng: &Engine, preset: Preset) -> Result<()> {
    println!("== Fig 7/8: Hessian-aware pruning ablation (resnet20) ==");
    let ds = cifar_ds(preset, 42);
    let mut csv = Csv::create(
        &results_dir().join("fig7_bit_schemes.csv"),
        &["variant", "layer", "final_bits"],
    )?;
    let mut acc_csv = Csv::create(
        &results_dir().join("fig8_acc_curves.csv"),
        &["variant", "epoch", "eval_acc"],
    )?;
    let mut summary = Table::new(&["Variant", "Γ reached @", "Comp", "Final acc", "Best acc"]);
    for (label, use_h) in [("with_hessian", true), ("without_hessian", false)] {
        let mut cfg = base_cfg("resnet20", "msq", preset);
        cfg.gamma = 16.0;
        cfg.use_hessian = use_h;
        let r = run_method(eng, cfg, &ds)?;
        for (l, &b) in r.final_bits.iter().enumerate() {
            csv.row(&[label.into(), l.to_string(), b.to_string()])?;
        }
        for (e, a) in r.eval_epochs.iter().zip(&r.eval_acc) {
            acc_csv.row(&[label.into(), e.to_string(), format!("{a:.4}")])?;
        }
        summary.row(&[
            label.into(),
            r.gamma_reached_epoch.map(|e| e.to_string()).unwrap_or("—".into()),
            format!("{:.2}", r.final_compression),
            format!("{:.2}%", r.final_acc * 100.0),
            format!("{:.2}%", r.best_acc * 100.0),
        ]);
        r.save(&results_dir().join(format!("fig78_{label}.json")))?;
    }
    csv.flush()?;
    acc_csv.flush()?;
    summary.print();
    println!("(paper: Hessian reaches Γ earlier with higher final accuracy)");
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 9 — final bit schemes MSQ vs BSQ
// ---------------------------------------------------------------------------

pub fn fig9(eng: &Engine, preset: Preset) -> Result<()> {
    println!("== Fig 9: final bit schemes, MSQ vs BSQ (resnet20, Γ≈20) ==");
    let ds = cifar_ds(preset, 42);
    let mut csv = Csv::create(
        &results_dir().join("fig9_schemes.csv"),
        &["method", "layer", "final_bits"],
    )?;
    let mut summary = Table::new(&["Method", "Comp", "Acc", "Scheme"]);
    for method in ["msq", "bsq"] {
        let mut cfg = base_cfg("resnet20", method, preset);
        cfg.gamma = 20.0;
        let r = run_method(eng, cfg, &ds)?;
        for (l, &b) in r.final_bits.iter().enumerate() {
            csv.row(&[method.into(), l.to_string(), b.to_string()])?;
        }
        let spread: Vec<String> = r.final_bits.iter().map(|b| b.to_string()).collect();
        summary.row(&[
            method.to_uppercase(),
            format!("{:.2}", r.final_compression),
            format!("{:.2}%", r.final_acc * 100.0),
            spread.join(""),
        ]);
        r.save(&results_dir().join(format!("fig9_{method}.json")))?;
    }
    csv.flush()?;
    summary.print();
    println!("(paper: BSQ sparsity concentrates in a few layers; MSQ is more even)");
    Ok(())
}

// ---------------------------------------------------------------------------
// supp Fig. 4 — λ ablation on the LSB-nonzero rate
// ---------------------------------------------------------------------------

pub fn supp_lambda(eng: &Engine, preset: Preset) -> Result<()> {
    println!("== supp Fig 4: λ ablation (mean β across training) ==");
    let ds = cifar_ds(preset, 42);
    let mut csv = Csv::create(
        &results_dir().join("supp_lambda.csv"),
        &["lam", "prune_step", "epoch", "mean_beta"],
    )?;
    for lam_paper in [5e-5f32, 1e-4] {
        let lam = lam_paper * preset.lam_mult(); // keep the 2x ratio at scale
        let mut cfg = base_cfg("resnet20", "msq", preset);
        cfg.lam = lam;
        cfg.gamma = 1e9; // never reached: keep regularizing, record β at every interval
        cfg.alpha = -1.0; // never prune: observe β trajectory alone
        let r = run_method(eng, cfg, &ds)?;
        for (si, e) in r.prune_events.iter().enumerate() {
            let mean_b = e.beta.iter().sum::<f32>() / e.beta.len().max(1) as f32;
            csv.row(&[format!("{lam_paper:e}"), si.to_string(), e.epoch.to_string(), format!("{mean_b:.4}")])?;
            println!("λ={lam_paper:.0e} step {si} (epoch {}): mean β = {mean_b:.4}", e.epoch);
        }
    }
    csv.flush()?;
    println!("(paper: larger λ drives the LSB-nonzero rate lower)");
    Ok(())
}

// ---------------------------------------------------------------------------
// supp Table 1 — ViT-Base proxy
// ---------------------------------------------------------------------------

pub fn supp_vitbase(eng: &Engine, preset: Preset) -> Result<()> {
    println!("== supp Table 1: ViT-Base proxy (requires `make artifacts-large`) ==");
    if eng.manifest.find("vit_base", "msq", "train").is_err() {
        println!("vit_base artifacts missing — run `make artifacts-large` first; using vit_m proxy");
        let ds = in64_ds(preset, 42);
        let mut cfg = base_cfg("vit_m", "msq", preset);
        cfg.gamma = 9.14;
        let r = run_method(eng, cfg, &ds)?;
        println!("vit_m: comp {:.2}x acc {:.2}%", r.final_compression, r.final_acc * 100.0);
        return Ok(());
    }
    let ds = in64_ds(preset, 42);
    let mut cfg = base_cfg("vit_base", "msq", preset);
    cfg.batch = 8;
    cfg.gamma = 9.14;
    let r = run_method(eng, cfg, &ds)?;
    println!("vit_base: comp {:.2}x acc {:.2}%", r.final_compression, r.final_acc * 100.0);
    r.save(&results_dir().join("supp_vitbase.json"))?;
    Ok(())
}

/// Run the per-epoch time summary used by EXPERIMENTS.md §Perf.
pub fn perf_probe(eng: &Engine) -> Result<()> {
    let ds = cifar_ds(Preset::Smoke, 42);
    for (model, method, batch) in
        [("resnet20", "msq", 256), ("resnet20", "bsq", 256), ("resnet20", "csq", 256)]
    {
        let c = measure_steps(eng, model, method, batch, &ds, 2, 8)?;
        println!(
            "{model}/{method} b{batch}: {:.1} ms/step, {:.0} img/s, compile {:.1}s",
            c.step_seconds * 1e3,
            c.images_per_second(),
            c.compile_seconds
        );
    }
    Ok(())
}
