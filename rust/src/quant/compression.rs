//! Compression-ratio accounting (the paper's "Comp" columns).
//!
//! The weight compression ratio is computed relative to the FP32 model:
//! `Comp = 32 · Σ_l size_l / Σ_l bits_l · size_l` over the quantized
//! layers (the paper's convention; non-quantized parameters — norm
//! scales, biases — are a negligible constant on both sides and excluded,
//! matching BSQ/CSQ reporting).

/// Per-layer bit-state of a model under mixed-precision quantization.
#[derive(Clone, Debug)]
pub struct BitScheme {
    /// current bit-width q_l per quantized layer
    pub bits: Vec<u8>,
    /// parameter count per quantized layer
    pub sizes: Vec<usize>,
}

impl BitScheme {
    pub fn uniform(nbits: u8, sizes: &[usize]) -> Self {
        BitScheme { bits: vec![nbits; sizes.len()], sizes: sizes.to_vec() }
    }

    pub fn num_layers(&self) -> usize {
        self.bits.len()
    }

    pub fn total_params(&self) -> usize {
        self.sizes.iter().sum()
    }

    /// Weighted average bit-width.
    pub fn avg_bits(&self) -> f64 {
        let num: f64 = self
            .bits
            .iter()
            .zip(&self.sizes)
            .map(|(&b, &s)| b as f64 * s as f64)
            .sum();
        num / self.total_params().max(1) as f64
    }

    /// Compression ratio vs FP32 (paper "Comp").
    pub fn compression(&self) -> f64 {
        32.0 / self.avg_bits().max(1e-9)
    }

    /// Apply a prune of `k` bits to layer `l` (floored at 1 bit).
    pub fn prune(&mut self, l: usize, k: u8) {
        let b = self.bits[l];
        self.bits[l] = b.saturating_sub(k).max(1);
    }

    /// Quantized-model weight bytes (packed).
    pub fn weight_bits(&self) -> u64 {
        self.bits.iter().zip(&self.sizes).map(|(&b, &s)| b as u64 * s as u64).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_compression() {
        let s = BitScheme::uniform(8, &[100, 300]);
        assert!((s.compression() - 4.0).abs() < 1e-9);
        assert!((s.avg_bits() - 8.0).abs() < 1e-12);
    }

    #[test]
    fn mixed_compression() {
        let mut s = BitScheme::uniform(4, &[100, 100]);
        s.prune(0, 2); // layer0 -> 2 bits
        assert!((s.avg_bits() - 3.0).abs() < 1e-12);
        assert!((s.compression() - 32.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn prune_floors_at_one() {
        let mut s = BitScheme::uniform(2, &[10]);
        s.prune(0, 5);
        assert_eq!(s.bits[0], 1);
        s.prune(0, 1);
        assert_eq!(s.bits[0], 1);
    }

    #[test]
    fn paper_targets() {
        // Γ = 16.00 and 10.67 correspond to ~2- and ~3-bit average widths
        let s2 = BitScheme::uniform(2, &[1000]);
        let s3 = BitScheme::uniform(3, &[1000]);
        assert!((s2.compression() - 16.0).abs() < 1e-9);
        assert!((s3.compression() - 10.6667).abs() < 1e-3);
    }
}
