//! Rust mirror of the quantizer math (S1/S2) — bit-exact with
//! `python/compile/quant.py`.
//!
//! The graph-side quantizers live in the AOT artifacts; this module exists
//! for everything the coordinator does *outside* the graph: compression
//! accounting, bit-scheme reporting, Fig. 3's analytic quantizer maps,
//! weight-distribution histograms (Fig. 4), and the cross-language
//! numerics tests (rust vs the pytest oracle, exercised in
//! `rust/tests/integration.rs`).

pub mod compression;
pub mod pack;

/// Round half to even (matches XLA/jnp.round semantics).
#[inline]
pub fn round_ties_even(x: f32) -> f32 {
    let r = x.round(); // half away from zero
    if (x - x.trunc()).abs() == 0.5 {
        // tie: pick the even neighbour
        let f = x.floor();
        if (f as i64) % 2 == 0 {
            f
        } else {
            f + 1.0
        }
    } else {
        r
    }
}

/// RoundClamp quantizer on [0,1] (paper Eq. 4).
#[inline]
pub fn roundclamp01(w: f32, n: f32) -> f32 {
    let levels = n.exp2();
    (round_ties_even(levels * w)).min(levels - 1.0) / (levels - 1.0)
}

/// DoReFa quantizer on [0,1] (paper Eq. 1).
#[inline]
pub fn dorefa01(w: f32, n: f32) -> f32 {
    let scale = n.exp2() - 1.0;
    round_ties_even(scale * w) / scale
}

/// Integer code of the RoundClamp quantizer at `n` bits.
#[inline]
pub fn roundclamp_code(w: f32, n: f32) -> u32 {
    let levels = n.exp2();
    (round_ties_even(levels * w)).min(levels - 1.0).max(0.0) as u32
}

/// Continuous LSB proxy B_k under RoundClamp (paper Eq. 5, [0,1] scale):
/// distance to the centre of the nearest LSB-zero n-bit bin.
#[inline]
pub fn lsb_proxy_roundclamp(w: f32, n: f32, k: f32) -> f32 {
    let lm = (n - k).exp2();
    let target = (round_ties_even(lm * w)).min(lm - 1.0) / lm;
    w - target
}

/// B_k under the DoReFa bin placement (paper Fig. 3a pathology).
#[inline]
pub fn lsb_proxy_dorefa(w: f32, n: f32, k: f32) -> f32 {
    let sc = (n - k).exp2() - 1.0;
    let target = round_ties_even(sc * w) / sc;
    w - target
}

/// Are the k LSBs of the n-bit RoundClamp code nonzero?
#[inline]
pub fn lsb_nonzero(w: f32, n: f32, k: f32) -> bool {
    let code = roundclamp_code(w, n);
    let kk = k as u32;
    code % (1u32 << kk) != 0
}

/// Map a signed weight to [0,1] with per-layer scale `s` (DESIGN.md).
#[inline]
pub fn to_unit(w: f32, scale: f32) -> f32 {
    (w / (2.0 * scale) + 0.5).clamp(0.0, 1.0)
}

/// Inverse of `to_unit` on the quantized lattice.
#[inline]
pub fn from_unit(w01: f32, scale: f32) -> f32 {
    (w01 - 0.5) * 2.0 * scale
}

/// Fake-quantize a signed slice at `n` bits (RoundClamp), per-tensor
/// max-abs scale — the host-side twin of `quant.fake_quant`.
pub fn fake_quant_slice(w: &[f32], n: f32, out: &mut Vec<f32>) {
    let scale = w.iter().fold(0.0f32, |a, &x| a.max(x.abs())) + 1e-8;
    out.clear();
    out.extend(w.iter().map(|&x| from_unit(roundclamp01(to_unit(x, scale), n), scale)));
}

/// β for a signed slice: fraction of weights whose k LSBs are nonzero.
pub fn beta_slice(w: &[f32], n: f32, k: f32) -> f32 {
    if w.is_empty() {
        return 0.0;
    }
    let scale = w.iter().fold(0.0f32, |a, &x| a.max(x.abs())) + 1e-8;
    let nz = w.iter().filter(|&&x| lsb_nonzero(to_unit(x, scale), n, k)).count();
    nz as f32 / w.len() as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundclamp_range_and_lattice() {
        for n in 2..=8 {
            for i in 0..=1000 {
                let w = i as f32 / 1000.0;
                let q = roundclamp01(w, n as f32);
                assert!((0.0..=1.0).contains(&q), "n={n} w={w} q={q}");
                let code = q * ((1 << n) - 1) as f32;
                assert!((code - code.round()).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn lsb_zero_at_bin_centres() {
        let (n, k) = (4.0, 1.0);
        let m = 2u32.pow(3);
        for j in 0..m {
            let w = j as f32 / m as f32;
            assert!(lsb_proxy_roundclamp(w, n, k).abs() < 1e-6);
            assert!(!lsb_nonzero(w, n, k), "j={j}");
        }
    }

    #[test]
    fn basin_midpoint_switch() {
        // paper Fig. 3b: odd-bin midpoint is where the MSB target switches
        let (n, k) = (3.0f32, 1.0f32);
        let eps = 1e-3;
        assert!(lsb_proxy_roundclamp(3.0 / 8.0 - eps, n, k) > 0.0);
        assert!(lsb_proxy_roundclamp(3.0 / 8.0 + eps, n, k) < 0.0);
    }

    #[test]
    fn dorefa_misalignment() {
        // fraction of LSB-zero-coded weights whose dorefa target leaves the
        // bin must be macroscopic (Fig. 3a), and zero under roundclamp
        let (n, k) = (3.0f32, 1.0f32);
        let ln = 8.0f32;
        let mut bad_df = 0;
        let mut bad_rc = 0;
        let mut zero_ct = 0;
        for i in 0..=2000 {
            let w = i as f32 / 2000.0;
            let code_rc = roundclamp_code(w, n);
            if code_rc % 2 == 0 {
                zero_ct += 1;
                if lsb_proxy_roundclamp(w, n, k).abs() > 0.5 / ln + 1e-6 {
                    bad_rc += 1;
                }
            }
            let code_df = round_ties_even((ln - 1.0) * w) as u32;
            if code_df % 2 == 0 && lsb_proxy_dorefa(w, n, k).abs() > 0.5 / ln + 1e-6 {
                bad_df += 1;
            }
        }
        assert_eq!(bad_rc, 0);
        assert!(bad_df * 10 > zero_ct, "dorefa bad {bad_df} of {zero_ct}");
    }

    #[test]
    fn unit_roundtrip() {
        for &w in &[-0.9f32, -0.3, 0.0, 0.4, 0.85] {
            let u = to_unit(w, 1.0);
            assert!((from_unit(u, 1.0) - w).abs() < 1e-6);
        }
    }

    #[test]
    fn fake_quant_error_bound() {
        // max error of n-bit fake-quant is ~ scale / 2^(n-1) per step
        let w: Vec<f32> = (0..257).map(|i| (i as f32 / 128.0) - 1.0).collect();
        let mut q = Vec::new();
        fake_quant_slice(&w, 8.0, &mut q);
        let maxerr = w.iter().zip(&q).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
        assert!(maxerr < 2.0 * 2.0 / 255.0, "maxerr {maxerr}");
    }

    #[test]
    fn beta_decreases_with_k0() {
        // k = 0 => no LSBs => beta must be 0
        let w: Vec<f32> = (0..100).map(|i| (i as f32 / 50.0) - 1.0).collect();
        assert_eq!(beta_slice(&w, 8.0, 0.0), 0.0);
    }

    #[test]
    fn ties_even_matches_xla() {
        assert_eq!(round_ties_even(0.5), 0.0);
        assert_eq!(round_ties_even(1.5), 2.0);
        assert_eq!(round_ties_even(2.5), 2.0);
        assert_eq!(round_ties_even(-0.5), -0.0);
        assert_eq!(round_ties_even(3.3), 3.0);
    }
}
