//! Packed mixed-precision model export (S1 extension).
//!
//! The paper reports compression ratios over the *nominal* bit-widths;
//! this module makes them physical: each quantized layer's weights are
//! encoded to their n-bit RoundClamp integer codes and bit-packed into a
//! contiguous stream (little-endian bit order), with per-layer scale
//! metadata, producing a `.msqpack` file whose size realizes the claimed
//! compression. `unpack` reverses the process exactly (code-exact round
//! trip), so a packed model can be re-expanded and served through the
//! same eval artifacts.
//!
//! Format (all little-endian):
//! ```text
//! magic "MSQPACK2" | u64 input_dim | u32 n_layers
//! per layer: u32 name_len | name bytes | u8 bits | f32 scale | u64 numel
//! payload:  per layer, ceil(numel * bits / 8) bytes of packed codes
//! ```
//!
//! `input_dim` is the model's input width (0 = unknown), which lets the
//! serving registry chain the MLP layer shapes without an external
//! `--input-dim`. v1 files (magic `MSQPACK1`, no `input_dim` field)
//! still load — their `input_dim` reads as 0, so consumers fall back to
//! an explicit dimension.

use std::io::Write;
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::{from_unit, roundclamp_code, to_unit};

#[derive(Clone, Debug)]
pub struct PackedLayer {
    pub name: String,
    pub bits: u8,
    pub scale: f32,
    pub numel: usize,
    pub data: Vec<u8>,
}

impl PackedLayer {
    /// Exact payload size the (bits, numel) header implies; `None` if the
    /// product overflows (a corrupt header, not a real model).
    pub fn expected_bytes(&self) -> Option<usize> {
        self.numel.checked_mul(self.bits as usize).map(|b| b.div_ceil(8))
    }

    /// Header/payload consistency check shared by `unpack_layer` and the
    /// serving registry: bit-width in range, payload neither truncated nor
    /// oversized. Overflow-safe against corrupt headers.
    pub fn validate(&self) -> Result<()> {
        if !(1..=16).contains(&self.bits) {
            bail!("layer {:?}: bits {} outside 1..=16", self.name, self.bits);
        }
        let expect = match self.expected_bytes() {
            Some(b) => b,
            None => bail!("layer {:?}: implausible numel {}", self.name, self.numel),
        };
        if self.data.len() != expect {
            bail!(
                "layer {:?}: truncated or oversized payload — {} bytes, header implies {expect} \
                 ({} x {}-bit codes)",
                self.name,
                self.data.len(),
                self.numel,
                self.bits
            );
        }
        Ok(())
    }
}

#[derive(Clone, Debug, Default)]
pub struct PackedModel {
    /// Input width of the packed network (0 = unknown; v1 files and
    /// hand-assembled models). When set, serving infers the whole MLP
    /// topology from the header alone.
    pub input_dim: usize,
    pub layers: Vec<PackedLayer>,
}

/// Bit-level writer (LSB-first within each byte).
struct BitWriter {
    out: Vec<u8>,
    cur: u64,
    nbits: u32,
}

impl BitWriter {
    fn new(capacity_bits: usize) -> Self {
        BitWriter { out: Vec::with_capacity(capacity_bits / 8 + 1), cur: 0, nbits: 0 }
    }

    fn push(&mut self, code: u32, bits: u8) {
        self.cur |= (code as u64) << self.nbits;
        self.nbits += bits as u32;
        while self.nbits >= 8 {
            self.out.push((self.cur & 0xFF) as u8);
            self.cur >>= 8;
            self.nbits -= 8;
        }
    }

    fn finish(mut self) -> Vec<u8> {
        if self.nbits > 0 {
            self.out.push((self.cur & 0xFF) as u8);
        }
        self.out
    }
}

/// Bit-level reader matching `BitWriter`.
pub(crate) struct BitReader<'a> {
    data: &'a [u8],
    pos: usize,
    cur: u64,
    nbits: u32,
}

impl<'a> BitReader<'a> {
    pub(crate) fn new(data: &'a [u8]) -> Self {
        BitReader { data, pos: 0, cur: 0, nbits: 0 }
    }

    pub(crate) fn pull(&mut self, bits: u8) -> u32 {
        while self.nbits < bits as u32 {
            let b = self.data.get(self.pos).copied().unwrap_or(0);
            self.cur |= (b as u64) << self.nbits;
            self.pos += 1;
            self.nbits += 8;
        }
        let mask = (1u64 << bits) - 1;
        let v = (self.cur & mask) as u32;
        self.cur >>= bits;
        self.nbits -= bits as u32;
        v
    }
}

/// Quantize + pack one layer's float weights at `bits` precision with the
/// standard max-abs scale.
pub fn pack_layer(name: &str, w: &[f32], bits: u8) -> PackedLayer {
    let scale = w.iter().fold(0f32, |a, &x| a.max(x.abs())) + 1e-8;
    pack_layer_scaled(name, w, bits, scale)
}

/// Quantize + pack with an explicit scale (used when re-encoding already-
/// quantized weights: idempotence requires the original lattice).
pub fn pack_layer_scaled(name: &str, w: &[f32], bits: u8, scale: f32) -> PackedLayer {
    assert!((1..=16).contains(&bits));
    let mut bw = BitWriter::new(w.len() * bits as usize);
    for &x in w {
        bw.push(roundclamp_code(to_unit(x, scale), bits as f32), bits);
    }
    PackedLayer { name: name.into(), bits, scale, numel: w.len(), data: bw.finish() }
}

/// Unpack a layer back to float weights (RoundClamp dequantization).
/// Errors (never panics) when the payload is truncated relative to the
/// `numel`/`bits` header.
pub fn unpack_layer(l: &PackedLayer) -> Result<Vec<f32>> {
    l.validate()?;
    let mut br = BitReader::new(&l.data);
    let denom = (2f32.powi(l.bits as i32) - 1.0).max(1.0);
    Ok((0..l.numel)
        .map(|_| from_unit(br.pull(l.bits) as f32 / denom, l.scale))
        .collect())
}

impl PackedModel {
    /// Random He-initialized MLP packed at the given layer widths — the
    /// shared demo/bench/test substrate behind `msq pack-synth`, the
    /// `serve_throughput` bench, and the serve e2e tests. `bits[l]`
    /// quantizes the `dims[l] -> dims[l+1]` layer.
    pub fn synth_mlp(dims: &[usize], bits: &[u8], seed: u64) -> Result<PackedModel> {
        if dims.len() < 2 || dims.iter().any(|&d| d == 0) {
            bail!("synth_mlp: need >= 2 nonzero widths, got {dims:?}");
        }
        if bits.len() != dims.len() - 1 {
            bail!("synth_mlp: {} bit-widths for {} layers", bits.len(), dims.len() - 1);
        }
        let mut rng = crate::util::prng::Rng::new(seed);
        let mut pm = PackedModel { input_dim: dims[0], ..Default::default() };
        for l in 0..dims.len() - 1 {
            let (cin, cout) = (dims[l], dims[l + 1]);
            let std = (2.0 / cin as f32).sqrt(); // He init: keeps logits sane
            let w: Vec<f32> = (0..cin * cout).map(|_| rng.normal() * std).collect();
            pm.layers.push(pack_layer(&format!("fc{l}"), &w, bits[l]));
        }
        Ok(pm)
    }

    /// Physical payload bytes (what the compression ratio is about).
    pub fn payload_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.data.len()).sum()
    }

    pub fn fp32_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.numel * 4).sum()
    }

    /// Realized compression vs FP32 payload.
    pub fn compression(&self) -> f64 {
        self.fp32_bytes() as f64 / self.payload_bytes().max(1) as f64
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(b"MSQPACK2")?;
        f.write_all(&(self.input_dim as u64).to_le_bytes())?;
        f.write_all(&(self.layers.len() as u32).to_le_bytes())?;
        for l in &self.layers {
            f.write_all(&(l.name.len() as u32).to_le_bytes())?;
            f.write_all(l.name.as_bytes())?;
            f.write_all(&[l.bits])?;
            f.write_all(&l.scale.to_le_bytes())?;
            f.write_all(&(l.numel as u64).to_le_bytes())?;
        }
        for l in &self.layers {
            f.write_all(&l.data)?;
        }
        Ok(())
    }

    pub fn load(path: &Path) -> Result<PackedModel> {
        let bytes = std::fs::read(path).with_context(|| format!("{path:?}"))?;
        let mut p = 0usize;
        let take = |p: &mut usize, n: usize| -> Result<&[u8]> {
            if *p + n > bytes.len() {
                bail!("truncated msqpack at byte {p}");
            }
            let s = &bytes[*p..*p + n];
            *p += n;
            Ok(s)
        };
        let input_dim = match take(&mut p, 8)? {
            b"MSQPACK2" => u64::from_le_bytes(take(&mut p, 8)?.try_into().unwrap()) as usize,
            b"MSQPACK1" => 0, // pre-v2 pack: input width unknown
            _ => bail!("bad magic"),
        };
        let n_layers = u32::from_le_bytes(take(&mut p, 4)?.try_into().unwrap()) as usize;
        // each layer header is >= 17 bytes; reject absurd counts before
        // allocating (corrupt-file hardening)
        if n_layers > bytes.len() / 17 {
            bail!("implausible layer count {n_layers} for {} bytes", bytes.len());
        }
        let mut layers = Vec::with_capacity(n_layers);
        for _ in 0..n_layers {
            let name_len = u32::from_le_bytes(take(&mut p, 4)?.try_into().unwrap()) as usize;
            let name = String::from_utf8(take(&mut p, name_len)?.to_vec())?;
            let bits = take(&mut p, 1)?[0];
            let scale = f32::from_le_bytes(take(&mut p, 4)?.try_into().unwrap());
            let numel = u64::from_le_bytes(take(&mut p, 8)?.try_into().unwrap()) as usize;
            layers.push(PackedLayer { name, bits, scale, numel, data: Vec::new() });
        }
        for l in layers.iter_mut() {
            let nbytes = match l.expected_bytes() {
                // payload can't exceed the file either way
                Some(b) if b <= bytes.len() => b,
                _ => bail!(
                    "layer {:?}: implausible numel {} for {} file bytes",
                    l.name,
                    l.numel,
                    bytes.len()
                ),
            };
            l.data = take(&mut p, nbytes)?.to_vec();
        }
        Ok(PackedModel { input_dim, layers })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn rand_weights(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| r.normal() * 0.2).collect()
    }

    #[test]
    fn repeated_requantization_converges() {
        // RoundClamp is NOT idempotent (the output value i/(2^n - 1) sits
        // outside bin i for codes above (2^n - 1)/2 — inherent to the
        // paper's Eq. 4 scaling mismatch between the 2^n rounding grid and
        // the 2^n - 1 output lattice). Re-quantizing an already-quantized
        // tensor therefore walks upper codes toward the clamp; packing is
        // applied ONCE per export in practice. This test pins the
        // behaviour: codes are monotone non-decreasing under re-encoding
        // and reach a fixed point within 2^bits cycles.
        for bits in [1u8, 2, 3, 4, 5, 8] {
            let w = rand_weights(500, bits as u64);
            let p1 = pack_layer("l", &w, bits);
            let mut prev = p1.clone();
            let mut converged = false;
            for _ in 0..(1usize << bits) + 1 {
                let wv = unpack_layer(&prev).unwrap();
                let next = pack_layer_scaled("l", &wv, bits, p1.scale);
                // monotone: codes never decrease cycle-over-cycle
                let mut ra = super::BitReader::new(&prev.data);
                let mut rb = super::BitReader::new(&next.data);
                for _ in 0..prev.numel {
                    let a = ra.pull(bits);
                    let b = rb.pull(bits);
                    assert!(b >= a, "bits {bits}: code decreased {a} -> {b}");
                }
                if next.data == prev.data {
                    converged = true;
                    break;
                }
                prev = next;
            }
            assert!(converged, "bits {bits}: no fixed point within 2^bits cycles");
        }
    }

    #[test]
    fn quantization_error_bounded() {
        let w = rand_weights(4096, 7);
        let packed = pack_layer("l", &w, 8);
        let back = unpack_layer(&packed).unwrap();
        let scale = w.iter().fold(0f32, |a, &x| a.max(x.abs())) + 1e-8;
        let bound = 2.0 * scale * 2.0 / 255.0;
        for (a, b) in w.iter().zip(&back) {
            assert!((a - b).abs() <= bound, "{a} vs {b}");
        }
    }

    #[test]
    fn payload_size_matches_bits() {
        let w = rand_weights(1000, 3);
        for bits in [2u8, 3, 4] {
            let p = pack_layer("l", &w, bits);
            assert_eq!(p.data.len(), (1000 * bits as usize).div_ceil(8));
        }
    }

    #[test]
    fn model_file_roundtrip() {
        let mut m = PackedModel::default();
        m.layers.push(pack_layer("conv1", &rand_weights(300, 1), 3));
        m.layers.push(pack_layer("fc", &rand_weights(1000, 2), 2));
        let path = std::env::temp_dir().join("msq_pack_test.msqpack");
        m.save(&path).unwrap();
        let back = PackedModel::load(&path).unwrap();
        assert_eq!(back.layers.len(), 2);
        for (a, b) in m.layers.iter().zip(&back.layers) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.bits, b.bits);
            assert_eq!(a.data, b.data);
            assert_eq!(a.numel, b.numel);
        }
    }

    #[test]
    fn realized_compression_matches_nominal() {
        let mut m = PackedModel::default();
        m.layers.push(pack_layer("a", &rand_weights(10_000, 2), 2));
        // 32/2 = 16x nominal; packed adds only sub-byte padding
        let c = m.compression();
        assert!((c - 16.0).abs() < 0.1, "{c}");
    }

    #[test]
    fn synth_mlp_is_seed_reproducible() {
        // `msq pack-synth --seed S` threads S straight into weight
        // generation: identical seeds must produce byte-identical packs
        // (serve e2e fixtures depend on this), different seeds must not.
        let dims = [24usize, 16, 4];
        let bits = [4u8, 3];
        let a = PackedModel::synth_mlp(&dims, &bits, 42).unwrap();
        let b = PackedModel::synth_mlp(&dims, &bits, 42).unwrap();
        for (la, lb) in a.layers.iter().zip(&b.layers) {
            assert_eq!(la.data, lb.data);
            assert_eq!(la.scale, lb.scale);
        }
        let c = PackedModel::synth_mlp(&dims, &bits, 43).unwrap();
        assert!(
            a.layers.iter().zip(&c.layers).any(|(x, y)| x.data != y.data),
            "different seeds produced identical packs"
        );
    }

    #[test]
    fn v2_header_roundtrips_input_dim() {
        let pm = PackedModel::synth_mlp(&[24, 16, 4], &[4, 3], 7).unwrap();
        assert_eq!(pm.input_dim, 24);
        let path = std::env::temp_dir().join("msq_pack_v2.msqpack");
        pm.save(&path).unwrap();
        let back = PackedModel::load(&path).unwrap();
        assert_eq!(back.input_dim, 24);
        assert_eq!(back.layers.len(), 2);
    }

    #[test]
    fn v1_files_still_load_with_unknown_dim() {
        // hand-write a v1 file: old magic, no input_dim field
        let l = pack_layer("fc0", &rand_weights(12, 1), 4);
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"MSQPACK1");
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(&(l.name.len() as u32).to_le_bytes());
        bytes.extend_from_slice(l.name.as_bytes());
        bytes.push(l.bits);
        bytes.extend_from_slice(&l.scale.to_le_bytes());
        bytes.extend_from_slice(&(l.numel as u64).to_le_bytes());
        bytes.extend_from_slice(&l.data);
        let path = std::env::temp_dir().join("msq_pack_v1.msqpack");
        std::fs::write(&path, &bytes).unwrap();
        let back = PackedModel::load(&path).unwrap();
        assert_eq!(back.input_dim, 0, "v1 packs carry no input width");
        assert_eq!(back.layers[0].numel, 12);
        assert_eq!(unpack_layer(&back.layers[0]).unwrap().len(), 12);
    }

    #[test]
    fn corrupt_file_rejected() {
        let path = std::env::temp_dir().join("msq_pack_bad.msqpack");
        std::fs::write(&path, b"NOTPACK!").unwrap();
        assert!(PackedModel::load(&path).is_err());
        std::fs::write(&path, b"MSQPACK1\xff\xff\xff\xff").unwrap();
        assert!(PackedModel::load(&path).is_err());
    }

    #[test]
    fn one_bit_layers_pack() {
        let w = rand_weights(77, 9);
        let p = pack_layer("l", &w, 1);
        assert_eq!(p.data.len(), 10); // ceil(77/8)
        let back = unpack_layer(&p).unwrap();
        assert_eq!(back.len(), 77);
    }

    #[test]
    fn prop_roundtrip_code_exact_any_bits_any_length() {
        // bits 1..=8, lengths chosen to hit non-byte-aligned stream ends:
        // unpacked floats must equal the dequantization of the per-element
        // codes computed independently, and the payload must be bit-exact
        // in size with zeroed trailing padding bits.
        crate::util::prop::check(200, |g| {
            let bits = g.usize_in(1, 8) as u8;
            let n = g.usize_in(0, 67);
            let w = g.vec_normal(n, 0.3);
            let p = pack_layer("l", &w, bits);
            crate::util::prop::ensure(
                p.data.len() == (n * bits as usize).div_ceil(8),
                format!("payload {} for n={n} bits={bits}", p.data.len()),
            )?;
            let back = unpack_layer(&p).map_err(|e| e.to_string())?;
            crate::util::prop::ensure(back.len() == n, "length mismatch")?;
            let denom = (2f32.powi(bits as i32) - 1.0).max(1.0);
            for (i, &x) in w.iter().enumerate() {
                let code = roundclamp_code(to_unit(x, p.scale), bits as f32);
                let expect = from_unit(code as f32 / denom, p.scale);
                crate::util::prop::ensure(
                    back[i] == expect,
                    format!("elem {i}: {} != {expect} (bits {bits})", back[i]),
                )?;
            }
            // trailing padding bits of the last byte must be zero
            let used_bits = n * bits as usize;
            if used_bits % 8 != 0 {
                let last = *p.data.last().unwrap();
                let pad_mask = !((1u16 << (used_bits % 8)) - 1) as u8;
                crate::util::prop::ensure(
                    last & pad_mask == 0,
                    format!("nonzero padding bits {last:#010b}"),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn empty_layer_roundtrips_through_file() {
        let mut m = PackedModel::default();
        m.layers.push(pack_layer("empty", &[], 4));
        m.layers.push(pack_layer("tail", &rand_weights(13, 5), 3)); // 39 bits: unaligned
        let path = std::env::temp_dir().join("msq_pack_empty.msqpack");
        m.save(&path).unwrap();
        let back = PackedModel::load(&path).unwrap();
        assert_eq!(back.layers[0].numel, 0);
        assert!(back.layers[0].data.is_empty());
        assert_eq!(unpack_layer(&back.layers[0]).unwrap(), Vec::<f32>::new());
        assert_eq!(unpack_layer(&back.layers[1]).unwrap().len(), 13);
    }

    #[test]
    fn truncated_payload_is_error_not_panic() {
        let mut p = pack_layer("l", &rand_weights(40, 2), 3);
        p.data.pop();
        let err = unpack_layer(&p).unwrap_err().to_string();
        assert!(err.contains("truncated"), "{err}");

        // oversized payloads are rejected too (corrupt header vs payload)
        let mut q = pack_layer("l", &rand_weights(8, 2), 2);
        q.data.push(0);
        assert!(unpack_layer(&q).is_err());

        // bits outside the packable range
        let bad =
            PackedLayer { name: "b".into(), bits: 17, scale: 1.0, numel: 1, data: vec![0; 3] };
        assert!(unpack_layer(&bad).is_err());

        // overflow-scale numel in a corrupt header: error, not a panic
        let huge = PackedLayer {
            name: "h".into(),
            bits: 8,
            scale: 1.0,
            numel: usize::MAX / 4,
            data: Vec::new(),
        };
        assert!(unpack_layer(&huge).is_err());
    }

    #[test]
    fn truncated_file_is_error_not_panic() {
        let mut m = PackedModel::default();
        m.layers.push(pack_layer("a", &rand_weights(100, 4), 5));
        let path = std::env::temp_dir().join("msq_pack_trunc.msqpack");
        m.save(&path).unwrap();
        let full = std::fs::read(&path).unwrap();
        // chop the file at several points: header, layer table, payload
        for cut in [4usize, 9, 20, full.len() - 1] {
            std::fs::write(&path, &full[..cut.min(full.len())]).unwrap();
            assert!(PackedModel::load(&path).is_err(), "cut at {cut} must fail");
        }
        std::fs::write(&path, &full).unwrap();
        assert!(PackedModel::load(&path).is_ok());
    }
}
